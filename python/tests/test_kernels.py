"""L1 correctness: Pallas kernels vs pure-jnp oracle.

hypothesis sweeps grid shapes, block sizes and dtypes; every case asserts
allclose between `star13_pallas` / `jacobi_step_pallas` (interpret mode)
and `ref.py`.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import STAR13, jacobi_step_ref, star13_ref
from compile.kernels.star13 import (
    R,
    choose_block_z,
    jacobi_step_pallas,
    star13_pallas,
    vmem_report,
)

jax.config.update("jax_platform_name", "cpu")
jax.config.update("jax_enable_x64", True)  # for the f64 oracle cases


def rand(shape, dtype=jnp.float32, seed=0):
    rng = np.random.RandomState(seed)
    return jnp.asarray(rng.standard_normal(shape), dtype)


def tol(dtype):
    return dict(rtol=2e-4, atol=2e-4) if dtype == jnp.float32 else dict(rtol=1e-10, atol=1e-10)


class TestStar13Weights:
    def test_thirteen_points(self):
        assert len(STAR13) == 13
        assert len({(dx, dy, dz) for dx, dy, dz, _ in STAR13}) == 13

    def test_weights_sum_to_zero(self):
        assert abs(sum(w for *_, w in STAR13)) < 1e-12

    def test_symmetric(self):
        pts = {(dx, dy, dz): w for dx, dy, dz, w in STAR13}
        for (dx, dy, dz), w in pts.items():
            assert pts[(-dx, -dy, -dz)] == w


class TestStar13Kernel:
    @pytest.mark.parametrize("shape", [(8, 8, 8), (16, 12, 10), (5, 9, 6), (32, 8, 16)])
    def test_matches_ref(self, shape):
        u = rand(shape)
        got = star13_pallas(u)
        want = star13_ref(u)
        np.testing.assert_allclose(got, want, **tol(jnp.float32))

    @pytest.mark.parametrize("bz", [1, 2, 4, 8])
    def test_block_size_invariance(self, bz):
        u = rand((12, 10, 8), seed=3)
        got = star13_pallas(u, block_z=bz)
        want = star13_ref(u)
        np.testing.assert_allclose(got, want, **tol(jnp.float32))

    def test_f64(self):
        u = rand((9, 7, 5), dtype=jnp.float64, seed=4)
        np.testing.assert_allclose(
            star13_pallas(u), star13_ref(u), **tol(jnp.float64)
        )

    def test_zero_input(self):
        u = jnp.zeros((8, 8, 8), jnp.float32)
        assert jnp.all(star13_pallas(u) == 0)

    def test_constant_interior_annihilated(self):
        # weights sum to 0 ⇒ interior of a constant field maps to ~0.
        u = jnp.ones((16, 16, 16), jnp.float32)
        q = star13_pallas(u)
        interior = q[2 * R : -2 * R, 2 * R : -2 * R, 2 * R : -2 * R]
        np.testing.assert_allclose(interior, 0.0, atol=1e-5)

    def test_linearity(self):
        a, b = rand((8, 8, 8), seed=5), rand((8, 8, 8), seed=6)
        lhs = star13_pallas(2.0 * a + 3.0 * b)
        rhs = 2.0 * star13_pallas(a) + 3.0 * star13_pallas(b)
        np.testing.assert_allclose(lhs, rhs, rtol=1e-3, atol=1e-3)

    @settings(max_examples=25, deadline=None)
    @given(
        nx=st.integers(5, 20),
        ny=st.integers(5, 20),
        nz=st.integers(5, 16),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_shapes(self, nx, ny, nz, seed):
        u = rand((nx, ny, nz), seed=seed % 1000)
        np.testing.assert_allclose(
            star13_pallas(u), star13_ref(u), **tol(jnp.float32)
        )


class TestJacobiKernel:
    @pytest.mark.parametrize("alpha", [0.0, 0.05, -0.01])
    def test_matches_ref(self, alpha):
        u = rand((10, 12, 8), seed=7)
        got = jacobi_step_pallas(u, alpha)
        want = jacobi_step_ref(u, alpha)
        np.testing.assert_allclose(got, want, **tol(jnp.float32))

    def test_alpha_zero_is_identity(self):
        u = rand((8, 8, 8), seed=8)
        np.testing.assert_allclose(jacobi_step_pallas(u, 0.0), u, rtol=1e-6)

    def test_fused_equals_two_pass(self):
        u = rand((8, 8, 8), seed=9)
        fused = jacobi_step_pallas(u, 0.05)
        two_pass = u + 0.05 * star13_pallas(u)
        np.testing.assert_allclose(fused, two_pass, rtol=1e-5, atol=1e-5)

    @settings(max_examples=15, deadline=None)
    @given(
        nx=st.integers(5, 16),
        nz=st.integers(5, 12),
        alpha=st.floats(-0.1, 0.1, allow_nan=False),
    )
    def test_hypothesis(self, nx, nz, alpha):
        u = rand((nx, 8, nz), seed=nx * 31 + nz)
        np.testing.assert_allclose(
            jacobi_step_pallas(u, alpha),
            jacobi_step_ref(u, alpha),
            rtol=5e-4,
            atol=5e-4,
        )


class TestBlockChooser:
    def test_divides(self):
        for nz in [5, 8, 12, 64, 97]:
            bz = choose_block_z((16, 16, nz))
            assert nz % bz == 0

    def test_respects_budget(self):
        shape = (64, 64, 64)
        budget = 80_000
        bz = choose_block_z(shape, budget)
        assert (shape[0] + 2 * R) * (shape[1] + 2 * R) * (bz + 2 * R) <= budget

    def test_prefers_bigger_blocks(self):
        small = choose_block_z((16, 16, 64), budget_words=10_000)
        big = choose_block_z((16, 16, 64), budget_words=10_000_000)
        assert big >= small
        assert big == 64  # whole axis fits the big budget

    def test_vmem_report_fields(self):
        rep = vmem_report((64, 64, 64))
        assert rep["vmem_words"] <= 4 * (1 << 20)
        assert 0.0 < rep["halo_overhead"] < 5.0
