"""Test bootstrap: put ``python/`` on sys.path so ``compile.*`` imports
resolve, and provide a minimal in-repo fallback for ``hypothesis`` when the
real package is unavailable (offline CI images bake in jax/numpy/pytest but
not necessarily hypothesis).

The fallback implements just the surface these tests use — ``given``,
``settings`` and the ``integers``/``floats`` strategies — running a fixed
number of deterministically seeded examples per test. It does no shrinking;
it exists so the suite stays runnable (and still sweeps dozens of sampled
cases) without the dependency.
"""

import os
import random
import sys
import types

sys.path.insert(0, os.path.abspath(os.path.join(os.path.dirname(__file__), "..")))

try:
    import hypothesis  # noqa: F401
except ImportError:

    class _Strategy:
        def __init__(self, sample):
            self.sample = sample

    def _integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def _floats(min_value=None, max_value=None, allow_nan=True, **_kw):
        lo = -1e9 if min_value is None else min_value
        hi = 1e9 if max_value is None else max_value
        return _Strategy(lambda rng: rng.uniform(lo, hi))

    def _settings(max_examples=20, deadline=None, **_kw):
        def deco(fn):
            fn._hyp_max_examples = max_examples
            return fn

        return deco

    def _given(**strategies):
        def deco(fn):
            def wrapper(*args, **kwargs):
                rng = random.Random(0xC0FFEE)
                for _ in range(getattr(wrapper, "_hyp_max_examples", 20)):
                    drawn = {k: s.sample(rng) for k, s in strategies.items()}
                    fn(*args, **drawn, **kwargs)

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper

        return deco

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = types.SimpleNamespace(integers=_integers, floats=_floats)
    _hyp.__doc__ = "minimal offline fallback installed by python/tests/conftest.py"
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _hyp.strategies
