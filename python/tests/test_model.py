"""L2 correctness: model graphs (jacobi sweep, norms) and AOT lowering."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.aot import ALPHA, SWEEP_STEPS, entries_for_shape, to_hlo_text
from compile.kernels.ref import jacobi_run_ref, norms_ref

jax.config.update("jax_platform_name", "cpu")


def rand(shape, seed=0):
    rng = np.random.RandomState(seed)
    return jnp.asarray(rng.standard_normal(shape), jnp.float32)


class TestModel:
    def test_sweep_equals_repeated_steps(self):
        u = rand((8, 8, 8), seed=1)
        swept = model.jacobi_sweep(u, 0.05, 4)
        stepped = u
        for _ in range(4):
            stepped = model.jacobi_step(stepped, 0.05)
        np.testing.assert_allclose(swept, stepped, rtol=1e-5, atol=1e-5)

    def test_sweep_matches_ref(self):
        u = rand((6, 7, 8), seed=2)
        np.testing.assert_allclose(
            model.jacobi_sweep(u, 0.05, 3),
            jacobi_run_ref(u, 0.05, 3),
            rtol=2e-4,
            atol=2e-4,
        )

    def test_heat_decays_energy(self):
        # Explicit heat step with stable α must not increase ‖u‖ (zero BC).
        u = rand((12, 12, 12), seed=3)
        n0 = float(jnp.linalg.norm(u))
        v = model.jacobi_sweep(u, ALPHA, 50)
        n1 = float(jnp.linalg.norm(v))
        assert n1 < n0, f"{n1} !< {n0}"
        assert np.isfinite(n1)

    def test_norms_match_ref(self):
        u = rand((8, 9, 10), seed=4)
        got = model.norms(u)
        want = norms_ref(u)
        np.testing.assert_allclose(got[0], want[0], rtol=1e-5)
        np.testing.assert_allclose(got[1], want[1], rtol=1e-4)

    def test_step_with_norms_consistent(self):
        u = rand((8, 8, 8), seed=5)
        v, ns = model.step_with_norms(u, 0.05)
        np.testing.assert_allclose(v, model.jacobi_step(u, 0.05), rtol=1e-6)
        np.testing.assert_allclose(ns, model.norms(v), rtol=1e-6)


class TestLowering:
    @pytest.mark.parametrize("n", [8])
    def test_all_entries_lower_to_hlo_text(self, n):
        for name, fn, args, n_outputs, _ in entries_for_shape(n):
            lowered = jax.jit(fn).lower(*args)
            text = to_hlo_text(lowered)
            assert "ENTRY" in text, name
            assert "HloModule" in text, name
            # 64-bit-id safety: text parser reassigns ids; nothing to check
            # beyond non-emptiness and structure.
            assert len(text) > 200, name

    def test_sweep_hlo_is_compact(self):
        # fori_loop must lower to a while loop, not SWEEP_STEPS unrolled
        # kernel bodies: the sweep HLO stays within ~4× of the single step.
        n = 8
        entries = {e[0]: e for e in entries_for_shape(n)}
        step = entries[f"jacobi_step_{n}"]
        sweep = entries[f"jacobi_sweep_{n}x{SWEEP_STEPS}"]
        step_text = to_hlo_text(jax.jit(step[1]).lower(*step[2]))
        sweep_text = to_hlo_text(jax.jit(sweep[1]).lower(*sweep[2]))
        assert len(sweep_text) < 4 * len(step_text), (
            len(sweep_text),
            len(step_text),
        )

    def test_manifest_entry_names_unique(self):
        names = [e[0] for n in (8, 16) for e in entries_for_shape(n)]
        assert len(names) == len(set(names))
