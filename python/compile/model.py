"""L2: the JAX compute graph built on the L1 Pallas kernels.

Build-time only — these functions are lowered once by `aot.py` to HLO text
and executed forever after from the rust runtime. Python never runs on the
request path.

The model is the paper's workload: explicit evaluation of the 13-point
star operator `q = Ku` on a 3-D structured grid, plus the explicit heat
solver (damped Jacobi sweeps) that the end-to-end example drives.
"""

import jax
import jax.numpy as jnp

from .kernels.star13 import jacobi_step_pallas, star13_pallas


def star13_apply(u):
    """q = Ku (single stencil application)."""
    return star13_pallas(u)


def jacobi_step(u, alpha):
    """One explicit heat/Jacobi step: u' = u + α·Ku (fused Pallas kernel)."""
    return jacobi_step_pallas(u, alpha)


def jacobi_sweep(u, alpha, steps: int):
    """`steps` fused Jacobi steps inside one compiled graph.

    `lax.fori_loop` keeps the HLO size O(1) in `steps` (a while-loop in
    HLO), instead of unrolling the kernel body `steps` times.
    """

    def body(_, v):
        return jacobi_step_pallas(v, alpha)

    return jax.lax.fori_loop(0, steps, body, u)


def norms(u):
    """(‖u‖₂, ‖Ku‖₂) packed as a length-2 vector — the convergence metrics
    the e2e driver logs per step."""
    ku = star13_pallas(u)
    return jnp.stack([jnp.sqrt(jnp.sum(u * u)), jnp.sqrt(jnp.sum(ku * ku))])


def step_with_norms(u, alpha):
    """Fused service call for the solver hot loop: one Jacobi step plus the
    metrics of the *new* iterate, in a single PJRT execution."""
    v = jacobi_step_pallas(u, alpha)
    return v, norms(v)
