"""AOT lowering: JAX (L2 + L1) → HLO text artifacts for the rust runtime.

Interchange format is HLO **text**, not serialized HloModuleProto: jax ≥ 0.5
emits protos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Each artifact is lowered with `return_tuple=True`, so the rust side unwraps
with `to_tuple1()` (or indexes the tuple for multi-output entries).

Usage:  python -m compile.aot --out-dir ../artifacts [--shapes 32,64]

Writes `<name>.hlo.txt` per entry point plus `manifest.json` describing
every artifact (name, inputs, outputs, dtype) for the rust artifact
registry.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Default e2e solver step-count compiled into the sweep artifact.
SWEEP_STEPS = 10
# Heat-stable step size for the 13-point star: |α|·Σ|w| < 1 ⇒ α ≤ 0.05.
ALPHA = 0.05


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the 0.5.1-compatible path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def entries_for_shape(n: int):
    """The artifact set for one cubic grid extent n."""
    spec = jax.ShapeDtypeStruct((n, n, n), jnp.float32)

    def sweep(u):
        return model.jacobi_sweep(u, ALPHA, SWEEP_STEPS)

    def step(u):
        return model.jacobi_step(u, ALPHA)

    def step_norms(u):
        return model.step_with_norms(u, ALPHA)

    return [
        # (name, fn, example args, output arity, description)
        (f"star13_{n}", model.star13_apply, (spec,), 1, "q = Ku, 13-pt star"),
        (f"jacobi_step_{n}", step, (spec,), 1, f"u + {ALPHA}*Ku"),
        (f"jacobi_sweep_{n}x{SWEEP_STEPS}", sweep, (spec,), 1, f"{SWEEP_STEPS} fused steps"),
        (f"norms_{n}", model.norms, (spec,), 1, "[||u||, ||Ku||]"),
        (f"step_norms_{n}", step_norms, (spec,), 2, "(u', [||u'||, ||Ku'||])"),
    ]


def lower_all(out_dir: str, shapes) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"alpha": ALPHA, "sweep_steps": SWEEP_STEPS, "artifacts": []}
    for n in shapes:
        for name, fn, args, n_outputs, desc in entries_for_shape(n):
            lowered = jax.jit(fn).lower(*args)
            text = to_hlo_text(lowered)
            path = os.path.join(out_dir, f"{name}.hlo.txt")
            with open(path, "w") as f:
                f.write(text)
            manifest["artifacts"].append(
                {
                    "name": name,
                    "file": f"{name}.hlo.txt",
                    "input_shape": [n, n, n],
                    "dtype": "f32",
                    "n_outputs": n_outputs,
                    "description": desc,
                }
            )
            print(f"wrote {path} ({len(text)} chars)")
    manifest_path = os.path.join(out_dir, "manifest.json")
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {manifest_path}")
    return manifest


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--shapes",
        default="16,32,64",
        help="comma-separated cubic grid extents to compile",
    )
    args = ap.parse_args()
    shapes = [int(s) for s in args.shapes.split(",") if s]
    lower_all(args.out_dir, shapes)


if __name__ == "__main__":
    main()
