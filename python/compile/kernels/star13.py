"""L1 Pallas kernel: the paper's 13-point second-order star stencil.

Hardware adaptation (DESIGN.md §3). The paper tiles a hardware-indexed
cache by the fundamental parallelepiped of the interference lattice; on TPU
the fast memory (VMEM) is a *software-managed* scratchpad, so there is no
interference lattice to dodge — what survives of the paper's algorithm is
its **surface-to-volume objective**: choose the HBM→VMEM block so that halo
traffic (the analogue of pencil-boundary replacement loads) is minimal for
the VMEM budget. `choose_block_z` implements that objective for the z-sliced
sweep this kernel uses:

- x,y are kept whole (the face `F` of the sweep; contiguous in the
  (8,128)-tiled register layout),
- z is blocked: each program instance receives an *overlapping* window
  `[k·bz − r, k·bz + bz + r)` of the zero-padded input (element-offset
  indexing — `pl.unblocked` here, `pl.Element` in newer jax), computes one
  z-slab of the output, and the Pallas pipeline
  double-buffers consecutive windows — the moral equivalent of the paper's
  scanning face `F + k·w` sweeping a pencil.

The kernel must be lowered with `interpret=True`: the CPU PJRT plugin
cannot execute Mosaic custom-calls, and the interpret path produces plain
HLO that the rust runtime loads (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import STAR13

R = 2  # stencil radius

# VMEM budget for one input window, in words. Real TPUs have ~16 MiB of
# VMEM per core; we target ≤ 1 MiW (4 MiB f32) for the window so that
# double-buffering input + output + accumulator head-room fits comfortably.
VMEM_BUDGET_WORDS = 1 << 20


def choose_block_z(shape, budget_words=VMEM_BUDGET_WORDS):
    """Pick the z-block size: the largest divisor `bz` of nz whose padded
    window (nx+2r)(ny+2r)(bz+2r) fits the VMEM budget.

    Surface-to-volume: halo traffic per block is ∝ (bz+2r)/bz, so larger bz
    is strictly better until the budget bites — the 1-D specialization of
    the paper's Eq 11 objective.
    """
    nx, ny, nz = shape
    face = (nx + 2 * R) * (ny + 2 * R)
    best = 1
    for bz in range(1, nz + 1):
        if nz % bz == 0 and face * (bz + 2 * R) <= budget_words:
            best = bz
    return best


def _star13_kernel(u_ref, o_ref):
    """One program instance: apply the star to a (nx, ny, bz) output slab
    from its haloed (nx+2r, ny+2r, bz+2r) input window."""
    u = u_ref[...]
    nx = o_ref.shape[0]
    ny = o_ref.shape[1]
    bz = o_ref.shape[2]
    acc = jnp.zeros(o_ref.shape, u.dtype)
    for dx, dy, dz, w in STAR13:
        acc = acc + jnp.asarray(w, u.dtype) * u[
            R + dx : R + dx + nx, R + dy : R + dy + ny, R + dz : R + dz + bz
        ]
    o_ref[...] = acc


def _fused_jacobi_kernel(u_ref, uwin_ref, alpha_ref, o_ref):
    """Fused u' = u + α·Ku: reads the unpadded slab (for u) and the haloed
    window (for Ku); one pass through VMEM instead of two."""
    nx, ny, bz = o_ref.shape
    u = uwin_ref[...]
    acc = jnp.zeros(o_ref.shape, u.dtype)
    for dx, dy, dz, w in STAR13:
        acc = acc + jnp.asarray(w, u.dtype) * u[
            R + dx : R + dx + nx, R + dy : R + dy + ny, R + dz : R + dz + bz
        ]
    alpha = alpha_ref[0]
    o_ref[...] = u_ref[...] + alpha.astype(u.dtype) * acc


def _specs(shape, bz):
    nx, ny, nz = shape
    # Overlapping z-windows need *element* indexing: program k reads the
    # padded slab starting at element k·bz (windows of bz+2r planes overlap
    # by 2r). jax 0.4.x spells this `indexing_mode=pl.unblocked` (index map
    # returns element offsets for every dim); newer jax replaced that with
    # per-dim `pl.Element` markers. Branch on the API so the kernel runs on
    # both generations.
    if hasattr(pl, "Element"):
        in_win = pl.BlockSpec(
            (nx + 2 * R, ny + 2 * R, pl.Element(bz + 2 * R, padding=(0, 0))),
            lambda k: (0, 0, k * bz),
        )
    else:
        in_win = pl.BlockSpec(
            (nx + 2 * R, ny + 2 * R, bz + 2 * R),
            lambda k: (0, 0, k * bz),
            indexing_mode=pl.unblocked,
        )
    out_spec = pl.BlockSpec((nx, ny, bz), lambda k: (0, 0, k))
    return in_win, out_spec


def star13_pallas(u, block_z=None, interpret=True):
    """q = Ku over the full grid with zero (Dirichlet) halo.

    `u`: (nx, ny, nz) array. `block_z`: override the VMEM block chooser
    (must divide nz).
    """
    shape = u.shape
    nx, ny, nz = shape
    bz = block_z or choose_block_z(shape)
    assert nz % bz == 0, f"block_z={bz} must divide nz={nz}"
    up = jnp.pad(u, R)
    in_win, out_spec = _specs(shape, bz)
    return pl.pallas_call(
        _star13_kernel,
        grid=(nz // bz,),
        in_specs=[in_win],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct(shape, u.dtype),
        interpret=interpret,
    )(up)


def jacobi_step_pallas(u, alpha, block_z=None, interpret=True):
    """u' = u + α·Ku (fused single-pass kernel). `alpha` is a scalar."""
    shape = u.shape
    nx, ny, nz = shape
    bz = block_z or choose_block_z(shape)
    assert nz % bz == 0, f"block_z={bz} must divide nz={nz}"
    up = jnp.pad(u, R)
    in_win, out_spec = _specs(shape, bz)
    u_spec = pl.BlockSpec((nx, ny, bz), lambda k: (0, 0, k))
    alpha_arr = jnp.asarray(alpha, u.dtype).reshape(1)
    alpha_spec = pl.BlockSpec((1,), lambda k: (0,))
    return pl.pallas_call(
        _fused_jacobi_kernel,
        grid=(nz // bz,),
        in_specs=[u_spec, in_win, alpha_spec],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct(shape, u.dtype),
        interpret=interpret,
    )(u, up, alpha_arr)


@functools.lru_cache(maxsize=None)
def vmem_report(shape, block_z=None):
    """Estimated VMEM footprint (words) and halo-traffic overhead of the
    chosen blocking — the quantities DESIGN.md §Perf reports for real-TPU
    estimates (interpret-mode wallclock is *not* a TPU proxy)."""
    nx, ny, nz = shape
    bz = block_z or choose_block_z(shape)
    window = (nx + 2 * R) * (ny + 2 * R) * (bz + 2 * R)
    out_block = nx * ny * bz
    halo_overhead = window / ((nx * ny) * bz) - 1.0
    return {
        "block_z": bz,
        "window_words": window,
        "out_block_words": out_block,
        # double-buffered in + out resident simultaneously
        "vmem_words": 2 * (window + out_block),
        "halo_overhead": halo_overhead,
    }
