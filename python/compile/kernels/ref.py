"""Pure-jnp correctness oracles for the Pallas kernels (L1).

Every kernel in this package has a reference implementation here built only
from `jnp.pad` + static slicing. pytest sweeps shapes/dtypes (hypothesis)
and asserts allclose between kernel and oracle — this is the build-time
correctness gate for the AOT artifacts the rust runtime executes.

The stencil is the paper's measurement operator: the 13-point second-order
star in 3-D (radius 2; fourth-order Laplacian weights), matching
`rust/src/stencil/mod.rs::Stencil::star13`.
"""

import jax.numpy as jnp

# 13-point star weights, identical to the rust side (Stencil::star(3, 2)):
# center −2·d·Σw, axis ±1 → 4/3, axis ±2 → −1/12.
W1 = 4.0 / 3.0
W2 = -1.0 / 12.0
WC = -2.0 * 3.0 * (W1 + W2)

# (dx, dy, dz, weight) for all 13 points.
STAR13 = [(0, 0, 0, WC)] + [
    (sign * k * ax, sign * k * ay, sign * k * az, w)
    for (ax, ay, az) in [(1, 0, 0), (0, 1, 0), (0, 0, 1)]
    for k, w in [(1, W1), (2, W2)]
    for sign in (1, -1)
]


def star13_ref(u):
    """q = Ku with zero (Dirichlet) halo: apply the 13-point star to every
    point of u, treating out-of-grid neighbors as 0."""
    r = 2
    up = jnp.pad(u, r)
    nx, ny, nz = u.shape
    acc = jnp.zeros_like(u)
    for dx, dy, dz, w in STAR13:
        acc = acc + jnp.asarray(w, u.dtype) * up[
            r + dx : r + dx + nx, r + dy : r + dy + ny, r + dz : r + dz + nz
        ]
    return acc


def jacobi_step_ref(u, alpha):
    """One damped-Jacobi / explicit-Euler heat step: u' = u + α·Ku."""
    return u + jnp.asarray(alpha, u.dtype) * star13_ref(u)


def jacobi_run_ref(u, alpha, steps):
    for _ in range(steps):
        u = jacobi_step_ref(u, alpha)
    return u


def norms_ref(u):
    """(‖u‖₂, ‖Ku‖₂) — the residual pair logged by the e2e driver."""
    return jnp.sqrt(jnp.sum(u * u)), jnp.sqrt(jnp.sum(jnp.square(star13_ref(u))))
