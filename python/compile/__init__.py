"""Build-time compilation layer: JAX/Pallas kernels (L1), model graphs and
AOT lowering to HLO text (L2). Imported as ``compile`` with ``python/`` on
``sys.path`` (the test suite's conftest arranges this)."""
