//! Serving-layer integration tests: memoized responses must be
//! **bit-identical** to cold-computed ones, the S3-FIFO tier must be
//! scan-resistant on real coordinator traffic, and the replay driver must
//! meet the acceptance bar (≥ 80% hit rate over a ≥ 500-request
//! Zipf+scan trace with the hot set retained across the scan).
//!
//! The network-serving tier rides the same contract: concurrent identical
//! misses single-flight onto one computation, overload sheds with a typed
//! response instead of hanging, and a panicking request answers an error
//! while the resident service keeps serving.

use stencilcache::coordinator::{
    Coordinator, JobKind, PlannerConfig, Service, StencilRequest, StencilResponse, StencilSpec, TraversalChoice,
};
use stencilcache::experiments::replay::{self, ReplayConfig};
use std::sync::atomic::Ordering;

/// Everything observable about a response except `wall_micros` (timing is
/// not part of the memoized value). Rust's float `Debug` prints the
/// shortest representation that round-trips, so string equality here *is*
/// bit equality for every f64 in the plan, the per-level profiles, and
/// the solve log.
fn fingerprint(r: &StencilResponse) -> String {
    let report = r
        .miss_report
        .as_ref()
        .map(|m| format!("{} {:?} {} {} {:?}", m.points, m.total, m.u_loads, m.u_misses, m.levels));
    format!("plan={:?} report={report:?} norm={:?} log={:?}", r.plan, r.result_norm, r.solve_log)
}

fn star13(dims: &[usize], kind: JobKind) -> StencilRequest {
    StencilRequest { dims: dims.to_vec(), stencil: StencilSpec::Star13, rhs_arrays: 1, kind }
}

fn cold_coordinator() -> Coordinator {
    let mut c = Coordinator::analysis_only(PlannerConfig::default());
    c.configure_memo(None);
    c
}

/// Property: serve the same request stream through a fresh (memo-less)
/// service and a warm (pre-primed) service — every warm response must be
/// bit-identical to the cold recomputation, for every job kind that
/// produces memoized artifacts.
#[test]
fn memoized_responses_bit_identical_to_cold() {
    use stencilcache::util::proptest::{forall, DimsGen};
    let cold = cold_coordinator();
    let warm = Coordinator::analysis_only(PlannerConfig::default());
    forall(41, 10, &DimsGen { d: 3, lo: 10, hi: 26 }, |dims| {
        for kind in [
            JobKind::Plan,
            JobKind::Analyze,
            JobKind::AnalyzeWith(TraversalChoice::Natural),
            JobKind::AnalyzeWith(TraversalChoice::CacheFitting),
        ] {
            let req = star13(dims, kind);
            let _ = warm.submit(&req).unwrap(); // prime
            let memoized = warm.submit(&req).unwrap(); // served from cache
            let recomputed = cold.submit(&req).unwrap();
            if fingerprint(&memoized) != fingerprint(&recomputed) {
                let (w, c) = (fingerprint(&memoized), fingerprint(&recomputed));
                eprintln!("mismatch for {dims:?}:\n  warm {w}\n  cold {c}");
                return false;
            }
        }
        true
    });
    // the warm side really served from cache (one hit per kind per case)
    assert!(warm.metrics().sim_memo_hits.load(Ordering::Relaxed) >= 40);
}

/// The same stream twice through one service: second pass all hits, and
/// the full response set (including hierarchical per-level LoadProfiles)
/// matches the first pass bit for bit.
#[test]
fn warm_pass_matches_cold_pass_on_hierarchical_machine() {
    use stencilcache::cache::MachineModel;
    let config = PlannerConfig { machine: MachineModel::r10000_full(), ..PlannerConfig::default() };
    let svc = Service::new(config);
    let stream: Vec<StencilRequest> = [[20usize, 20, 20], [16, 18, 22], [45, 91, 20]]
        .iter()
        .flat_map(|d| [star13(d, JobKind::Plan), star13(d, JobKind::Analyze)])
        .collect();
    // sequential passes: deterministic shard counts, quiet coordinator
    let cold: Vec<String> = stream.iter().map(|r| fingerprint(&svc.coordinator().submit(r).unwrap())).collect();
    let warm: Vec<String> = stream.iter().map(|r| fingerprint(&svc.coordinator().submit(r).unwrap())).collect();
    assert_eq!(cold, warm);
    let m = svc.coordinator().metrics();
    assert_eq!(m.sim_memo_hits.load(Ordering::Relaxed), stream.len() as u64, "second pass must be all hits");
    // the per-level profile really is present in the memoized reports
    let resp = svc.coordinator().submit(&star13(&[20, 20, 20], JobKind::Analyze)).unwrap();
    assert_eq!(resp.miss_report.unwrap().levels.levels().len(), 3);
}

/// Scan-resistance property: after a one-pass sweep of N cold shapes
/// overflows the memo budget, every pre-sweep hot facet still hits.
#[test]
fn hot_set_survives_one_pass_scan() {
    let mut c = Coordinator::analysis_only(PlannerConfig::default());
    c.configure_memo(Some(16 * 1024));
    let svc = Service::over(c);
    let hot = replay::hot_shapes(6);
    // three warm passes: every hot facet ends with freq ≥ 2, past the
    // S3-FIFO promotion bar
    for _ in 0..3 {
        svc.prefill(&hot, 1);
    }
    let m = svc.coordinator().metrics();

    // one-pass sweep of 40 never-seen shapes (sequential: a real sweep)
    for dims in replay::scan_shapes(200, 40) {
        svc.coordinator().submit(&star13(&dims, JobKind::Analyze)).unwrap();
    }
    assert!(m.memo_evictions.load(Ordering::Relaxed) > 0, "the sweep must overflow the 16 KiB budget");

    // every pre-sweep hot shape still hits, on both facets
    let misses_before = m.sim_memo_misses.load(Ordering::Relaxed);
    let hits_before = m.sim_memo_hits.load(Ordering::Relaxed);
    for dims in &hot {
        svc.coordinator().submit(&star13(dims, JobKind::Plan)).unwrap();
        svc.coordinator().submit(&star13(dims, JobKind::Analyze)).unwrap();
    }
    assert_eq!(m.sim_memo_misses.load(Ordering::Relaxed), misses_before, "scan evicted part of the hot set");
    assert_eq!(m.sim_memo_hits.load(Ordering::Relaxed), hits_before + 2 * hot.len() as u64);
}

/// The ISSUE acceptance bar: a deterministic Zipf(8 hot shapes)+scan
/// trace of ≥ 500 Plan/Analyze requests reaches ≥ 80% memo hit rate and
/// keeps the hot set resident across the scan.
#[test]
fn replay_acceptance_hit_rate_and_retention() {
    let out = replay::run(&ReplayConfig::paper(false));
    assert!(out.total_requests >= 500, "trace too short: {}", out.total_requests);
    assert!(out.hit_rate() >= 0.8, "hit rate {:.3} < 0.8\n{}", out.hit_rate(), out.table.to_text());
    assert!(out.hot_set_retained(), "{} hot misses after the scan\n{}", out.hot_misses_after_scan, out.table.to_text());
    // phases: pre-scan hot traffic is all hits, the scan is all misses
    assert_eq!(out.phases[0].hits, out.phases[0].requests);
    assert_eq!(out.phases[1].hits, 0);
    assert_eq!(out.phases[2].hits, out.phases[2].requests);
}

/// Execute reuses the memoized plan but always recomputes numerics — and
/// the numeric result is unchanged by the cache hit.
#[test]
fn execute_after_analyze_reuses_plan_and_recomputes() {
    let warm = Coordinator::analysis_only(PlannerConfig::default());
    let cold = cold_coordinator();
    let dims = [16usize, 16, 16];
    let _ = warm.submit(&star13(&dims, JobKind::Analyze)).unwrap();
    let warm_exec = warm.submit(&star13(&dims, JobKind::Execute)).unwrap();
    let cold_exec = cold.submit(&star13(&dims, JobKind::Execute)).unwrap();
    assert_eq!(warm.metrics().planned.load(Ordering::Relaxed), 1, "Execute must reuse the cached plan");
    assert_eq!(warm.metrics().native_executions.load(Ordering::Relaxed), 1, "Execute must still run numerics");
    assert_eq!(fingerprint(&warm_exec), fingerprint(&cold_exec));
}

/// A burst of identical cold Plan requests must run the planner exactly
/// once: the first caller leads, every other caller either collapses onto
/// the in-flight computation or hits the memo entry the leader published,
/// and all of them share one `Arc<Plan>` allocation.
#[test]
fn single_flight_collapses_concurrent_plan_misses() {
    use stencilcache::coordinator::Plan;
    use std::sync::{Arc, Barrier};
    let c = Coordinator::analysis_only(PlannerConfig::default());
    let k = 8;
    let barrier = Barrier::new(k);
    let plans: Vec<Arc<Plan>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..k)
            .map(|_| {
                let (c, barrier) = (&c, &barrier);
                s.spawn(move || {
                    barrier.wait();
                    c.submit(&star13(&[40, 40, 40], JobKind::Plan)).unwrap().plan
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let m = c.metrics();
    assert_eq!(m.planned.load(Ordering::Relaxed), 1, "k concurrent misses must plan exactly once");
    assert!(plans.iter().all(|p| Arc::ptr_eq(p, &plans[0])), "all callers must share the leader's Arc<Plan>");
    let collapsed = m.single_flight_collapsed.load(Ordering::Relaxed);
    let hits = m.sim_memo_hits.load(Ordering::Relaxed);
    assert_eq!(collapsed + hits, k as u64 - 1, "every non-leader collapsed onto the flight or hit the memo");
}

/// Same property for the expensive side: concurrent identical Analyze
/// misses run the cache simulation once, and every caller receives an
/// identical report.
#[test]
fn single_flight_collapses_concurrent_analysis_misses() {
    use std::sync::Barrier;
    let c = Coordinator::analysis_only(PlannerConfig::default());
    let k = 6;
    let barrier = Barrier::new(k);
    let prints: Vec<String> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..k)
            .map(|_| {
                let (c, barrier) = (&c, &barrier);
                s.spawn(move || {
                    barrier.wait();
                    fingerprint(&c.submit(&star13(&[36, 36, 36], JobKind::Analyze)).unwrap())
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let m = c.metrics();
    assert_eq!(m.analyzed.load(Ordering::Relaxed), 1, "k concurrent misses must simulate exactly once");
    assert_eq!(m.planned.load(Ordering::Relaxed), 1, "and plan exactly once");
    assert!(prints.windows(2).all(|w| w[0] == w[1]), "all callers must see the leader's report");
}

/// Overload behavior over the wire: with the inflight cap at 1, a
/// pipelined burst gets a mix of `ok` and typed `overloaded` answers —
/// every line is answered (bounded reads, no hang) — and once the burst
/// drains the very next request is served normally.
#[test]
fn server_sheds_on_overload_answers_every_line_and_recovers() {
    use stencilcache::coordinator::{Server, ServerConfig};
    use stencilcache::util::json::{self, Json};
    use std::io::{BufRead, BufReader, Write};
    let svc = std::sync::Arc::new(Service::new(PlannerConfig::default()));
    let cfg = ServerConfig { max_inflight: 1, workers: 4, ..ServerConfig::default() };
    let mut server = Server::start(svc, cfg).expect("bind loopback");
    let stream = std::net::TcpStream::connect(server.addr()).expect("connect");
    stream.set_read_timeout(Some(std::time::Duration::from_secs(120))).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut w = stream;
    // distinct shapes: responses must come from real dispatch, not memo
    for i in 0..6u32 {
        let n = 60 + 2 * i;
        writeln!(w, "{{\"id\":{i},\"kind\":\"analyze\",\"dims\":[{n},{n},{n}]}}").unwrap();
    }
    w.flush().unwrap();
    let (mut ok, mut overloaded) = (0, 0);
    for _ in 0..6 {
        let mut line = String::new();
        assert!(reader.read_line(&mut line).unwrap() > 0, "server must answer every line, not hang");
        let v = json::parse(line.trim()).unwrap();
        if matches!(v.get("ok"), Some(Json::Bool(true))) {
            ok += 1;
        } else {
            assert_eq!(v.get("error").and_then(Json::as_str), Some("overloaded"), "unexpected error in {line}");
            overloaded += 1;
        }
    }
    assert!(ok >= 1, "the admitted request must complete");
    assert!(overloaded >= 1, "cap 1 must shed part of a 6-deep pipelined burst");
    assert!(server.admission().shed_total() >= overloaded as u64);
    // recovery: the burst is drained, so a fresh request is admitted
    writeln!(w, "{{\"id\":9,\"kind\":\"plan\",\"dims\":[16,16,16]}}").unwrap();
    let mut line = String::new();
    assert!(reader.read_line(&mut line).unwrap() > 0, "server must keep serving after shedding");
    let v = json::parse(line.trim()).unwrap();
    assert!(matches!(v.get("ok"), Some(Json::Bool(true))), "post-burst request must succeed: {line}");
    server.shutdown();
}

/// Panic containment at the Service layer: a fault-injected request in the
/// middle of a wave answers `Err` while its neighbors succeed, and the
/// same resident service keeps serving the next wave.
#[test]
fn service_survives_panicking_request_mid_wave() {
    let svc = Service::new(PlannerConfig::default());
    svc.submit(star13(&[16, 16, 16], JobKind::Analyze));
    svc.submit(StencilRequest {
        dims: vec![4, 4, 4],
        stencil: StencilSpec::Star { r: 1 },
        rhs_arrays: 1,
        kind: JobKind::ChaosPanic,
    });
    svc.submit(star13(&[18, 18, 18], JobKind::Analyze));
    let wave = svc.drain();
    assert_eq!(wave.len(), 3);
    assert!(wave[0].1.is_ok());
    let err = wave[1].1.as_ref().expect_err("fault injection must surface as Err").to_string();
    assert!(err.contains("panicked"), "error must identify the panic: {err}");
    assert!(wave[2].1.is_ok(), "the request after the panic must still succeed");
    // the same resident service serves the next wave normally
    svc.submit(star13(&[16, 16, 16], JobKind::Analyze));
    let next = svc.drain();
    assert_eq!(next.len(), 1);
    assert!(next[0].1.is_ok());
}

/// Mixed batched traffic through Service::serve: memoization must not
/// perturb responses vs a memo-less coordinator (order-preserving,
/// failure-isolating serve contract unchanged).
#[test]
fn batched_serve_with_memo_matches_cold_responses() {
    let warm_svc = Service::new(PlannerConfig::default());
    let cold = cold_coordinator();
    let mut reqs: Vec<StencilRequest> = Vec::new();
    for n in [14usize, 18, 14, 22, 18, 14] {
        reqs.push(star13(&[n, n, n], JobKind::Analyze));
        reqs.push(star13(&[n, n, n], JobKind::Plan));
    }
    let invalid =
        StencilRequest { dims: vec![0, 4], stencil: StencilSpec::Star { r: 1 }, rhs_arrays: 1, kind: JobKind::Plan };
    reqs.push(invalid);
    let batch = warm_svc.serve(&reqs);
    assert_eq!(batch.len(), reqs.len());
    assert!(batch.last().unwrap().is_err(), "invalid request must still fail cleanly");
    for (req, resp) in reqs.iter().zip(&batch).take(reqs.len() - 1) {
        let resp = resp.as_ref().unwrap();
        assert_eq!(fingerprint(resp), fingerprint(&cold.submit(req).unwrap()));
    }
}
