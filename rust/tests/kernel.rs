//! Row-kernel equivalence pins (DESIGN.md §2.11): the vectorized kernel
//! every production path now runs must stay anchored to the retained
//! per-point scalar sweep (`engine::apply_reference`).
//!
//! The contract, enforced in BOTH CI legs (default build and
//! `--features simd`):
//!
//! - `KernelCfg::strict()` is **bitwise** equal to the scalar reference,
//!   always — strict mode never dispatches to FMA code.
//! - The default cfg is bitwise on the portable build and within a
//!   documented 1e-12 relative reassociation/FMA tolerance under `simd`.
//! - Prefetch distance is a pure hint: any value leaves results bitwise
//!   unchanged for the same cfg.
//! - Sharded sweeps equal the serial sweep bitwise under the same cfg
//!   (pencil ranges split rows between workers, never within a row).
//!
//! Coverage axes from the issue: radii r ∈ {1, 2, 4}, 1/2/3-D grids,
//! unaligned pencil base offsets (odd extents and deliberate padding), and
//! dim-0 interior lengths 0..8 so every 4-lane remainder shape (including
//! the empty row) is exercised.

use stencilcache::engine::{self, KernelCfg};
use stencilcache::grid::GridDesc;
use stencilcache::solver;
use stencilcache::stencil::Stencil;
use stencilcache::traversal::{self, Traversal};
use stencilcache::util::threadpool::ThreadPool;

/// 1/2/3-D cases per radius. Dim-0 extents are chosen so the interior row
/// length `dims[0] - 2r` sweeps 0..=8 (every remainder class of the 4-lane
/// kernel, plus rows shorter than one chunk and the degenerate empty row)
/// and then some longer, odd, unaligned lengths.
fn cases(r: usize) -> Vec<(Vec<usize>, Vec<usize>)> {
    let mut out = Vec::new();
    for tail in 0..=8usize {
        // 1-D: row length == tail exactly; no padding
        out.push((vec![2 * r + tail], vec![0]));
    }
    // 2/3-D with odd extents and padding that misaligns every pencil base
    // (storage row pitch becomes coprime to the 4-word / 8-word lines).
    out.push((vec![2 * r + 5, 7], vec![1, 0]));
    out.push((vec![2 * r + 11, 6], vec![3, 1]));
    out.push((vec![2 * r + 9, 5, 4], vec![1, 2, 0]));
    out.push((vec![2 * r + 14, 7, 3], vec![0, 1, 1]));
    out
}

fn fields(g: &GridDesc, r: usize) -> (Vec<f64>, Vec<f64>, Vec<f64>) {
    let words = g.storage_words() as usize;
    let u = solver::deterministic_field(g, r, 13);
    (u, vec![0.0; words], vec![0.0; words])
}

/// Strict mode is bitwise equal to the per-point scalar reference in every
/// build — this is the anchor that keeps default and simd builds honest.
#[test]
fn strict_mode_bitwise_equals_pointwise_reference() {
    for r in [1usize, 2, 4] {
        for (dims, pad) in cases(r) {
            let g = GridDesc::with_padding(&dims, &pad);
            let s = Stencil::star(dims.len(), r);
            let nat = traversal::natural_stream(&g, r);
            let (u, mut q_ref, mut q) = fields(&g, r);
            engine::apply_reference(&nat, &g, &s, &u, &mut q_ref);
            engine::apply_cfg(&nat, &g, &s, &u, &mut q, &KernelCfg::strict());
            assert_eq!(q, q_ref, "strict kernel must be bitwise: {dims:?} pad {pad:?} r={r}");
        }
    }
}

/// Default cfg: bitwise without the `simd` feature; within 1e-12 relative
/// of the scalar reference with it (FMA contraction + 4-lane horizontal
/// reassociation — see the tolerance derivation in DESIGN.md §2.11).
#[test]
fn default_mode_within_documented_tolerance_of_reference() {
    let strict_build = !cfg!(feature = "simd");
    for r in [1usize, 2, 4] {
        for (dims, pad) in cases(r) {
            let g = GridDesc::with_padding(&dims, &pad);
            let s = Stencil::star(dims.len(), r);
            let nat = traversal::natural_stream(&g, r);
            let (u, mut q_ref, mut q) = fields(&g, r);
            engine::apply_reference(&nat, &g, &s, &u, &mut q_ref);
            engine::apply_cfg(&nat, &g, &s, &u, &mut q, &KernelCfg::default());
            if strict_build {
                assert_eq!(q, q_ref, "portable default must be bitwise: {dims:?} r={r}");
            } else {
                for (i, (a, b)) in q.iter().zip(&q_ref).enumerate() {
                    let tol = 1e-12 * (1.0 + a.abs().max(b.abs()));
                    assert!((a - b).abs() <= tol, "{dims:?} r={r} word {i}: {a} vs {b}");
                }
            }
        }
    }
}

/// Prefetch is a hint, never a semantic knob: any distance (including ones
/// far past the row end, exercising the clamp) leaves the field bitwise
/// identical to distance 0 under the same cfg.
#[test]
fn prefetch_distance_never_changes_the_field() {
    let g = GridDesc::with_padding(&[21, 7, 5], &[1, 1, 0]);
    let s = Stencil::star13();
    let nat = traversal::natural_stream(&g, 2);
    let (u, mut q_ref, mut q) = fields(&g, 2);
    engine::apply_cfg(&nat, &g, &s, &u, &mut q_ref, &KernelCfg::default());
    for dist in [1usize, 8, 112, 1 << 20] {
        q.iter_mut().for_each(|w| *w = 0.0);
        engine::apply_cfg(&nat, &g, &s, &u, &mut q, &KernelCfg { strict: false, prefetch: dist });
        assert_eq!(q, q_ref, "prefetch {dist} changed the field");
    }
}

/// Sharded-vs-serial under the same cfg is bitwise in every build — under
/// `--features simd` this pins that shard splits never change which code
/// path (or which lane grouping) computes a given row.
#[test]
fn sharded_apply_bitwise_equals_serial_for_every_cfg() {
    let pool = ThreadPool::new(3);
    let cfgs = [KernelCfg::default(), KernelCfg::strict(), KernelCfg { strict: false, prefetch: 112 }];
    for r in [1usize, 2] {
        let g = GridDesc::with_padding(&[2 * r + 13, 9, 7], &[1, 0, 1]);
        let s = Stencil::star(3, r);
        let nat = traversal::natural_stream(&g, r);
        let (u, mut q_ref, mut q) = fields(&g, r);
        for cfg in &cfgs {
            q_ref.iter_mut().for_each(|w| *w = 0.0);
            engine::apply_cfg(&nat, &g, &s, &u, &mut q_ref, cfg);
            for shards in [2usize, 5, 16] {
                q.iter_mut().for_each(|w| *w = 0.0);
                engine::apply_sharded_cfg(&nat, &g, &s, &u, &mut q, &pool, shards, cfg);
                assert_eq!(q, q_ref, "r={r} {shards} shards cfg {cfg:?}");
            }
        }
    }
}

/// The non-natural traversal families route through the same row kernel
/// (`stream_rows` fallback included): strict mode stays bitwise equal to
/// the reference regardless of visit order.
#[test]
fn strict_mode_bitwise_across_traversal_families() {
    let g = GridDesc::new(&[17, 11, 9]);
    let r = 2usize;
    let s = Stencil::star(3, r);
    let (u, mut q_ref, mut q) = fields(&g, r);
    engine::apply_reference(&traversal::natural_stream(&g, r), &g, &s, &u, &mut q_ref);
    let fams: Vec<(&str, Box<dyn Traversal>)> = vec![
        ("strip3", Box::new(traversal::strip_stream(&g, r, 3))),
        ("blocked", Box::new(traversal::blocked_stream(&g, r, &[4, 4, 4]))),
        ("tiled_z", Box::new(traversal::tiled_z_sweep_stream(&g, r, 4096, 2))),
    ];
    for (name, t) in &fams {
        q.iter_mut().for_each(|w| *w = 0.0);
        engine::apply_cfg(t.as_ref(), &g, &s, &u, &mut q, &KernelCfg::strict());
        assert_eq!(q, q_ref, "{name}");
    }
}
