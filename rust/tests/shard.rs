//! Shard/halo decomposition integration tests (DESIGN.md §2.9): the
//! block-decomposed solve must be **bitwise identical** to the classic
//! unsharded path on star stencils — per point, both fold the same
//! coefficients over the same operand values in the same order — with ghost
//! values crossing shard boundaries only inside typed `HaloMsg`s. Norm
//! sums combine per-shard partials in shard order, so they match the flat
//! sums to summation-order tolerance (exactly, for a single shard).

use stencilcache::engine;
use stencilcache::grid::GridDesc;
use stencilcache::shard::{self, solve_blocks, solve_blocks_with_field, ShardPlan, ShardStorage};
use stencilcache::solver::{self, NativeBackend};
use stencilcache::stencil::Stencil;
use stencilcache::traversal;
use stencilcache::util::threadpool::ThreadPool;
use std::sync::Arc;

/// Reference: `steps` classic explicit steps (apply + full-buffer axpy) on
/// the flat unpadded grid — the exact arithmetic of the unsharded native
/// solve with `shards = 1`.
fn classic_steps(g: &GridDesc, s: &Stencil, u0: &[f64], alpha: f64, steps: usize) -> (Vec<f64>, Vec<(f64, f64)>) {
    let nat = traversal::natural_stream(g, s.radius());
    let mut u = u0.to_vec();
    let mut q = vec![0.0; u.len()];
    let mut norms = Vec::new();
    for _ in 0..steps {
        engine::apply(&nat, g, s, &u, &mut q);
        let (mut u2, mut r2) = (0.0, 0.0);
        for i in 0..u.len() {
            u[i] += alpha * q[i];
            u2 += u[i] * u[i];
            r2 += q[i] * q[i];
        }
        norms.push((u2, r2));
    }
    (u, norms)
}

fn close(a: f64, b: f64) -> bool {
    (a - b).abs() <= 1e-9 * (1.0 + a.abs().max(b.abs()))
}

/// SATELLITE: a shard grid of 1 has no halo at all, and the solve is
/// bitwise the unsharded path — fields exact, norms exact too (the block
/// sweep accumulates the same nonzero addends in the same order; the flat
/// sums only interleave exact `+ 0.0` terms for boundary words).
#[test]
fn single_shard_solve_bitwise_equals_classic() {
    for (dims, r) in [(vec![14usize, 12, 10], 1usize), (vec![13, 11], 2), (vec![40], 1)] {
        let g = GridDesc::new(&dims);
        let s = Stencil::star(dims.len(), r);
        let alpha = NativeBackend::stable_alpha(&s);
        let u0 = solver::deterministic_field(&g, r, 0xBEEF);
        let (u_ref, norms_ref) = classic_steps(&g, &s, &u0, alpha, 5);
        let plan = Arc::new(ShardPlan::new(&dims, &vec![1; dims.len()], r));
        let pool = ThreadPool::new(2);
        let (out, f) =
            solve_blocks_with_field(&plan, &s, alpha, 5, 0xBEEF, &ShardStorage::InMemory, &pool, None).unwrap();
        assert_eq!(f.gather().unwrap(), u_ref, "{dims:?} r={r}: field must be bitwise equal");
        assert_eq!(out.halo_words_loaded, 0, "one shard has no one to talk to");
        assert_eq!(out.halo_exchanges, 0);
        for (i, (sn, (u2, r2))) in out.steps.iter().zip(&norms_ref).enumerate() {
            assert_eq!(sn.u2, *u2, "{dims:?} step {i}");
            assert_eq!(sn.r2, *r2, "{dims:?} step {i}");
        }
    }
}

/// TENTPOLE equivalence: multi-shard decompositions over random 3-D grids
/// produce bitwise-identical fields, and the measured halo traffic is
/// exactly `steps · plan.halo_words()`.
#[test]
fn multi_shard_solve_bitwise_equals_classic_3d() {
    use stencilcache::util::proptest::{forall, DimsGen};
    let pool = ThreadPool::new(3);
    forall(7, 6, &DimsGen { d: 3, lo: 8, hi: 14 }, |dims| {
        let g = GridDesc::new(dims);
        for (r, grid) in [(1usize, vec![2usize, 2, 1]), (2, vec![1, 2, 2])] {
            let s = Stencil::star(3, r);
            let alpha = NativeBackend::stable_alpha(&s);
            let u0 = solver::deterministic_field(&g, r, 99);
            let (u_ref, norms_ref) = classic_steps(&g, &s, &u0, alpha, 3);
            let plan = Arc::new(ShardPlan::new(dims, &grid, r));
            let (out, f) =
                solve_blocks_with_field(&plan, &s, alpha, 3, 99, &ShardStorage::InMemory, &pool, None).unwrap();
            if f.gather().unwrap() != u_ref {
                eprintln!("{dims:?} r={r} grid {grid:?}: field mismatch");
                return false;
            }
            if out.halo_words_loaded != 3 * plan.halo_words() {
                eprintln!("{dims:?} grid {grid:?}: halo {} != 3·{}", out.halo_words_loaded, plan.halo_words());
                return false;
            }
            for (sn, (u2, r2)) in out.steps.iter().zip(&norms_ref) {
                if !close(sn.u2, *u2) || !close(sn.r2, *r2) {
                    eprintln!("{dims:?} grid {grid:?}: norm drift");
                    return false;
                }
            }
        }
        true
    });
}

/// SATELLITE: ghost-region width follows the stencil radius — for a
/// `Star{r}` with r ∈ {1, 2, 4} in 1-D/2-D/3-D, a single cut exchanges
/// exactly `2·r·(face area)` words per step, and the halo boxes extend
/// exactly `r` past the cut on each side.
#[test]
fn halo_width_follows_stencil_radius() {
    let pool = ThreadPool::new(2);
    for d in 1..=3usize {
        for r in [1usize, 2, 4] {
            let n = 24usize;
            let dims = vec![n; d];
            let mut grid = vec![1usize; d];
            grid[0] = 2;
            let plan = Arc::new(ShardPlan::new(&dims, &grid, r));
            let cut = (n / 2) as i64;
            assert_eq!(plan.halo_box(0)[0], 0..cut + r as i64, "d={d} r={r}");
            assert_eq!(plan.halo_box(1)[0], cut - r as i64..n as i64, "d={d} r={r}");
            let face: u64 = dims[1..].iter().map(|&x| x as u64).product();
            assert_eq!(plan.halo_words(), 2 * r as u64 * face, "d={d} r={r}");
            // ...and a real solve moves exactly that many ghost words/step
            let s = Stencil::star(d, r);
            let alpha = NativeBackend::stable_alpha(&s);
            let out = solve_blocks(&plan, &s, alpha, 2, 5, &ShardStorage::InMemory, &pool, None).unwrap();
            assert_eq!(out.halo_words_loaded, 2 * plan.halo_words(), "d={d} r={r}");
            assert_eq!(out.halo_exchanges, 2 * 2, "two shards, one message each, two steps");
        }
    }
}

/// Out-of-core disk tiles under a RAM budget produce the bitwise-identical
/// field AND bitwise-identical norms: per-shard partials are combined in
/// shard order regardless of the budget-throttled wave size.
#[test]
fn out_of_core_solve_bitwise_equals_in_memory() {
    let dims = vec![12usize, 10, 8];
    let s = Stencil::star13();
    let alpha = NativeBackend::stable_alpha(&s);
    let plan = Arc::new(ShardPlan::new(&dims, &[2, 2, 2], 2));
    let pool = ThreadPool::new(4);
    let (mem_out, mem_f) =
        solve_blocks_with_field(&plan, &s, alpha, 4, 0xBEEF, &ShardStorage::InMemory, &pool, None).unwrap();
    let storage = ShardStorage::temp();
    // budget of one working set ⇒ waves of exactly one shard at a time
    let budget = plan.peak_working_words();
    let (ooc_out, ooc_f) = solve_blocks_with_field(&plan, &s, alpha, 4, 0xBEEF, &storage, &pool, Some(budget)).unwrap();
    assert_eq!(mem_f.gather().unwrap(), ooc_f.gather().unwrap(), "disk tiles must hold the same bits");
    for (a, b) in mem_out.steps.iter().zip(&ooc_out.steps) {
        assert_eq!(a.u2, b.u2);
        assert_eq!(a.r2, b.r2);
    }
    assert_eq!(mem_out.halo_words_loaded, ooc_out.halo_words_loaded);
    assert_eq!(mem_out.halo_exchanges, ooc_out.halo_exchanges);
    drop(ooc_f);
    if let ShardStorage::OutOfCore { dir } = &storage {
        assert!(!dir.exists(), "dropping the final field must clean up the tile directory");
    }
}

/// TENTPOLE (DESIGN.md §2.12): the k-deep superstep solve is **bitwise
/// identical** to k classic single steps across dimensionality, radius,
/// depth, and shard grids — fields exact, per-step norms within 1e-9 —
/// while exchanging exactly `⌈steps/k⌉` full-depth halo rounds. Ghost
/// recompute appears only when a superstep actually sweeps more than one
/// step between exchanges.
#[test]
fn sharded_temporal_superstep_bitwise_equals_classic() {
    let pool = ThreadPool::new(3);
    let steps = 5usize; // not a multiple of k: the tail superstep runs short
    let cases: &[(&[usize], &[usize])] = &[(&[48], &[3]), (&[26, 22], &[2, 2]), (&[16, 14, 12], &[2, 1, 2])];
    for &(dims, grid) in cases {
        for r in [1usize, 2, 4] {
            let g = GridDesc::new(dims);
            let s = Stencil::star(dims.len(), r);
            let alpha = NativeBackend::stable_alpha(&s);
            let u0 = solver::deterministic_field(&g, r, 0xBEEF);
            let (u_ref, norms_ref) = classic_steps(&g, &s, &u0, alpha, steps);
            for k in [1usize, 2, 4] {
                let plan = Arc::new(ShardPlan::with_depth(dims, grid, r, k));
                let (out, f) =
                    solve_blocks_with_field(&plan, &s, alpha, steps, 0xBEEF, &ShardStorage::InMemory, &pool, None)
                        .unwrap();
                assert_eq!(
                    f.gather().unwrap(),
                    u_ref,
                    "{dims:?} grid {grid:?} r={r} k={k}: field must be bitwise equal to {steps} classic steps"
                );
                assert_eq!(out.steps.len(), steps, "supersteps must still report per-step norms");
                for (i, (sn, (u2, r2))) in out.steps.iter().zip(&norms_ref).enumerate() {
                    assert!(
                        close(sn.u2, *u2) && close(sn.r2, *r2),
                        "{dims:?} grid {grid:?} r={r} k={k} step {i}: norm drift"
                    );
                }
                let rounds = steps.div_ceil(k) as u64;
                assert_eq!(
                    out.halo_words_loaded,
                    rounds * plan.halo_words(),
                    "{dims:?} grid {grid:?} r={r} k={k}: exchange rounds must be ceil(steps/k)"
                );
                if k == 1 {
                    assert_eq!(out.halo_redundant_words, 0, "depth-1 must not recompute ghost cells");
                } else {
                    assert!(
                        out.halo_redundant_words > 0,
                        "{dims:?} grid {grid:?} r={r} k={k}: deep supersteps recompute the halo rind"
                    );
                }
            }
        }
    }
}

/// A deep plan on a grid with no full interior (some dim < 2r+1) cannot
/// run supersteps; the solve must degrade it to depth-1 halos rather than
/// exchanging k·r-deep ghost boxes every classic step — bits equal to the
/// classic reference, traffic equal to a depth-1 plan, nothing recomputed.
#[test]
fn degenerate_deep_plan_degrades_to_classic_depth_one_accounting() {
    let pool = ThreadPool::new(3);
    let steps = 4usize;
    // dim 0 = 4 < 2r+1 = 5 ⇒ no interior anywhere along that axis
    let (dims, grid, r) = (vec![4usize, 12], vec![2usize, 2], 2usize);
    let g = GridDesc::new(&dims);
    let s = Stencil::star(2, r);
    let alpha = NativeBackend::stable_alpha(&s);
    let u0 = solver::deterministic_field(&g, r, 0xBEEF);
    let (u_ref, norms_ref) = classic_steps(&g, &s, &u0, alpha, steps);
    let deep = Arc::new(ShardPlan::with_depth(&dims, &grid, r, 3));
    let (out, f) = solve_blocks_with_field(&deep, &s, alpha, steps, 0xBEEF, &ShardStorage::InMemory, &pool, None).unwrap();
    assert_eq!(f.gather().unwrap(), u_ref, "degenerate deep plan must still match the classic field");
    for (sn, (u2, r2)) in out.steps.iter().zip(&norms_ref) {
        assert!(close(sn.u2, *u2) && close(sn.r2, *r2), "norm drift on the degenerate path");
    }
    let shallow = ShardPlan::new(&dims, &grid, r);
    assert_eq!(
        out.halo_words_loaded,
        steps as u64 * shallow.halo_words(),
        "tiny grids must pay depth-1 halo traffic, not the deep plan's"
    );
    assert_eq!(out.halo_redundant_words, 0, "no superstep ⇒ no ghost recompute");
}

/// The deep-halo superstep path survives the out-of-core backend at the
/// tightest budget (waves of one shard): same bits, same norms, same
/// exchange-round accounting as the in-memory deep solve.
#[test]
fn out_of_core_temporal_solve_bitwise_equals_in_memory() {
    let dims = vec![12usize, 10, 8];
    let s = Stencil::star13();
    let alpha = NativeBackend::stable_alpha(&s);
    let plan = Arc::new(ShardPlan::with_depth(&dims, &[2, 2, 2], 2, 2));
    let pool = ThreadPool::new(4);
    let (mem_out, mem_f) =
        solve_blocks_with_field(&plan, &s, alpha, 5, 0xBEEF, &ShardStorage::InMemory, &pool, None).unwrap();
    let storage = ShardStorage::temp();
    // budget of one deep working set ⇒ waves of exactly one shard at a time
    let budget = plan.peak_working_words();
    let (ooc_out, ooc_f) = solve_blocks_with_field(&plan, &s, alpha, 5, 0xBEEF, &storage, &pool, Some(budget)).unwrap();
    assert_eq!(mem_f.gather().unwrap(), ooc_f.gather().unwrap(), "deep disk tiles must hold the same bits");
    for (a, b) in mem_out.steps.iter().zip(&ooc_out.steps) {
        assert_eq!(a.u2, b.u2);
        assert_eq!(a.r2, b.r2);
    }
    assert_eq!(mem_out.halo_words_loaded, 3 * plan.halo_words(), "ceil(5/2) = 3 exchange rounds");
    assert_eq!(ooc_out.halo_words_loaded, mem_out.halo_words_loaded);
    assert_eq!(ooc_out.halo_redundant_words, mem_out.halo_redundant_words);
}

/// ACCEPTANCE (nightly): a 512³ star13 solve completes out-of-core under a
/// 256 MiB RAM budget — 1/16 of the 4 GiB the in-memory ping-pong would
/// need — with the planner-refined shard grid and energy decay intact.
/// Run with:
///
/// ```text
/// cargo test --release -q --test shard -- --ignored out_of_core_512
/// ```
#[test]
#[ignore = "large: 512³ disk tiles (~2 GiB under $TMPDIR) + 2 full sweeps; nightly CI runs it in release"]
fn out_of_core_512_cubed_under_ram_budget() {
    let dims = vec![512usize, 512, 512];
    let s = Stencil::star13();
    let alpha = NativeBackend::stable_alpha(&s);
    let budget: u64 = 32 << 20; // 32 Mi words = 256 MiB of f64
    let grid = shard::refine_grid_for_budget(&dims, 2, shard::choose_shard_grid(&dims, 2, 8), budget);
    let plan = Arc::new(ShardPlan::new(&dims, &grid, 2));
    assert!(
        plan.peak_working_words() <= budget,
        "refined grid {grid:?} must fit: {} > {budget}",
        plan.peak_working_words()
    );
    let pool = ThreadPool::with_default_parallelism();
    let storage = ShardStorage::temp();
    let out = solve_blocks(&plan, &s, alpha, 2, 0xBEEF, &storage, &pool, Some(budget)).unwrap();
    assert_eq!(out.steps.len(), 2);
    assert!(out.steps[0].u2.is_finite() && out.steps[0].u2 > 0.0);
    assert!(out.steps[1].u2 <= out.steps[0].u2 * 1.0001, "explicit heat step must not grow energy");
    assert_eq!(out.halo_words_loaded, 2 * plan.halo_words());
    if let ShardStorage::OutOfCore { dir } = &storage {
        assert!(!dir.exists(), "tile directory must be cleaned up");
    }
}

/// ACCEPTANCE (nightly): the k-deep superstep path holds at scale — a 512³
/// star13 solve runs out-of-core under the same 256 MiB budget with k = 2,
/// exchanging one full-depth round per two steps. Run with:
///
/// ```text
/// cargo test --release -q --test shard -- --ignored out_of_core_512_cubed_temporal
/// ```
#[test]
#[ignore = "large: 512³ disk tiles (~2 GiB under $TMPDIR) + 4 full sweeps; nightly CI runs it in release"]
fn out_of_core_512_cubed_temporal_k2_under_ram_budget() {
    let dims = vec![512usize, 512, 512];
    let s = Stencil::star13();
    let alpha = NativeBackend::stable_alpha(&s);
    let budget: u64 = 32 << 20; // 32 Mi words = 256 MiB of f64
    // refine until the *deep* working set (halos at 2·r) fits the budget;
    // deep peaks run a little above the classic peak the refiner targets
    let mut refine_budget = budget;
    let mut grid = shard::refine_grid_for_budget(&dims, 2, shard::choose_shard_grid(&dims, 2, 8), refine_budget);
    for _ in 0..8 {
        if ShardPlan::with_depth(&dims, &grid, 2, 2).peak_working_words() <= budget {
            break;
        }
        refine_budget /= 2;
        grid = shard::refine_grid_for_budget(&dims, 2, grid, refine_budget);
    }
    let plan = Arc::new(ShardPlan::with_depth(&dims, &grid, 2, 2));
    assert!(
        plan.peak_working_words() <= budget,
        "refined grid {grid:?} must fit the deep working set: {} > {budget}",
        plan.peak_working_words()
    );
    let pool = ThreadPool::with_default_parallelism();
    let storage = ShardStorage::temp();
    let steps = 4usize;
    let out = solve_blocks(&plan, &s, alpha, steps, 0xBEEF, &storage, &pool, Some(budget)).unwrap();
    assert_eq!(out.steps.len(), steps);
    assert!(out.steps[0].u2.is_finite() && out.steps[0].u2 > 0.0);
    assert!(
        out.steps[steps - 1].u2 <= out.steps[0].u2 * 1.0001,
        "explicit heat step must not grow energy"
    );
    assert_eq!(out.halo_words_loaded, 2 * plan.halo_words(), "ceil(4/2) = 2 full-depth exchange rounds");
    assert!(out.halo_redundant_words > 0, "k = 2 supersteps recompute the halo rind");
    if let ShardStorage::OutOfCore { dir } = &storage {
        assert!(!dir.exists(), "tile directory must be cleaned up");
    }
}
