//! Streaming-traversal integration tests: every lazy implementation must
//! stream exactly the same point multiset as its materialized counterpart,
//! sharded pencil ranges must partition the interior (no dupes, no gaps),
//! and the engine's sharded analysis must agree with the sequential one on
//! points and accesses.

use stencilcache::cache::{CacheParams, CacheSim, MachineModel};
use stencilcache::coordinator::{Coordinator, JobKind, PlannerConfig, StencilRequest, StencilSpec};
use stencilcache::engine;
use stencilcache::grid::{GridDesc, MultiArrayLayout};
use stencilcache::lattice::InterferenceLattice;
use stencilcache::stencil::Stencil;
use stencilcache::traversal::{
    self, blocked_stream, cache_fitting_stream, natural_stream, shard_ranges, strip_stream, Order, Traversal,
};
use stencilcache::util::threadpool::ThreadPool;

/// All streaming traversals for a grid, with names for failure messages.
fn streaming_family(g: &GridDesc, r: usize, modulus: usize) -> Vec<(String, Box<dyn Traversal>)> {
    let lat = InterferenceLattice::new(g.storage_dims(), modulus);
    let mut out: Vec<(String, Box<dyn Traversal>)> = vec![
        ("natural".into(), Box::new(natural_stream(g, r))),
        ("strip4".into(), Box::new(strip_stream(g, r, 4))),
        ("blocked".into(), Box::new(blocked_stream(g, r, &vec![3; g.ndim()]))),
        ("fitting".into(), Box::new(cache_fitting_stream(g, r, &lat))),
    ];
    if g.ndim() == 3 {
        out.push(("tiled_z".into(), Box::new(traversal::tiled_z_sweep_stream(g, r, modulus, 2))));
    }
    out
}

fn multiset(t: &dyn Traversal, pencils: std::ops::Range<usize>) -> Vec<u64> {
    let mut v = Vec::new();
    t.stream_pencils(pencils, &mut |x| v.push(Order::pack(x)));
    v.sort_unstable();
    v
}

/// The grids every property below sweeps: favorable, unfavorable (the
/// Figure-4/5 spike families whose lattices have very short vectors), thin,
/// and 2-D.
fn test_grids() -> Vec<(Vec<usize>, usize)> {
    vec![
        (vec![12, 11, 10], 1),
        (vec![20, 17, 12], 2),
        (vec![45, 91], 1),  // unfavorable 2-D (45·91 = 4095 ≈ S)
        (vec![60, 32], 1),  // row length ≈ cache size
        (vec![45, 91, 8], 2), // unfavorable 3-D, thin z
        (vec![13, 9, 21], 1),
        (vec![7, 7], 3), // single-point interior
    ]
}

#[test]
fn streams_match_materialized_multisets() {
    for (dims, r) in test_grids() {
        let g = GridDesc::new(&dims);
        let reference = traversal::natural(&g, r).canonical_set();
        for (name, t) in streaming_family(&g, r, 128) {
            assert_eq!(t.num_points(), g.interior_points(r), "{name} on {dims:?}");
            assert_eq!(multiset(t.as_ref(), 0..t.num_pencils()), reference, "{name} on {dims:?}");
        }
    }
}

#[test]
fn sharded_pencil_ranges_partition_the_interior() {
    for (dims, r) in test_grids() {
        let g = GridDesc::new(&dims);
        let reference = traversal::natural(&g, r).canonical_set();
        for (name, t) in streaming_family(&g, r, 128) {
            for shards in [1usize, 2, 3, 7, 1000] {
                let ranges = shard_ranges(t.num_pencils(), shards);
                let mut all = Vec::new();
                for rg in ranges {
                    t.stream_pencils(rg, &mut |x| all.push(Order::pack(x)));
                }
                all.sort_unstable();
                // no dupes, no gaps: the shard union is exactly the interior
                assert_eq!(all, reference, "{name} on {dims:?} with {shards} shards");
            }
        }
    }
}

#[test]
fn property_streams_match_on_random_grids() {
    use stencilcache::util::proptest::{forall, DimsGen};
    forall(77, 12, &DimsGen { d: 3, lo: 6, hi: 18 }, |dims| {
        let g = GridDesc::new(dims);
        let reference = traversal::natural(&g, 1).canonical_set();
        streaming_family(&g, 1, 64).iter().all(|(_, t)| {
            let full = multiset(t.as_ref(), 0..t.num_pencils());
            let mut sharded = Vec::new();
            for rg in shard_ranges(t.num_pencils(), 3) {
                t.stream_pencils(rg, &mut |x| sharded.push(Order::pack(x)));
            }
            sharded.sort_unstable();
            full == reference && sharded == reference
        })
    });
}

#[test]
fn sharded_engine_agrees_with_sequential_on_totals() {
    let g = GridDesc::new(&[24, 22, 18]);
    let stencil = Stencil::star(3, 1);
    let cache = CacheParams::new(2, 64, 2);
    let layout = MultiArrayLayout::paper_offsets(&g, 1, cache.size_words());
    let pool = ThreadPool::new(3);
    for (name, t) in streaming_family(&g, 1, cache.lattice_modulus()) {
        let mut sim = CacheSim::new(cache);
        let seq = engine::simulate(t.as_ref(), &layout, &stencil, &mut sim);
        let shd = engine::simulate_sharded(t.as_ref(), &layout, &stencil, &MachineModel::l1_only(cache), &pool, 4);
        assert_eq!(seq.points, shd.points, "{name}");
        assert_eq!(seq.total.accesses, shd.total.accesses, "{name}");
        // per-shard cold caches can only add misses relative to the warm
        // sequential stream (LRU: a warm prefix never hurts a suffix)
        assert!(shd.total.misses() >= seq.total.misses(), "{name}");
    }
}

/// Acceptance check for the streaming engine: a 512³ star13 Analyze —
/// whose packed visit order alone would need ~1 GB, plus ~2.6 GB of sort
/// keys on the materialized path — completes under CI memory limits by
/// streaming pencils. Run with:
///
/// ```text
/// cargo test --release -q --test streaming -- --ignored analyze_512
/// ```
#[test]
#[ignore = "large: ~1.9e9 simulated accesses; run in release (CI build job does)"]
fn analyze_512_cubed_star13_streaming() {
    let c = Coordinator::analysis_only(PlannerConfig::default());
    let req = StencilRequest {
        dims: vec![512, 512, 512],
        stencil: StencilSpec::Star13,
        rhs_arrays: 1,
        kind: JobKind::Analyze,
    };
    let resp = c.submit(&req).expect("512³ analyze");
    let rep = resp.miss_report.expect("analysis report");
    assert_eq!(rep.points, 508 * 508 * 508);
    assert_eq!(rep.total.accesses, rep.points * 14); // 13 u-reads + 1 q-write
    assert!(rep.u_loads_per_point() >= 1.0);
    assert!(resp.plan.shards > 1, "a 512³ job must be shardable");
}
