//! Numeric-backend integration tests: the coordinator's `Solve` path must
//! complete end-to-end **without** PJRT (the acceptance criterion for the
//! native backend), and the numeric sweep must be equivalent across every
//! traversal family — same visited multiset, same field, bit-for-bit when
//! the arithmetic admits it.

use stencilcache::cache::CacheParams;
use stencilcache::coordinator::{Coordinator, JobKind, PlannerConfig, StencilRequest, StencilSpec};
use stencilcache::engine;
use stencilcache::grid::GridDesc;
use stencilcache::lattice::InterferenceLattice;
use stencilcache::solver::{self, NativeBackend, NumericBackend, NumericJob};
use stencilcache::stencil::Stencil;
use stencilcache::traversal::{self, Order, Traversal};
use stencilcache::util::threadpool::ThreadPool;

/// Every streaming traversal family applicable to this grid.
fn traversal_family(g: &GridDesc, r: usize, modulus: usize) -> Vec<(String, Box<dyn Traversal>)> {
    let mut out: Vec<(String, Box<dyn Traversal>)> = vec![
        ("natural".into(), Box::new(traversal::natural_stream(g, r))),
        ("strip3".into(), Box::new(traversal::strip_stream(g, r, 3))),
        ("blocked".into(), Box::new(traversal::blocked_stream(g, r, &vec![4; g.ndim()]))),
    ];
    if g.ndim() <= 3 {
        let lat = InterferenceLattice::new(g.storage_dims(), modulus);
        out.push(("fitting".into(), Box::new(traversal::cache_fitting_stream(g, r, &lat))));
    }
    if g.ndim() == 3 {
        out.push(("tiled_z".into(), Box::new(traversal::tiled_z_sweep_stream(g, r, modulus, 2))));
    }
    out
}

/// ACCEPTANCE: with the `pjrt` feature off (the default build), a Solve
/// request completes numerically in CI on the native backend, logging
/// residual/L2 norms per step and dissipating energy.
#[test]
fn coordinator_solve_completes_natively_in_ci() {
    let coord = Coordinator::analysis_only(PlannerConfig::default());
    let resp = coord
        .submit(&StencilRequest {
            dims: vec![32, 32, 32],
            stencil: StencilSpec::Star13,
            rhs_arrays: 1,
            kind: JobKind::Solve { steps: 10 },
        })
        .expect("native Solve must complete without PJRT");
    assert_eq!(resp.solve_log.len(), 10);
    for s in &resp.solve_log {
        assert!(s.u_norm.is_finite() && s.u_norm > 0.0);
        assert!(s.residual_norm.is_finite() && s.residual_norm > 0.0);
    }
    for w in resp.solve_log.windows(2) {
        assert!(w[1].u_norm <= w[0].u_norm * 1.0001, "energy must not grow: {w:?}");
    }
    assert!(resp.solve_log.last().unwrap().u_norm < resp.solve_log[0].u_norm);
    assert!(resp.result_norm.unwrap() > 0.0);
    // Execute also runs natively on the same coordinator
    let exec = coord
        .submit(&StencilRequest {
            dims: vec![24, 24, 24],
            stencil: StencilSpec::Star13,
            rhs_arrays: 1,
            kind: JobKind::Execute,
        })
        .expect("native Execute");
    assert!(exec.result_norm.unwrap() > 0.0);
}

/// Mixed serve() workload with numeric jobs and no runtime: everything
/// completes, numeric responses carry norms, analyses carry reports.
#[test]
fn serve_mixed_numeric_and_analysis_without_runtime() {
    let coord = Coordinator::analysis_only(PlannerConfig::default());
    let reqs = vec![
        StencilRequest::analyze(&[16, 16, 16]),
        StencilRequest {
            dims: vec![16, 16, 16],
            stencil: StencilSpec::Star13,
            rhs_arrays: 1,
            kind: JobKind::Solve { steps: 3 },
        },
        StencilRequest {
            dims: vec![20, 18, 16],
            stencil: StencilSpec::Star { r: 1 },
            rhs_arrays: 1,
            kind: JobKind::Execute,
        },
        StencilRequest::analyze(&[16, 16, 16]),
    ];
    let resps = coord.serve(&reqs);
    assert_eq!(resps.len(), 4);
    let r0 = resps[0].as_ref().unwrap();
    assert!(r0.miss_report.is_some());
    let r1 = resps[1].as_ref().unwrap();
    assert_eq!(r1.solve_log.len(), 3);
    let r2 = resps[2].as_ref().unwrap();
    assert!(r2.result_norm.unwrap() > 0.0);
}

/// Cross-traversal equivalence: for random small grids and stencils, every
/// traversal visits exactly the natural order's interior multiset, and the
/// numeric apply produces the identical field. Per-point arithmetic does
/// not depend on visit order (q reads only u, coefficients are folded in a
/// fixed order), so equality is exact, not approximate.
#[test]
fn property_apply_equivalent_across_traversals_3d() {
    use stencilcache::util::proptest::{forall, DimsGen};
    forall(31, 10, &DimsGen { d: 3, lo: 7, hi: 15 }, |dims| {
        let g = GridDesc::new(dims);
        for r in [1usize, 2] {
            let s = Stencil::star(3, r);
            let words = g.storage_words() as usize;
            let u = solver::deterministic_field(&g, r, 17);
            let mut q_ref = vec![0.0; words];
            engine::apply(&traversal::natural_stream(&g, r), &g, &s, &u, &mut q_ref);
            let reference_set = traversal::natural(&g, r).canonical_set();
            for (name, t) in traversal_family(&g, r, 128) {
                let mut set = Vec::new();
                t.stream(&mut |x| set.push(Order::pack(x)));
                set.sort_unstable();
                if set != reference_set {
                    eprintln!("{name} on {dims:?} r={r}: multiset mismatch");
                    return false;
                }
                let mut q = vec![0.0; words];
                engine::apply(t.as_ref(), &g, &s, &u, &mut q);
                if q != q_ref {
                    eprintln!("{name} on {dims:?} r={r}: field mismatch");
                    return false;
                }
            }
        }
        true
    });
}

#[test]
fn apply_equivalent_across_traversals_2d_and_4d() {
    for (dims, r) in [(vec![13usize, 11], 2usize), (vec![7, 6, 5, 6], 1)] {
        let g = GridDesc::new(&dims);
        let s = Stencil::star(dims.len(), r);
        let words = g.storage_words() as usize;
        let u = solver::deterministic_field(&g, r, 3);
        let mut q_ref = vec![0.0; words];
        engine::apply(&traversal::natural_stream(&g, r), &g, &s, &u, &mut q_ref);
        for (name, t) in traversal_family(&g, r, 64) {
            let mut q = vec![0.0; words];
            engine::apply(t.as_ref(), &g, &s, &u, &mut q);
            assert_eq!(q, q_ref, "{name} on {dims:?}");
        }
    }
}

/// Bit-for-bit equality with dyadic (integer) coefficients, explicitly:
/// the r=1 star has coefficients {1, −2d}, exactly representable, and the
/// per-point accumulation runs the same op sequence under every traversal
/// and shard split — so natural, sharded, and exotic orders must agree to
/// the last bit.
#[test]
fn dyadic_star_bitwise_across_traversals_and_shards() {
    let g = GridDesc::new(&[14, 12, 10]);
    let s = Stencil::star(3, 1);
    let coeffs_dyadic = s.coeffs().iter().all(|c| c.fract() == 0.0);
    assert!(coeffs_dyadic, "r=1 star coefficients must be integers: {:?}", s.coeffs());
    let words = g.storage_words() as usize;
    let u = solver::deterministic_field(&g, 1, 23);
    let mut q_ref = vec![0.0; words];
    engine::apply(&traversal::natural_stream(&g, 1), &g, &s, &u, &mut q_ref);
    let pool = ThreadPool::new(3);
    for (name, t) in traversal_family(&g, 1, 64) {
        for shards in [1usize, 2, 7] {
            let mut q = vec![0.0; words];
            engine::apply_sharded(t.as_ref(), &g, &s, &u, &mut q, &pool, shards);
            assert_eq!(q, q_ref, "{name}, {shards} shards");
        }
    }
}

/// The native backend over different traversals must report identical
/// norms for the same job (the field is traversal-invariant; the reduction
/// order is fixed by the shard count, not the traversal).
#[test]
fn native_backend_norms_traversal_invariant() {
    let g = GridDesc::new(&[18, 16, 14]);
    let s = Stencil::star13();
    let pool = ThreadPool::new(2);
    let backend = NativeBackend::new(&pool);
    let dims = [18usize, 16, 14];
    let mut norms = Vec::new();
    for (_, t) in traversal_family(&g, 2, 4096) {
        let job = NumericJob {
            dims: &dims,
            grid: &g,
            stencil: &s,
            traversal: t.as_ref(),
            shards: 1,
            seed: 0xBEEF,
            temporal: None,
        };
        let out = backend.solve(&job, 4).unwrap();
        norms.push(out.solve_log.iter().map(|st| (st.u_norm, st.residual_norm)).collect::<Vec<_>>());
    }
    for w in norms.windows(2) {
        assert_eq!(w[0], w[1]);
    }
}

/// Heavy numeric end-to-end for the scheduled CI job: a 128³ star13 solve
/// on the native backend (sharded sweep + reductions), checking energy
/// decay at scale. Run with:
///
/// ```text
/// cargo test --release -q --test numeric -- --ignored native_solve_128
/// ```
#[test]
#[ignore = "large: ~2M points × 20 steps of 13-point FLOPs; run in release (scheduled CI job does)"]
fn native_solve_128_cubed_end_to_end() {
    let coord = Coordinator::analysis_only(PlannerConfig::default());
    let resp = coord
        .submit(&StencilRequest {
            dims: vec![128, 128, 128],
            stencil: StencilSpec::Star13,
            rhs_arrays: 1,
            kind: JobKind::Solve { steps: 20 },
        })
        .expect("128³ native solve");
    assert_eq!(resp.solve_log.len(), 20);
    for w in resp.solve_log.windows(2) {
        assert!(w[1].u_norm <= w[0].u_norm * 1.0001);
    }
    let (first, last) = (&resp.solve_log[0], resp.solve_log.last().unwrap());
    assert!(last.u_norm < first.u_norm);
    assert!(last.residual_norm > 0.0);
}

// ---------------------------------------------------------------------------
// Temporal blocking (DESIGN.md §2.6)
// ---------------------------------------------------------------------------

/// Reference: `steps` classic explicit steps (apply + full-buffer axpy),
/// returning the final field and the per-step `(Σ u'², Σ q²)` sums — the
/// exact arithmetic `NativeBackend::solve` performs with `shards = 1`.
fn classic_steps(g: &GridDesc, s: &Stencil, u0: &[f64], alpha: f64, steps: usize) -> (Vec<f64>, Vec<(f64, f64)>) {
    let nat = traversal::natural_stream(g, s.radius());
    let mut u = u0.to_vec();
    let mut q = vec![0.0; u.len()];
    let mut norms = Vec::new();
    for _ in 0..steps {
        engine::apply(&nat, g, s, &u, &mut q);
        let (mut u2, mut r2) = (0.0, 0.0);
        for i in 0..u.len() {
            u[i] += alpha * q[i];
            u2 += u[i] * u[i];
            r2 += q[i] * q[i];
        }
        norms.push((u2, r2));
    }
    (u, norms)
}

fn close(a: f64, b: f64) -> bool {
    // summation-order tolerance: ~n·ε relative for sums of ~10⁴ terms
    (a - b).abs() <= 1e-11 * (1.0 + a.abs().max(b.abs()))
}

/// TENTPOLE equivalence: one time-tiled superstep of depth `k` produces a
/// field **bitwise equal** to `k` classic single steps, for k ∈ {1, 2, 4},
/// across star radii, odd grid shapes and dimensionalities; the per-step
/// norm sums agree to summation-order tolerance.
#[test]
fn temporal_step_bitwise_equals_k_single_steps() {
    let pool = ThreadPool::new(2);
    let cases: &[(&[usize], usize, &[usize])] = &[
        (&[24, 22, 20], 2, &[18, 5, 6]),
        (&[19, 17, 16], 1, &[17, 4, 5]),
        (&[15, 14], 2, &[11, 4]),
        (&[40], 1, &[38]),
    ];
    for &(dims, r, tile) in cases {
        let g = GridDesc::new(dims);
        let s = Stencil::star(dims.len(), r);
        let alpha = NativeBackend::stable_alpha(&s);
        let u0 = solver::deterministic_field(&g, r, 41);
        for k in [1usize, 2, 4] {
            let (u_ref, norms_ref) = classic_steps(&g, &s, &u0, alpha, k);
            let tt = traversal::temporal_stream(&g, r, tile, k);
            let mut v = u0.clone();
            let norms = engine::step_time_tiled(&tt, &g, &s, &u0, &mut v, alpha, k, &pool, 1);
            assert_eq!(v, u_ref, "{dims:?} r={r} k={k}: field must be bitwise equal");
            assert_eq!(norms.len(), k);
            for (i, ((u2, r2), (u2r, r2r))) in norms.iter().zip(&norms_ref).enumerate() {
                assert!(close(*u2, *u2r), "{dims:?} k={k} step {i}: u² {u2} vs {u2r}");
                assert!(close(*r2, *r2r), "{dims:?} k={k} step {i}: r² {r2} vs {r2r}");
            }
        }
    }
}

/// Sharded time-tiled sweeps are bitwise identical to the serial sweep:
/// owned tiles partition the interior, so shard boundaries cannot change a
/// single written word.
#[test]
fn temporal_step_sharded_matches_serial_bitwise() {
    let g = GridDesc::new(&[19, 18, 17]);
    let s = Stencil::star13();
    let alpha = NativeBackend::stable_alpha(&s);
    let u0 = solver::deterministic_field(&g, 2, 53);
    let pool = ThreadPool::new(4);
    for k in [1usize, 3] {
        let tt = traversal::temporal_stream(&g, 2, &[15, 4, 5], k);
        let mut v_ref = u0.clone();
        engine::step_time_tiled(&tt, &g, &s, &u0, &mut v_ref, alpha, k, &pool, 1);
        let (u_classic, _) = classic_steps(&g, &s, &u0, alpha, k);
        assert_eq!(v_ref, u_classic, "serial temporal k={k} vs classic");
        for shards in [2usize, 7] {
            let mut v = u0.clone();
            engine::step_time_tiled(&tt, &g, &s, &u0, &mut v, alpha, k, &pool, shards);
            assert_eq!(v, v_ref, "k={k}, {shards} shards");
        }
    }
}

/// Halo correctness when the whole grid is smaller than one halo-deep
/// tile: the valid-region clamp must keep every read in bounds and the
/// result exact (single tile, box = entire grid, deep k).
#[test]
fn temporal_halo_correctness_grid_smaller_than_tile() {
    let pool = ThreadPool::new(2);
    for (dims, r, k) in [(vec![9usize, 8, 7], 1usize, 4usize), (vec![7, 7], 2, 2), (vec![11, 9], 1, 4)] {
        let g = GridDesc::new(&dims);
        let s = Stencil::star(dims.len(), r);
        let alpha = NativeBackend::stable_alpha(&s);
        let u0 = solver::deterministic_field(&g, r, 67);
        let (u_ref, _) = classic_steps(&g, &s, &u0, alpha, k);
        let tt = traversal::temporal_stream(&g, r, &vec![64; dims.len()], k);
        assert_eq!(tt.num_pencils(), 1, "{dims:?}: tile must swallow the grid");
        let mut v = u0.clone();
        engine::step_time_tiled(&tt, &g, &s, &u0, &mut v, alpha, k, &pool, 3);
        assert_eq!(v, u_ref, "{dims:?} r={r} k={k}");
    }
}

/// End-to-end through the coordinator: a machine with an L2 plans a deep
/// time tile (k = 8 at 48³), and the temporal solve's per-step norms match
/// the default machine's fused-k=1 solve to reduction-order tolerance.
#[test]
fn coordinator_temporal_solve_matches_default_machine() {
    use stencilcache::cache::MachineModel;
    let req = || StencilRequest {
        dims: vec![48, 48, 48],
        stencil: StencilSpec::Star13,
        rhs_arrays: 1,
        kind: JobKind::Solve { steps: 9 },
    };
    let fused = Coordinator::analysis_only(PlannerConfig::default()).submit(&req()).unwrap();
    assert_eq!(fused.plan.time_tile, 1, "L1-only machine cannot hold a halo-deep tile");
    let full = PlannerConfig { machine: MachineModel::r10000_full(), ..PlannerConfig::default() };
    let deep = Coordinator::analysis_only(full).submit(&req()).unwrap();
    assert!(deep.plan.time_tile >= 4, "plan.time_tile = {}", deep.plan.time_tile);
    assert_eq!(deep.plan.time_tile_dims.len(), 3);
    assert_eq!(deep.solve_log.len(), 9);
    for (a, b) in fused.solve_log.iter().zip(&deep.solve_log) {
        assert!((a.u_norm - b.u_norm).abs() < 1e-9 * (1.0 + a.u_norm), "step {}: {} vs {}", a.step, a.u_norm, b.u_norm);
        let dr = (a.residual_norm - b.residual_norm).abs();
        assert!(dr < 1e-9 * (1.0 + a.residual_norm), "step {}", a.step);
    }
    for w in deep.solve_log.windows(2) {
        assert!(w[1].u_norm <= w[0].u_norm * 1.0001, "energy must not grow: {w:?}");
    }
}

/// Full-size temporal equivalence for the scheduled CI job: at 256³ the
/// r10000-full planner picks k ≥ 4, and one depth-k superstep is bitwise
/// equal to k classic steps. Run with:
///
/// ```text
/// cargo test --release -q --test numeric -- --ignored temporal_equivalence_256
/// ```
#[test]
#[ignore = "large: ~134 MB per buffer and 4+ full-grid sweeps; nightly CI runs it in release"]
fn temporal_equivalence_256_cubed() {
    use stencilcache::cache::MachineModel;
    use stencilcache::coordinator::choose_time_tile;
    let g = GridDesc::new(&[256, 256, 256]);
    let s = Stencil::star13();
    let (k, tile) = choose_time_tile(&MachineModel::r10000_full(), &g, 2);
    assert!(k >= 4, "256³ on r10000-full must time-tile at least 4 deep, got {k}");
    let alpha = NativeBackend::stable_alpha(&s);
    let u0 = solver::deterministic_field(&g, 2, 97);
    let (u_ref, _) = classic_steps(&g, &s, &u0, alpha, k);
    let pool = ThreadPool::new(4);
    let tt = traversal::temporal_stream(&g, 2, &tile, k);
    let mut v = u0.clone();
    engine::step_time_tiled(&tt, &g, &s, &u0, &mut v, alpha, k, &pool, 4);
    assert_eq!(v, u_ref, "256³ k={k}: temporal field must be bitwise equal");
}

/// The §5 cache-params used by the sharded analysis must not change the
/// numeric result either: apply with the planner's fitting traversal on a
/// padded grid equals the natural sweep on that same padded grid.
#[test]
fn padded_grid_apply_matches_natural() {
    let g = GridDesc::with_padding(&[15, 13, 11], &[3, 1, 0]);
    let s = Stencil::star(3, 1);
    let cache = CacheParams::new(2, 64, 2);
    let words = g.storage_words() as usize;
    let u = solver::deterministic_field(&g, 1, 29);
    let mut q_nat = vec![0.0; words];
    engine::apply(&traversal::natural_stream(&g, 1), &g, &s, &u, &mut q_nat);
    let mut q_fit = vec![0.0; words];
    engine::apply(&traversal::cache_fitting_stream_for_cache(&g, 1, &cache), &g, &s, &u, &mut q_fit);
    assert_eq!(q_nat, q_fit);
}
