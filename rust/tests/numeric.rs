//! Numeric-backend integration tests: the coordinator's `Solve` path must
//! complete end-to-end **without** PJRT (the acceptance criterion for the
//! native backend), and the numeric sweep must be equivalent across every
//! traversal family — same visited multiset, same field, bit-for-bit when
//! the arithmetic admits it.

use stencilcache::cache::CacheParams;
use stencilcache::coordinator::{Coordinator, JobKind, PlannerConfig, StencilRequest, StencilSpec};
use stencilcache::engine;
use stencilcache::grid::GridDesc;
use stencilcache::lattice::InterferenceLattice;
use stencilcache::solver::{self, NativeBackend, NumericBackend, NumericJob};
use stencilcache::stencil::Stencil;
use stencilcache::traversal::{self, Order, Traversal};
use stencilcache::util::threadpool::ThreadPool;

/// Every streaming traversal family applicable to this grid.
fn traversal_family(g: &GridDesc, r: usize, modulus: usize) -> Vec<(String, Box<dyn Traversal>)> {
    let mut out: Vec<(String, Box<dyn Traversal>)> = vec![
        ("natural".into(), Box::new(traversal::natural_stream(g, r))),
        ("strip3".into(), Box::new(traversal::strip_stream(g, r, 3))),
        ("blocked".into(), Box::new(traversal::blocked_stream(g, r, &vec![4; g.ndim()]))),
    ];
    if g.ndim() <= 3 {
        let lat = InterferenceLattice::new(g.storage_dims(), modulus);
        out.push(("fitting".into(), Box::new(traversal::cache_fitting_stream(g, r, &lat))));
    }
    if g.ndim() == 3 {
        out.push(("tiled_z".into(), Box::new(traversal::tiled_z_sweep_stream(g, r, modulus, 2))));
    }
    out
}

/// ACCEPTANCE: with the `pjrt` feature off (the default build), a Solve
/// request completes numerically in CI on the native backend, logging
/// residual/L2 norms per step and dissipating energy.
#[test]
fn coordinator_solve_completes_natively_in_ci() {
    let coord = Coordinator::analysis_only(PlannerConfig::default());
    let resp = coord
        .submit(&StencilRequest {
            dims: vec![32, 32, 32],
            stencil: StencilSpec::Star13,
            rhs_arrays: 1,
            kind: JobKind::Solve { steps: 10 },
        })
        .expect("native Solve must complete without PJRT");
    assert_eq!(resp.solve_log.len(), 10);
    for s in &resp.solve_log {
        assert!(s.u_norm.is_finite() && s.u_norm > 0.0);
        assert!(s.residual_norm.is_finite() && s.residual_norm > 0.0);
    }
    for w in resp.solve_log.windows(2) {
        assert!(w[1].u_norm <= w[0].u_norm * 1.0001, "energy must not grow: {w:?}");
    }
    assert!(resp.solve_log.last().unwrap().u_norm < resp.solve_log[0].u_norm);
    assert!(resp.result_norm.unwrap() > 0.0);
    // Execute also runs natively on the same coordinator
    let exec = coord
        .submit(&StencilRequest {
            dims: vec![24, 24, 24],
            stencil: StencilSpec::Star13,
            rhs_arrays: 1,
            kind: JobKind::Execute,
        })
        .expect("native Execute");
    assert!(exec.result_norm.unwrap() > 0.0);
}

/// Mixed serve() workload with numeric jobs and no runtime: everything
/// completes, numeric responses carry norms, analyses carry reports.
#[test]
fn serve_mixed_numeric_and_analysis_without_runtime() {
    let coord = Coordinator::analysis_only(PlannerConfig::default());
    let reqs = vec![
        StencilRequest::analyze(&[16, 16, 16]),
        StencilRequest {
            dims: vec![16, 16, 16],
            stencil: StencilSpec::Star13,
            rhs_arrays: 1,
            kind: JobKind::Solve { steps: 3 },
        },
        StencilRequest {
            dims: vec![20, 18, 16],
            stencil: StencilSpec::Star { r: 1 },
            rhs_arrays: 1,
            kind: JobKind::Execute,
        },
        StencilRequest::analyze(&[16, 16, 16]),
    ];
    let resps = coord.serve(&reqs);
    assert_eq!(resps.len(), 4);
    let r0 = resps[0].as_ref().unwrap();
    assert!(r0.miss_report.is_some());
    let r1 = resps[1].as_ref().unwrap();
    assert_eq!(r1.solve_log.len(), 3);
    let r2 = resps[2].as_ref().unwrap();
    assert!(r2.result_norm.unwrap() > 0.0);
}

/// Cross-traversal equivalence: for random small grids and stencils, every
/// traversal visits exactly the natural order's interior multiset, and the
/// numeric apply produces the identical field. Per-point arithmetic does
/// not depend on visit order (q reads only u, coefficients are folded in a
/// fixed order), so equality is exact, not approximate.
#[test]
fn property_apply_equivalent_across_traversals_3d() {
    use stencilcache::util::proptest::{forall, DimsGen};
    forall(31, 10, &DimsGen { d: 3, lo: 7, hi: 15 }, |dims| {
        let g = GridDesc::new(dims);
        for r in [1usize, 2] {
            let s = Stencil::star(3, r);
            let words = g.storage_words() as usize;
            let u = solver::deterministic_field(&g, r, 17);
            let mut q_ref = vec![0.0; words];
            engine::apply(&traversal::natural_stream(&g, r), &g, &s, &u, &mut q_ref);
            let reference_set = traversal::natural(&g, r).canonical_set();
            for (name, t) in traversal_family(&g, r, 128) {
                let mut set = Vec::new();
                t.stream(&mut |x| set.push(Order::pack(x)));
                set.sort_unstable();
                if set != reference_set {
                    eprintln!("{name} on {dims:?} r={r}: multiset mismatch");
                    return false;
                }
                let mut q = vec![0.0; words];
                engine::apply(t.as_ref(), &g, &s, &u, &mut q);
                if q != q_ref {
                    eprintln!("{name} on {dims:?} r={r}: field mismatch");
                    return false;
                }
            }
        }
        true
    });
}

#[test]
fn apply_equivalent_across_traversals_2d_and_4d() {
    for (dims, r) in [(vec![13usize, 11], 2usize), (vec![7, 6, 5, 6], 1)] {
        let g = GridDesc::new(&dims);
        let s = Stencil::star(dims.len(), r);
        let words = g.storage_words() as usize;
        let u = solver::deterministic_field(&g, r, 3);
        let mut q_ref = vec![0.0; words];
        engine::apply(&traversal::natural_stream(&g, r), &g, &s, &u, &mut q_ref);
        for (name, t) in traversal_family(&g, r, 64) {
            let mut q = vec![0.0; words];
            engine::apply(t.as_ref(), &g, &s, &u, &mut q);
            assert_eq!(q, q_ref, "{name} on {dims:?}");
        }
    }
}

/// Bit-for-bit equality with dyadic (integer) coefficients, explicitly:
/// the r=1 star has coefficients {1, −2d}, exactly representable, and the
/// per-point accumulation runs the same op sequence under every traversal
/// and shard split — so natural, sharded, and exotic orders must agree to
/// the last bit.
#[test]
fn dyadic_star_bitwise_across_traversals_and_shards() {
    let g = GridDesc::new(&[14, 12, 10]);
    let s = Stencil::star(3, 1);
    let coeffs_dyadic = s.coeffs().iter().all(|c| c.fract() == 0.0);
    assert!(coeffs_dyadic, "r=1 star coefficients must be integers: {:?}", s.coeffs());
    let words = g.storage_words() as usize;
    let u = solver::deterministic_field(&g, 1, 23);
    let mut q_ref = vec![0.0; words];
    engine::apply(&traversal::natural_stream(&g, 1), &g, &s, &u, &mut q_ref);
    let pool = ThreadPool::new(3);
    for (name, t) in traversal_family(&g, 1, 64) {
        for shards in [1usize, 2, 7] {
            let mut q = vec![0.0; words];
            engine::apply_sharded(t.as_ref(), &g, &s, &u, &mut q, &pool, shards);
            assert_eq!(q, q_ref, "{name}, {shards} shards");
        }
    }
}

/// The native backend over different traversals must report identical
/// norms for the same job (the field is traversal-invariant; the reduction
/// order is fixed by the shard count, not the traversal).
#[test]
fn native_backend_norms_traversal_invariant() {
    let g = GridDesc::new(&[18, 16, 14]);
    let s = Stencil::star13();
    let pool = ThreadPool::new(2);
    let backend = NativeBackend::new(&pool);
    let dims = [18usize, 16, 14];
    let mut norms = Vec::new();
    for (_, t) in traversal_family(&g, 2, 4096) {
        let job = NumericJob { dims: &dims, grid: &g, stencil: &s, traversal: t.as_ref(), shards: 1, seed: 0xBEEF };
        let out = backend.solve(&job, 4).unwrap();
        norms.push(out.solve_log.iter().map(|st| (st.u_norm, st.residual_norm)).collect::<Vec<_>>());
    }
    for w in norms.windows(2) {
        assert_eq!(w[0], w[1]);
    }
}

/// Heavy numeric end-to-end for the scheduled CI job: a 128³ star13 solve
/// on the native backend (sharded sweep + reductions), checking energy
/// decay at scale. Run with:
///
/// ```text
/// cargo test --release -q --test numeric -- --ignored native_solve_128
/// ```
#[test]
#[ignore = "large: ~2M points × 20 steps of 13-point FLOPs; run in release (scheduled CI job does)"]
fn native_solve_128_cubed_end_to_end() {
    let coord = Coordinator::analysis_only(PlannerConfig::default());
    let resp = coord
        .submit(&StencilRequest {
            dims: vec![128, 128, 128],
            stencil: StencilSpec::Star13,
            rhs_arrays: 1,
            kind: JobKind::Solve { steps: 20 },
        })
        .expect("128³ native solve");
    assert_eq!(resp.solve_log.len(), 20);
    for w in resp.solve_log.windows(2) {
        assert!(w[1].u_norm <= w[0].u_norm * 1.0001);
    }
    let (first, last) = (&resp.solve_log[0], resp.solve_log.last().unwrap());
    assert!(last.u_norm < first.u_norm);
    assert!(last.residual_norm > 0.0);
}

/// The §5 cache-params used by the sharded analysis must not change the
/// numeric result either: apply with the planner's fitting traversal on a
/// padded grid equals the natural sweep on that same padded grid.
#[test]
fn padded_grid_apply_matches_natural() {
    let g = GridDesc::with_padding(&[15, 13, 11], &[3, 1, 0]);
    let s = Stencil::star(3, 1);
    let cache = CacheParams::new(2, 64, 2);
    let words = g.storage_words() as usize;
    let u = solver::deterministic_field(&g, 1, 29);
    let mut q_nat = vec![0.0; words];
    engine::apply(&traversal::natural_stream(&g, 1), &g, &s, &u, &mut q_nat);
    let mut q_fit = vec![0.0; words];
    engine::apply(&traversal::cache_fitting_stream_for_cache(&g, 1, &cache), &g, &s, &u, &mut q_fit);
    assert_eq!(q_nat, q_fit);
}
