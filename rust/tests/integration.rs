//! Cross-module integration tests: the full pipeline from planning through
//! simulation and (when artifacts are present) PJRT execution.

use stencilcache::cache::{CacheParams, CacheSim};
use stencilcache::coordinator::{Coordinator, JobKind, PlannerConfig, StencilRequest, StencilSpec, TraversalChoice};
use stencilcache::engine;
use stencilcache::grid::{GridDesc, MultiArrayLayout};
use stencilcache::lattice::InterferenceLattice;
use stencilcache::stencil::Stencil;
use stencilcache::traversal;
use stencilcache::tuner;

/// The paper's core qualitative claim, end to end through the public API:
/// on a favorable grid, the planner's fitting traversal strictly reduces
/// replacement misses vs the natural order; on the Figure-4 spike grid the
/// padding advisor rescues it.
#[test]
fn paper_story_end_to_end() {
    let cache = CacheParams::r10000();
    let stencil = Stencil::star13();

    // favorable grid: fitting wins
    let good = GridDesc::new(&[44, 91, 30]);
    let layout = MultiArrayLayout::paper_offsets(&good, 1, cache.size_words());
    let mut sim = CacheSim::new(cache);
    let nat = engine::simulate(&traversal::natural(&good, 2), &layout, &stencil, &mut sim);
    let (fit_order, _) = tuner::auto_fitting_order(&good, &stencil, &cache);
    let mut sim2 = CacheSim::new(cache);
    let fit = engine::simulate(&fit_order, &layout, &stencil, &mut sim2);
    assert!(fit.total.misses() * 2 < nat.total.misses(), "fit {} vs nat {}", fit.total.misses(), nat.total.misses());

    // spike grid: unfavorable, advisor pads, padded grid behaves
    let bad = GridDesc::new(&[45, 91, 30]);
    assert!(stencilcache::padding::is_unfavorable(&bad, &stencil, &cache));
    let advice = stencilcache::padding::advise(&bad, &stencil, &cache, 8);
    assert!(advice.favorable);
    let padded = GridDesc::with_padding(bad.dims(), &advice.pad);
    let playout = MultiArrayLayout::paper_offsets(&padded, 1, cache.size_words());
    let (porder, _) = tuner::auto_fitting_order(&padded, &stencil, &cache);
    let mut sim3 = CacheSim::new(cache);
    let padded_fit = engine::simulate(&porder, &playout, &stencil, &mut sim3);
    let mut sim4 = CacheSim::new(cache);
    let bad_layout = MultiArrayLayout::paper_offsets(&bad, 1, cache.size_words());
    let (border, _) = tuner::auto_fitting_order(&bad, &stencil, &cache);
    let bad_fit = engine::simulate(&border, &bad_layout, &stencil, &mut sim4);
    assert!(
        padded_fit.misses_per_point() < 0.5 * bad_fit.misses_per_point(),
        "padding must rescue the spike grid: {} vs {}",
        padded_fit.misses_per_point(),
        bad_fit.misses_per_point()
    );
}

/// Eq 7 must lower-bound measured u-loads for *every* traversal order —
/// it is a lower bound on the problem, not on an algorithm.
#[test]
fn lower_bound_holds_for_all_orders() {
    let cache = CacheParams::new(2, 64, 2); // S = 256
    let grid = GridDesc::new(&[24, 22, 18]);
    let stencil = Stencil::star(3, 1);
    let lb = stencilcache::bounds::lower_bound_loads(&grid, cache.size_words());
    let layout = MultiArrayLayout::paper_offsets(&grid, 1, cache.size_words());
    let orders = vec![
        ("natural", traversal::natural(&grid, 1)),
        ("blocked8", traversal::blocked(&grid, 1, &[8, 8, 8])),
        ("strip4", traversal::strip(&grid, 1, 4)),
        ("fitting", traversal::cache_fitting_for_cache(&grid, 1, &cache)),
        ("tiled", traversal::tiled_z_sweep(&grid, 1, cache.size_words())),
    ];
    for (name, order) in orders {
        let mut sim = CacheSim::new(cache);
        let rep = engine::simulate(&order, &layout, &stencil, &mut sim);
        // Eq 7 is stated for loads of u over the K-interior computation.
        assert!(
            rep.u_loads as f64 >= lb * 0.999,
            "{name}: measured {} < lower bound {lb}",
            rep.u_loads
        );
    }
}

/// The coordinator's full mixed-workload serve path with failure injection:
/// invalid requests fail cleanly without poisoning the batch.
#[test]
fn serve_with_failure_injection() {
    let coord = Coordinator::analysis_only(PlannerConfig::default());
    let mut reqs: Vec<StencilRequest> = (0..6).map(|i| StencilRequest::analyze(&[14 + i % 2, 14, 14])).collect();
    reqs.insert(2, StencilRequest { dims: vec![0, 4], stencil: StencilSpec::Star { r: 1 }, rhs_arrays: 1, kind: JobKind::Plan });
    reqs.insert(5, StencilRequest { dims: vec![16, 16, 16], stencil: StencilSpec::Star13, rhs_arrays: 0, kind: JobKind::Plan });
    let resps = coord.serve(&reqs);
    assert_eq!(resps.len(), 8);
    assert!(resps[2].is_err());
    assert!(resps[5].is_err());
    let ok = resps.iter().filter(|r| r.is_ok()).count();
    assert_eq!(ok, 6);
}

/// Planner invariants across a random sample of shapes (property-style).
#[test]
fn planner_invariants_random_grids() {
    use stencilcache::util::proptest::{forall, DimsGen};
    let config = PlannerConfig::default();
    forall(99, 25, &DimsGen { d: 3, lo: 12, hi: 80 }, |dims| {
        let plan = stencilcache::coordinator::plan(&config, dims, &Stencil::star13(), 1);
        let storage_ok = plan.storage_dims.iter().zip(dims).all(|(&s, &l)| s >= l);
        let bounds_ok = plan.lower_bound <= plan.upper_bound && plan.lower_bound >= 0.0;
        let pad_ok = plan.pad.len() == 3 && plan.pad[2] == 0;
        storage_ok && bounds_ok && pad_ok
    });
}

/// PJRT round trip (skipped gracefully when artifacts are absent).
#[test]
fn pjrt_solve_through_coordinator() {
    let Ok(svc) = stencilcache::runtime::RuntimeService::start(None) else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let coord = Coordinator::with_runtime(PlannerConfig::default(), svc.handle());
    let resp = coord
        .submit(&StencilRequest {
            dims: vec![16, 16, 16],
            stencil: StencilSpec::Star13,
            rhs_arrays: 1,
            kind: JobKind::Solve { steps: 8 },
        })
        .expect("solve");
    assert_eq!(resp.solve_log.len(), 8);
    // energy decreases monotonically under the stable explicit step
    for w in resp.solve_log.windows(2) {
        assert!(w[1].u_norm <= w[0].u_norm * 1.0001, "{:?}", w);
    }
    // analysis jobs work on the same coordinator
    let a = coord
        .submit(&StencilRequest {
            dims: vec![20, 20, 20],
            stencil: StencilSpec::Star13,
            rhs_arrays: 1,
            kind: JobKind::AnalyzeWith(TraversalChoice::Natural),
        })
        .expect("analyze");
    assert!(a.miss_report.unwrap().total.misses() > 0);
}

/// Lattice ↔ simulator consistency: two addresses collide in the simulated
/// cache iff their index difference is in the interference lattice.
#[test]
fn lattice_predicts_simulated_conflicts() {
    let cache = CacheParams::new(1, 64, 1); // direct-mapped, S = 64: collisions exact
    let dims = [12usize, 10];
    let grid = GridDesc::new(&dims);
    let lat = InterferenceLattice::new(&dims, cache.lattice_modulus());
    let mut sim = CacheSim::new(cache);
    let mut rng = stencilcache::util::rng::Rng::new(3);
    for _ in 0..200 {
        let a = [rng.below(12 as u64) as i64, rng.below(10) as i64];
        let b = [rng.below(12) as i64, rng.below(10) as i64];
        let diff = [a[0] - b[0], a[1] - b[1]];
        let addr_a = grid.offset_of(&a);
        let addr_b = grid.offset_of(&b);
        let same_set = cache.set_of(addr_a) == cache.set_of(addr_b);
        assert_eq!(lat.contains(&diff), same_set, "a={a:?} b={b:?}");
        sim.access(addr_a);
        sim.access(addr_b);
    }
}
