//! Golden regression tests for the paper-reproduction drivers: the Eq 7 /
//! Eq 12 bound numbers (and sec3's measured + closed-form loads) are pinned
//! to committed fixtures so they cannot silently drift when someone touches
//! the bounds math, the lattice reduction, the layout, or the simulator.
//!
//! The float-valued diagnostic columns that merely *derive* from the pinned
//! ones (rel err, per-point rates) are not pinned — they'd only duplicate
//! the comparison with extra formatting hazards. Measured columns that
//! depend on the auto-tuner's candidate choice (bounds `measured`) are
//! covered by the sandwich property tests instead.
//!
//! To regenerate after an *intentional* change:
//!
//! ```text
//! STENCILCACHE_BLESS=1 cargo test --test golden
//! git diff rust/tests/fixtures/   # review, then commit
//! ```

use stencilcache::cache::MachineModel;
use stencilcache::engine;
use stencilcache::experiments::{bounds_table, sec3};
use stencilcache::grid::{GridDesc, MultiArrayLayout};
use stencilcache::report::Table;
use stencilcache::stencil::Stencil;
use stencilcache::traversal;
use std::path::PathBuf;

fn fixture_path(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures").join(name)
}

/// Project the table onto `cols`, one space-joined line per row.
fn project(t: &Table, cols: &[usize]) -> String {
    let mut out = String::new();
    for row in t.rows() {
        let cells: Vec<&str> = cols.iter().map(|&c| row[c].as_str()).collect();
        out.push_str(&cells.join(" "));
        out.push('\n');
    }
    out
}

fn check_golden(name: &str, got: &str) {
    let path = fixture_path(name);
    if std::env::var("STENCILCACHE_BLESS").is_ok() {
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, got).unwrap();
        eprintln!("blessed {path:?}");
        return;
    }
    let want = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("missing fixture {path:?} ({e}); regenerate with STENCILCACHE_BLESS=1"));
    if got != want {
        eprintln!("--- got ---\n{got}--- want ---\n{want}");
        panic!("{name} drifted; if intentional: STENCILCACHE_BLESS=1 cargo test --test golden, then commit");
    }
}

/// sec3 columns: k, n1, n2, measured u-loads, closed form, Eq7 bound.
/// The measured count is an exact LRU simulation of a deterministic
/// address stream — any change is a semantic change, never noise.
#[test]
fn sec3_numbers_match_fixture() {
    let t = sec3::run(true);
    assert_eq!(t.num_rows(), 3);
    check_golden("sec3_quick.golden", &project(&t, &[0, 1, 2, 3, 4, 6]));
}

/// bounds columns: grid, S, Eq7 lower, Eq12 upper, reduced-basis
/// eccentricity, parallelepiped volume utilization.
#[test]
fn bounds_table_numbers_match_fixture() {
    let t = bounds_table::run(true);
    assert!(t.num_rows() >= 4, "quick bounds table lost rows");
    check_golden("bounds_quick.golden", &project(&t, &[0, 1, 2, 4, 6, 7]));
}

/// Per-level profile of a 90×91×8 star13 analysis (natural order, §5
/// offset layout) on the full `r10000-full` machine — one line per level
/// with every §2 counter, plus the stall estimate. 90×91 is a Figure-4
/// L1 spike grid *and* its 5-plane page window (~80 pages) overflows the
/// 64-entry TLB, so every level shows cold and replacement traffic. The
/// fixture pins the L1/L2/TLB composition exactly; the L1 row doubles as
/// the single-level regression (it must equal what a bare `CacheSim`
/// produced before the memory-model refactor).
#[test]
fn hierarchy_profile_matches_fixture() {
    let machine = MachineModel::r10000_full();
    let grid = GridDesc::new(&[90, 91, 8]);
    let stencil = Stencil::star13();
    let layout = MultiArrayLayout::paper_offsets(&grid, 1, machine.l1.size_words());
    let mut hier = machine.build_hierarchy();
    let rep = engine::simulate(&traversal::natural_stream(&grid, 2), &layout, &stencil, &mut hier);
    let mut got = String::new();
    for lv in rep.levels.levels() {
        let s = lv.stats;
        got.push_str(&format!(
            "{} {} {} {} {} {} {} {}\n",
            lv.level.name(),
            s.accesses,
            s.hits,
            s.cold_misses,
            s.replacement_misses,
            s.cold_loads,
            s.replacement_loads,
            s.evictions
        ));
    }
    got.push_str(&format!("stall {}\n", rep.levels.stall_cycles(machine.latency)));
    check_golden("hierarchy_quick.golden", &got);
}
