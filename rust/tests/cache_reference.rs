//! CacheSim vs a brute-force reference model.
//!
//! The production simulator ([`stencilcache::cache::CacheSim`]) is the
//! hottest code in the repo and is correspondingly optimized (move-to-front
//! LRU arrays, growable bitsets). This file re-implements §2 of the paper
//! in the most naive way possible — per-set `Vec`s in recency order,
//! `HashSet`s for history — and checks the two agree **per access** on
//! random address streams over direct-mapped, set-associative, and fully
//! associative geometries, including the cold/replacement *load*
//! classification the paper's bounds constrain.

use stencilcache::cache::{AccessKind, CacheParams, CacheSim, CacheStats, Hierarchy, TlbParams};
use stencilcache::util::rng::Rng;
use std::collections::HashSet;

/// Naive reference: exact LRU set-associative cache with §2 counters.
struct RefCache {
    params: CacheParams,
    /// One Vec per set, most-recently-used first, holding line numbers.
    sets: Vec<Vec<u64>>,
    seen_lines: HashSet<u64>,
    requested_words: HashSet<u64>,
    stats: CacheStats,
}

impl RefCache {
    fn new(params: CacheParams) -> RefCache {
        RefCache {
            params,
            sets: vec![Vec::new(); params.sets],
            seen_lines: HashSet::new(),
            requested_words: HashSet::new(),
            stats: CacheStats::default(),
        }
    }

    fn is_resident(&self, addr: u64) -> bool {
        let line = self.params.line_of(addr);
        self.sets[self.params.set_of(addr)].contains(&line)
    }

    fn access(&mut self, addr: u64) -> AccessKind {
        self.stats.accesses += 1;
        let line = self.params.line_of(addr);
        let set = self.params.set_of(addr);
        let ways = &mut self.sets[set];
        let kind = if let Some(pos) = ways.iter().position(|&l| l == line) {
            // hit: move to front
            ways.remove(pos);
            ways.insert(0, line);
            AccessKind::Hit
        } else {
            ways.insert(0, line);
            if ways.len() > self.params.assoc {
                ways.pop(); // evict LRU
                self.stats.evictions += 1;
            }
            if self.seen_lines.insert(line) {
                AccessKind::ColdMiss
            } else {
                AccessKind::ReplacementMiss
            }
        };
        match kind {
            AccessKind::Hit => self.stats.hits += 1,
            AccessKind::ColdMiss => self.stats.cold_misses += 1,
            AccessKind::ReplacementMiss => self.stats.replacement_misses += 1,
        }
        // §2 word-level loads: cold = first explicit request to the word;
        // replacement = re-request whose line had to be re-fetched.
        let requested_before = !self.requested_words.insert(addr);
        if !requested_before {
            self.stats.cold_loads += 1;
        } else if kind != AccessKind::Hit {
            self.stats.replacement_loads += 1;
        }
        kind
    }
}

/// Drive both models through one pseudo-random stream, asserting agreement
/// per access (outcome + residency of a probe address) and at the end
/// (every counter).
fn compare_on_random_stream(params: CacheParams, addr_space: u64, accesses: usize, seed: u64) {
    let mut fast = CacheSim::new(params);
    let mut slow = RefCache::new(params);
    let mut rng = Rng::new(seed);
    for i in 0..accesses {
        let addr = rng.below(addr_space);
        let a = fast.access(addr);
        let b = slow.access(addr);
        assert_eq!(a, b, "access #{i} (addr {addr}) diverged: sim {a:?} vs reference {b:?}");
        let probe = rng.below(addr_space);
        assert_eq!(fast.is_resident(probe), slow.is_resident(probe), "residency diverged at access #{i}");
    }
    assert_eq!(fast.stats(), slow.stats, "final counters diverged for {params:?}");
}

#[test]
fn direct_mapped_matches_reference() {
    // Collisions every `sets·line_words` words; tiny cache, hot conflicts.
    compare_on_random_stream(CacheParams::new(1, 4, 1), 64, 4000, 1);
    compare_on_random_stream(CacheParams::new(1, 8, 2), 128, 4000, 2);
}

#[test]
fn set_associative_matches_reference() {
    compare_on_random_stream(CacheParams::new(2, 8, 2), 256, 6000, 3);
    compare_on_random_stream(CacheParams::new(4, 4, 4), 512, 6000, 4);
}

#[test]
fn fully_associative_matches_reference() {
    compare_on_random_stream(CacheParams::fully_associative(32, 2), 256, 6000, 5);
    compare_on_random_stream(CacheParams::fully_associative(16, 1), 64, 6000, 6);
}

#[test]
fn stencil_like_streams_match_reference() {
    // Strided sweeps (the workload the simulator actually sees) rather
    // than uniform random: three interleaved arrays with stencil offsets.
    let params = CacheParams::new(2, 16, 2);
    let mut fast = CacheSim::new(params);
    let mut slow = RefCache::new(params);
    let n1 = 23u64;
    for x2 in 1..40u64 {
        for x1 in 1..n1 - 1 {
            let base = x1 + n1 * x2;
            for delta in [0i64, 1, -1, n1 as i64, -(n1 as i64)] {
                let addr = (base as i64 + delta) as u64;
                assert_eq!(fast.access(addr), slow.access(addr));
            }
            let q = 4096 + base;
            assert_eq!(fast.access(q), slow.access(q));
        }
    }
    assert_eq!(fast.stats(), slow.stats);
}

#[test]
fn direct_mapped_vs_fully_associative_conflicts() {
    // Same capacity (8 words, w=1); addresses 0 and 8 conflict only in the
    // direct-mapped geometry. The satellite's §2 edge case: a re-request
    // after eviction is a *replacement* load, never a cold one.
    let mut dm = CacheSim::new(CacheParams::direct_mapped(8, 1));
    let mut fa = CacheSim::new(CacheParams::fully_associative(8, 1));
    for c in [&mut dm, &mut fa] {
        assert_eq!(c.access(0), AccessKind::ColdMiss);
        assert_eq!(c.access(8), AccessKind::ColdMiss);
    }
    // direct-mapped: 8 evicted 0; re-request of 0 is a replacement load
    assert!(!dm.is_resident(0));
    assert_eq!(dm.access(0), AccessKind::ReplacementMiss);
    assert_eq!(dm.stats().replacement_loads, 1);
    assert_eq!(dm.stats().cold_loads, 2);
    // fully associative: both fit; the same re-request is a pure hit
    assert!(fa.is_resident(0) && fa.is_resident(8));
    assert_eq!(fa.access(0), AccessKind::Hit);
    assert_eq!(fa.stats().replacement_loads, 0);
}

#[test]
fn residency_tracks_lru_rotation() {
    // 4-way single set: rotating the MRU must not disturb residency
    // bookkeeping; the 5th distinct line evicts the true LRU.
    let mut c = CacheSim::new(CacheParams::new(4, 1, 1));
    for a in 0..4u64 {
        c.access(a);
    }
    assert_eq!(c.access(0), AccessKind::Hit); // 0 becomes MRU; LRU is now 1
    c.access(4); // evicts 1
    assert!(!c.is_resident(1), "true LRU must be evicted after rotation");
    for a in [0u64, 2, 3, 4] {
        assert!(c.is_resident(a), "addr {a} must remain resident");
    }
    assert_eq!(c.access(1), AccessKind::ReplacementMiss);
}

/// Hierarchy reference property 1: a stream pushed through a full
/// hierarchy must leave the **L1** in exactly the state a standalone
/// [`CacheSim`] reaches on the same stream — level composition must not
/// perturb the paper's single-level numbers, per access and per counter.
#[test]
fn hierarchy_l1_equals_standalone_cache_sim() {
    let l1 = CacheParams::new(2, 8, 2);
    let mut hier = Hierarchy::new(l1, CacheParams::new(2, 64, 4), TlbParams { entries: 4, page_words: 32 });
    let mut solo = CacheSim::new(l1);
    let mut rng = Rng::new(42);
    for i in 0..20_000 {
        let addr = rng.below(4096);
        let a = hier.access(addr);
        let b = solo.access(addr);
        assert_eq!(a, b, "access #{i} (addr {addr}) diverged: hierarchy {a:?} vs standalone {b:?}");
    }
    assert_eq!(hier.l1_stats(), solo.stats(), "final L1 counters diverged");
    assert_eq!(hier.stats().l1_misses, solo.stats().misses());
}

/// Hierarchy reference property 2: the TLB miss count must equal a
/// brute-force fully-associative LRU simulated directly over the
/// *page-number* stream (recency `Vec`, no cache machinery).
#[test]
fn hierarchy_tlb_equals_bruteforce_page_lru() {
    let tlb = TlbParams { entries: 8, page_words: 64 };
    let mut hier = Hierarchy::new(CacheParams::new(2, 8, 2), CacheParams::new(2, 64, 4), tlb);
    let mut lru: Vec<u64> = Vec::new(); // most-recent first
    let mut brute_misses = 0u64;
    let mut rng = Rng::new(7);
    for _ in 0..30_000 {
        let addr = rng.below(1 << 14);
        hier.access(addr);
        let page = addr / tlb.page_words as u64;
        if let Some(pos) = lru.iter().position(|&p| p == page) {
            lru.remove(pos);
        } else {
            brute_misses += 1;
            if lru.len() == tlb.entries {
                lru.pop();
            }
        }
        lru.insert(0, page);
    }
    assert_eq!(hier.stats().tlb_misses, brute_misses);
    assert_eq!(hier.tlb_stats().misses(), brute_misses);
    // every word access probes the TLB exactly once
    assert_eq!(hier.tlb_stats().accesses, hier.stats().accesses);
}

#[test]
fn line_granular_loads_cold_after_eviction_of_neighbor_word() {
    // w=2: words 0 and 1 share a line. Touch word 0, evict the line, then
    // request word 1 for the first time — §2 classifies that as a *cold*
    // load (first explicit request) even though the line itself re-fetches
    // as a replacement miss.
    let mut c = CacheSim::new(CacheParams::new(1, 2, 2)); // 4-word DM cache
    assert_eq!(c.access(0), AccessKind::ColdMiss); // line 0 in set 0
    assert_eq!(c.access(4), AccessKind::ColdMiss); // line 2, set 0 — evicts line 0
    assert!(!c.is_resident(0));
    assert_eq!(c.access(1), AccessKind::ReplacementMiss); // line 0 re-fetched
    let s = c.stats();
    assert_eq!(s.cold_loads, 3, "word 1 was never requested before: cold load");
    assert_eq!(s.replacement_loads, 0, "no previously-requested word expired");
    // now word 0 again: line is resident (hit), but its residence HAD
    // expired — §2 loads count only explicit requests, so this is a plain
    // hit with no load at all.
    assert_eq!(c.access(0), AccessKind::Hit);
    assert_eq!(c.stats().loads(), 3);
}
