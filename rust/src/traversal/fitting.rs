//! The **cache fitting algorithm** (paper §4) — the paper's central
//! contribution.
//!
//! Given the interference lattice `L` of the array, take a *reduced* basis
//! `b_1 … b_d` (LLL), let `v = b_iv` be the longest basis vector, and let
//! `F` be the face of the fundamental parallelepiped spanned by the other
//! basis vectors. Space is partitioned into **pencils**
//! `Q = {f + t·v | f ∈ F + integer face offsets}`; the algorithm sweeps the
//! scanning face `F + k·(v/g)` through each pencil in the direction of `v`:
//!
//! ```text
//! do Q = Qmin, Qmax                       (pencils)
//!   do k = kmin, kmax                     (face shifts along v)
//!     load u on K(F + k·w);  compute q on F + k·w
//! ```
//!
//! Because all points of a face `F + k·w` differ by *non-lattice* vectors
//! shorter than the parallelepiped, they map to distinct cache locations:
//! the working set of a face sweep fits the cache by construction, and
//! replacement loads occur only within distance `r` of pencil boundaries
//! (≤ `r(2r+1)^d · A` in total, where `A` is the pencil surface area —
//! Eq 12 follows from the reduced basis's surface-to-volume ratio, Eq 11).
//!
//! **Two implementations.**
//!
//! [`cache_fitting_stream`] (the hot path) is the paper's loop nest made
//! literal: pencils are enumerated from the y-space (reduced-basis
//! coordinates) bounding box of the interior, and each pencil's points are
//! generated cell by cell along the sweep — rasterize the x-space bounding
//! box of one fundamental-parallelepiped cell (≈ S points), keep the
//! integer points whose basis-coordinate floors land in the cell ("whenever
//! a point is not contained in the grid, it is simply skipped", §4), order
//! them along `v`, emit. Memory is O(cell), never O(grid).
//!
//! [`cache_fitting`] (the materialized adapter, kept for property tests and
//! small replayed experiment orders) computes for every interior point its
//! real coordinates `y = B⁻¹x` and sorts points by
//! `(⌊y_j⌋ for j ≠ iv ; y_iv)`. Points sharing all `⌊y_j⌋, j≠iv` form
//! exactly one fundamental-parallelepiped *pencil*; ordering by `y_iv`
//! within a pencil is the face sweep with step `1/g`. Both implementations
//! visit the same point multiset (property-tested) with the same
//! pencil-contiguity guarantee.

use super::{interior_ranges, points_of, Order, Traversal, MAX_STREAM_DIMS};
use crate::grid::GridDesc;
use crate::lattice::InterferenceLattice;
use std::ops::Range;

/// Pencil-coordinate bias: supports floor values in ±2^19.
const BIAS: i64 = 1 << 19;

/// Float slack when rasterizing cell bounding boxes: large enough to absorb
/// f64 rounding of basis-coordinate products, far below integer spacing.
const EPS: f64 = 1e-6;

/// Tuning knobs for the fitting sweep (see the ablation bench
/// `bench_traversal` and EXPERIMENTS.md §Perf for the measured effect of
/// each).
#[derive(Debug, Clone)]
pub struct FittingOptions {
    /// Which reduced-basis vector to sweep along; None → longest (§5's
    /// prescription).
    pub sweep_index: Option<usize>,
    /// Pencil width in *cells* along each non-sweep basis direction. The
    /// paper: "pencils as wide as possible"; widths beyond the cache
    /// associativity reintroduce conflicts, so `widths_from_assoc` caps at
    /// `a` cells total.
    pub widths: Vec<usize>,
    /// Serpentine (boustrophedon) pencil visiting: alternate the sweep and
    /// inner-pencil directions so adjacent pencils share their freshest
    /// boundary halo instead of their coldest.
    pub serpentine: bool,
}

impl Default for FittingOptions {
    fn default() -> Self {
        FittingOptions { sweep_index: None, widths: Vec::new(), serpentine: true }
    }
}

impl FittingOptions {
    /// Widen pencils up to the cache associativity: `a` lattice-equivalent
    /// copies fit the `a` ways, so a pencil may span `a` cells across one
    /// face direction without self-eviction.
    pub fn widths_from_assoc(mut self, d: usize, assoc: usize) -> Self {
        let mut widths = vec![1usize; d];
        if d >= 2 && assoc >= 2 {
            // widen along the first face direction only: total copies = a.
            widths[0] = assoc;
        }
        self.widths = widths;
        self
    }
}

pub fn cache_fitting(grid: &GridDesc, r: usize, lattice: &InterferenceLattice) -> Order {
    cache_fitting_opts(grid, r, lattice, &FittingOptions::default())
}

/// Like [`cache_fitting`] with an explicit sweep-vector index into the
/// reduced basis (exposed for the sweep-choice ablation bench).
pub fn cache_fitting_sweep(grid: &GridDesc, r: usize, lattice: &InterferenceLattice, iv: usize) -> Order {
    cache_fitting_opts(
        grid,
        r,
        lattice,
        &FittingOptions { sweep_index: Some(iv), ..FittingOptions::default() },
    )
}

/// Full-control variant.
pub fn cache_fitting_opts(grid: &GridDesc, r: usize, lattice: &InterferenceLattice, opts: &FittingOptions) -> Order {
    let d = grid.ndim();
    assert_eq!(lattice.dims().len(), d, "lattice dimensionality mismatch");
    let Some(ranges) = grid.interior(r) else {
        return Order::from_packed(d, Vec::new());
    };
    if d == 1 {
        // One-dimensional grids have a single pencil; the sweep is natural.
        return super::natural(grid, r);
    }
    let iv = opts.sweep_index.unwrap_or_else(|| lattice.longest_basis_index());
    assert!(iv < d);

    // Inverse of the reduced-basis matrix (rows = basis vectors): y = x·Binv
    // gives basis coordinates. Computed once per grid.
    let basis = lattice.reduced_basis();
    let binv = invert(basis);
    // width per face direction (cells), indexed by face slot order.
    let mut widths = [1usize; 8];
    {
        let mut slot = 0;
        for j in 0..d {
            if j == iv {
                continue;
            }
            widths[slot] = *opts.widths.get(slot).unwrap_or(&1);
            assert!(widths[slot] >= 1);
            slot += 1;
        }
    }

    // Enumerate interior points (natural order), computing sort keys.
    let n: usize = ranges.iter().map(|rg| (rg.end - rg.start) as usize).product();
    let mut keyed: Vec<(u64, f32, u64)> = Vec::with_capacity(n);
    let mut x: Vec<i64> = ranges.iter().map(|rg| rg.start).collect();
    let mut y = vec![0.0f64; d];
    loop {
        // y = B^{-1} x (x as real vector)
        for (i, yi) in y.iter_mut().enumerate() {
            let mut acc = 0.0;
            for (j, &xj) in x.iter().enumerate() {
                acc += binv[i][j] * xj as f64;
            }
            *yi = acc;
        }
        // pencil coordinates (outermost sort key first), with serpentine
        // parity folding: each level's coordinate is mirrored when the sum
        // of outer-level coordinates is odd, so consecutive pencils in the
        // visit order are spatial neighbors sharing a *fresh* wall.
        let mut pencil_key = 0u64;
        let mut slot = 0usize;
        let mut parity: i64 = 0;
        let mut shift: u32 = 40; // outermost face coord in the top bits
        for (j, &yj) in y.iter().enumerate() {
            if j == iv {
                continue;
            }
            let mut fl = (yj / widths[slot] as f64).floor() as i64;
            if opts.serpentine && parity & 1 == 1 {
                fl = -fl;
            }
            parity += fl.abs();
            let biased = fl + BIAS;
            debug_assert!((0..(1 << 20)).contains(&biased), "pencil coordinate overflow");
            pencil_key |= (biased as u64) << shift;
            shift = shift.saturating_sub(20);
            slot += 1;
        }
        let mut sweep = y[iv] as f32;
        if opts.serpentine && parity & 1 == 1 {
            sweep = -sweep;
        }
        keyed.push((pencil_key, sweep, Order::pack(&x)));

        // odometer
        let mut i = 0;
        loop {
            x[i] += 1;
            if x[i] < ranges[i].end {
                break;
            }
            x[i] = ranges[i].start;
            i += 1;
            if i == d {
                // sort by (pencil, sweep coordinate, point) — total order.
                keyed.sort_unstable_by(|a, b| {
                    a.0.cmp(&b.0).then(a.1.partial_cmp(&b.1).unwrap()).then(a.2.cmp(&b.2))
                });
                let points = keyed.iter().map(|k| k.2).collect();
                return Order::from_packed(d, points);
            }
        }
    }
}

/// Cache-fitting order against a concrete cache: builds the interference
/// lattice of the grid's storage dims with modulus `S`.
pub fn cache_fitting_for_cache(grid: &GridDesc, r: usize, cache: &crate::cache::CacheParams) -> Order {
    let lattice = InterferenceLattice::new(grid.storage_dims(), cache.lattice_modulus());
    cache_fitting(grid, r, &lattice)
}

/// One §4 pencil in the streaming traversal: the raw floor coordinates of
/// the non-sweep basis directions, plus whether the serpentine fold
/// reverses its sweep direction.
#[derive(Debug, Clone)]
struct Pencil {
    q: Vec<i64>,
    flip: bool,
}

/// The **streaming** cache-fitting traversal: the §4 pencil sweep generated
/// lazily, one fundamental-parallelepiped cell at a time, with O(cell)
/// memory instead of the O(grid) sort of [`cache_fitting`].
///
/// Pencils double as shard units: each pencil's point set depends only on
/// its own floor coordinates, so disjoint pencil ranges partition the
/// interior exactly — which is what lets the coordinator fan one Analyze
/// job out across worker threads.
#[derive(Debug, Clone)]
pub struct FittingTraversal {
    ranges: Vec<Range<i64>>,
    iv: usize,
    /// Reduced basis rows (owned copy — the traversal outlives the lattice).
    basis: Vec<Vec<i64>>,
    /// Inverse of Bᵀ: `y = binv · x` are the reduced-basis coordinates.
    binv: Vec<Vec<f64>>,
    /// Pencil width (cells) per face slot, in ascending non-sweep dim order.
    widths: Vec<usize>,
    /// Pencils in visit order (serpentine-folded lexicographic); each
    /// carries its own precomputed sweep-direction flip.
    pencils: Vec<Pencil>,
    /// Global floor range of the sweep coordinate, inclusive.
    k_lo: i64,
    k_hi: i64,
}

/// Build the streaming cache-fitting traversal with default options.
pub fn cache_fitting_stream(grid: &GridDesc, r: usize, lattice: &InterferenceLattice) -> FittingTraversal {
    cache_fitting_stream_opts(grid, r, lattice, &FittingOptions::default())
}

/// Streaming cache-fitting against a concrete cache (lattice built from the
/// grid's storage dims with modulus `S`).
pub fn cache_fitting_stream_for_cache(grid: &GridDesc, r: usize, cache: &crate::cache::CacheParams) -> FittingTraversal {
    let lattice = InterferenceLattice::new(grid.storage_dims(), cache.lattice_modulus());
    cache_fitting_stream(grid, r, &lattice)
}

/// Full-control streaming variant.
pub fn cache_fitting_stream_opts(
    grid: &GridDesc,
    r: usize,
    lattice: &InterferenceLattice,
    opts: &FittingOptions,
) -> FittingTraversal {
    let d = grid.ndim();
    assert_eq!(lattice.dims().len(), d, "lattice dimensionality mismatch");
    let ranges = interior_ranges(grid, r);
    let empty = points_of(&ranges) == 0;
    if d == 1 || empty {
        // 1-D: a single pencil, swept naturally. No interior: no pencils.
        let pencils = if empty { Vec::new() } else { vec![Pencil { q: Vec::new(), flip: false }] };
        return FittingTraversal {
            ranges,
            iv: 0,
            basis: Vec::new(),
            binv: Vec::new(),
            widths: Vec::new(),
            pencils,
            k_lo: 0,
            k_hi: 0,
        };
    }
    let iv = opts.sweep_index.unwrap_or_else(|| lattice.longest_basis_index());
    assert!(iv < d);
    let basis: Vec<Vec<i64>> = lattice.reduced_basis().to_vec();
    let binv = invert(&basis);
    let widths: Vec<usize> = (0..d - 1)
        .map(|slot| {
            let w = *opts.widths.get(slot).unwrap_or(&1);
            assert!(w >= 1);
            w
        })
        .collect();

    // y-space bounding box of the interior: y is linear in x, so extremes
    // occur at box corners; accumulate per-coordinate min/max directly.
    let mut ymin = vec![0.0f64; d];
    let mut ymax = vec![0.0f64; d];
    for j in 0..d {
        let (mut mn, mut mx) = (0.0f64, 0.0f64);
        for (k, rg) in ranges.iter().enumerate() {
            let c = binv[j][k];
            let a = c * rg.start as f64;
            let b = c * (rg.end - 1) as f64;
            mn += a.min(b);
            mx += a.max(b);
        }
        ymin[j] = mn;
        ymax[j] = mx;
    }
    let k_lo = (ymin[iv] - EPS).floor() as i64;
    let k_hi = (ymax[iv] + EPS).floor() as i64;

    // Enumerate the (d−1)-dim box of candidate pencils and sort by the
    // serpentine-folded key — the same total order the materialized path
    // encodes into its packed pencil_key.
    let mut q_lo = Vec::with_capacity(d - 1);
    let mut q_hi = Vec::with_capacity(d - 1);
    {
        let mut slot = 0usize;
        for j in 0..d {
            if j == iv {
                continue;
            }
            let w = widths[slot] as f64;
            q_lo.push(((ymin[j] - EPS) / w).floor() as i64);
            q_hi.push(((ymax[j] + EPS) / w).floor() as i64);
            slot += 1;
        }
    }
    let nslots = d - 1;
    let mut keyed: Vec<(Vec<i64>, Pencil)> = Vec::new();
    let mut q = q_lo.clone();
    'boxes: loop {
        let mut folded = vec![0i64; nslots];
        let mut parity: i64 = 0;
        for s in 0..nslots {
            let mut fl = q[s];
            if opts.serpentine && parity & 1 == 1 {
                fl = -fl;
            }
            parity += q[s].abs();
            folded[s] = fl;
        }
        let flip = opts.serpentine && parity & 1 == 1;
        keyed.push((folded, Pencil { q: q.clone(), flip }));
        // odometer, innermost slot last so slot 0 stays the outer key
        let mut s = nslots;
        loop {
            if s == 0 {
                break 'boxes;
            }
            s -= 1;
            q[s] += 1;
            if q[s] <= q_hi[s] {
                break;
            }
            q[s] = q_lo[s];
            if s == 0 {
                break 'boxes;
            }
        }
    }
    keyed.sort_by(|a, b| a.0.cmp(&b.0));
    let pencils = keyed.into_iter().map(|(_, p)| p).collect();

    FittingTraversal { ranges, iv, basis, binv, widths, pencils, k_lo, k_hi }
}

impl FittingTraversal {
    /// Stream the points of one fundamental-parallelepiped cell
    /// `(pencil q, sweep floor k)`: rasterize the cell's x-space bounding
    /// box, keep the integer points whose basis-coordinate floors land in
    /// the cell, order them along the sweep, emit.
    fn emit_cell(
        &self,
        q: &[i64],
        k: i64,
        flip: bool,
        buf: &mut Vec<(f64, [i64; MAX_STREAM_DIMS])>,
        f: &mut dyn FnMut(&[i64]),
    ) {
        let d = self.ranges.len();
        let mut xlo = [0i64; MAX_STREAM_DIMS];
        let mut xhi = [0i64; MAX_STREAM_DIMS];
        for r in 0..d {
            let (mut mn, mut mx) = (0.0f64, 0.0f64);
            let mut slot = 0usize;
            for c in 0..d {
                let bc = self.basis[c][r] as f64;
                let (ylo, yhi) = if c == self.iv {
                    (k as f64, (k + 1) as f64)
                } else {
                    let w = self.widths[slot] as f64;
                    let lo = q[slot] as f64 * w;
                    slot += 1;
                    (lo, lo + w)
                };
                let a = bc * ylo;
                let b = bc * yhi;
                mn += a.min(b);
                mx += a.max(b);
            }
            let lo = ((mn - EPS).ceil() as i64).max(self.ranges[r].start);
            let hi = ((mx + EPS).floor() as i64).min(self.ranges[r].end - 1);
            if lo > hi {
                return; // cell misses the interior entirely
            }
            xlo[r] = lo;
            xhi[r] = hi;
        }

        buf.clear();
        let mut x = xlo;
        'points: loop {
            // y = B^{-1} x, same summation order as the materialized path so
            // floor classification agrees bit for bit.
            let mut accept = true;
            let mut sweep = 0.0f64;
            let mut slot = 0usize;
            for i in 0..d {
                let mut acc = 0.0f64;
                for j in 0..d {
                    acc += self.binv[i][j] * x[j] as f64;
                }
                if i == self.iv {
                    if acc.floor() as i64 != k {
                        accept = false;
                        break;
                    }
                    sweep = acc;
                } else {
                    if (acc / self.widths[slot] as f64).floor() as i64 != q[slot] {
                        accept = false;
                        break;
                    }
                    slot += 1;
                }
            }
            if accept {
                buf.push((sweep, x));
            }
            let mut i = 0;
            loop {
                x[i] += 1;
                if x[i] <= xhi[i] {
                    continue 'points;
                }
                x[i] = xlo[i];
                i += 1;
                if i == d {
                    break 'points;
                }
            }
        }
        if flip {
            buf.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap().then(a.1.cmp(&b.1)));
        } else {
            buf.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
        }
        for (_, pt) in buf.iter() {
            f(&pt[..d]);
        }
    }
}

impl Traversal for FittingTraversal {
    fn ndim(&self) -> usize {
        self.ranges.len()
    }

    fn num_points(&self) -> u64 {
        points_of(&self.ranges)
    }

    fn num_pencils(&self) -> usize {
        self.pencils.len()
    }

    fn stream_pencils(&self, pencils: Range<usize>, f: &mut dyn FnMut(&[i64])) {
        let np = self.pencils.len();
        let pencils = pencils.start.min(np)..pencils.end.min(np);
        if pencils.is_empty() {
            return;
        }
        let d = self.ranges.len();
        if d == 1 {
            let mut x = [0i64; 1];
            for v in self.ranges[0].clone() {
                x[0] = v;
                f(&x);
            }
            return;
        }
        let mut buf: Vec<(f64, [i64; MAX_STREAM_DIMS])> = Vec::new();
        for p in &self.pencils[pencils] {
            if p.flip {
                for k in (self.k_lo..=self.k_hi).rev() {
                    self.emit_cell(&p.q, k, true, &mut buf, f);
                }
            } else {
                for k in self.k_lo..=self.k_hi {
                    self.emit_cell(&p.q, k, false, &mut buf, f);
                }
            }
        }
    }
}

/// Invert a small integer matrix (rows = basis vectors) to f64.
/// Gauss–Jordan with partial pivoting; basis matrices are well-conditioned
/// after LLL at our dimensions.
fn invert(rows: &[Vec<i64>]) -> Vec<Vec<f64>> {
    let n = rows.len();
    // We need y with x = Σ y_i b_i, i.e. Bᵀ y = x, so we invert Bᵀ:
    // a[r][c] = basis[c][r].
    let mut a: Vec<Vec<f64>> = (0..n).map(|r| (0..n).map(|c| rows[c][r] as f64).collect()).collect();
    let mut inv: Vec<Vec<f64>> = (0..n).map(|r| (0..n).map(|c| if r == c { 1.0 } else { 0.0 }).collect()).collect();
    for col in 0..n {
        let piv = (col..n)
            .max_by(|&i, &j| a[i][col].abs().partial_cmp(&a[j][col].abs()).unwrap())
            .unwrap();
        a.swap(col, piv);
        inv.swap(col, piv);
        let p = a[col][col];
        assert!(p.abs() > 1e-12, "singular basis matrix");
        for c in 0..n {
            a[col][c] /= p;
            inv[col][c] /= p;
        }
        for r in 0..n {
            if r != col {
                let f = a[r][col];
                for c in 0..n {
                    a[r][c] -= f * a[col][c];
                    inv[r][c] -= f * inv[col][c];
                }
            }
        }
    }
    inv
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::CacheParams;
    use crate::traversal::natural;

    #[test]
    fn invert_roundtrip() {
        let b = vec![vec![2, 1, 0], vec![0, 3, 1], vec![1, 0, 4]];
        let inv = invert(&b);
        // check Bᵀ · inv = I, i.e. for x = Bᵀ e_k, inv·x = e_k — equivalent:
        // y = inv · (Bᵀ y0) must return y0.
        let y0 = [1.0, -2.0, 0.5];
        let mut x = [0.0f64; 3];
        for r in 0..3 {
            for k in 0..3 {
                x[r] += b[k][r] as f64 * y0[k];
            }
        }
        for i in 0..3 {
            let yi: f64 = (0..3).map(|j| inv[i][j] * x[j]).sum();
            assert!((yi - y0[i]).abs() < 1e-9, "component {i}");
        }
    }

    #[test]
    fn fitting_is_permutation_of_natural() {
        let g = GridDesc::new(&[20, 17, 12]);
        let lat = InterferenceLattice::new(g.storage_dims(), 256);
        let fit = cache_fitting(&g, 2, &lat);
        let nat = natural(&g, 2);
        assert_eq!(fit.len(), nat.len());
        assert_eq!(fit.canonical_set(), nat.canonical_set());
    }

    #[test]
    fn fitting_1d_equals_natural() {
        let g = GridDesc::new(&[50]);
        let lat = InterferenceLattice::new(g.storage_dims(), 16);
        let fit = cache_fitting(&g, 1, &lat);
        let nat = natural(&g, 1);
        assert_eq!(fit.packed(), nat.packed());
    }

    #[test]
    fn fitting_groups_pencils_contiguously() {
        // Within the produced order, each pencil's points must appear as one
        // contiguous run (no interleaving) — this is what makes the working
        // set cache-resident.
        let g = GridDesc::new(&[24, 24]);
        let lat = InterferenceLattice::new(g.storage_dims(), 64);
        let fit = cache_fitting(&g, 1, &lat);
        let basis = lat.reduced_basis();
        let binv = invert(basis);
        let iv = lat.longest_basis_index();
        let jf = 1 - iv; // the face dim in 2-D
        let mut seen = std::collections::HashSet::new();
        let mut current: Option<i64> = None;
        fit.for_each(|x| {
            let y: f64 = (0..2).map(|j| binv[jf][j] * x[j] as f64).sum();
            let pencil = y.floor() as i64;
            if current != Some(pencil) {
                assert!(seen.insert(pencil), "pencil {pencil} revisited — order interleaves pencils");
                current = Some(pencil);
            }
        });
        assert!(seen.len() > 1, "test should exercise multiple pencils");
    }

    #[test]
    fn fitting_for_cache_wrapper() {
        let g = GridDesc::new(&[40, 30, 10]);
        let fit = cache_fitting_for_cache(&g, 1, &CacheParams::new(2, 64, 2));
        assert_eq!(fit.len() as u64, g.interior_points(1));
    }

    #[test]
    fn property_fitting_permutation_random_grids() {
        use crate::util::proptest::{forall, DimsGen};
        forall(31, 15, &DimsGen { d: 3, lo: 6, hi: 20 }, |dims| {
            let g = GridDesc::new(dims);
            let lat = InterferenceLattice::new(g.storage_dims(), 128);
            let fit = cache_fitting(&g, 1, &lat);
            fit.canonical_set() == natural(&g, 1).canonical_set()
        });
    }

    #[test]
    fn fitting_beats_natural_on_conflicting_grid() {
        // A 2-D grid whose row length (60) nearly fills the 64-word cache:
        // natural order needs three rows resident (180 words) and thrashes,
        // while the lattice is favorable (shortest vector (4,1), L1 = 5 ≥
        // diameter 3), so cache fitting's diagonal pencils fit.
        use crate::cache::CacheSim;
        let cache = CacheParams::new(1, 64, 1); // direct-mapped, 64 words
        let g = GridDesc::new(&[60, 32]);
        let r = 1;
        let lat = InterferenceLattice::new(g.storage_dims(), cache.lattice_modulus());
        let star = crate::stencil::Stencil::star(2, 1);
        let deltas: Vec<i64> = star.offsets().iter().map(|o| g.delta_of(o)).collect();

        let run = |order: &Order| -> (u64, u64) {
            let mut sim = CacheSim::new(cache);
            let mut x = vec![0i64; 2];
            for &p in order.packed() {
                Order::unpack(p, &mut x);
                let base = g.offset_of(&x) as i64;
                for &dl in &deltas {
                    sim.access((base + dl) as u64);
                }
            }
            (sim.stats().misses(), sim.stats().replacement_misses)
        };
        let (nat_misses, nat_repl) = run(&natural(&g, r));
        let (fit_misses, fit_repl) = run(&cache_fitting(&g, r, &lat));
        // Cold misses are unavoidable for both; the algorithm's claim is
        // about *replacement* misses (ρ in the paper), which must drop
        // sharply on a favorable lattice.
        assert!(
            (fit_repl as f64) < 0.6 * nat_repl as f64,
            "fitting repl {fit_repl} vs natural repl {nat_repl}"
        );
        assert!(fit_misses < nat_misses, "total {fit_misses} vs {nat_misses}");
    }

    // ---- streaming implementation -------------------------------------

    fn stream_multiset(t: &FittingTraversal) -> Vec<u64> {
        let mut v = Vec::new();
        t.stream(&mut |x| v.push(Order::pack(x)));
        v.sort_unstable();
        v
    }

    #[test]
    fn stream_matches_materialized_multiset() {
        for dims in [vec![20usize, 17, 12], vec![24, 24], vec![45, 91], vec![13, 9, 21]] {
            let g = GridDesc::new(&dims);
            let lat = InterferenceLattice::new(g.storage_dims(), 128);
            let t = cache_fitting_stream(&g, 1, &lat);
            assert_eq!(t.num_points(), g.interior_points(1), "{dims:?}");
            assert_eq!(
                stream_multiset(&t),
                cache_fitting(&g, 1, &lat).canonical_set(),
                "{dims:?}"
            );
        }
    }

    #[test]
    fn stream_1d_and_empty_grids() {
        let g1 = GridDesc::new(&[50]);
        let lat1 = InterferenceLattice::new(g1.storage_dims(), 16);
        let t1 = cache_fitting_stream(&g1, 1, &lat1);
        assert_eq!(t1.num_pencils(), 1);
        let mut seq = Vec::new();
        t1.stream(&mut |x| seq.push(Order::pack(x)));
        assert_eq!(seq, natural(&g1, 1).packed());

        let g0 = GridDesc::new(&[3, 3]);
        let lat0 = InterferenceLattice::new(g0.storage_dims(), 16);
        let t0 = cache_fitting_stream(&g0, 2, &lat0);
        assert_eq!(t0.num_pencils(), 0);
        assert_eq!(t0.num_points(), 0);
    }

    #[test]
    fn stream_pencil_ranges_partition() {
        let g = GridDesc::new(&[22, 19]);
        let lat = InterferenceLattice::new(g.storage_dims(), 64);
        let t = cache_fitting_stream(&g, 1, &lat);
        let full = stream_multiset(&t);
        for shards in [2usize, 3, 7] {
            let mut all = Vec::new();
            for rg in crate::traversal::shard_ranges(t.num_pencils(), shards) {
                t.stream_pencils(rg, &mut |x| all.push(Order::pack(x)));
            }
            all.sort_unstable();
            assert_eq!(all, full, "shards={shards}");
        }
    }

    #[test]
    fn stream_keeps_pencils_contiguous() {
        // Same invariant as the materialized test, on the streamed order.
        let g = GridDesc::new(&[24, 24]);
        let lat = InterferenceLattice::new(g.storage_dims(), 64);
        let t = cache_fitting_stream(&g, 1, &lat);
        let binv = invert(lat.reduced_basis());
        let iv = lat.longest_basis_index();
        let jf = 1 - iv;
        let mut seen = std::collections::HashSet::new();
        let mut current: Option<i64> = None;
        t.stream(&mut |x| {
            let y: f64 = (0..2).map(|j| binv[jf][j] * x[j] as f64).sum();
            let pencil = y.floor() as i64;
            if current != Some(pencil) {
                assert!(seen.insert(pencil), "pencil {pencil} revisited in stream");
                current = Some(pencil);
            }
        });
        assert!(seen.len() > 1);
    }

    #[test]
    fn stream_for_cache_wrapper() {
        let g = GridDesc::new(&[40, 30, 10]);
        let t = cache_fitting_stream_for_cache(&g, 1, &CacheParams::new(2, 64, 2));
        assert_eq!(stream_multiset(&t).len() as u64, g.interior_points(1));
    }
}
