//! Lattice-aware conflict-free tiling — the practical 3-D specialization
//! of the cache-fitting idea (paper §3 example and the end-of-§4 remark
//! about sweeping a reduced-basis parallelepiped of the `x_d = 0`
//! interference lattice along the d-th coordinate; cf. the
//! self-interference-free blocks of Ghosh–Martonosi–Malik [4], against
//! which §4 compares).
//!
//! For a 3-D grid swept along z, the working window holds the `2r+1`
//! z-planes of a 2-D tile `T`. Two window points collide in the cache iff
//! their difference `(di1, di2, dz)`, `|dz| ≤ 2r`, lies in the 3-D
//! interference lattice (Eq 8). [`conflict_free_tile`] finds the largest
//! rectangular tile such that **no** such difference fits inside the
//! tile's halo-extended bounding box — the window is then conflict-free by
//! construction and replacement loads occur only on tile boundaries, like
//! the pencil walls of §4 but with a far better surface-to-volume ratio
//! when S is small relative to `(2r+1)^d`.

use crate::grid::GridDesc;

/// Maximum cache-location occupancy of the `(2r+1)`-plane working window
/// of a `(t1, t2)` tile (+halo r each side), against the interference
/// lattice of `dims` mod `modulus`. Occupancy k means k window cells share
/// one cache location — tolerable while k ≤ associativity.
pub fn window_occupancy(dims: &[usize], modulus: usize, r: usize, t1: usize, t2: usize) -> usize {
    let s = modulus as u64;
    let n1 = dims[0] as u64;
    let m3 = n1 * dims[1] as u64;
    let (w1, w2, w3) = (t1 + 2 * r, t2 + 2 * r, 2 * r + 1);
    let mut counts: std::collections::HashMap<u64, usize> = std::collections::HashMap::with_capacity(w1 * w2 * w3);
    let mut max = 0usize;
    for z in 0..w3 as u64 {
        for y in 0..w2 as u64 {
            let row = (n1 * y + m3 * z) % s;
            for x in 0..w1 as u64 {
                let loc = (row + x) % s;
                let c = counts.entry(loc).or_insert(0);
                *c += 1;
                max = max.max(*c);
            }
        }
    }
    max
}

/// Find the rectangular 2-D tile `(t1, t2)` maximizing area subject to the
/// window occupancy staying within the cache associativity (`assoc`).
/// `dims` are the grid's *storage* dims (d = 3), `modulus` = S.
///
/// This is the §4-remark construction made practical: conflict-free up to
/// associativity instead of strictly one-per-location, because the 2-way
/// R10000 absorbs one lattice-translate pair per set (cf. [4], whose
/// strictly-interference-free blocks are what the paper compares against).
pub fn conflict_free_tile(dims: &[usize], modulus: usize, r: usize) -> (usize, usize) {
    conflict_free_tile_assoc(dims, modulus, r, 2)
}

/// [`conflict_free_tile`] with an explicit occupancy budget.
pub fn conflict_free_tile_assoc(dims: &[usize], modulus: usize, r: usize, assoc: usize) -> (usize, usize) {
    assert_eq!(dims.len(), 3, "conflict-free tiling is the 3-D specialization");
    let max1 = dims[0].min(256);
    let max2 = dims[1].min(256);
    let mut best = (1usize, 1usize);
    let mut best_area = 0usize;
    // Occupancy is monotone in (t1, t2): for each t1, binary-search the
    // largest viable t2. Also cap the window at S words (capacity).
    let cap = modulus;
    let mut t1 = 1usize;
    while t1 <= max1 {
        let mut lo = 1usize;
        let mut hi = max2;
        let mut found = 0usize;
        while lo <= hi {
            let mid = (lo + hi) / 2;
            let window = (t1 + 2 * r) * (mid + 2 * r) * (2 * r + 1);
            if window <= cap && window_occupancy(dims, modulus, r, t1, mid) <= assoc {
                found = mid;
                lo = mid + 1;
            } else {
                hi = mid - 1;
            }
        }
        if found > 0 && t1 * found > best_area {
            best_area = t1 * found;
            best = (t1, found);
        }
        // geometric-ish stepping keeps the search cheap on large dims
        t1 += 1 + t1 / 8;
    }
    best
}

/// Build the tiled z-sweep order: rectangular (t1, t2) tiles from
/// [`conflict_free_tile_assoc`], each swept across the full z extent (the
/// `blocked` traversal with tile `(t1, t2, nz)`).
pub fn tiled_z_sweep(grid: &GridDesc, r: usize, modulus: usize) -> super::Order {
    tiled_z_sweep_assoc(grid, r, modulus, 2)
}

/// [`tiled_z_sweep`] with an explicit occupancy budget.
pub fn tiled_z_sweep_assoc(grid: &GridDesc, r: usize, modulus: usize, assoc: usize) -> super::Order {
    assert_eq!(grid.ndim(), 3);
    let (t1, t2) = conflict_free_tile_assoc(grid.storage_dims(), modulus, r, assoc);
    super::blocked(grid, r, &[t1, t2, grid.dims()[2]])
}

/// Streaming tiled z-sweep: same tile geometry as [`tiled_z_sweep_assoc`],
/// generated lazily one tile (pencil) at a time — the hot-path variant the
/// coordinator shards across workers.
pub fn tiled_z_sweep_stream(grid: &GridDesc, r: usize, modulus: usize, assoc: usize) -> super::BlockedTraversal {
    assert_eq!(grid.ndim(), 3);
    let (t1, t2) = conflict_free_tile_assoc(grid.storage_dims(), modulus, r, assoc);
    super::blocked_stream(grid, r, &[t1, t2, grid.dims()[2]])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::{CacheParams, CacheSim};
    use crate::engine;
    use crate::grid::MultiArrayLayout;
    use crate::stencil::Stencil;

    #[test]
    fn tile_occupancy_within_budget() {
        let dims = [44usize, 91, 100];
        let s = 4096usize;
        let r = 2usize;
        let (t1, t2) = conflict_free_tile(&dims, s, r);
        assert!(t1 >= 1 && t2 >= 1);
        assert!(window_occupancy(&dims, s, r, t1, t2) <= 2, "tile {t1}x{t2}");
    }

    #[test]
    fn occupancy_monotone_in_tile() {
        let dims = [44usize, 91, 100];
        let a = window_occupancy(&dims, 4096, 2, 4, 4);
        let b = window_occupancy(&dims, 4096, 2, 16, 16);
        let c = window_occupancy(&dims, 4096, 2, 40, 80);
        assert!(a <= b && b <= c, "{a} {b} {c}");
    }

    #[test]
    fn tile_area_is_substantial() {
        // For favorable grids the window (tile+halo × 5 planes) should use
        // a decent fraction of the 4096-word cache.
        let (t1, t2) = conflict_free_tile(&[44, 91, 100], 4096, 2);
        let window = (t1 + 4) * (t2 + 4) * 5;
        assert!(window > 4096 / 4, "tiny window {t1}x{t2} → {window}");
        assert!(window <= 4096, "occupancy-bounded window must respect capacity");
    }

    #[test]
    fn unfavorable_grid_yields_degenerate_tile() {
        // n1 = 45, n2 = 91: lattice vector (1,0,1) ⇒ planes z and z+1
        // collide at x-offset 1 ⇒ occupancy blows up immediately: any
        // window wider than a couple of words stacks > 2 copies.
        let (t1, t2) = conflict_free_tile(&[45, 91, 100], 4096, 2);
        assert!(t1 * t2 <= 64, "expected degenerate tile, got {t1}x{t2}");
    }

    #[test]
    fn tiled_sweep_is_permutation() {
        let g = GridDesc::new(&[20, 18, 12]);
        let order = tiled_z_sweep(&g, 1, 256);
        assert_eq!(order.canonical_set(), super::super::natural(&g, 1).canonical_set());
    }

    #[test]
    fn tiled_stream_matches_materialized() {
        use crate::traversal::{materialize, Traversal};
        let g = GridDesc::new(&[20, 18, 12]);
        let t = tiled_z_sweep_stream(&g, 1, 256, 2);
        assert_eq!(t.num_points(), g.interior_points(1));
        assert_eq!(materialize(&t).packed(), tiled_z_sweep(&g, 1, 256).packed());
    }

    #[test]
    fn tiled_sweep_beats_natural_on_fig4_grid() {
        // a=1 tile with a z block (the tuner's workhorse candidate) on the
        // favorable n1=44 grid: ≥2× fewer misses than natural.
        let cache = CacheParams::r10000();
        let g = GridDesc::new(&[44, 91, 40]);
        let stencil = Stencil::star13();
        let run = |order: &crate::traversal::Order| {
            let layout = MultiArrayLayout::paper_offsets(&g, 1, cache.size_words());
            let mut sim = CacheSim::new(cache);
            engine::simulate(order, &layout, &stencil, &mut sim).total.misses()
        };
        let nat = run(&crate::traversal::natural(&g, 2));
        let (t1, t2) = conflict_free_tile_assoc(g.storage_dims(), cache.lattice_modulus(), 2, 1);
        let tiled = run(&crate::traversal::blocked(&g, 2, &[t1.max(1), t2.max(1), 16]));
        assert!(
            (tiled as f64) < 0.5 * nat as f64,
            "tiled {tiled} vs natural {nat} — expected ≥2× reduction"
        );
    }
}
