//! Temporal (time-tiled) traversal: halo-deep pencil blocks advanced `k`
//! timesteps per visit.
//!
//! The §4 traversals bound the traffic of *one* sweep; a multi-step solve
//! pays that traffic once per timestep. Temporal blocking (Malas/Hager/
//! Wellein-style overlapped tiles; see DESIGN.md §2.6) amortizes it: each
//! tile loads a block deep enough to carry `k·r` halo layers, advances `k`
//! steps entirely in cache-resident scratch, and only then moves on —
//! main-memory words move once per *superstep* instead of once per step.
//!
//! A [`TemporalTraversal`] partitions the K-interior into rectangular
//! **owned** tiles (dim 0 is normally kept uncut so lines stay contiguous;
//! the planner chooses the outer extents from the [`crate::cache::MachineModel`]).
//! As a plain [`Traversal`] it streams each owned tile in natural order —
//! one pencil per tile, so the existing `shard_ranges` machinery shards the
//! time-tiled sweep exactly like any other order. The halo arithmetic
//! (valid-region shrinkage, scratch boxes) lives in
//! [`crate::engine::step_time_tiled`], which consumes the tile geometry via
//! [`TemporalTraversal::tile_ranges`].

use super::{extent, interior_ranges, points_of, Traversal, MAX_STREAM_DIMS};
use crate::grid::GridDesc;
use std::ops::Range;

/// Owned-tile decomposition of the K-interior plus the time-tile depth `k`.
#[derive(Debug, Clone)]
pub struct TemporalTraversal {
    ranges: Vec<Range<i64>>,
    tile: Vec<usize>,
    k: usize,
    r: usize,
}

/// Build a temporal traversal: `tile[i]` is the owned (halo-free) tile
/// extent along dim `i`, `k ≥ 1` the number of timesteps advanced per tile
/// visit (`k = 1` is the fused single-pass update — no halo redundancy).
pub fn temporal_stream(grid: &GridDesc, r: usize, tile: &[usize], k: usize) -> TemporalTraversal {
    assert_eq!(tile.len(), grid.ndim());
    assert!(tile.iter().all(|&t| t >= 1));
    assert!(k >= 1, "time-tile depth must be at least 1");
    TemporalTraversal { ranges: interior_ranges(grid, r), tile: tile.to_vec(), k, r }
}

impl TemporalTraversal {
    /// Timesteps advanced per tile visit.
    pub fn time_tile(&self) -> usize {
        self.k
    }

    /// Stencil radius the halo math was built for.
    pub fn radius(&self) -> usize {
        self.r
    }

    /// K-interior ranges the owned tiles partition.
    pub fn interior(&self) -> &[Range<i64>] {
        &self.ranges
    }

    fn tiles_along(&self, i: usize) -> usize {
        extent(&self.ranges[i]).div_ceil(self.tile[i])
    }

    /// Owned region of tile `t` (global coordinates, clipped to the
    /// interior). Tiles are indexed dim-0-fastest; together they partition
    /// the K-interior exactly.
    pub fn tile_ranges(&self, t: usize) -> Vec<Range<i64>> {
        let d = self.ranges.len();
        let mut out = Vec::with_capacity(d);
        let mut k = t;
        for i in 0..d {
            let tiles = self.tiles_along(i);
            let ti = k % tiles;
            k /= tiles;
            let lo = self.ranges[i].start + (ti * self.tile[i]) as i64;
            out.push(lo..(lo + self.tile[i] as i64).min(self.ranges[i].end));
        }
        out
    }
}

impl Traversal for TemporalTraversal {
    fn ndim(&self) -> usize {
        self.ranges.len()
    }

    fn num_points(&self) -> u64 {
        points_of(&self.ranges)
    }

    fn num_pencils(&self) -> usize {
        if self.num_points() == 0 {
            return 0;
        }
        (0..self.ranges.len()).map(|i| self.tiles_along(i)).product()
    }

    fn stream_pencils(&self, pencils: Range<usize>, f: &mut dyn FnMut(&[i64])) {
        let np = self.num_pencils();
        let pencils = pencils.start.min(np)..pencils.end.min(np);
        if pencils.is_empty() {
            return;
        }
        let d = self.ranges.len();
        let mut x = vec![0i64; d];
        for t in pencils {
            let tr = self.tile_ranges(t);
            let mut origin = [0i64; MAX_STREAM_DIMS];
            let mut hi = [0i64; MAX_STREAM_DIMS];
            for i in 0..d {
                origin[i] = tr[i].start;
                hi[i] = tr[i].end;
            }
            x.copy_from_slice(&origin[..d]);
            'points: loop {
                f(&x);
                let mut i = 0;
                loop {
                    x[i] += 1;
                    if x[i] < hi[i] {
                        continue 'points;
                    }
                    x[i] = origin[i];
                    i += 1;
                    if i == d {
                        break 'points;
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::{natural, Order};
    use super::*;

    #[test]
    fn tiles_partition_the_interior() {
        let g = GridDesc::new(&[14, 12, 10]);
        let tt = temporal_stream(&g, 2, &[10, 3, 4], 4);
        let mut seen = Vec::new();
        for t in 0..tt.num_pencils() {
            let tr = tt.tile_ranges(t);
            assert!(tr.iter().all(|rg| rg.start < rg.end), "tile {t} empty: {tr:?}");
            for z in tr[2].clone() {
                for y in tr[1].clone() {
                    for x in tr[0].clone() {
                        seen.push(Order::pack(&[x, y, z]));
                    }
                }
            }
        }
        seen.sort_unstable();
        let reference = natural(&g, 2).canonical_set();
        assert_eq!(seen, reference, "owned tiles must partition the K-interior");
    }

    #[test]
    fn stream_visits_the_interior_multiset() {
        for (dims, r, tile, k) in
            [(vec![11usize, 9, 8], 1usize, vec![9usize, 2, 3], 2usize), (vec![7, 6], 2, vec![16, 16], 3)]
        {
            let g = GridDesc::new(&dims);
            let tt = temporal_stream(&g, r, &tile, k);
            let mut set = Vec::new();
            tt.stream(&mut |x| set.push(Order::pack(x)));
            assert_eq!(set.len() as u64, tt.num_points());
            set.sort_unstable();
            assert_eq!(set, natural(&g, r).canonical_set(), "{dims:?}");
        }
    }

    #[test]
    fn single_tile_when_tile_exceeds_interior() {
        let g = GridDesc::new(&[9, 9, 9]);
        let tt = temporal_stream(&g, 2, &[64, 64, 64], 8);
        assert_eq!(tt.num_pencils(), 1);
        assert_eq!(tt.tile_ranges(0), vec![2..7, 2..7, 2..7]);
        assert_eq!(tt.time_tile(), 8);
        assert_eq!(tt.radius(), 2);
    }

    #[test]
    fn empty_interior_has_no_pencils() {
        let g = GridDesc::new(&[4, 4]);
        let tt = temporal_stream(&g, 2, &[1, 1], 2);
        assert_eq!(tt.num_pencils(), 0);
        assert_eq!(tt.num_points(), 0);
    }
}
