//! Traversal orders over the K-interior of a grid.
//!
//! The paper's entire subject is *which order* to visit grid points in:
//! the number of replacement loads depends only on the visit order (given
//! layout). This module provides:
//!
//! - [`natural_stream`] — lexicographic column-major order: what the
//!   compiled Fortran loop nest does (the paper's baseline, Figure 4 top
//!   line);
//! - [`blocked_stream`] — classical rectangular tiling (the
//!   tile-size-selection baseline of Coleman–McKinley [3] / the CME blocks
//!   of [4]);
//! - [`fitting::cache_fitting_stream`] — the paper's contribution (§4):
//!   sweep the faces of the fundamental parallelepiped of a *reduced
//!   basis* of the interference lattice along pencils (see [`fitting`]);
//! - [`strip_stream`] — the §3 example order that attains the lower bound
//!   when `n_1 = k·S` and associativity exceeds the stencil diameter;
//! - [`temporal::temporal_stream`] — owned-tile decomposition for the
//!   time-tiled solve path (k timesteps per halo-deep tile; see
//!   [`temporal`] and `engine::step_time_tiled`).
//!
//! ## Streaming vs materialized
//!
//! Every order is a [`Traversal`]: a *stream* of interior points generated
//! lazily, one **pencil** (independently replayable chunk — a line, strip,
//! tile, or lattice pencil) at a time. Nothing is allocated per point and
//! nothing proportional to the grid is ever materialized, which is what
//! lets the engine analyze grids (512³ and beyond) whose visit sequence
//! would not fit in memory, and lets the coordinator shard one traversal
//! into disjoint pencil ranges across worker threads ([`shard_ranges`]).
//!
//! The legacy [`Order`] — a packed `Vec<u64>` of the whole sequence — is
//! kept as the *materialized adapter*: [`materialize`] collects any
//! traversal into an `Order`, and `Order` itself implements [`Traversal`]
//! (a single pencil). Property tests compare streamed multisets against
//! materialized [`Order::canonical_set`]s; experiment drivers that replay
//! one small order many times also keep using `Order`.
//!
//! Every order visits exactly the same point set (property-tested), so
//! simulated miss counts are directly comparable.

pub mod fitting;
pub mod temporal;
pub mod tiled;

use crate::grid::GridDesc;
use std::ops::Range;

pub use fitting::{
    cache_fitting, cache_fitting_for_cache, cache_fitting_stream, cache_fitting_stream_for_cache,
    cache_fitting_sweep, FittingOptions, FittingTraversal,
};
pub use temporal::{temporal_stream, TemporalTraversal};
pub use tiled::{conflict_free_tile, tiled_z_sweep, tiled_z_sweep_stream};

/// Maximum dimensions representable by the packed [`Order`] encoding.
pub const MAX_DIMS: usize = 4;

/// Maximum dimensions supported by the streaming traversals (coordinate
/// buffers are fixed-size stack arrays).
pub const MAX_STREAM_DIMS: usize = 8;

/// A lazily generated visit order over the K-interior of a grid.
///
/// The unit of generation is the **pencil**: an independently replayable
/// contiguous chunk of the visit sequence (a dim-0 line for the natural
/// order, a strip, a tile, a §4 lattice pencil). Pencils are the shard
/// unit: [`shard_ranges`] partitions `0..num_pencils()` into disjoint
/// ranges and [`Traversal::stream_pencils`] replays any range without
/// touching the others, so workers can stream shards concurrently.
///
/// `Sync` is a supertrait because sharded execution hands `&self` to
/// multiple worker threads; implementations are plain data, so this costs
/// nothing.
pub trait Traversal: Sync {
    /// Grid dimensionality of the streamed coordinate vectors.
    fn ndim(&self) -> usize;

    /// Total number of interior points the full stream visits.
    fn num_points(&self) -> u64;

    /// Number of pencils (shard units). Zero when there is no interior.
    fn num_pencils(&self) -> usize;

    /// Stream the points of the pencils in `pencils` (clamped to
    /// `0..num_pencils()`), in visit order, calling `f` with each
    /// coordinate vector.
    fn stream_pencils(&self, pencils: Range<usize>, f: &mut dyn FnMut(&[i64]));

    /// Stream every interior point in visit order.
    fn stream(&self, f: &mut dyn FnMut(&[i64])) {
        self.stream_pencils(0..self.num_pencils(), f);
    }

    /// Stream the pencils in `pencils` as **rows**: maximal runs of
    /// consecutive dim-0 points. `f` receives the coordinate of the row's
    /// first point and the run length `n`; since the dim-0 stride is 1 by
    /// layout, the `n` points occupy adjacent storage words — exactly the
    /// shape `engine::kernel`'s vector row primitives consume.
    ///
    /// The default degrades every point to a 1-long row (bitwise
    /// identical to [`Traversal::stream_pencils`], just slower), so
    /// orders without dim-0-contiguous structure (lattice pencils,
    /// materialized replays) stay correct without an override. Natural /
    /// strip / blocked orders override with true multi-point rows.
    fn stream_rows(&self, pencils: Range<usize>, f: &mut dyn FnMut(&[i64], usize)) {
        self.stream_pencils(pencils, &mut |x| f(x, 1));
    }
}

/// Partition `0..num_pencils` into at most `shards` contiguous, disjoint,
/// gap-free ranges of near-equal size (the first `num_pencils % shards`
/// ranges are one longer). Returns fewer ranges when there are fewer
/// pencils than requested shards, and none when there are no pencils.
pub fn shard_ranges(num_pencils: usize, shards: usize) -> Vec<Range<usize>> {
    if num_pencils == 0 {
        return Vec::new();
    }
    let shards = shards.clamp(1, num_pencils);
    let base = num_pencils / shards;
    let rem = num_pencils % shards;
    let mut out = Vec::with_capacity(shards);
    let mut start = 0usize;
    for s in 0..shards {
        let len = base + usize::from(s < rem);
        out.push(start..start + len);
        start += len;
    }
    out
}

/// Collect any traversal into a materialized [`Order`] (for property tests
/// and small replayed experiment orders; the hot paths stream instead).
pub fn materialize(t: &dyn Traversal) -> Order {
    let mut points = Vec::with_capacity(t.num_points() as usize);
    t.stream(&mut |x| points.push(Order::pack(x)));
    Order::from_packed(t.ndim(), points)
}

/// A materialized traversal order over interior points.
/// Coordinates are packed little-endian, 16 bits per dimension.
#[derive(Debug, Clone)]
pub struct Order {
    ndim: usize,
    points: Vec<u64>,
}

impl Order {
    pub(crate) fn from_packed(ndim: usize, points: Vec<u64>) -> Order {
        assert!(ndim >= 1 && ndim <= MAX_DIMS);
        Order { ndim, points }
    }

    #[inline]
    pub fn pack(x: &[i64]) -> u64 {
        debug_assert!(x.len() <= MAX_DIMS);
        let mut p = 0u64;
        for (i, &xi) in x.iter().enumerate() {
            debug_assert!((0..65536).contains(&xi), "coordinate out of packed range: {xi}");
            p |= (xi as u64) << (16 * i);
        }
        p
    }

    #[inline]
    pub fn unpack(p: u64, out: &mut [i64]) {
        for (i, o) in out.iter_mut().enumerate() {
            *o = ((p >> (16 * i)) & 0xFFFF) as i64;
        }
    }

    pub fn ndim(&self) -> usize {
        self.ndim
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    pub fn packed(&self) -> &[u64] {
        &self.points
    }

    /// Visit every point in order with its coordinate vector.
    pub fn for_each(&self, mut f: impl FnMut(&[i64])) {
        let mut x = vec![0i64; self.ndim];
        for &p in &self.points {
            Self::unpack(p, &mut x);
            f(&x);
        }
    }

    /// The linear word offsets of the visited points (given grid strides).
    pub fn linear_offsets(&self, grid: &GridDesc) -> Vec<u64> {
        let mut x = vec![0i64; self.ndim];
        self.points
            .iter()
            .map(|&p| {
                Self::unpack(p, &mut x);
                grid.offset_of(&x)
            })
            .collect()
    }

    /// Sorted copy of the packed points — canonical form for set-equality
    /// checks between orders.
    pub fn canonical_set(&self) -> Vec<u64> {
        let mut v = self.points.clone();
        v.sort_unstable();
        v
    }
}

/// A materialized [`Order`] is itself a (single-pencil) traversal, so the
/// streaming engine accepts it everywhere a lazy order fits.
impl Traversal for Order {
    fn ndim(&self) -> usize {
        self.ndim
    }

    fn num_points(&self) -> u64 {
        self.points.len() as u64
    }

    fn num_pencils(&self) -> usize {
        usize::from(!self.points.is_empty())
    }

    fn stream_pencils(&self, pencils: Range<usize>, f: &mut dyn FnMut(&[i64])) {
        if pencils.start == 0 && pencils.end >= 1 {
            self.for_each(f);
        }
    }
}

/// Adapter wrapping an [`Order`] as a chunked [`Traversal`]: the packed
/// sequence is cut into fixed-size pencils so property tests can exercise
/// sharding against a ground-truth materialized order.
#[derive(Debug, Clone)]
pub struct MaterializedTraversal {
    order: Order,
    pencil_len: usize,
}

impl MaterializedTraversal {
    /// Wrap with the default pencil length (4096 points).
    pub fn new(order: Order) -> MaterializedTraversal {
        MaterializedTraversal::with_pencil_len(order, 4096)
    }

    pub fn with_pencil_len(order: Order, pencil_len: usize) -> MaterializedTraversal {
        assert!(pencil_len >= 1);
        MaterializedTraversal { order, pencil_len }
    }

    pub fn order(&self) -> &Order {
        &self.order
    }

    pub fn into_order(self) -> Order {
        self.order
    }
}

impl Traversal for MaterializedTraversal {
    fn ndim(&self) -> usize {
        self.order.ndim()
    }

    fn num_points(&self) -> u64 {
        self.order.len() as u64
    }

    fn num_pencils(&self) -> usize {
        self.order.len().div_ceil(self.pencil_len)
    }

    fn stream_pencils(&self, pencils: Range<usize>, f: &mut dyn FnMut(&[i64])) {
        let n = self.order.len();
        let lo = pencils.start.saturating_mul(self.pencil_len).min(n);
        let hi = pencils.end.saturating_mul(self.pencil_len).min(n);
        if lo >= hi {
            return;
        }
        let mut x = vec![0i64; self.order.ndim()];
        for &p in &self.order.packed()[lo..hi] {
            Order::unpack(p, &mut x);
            f(&x);
        }
    }
}

/// Interior ranges of `grid` for radius `r`, or per-dim empty ranges when
/// the grid has no interior (so extents multiply to zero).
fn interior_ranges(grid: &GridDesc, r: usize) -> Vec<Range<i64>> {
    assert!(grid.ndim() <= MAX_STREAM_DIMS, "streaming traversals support up to {MAX_STREAM_DIMS} dims");
    grid.interior(r).unwrap_or_else(|| vec![0..0; grid.ndim()])
}

fn extent(rg: &Range<i64>) -> usize {
    (rg.end - rg.start).max(0) as usize
}

fn points_of(ranges: &[Range<i64>]) -> u64 {
    ranges.iter().map(|rg| extent(rg) as u64).product()
}

/// Streaming natural (lexicographic, dim-0-fastest) order over the
/// K-interior — the compiled loop nest of the paper's baseline. One pencil
/// per dim-0 line.
#[derive(Debug, Clone)]
pub struct NaturalTraversal {
    ranges: Vec<Range<i64>>,
}

/// Build the streaming natural order.
pub fn natural_stream(grid: &GridDesc, r: usize) -> NaturalTraversal {
    NaturalTraversal { ranges: interior_ranges(grid, r) }
}

impl Traversal for NaturalTraversal {
    fn ndim(&self) -> usize {
        self.ranges.len()
    }

    fn num_points(&self) -> u64 {
        points_of(&self.ranges)
    }

    fn num_pencils(&self) -> usize {
        if self.num_points() == 0 {
            return 0;
        }
        self.ranges[1..].iter().map(extent).product::<usize>().max(1)
    }

    fn stream_pencils(&self, pencils: Range<usize>, f: &mut dyn FnMut(&[i64])) {
        let np = self.num_pencils();
        let pencils = pencils.start.min(np)..pencils.end.min(np);
        if pencils.is_empty() {
            return;
        }
        let d = self.ranges.len();
        let (lo0, hi0) = (self.ranges[0].start, self.ranges[0].end);
        let mut x = vec![0i64; d];
        // Decode the first pencil index into the line odometer (dims 1..d,
        // dim 1 fastest — matching the natural order's carry chain).
        let mut k = pencils.start;
        for i in 1..d {
            let len = extent(&self.ranges[i]);
            x[i] = self.ranges[i].start + (k % len) as i64;
            k /= len;
        }
        for _ in 0..pencils.len() {
            for v in lo0..hi0 {
                x[0] = v;
                f(&x);
            }
            // advance to the next line
            let mut i = 1;
            loop {
                if i == d {
                    return;
                }
                x[i] += 1;
                if x[i] < self.ranges[i].end {
                    break;
                }
                x[i] = self.ranges[i].start;
                i += 1;
            }
        }
    }

    fn stream_rows(&self, pencils: Range<usize>, f: &mut dyn FnMut(&[i64], usize)) {
        let np = self.num_pencils();
        let pencils = pencils.start.min(np)..pencils.end.min(np);
        if pencils.is_empty() {
            return;
        }
        let d = self.ranges.len();
        let (lo0, hi0) = (self.ranges[0].start, self.ranges[0].end);
        let n0 = (hi0 - lo0) as usize;
        let mut x = vec![0i64; d];
        x[0] = lo0;
        let mut k = pencils.start;
        for i in 1..d {
            let len = extent(&self.ranges[i]);
            x[i] = self.ranges[i].start + (k % len) as i64;
            k /= len;
        }
        for _ in 0..pencils.len() {
            f(&x, n0);
            let mut i = 1;
            loop {
                if i == d {
                    return;
                }
                x[i] += 1;
                if x[i] < self.ranges[i].end {
                    break;
                }
                x[i] = self.ranges[i].start;
                i += 1;
            }
        }
    }
}

/// Streaming §3 strip order: dim 0 cut into strips of `width`; within each
/// strip the remaining dims sweep naturally with dim 0 innermost. One
/// pencil per strip.
#[derive(Debug, Clone)]
pub struct StripTraversal {
    ranges: Vec<Range<i64>>,
    width: usize,
}

/// Build the streaming strip order.
pub fn strip_stream(grid: &GridDesc, r: usize, width: usize) -> StripTraversal {
    assert!(width >= 1);
    StripTraversal { ranges: interior_ranges(grid, r), width }
}

impl Traversal for StripTraversal {
    fn ndim(&self) -> usize {
        self.ranges.len()
    }

    fn num_points(&self) -> u64 {
        points_of(&self.ranges)
    }

    fn num_pencils(&self) -> usize {
        if self.num_points() == 0 {
            return 0;
        }
        extent(&self.ranges[0]).div_ceil(self.width)
    }

    fn stream_pencils(&self, pencils: Range<usize>, f: &mut dyn FnMut(&[i64])) {
        let np = self.num_pencils();
        let pencils = pencils.start.min(np)..pencils.end.min(np);
        let d = self.ranges.len();
        let (lo0, hi0) = if pencils.is_empty() {
            return;
        } else {
            (self.ranges[0].start, self.ranges[0].end)
        };
        let mut x = vec![0i64; d];
        for s in pencils {
            let s_lo = lo0 + (s * self.width) as i64;
            let s_hi = (s_lo + self.width as i64).min(hi0);
            if d == 1 {
                for v in s_lo..s_hi {
                    x[0] = v;
                    f(&x);
                }
                continue;
            }
            for (i, rg) in self.ranges.iter().enumerate().skip(1) {
                x[i] = rg.start;
            }
            'lines: loop {
                for v in s_lo..s_hi {
                    x[0] = v;
                    f(&x);
                }
                let mut i = 1;
                loop {
                    x[i] += 1;
                    if x[i] < self.ranges[i].end {
                        break;
                    }
                    x[i] = self.ranges[i].start;
                    i += 1;
                    if i == d {
                        break 'lines;
                    }
                }
            }
        }
    }

    fn stream_rows(&self, pencils: Range<usize>, f: &mut dyn FnMut(&[i64], usize)) {
        let np = self.num_pencils();
        let pencils = pencils.start.min(np)..pencils.end.min(np);
        let d = self.ranges.len();
        let (lo0, hi0) = if pencils.is_empty() {
            return;
        } else {
            (self.ranges[0].start, self.ranges[0].end)
        };
        let mut x = vec![0i64; d];
        for s in pencils {
            let s_lo = lo0 + (s * self.width) as i64;
            let s_hi = (s_lo + self.width as i64).min(hi0);
            let n = (s_hi - s_lo) as usize;
            x[0] = s_lo;
            if d == 1 {
                f(&x, n);
                continue;
            }
            for (i, rg) in self.ranges.iter().enumerate().skip(1) {
                x[i] = rg.start;
            }
            'lines: loop {
                f(&x, n);
                let mut i = 1;
                loop {
                    x[i] += 1;
                    if x[i] < self.ranges[i].end {
                        break;
                    }
                    x[i] = self.ranges[i].start;
                    i += 1;
                    if i == d {
                        break 'lines;
                    }
                }
            }
        }
    }
}

/// Streaming rectangular tiling: tiles ordered lexicographically (dim 0
/// fastest), natural order within each tile. One pencil per tile.
#[derive(Debug, Clone)]
pub struct BlockedTraversal {
    ranges: Vec<Range<i64>>,
    tile: Vec<usize>,
}

/// Build the streaming blocked order. `tile[i]` is the tile extent along
/// dim i.
pub fn blocked_stream(grid: &GridDesc, r: usize, tile: &[usize]) -> BlockedTraversal {
    assert_eq!(tile.len(), grid.ndim());
    assert!(tile.iter().all(|&t| t >= 1));
    BlockedTraversal { ranges: interior_ranges(grid, r), tile: tile.to_vec() }
}

impl BlockedTraversal {
    fn tiles_along(&self, i: usize) -> usize {
        extent(&self.ranges[i]).div_ceil(self.tile[i])
    }
}

impl Traversal for BlockedTraversal {
    fn ndim(&self) -> usize {
        self.ranges.len()
    }

    fn num_points(&self) -> u64 {
        points_of(&self.ranges)
    }

    fn num_pencils(&self) -> usize {
        if self.num_points() == 0 {
            return 0;
        }
        (0..self.ranges.len()).map(|i| self.tiles_along(i)).product()
    }

    fn stream_pencils(&self, pencils: Range<usize>, f: &mut dyn FnMut(&[i64])) {
        let np = self.num_pencils();
        let pencils = pencils.start.min(np)..pencils.end.min(np);
        if pencils.is_empty() {
            return;
        }
        let d = self.ranges.len();
        let mut x = vec![0i64; d];
        for t in pencils {
            // decode tile index (dim 0 fastest, matching the tile odometer
            // of the materialized blocked order)
            let mut k = t;
            let mut origin = [0i64; MAX_STREAM_DIMS];
            let mut hi = [0i64; MAX_STREAM_DIMS];
            for i in 0..d {
                let tiles = self.tiles_along(i);
                let ti = k % tiles;
                k /= tiles;
                origin[i] = self.ranges[i].start + (ti * self.tile[i]) as i64;
                hi[i] = (origin[i] + self.tile[i] as i64).min(self.ranges[i].end);
            }
            x.copy_from_slice(&origin[..d]);
            'points: loop {
                f(&x);
                let mut i = 0;
                loop {
                    x[i] += 1;
                    if x[i] < hi[i] {
                        continue 'points;
                    }
                    x[i] = origin[i];
                    i += 1;
                    if i == d {
                        break 'points;
                    }
                }
            }
        }
    }

    fn stream_rows(&self, pencils: Range<usize>, f: &mut dyn FnMut(&[i64], usize)) {
        let np = self.num_pencils();
        let pencils = pencils.start.min(np)..pencils.end.min(np);
        if pencils.is_empty() {
            return;
        }
        let d = self.ranges.len();
        let mut x = vec![0i64; d];
        for t in pencils {
            let mut k = t;
            let mut origin = [0i64; MAX_STREAM_DIMS];
            let mut hi = [0i64; MAX_STREAM_DIMS];
            for i in 0..d {
                let tiles = self.tiles_along(i);
                let ti = k % tiles;
                k /= tiles;
                origin[i] = self.ranges[i].start + (ti * self.tile[i]) as i64;
                hi[i] = (origin[i] + self.tile[i] as i64).min(self.ranges[i].end);
            }
            let n = (hi[0] - origin[0]) as usize;
            x.copy_from_slice(&origin[..d]);
            if d == 1 {
                f(&x, n);
                continue;
            }
            'rows: loop {
                f(&x, n);
                let mut i = 1;
                loop {
                    x[i] += 1;
                    if x[i] < hi[i] {
                        continue 'rows;
                    }
                    x[i] = origin[i];
                    i += 1;
                    if i == d {
                        break 'rows;
                    }
                }
            }
        }
    }
}

/// Natural (lexicographic, dim-0-fastest) materialized order — the
/// streaming [`natural_stream`] collected into an [`Order`].
pub fn natural(grid: &GridDesc, r: usize) -> Order {
    assert!(grid.ndim() <= MAX_DIMS, "packed orders support up to {MAX_DIMS} dims");
    materialize(&natural_stream(grid, r))
}

/// Classical rectangular tiling, materialized: visit tile-by-tile (tiles
/// ordered lexicographically), natural order within each tile. `tile[i]`
/// is the tile extent along dim i.
pub fn blocked(grid: &GridDesc, r: usize, tile: &[usize]) -> Order {
    assert!(grid.ndim() <= MAX_DIMS, "packed orders support up to {MAX_DIMS} dims");
    materialize(&blocked_stream(grid, r, tile))
}

/// The §3 lower-bound-attaining order, materialized: partition dim 0 into
/// strips of `width` points; for each strip, sweep the remaining dims
/// naturally with dim 0 innermost within the strip:
///
/// ```text
/// do strip                      (i in the paper, k·a strips)
///   do x_d … x_2                (j in the paper)
///     do x_1 in strip           (i1)
/// ```
pub fn strip(grid: &GridDesc, r: usize, width: usize) -> Order {
    assert!(grid.ndim() <= MAX_DIMS, "packed orders support up to {MAX_DIMS} dims");
    materialize(&strip_stream(grid, r, width))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grid_3d() -> GridDesc {
        GridDesc::new(&[8, 7, 6])
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let x = [3i64, 65535, 0, 7];
        let p = Order::pack(&x);
        let mut y = [0i64; 4];
        Order::unpack(p, &mut y);
        assert_eq!(x, y);
    }

    #[test]
    fn natural_matches_interior_count_and_order() {
        let g = grid_3d();
        let o = natural(&g, 1);
        assert_eq!(o.len() as u64, g.interior_points(1));
        // first point is (1,1,1); second is (2,1,1) — dim 0 fastest.
        let mut pts = Vec::new();
        o.for_each(|x| pts.push(x.to_vec()));
        assert_eq!(pts[0], vec![1, 1, 1]);
        assert_eq!(pts[1], vec![2, 1, 1]);
        assert_eq!(*pts.last().unwrap(), vec![6, 5, 4]);
    }

    #[test]
    fn natural_empty_when_no_interior() {
        let g = GridDesc::new(&[3, 3]);
        assert!(natural(&g, 2).is_empty());
        let s = natural_stream(&g, 2);
        assert_eq!(s.num_points(), 0);
        assert_eq!(s.num_pencils(), 0);
    }

    #[test]
    fn blocked_same_set_as_natural() {
        let g = grid_3d();
        let nat = natural(&g, 1);
        for tile in [[2usize, 2, 2], [3, 5, 1], [100, 1, 2]] {
            let b = blocked(&g, 1, &tile);
            assert_eq!(b.canonical_set(), nat.canonical_set(), "tile {tile:?}");
        }
    }

    #[test]
    fn blocked_visits_tile_first() {
        let g = GridDesc::new(&[6, 6]);
        let b = blocked(&g, 1, &[2, 2]);
        let mut pts = Vec::new();
        b.for_each(|x| pts.push((x[0], x[1])));
        // first tile covers (1..3)×(1..3)
        assert_eq!(&pts[..4], &[(1, 1), (2, 1), (1, 2), (2, 2)]);
    }

    #[test]
    fn strip_same_set_as_natural() {
        let g = grid_3d();
        let nat = natural(&g, 1);
        for w in [1usize, 2, 3, 100] {
            let s = strip(&g, 1, w);
            assert_eq!(s.canonical_set(), nat.canonical_set(), "width {w}");
        }
    }

    #[test]
    fn strip_order_shape() {
        let g = GridDesc::new(&[8, 4]);
        let s = strip(&g, 1, 3);
        let mut pts = Vec::new();
        s.for_each(|x| pts.push((x[0], x[1])));
        // interior x0 in 1..7, x1 in 1..3; first strip x0 in 1..4 sweeps all x1
        assert_eq!(&pts[..6], &[(1, 1), (2, 1), (3, 1), (1, 2), (2, 2), (3, 2)]);
        // second strip picks up x0 in 4..7
        assert_eq!(pts[6], (4, 1));
    }

    #[test]
    fn linear_offsets_match_strides() {
        let g = GridDesc::new(&[5, 5]);
        let o = natural(&g, 1);
        let offs = o.linear_offsets(&g);
        assert_eq!(offs[0], 6); // (1,1) → 1 + 5
        assert_eq!(offs[1], 7); // (2,1)
    }

    #[test]
    fn property_all_orders_are_permutations() {
        use crate::util::proptest::{forall, DimsGen};
        forall(21, 25, &DimsGen { d: 3, lo: 5, hi: 14 }, |dims| {
            let g = GridDesc::new(dims);
            let nat = natural(&g, 2).canonical_set();
            let b = blocked(&g, 2, &[3, 2, 4]).canonical_set();
            let s = strip(&g, 2, 4).canonical_set();
            // canonical sets must be identical AND free of duplicates
            let mut dedup = nat.clone();
            dedup.dedup();
            nat == b && nat == s && dedup.len() == nat.len()
        });
    }

    // ---- streaming-specific tests -------------------------------------

    /// Multiset of a pencil range, as sorted packed points.
    fn stream_set(t: &dyn Traversal, pencils: Range<usize>) -> Vec<u64> {
        let mut v = Vec::new();
        t.stream_pencils(pencils, &mut |x| v.push(Order::pack(x)));
        v.sort_unstable();
        v
    }

    #[test]
    fn shard_ranges_partition() {
        for (n, k) in [(0usize, 3usize), (1, 4), (7, 3), (12, 4), (100, 7), (5, 5), (3, 10)] {
            let ranges = shard_ranges(n, k);
            if n == 0 {
                assert!(ranges.is_empty());
                continue;
            }
            assert!(ranges.len() <= k.max(1));
            assert_eq!(ranges[0].start, 0);
            assert_eq!(ranges.last().unwrap().end, n);
            for w in ranges.windows(2) {
                assert_eq!(w[0].end, w[1].start, "gap/overlap between {w:?}");
                assert!(!w[0].is_empty());
            }
        }
    }

    #[test]
    fn natural_stream_matches_materialized_sequence() {
        let g = grid_3d();
        let s = natural_stream(&g, 1);
        let o = natural(&g, 1);
        assert_eq!(s.num_points(), o.len() as u64);
        let mut streamed = Vec::new();
        s.stream(&mut |x| streamed.push(Order::pack(x)));
        assert_eq!(streamed, o.packed());
    }

    #[test]
    fn strip_and_blocked_streams_match_materialized_sequences() {
        let g = grid_3d();
        let ss = strip_stream(&g, 1, 3);
        let mut streamed = Vec::new();
        ss.stream(&mut |x| streamed.push(Order::pack(x)));
        assert_eq!(streamed, strip(&g, 1, 3).packed());

        let bs = blocked_stream(&g, 1, &[3, 2, 4]);
        let mut streamed = Vec::new();
        bs.stream(&mut |x| streamed.push(Order::pack(x)));
        assert_eq!(streamed, blocked(&g, 1, &[3, 2, 4]).packed());
    }

    #[test]
    fn pencil_shards_partition_the_interior() {
        let g = GridDesc::new(&[9, 8, 7]);
        let nat_set = natural(&g, 1).canonical_set();
        let traversals: Vec<Box<dyn Traversal>> = vec![
            Box::new(natural_stream(&g, 1)),
            Box::new(strip_stream(&g, 1, 2)),
            Box::new(blocked_stream(&g, 1, &[3, 3, 3])),
            Box::new(MaterializedTraversal::with_pencil_len(natural(&g, 1), 17)),
        ];
        for t in &traversals {
            for shards in [1usize, 2, 3, 5, 64] {
                let mut all = Vec::new();
                for rg in shard_ranges(t.num_pencils(), shards) {
                    all.extend(stream_set(t.as_ref(), rg));
                }
                all.sort_unstable();
                assert_eq!(all, nat_set, "shards={shards}");
            }
        }
    }

    #[test]
    fn mid_range_pencils_stream_correct_lines() {
        // pencil decoding must be correct for ranges not starting at 0
        let g = GridDesc::new(&[6, 5, 4]);
        let t = natural_stream(&g, 1);
        let full = stream_set(&t, 0..t.num_pencils());
        let head = stream_set(&t, 0..2);
        let mid = stream_set(&t, 2..5);
        let tail = stream_set(&t, 5..t.num_pencils());
        let mut joined = [head, mid, tail].concat();
        joined.sort_unstable();
        assert_eq!(joined, full);
    }

    #[test]
    fn stream_rows_reconstructs_the_exact_point_sequence() {
        // rows (start coordinate + run length along dim 0) expanded back
        // to points must reproduce stream_pencils exactly — order included
        let g = GridDesc::new(&[9, 8, 7]);
        let traversals: Vec<Box<dyn Traversal>> = vec![
            Box::new(natural_stream(&g, 1)),
            Box::new(strip_stream(&g, 1, 3)),
            Box::new(blocked_stream(&g, 1, &[3, 2, 4])),
            Box::new(MaterializedTraversal::with_pencil_len(natural(&g, 1), 17)),
        ];
        for t in &traversals {
            for rg in [0..t.num_pencils(), 1..3, 2..t.num_pencils()] {
                let mut pts = Vec::new();
                t.stream_pencils(rg.clone(), &mut |x| pts.push(Order::pack(x)));
                let mut from_rows = Vec::new();
                t.stream_rows(rg, &mut |x, n| {
                    let mut y = x.to_vec();
                    for j in 0..n as i64 {
                        y[0] = x[0] + j;
                        from_rows.push(Order::pack(&y));
                    }
                });
                assert_eq!(from_rows, pts);
            }
        }
    }

    #[test]
    fn stream_rows_handles_one_dimensional_grids() {
        let g = GridDesc::new(&[16]);
        for t in [
            Box::new(natural_stream(&g, 2)) as Box<dyn Traversal>,
            Box::new(strip_stream(&g, 2, 5)),
            Box::new(blocked_stream(&g, 2, &[4])),
        ] {
            let mut pts = Vec::new();
            t.stream(&mut |x| pts.push(x[0]));
            let mut from_rows = Vec::new();
            t.stream_rows(0..t.num_pencils(), &mut |x, n| {
                for j in 0..n as i64 {
                    from_rows.push(x[0] + j);
                }
            });
            assert_eq!(from_rows, pts);
        }
    }

    #[test]
    fn order_is_a_single_pencil_traversal() {
        let g = GridDesc::new(&[6, 6]);
        let o = natural(&g, 1);
        assert_eq!(Traversal::num_points(&o), o.len() as u64);
        assert_eq!(o.num_pencils(), 1);
        assert_eq!(stream_set(&o, 0..1), o.canonical_set());
    }

    #[test]
    fn materialize_roundtrip() {
        let g = GridDesc::new(&[7, 6, 5]);
        let s = blocked_stream(&g, 1, &[2, 3, 4]);
        let o = materialize(&s);
        assert_eq!(o.canonical_set(), natural(&g, 1).canonical_set());
        let m = MaterializedTraversal::new(o.clone());
        assert_eq!(m.order().len(), o.len());
        assert_eq!(m.into_order().packed(), o.packed());
    }
}
