//! Traversal orders over the K-interior of a grid.
//!
//! The paper's entire subject is *which order* to visit grid points in:
//! the number of replacement loads depends only on the visit order (given
//! layout). This module provides:
//!
//! - [`natural`] — lexicographic column-major order: what the compiled
//!   Fortran loop nest does (the paper's baseline, Figure 4 top line);
//! - [`blocked`] — classical rectangular tiling (the tile-size-selection
//!   baseline of Coleman–McKinley [3] / the CME blocks of [4]);
//! - [`cache_fitting`] — the paper's contribution (§4): sweep the faces of
//!   the fundamental parallelepiped of a *reduced basis* of the
//!   interference lattice along pencils (see [`fitting`]);
//! - [`strip`] — the §3 example order that attains the lower bound when
//!   `n_1 = k·S` and associativity exceeds the stencil diameter.
//!
//! All constructors produce an [`Order`]: a materialized point sequence
//! over the interior, packed 16 bits per coordinate. Every order visits
//! exactly the same point set (property-tested), so simulated miss counts
//! are directly comparable.

pub mod fitting;
pub mod tiled;

use crate::grid::GridDesc;

pub use fitting::{cache_fitting, cache_fitting_for_cache, cache_fitting_sweep, FittingOptions};
pub use tiled::{conflict_free_tile, tiled_z_sweep};

/// Maximum dimensions representable by the packed encoding.
pub const MAX_DIMS: usize = 4;

/// A materialized traversal order over interior points.
/// Coordinates are packed little-endian, 16 bits per dimension.
#[derive(Debug, Clone)]
pub struct Order {
    ndim: usize,
    points: Vec<u64>,
}

impl Order {
    pub(crate) fn from_packed(ndim: usize, points: Vec<u64>) -> Order {
        assert!(ndim >= 1 && ndim <= MAX_DIMS);
        Order { ndim, points }
    }

    #[inline]
    pub fn pack(x: &[i64]) -> u64 {
        debug_assert!(x.len() <= MAX_DIMS);
        let mut p = 0u64;
        for (i, &xi) in x.iter().enumerate() {
            debug_assert!((0..65536).contains(&xi), "coordinate out of packed range: {xi}");
            p |= (xi as u64) << (16 * i);
        }
        p
    }

    #[inline]
    pub fn unpack(p: u64, out: &mut [i64]) {
        for (i, o) in out.iter_mut().enumerate() {
            *o = ((p >> (16 * i)) & 0xFFFF) as i64;
        }
    }

    pub fn ndim(&self) -> usize {
        self.ndim
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    pub fn packed(&self) -> &[u64] {
        &self.points
    }

    /// Visit every point in order with its coordinate vector.
    pub fn for_each(&self, mut f: impl FnMut(&[i64])) {
        let mut x = vec![0i64; self.ndim];
        for &p in &self.points {
            Self::unpack(p, &mut x);
            f(&x);
        }
    }

    /// The linear word offsets of the visited points (given grid strides).
    pub fn linear_offsets(&self, grid: &GridDesc) -> Vec<u64> {
        let mut x = vec![0i64; self.ndim];
        self.points
            .iter()
            .map(|&p| {
                Self::unpack(p, &mut x);
                grid.offset_of(&x)
            })
            .collect()
    }

    /// Sorted copy of the packed points — canonical form for set-equality
    /// checks between orders.
    pub fn canonical_set(&self) -> Vec<u64> {
        let mut v = self.points.clone();
        v.sort_unstable();
        v
    }
}

/// Enumerate the interior ranges, or an empty order if no interior exists.
fn interior_or_empty(grid: &GridDesc, r: usize) -> Option<Vec<std::ops::Range<i64>>> {
    assert!(grid.ndim() <= MAX_DIMS, "packed orders support up to {MAX_DIMS} dims");
    grid.interior(r)
}

/// Natural (lexicographic, dim-0-fastest) order over the K-interior —
/// the compiled loop nest of the paper's baseline.
pub fn natural(grid: &GridDesc, r: usize) -> Order {
    let d = grid.ndim();
    let Some(ranges) = interior_or_empty(grid, r) else {
        return Order::from_packed(d, Vec::new());
    };
    let n: u64 = ranges.iter().map(|rg| (rg.end - rg.start) as u64).product();
    let mut points = Vec::with_capacity(n as usize);
    let mut x: Vec<i64> = ranges.iter().map(|rg| rg.start).collect();
    loop {
        points.push(Order::pack(&x));
        let mut i = 0;
        loop {
            x[i] += 1;
            if x[i] < ranges[i].end {
                break;
            }
            x[i] = ranges[i].start;
            i += 1;
            if i == d {
                return Order::from_packed(d, points);
            }
        }
    }
}

/// Classical rectangular tiling: visit tile-by-tile (tiles ordered
/// lexicographically), natural order within each tile. `tile[i]` is the
/// tile extent along dim i.
pub fn blocked(grid: &GridDesc, r: usize, tile: &[usize]) -> Order {
    let d = grid.ndim();
    assert_eq!(tile.len(), d);
    assert!(tile.iter().all(|&t| t >= 1));
    let Some(ranges) = interior_or_empty(grid, r) else {
        return Order::from_packed(d, Vec::new());
    };
    let mut points = Vec::new();
    // tile origin odometer
    let mut origin: Vec<i64> = ranges.iter().map(|rg| rg.start).collect();
    'tiles: loop {
        // points within tile
        let hi: Vec<i64> = (0..d).map(|i| (origin[i] + tile[i] as i64).min(ranges[i].end)).collect();
        let mut x = origin.clone();
        'points: loop {
            points.push(Order::pack(&x));
            let mut i = 0;
            loop {
                x[i] += 1;
                if x[i] < hi[i] {
                    continue 'points;
                }
                x[i] = origin[i];
                i += 1;
                if i == d {
                    break 'points;
                }
            }
        }
        // advance tile origin
        let mut i = 0;
        loop {
            origin[i] += tile[i] as i64;
            if origin[i] < ranges[i].end {
                break;
            }
            origin[i] = ranges[i].start;
            i += 1;
            if i == d {
                break 'tiles;
            }
        }
    }
    Order::from_packed(d, points)
}

/// The §3 lower-bound-attaining order: partition dim 0 into strips of
/// `width` points; for each strip, sweep the remaining dims naturally with
/// dim 0 innermost within the strip:
///
/// ```text
/// do strip                      (i in the paper, k·a strips)
///   do x_d … x_2                (j in the paper)
///     do x_1 in strip           (i1)
/// ```
pub fn strip(grid: &GridDesc, r: usize, width: usize) -> Order {
    let d = grid.ndim();
    assert!(width >= 1);
    let Some(ranges) = interior_or_empty(grid, r) else {
        return Order::from_packed(d, Vec::new());
    };
    let mut points = Vec::new();
    let (lo0, hi0) = (ranges[0].start, ranges[0].end);
    let mut s_lo = lo0;
    while s_lo < hi0 {
        let s_hi = (s_lo + width as i64).min(hi0);
        if d == 1 {
            let mut x = vec![0i64];
            for x0 in s_lo..s_hi {
                x[0] = x0;
                points.push(Order::pack(&x));
            }
        } else {
            // odometer over dims 1..d
            let mut x: Vec<i64> = ranges.iter().map(|rg| rg.start).collect();
            'outer: loop {
                for x0 in s_lo..s_hi {
                    x[0] = x0;
                    points.push(Order::pack(&x));
                }
                let mut i = 1;
                loop {
                    x[i] += 1;
                    if x[i] < ranges[i].end {
                        break;
                    }
                    x[i] = ranges[i].start;
                    i += 1;
                    if i == d {
                        break 'outer;
                    }
                }
            }
        }
        s_lo = s_hi;
    }
    Order::from_packed(d, points)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grid_3d() -> GridDesc {
        GridDesc::new(&[8, 7, 6])
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let x = [3i64, 65535, 0, 7];
        let p = Order::pack(&x);
        let mut y = [0i64; 4];
        Order::unpack(p, &mut y);
        assert_eq!(x, y);
    }

    #[test]
    fn natural_matches_interior_count_and_order() {
        let g = grid_3d();
        let o = natural(&g, 1);
        assert_eq!(o.len() as u64, g.interior_points(1));
        // first point is (1,1,1); second is (2,1,1) — dim 0 fastest.
        let mut pts = Vec::new();
        o.for_each(|x| pts.push(x.to_vec()));
        assert_eq!(pts[0], vec![1, 1, 1]);
        assert_eq!(pts[1], vec![2, 1, 1]);
        assert_eq!(*pts.last().unwrap(), vec![6, 5, 4]);
    }

    #[test]
    fn natural_empty_when_no_interior() {
        let g = GridDesc::new(&[3, 3]);
        assert!(natural(&g, 2).is_empty());
    }

    #[test]
    fn blocked_same_set_as_natural() {
        let g = grid_3d();
        let nat = natural(&g, 1);
        for tile in [[2usize, 2, 2], [3, 5, 1], [100, 1, 2]] {
            let b = blocked(&g, 1, &tile);
            assert_eq!(b.canonical_set(), nat.canonical_set(), "tile {tile:?}");
        }
    }

    #[test]
    fn blocked_visits_tile_first() {
        let g = GridDesc::new(&[6, 6]);
        let b = blocked(&g, 1, &[2, 2]);
        let mut pts = Vec::new();
        b.for_each(|x| pts.push((x[0], x[1])));
        // first tile covers (1..3)×(1..3)
        assert_eq!(&pts[..4], &[(1, 1), (2, 1), (1, 2), (2, 2)]);
    }

    #[test]
    fn strip_same_set_as_natural() {
        let g = grid_3d();
        let nat = natural(&g, 1);
        for w in [1usize, 2, 3, 100] {
            let s = strip(&g, 1, w);
            assert_eq!(s.canonical_set(), nat.canonical_set(), "width {w}");
        }
    }

    #[test]
    fn strip_order_shape() {
        let g = GridDesc::new(&[8, 4]);
        let s = strip(&g, 1, 3);
        let mut pts = Vec::new();
        s.for_each(|x| pts.push((x[0], x[1])));
        // interior x0 in 1..7, x1 in 1..3; first strip x0 in 1..4 sweeps all x1
        assert_eq!(&pts[..6], &[(1, 1), (2, 1), (3, 1), (1, 2), (2, 2), (3, 2)]);
        // second strip picks up x0 in 4..7
        assert_eq!(pts[6], (4, 1));
    }

    #[test]
    fn linear_offsets_match_strides() {
        let g = GridDesc::new(&[5, 5]);
        let o = natural(&g, 1);
        let offs = o.linear_offsets(&g);
        assert_eq!(offs[0], 6); // (1,1) → 1 + 5
        assert_eq!(offs[1], 7); // (2,1)
    }

    #[test]
    fn property_all_orders_are_permutations() {
        use crate::util::proptest::{forall, DimsGen};
        forall(21, 25, &DimsGen { d: 3, lo: 5, hi: 14 }, |dims| {
            let g = GridDesc::new(dims);
            let nat = natural(&g, 2).canonical_set();
            let b = blocked(&g, 2, &[3, 2, 4]).canonical_set();
            let s = strip(&g, 2, 4).canonical_set();
            // canonical sets must be identical AND free of duplicates
            let mut dedup = nat.clone();
            dedup.dedup();
            nat == b && nat == s && dedup.len() == nat.len()
        });
    }
}
