//! Appendix A: lattice-point counts for the discrete octahedron and simplex,
//! and the isoperimetric machinery behind the paper's lower bound.
//!
//! Definitions (Eq 15/16):
//! - `O(d,t) = {x ∈ Z^d : Σ|x_i| ≤ t}` — the standard octahedron;
//! - `S(d,t) = {x ∈ Z^d : x_i ≥ 0, Σ x_i ≤ t}` — the standard simplex.
//!
//! Closed forms (Eq 18/19/23):
//! - `|O(d,t)| = Σ_k 2^k C(d,k) C(t,k)`
//! - `|δO(d,t−1)| = Σ_k 2^k C(d,k) C(t−1,k−1)`
//! - `|S(d,t)| = C(d+t,d)`

/// Binomial coefficient C(n, k) in u128 (n may exceed usize range of k).
pub fn binom(n: u64, k: u64) -> u128 {
    if k > n {
        return 0;
    }
    let k = k.min(n - k);
    let mut acc: u128 = 1;
    for i in 0..k {
        acc = acc * (n - i) as u128 / (i + 1) as u128;
    }
    acc
}

/// |O(d,t)| — integer points in the octahedron of radius t (Eq 18).
pub fn octahedron_volume(d: u32, t: u64) -> u128 {
    (0..=d as u64).map(|k| (1u128 << k) * binom(d as u64, k) * binom(t, k)).sum()
}

/// |δO(d,t)| — boundary points of the octahedron of radius t: the shell
/// `O(d,t+1) − O(d,t)` … the paper indexes it as Eq 19, equivalently the
/// Eq 4 form `Σ_k 2^k C(d,k) C(t,k−1)`.
pub fn octahedron_surface(d: u32, t: u64) -> u128 {
    (1..=d as u64).map(|k| (1u128 << k) * binom(d as u64, k) * binom(t, k - 1)).sum()
}

/// |S(d,t)| = C(d+t, d) — integer points in the simplex (Eq 23).
pub fn simplex_volume(d: u32, t: u64) -> u128 {
    binom(d as u64 + t, d as u64)
}

/// Brute-force octahedron count (for testing the closed forms).
pub fn octahedron_volume_brute(d: u32, t: i64) -> u128 {
    fn rec(d: u32, budget: i64) -> u128 {
        if d == 0 {
            return 1;
        }
        let mut acc = 0u128;
        for x in -budget..=budget {
            acc += rec(d - 1, budget - x.abs());
        }
        acc
    }
    rec(d, t)
}

/// Choose the smallest octahedron radius `t` with `|δO(d,t)| ≥ target`
/// (the paper's σ selection around Eq 4: σ = |δO(d,t)| ≥ 8dS, and by Eq 21
/// σ < 8d(2d+1)S for the minimal such t).
pub fn radius_for_surface(d: u32, target: u128) -> u64 {
    let mut t = 1u64;
    while octahedron_surface(d, t) < target {
        t = if t < 16 { t + 1 } else { t + t / 8 + 1 };
    }
    // back off to the minimal t by linear descent (cheap: few steps).
    while t > 1 && octahedron_surface(d, t - 1) >= target {
        t -= 1;
    }
    t
}

/// Surface-to-volume ratio of the octahedron with |δO| ≈ the given surface
/// target — the isoperimetric quantity in Eq 5.
pub fn isoperimetric_ratio(d: u32, surface_target: u128) -> f64 {
    let t = radius_for_surface(d, surface_target);
    octahedron_surface(d, t) as f64 / octahedron_volume(d, t) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn binom_basics() {
        assert_eq!(binom(5, 2), 10);
        assert_eq!(binom(5, 0), 1);
        assert_eq!(binom(5, 5), 1);
        assert_eq!(binom(4, 7), 0);
        assert_eq!(binom(52, 5), 2_598_960);
    }

    #[test]
    fn octahedron_matches_brute_force() {
        for d in 1..=4u32 {
            for t in 0..=6u64 {
                assert_eq!(
                    octahedron_volume(d, t),
                    octahedron_volume_brute(d, t as i64),
                    "d={d} t={t}"
                );
            }
        }
    }

    #[test]
    fn small_octahedra_known_values() {
        // d=2: diamond of radius t has 2t²+2t+1 points.
        for t in 0..10u64 {
            assert_eq!(octahedron_volume(2, t), (2 * t * t + 2 * t + 1) as u128);
        }
        // d=3, t=1: center + 6 = 7.
        assert_eq!(octahedron_volume(3, 1), 7);
        assert_eq!(octahedron_volume(3, 2), 25);
    }

    #[test]
    fn surface_is_volume_difference() {
        // |δO(d,t)| must equal |O(d,t+1)| − |O(d,t)| (shell of radius t+1)
        // — the paper's Eq 19 with its t−1 shifted to t.
        for d in 1..=4u32 {
            for t in 0..=8u64 {
                assert_eq!(
                    octahedron_surface(d, t),
                    octahedron_volume(d, t + 1) - octahedron_volume(d, t),
                    "d={d} t={t}"
                );
            }
        }
    }

    #[test]
    fn recurrence_eq17() {
        // |O(d,t)| = |O(d−1,t)| + 2 Σ_{k=0}^{t−1} |O(d−1,k)|
        for d in 2..=4u32 {
            for t in 1..=8u64 {
                let rhs: u128 = octahedron_volume(d - 1, t)
                    + 2 * (0..t).map(|k| octahedron_volume(d - 1, k)).sum::<u128>();
                assert_eq!(octahedron_volume(d, t), rhs, "d={d} t={t}");
            }
        }
    }

    #[test]
    fn recurrence_eq20() {
        // |δO(d,t)| = |δO(d,t−1)| + |δO(d−1,t)| + |δO(d−1,t−1)|
        for d in 2..=4u32 {
            for t in 1..=8u64 {
                let rhs = octahedron_surface(d, t - 1)
                    + octahedron_surface(d - 1, t)
                    + octahedron_surface(d - 1, t - 1);
                assert_eq!(octahedron_surface(d, t), rhs, "d={d} t={t}");
            }
        }
    }

    #[test]
    fn growth_bound_eq21() {
        // |δO(d,t)| ≤ (2d+1)|δO(d,t−1)|
        for d in 2..=4u32 {
            for t in 1..=10u64 {
                assert!(
                    octahedron_surface(d, t) <= (2 * d as u128 + 1) * octahedron_surface(d, t - 1),
                    "d={d} t={t}"
                );
            }
        }
    }

    #[test]
    fn simplex_recurrence_eq22_and_closed_form() {
        for d in 1..=5u32 {
            for t in 1..=8u64 {
                assert_eq!(
                    simplex_volume(d, t),
                    simplex_volume(d - 1, t) + simplex_volume(d, t - 1),
                    "d={d} t={t}"
                );
            }
        }
        assert_eq!(simplex_volume(3, 3), binom(6, 3));
    }

    #[test]
    fn octahedron_simplex_sandwich_eq24() {
        // 2|S(d−1,t)| ≤ |δO(d,t−1)| ≤ 2^d |S(d−1,t)| for d ≥ 2
        for d in 2..=4u32 {
            for t in 1..=8u64 {
                let s = simplex_volume(d - 1, t);
                let shell = octahedron_surface(d, t - 1);
                assert!(2 * s <= shell, "lower d={d} t={t}");
                assert!(shell <= (1 << d) * s, "upper d={d} t={t}");
            }
        }
    }

    #[test]
    fn radius_for_surface_minimal() {
        for d in 2..=3u32 {
            for target in [10u128, 100, 10_000, 1_000_000] {
                let t = radius_for_surface(d, target);
                assert!(octahedron_surface(d, t) >= target);
                if t > 1 {
                    assert!(octahedron_surface(d, t - 1) < target);
                }
            }
        }
    }

    #[test]
    fn isoperimetric_ratio_decreases_with_size() {
        let r1 = isoperimetric_ratio(3, 1_000);
        let r2 = isoperimetric_ratio(3, 1_000_000);
        assert!(r2 < r1);
    }
}
