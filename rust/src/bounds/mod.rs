//! The paper's lower and upper bounds on cache loads (§3, §4, §5) and the
//! Appendix B favorable-grid construction.
//!
//! All bounds are stated for the number of cache **loads** μ of the RHS
//! array(s) needed to evaluate a stencil containing the star over the
//! K-interior of a grid G on a cache of S words:
//!
//! - **Lower** (Eq 7, any cache incl. fully associative):
//!   `μ ≥ |G|·(1 − (2d+1)/l + (1 − 2d/l)·c_d·S^{−1/(d−1)})`,
//!   `c_d = 1/(d(2d+1)2^{d+2})`, `l` = smallest grid extent.
//! - **Upper** (Eq 12, cache-fitting algorithm, favorable lattice):
//!   `μ ≤ |G|·(1 + e·c''_d·S^{−1/d})`, `c''_d = r(2r+1)^d·2d·c^{LLL}_d`,
//!   `e` = reduced-basis eccentricity, `c^{LLL}_d = 2^{d(d−1)/4}`.
//! - **Multi-RHS** (Eq 13/14): same with `|G| → p|G|`, `S → ⌈S/p⌉`.
//!
//! Note the paper overloads `c_d`: the lower-bound constant (isoperimetric)
//! and the reduced-basis constant (Eq 10) are different; we name them
//! `lower_c_d` and `lll_c_d` here.

pub mod favorable;
mod octahedron;

pub use favorable::FavorableGrid;
pub use octahedron::{
    binom, isoperimetric_ratio, octahedron_surface, octahedron_volume, octahedron_volume_brute,
    radius_for_surface, simplex_volume,
};

use crate::grid::GridDesc;

/// The isoperimetric constant `c_d = 1/(d(2d+1)2^{d+2})` of Eq 5–7.
pub fn lower_c_d(d: u32) -> f64 {
    let d = d as f64;
    1.0 / (d * (2.0 * d + 1.0) * 2f64.powf(d + 2.0))
}

/// The LLL reduced-basis constant `c_d = 2^{d(d−1)/4}` (Eq 10 footnote).
pub fn lll_c_d(d: u32) -> f64 {
    2f64.powf(d as f64 * (d as f64 - 1.0) / 4.0)
}

/// `c'_d = 2d·c^{LLL}_d` (below Eq 11).
pub fn c_prime_d(d: u32) -> f64 {
    2.0 * d as f64 * lll_c_d(d)
}

/// `c''_d = r(2r+1)^d·c'_d` (below Eq 12).
pub fn c_double_prime_d(d: u32, r: u32) -> f64 {
    r as f64 * (2.0 * r as f64 + 1.0).powi(d as i32) * c_prime_d(d)
}

/// Eq 7: lower bound on loads per the whole grid, for a star-containing
/// stencil on a d-dimensional grid (d ≥ 2) with smallest extent `l`.
/// Returns loads (words).
pub fn lower_bound_loads(grid: &GridDesc, cache_words: usize) -> f64 {
    lower_bound_loads_multi(grid, cache_words, 1)
}

/// Eq 13: multi-RHS lower bound (p arrays; p = 1 recovers Eq 7 with the
/// paper's (2d−1) ↔ (2d+1) boundary-term discrepancy resolved conservatively
/// in favor of the weaker — always-valid — (2d+1) form).
pub fn lower_bound_loads_multi(grid: &GridDesc, cache_words: usize, p: usize) -> f64 {
    let d = grid.ndim() as u32;
    assert!(d >= 2, "the isoperimetric lower bound needs d ≥ 2");
    assert!(p >= 1);
    let g = grid.num_points() as f64;
    let l = grid.min_dim() as f64;
    let s_eff = (cache_words as f64 / p as f64).ceil();
    let c = lower_c_d(d);
    let term = 1.0 - (2.0 * d as f64 + 1.0) / l
        + (1.0 - 2.0 * d as f64 / l) * c * s_eff.powf(-1.0 / (d as f64 - 1.0));
    (p as f64 * g * term).max(0.0)
}

/// Eq 12: upper bound on loads achieved by the cache-fitting algorithm,
/// given the eccentricity `e` of the reduced interference-lattice basis and
/// stencil radius `r`.
pub fn upper_bound_loads(grid: &GridDesc, cache_words: usize, r: u32, eccentricity: f64) -> f64 {
    upper_bound_loads_multi(grid, cache_words, r, eccentricity, 1)
}

/// Eq 14: multi-RHS upper bound.
pub fn upper_bound_loads_multi(
    grid: &GridDesc,
    cache_words: usize,
    r: u32,
    eccentricity: f64,
    p: usize,
) -> f64 {
    let d = grid.ndim() as u32;
    assert!(p >= 1);
    let g = grid.num_points() as f64;
    let s_eff = (cache_words as f64 / p as f64).ceil();
    p as f64 * g * (1.0 + eccentricity * c_double_prime_d(d, r) * s_eff.powf(-1.0 / d as f64))
}

/// The §3 example closed form: loads of u for the strip order on a 2-D
/// grid with `n1 = k·S`, radius-r star, associativity a:
/// `n1·n2·(1 − 2/n1 + 2ra(1 − 2/n2)/S)` (the paper states r = 1; we keep r
/// explicit).
pub fn sec3_example_loads(n1: u64, n2: u64, s: u64, a: u64, r: u64) -> f64 {
    let (n1f, n2f, sf, af, rf) = (n1 as f64, n2 as f64, s as f64, a as f64, r as f64);
    n1f * n2f * (1.0 - 2.0 / n1f + 2.0 * rf * af * (1.0 - 2.0 / n2f) / sf)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constants_match_paper() {
        // d = 3: c_3 = 1/(3·7·2^5) = 1/672.
        assert!((lower_c_d(3) - 1.0 / 672.0).abs() < 1e-15);
        // d = 2: c_2 = 1/(2·5·16) = 1/160.
        assert!((lower_c_d(2) - 1.0 / 160.0).abs() < 1e-15);
        // LLL: c_3 = 2^{3·2/4} = 2^{1.5}.
        assert!((lll_c_d(3) - 2f64.powf(1.5)).abs() < 1e-12);
        assert!((c_prime_d(3) - 6.0 * 2f64.powf(1.5)).abs() < 1e-12);
        // c''_3 for r=2: 2·5³·c'_3.
        assert!((c_double_prime_d(3, 2) - 2.0 * 125.0 * c_prime_d(3)).abs() < 1e-9);
    }

    #[test]
    fn lower_bound_close_to_volume_for_large_grids() {
        // For realistic l the boundary discount (2d+1)/l dominates the tiny
        // isoperimetric surcharge c_d·S^{-1/(d-1)}: the bound sits just
        // below |G|, approaching it from below as l grows.
        let lb500 = lower_bound_loads(&GridDesc::new(&[500, 500, 500]), 4096);
        let g500 = 500f64.powi(3);
        assert!(lb500 > 0.98 * g500 && lb500 < g500, "lb = {lb500}");
        // asymptotically (l large relative to S) the isoperimetric term
        // wins: per-point bound > 1 once (2d+1)/l < c_d·S^{-1/(d-1)}.
        // 2-D, S = 64: need l > 5·160·64 = 51200.
        let g2 = GridDesc::new(&[500_000, 500_000]);
        let lb2 = lower_bound_loads(&g2, 64);
        assert!(lb2 > g2.num_points() as f64, "lb2 = {lb2}");
    }

    #[test]
    fn lower_bound_small_grid_degrades_gracefully() {
        // Small l makes the boundary term dominate; bound must stay ≥ 0.
        let g = GridDesc::new(&[5, 5]);
        assert!(lower_bound_loads(&g, 1024) >= 0.0);
    }

    #[test]
    fn upper_above_lower_for_favorable_lattices() {
        // The sandwich must hold whenever e is modest (favorable grid).
        for dims in [[64usize, 64, 64], [100, 91, 80], [128, 96, 56]] {
            let g = GridDesc::new(&dims);
            let lat = crate::lattice::InterferenceLattice::new(g.storage_dims(), 4096);
            let lb = lower_bound_loads(&g, 4096);
            let ub = upper_bound_loads(&g, 4096, 2, lat.eccentricity());
            assert!(ub > lb, "dims {dims:?}: ub {ub} ≤ lb {lb}");
            // Both bracket |G| from the right side.
            assert!(ub > g.num_points() as f64);
        }
    }

    #[test]
    fn relative_gap_shrinks_with_cache_size() {
        // Paper (end of §4): for favorable lattices the relative gap between
        // Eq 12 and Eq 7 goes to zero as S increases. With e held fixed,
        // (ub − lb)/|G| must decrease in S.
        let g = GridDesc::new(&[400, 400, 400]);
        let gap = |s: usize| {
            let lb = lower_bound_loads(&g, s);
            let ub = upper_bound_loads(&g, s, 1, 2.0);
            (ub - lb) / g.num_points() as f64
        };
        let g1 = gap(1 << 12);
        let g2 = gap(1 << 16);
        let g3 = gap(1 << 20);
        assert!(g1 > g2 && g2 > g3, "{g1} {g2} {g3}");
    }

    #[test]
    fn multi_rhs_bounds_scale_with_p() {
        let g = GridDesc::new(&[100, 100, 100]);
        let s = 4096;
        let lb1 = lower_bound_loads_multi(&g, s, 1);
        let lb4 = lower_bound_loads_multi(&g, s, 4);
        assert!(lb4 > 3.9 * lb1, "lb4 = {lb4}, lb1 = {lb1}");
        let ub1 = upper_bound_loads_multi(&g, s, 2, 2.0, 1);
        let ub4 = upper_bound_loads_multi(&g, s, 2, 2.0, 4);
        assert!(ub4 > 4.0 * ub1, "effective cache shrinks ⇒ more than 4× loads");
    }

    #[test]
    fn sec3_example_formula() {
        // n1 = S, k = 1, a = 2, r = 1, big n2: loads ≈ n1 n2 (1 + 2a/S).
        let s = 4096u64;
        let v = sec3_example_loads(s, 1000, s, 2, 1);
        let expect = s as f64 * 1000.0 * (1.0 - 2.0 / s as f64 + 4.0 * (1.0 - 0.002) / s as f64);
        assert!((v - expect).abs() < 1e-6);
        // near-optimal: within 0.2% of |G| for these parameters.
        assert!(v < s as f64 * 1000.0 * 1.002);
    }

    #[test]
    #[should_panic(expected = "d ≥ 2")]
    fn lower_bound_rejects_1d() {
        lower_bound_loads(&GridDesc::new(&[100]), 64);
    }
}
