//! Appendix B: construction of grids with **favorable** interference
//! lattices — lattices whose shortest vector has length ≥ (S/f)^{1/d} with
//! `f` independent of S (when S is a prime power).
//!
//! The construction: pick badly-approximable reals μ_2 … μ_d (we use the
//! algebraic numbers μ_i = 2^{(i−1)/d}, linearly independent over Q with 1,
//! which satisfy the Cassels Theorem VIII simultaneous-approximation lower
//! bound), set `m_i = round(S·μ_i)` adjusted to be coprime with S, and
//! recover grid dimensions by solving `n_i·m_i ≡ m_{i+1} (mod S)`
//! (step b of the appendix; sorted so gcd(m_i,S) | gcd(m_{i+1},S) — with
//! coprime m_i the congruences are directly solvable).
//!
//! The resulting lattice has basis `{S·e_1, −m_i·e_1 + e_i}` — the Eq 9
//! basis of the constructed grid — and no short vectors; its reduced basis
//! has eccentricity depending only on d.

use crate::lattice::InterferenceLattice;

/// A grid produced by the Appendix B construction, with its certificate.
#[derive(Debug, Clone)]
pub struct FavorableGrid {
    /// Grid dimensions n_1 … n_d (determined mod S; representatives chosen
    /// in [2, S+1]).
    pub dims: Vec<usize>,
    /// The m_i multipliers (m_1 = 1).
    pub multipliers: Vec<i64>,
    /// Shortest-vector length of the resulting interference lattice.
    pub shortest_len: f64,
    /// The achieved quality `f = S / ‖v‖^d` (smaller is better; Appendix B
    /// promises f bounded independent of S).
    pub f_quality: f64,
}

/// Extended gcd: returns (g, x, y) with a·x + b·y = g.
fn egcd(a: i64, b: i64) -> (i64, i64, i64) {
    if b == 0 {
        (a.abs(), a.signum(), 0)
    } else {
        let (g, x, y) = egcd(b, a % b);
        (g, y, x - (a / b) * y)
    }
}

/// Modular inverse of a mod m (requires gcd(a, m) = 1).
pub fn mod_inverse(a: i64, m: i64) -> Option<i64> {
    let (g, x, _) = egcd(a.rem_euclid(m), m);
    if g != 1 {
        None
    } else {
        Some(x.rem_euclid(m))
    }
}

/// Construct a favorable d-dimensional grid for a cache of `s` words
/// (s should be a prime power — true of every practical cache size).
pub fn construct(d: usize, s: usize) -> FavorableGrid {
    assert!(d >= 2, "construction needs d ≥ 2");
    assert!(s >= 4);
    let sf = s as f64;
    // μ_i = 2^{(i−1)/d}, i = 2..d; m_i = round(S μ_i), forced coprime to S.
    // (For S = 2^n coprime ⇔ odd; for general prime-power p^n adjust until
    // gcd = 1 — at most p−1 steps.)
    let mut multipliers = vec![1i64]; // m_1 = 1
    for i in 2..=d {
        let mu = 2f64.powf((i - 1) as f64 / d as f64);
        let mut m = (sf * mu).round() as i64;
        while egcd(m, s as i64).0 != 1 {
            m += 1;
        }
        multipliers.push(m);
    }
    // Solve n_i m_i ≡ m_{i+1} (mod S) for i = 1..d−1; last dim free (take a
    // representative ≥ 2 as well — use m_d's solution pattern by wrapping:
    // n_d only affects strides beyond the modulus, choose n_d = S/2+1 odd
    // representative for definiteness).
    let si = s as i64;
    let mut dims = Vec::with_capacity(d);
    for i in 0..d - 1 {
        let inv = mod_inverse(multipliers[i], si).expect("m_i coprime with S");
        let mut n = (multipliers[i + 1] as i128 * inv as i128).rem_euclid(si as i128) as i64;
        // dimensions must be ≥ 2 to be a real grid; n ≡ n + S preserves the
        // lattice (Appendix B corollary).
        while n < 2 {
            n += si;
        }
        dims.push(n as usize);
    }
    dims.push((s / 2 + 1) | 1); // arbitrary final extent, lattice-irrelevant scale

    let lattice = InterferenceLattice::new(&dims, s);
    let shortest_len = lattice.shortest_len();
    let f_quality = sf / shortest_len.powi(d as i32);
    FavorableGrid { dims, multipliers, shortest_len, f_quality }
}

/// Verify the certificate: the constructed dims' lattice must contain every
/// `−m_i·e_1 + e_i` (i.e. the intended lattice was realized).
pub fn verify(fg: &FavorableGrid, s: usize) -> bool {
    let lat = InterferenceLattice::new(&fg.dims, s);
    let d = fg.dims.len();
    for i in 1..d {
        let mut v = vec![0i64; d];
        v[0] = -fg.multipliers[i];
        v[i] = 1;
        if !lat.contains(&v) {
            return false;
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn egcd_and_inverse() {
        let (g, x, y) = egcd(240, 46);
        assert_eq!(g, 2);
        assert_eq!(240 * x + 46 * y, 2);
        assert_eq!(mod_inverse(3, 7), Some(5));
        assert_eq!(mod_inverse(2, 4), None);
        assert_eq!(mod_inverse(1, 2), Some(1));
    }

    #[test]
    fn construct_3d_realizes_intended_lattice() {
        for s in [256usize, 1024, 4096] {
            let fg = construct(3, s);
            assert!(verify(&fg, s), "S = {s}: {fg:?}");
        }
    }

    #[test]
    fn constructed_grids_have_no_short_vectors() {
        // The whole point: shortest vector comfortably above the 13-pt-star
        // unfavorability bar (L1 < 3 with assoc 2).
        for s in [1024usize, 4096, 16384] {
            let fg = construct(3, s);
            let lat = InterferenceLattice::new(&fg.dims, s);
            assert!(!lat.is_unfavorable(5), "S = {s}: {:?}", fg.dims);
            assert!(fg.shortest_len >= (s as f64 / 40.0).powf(1.0 / 3.0), "S={s} len={}", fg.shortest_len);
        }
    }

    #[test]
    fn f_quality_bounded_across_s() {
        // Appendix B: f independent of S. Empirically our construction keeps
        // f below ~40 for d = 3 across three decades of S.
        let fs: Vec<f64> = [256usize, 1024, 4096, 16384, 65536]
            .iter()
            .map(|&s| construct(3, s).f_quality)
            .collect();
        for (i, f) in fs.iter().enumerate() {
            assert!(*f < 40.0, "f[{i}] = {f}");
        }
    }

    #[test]
    fn construct_2d() {
        let fg = construct(2, 4096);
        assert!(verify(&fg, 4096));
        let lat = InterferenceLattice::new(&fg.dims, 4096);
        // 2-D favorable: shortest ≥ sqrt(S/f) with small f.
        assert!(lat.shortest_len() >= (4096.0f64 / 16.0).sqrt(), "len = {}", lat.shortest_len());
    }

    #[test]
    fn dims_are_positive_and_reasonable() {
        let fg = construct(3, 4096);
        assert!(fg.dims.iter().all(|&n| n >= 2));
        assert!(fg.dims.iter().all(|&n| n <= 2 * 4096));
    }
}
