//! The set-associative cache simulator.
//!
//! This is the hottest code in the repository: the FIG5A experiment pushes
//! billions of accesses through [`CacheSim::access`]. The hit path is a
//! short linear scan over the ways of one set (move-to-front LRU), with no
//! allocation and no hashing. Cold/replacement classification is done with
//! growable bitsets indexed by line / word address.

use super::CacheParams;

/// Outcome of a single access, at line granularity.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AccessKind {
    /// Word's line was resident.
    Hit,
    /// Line never seen before (compulsory miss).
    ColdMiss,
    /// Line was evicted earlier and re-fetched now (conflict/capacity miss).
    ReplacementMiss,
}

/// Counters, following the definitions of §2 of the paper.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Total word requests issued.
    pub accesses: u64,
    /// Requests whose line was resident.
    pub hits: u64,
    /// φ restricted to first-touch lines.
    pub cold_misses: u64,
    /// φ restricted to re-fetched lines.
    pub replacement_misses: u64,
    /// μ cold component: first explicit request to each distinct word.
    pub cold_loads: u64,
    /// μ replacement component: re-request to a previously-requested word
    /// whose residence expired.
    pub replacement_loads: u64,
    /// Lines evicted (diagnostics).
    pub evictions: u64,
}

impl CacheStats {
    /// φ — total cache misses.
    pub fn misses(&self) -> u64 {
        self.cold_misses + self.replacement_misses
    }

    /// μ — total cache loads (the quantity the paper's bounds constrain).
    pub fn loads(&self) -> u64 {
        self.cold_loads + self.replacement_loads
    }

    /// Miss rate φ / accesses.
    pub fn miss_rate(&self) -> f64 {
        if self.accesses == 0 {
            0.0
        } else {
            self.misses() as f64 / self.accesses as f64
        }
    }

    /// Counter-wise difference `post − pre` of two cumulative snapshots of
    /// the same counter set (the building block for incremental reports).
    pub fn delta(post: CacheStats, pre: CacheStats) -> CacheStats {
        CacheStats {
            accesses: post.accesses - pre.accesses,
            hits: post.hits - pre.hits,
            cold_misses: post.cold_misses - pre.cold_misses,
            replacement_misses: post.replacement_misses - pre.replacement_misses,
            cold_loads: post.cold_loads - pre.cold_loads,
            replacement_loads: post.replacement_loads - pre.replacement_loads,
            evictions: post.evictions - pre.evictions,
        }
    }

    /// Counter-wise sum (shard merging).
    pub fn accumulate(&mut self, other: &CacheStats) {
        self.accesses += other.accesses;
        self.hits += other.hits;
        self.cold_misses += other.cold_misses;
        self.replacement_misses += other.replacement_misses;
        self.cold_loads += other.cold_loads;
        self.replacement_loads += other.replacement_loads;
        self.evictions += other.evictions;
    }
}

/// Growable bitset over u64 indices.
#[derive(Debug, Default)]
struct BitSet {
    words: Vec<u64>,
}

impl BitSet {
    #[inline]
    fn test_and_set(&mut self, idx: u64) -> bool {
        let w = (idx >> 6) as usize;
        if w >= self.words.len() {
            self.words.resize(w + 1 + w / 2, 0);
        }
        let mask = 1u64 << (idx & 63);
        let was = self.words[w] & mask != 0;
        self.words[w] |= mask;
        was
    }

    #[inline]
    fn get(&self, idx: u64) -> bool {
        let w = (idx >> 6) as usize;
        w < self.words.len() && self.words[w] & (1u64 << (idx & 63)) != 0
    }
}

const EMPTY: u64 = u64::MAX;

/// Set-associative LRU cache simulator with §2's load/miss classification.
///
/// Addresses are word addresses (one word = one array element = one f64).
/// The simulator is exact: LRU per set, move-to-front encoding (way 0 is
/// most recently used).
pub struct CacheSim {
    params: CacheParams,
    /// `sets × assoc` line tags, most-recently-used first within each set.
    /// Tag stored = full line number (cheaper than splitting tag/index).
    ways: Vec<u64>,
    assoc: usize,
    set_mask: u64,
    line_shift: u32,
    /// Lines ever fetched (cold vs replacement miss classification).
    seen_lines: BitSet,
    /// Words ever explicitly requested (cold vs replacement load).
    requested_words: BitSet,
    /// Lines currently resident — kept in sync with `ways`; needed to answer
    /// "did this word's residence expire?" without scanning the set twice.
    resident_lines: BitSet,
    stats: CacheStats,
}

impl CacheSim {
    pub fn new(params: CacheParams) -> CacheSim {
        CacheSim {
            params,
            ways: vec![EMPTY; params.sets * params.assoc],
            assoc: params.assoc,
            set_mask: (params.sets - 1) as u64,
            line_shift: params.line_words.trailing_zeros(),
            seen_lines: BitSet::default(),
            requested_words: BitSet::default(),
            resident_lines: BitSet::default(),
            stats: CacheStats::default(),
        }
    }

    pub fn params(&self) -> CacheParams {
        self.params
    }

    pub fn stats(&self) -> CacheStats {
        self.stats
    }

    /// Reset counters and contents (address-history bitsets included).
    pub fn reset(&mut self) {
        self.ways.fill(EMPTY);
        self.seen_lines = BitSet::default();
        self.requested_words = BitSet::default();
        self.resident_lines = BitSet::default();
        self.stats = CacheStats::default();
    }

    /// Is the word at `addr` currently resident (non-mutating probe)?
    pub fn is_resident(&self, addr: u64) -> bool {
        self.resident_lines.get(addr >> self.line_shift)
    }

    /// Issue one word request; returns the line-level outcome and updates
    /// all §2 counters (misses *and* loads).
    #[inline]
    pub fn access(&mut self, addr: u64) -> AccessKind {
        self.stats.accesses += 1;
        let line = addr >> self.line_shift;
        let set = (line & self.set_mask) as usize;
        let base = set * self.assoc;
        let ways = &mut self.ways[base..base + self.assoc];

        // --- line-level lookup with move-to-front LRU ---
        let kind = if ways[0] == line {
            AccessKind::Hit // fast path: MRU hit (dominant in stencil sweeps)
        } else if let Some(pos) = ways[1..].iter().position(|&t| t == line) {
            let pos = pos + 1;
            ways[..=pos].rotate_right(1); // move to front
            AccessKind::Hit
        } else {
            // miss: evict LRU (last way), insert line at front.
            let victim = ways[self.assoc - 1];
            ways.rotate_right(1);
            ways[0] = line;
            if victim != EMPTY {
                self.stats.evictions += 1;
                self.clear_resident(victim);
            }
            self.set_resident(line);
            if self.seen_lines.test_and_set(line) {
                AccessKind::ReplacementMiss
            } else {
                AccessKind::ColdMiss
            }
        };

        match kind {
            AccessKind::Hit => self.stats.hits += 1,
            AccessKind::ColdMiss => self.stats.cold_misses += 1,
            AccessKind::ReplacementMiss => self.stats.replacement_misses += 1,
        }

        // --- word-level load classification (paper §2) ---
        // cold load: first explicit request to this word, regardless of
        //            whether its line happened to be resident already;
        // replacement load: previously-requested word whose line had to be
        //            re-fetched (i.e. this request missed).
        let requested_before = self.requested_words.test_and_set(addr);
        if !requested_before {
            self.stats.cold_loads += 1;
        } else if kind != AccessKind::Hit {
            self.stats.replacement_loads += 1;
        }
        kind
    }

    /// Convenience: run a sequence of accesses.
    pub fn access_all<I: IntoIterator<Item = u64>>(&mut self, addrs: I) {
        for a in addrs {
            self.access(a);
        }
    }

    #[inline]
    fn set_resident(&mut self, line: u64) {
        self.resident_lines.test_and_set(line);
    }

    #[inline]
    fn clear_resident(&mut self, line: u64) {
        let w = (line >> 6) as usize;
        if w < self.resident_lines.words.len() {
            self.resident_lines.words[w] &= !(1u64 << (line & 63));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> CacheSim {
        // direct-mapped, 4 sets, 1 word/line → 4-word cache; collisions every
        // 4 words.
        CacheSim::new(CacheParams::new(1, 4, 1))
    }

    #[test]
    fn cold_then_hit() {
        let mut c = tiny();
        assert_eq!(c.access(0), AccessKind::ColdMiss);
        assert_eq!(c.access(0), AccessKind::Hit);
        let s = c.stats();
        assert_eq!(s.accesses, 2);
        assert_eq!(s.hits, 1);
        assert_eq!(s.cold_misses, 1);
        assert_eq!(s.cold_loads, 1);
        assert_eq!(s.replacement_loads, 0);
    }

    #[test]
    fn conflict_eviction_direct_mapped() {
        let mut c = tiny();
        c.access(0); // set 0
        assert_eq!(c.access(4), AccessKind::ColdMiss); // evicts 0
        assert!(!c.is_resident(0));
        assert_eq!(c.access(0), AccessKind::ReplacementMiss);
        let s = c.stats();
        assert_eq!(s.replacement_misses, 1);
        assert_eq!(s.replacement_loads, 1); // word 0 requested before, expired
        assert_eq!(s.evictions, 2);
    }

    #[test]
    fn two_way_lru_order() {
        // 2-way, 1 set, 1 word/line: capacity 2, LRU.
        let mut c = CacheSim::new(CacheParams::new(2, 1, 1));
        c.access(0);
        c.access(1);
        c.access(0); // 0 is now MRU; LRU is 1
        assert_eq!(c.access(2), AccessKind::ColdMiss); // evicts 1
        assert!(c.is_resident(0));
        assert!(!c.is_resident(1));
        assert_eq!(c.access(0), AccessKind::Hit);
        assert_eq!(c.access(1), AccessKind::ReplacementMiss);
    }

    #[test]
    fn line_fetch_makes_neighbors_resident() {
        // 1 set, 1 way, 4 words/line.
        let mut c = CacheSim::new(CacheParams::new(1, 1, 4));
        assert_eq!(c.access(0), AccessKind::ColdMiss);
        // Word 3 is on the same line: hit, but still a *cold load* (first
        // explicit request to the word) per §2.
        assert_eq!(c.access(3), AccessKind::Hit);
        let s = c.stats();
        assert_eq!(s.cold_loads, 2);
        assert_eq!(s.misses(), 1);
    }

    #[test]
    fn loads_vs_misses_interval_inequality() {
        // μ ≤ w·φ (paper §2) for any access pattern.
        let w = 4;
        let mut c = CacheSim::new(CacheParams::new(2, 8, w));
        // pseudo-random address stream in a space larger than the cache
        let mut x = 12345u64;
        for _ in 0..10_000 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            c.access(x % 4096);
        }
        let s = c.stats();
        assert!(s.loads() <= w as u64 * s.misses(), "μ={} > w·φ={}", s.loads(), w as u64 * s.misses());
    }

    #[test]
    fn sequential_sweep_miss_rate_is_one_over_w() {
        // A long unit-stride sweep misses exactly once per line.
        let p = CacheParams::new(2, 512, 4);
        let mut c = CacheSim::new(p);
        let n = 64 * 1024u64;
        for a in 0..n {
            c.access(a);
        }
        let s = c.stats();
        assert_eq!(s.misses(), n / 4);
        assert_eq!(s.cold_loads, n);
        assert_eq!(s.replacement_loads, 0);
    }

    #[test]
    fn full_associativity_no_conflicts_within_capacity() {
        let p = CacheParams::fully_associative(64, 4);
        let mut c = CacheSim::new(p);
        // touch 64 words (16 lines), then touch again: all hits.
        for a in 0..64u64 {
            c.access(a);
        }
        for a in 0..64u64 {
            assert_eq!(c.access(a), AccessKind::Hit, "addr {a}");
        }
        assert_eq!(c.stats().replacement_misses, 0);
    }

    #[test]
    fn fully_associative_lru_capacity_eviction() {
        let p = CacheParams::fully_associative(4, 1);
        let mut c = CacheSim::new(p);
        for a in 0..5u64 {
            c.access(a); // 5th evicts addr 0 (LRU)
        }
        assert!(!c.is_resident(0));
        assert!(c.is_resident(4));
        assert_eq!(c.access(0), AccessKind::ReplacementMiss);
    }

    #[test]
    fn reset_clears_everything() {
        let mut c = tiny();
        c.access(0);
        c.access(4);
        c.reset();
        assert_eq!(c.stats(), CacheStats::default());
        assert_eq!(c.access(0), AccessKind::ColdMiss);
    }

    #[test]
    fn same_set_different_ways_coexist() {
        // 2-way: addresses 0 and 8 map to set 0 of a (2, 8, 1) cache and must
        // coexist; adding 16 evicts the LRU of the two.
        let mut c = CacheSim::new(CacheParams::new(2, 8, 1));
        c.access(0);
        c.access(8);
        assert_eq!(c.access(0), AccessKind::Hit);
        assert_eq!(c.access(8), AccessKind::Hit);
        c.access(16); // set 0 full of {8, 0}; LRU is 0
        assert!(!c.is_resident(0));
        assert!(c.is_resident(8));
        assert!(c.is_resident(16));
    }

    #[test]
    fn paper_interference_period() {
        // Two addresses S/a = z·w apart collide in the same set.
        let p = CacheParams::r10000();
        let mut c = CacheSim::new(p);
        let stride = p.way_words() as u64; // 2048
        // Three lines stride apart → same set, 2 ways → third evicts first.
        c.access(0);
        c.access(stride);
        assert_eq!(c.access(0), AccessKind::Hit);
        c.access(2 * stride); // evicts LRU (= stride)
        assert_eq!(c.access(stride), AccessKind::ReplacementMiss);
    }
}
