//! The memory-model layer: one trait over every simulated memory system.
//!
//! The paper analyzes a single L1 data cache, but its §6 measurements show
//! miss spikes on unfavorable grids for the *TLB as well as the L1 cache*,
//! and §7 names the secondary cache + TLB as the next step. This module
//! turns "a [`CacheSim`]" into "a memory model":
//!
//! - [`MemoryModel`] — per-access simulation returning the paper's §2
//!   line-level outcome, plus a per-level [`LoadProfile`] snapshot. Both
//!   [`CacheSim`] (single level) and [`Hierarchy`] (L1 + L2 + TLB)
//!   implement it, so `engine::simulate*` is generic over the memory
//!   system.
//! - [`MachineModel`] — a machine descriptor (L1 geometry, optional L2 and
//!   TLB, miss latencies) with named presets: the paper's R10000 L1
//!   (`r10000`), the full R10000/Origin2000 hierarchy (`r10000-full`), and
//!   a `modern` deep-cache geometry. The planner, coordinator, tuner and
//!   CLI thread a `MachineModel` instead of a raw [`CacheParams`].
//! - [`LoadProfile`] — per-level §2 counters with shard-mergeable
//!   semantics and a stall-cycle estimate under a [`Latency`] model.

use super::{AccessKind, CacheParams, CacheSim, CacheStats, Hierarchy, TlbParams};

/// One level of the simulated memory hierarchy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Level {
    /// Primary data cache (the paper's single-level model).
    L1,
    /// Unified secondary cache.
    L2,
    /// Translation lookaside buffer — a fully-associative LRU cache over
    /// virtual page numbers.
    Tlb,
}

impl Level {
    pub fn name(self) -> &'static str {
        match self {
            Level::L1 => "L1",
            Level::L2 => "L2",
            Level::Tlb => "TLB",
        }
    }
}

/// §2 counters attributed to one hierarchy level.
///
/// For the TLB level the "word" is a page number: `accesses` counts one
/// page-number probe per word access and `misses()` counts page walks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LevelLoad {
    pub level: Level,
    pub stats: CacheStats,
}

/// Maximum number of levels a [`LoadProfile`] can carry (L1 + L2 + TLB).
pub const MAX_LEVELS: usize = 3;

/// Per-level load statistics of a simulated run — the multi-level
/// generalization of a single [`CacheStats`]. Fixed-capacity (and `Copy`)
/// so `MissReport` stays a plain value; levels appear in probe order
/// (L1, then L2, then TLB when present).
#[derive(Debug, Clone, Copy)]
pub struct LoadProfile {
    len: usize,
    levels: [LevelLoad; MAX_LEVELS],
}

impl Default for LoadProfile {
    fn default() -> LoadProfile {
        let empty = LevelLoad { level: Level::L1, stats: CacheStats::default() };
        LoadProfile { len: 0, levels: [empty; MAX_LEVELS] }
    }
}

impl PartialEq for LoadProfile {
    fn eq(&self, other: &LoadProfile) -> bool {
        self.levels() == other.levels()
    }
}

impl Eq for LoadProfile {}

impl LoadProfile {
    /// Profile of a single-level run (the paper's model).
    pub fn single(stats: CacheStats) -> LoadProfile {
        let mut p = LoadProfile::default();
        p.push(Level::L1, stats);
        p
    }

    /// Append a level (probe order). Panics beyond [`MAX_LEVELS`] or on a
    /// duplicate level.
    pub fn push(&mut self, level: Level, stats: CacheStats) {
        assert!(self.len < MAX_LEVELS, "LoadProfile overflow");
        assert!(self.get(level).is_none(), "duplicate level {}", level.name());
        self.levels[self.len] = LevelLoad { level, stats };
        self.len += 1;
    }

    /// The recorded levels, in probe order.
    pub fn levels(&self) -> &[LevelLoad] {
        &self.levels[..self.len]
    }

    /// Stats of one level, if the model simulates it.
    pub fn get(&self, level: Level) -> Option<CacheStats> {
        self.levels().iter().find(|l| l.level == level).map(|l| l.stats)
    }

    /// Level-wise `post − pre` of two cumulative snapshots from the *same*
    /// model — the multi-level twin of [`CacheStats::delta`].
    pub fn delta(post: &LoadProfile, pre: &LoadProfile) -> LoadProfile {
        assert_eq!(post.len, pre.len, "profiles from different models");
        let mut out = LoadProfile::default();
        for (a, b) in post.levels().iter().zip(pre.levels()) {
            assert_eq!(a.level, b.level, "profiles from different models");
            out.push(a.level, CacheStats::delta(a.stats, b.stats));
        }
        out
    }

    /// Accumulate another profile level-wise (shard merging). An empty
    /// profile adopts `other`'s levels; otherwise the level lists must
    /// match.
    pub fn merge(&mut self, other: &LoadProfile) {
        if other.len == 0 {
            return;
        }
        if self.len == 0 {
            *self = *other;
            return;
        }
        assert_eq!(self.len, other.len, "merging profiles from different models");
        for (a, b) in self.levels[..self.len].iter_mut().zip(other.levels()) {
            assert_eq!(a.level, b.level, "merging profiles from different models");
            a.stats.accumulate(&b.stats);
        }
    }

    /// Additive stall-cycle estimate under `lat` (hit costs folded into
    /// CPI, mirroring [`super::HierarchyStats::stall_cycles`]): an L1 miss
    /// pays the next level's latency (L2 when present, memory otherwise),
    /// an L2 miss pays memory, a TLB miss pays the refill.
    pub fn stall_cycles(&self, lat: Latency) -> u64 {
        let mut cycles = 0u64;
        match (self.get(Level::L1), self.get(Level::L2)) {
            (Some(l1), Some(l2)) => cycles += l1.misses() * lat.l2 + l2.misses() * lat.mem,
            (Some(l1), None) => cycles += l1.misses() * lat.mem,
            _ => {}
        }
        if let Some(tlb) = self.get(Level::Tlb) {
            cycles += tlb.misses() * lat.tlb;
        }
        cycles
    }

    /// [`LoadProfile::stall_cycles`] with the software-prefetch discount of
    /// `engine::kernel`'s streaming prefetch: a kernel running with
    /// `prefetch_distance > 0` issues the operand line `dist` words early,
    /// hiding up to [`Latency::prefetch`] cycles of each **cold** miss at
    /// the memory boundary (L2 when present, else L1). Only cold misses
    /// are discounted — they are the first-touch streaming traffic the
    /// row-ahead prefetch targets; replacement misses come from reuse the
    /// traversal failed to keep resident, which a streaming prefetch does
    /// not help. With `dist == 0` or `lat.prefetch == 0` this is exactly
    /// [`LoadProfile::stall_cycles`].
    pub fn stall_cycles_prefetched(&self, lat: Latency, dist: usize) -> u64 {
        let base = self.stall_cycles(lat);
        if dist == 0 || lat.prefetch == 0 {
            return base;
        }
        let cold = match (self.get(Level::L1), self.get(Level::L2)) {
            (_, Some(l2)) => l2.cold_misses,
            (Some(l1), None) => l1.cold_misses,
            _ => 0,
        };
        base.saturating_sub(cold * lat.prefetch.min(lat.mem))
    }
}

/// Miss latencies in cycles for the stall estimate. The numbers are coarse
/// machine constants, not measurements — the estimate ranks traversals and
/// machines, it does not predict wall time.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Latency {
    /// L1 miss serviced by L2.
    pub l2: u64,
    /// Last-level miss serviced by memory.
    pub mem: u64,
    /// TLB refill (software on MIPS).
    pub tlb: u64,
    /// Cycles a *timely software prefetch* hides of a memory-serviced cold
    /// miss (0 = the machine gets nothing from software prefetch, and
    /// [`LoadProfile::stall_cycles_prefetched`] degenerates to the exact
    /// [`LoadProfile::stall_cycles`]). Capped at `mem` — a prefetch cannot
    /// hide more than the full memory trip.
    pub prefetch: u64,
    /// Cross-node (remote NUMA) word latency: what a halo word fetched
    /// from a neighbor shard's node costs, per word. The planner's
    /// superstep-depth chooser weighs `remote` per exchanged halo word
    /// against `mem` per redundantly recomputed ghost point — temporal
    /// blocking across shards only wins while the exchange it saves is
    /// dearer than the ghost compute it adds.
    pub remote: u64,
}

impl Latency {
    /// R10000 / Origin 2000 ballpark: ~10-cycle L2, ~80-cycle local
    /// memory, ~50-cycle software TLB refill. `prefetch` is 0: the paper's
    /// platform model stays exactly the §2/§7 stall estimate. `remote` is
    /// the Origin 2000's ~3× local-memory penalty for a one-hop remote
    /// line.
    pub fn r10000() -> Latency {
        Latency { l2: 10, mem: 80, tlb: 50, prefetch: 0, remote: 240 }
    }
}

impl Default for Latency {
    fn default() -> Latency {
        Latency::r10000()
    }
}

/// A simulated memory system: per-word-access outcome plus per-level
/// statistics. Implemented by [`CacheSim`] (the paper's single-level
/// model) and [`Hierarchy`] (L1 + L2 + TLB).
///
/// `access` returns the **L1-level** outcome so the §2 load/miss
/// accounting of `engine::simulate` is identical across models — the
/// deeper levels only add rows to [`MemoryModel::profile`].
pub trait MemoryModel {
    /// Issue one word request; returns the L1 line-level outcome.
    fn access(&mut self, addr: u64) -> AccessKind;

    /// Cumulative L1 counters — the quantity the paper's bounds constrain.
    fn l1_stats(&self) -> CacheStats;

    /// Cumulative per-level counters.
    fn profile(&self) -> LoadProfile;

    /// Reset counters and contents.
    fn reset(&mut self);
}

impl MemoryModel for CacheSim {
    #[inline]
    fn access(&mut self, addr: u64) -> AccessKind {
        CacheSim::access(self, addr)
    }

    fn l1_stats(&self) -> CacheStats {
        self.stats()
    }

    fn profile(&self) -> LoadProfile {
        LoadProfile::single(self.stats())
    }

    fn reset(&mut self) {
        CacheSim::reset(self)
    }
}

impl MemoryModel for Hierarchy {
    #[inline]
    fn access(&mut self, addr: u64) -> AccessKind {
        Hierarchy::access(self, addr)
    }

    fn l1_stats(&self) -> CacheStats {
        Hierarchy::l1_stats(self)
    }

    fn profile(&self) -> LoadProfile {
        Hierarchy::profile(self)
    }

    fn reset(&mut self) {
        Hierarchy::reset(self)
    }
}

/// A machine descriptor: which memory levels exist and with what geometry.
/// This is what the planner, coordinator, tuner and CLI thread around in
/// place of a raw [`CacheParams`] — one request can be analyzed against
/// the paper's L1-only R10000, the full R10000, or a modern geometry by
/// swapping the descriptor.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct MachineModel {
    /// Preset (or caller-supplied) name, for logs and tables.
    pub name: &'static str,
    /// Primary data cache — always present; the lattice/bounds machinery
    /// and the §2 load accounting run against this level.
    pub l1: CacheParams,
    /// Unified secondary cache (probed on L1 misses).
    pub l2: Option<CacheParams>,
    /// TLB (probed on every access, at page granularity).
    pub tlb: Option<TlbParams>,
    /// Miss latencies for the stall-cycle estimate.
    pub latency: Latency,
}

impl MachineModel {
    /// Single-level machine around an explicit L1 geometry (e.g. the CLI's
    /// `--cache a,z,w`).
    pub fn l1_only(l1: CacheParams) -> MachineModel {
        MachineModel { name: "custom-l1", l1, l2: None, tlb: None, latency: Latency::r10000() }
    }

    /// The paper's model: MIPS R10000 32 KB L1 D-cache only.
    pub fn r10000() -> MachineModel {
        MachineModel { name: "r10000", ..MachineModel::l1_only(CacheParams::r10000()) }
    }

    /// The paper's measurement platform in full (§7's "secondary cache and
    /// TLB"): R10000 L1 + 4 MB unified L2 + 64-entry TLB over 4 KB pages.
    pub fn r10000_full() -> MachineModel {
        MachineModel {
            name: "r10000-full",
            l1: CacheParams::r10000(),
            l2: Some(CacheParams::new(2, 16 * 1024, 16)), // 512K words = 4 MB
            tlb: Some(TlbParams::r10000()),
            latency: Latency::r10000(),
        }
    }

    /// A modern three-level geometry: 48 KB 12-way L1 with 64 B lines,
    /// 1 MB 16-way L2, 1536-entry TLB over 4 KB pages, deeper memory.
    pub fn modern() -> MachineModel {
        MachineModel {
            name: "modern",
            l1: CacheParams::new(12, 64, 8),      // 6144 words = 48 KB
            l2: Some(CacheParams::new(16, 1024, 8)), // 131072 words = 1 MB
            tlb: Some(TlbParams { entries: 1536, page_words: 512 }),
            // prefetch ≈ 3/4 of the memory trip: modern cores overlap a
            // timely T0 prefetch with the fold almost entirely, but DRAM
            // queueing keeps some exposure; remote ≈ 3× local DRAM for a
            // cross-socket line
            latency: Latency { l2: 14, mem: 220, tlb: 30, prefetch: 160, remote: 660 },
        }
    }

    /// Look up a named preset (see [`MachineModel::preset_names`]).
    pub fn preset(name: &str) -> Option<MachineModel> {
        match name {
            "r10000" => Some(MachineModel::r10000()),
            "r10000-full" => Some(MachineModel::r10000_full()),
            "modern" => Some(MachineModel::modern()),
            _ => None,
        }
    }

    /// Names accepted by [`MachineModel::preset`] / the CLI `--machine=`.
    pub fn preset_names() -> &'static [&'static str] {
        &["r10000", "r10000-full", "modern"]
    }

    /// Does this machine simulate anything beyond the L1?
    pub fn is_hierarchical(&self) -> bool {
        self.l2.is_some() || self.tlb.is_some()
    }

    /// Scratch capacity for cache-resident tiling: the deepest *cache*
    /// level's size in words (L2 when present, else L1). The TLB is
    /// deliberately skipped even when it is the deepest level the machine
    /// exposes — its span is translation *reach* over memory that still
    /// misses the real caches, so sizing a working set to page reach on a
    /// TLB-but-no-L2 machine would thrash the only cache that exists.
    pub fn scratch_words(&self) -> usize {
        self.l2.as_ref().map_or(self.l1.size_words(), |c| c.size_words())
    }

    /// The TLB's reach in words (`entries · page_words`) — the modulus of
    /// the **page interference lattice**, the TLB analog of
    /// [`CacheParams::lattice_modulus`]: under the capacity-modulus
    /// convention of Eq 8, grid strides congruent modulo the TLB span
    /// contend for the same translation reach.
    pub fn page_modulus(&self) -> Option<usize> {
        self.tlb.map(|t| t.span_words())
    }

    /// The software-prefetch distance (in words ahead of the current
    /// chunk) the planner hands to `engine::kernel`: enough whole L1
    /// lines to cover the memory latency at ~2 cycles of fold work per
    /// streamed word, clamped to [1, 16] lines. Deterministic in the
    /// descriptor — machines whose [`Latency::prefetch`] is 0 (the paper's
    /// R10000) get 0, so their kernels issue no prefetch and their stall
    /// estimate stays exact.
    pub fn prefetch_distance(&self) -> usize {
        if self.latency.prefetch == 0 {
            return 0;
        }
        let lw = self.l1.line_words;
        let per_line = 2 * lw as u64;
        let lines = (self.latency.mem.div_ceil(per_line) as usize).clamp(1, 16);
        lines * lw
    }

    /// Build the hierarchy simulator for this machine (requires at least
    /// one level beyond L1 — single-level machines use [`CacheSim`]).
    pub fn build_hierarchy(&self) -> Hierarchy {
        assert!(self.is_hierarchical(), "single-level machine: use CacheSim::new(self.l1)");
        Hierarchy::with_levels(self.l1, self.l2, self.tlb)
    }

    /// Build the memory model as a trait object — the generic composition
    /// point. Hot paths that care about monomorphized access loops should
    /// branch on [`MachineModel::is_hierarchical`] instead.
    pub fn build_model(&self) -> Box<dyn MemoryModel + Send> {
        if self.is_hierarchical() {
            Box::new(self.build_hierarchy())
        } else {
            Box::new(CacheSim::new(self.l1))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_resolve_and_list() {
        for &name in MachineModel::preset_names() {
            let m = MachineModel::preset(name).unwrap();
            assert_eq!(m.name, name);
        }
        assert!(MachineModel::preset("r20000").is_none());
    }

    #[test]
    fn r10000_presets_match_paper_geometry() {
        let single = MachineModel::r10000();
        assert!(!single.is_hierarchical());
        assert_eq!(single.l1.size_words(), 4096);
        assert!(single.page_modulus().is_none());
        let full = MachineModel::r10000_full();
        assert!(full.is_hierarchical());
        assert_eq!(full.l1, single.l1);
        assert_eq!(full.l2.unwrap().size_words(), 512 * 1024);
        assert_eq!(full.page_modulus(), Some(64 * 512)); // 256 KB reach
    }

    #[test]
    fn modern_geometry_sane() {
        let m = MachineModel::modern();
        assert_eq!(m.l1.size_words(), 6144);
        assert_eq!(m.l2.unwrap().size_words(), 131072);
        assert!(m.l2.unwrap().size_words() > m.l1.size_words());
        assert_eq!(m.page_modulus(), Some(1536 * 512));
    }

    #[test]
    fn build_model_matches_levels() {
        let mut single = MachineModel::r10000().build_model();
        single.access(0);
        assert_eq!(single.profile().levels().len(), 1);
        let mut full = MachineModel::r10000_full().build_model();
        full.access(0);
        let p = full.profile();
        assert_eq!(p.levels().len(), 3);
        assert!(p.get(Level::L2).is_some());
        assert!(p.get(Level::Tlb).is_some());
    }

    #[test]
    fn cache_sim_profile_is_its_stats() {
        let mut sim = CacheSim::new(CacheParams::new(1, 4, 1));
        for a in [0u64, 4, 0, 1] {
            MemoryModel::access(&mut sim, a);
        }
        let p = sim.profile();
        assert_eq!(p.levels().len(), 1);
        assert_eq!(p.get(Level::L1).unwrap(), sim.stats());
        assert_eq!(sim.l1_stats(), sim.stats());
    }

    #[test]
    fn profile_delta_and_merge_roundtrip() {
        let machine = MachineModel::r10000_full();
        let mut model = machine.build_model();
        for a in 0..3000u64 {
            model.access(a * 7 % 2048);
        }
        let mid = model.profile();
        for a in 0..3000u64 {
            model.access(a * 13 % 8192);
        }
        let end = model.profile();
        let tail = LoadProfile::delta(&end, &mid);
        let mut merged = mid;
        merged.merge(&tail);
        assert_eq!(merged, end);
        // empty profile adopts the other side
        let mut empty = LoadProfile::default();
        empty.merge(&end);
        assert_eq!(empty, end);
    }

    #[test]
    fn stall_cycles_shapes() {
        let lat = Latency { l2: 10, mem: 100, tlb: 50, prefetch: 0, remote: 300 };
        let one = CacheStats { cold_misses: 2, ..CacheStats::default() };
        // single level: misses go straight to memory
        assert_eq!(LoadProfile::single(one).stall_cycles(lat), 200);
        // three levels: L1 → l2 lat, L2 → mem, TLB → refill
        let mut p = LoadProfile::default();
        p.push(Level::L1, one);
        p.push(Level::L2, CacheStats { replacement_misses: 1, ..CacheStats::default() });
        p.push(Level::Tlb, CacheStats { cold_misses: 3, ..CacheStats::default() });
        assert_eq!(p.stall_cycles(lat), 2 * 10 + 100 + 3 * 50);
    }

    #[test]
    fn prefetched_stalls_discount_memory_cold_misses_only() {
        let lat = Latency { l2: 10, mem: 100, tlb: 50, prefetch: 60, remote: 300 };
        // single level: 2 cold + 1 replacement miss → 300 cycles base;
        // prefetch hides 60 of each *cold* miss only
        let one = CacheStats { cold_misses: 2, replacement_misses: 1, ..CacheStats::default() };
        let p = LoadProfile::single(one);
        assert_eq!(p.stall_cycles(lat), 300);
        assert_eq!(p.stall_cycles_prefetched(lat, 64), 300 - 2 * 60);
        // distance 0 or prefetch term 0 → exactly the base estimate
        assert_eq!(p.stall_cycles_prefetched(lat, 0), 300);
        let dead = Latency { prefetch: 0, ..lat };
        assert_eq!(p.stall_cycles_prefetched(dead, 64), 300);
        // hierarchical: only the L2's (memory-boundary) cold misses count
        let mut h = LoadProfile::default();
        h.push(Level::L1, CacheStats { cold_misses: 5, ..CacheStats::default() });
        h.push(Level::L2, CacheStats { cold_misses: 3, replacement_misses: 2, ..CacheStats::default() });
        assert_eq!(h.stall_cycles_prefetched(lat, 64), h.stall_cycles(lat) - 3 * 60);
        // the discount is capped at the full memory trip
        let wild = Latency { prefetch: 10_000, ..lat };
        assert_eq!(p.stall_cycles_prefetched(wild, 64), 300 - 2 * 100);
    }

    #[test]
    fn prefetch_distance_is_deterministic_per_preset() {
        // r10000: prefetch term 0 → no distance, stall estimate exact
        assert_eq!(MachineModel::r10000().prefetch_distance(), 0);
        assert_eq!(MachineModel::r10000_full().prefetch_distance(), 0);
        // modern: ceil(220 / (2·8)) = 14 lines of 8 words
        assert_eq!(MachineModel::modern().prefetch_distance(), 14 * 8);
    }

    #[test]
    #[should_panic(expected = "different models")]
    fn delta_rejects_mismatched_levels() {
        let a = LoadProfile::single(CacheStats::default());
        let mut b = LoadProfile::default();
        b.push(Level::L1, CacheStats::default());
        b.push(Level::Tlb, CacheStats::default());
        let _ = LoadProfile::delta(&b, &a);
    }
}
