//! Two-level cache + TLB simulation — the paper's §7 "future work"
//! extension ("we plan ... to take into account a secondary cache and TLB").
//!
//! The hierarchy is inclusive and write-allocate like the R10000/Origin2000:
//! an L1 miss probes L2; a TLB is a small fully-associative LRU cache over
//! virtual pages. We reuse [`CacheSim`] for every level — a TLB *is* a
//! cache of page numbers. Levels beyond L1 are optional
//! ([`Hierarchy::with_levels`]) so a [`super::MachineModel`] can describe
//! any subset; the preset constructors keep the full R10000 shape.

use super::{AccessKind, CacheParams, CacheSim, LoadProfile};

/// TLB geometry: `entries` fully-associative entries over pages of
/// `page_words` words (R10000: 64 dual entries over 4 KB pages ⇒ model as
/// 64 entries × 512 words).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct TlbParams {
    pub entries: usize,
    pub page_words: usize,
}

impl TlbParams {
    pub fn r10000() -> TlbParams {
        TlbParams { entries: 64, page_words: 512 }
    }

    /// The TLB's reach in words: `entries · page_words`.
    pub fn span_words(&self) -> usize {
        self.entries * self.page_words
    }
}

/// Aggregated statistics for a hierarchical access stream.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct HierarchyStats {
    pub accesses: u64,
    pub l1_misses: u64,
    pub l2_misses: u64,
    pub tlb_misses: u64,
}

impl HierarchyStats {
    /// Approximate stall cycles with a simple additive latency model
    /// (hit costs folded into CPI): L1 miss → `l2_lat`, L2 miss → `mem_lat`,
    /// TLB miss → `tlb_lat` (software-refill on MIPS).
    ///
    /// This prices every L1 miss at `l2_lat`, which assumes an L2 exists;
    /// for a hierarchy built without one ([`Hierarchy::with_levels`]) pass
    /// `l2_lat = mem_lat`, or use the level-aware
    /// [`super::LoadProfile::stall_cycles`] (via
    /// [`super::MemoryModel::profile`]), which prices L1 misses at memory
    /// latency when no L2 level is present.
    pub fn stall_cycles(&self, l2_lat: u64, mem_lat: u64, tlb_lat: u64) -> u64 {
        self.l1_misses * l2_lat + self.l2_misses * mem_lat + self.tlb_misses * tlb_lat
    }

    /// Merge shard snapshots by summing every counter — the hierarchical
    /// twin of `MissReport::merged`, so sharded runs over per-shard
    /// hierarchies can combine their per-level totals.
    pub fn merged(reports: &[HierarchyStats]) -> HierarchyStats {
        let mut out = HierarchyStats::default();
        for r in reports {
            out.accesses += r.accesses;
            out.l1_misses += r.l1_misses;
            out.l2_misses += r.l2_misses;
            out.tlb_misses += r.tlb_misses;
        }
        out
    }

    /// Counter-wise difference `post − pre` of two cumulative snapshots of
    /// one hierarchy — the twin of [`super::CacheStats::delta`], for
    /// incremental per-range reports over a shared warm hierarchy.
    pub fn delta(post: HierarchyStats, pre: HierarchyStats) -> HierarchyStats {
        HierarchyStats {
            accesses: post.accesses - pre.accesses,
            l1_misses: post.l1_misses - pre.l1_misses,
            l2_misses: post.l2_misses - pre.l2_misses,
            tlb_misses: post.tlb_misses - pre.tlb_misses,
        }
    }
}

/// L1 + optional L2 + optional TLB simulator.
pub struct Hierarchy {
    l1: CacheSim,
    l2: Option<CacheSim>,
    tlb: Option<CacheSim>,
    tlb_page_shift: u32,
    stats: HierarchyStats,
}

impl Hierarchy {
    pub fn new(l1: CacheParams, l2: CacheParams, tlb: TlbParams) -> Hierarchy {
        Hierarchy::with_levels(l1, Some(l2), Some(tlb))
    }

    /// Build with any subset of levels beyond L1 (the
    /// [`super::MachineModel`] construction point).
    pub fn with_levels(l1: CacheParams, l2: Option<CacheParams>, tlb: Option<TlbParams>) -> Hierarchy {
        if let Some(t) = tlb {
            assert!(t.page_words.is_power_of_two(), "page size must be a power of two");
        }
        if let Some(l2) = l2 {
            assert!(l2.size_words() >= l1.size_words(), "L2 must not be smaller than L1");
        }
        Hierarchy {
            l1: CacheSim::new(l1),
            l2: l2.map(CacheSim::new),
            // model TLB as a fully-associative cache of 1-word lines over
            // page numbers.
            tlb: tlb.map(|t| CacheSim::new(CacheParams::fully_associative(t.entries, 1))),
            tlb_page_shift: tlb.map(|t| t.page_words.trailing_zeros()).unwrap_or(0),
            stats: HierarchyStats::default(),
        }
    }

    /// The paper's platform with a 4 MB unified L2 (R10000 Origin 2000):
    /// L1 (2,512,4), L2 2-way, 16-word (128 B) lines, 512K words.
    pub fn r10000() -> Hierarchy {
        Hierarchy::new(
            CacheParams::r10000(),
            CacheParams::new(2, 16 * 1024, 16), // 2*16384*16 = 512K words = 4MB
            TlbParams::r10000(),
        )
    }

    pub fn stats(&self) -> HierarchyStats {
        self.stats
    }

    pub fn l1_stats(&self) -> super::CacheStats {
        self.l1.stats()
    }

    /// L2 §2 counters (zeroed when the hierarchy has no L2).
    pub fn l2_stats(&self) -> super::CacheStats {
        self.l2.as_ref().map(|c| c.stats()).unwrap_or_default()
    }

    /// TLB §2 counters over the *page-number* stream (zeroed when the
    /// hierarchy has no TLB): `accesses` is one probe per word access,
    /// `misses()` is page walks.
    pub fn tlb_stats(&self) -> super::CacheStats {
        self.tlb.as_ref().map(|c| c.stats()).unwrap_or_default()
    }

    /// Level-aware stall estimate for this hierarchy's actual shape:
    /// delegates to [`super::LoadProfile::stall_cycles`], which prices L1
    /// misses at memory latency when this hierarchy has no L2 (unlike the
    /// raw [`HierarchyStats::stall_cycles`] formula, which assumes one).
    pub fn stall_cycles(&self, lat: super::Latency) -> u64 {
        self.profile().stall_cycles(lat)
    }

    /// Cumulative per-level profile, in probe order.
    pub fn profile(&self) -> LoadProfile {
        let mut p = LoadProfile::default();
        p.push(super::Level::L1, self.l1.stats());
        if let Some(l2) = &self.l2 {
            p.push(super::Level::L2, l2.stats());
        }
        if let Some(tlb) = &self.tlb {
            p.push(super::Level::Tlb, tlb.stats());
        }
        p
    }

    pub fn reset(&mut self) {
        self.l1.reset();
        if let Some(l2) = &mut self.l2 {
            l2.reset();
        }
        if let Some(tlb) = &mut self.tlb {
            tlb.reset();
        }
        self.stats = HierarchyStats::default();
    }

    /// One word access through TLB → L1 → (on miss) L2.
    #[inline]
    pub fn access(&mut self, addr: u64) -> AccessKind {
        self.stats.accesses += 1;
        if let Some(tlb) = &mut self.tlb {
            if tlb.access(addr >> self.tlb_page_shift) != AccessKind::Hit {
                self.stats.tlb_misses += 1;
            }
        }
        let k1 = self.l1.access(addr);
        if k1 != AccessKind::Hit {
            self.stats.l1_misses += 1;
            if let Some(l2) = &mut self.l2 {
                if l2.access(addr) != AccessKind::Hit {
                    self.stats.l2_misses += 1;
                }
            }
        }
        k1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Hierarchy {
        Hierarchy::new(
            CacheParams::new(1, 4, 1),  // 4-word L1
            CacheParams::new(1, 16, 1), // 16-word L2
            TlbParams { entries: 2, page_words: 8 },
        )
    }

    #[test]
    fn l2_absorbs_l1_conflicts() {
        let mut h = tiny();
        // 0 and 4 conflict in L1 (4 sets) but not in L2 (16 sets).
        h.access(0);
        h.access(4);
        h.access(0);
        h.access(4);
        let s = h.stats();
        assert_eq!(s.l1_misses, 4); // every access misses L1
        assert_eq!(s.l2_misses, 2); // only cold misses reach memory
    }

    #[test]
    fn tlb_counts_page_walks() {
        let mut h = tiny();
        // 3 pages touched with 2 TLB entries, round-robin → thrash.
        for _ in 0..3 {
            h.access(0); // page 0
            h.access(8); // page 1
            h.access(16); // page 2
        }
        assert!(h.stats().tlb_misses > 3, "tlb misses: {}", h.stats().tlb_misses);
        assert_eq!(h.tlb_stats().misses(), h.stats().tlb_misses);
    }

    #[test]
    fn hits_do_not_touch_l2() {
        let mut h = tiny();
        h.access(0);
        h.access(0);
        h.access(0);
        assert_eq!(h.stats().l1_misses, 1);
        assert_eq!(h.l2_stats().accesses, 1);
    }

    #[test]
    fn stall_model_monotonic() {
        let mut h = tiny();
        for a in 0..32u64 {
            h.access(a);
        }
        let s = h.stats();
        assert!(s.stall_cycles(10, 100, 50) >= s.stall_cycles(1, 1, 1));
    }

    #[test]
    fn level_aware_stall_prices_l1_misses_at_memory_without_l2() {
        use super::super::Latency;
        let lat = Latency { l2: 10, mem: 80, tlb: 50, prefetch: 0, remote: 240 };
        // L1-only hierarchy: every miss goes straight to memory.
        let mut h = Hierarchy::with_levels(CacheParams::new(1, 4, 1), None, None);
        for a in [0u64, 4, 0, 4] {
            h.access(a);
        }
        assert_eq!(h.stall_cycles(lat), h.stats().l1_misses * lat.mem);
        // with an L2 the delegator matches the raw additive formula
        let mut full = tiny();
        for a in 0..32u64 {
            full.access(a);
        }
        assert_eq!(full.stall_cycles(lat), full.stats().stall_cycles(lat.l2, lat.mem, lat.tlb));
    }

    #[test]
    fn r10000_hierarchy_constructs() {
        let mut h = Hierarchy::r10000();
        for a in 0..10_000u64 {
            h.access(a % 5000);
        }
        assert!(h.stats().l2_misses <= h.stats().l1_misses);
    }

    #[test]
    fn partial_hierarchies_skip_absent_levels() {
        // L1-only hierarchy behaves like a bare CacheSim with zeroed
        // L2/TLB counters.
        let mut l1_only = Hierarchy::with_levels(CacheParams::new(1, 4, 1), None, None);
        let mut solo = CacheSim::new(CacheParams::new(1, 4, 1));
        for a in [0u64, 4, 0, 1, 5, 1] {
            assert_eq!(l1_only.access(a), solo.access(a));
        }
        assert_eq!(l1_only.l1_stats(), solo.stats());
        assert_eq!(l1_only.stats().l2_misses, 0);
        assert_eq!(l1_only.stats().tlb_misses, 0);
        assert_eq!(l1_only.l2_stats(), super::super::CacheStats::default());
        assert_eq!(l1_only.profile().levels().len(), 1);
        // L1 + TLB, no L2: TLB still walks pages, l2_misses stays zero.
        let mut no_l2 =
            Hierarchy::with_levels(CacheParams::new(1, 4, 1), None, Some(TlbParams { entries: 2, page_words: 8 }));
        for a in [0u64, 8, 16, 0] {
            no_l2.access(a);
        }
        assert!(no_l2.stats().tlb_misses >= 3);
        assert_eq!(no_l2.stats().l2_misses, 0);
        assert_eq!(no_l2.profile().levels().len(), 2);
    }

    #[test]
    fn stats_merged_sums_and_delta_inverts() {
        // Run one stream in two halves on separate hierarchies (the shard
        // picture): merged() must sum counters exactly. Then on a single
        // warm hierarchy, delta(end, mid) + mid must reproduce end.
        let mut a = tiny();
        let mut b = tiny();
        for x in 0..64u64 {
            a.access(x % 24);
        }
        for x in 0..64u64 {
            b.access((x * 5) % 40);
        }
        let m = HierarchyStats::merged(&[a.stats(), b.stats()]);
        assert_eq!(m.accesses, a.stats().accesses + b.stats().accesses);
        assert_eq!(m.l1_misses, a.stats().l1_misses + b.stats().l1_misses);
        assert_eq!(m.l2_misses, a.stats().l2_misses + b.stats().l2_misses);
        assert_eq!(m.tlb_misses, a.stats().tlb_misses + b.stats().tlb_misses);

        let mut h = tiny();
        for x in 0..32u64 {
            h.access(x % 24);
        }
        let mid = h.stats();
        for x in 0..32u64 {
            h.access((x * 3) % 40);
        }
        let end = h.stats();
        let tail = HierarchyStats::delta(end, mid);
        assert_eq!(HierarchyStats::merged(&[mid, tail]), end);
    }
}
