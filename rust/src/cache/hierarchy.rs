//! Two-level cache + TLB simulation — the paper's §7 "future work"
//! extension ("we plan ... to take into account a secondary cache and TLB").
//!
//! The hierarchy is inclusive and write-allocate like the R10000/Origin2000:
//! an L1 miss probes L2; a TLB is a small fully-associative LRU cache over
//! virtual pages. We reuse [`CacheSim`] for every level — a TLB *is* a
//! cache of page numbers.

use super::{AccessKind, CacheParams, CacheSim};

/// TLB geometry: `entries` fully-associative entries over pages of
/// `page_words` words (R10000: 64 dual entries over 4 KB pages ⇒ model as
/// 64 entries × 512 words).
#[derive(Debug, Clone, Copy)]
pub struct TlbParams {
    pub entries: usize,
    pub page_words: usize,
}

impl TlbParams {
    pub fn r10000() -> TlbParams {
        TlbParams { entries: 64, page_words: 512 }
    }
}

/// Aggregated statistics for a hierarchical access stream.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct HierarchyStats {
    pub accesses: u64,
    pub l1_misses: u64,
    pub l2_misses: u64,
    pub tlb_misses: u64,
}

impl HierarchyStats {
    /// Approximate stall cycles with a simple additive latency model
    /// (hit costs folded into CPI): L1 miss → `l2_lat`, L2 miss → `mem_lat`,
    /// TLB miss → `tlb_lat` (software-refill on MIPS).
    pub fn stall_cycles(&self, l2_lat: u64, mem_lat: u64, tlb_lat: u64) -> u64 {
        self.l1_misses * l2_lat + self.l2_misses * mem_lat + self.tlb_misses * tlb_lat
    }
}

/// L1 + L2 + TLB simulator.
pub struct Hierarchy {
    l1: CacheSim,
    l2: CacheSim,
    tlb: CacheSim,
    tlb_page_shift: u32,
    stats: HierarchyStats,
}

impl Hierarchy {
    pub fn new(l1: CacheParams, l2: CacheParams, tlb: TlbParams) -> Hierarchy {
        assert!(tlb.page_words.is_power_of_two(), "page size must be a power of two");
        assert!(l2.size_words() >= l1.size_words(), "L2 must not be smaller than L1");
        Hierarchy {
            l1: CacheSim::new(l1),
            l2: CacheSim::new(l2),
            // model TLB as a fully-associative cache of 1-word lines over
            // page numbers.
            tlb: CacheSim::new(CacheParams::fully_associative(tlb.entries, 1)),
            tlb_page_shift: tlb.page_words.trailing_zeros(),
            stats: HierarchyStats::default(),
        }
    }

    /// The paper's platform with a 4 MB unified L2 (R10000 Origin 2000):
    /// L1 (2,512,4), L2 2-way, 16-word (128 B) lines, 512K words.
    pub fn r10000() -> Hierarchy {
        Hierarchy::new(
            CacheParams::r10000(),
            CacheParams::new(2, 16 * 1024, 16), // 2*16384*16 = 512K words = 4MB
            TlbParams::r10000(),
        )
    }

    pub fn stats(&self) -> HierarchyStats {
        self.stats
    }

    pub fn l1_stats(&self) -> super::CacheStats {
        self.l1.stats()
    }

    pub fn l2_stats(&self) -> super::CacheStats {
        self.l2.stats()
    }

    pub fn reset(&mut self) {
        self.l1.reset();
        self.l2.reset();
        self.tlb.reset();
        self.stats = HierarchyStats::default();
    }

    /// One word access through TLB → L1 → (on miss) L2.
    #[inline]
    pub fn access(&mut self, addr: u64) -> AccessKind {
        self.stats.accesses += 1;
        if self.tlb.access(addr >> self.tlb_page_shift) != AccessKind::Hit {
            self.stats.tlb_misses += 1;
        }
        let k1 = self.l1.access(addr);
        if k1 != AccessKind::Hit {
            self.stats.l1_misses += 1;
            if self.l2.access(addr) != AccessKind::Hit {
                self.stats.l2_misses += 1;
            }
        }
        k1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Hierarchy {
        Hierarchy::new(
            CacheParams::new(1, 4, 1),  // 4-word L1
            CacheParams::new(1, 16, 1), // 16-word L2
            TlbParams { entries: 2, page_words: 8 },
        )
    }

    #[test]
    fn l2_absorbs_l1_conflicts() {
        let mut h = tiny();
        // 0 and 4 conflict in L1 (4 sets) but not in L2 (16 sets).
        h.access(0);
        h.access(4);
        h.access(0);
        h.access(4);
        let s = h.stats();
        assert_eq!(s.l1_misses, 4); // every access misses L1
        assert_eq!(s.l2_misses, 2); // only cold misses reach memory
    }

    #[test]
    fn tlb_counts_page_walks() {
        let mut h = tiny();
        // 3 pages touched with 2 TLB entries, round-robin → thrash.
        for _ in 0..3 {
            h.access(0); // page 0
            h.access(8); // page 1
            h.access(16); // page 2
        }
        assert!(h.stats().tlb_misses > 3, "tlb misses: {}", h.stats().tlb_misses);
    }

    #[test]
    fn hits_do_not_touch_l2() {
        let mut h = tiny();
        h.access(0);
        h.access(0);
        h.access(0);
        assert_eq!(h.stats().l1_misses, 1);
        assert_eq!(h.l2_stats().accesses, 1);
    }

    #[test]
    fn stall_model_monotonic() {
        let mut h = tiny();
        for a in 0..32u64 {
            h.access(a);
        }
        let s = h.stats();
        assert!(s.stall_cycles(10, 100, 50) >= s.stall_cycles(1, 1, 1));
    }

    #[test]
    fn r10000_hierarchy_constructs() {
        let mut h = Hierarchy::r10000();
        for a in 0..10_000u64 {
            h.access(a % 5000);
        }
        assert!(h.stats().l2_misses <= h.stats().l1_misses);
    }
}
