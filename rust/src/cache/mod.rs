//! The cache model of §2 of the paper, as an executable simulator.
//!
//! The paper considers a single-level, virtual-address-mapped,
//! set-associative data cache characterized by the triplet `(a, z, w)`:
//! `a` ways of associativity, `z` sets, lines of `w` words. A word
//! at virtual address `A` maps to line offset `w(A) = A mod w` and set
//! `z(A) = (A/w) mod z`; the way is chosen by LRU replacement.
//!
//! Terminology (paper §2, reproduced exactly):
//! - **cache miss**: a request for a word not present in the cache at the
//!   time of the request;
//! - **cold load**: an explicit request for a word for which no explicit
//!   request has been made previously;
//! - **replacement load**: a request for a word whose residence has expired
//!   because another word was loaded into the same cache location.
//!
//! For `w = 1` misses and loads coincide; in general `μ ≤ w·φ` and for a
//! non-redundant stencil `φ ≤ |K|·μ` (the “interval inequality” of §2).
//!
//! The reference machine in the paper is the MIPS R10000 L1 data cache:
//! `(a, z, w) = (2, 512, 4)`, i.e. `S = 4096` double-precision words (32 KB);
//! [`CacheParams::r10000`] reproduces it.

mod hierarchy;
mod model;
mod sim;

pub use hierarchy::{Hierarchy, HierarchyStats, TlbParams};
pub use model::{Latency, Level, LevelLoad, LoadProfile, MachineModel, MemoryModel, MAX_LEVELS};
pub use sim::{AccessKind, CacheSim, CacheStats};

/// Cache geometry `(a, z, w)`; all sizes in *words* (one word = one f64).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct CacheParams {
    /// Associativity (ways per set); `a = 1` is direct-mapped.
    pub assoc: usize,
    /// Number of sets.
    pub sets: usize,
    /// Words per cache line.
    pub line_words: usize,
}

impl CacheParams {
    pub fn new(assoc: usize, sets: usize, line_words: usize) -> CacheParams {
        assert!(assoc >= 1 && sets >= 1 && line_words >= 1, "degenerate cache geometry");
        assert!(sets.is_power_of_two(), "sets must be a power of two (hardware index bits)");
        assert!(line_words.is_power_of_two(), "line size must be a power of two");
        CacheParams { assoc, sets, line_words }
    }

    /// The paper's measurement platform: MIPS R10000 32 KB L1 D-cache,
    /// 2-way, 512 sets, 4 doubles per line → S = 4096 words.
    pub fn r10000() -> CacheParams {
        CacheParams::new(2, 512, 4)
    }

    /// Fully associative cache of capacity `s` words with line size `w`.
    pub fn fully_associative(s: usize, w: usize) -> CacheParams {
        assert!(s % w == 0);
        CacheParams { assoc: s / w, sets: 1, line_words: w }
    }

    /// Direct-mapped cache of `z` sets and `w` words per line.
    pub fn direct_mapped(sets: usize, line_words: usize) -> CacheParams {
        CacheParams::new(1, sets, line_words)
    }

    /// Total capacity `S = a·z·w` in words. This is the `S` appearing in all
    /// of the paper's bounds and in the interference-lattice definition
    /// (Eq 8), which uses the capacity *per way footprint* of the address
    /// map: addresses `A` and `A + z·w·k` collide in the same set.
    pub fn size_words(&self) -> usize {
        self.assoc * self.sets * self.line_words
    }

    /// The address-collision period `z·w`: two addresses map to the same set
    /// iff they differ by a multiple of `z·w` words (for aligned words also
    /// the same line offset iff multiple of `w`).
    pub fn way_words(&self) -> usize {
        self.sets * self.line_words
    }

    /// Set index of word address `A`: `(A / w) mod z`.
    #[inline]
    pub fn set_of(&self, addr: u64) -> usize {
        ((addr / self.line_words as u64) % self.sets as u64) as usize
    }

    /// Line number of word address `A`: `A / w`.
    #[inline]
    pub fn line_of(&self, addr: u64) -> u64 {
        addr / self.line_words as u64
    }

    /// The lattice modulus used by the paper's interference lattice (Eq 8).
    ///
    /// The paper states the lattice as arrays colliding mod `S`; for an
    /// `a`-way cache the set index repeats with period `z·w = S/a`, and the
    /// paper's R10000 analysis uses S with a=2 absorbing the two ways.
    /// We follow the paper: modulus = S (capacity), with associativity
    /// handled by its `diameter/a` short-vector criterion.
    pub fn lattice_modulus(&self) -> usize {
        self.size_words()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn r10000_geometry() {
        let p = CacheParams::r10000();
        assert_eq!(p.size_words(), 4096);
        assert_eq!(p.way_words(), 2048);
        assert_eq!(p.lattice_modulus(), 4096);
    }

    #[test]
    fn address_mapping_matches_paper_formulas() {
        let p = CacheParams::new(2, 512, 4);
        // w(A) = A mod 4 — line offset implicit; z(A) = (A/4) mod 512.
        assert_eq!(p.set_of(0), 0);
        assert_eq!(p.set_of(3), 0);
        assert_eq!(p.set_of(4), 1);
        assert_eq!(p.set_of(4 * 512), 0); // wraps after z lines
        assert_eq!(p.line_of(7), 1);
    }

    #[test]
    fn fully_associative_has_one_set() {
        let p = CacheParams::fully_associative(1024, 4);
        assert_eq!(p.sets, 1);
        assert_eq!(p.assoc, 256);
        assert_eq!(p.size_words(), 1024);
        assert_eq!(p.set_of(12345), 0);
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn non_power_of_two_sets_rejected() {
        let _ = CacheParams::new(1, 100, 4);
    }
}
