//! # stencilcache
//!
//! A production-quality reproduction of *“Efficient cache use for stencil
//! operations on structured discretization grids”* (M. A. Frumkin &
//! R. F. Van der Wijngaart, NASA Ames, 2000).
//!
//! The paper bounds the number of cache loads needed to evaluate an explicit
//! stencil operator `q = Ku` on a structured grid, gives a **cache fitting
//! algorithm** — a traversal order built from a reduced basis of the grid's
//! **interference lattice** — that approaches the lower bound, and shows that
//! grids whose interference lattice contains a *short vector* (empirically:
//! `n1·n2 ≈ k·S/2`) suffer anomalously many misses and should be padded.
//!
//! ## Architecture (three layers)
//!
//! - **L3 (this crate)**: cache model + simulator, interference-lattice
//!   machinery, **streaming traversal engine** (lazy pencil-at-a-time visit
//!   orders — see [`traversal::Traversal`] — sharded across the worker pool
//!   for large grids), bounds, padding advisor, the **memoizing serving
//!   layer** (an S3-FIFO plan/analysis cache behind the coordinator plus
//!   the long-lived [`coordinator::Service`] — see DESIGN.md §2.8 and
//!   `experiments::replay`), the **native numeric backend** ([`solver`]:
//!   real stencil FLOPs over
//!   the planner's traversal, no XLA required), and the PJRT runtime that
//!   executes AOT-compiled artifacts (behind the `pjrt` cargo feature; the
//!   coordinator falls back to the native backend without it).
//! - **L2 (python/compile/model.py, build-time)**: the stencil compute graph
//!   in JAX, lowered once to HLO text in `artifacts/`.
//! - **L1 (python/compile/kernels/, build-time)**: Pallas stencil kernels
//!   (interpret=True) with block shapes chosen by the paper's
//!   surface-to-volume criterion.
//!
//! See `DESIGN.md` (repository root) for the experiment index and
//! `EXPERIMENTS.md` (repository root) for paper-vs-measured results.

// The numeric kernels (LLL, Gauss–Jordan, odometer sweeps) index several
// parallel buffers per loop; rewriting them as zip chains hurts more than
// it helps. Everything else clippy flags is fixed, not allowed.
#![allow(clippy::needless_range_loop)]

pub mod bounds;
pub mod cache;
pub mod coordinator;
pub mod engine;
pub mod experiments;
pub mod grid;
pub mod lattice;
pub mod padding;
pub mod report;
pub mod runtime;
pub mod shard;
pub mod solver;
pub mod stencil;
pub mod traversal;
pub mod tuner;
pub mod util;

/// Crate version string.
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
