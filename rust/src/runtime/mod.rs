//! PJRT runtime: load AOT-compiled HLO artifacts and execute them.
//!
//! This is the only place the crate touches the `xla` crate, and the whole
//! backend is gated behind the **`pjrt`** cargo feature (off by default —
//! the feature additionally requires the `xla` (xla-rs) crate, which is
//! not part of the offline dependency set; see `rust/Cargo.toml`). Without
//! the feature, [`Runtime`] is a stub whose constructors fail with a clear
//! error, so the coordinator degrades to analysis-only serving and every
//! Execute/Solve request reports the missing backend instead of failing to
//! build. The pipeline when enabled:
//!
//! ```text
//! artifacts/<name>.hlo.txt  ──HloModuleProto::from_text_file──▶ proto
//!   ──XlaComputation::from_proto──▶ computation
//!   ──PjRtClient::compile──▶ PjRtLoadedExecutable   (cached per name)
//!   ──execute(literals)──▶ output tuple
//! ```
//!
//! HLO *text* is the interchange format: jax ≥ 0.5 serialized protos carry
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see python/compile/aot.py).
//!
//! All artifacts are lowered with `return_tuple=True`, so every execution
//! returns a tuple literal; [`Runtime::execute`] decomposes it.

mod manifest;
mod service;

pub use manifest::{ArtifactInfo, Manifest};
pub use service::{RuntimeHandle, RuntimeService};

#[cfg(feature = "pjrt")]
use anyhow::anyhow;
use anyhow::{bail, Context, Result};
#[cfg(feature = "pjrt")]
use std::collections::HashMap;
use std::path::Path;
#[cfg(feature = "pjrt")]
use std::path::PathBuf;
#[cfg(feature = "pjrt")]
use std::sync::Mutex;

/// A host-side tensor: f32 data plus dims. The runtime's lingua franca
/// between the engine/coordinator and PJRT.
#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

impl HostTensor {
    pub fn new(dims: Vec<usize>, data: Vec<f32>) -> Result<HostTensor> {
        let n: usize = dims.iter().product();
        if n != data.len() {
            bail!("dims {:?} need {} elements, got {}", dims, n, data.len());
        }
        Ok(HostTensor { dims, data })
    }

    pub fn zeros(dims: &[usize]) -> HostTensor {
        HostTensor { dims: dims.to_vec(), data: vec![0.0; dims.iter().product()] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// L2 norm (for convergence logging).
    pub fn norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }
}

/// The PJRT runtime: CPU client + artifact registry + executable cache.
///
/// Compilation happens at most once per artifact (guarded by a mutex-held
/// cache); execution needs no lock beyond the cache lookup.
#[cfg(feature = "pjrt")]
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

/// Stub runtime used when the crate is built without the `pjrt` feature:
/// constructors fail with a descriptive error, so callers degrade
/// gracefully — the coordinator serves Execute/Solve requests on the
/// native numeric backend ([`crate::solver::NativeBackend`]) instead.
#[cfg(not(feature = "pjrt"))]
pub struct Runtime {
    manifest: Manifest,
}

/// Load and validate the manifest of an artifact directory (shared by the
/// real and stub backends, so discovery/validation can never diverge).
fn load_manifest(dir: &Path) -> Result<Manifest> {
    Manifest::load(&dir.join("manifest.json"))
        .with_context(|| format!("loading manifest from {dir:?}; run `make artifacts` first"))
}

// Backend-independent surface: artifact discovery and metadata.
impl Runtime {
    /// Locate the repository's `artifacts/` directory from the current dir
    /// or its ancestors (so examples work from any working directory).
    pub fn open_default() -> Result<Runtime> {
        let mut dir = std::env::current_dir()?;
        loop {
            let cand = dir.join("artifacts");
            if cand.join("manifest.json").exists() {
                return Runtime::open(cand);
            }
            if !dir.pop() {
                bail!("no artifacts/manifest.json found in cwd or ancestors; run `make artifacts`");
            }
        }
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }
}

#[cfg(not(feature = "pjrt"))]
impl Runtime {
    /// Fails: executing artifacts needs the `pjrt` feature (and the `xla`
    /// crate it pulls in). The manifest is still validated so
    /// configuration errors surface even in stub builds. Numeric requests
    /// submitted through the coordinator still complete — they fall back
    /// to the native backend.
    pub fn open(dir: impl AsRef<Path>) -> Result<Runtime> {
        let _ = load_manifest(dir.as_ref())?;
        bail!("stencilcache was built without the `pjrt` feature; rebuild with `--features pjrt` (requires the xla crate) to execute artifacts — coordinator Solve/Execute fall back to the native numeric backend")
    }

    pub fn platform(&self) -> String {
        "unavailable (built without the pjrt feature)".to_string()
    }

    pub fn execute(&self, name: &str, _inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        bail!("cannot execute artifact {name:?}: built without the `pjrt` feature")
    }

    pub fn cached_executables(&self) -> usize {
        0
    }
}

#[cfg(feature = "pjrt")]
impl Runtime {
    /// Open the artifact directory (must contain `manifest.json`; run
    /// `make artifacts` to produce it) on the PJRT CPU client.
    pub fn open(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = load_manifest(&dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?;
        Ok(Runtime { client, dir, manifest, cache: Mutex::new(HashMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) the named artifact.
    pub fn load(&self, name: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(name) {
            return Ok(exe.clone());
        }
        let info = self
            .manifest
            .find(name)
            .ok_or_else(|| anyhow!("artifact {name:?} not in manifest ({:?})", self.manifest.names()))?;
        let path = self.dir.join(&info.file);
        let path_str = path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?;
        let proto = xla::HloModuleProto::from_text_file(path_str)
            .map_err(|e| anyhow!("parsing HLO text {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        let exe = std::sync::Arc::new(exe);
        self.cache.lock().unwrap().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute artifact `name` on f32 inputs; returns the decomposed output
    /// tuple as host tensors.
    pub fn execute(&self, name: &str, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        let exe = self.load(name)?;
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| {
                let dims: Vec<i64> = t.dims.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(&t.data)
                    .reshape(&dims)
                    .map_err(|e| anyhow!("reshaping input to {dims:?}: {e:?}"))
            })
            .collect::<Result<_>>()?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of {name}: {e:?}"))?;
        // Artifacts are lowered with return_tuple=True.
        let parts = out.to_tuple().map_err(|e| anyhow!("decomposing tuple: {e:?}"))?;
        parts
            .into_iter()
            .map(|lit| {
                let shape = lit.shape().map_err(|e| anyhow!("shape: {e:?}"))?;
                let dims: Vec<usize> = match &shape {
                    xla::Shape::Array(a) => a.dims().iter().map(|&d| d as usize).collect(),
                    other => bail!("unexpected non-array output: {other:?}"),
                };
                let data = lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))?;
                HostTensor::new(dims, data)
            })
            .collect()
    }

    /// Number of compiled executables currently cached.
    pub fn cached_executables(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}

#[cfg(test)]
mod host_tensor_tests {
    use super::*;

    #[test]
    fn host_tensor_validation() {
        assert!(HostTensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(HostTensor::new(vec![2, 3], vec![0.0; 5]).is_err());
        let z = HostTensor::zeros(&[4, 4]);
        assert_eq!(z.len(), 16);
        assert_eq!(z.norm(), 0.0);
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_runtime_fails_with_clear_error() {
        let err = Runtime::open_default().unwrap_err();
        let msg = format!("{err}");
        assert!(
            msg.contains("pjrt") || msg.contains("artifacts"),
            "unhelpful stub error: {msg}"
        );
    }
}

#[cfg(all(test, feature = "pjrt"))]
mod tests {
    use super::*;

    // These tests need `make artifacts` to have run (the Makefile test
    // target guarantees it). They exercise the full python→HLO→PJRT→rust
    // round trip on the smallest artifact shape (16³).

    fn runtime() -> Runtime {
        Runtime::open_default().expect("artifacts missing — run `make artifacts`")
    }

    fn rand_tensor(n: usize, seed: u64) -> HostTensor {
        let mut rng = crate::util::rng::Rng::new(seed);
        let data: Vec<f32> = (0..n * n * n).map(|_| rng.f64() as f32 - 0.5).collect();
        HostTensor::new(vec![n, n, n], data).unwrap()
    }

    #[test]
    fn manifest_lists_artifacts() {
        let rt = runtime();
        assert!(rt.manifest().find("star13_16").is_some());
        assert!(rt.manifest().find("nonexistent").is_none());
        assert!(rt.manifest().names().len() >= 5);
    }

    #[test]
    fn star13_matches_rust_stencil() {
        // The AOT kernel (python/pallas) must agree with the rust-native
        // engine on the shared interior. This pins L1 ↔ L3 numerics.
        let rt = runtime();
        let n = 16usize;
        let u = rand_tensor(n, 42);
        let out = rt.execute("star13_16", &[&u]).unwrap();
        assert_eq!(out.len(), 1);
        let q = &out[0];
        assert_eq!(q.dims, vec![n, n, n]);

        // rust-native computation
        let g = crate::grid::GridDesc::new(&[n, n, n]);
        let st = crate::stencil::Stencil::star13();
        let order = crate::traversal::natural(&g, 2);
        let u64v: Vec<f64> = u.data.iter().map(|&x| x as f64).collect();
        let mut qr = vec![0.0f64; u64v.len()];
        crate::engine::apply(&order, &g, &st, &u64v, &mut qr);
        // compare on the K-interior (python applies zero-halo everywhere;
        // interior values must agree). python arrays are row-major (x,y,z):
        // index = (x*n + y)*n + z; the rust grid is column-major with dim 0
        // fastest: offset = x + y*n + z*n². Feeding the python buffer into
        // the rust engine therefore computes the same stencil with the roles
        // of x and z swapped — the star13 stencil is axis-symmetric, so the
        // values coincide when we compare mirrored indices.
        let mut checked = 0;
        for z in 2..n - 2 {
            for y in 2..n - 2 {
                for x in 2..n - 2 {
                    // rust point (x,y,z) == python point (z,y,x); see above.
                    let pv = q.data[(z * n + y) * n + x] as f64;
                    let rv = qr[x + y * n + z * n * n];
                    assert!(
                        (pv - rv).abs() < 1e-3 * (1.0 + rv.abs()),
                        "mismatch at ({x},{y},{z}): pjrt {pv} vs rust {rv}"
                    );
                    checked += 1;
                }
            }
        }
        assert!(checked > 1000);
    }

    #[test]
    fn jacobi_step_reduces_energy() {
        let rt = runtime();
        let u = rand_tensor(16, 7);
        let before = u.norm();
        let out = rt.execute("jacobi_step_16", &[&u]).unwrap();
        let after = out[0].norm();
        assert!(after < before, "{after} !< {before}");
        assert!(after > 0.5 * before, "one stable step shouldn't crater the norm");
    }

    #[test]
    fn sweep_equals_ten_steps() {
        let rt = runtime();
        let u = rand_tensor(16, 11);
        let mut v = u.clone();
        for _ in 0..10 {
            v = rt.execute("jacobi_step_16", &[&v]).unwrap().remove(0);
        }
        let swept = rt.execute("jacobi_sweep_16x10", &[&u]).unwrap().remove(0);
        for (a, b) in v.data.iter().zip(&swept.data) {
            assert!((a - b).abs() < 1e-4 * (1.0 + b.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn step_norms_returns_two_outputs() {
        let rt = runtime();
        let u = rand_tensor(16, 13);
        let out = rt.execute("step_norms_16", &[&u]).unwrap();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].dims, vec![16, 16, 16]);
        assert_eq!(out[1].dims, vec![2]);
        // norms[0] must equal ||u'||
        let unorm = out[0].norm();
        assert!((out[1].data[0] as f64 - unorm).abs() < 1e-2 * (1.0 + unorm));
    }

    #[test]
    fn executable_cache_reuses_compilations() {
        let rt = runtime();
        let u = rand_tensor(16, 17);
        let _ = rt.execute("norms_16", &[&u]).unwrap();
        let c1 = rt.cached_executables();
        let _ = rt.execute("norms_16", &[&u]).unwrap();
        assert_eq!(rt.cached_executables(), c1);
    }

    #[test]
    fn missing_artifact_is_clean_error() {
        let rt = runtime();
        let u = rand_tensor(16, 19);
        let err = rt.execute("no_such_artifact", &[&u]).unwrap_err();
        assert!(format!("{err}").contains("not in manifest"));
    }
}
