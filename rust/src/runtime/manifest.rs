//! Artifact manifest reader (`artifacts/manifest.json`, written by
//! `python/compile/aot.py`). The manifest is the contract between the
//! build-time python layer and the runtime: names, input shapes, dtypes,
//! output arity.

use crate::util::json::{parse, Json};
use anyhow::{anyhow, bail, Result};
use std::path::Path;

/// One AOT artifact.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactInfo {
    pub name: String,
    pub file: String,
    pub input_shape: Vec<usize>,
    pub dtype: String,
    pub n_outputs: usize,
    pub description: String,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub alpha: f64,
    pub sweep_steps: usize,
    artifacts: Vec<ArtifactInfo>,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)?;
        Self::parse_str(&text)
    }

    pub fn parse_str(text: &str) -> Result<Manifest> {
        let root = parse(text).map_err(|e| anyhow!("manifest JSON: {e}"))?;
        let alpha = root.get("alpha").and_then(Json::as_f64).ok_or_else(|| anyhow!("manifest: missing alpha"))?;
        let sweep_steps = root
            .get("sweep_steps")
            .and_then(Json::as_i64)
            .ok_or_else(|| anyhow!("manifest: missing sweep_steps"))? as usize;
        let arr = root
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest: missing artifacts array"))?;
        let mut artifacts = Vec::with_capacity(arr.len());
        for a in arr {
            let get_str = |k: &str| -> Result<String> {
                a.get(k).and_then(Json::as_str).map(str::to_string).ok_or_else(|| anyhow!("artifact missing {k}"))
            };
            let shape = a
                .get("input_shape")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("artifact missing input_shape"))?
                .iter()
                .map(|v| v.as_i64().map(|x| x as usize).ok_or_else(|| anyhow!("bad dim")))
                .collect::<Result<Vec<_>>>()?;
            let n_outputs =
                a.get("n_outputs").and_then(Json::as_i64).ok_or_else(|| anyhow!("artifact missing n_outputs"))? as usize;
            artifacts.push(ArtifactInfo {
                name: get_str("name")?,
                file: get_str("file")?,
                input_shape: shape,
                dtype: get_str("dtype")?,
                n_outputs,
                description: get_str("description")?,
            });
        }
        if artifacts.is_empty() {
            bail!("manifest has no artifacts");
        }
        Ok(Manifest { alpha, sweep_steps, artifacts })
    }

    pub fn artifacts(&self) -> &[ArtifactInfo] {
        &self.artifacts
    }

    pub fn names(&self) -> Vec<&str> {
        self.artifacts.iter().map(|a| a.name.as_str()).collect()
    }

    pub fn find(&self, name: &str) -> Option<&ArtifactInfo> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// Find the artifact whose name starts with `prefix` and whose input
    /// shape matches `dims` (used by the coordinator's shape-keyed batcher).
    pub fn find_for_shape(&self, prefix: &str, dims: &[usize]) -> Option<&ArtifactInfo> {
        self.artifacts.iter().find(|a| a.name.starts_with(prefix) && a.input_shape == dims)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "alpha": 0.05,
      "sweep_steps": 10,
      "artifacts": [
        {"name": "star13_16", "file": "star13_16.hlo.txt",
         "input_shape": [16, 16, 16], "dtype": "f32", "n_outputs": 1,
         "description": "q = Ku"},
        {"name": "step_norms_16", "file": "step_norms_16.hlo.txt",
         "input_shape": [16, 16, 16], "dtype": "f32", "n_outputs": 2,
         "description": "(u', norms)"}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse_str(SAMPLE).unwrap();
        assert_eq!(m.alpha, 0.05);
        assert_eq!(m.sweep_steps, 10);
        assert_eq!(m.artifacts().len(), 2);
        let a = m.find("star13_16").unwrap();
        assert_eq!(a.input_shape, vec![16, 16, 16]);
        assert_eq!(a.n_outputs, 1);
    }

    #[test]
    fn find_for_shape() {
        let m = Manifest::parse_str(SAMPLE).unwrap();
        assert!(m.find_for_shape("star13", &[16, 16, 16]).is_some());
        assert!(m.find_for_shape("star13", &[32, 32, 32]).is_none());
        assert!(m.find_for_shape("nope", &[16, 16, 16]).is_none());
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse_str("{}").is_err());
        assert!(Manifest::parse_str("{\"alpha\": 0.05, \"sweep_steps\": 1, \"artifacts\": []}").is_err());
        assert!(Manifest::parse_str("not json").is_err());
    }
}
