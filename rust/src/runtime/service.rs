//! Runtime service: a dedicated executor thread owning the PJRT client.
//!
//! The `xla` crate's handles (`PjRtClient`, `PjRtLoadedExecutable`) wrap
//! `Rc`s and raw pointers — they are neither `Send` nor `Sync`. The
//! coordinator, however, serves requests from a thread pool. The standard
//! resolution (same shape as vLLM's engine-core thread) is an **actor**:
//! one thread owns the [`Runtime`]; everyone else holds a cloneable
//! [`RuntimeHandle`] and communicates via channels. PJRT CPU parallelizes
//! inside a single execution, so a single executor thread does not starve
//! the machine.

use super::{HostTensor, Manifest, Runtime};
use anyhow::{anyhow, Result};
use std::path::PathBuf;
use std::sync::mpsc::{channel, Sender};
use std::sync::Mutex;
use std::thread::JoinHandle;

enum Job {
    Execute { name: String, inputs: Vec<HostTensor>, reply: Sender<Result<Vec<HostTensor>>> },
    CachedExecutables { reply: Sender<usize> },
    Shutdown,
}

/// Cloneable, thread-safe handle to the runtime executor thread.
pub struct RuntimeHandle {
    tx: Mutex<Sender<Job>>,
    manifest: Manifest,
    platform: String,
}

impl RuntimeHandle {
    /// Execute an artifact; blocks until the executor thread replies.
    pub fn execute(&self, name: &str, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        let (reply, rx) = channel();
        let job = Job::Execute {
            name: name.to_string(),
            inputs: inputs.iter().map(|&t| t.clone()).collect(),
            reply,
        };
        self.tx
            .lock()
            .unwrap()
            .send(job)
            .map_err(|_| anyhow!("runtime service stopped"))?;
        rx.recv().map_err(|_| anyhow!("runtime service dropped reply"))?
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> &str {
        &self.platform
    }

    pub fn cached_executables(&self) -> usize {
        let (reply, rx) = channel();
        if self.tx.lock().unwrap().send(Job::CachedExecutables { reply }).is_err() {
            return 0;
        }
        rx.recv().unwrap_or(0)
    }
}

/// The service: owns the executor thread; dropping it shuts the thread
/// down after in-flight jobs complete.
pub struct RuntimeService {
    handle: std::sync::Arc<RuntimeHandle>,
    tx: Sender<Job>,
    join: Option<JoinHandle<()>>,
}

impl RuntimeService {
    /// Start the executor on the artifact directory (ancestor-searched when
    /// `dir` is None — see [`Runtime::open_default`]).
    pub fn start(dir: Option<PathBuf>) -> Result<RuntimeService> {
        let (tx, rx) = channel::<Job>();
        // Open the runtime *on the executor thread* (the client must live
        // where it is used); ship the manifest back through a bootstrap
        // channel so the handle can answer metadata queries locally.
        let (boot_tx, boot_rx) = channel::<Result<(Manifest, String)>>();
        let join = std::thread::Builder::new()
            .name("stencilcache-pjrt".to_string())
            .spawn(move || {
                let runtime = match dir {
                    Some(d) => Runtime::open(d),
                    None => Runtime::open_default(),
                };
                let runtime = match runtime {
                    Ok(rt) => {
                        let _ = boot_tx.send(Ok((rt.manifest().clone(), rt.platform())));
                        rt
                    }
                    Err(e) => {
                        let _ = boot_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(job) = rx.recv() {
                    match job {
                        Job::Execute { name, inputs, reply } => {
                            let refs: Vec<&HostTensor> = inputs.iter().collect();
                            let _ = reply.send(runtime.execute(&name, &refs));
                        }
                        Job::CachedExecutables { reply } => {
                            let _ = reply.send(runtime.cached_executables());
                        }
                        Job::Shutdown => break,
                    }
                }
            })
            .expect("failed to spawn runtime thread");
        let (manifest, platform) = boot_rx.recv().map_err(|_| anyhow!("runtime thread died during startup"))??;
        let handle = std::sync::Arc::new(RuntimeHandle { tx: Mutex::new(tx.clone()), manifest, platform });
        Ok(RuntimeService { handle, tx, join: Some(join) })
    }

    pub fn handle(&self) -> std::sync::Arc<RuntimeHandle> {
        self.handle.clone()
    }
}

impl Drop for RuntimeService {
    fn drop(&mut self) {
        let _ = self.tx.send(Job::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

#[cfg(test)]
mod startup_tests {
    use super::*;

    #[test]
    fn startup_error_is_propagated() {
        let err = RuntimeService::start(Some(PathBuf::from("/nonexistent/artifacts"))).err();
        assert!(err.is_some());
    }
}

#[cfg(all(test, feature = "pjrt"))]
mod tests {
    use super::*;

    fn service() -> RuntimeService {
        RuntimeService::start(None).expect("artifacts missing — run `make artifacts`")
    }

    #[test]
    fn executes_through_service_thread() {
        let svc = service();
        let h = svc.handle();
        let u = HostTensor::zeros(&[16, 16, 16]);
        let out = h.execute("star13_16", &[&u]).unwrap();
        assert_eq!(out[0].dims, vec![16, 16, 16]);
        assert_eq!(out[0].norm(), 0.0);
    }

    #[test]
    fn handle_usable_from_many_threads() {
        let svc = service();
        let h = svc.handle();
        std::thread::scope(|s| {
            for seed in 0..4u64 {
                let h = h.clone();
                s.spawn(move || {
                    let mut rng = crate::util::rng::Rng::new(seed);
                    let data: Vec<f32> = (0..16 * 16 * 16).map(|_| rng.f64() as f32).collect();
                    let u = HostTensor::new(vec![16, 16, 16], data).unwrap();
                    let out = h.execute("jacobi_step_16", &[&u]).unwrap();
                    assert!(out[0].norm() > 0.0);
                });
            }
        });
        assert!(h.cached_executables() >= 1);
    }

    #[test]
    fn manifest_available_on_handle() {
        let svc = service();
        assert!(svc.handle().manifest().find("star13_16").is_some());
        assert!(!svc.handle().platform().is_empty());
    }
}
