//! **FIG4** — reproduce Figure 4 of the paper.
//!
//! Setup (paper §6): 13-point star stencil, grids `40 ≤ n1 < 100`,
//! `n2 = 91`, `n3 = 100`, R10000 cache (2, 512, 4). Two codes:
//! the compiler-optimized naturally ordered nest (top line) and the cache
//! fitting algorithm (bottom line). Paper findings to reproduce:
//!
//! - typical natural/fitting miss ratio ≈ 3.5;
//! - spikes at n1 = 45 (shortest vector (1,0,1)) and n1 = 90 ((2,0,1));
//! - on those unfavorable grids the fitting algorithm's misses can exceed
//!   the compiler-optimized nest.

use super::{measure, save_csv, OrderKind};
use crate::cache::CacheParams;
use crate::grid::GridDesc;
use crate::lattice::InterferenceLattice;
use crate::report::{AsciiPlot, Table};
use crate::stencil::Stencil;
use crate::util::stats;
use crate::util::threadpool::ThreadPool;

/// Experiment parameters.
#[derive(Debug, Clone)]
pub struct Config {
    pub n1_range: std::ops::Range<usize>,
    pub n2: usize,
    pub n3: usize,
    pub cache: CacheParams,
}

impl Config {
    /// The paper's exact sweep; `quick` shrinks n3 (the paper itself notes
    /// the third dimension is irrelevant to the phenomenon).
    pub fn paper(quick: bool) -> Config {
        Config {
            n1_range: 40..100,
            n2: 91,
            n3: if quick { 20 } else { 100 },
            cache: CacheParams::r10000(),
        }
    }
}

/// One row of the Figure-4 dataset.
#[derive(Debug, Clone)]
pub struct Row {
    pub n1: usize,
    pub natural_misses: u64,
    pub fitting_misses: u64,
    pub ratio: f64,
    pub min_l1: Option<i64>,
    pub unfavorable: bool,
    /// Strictly favorable: shortest L1 vector strictly longer than the
    /// stencil diameter (borderline grids — min_l1 == diameter, e.g.
    /// n1 = 46's (2,−2,1) — behave unfavorably in practice and are
    /// excluded from the headline ratio).
    pub strictly_favorable: bool,
}

/// Run the sweep (parallel over n1) and print the figure.
pub fn run(config: Config) -> Vec<Table> {
    let stencil = Stencil::star13();
    let pool = ThreadPool::with_default_parallelism();
    let n1s: Vec<usize> = config.n1_range.clone().collect();
    let rows: Vec<Row> = pool.scope_map(n1s.len(), |i| {
        let n1 = n1s[i];
        let grid = GridDesc::new(&[n1, config.n2, config.n3]);
        let nat = measure(&grid, &stencil, config.cache, OrderKind::Natural, 1);
        let fit = measure(&grid, &stencil, config.cache, OrderKind::Auto, 1);
        let lat = InterferenceLattice::new(grid.storage_dims(), config.cache.lattice_modulus());
        let min_l1 = lat.min_l1(8);
        Row {
            n1,
            natural_misses: nat.total.misses(),
            fitting_misses: fit.total.misses(),
            ratio: nat.total.misses() as f64 / fit.total.misses().max(1) as f64,
            min_l1,
            unfavorable: lat.is_unfavorable(stencil.diameter() as i64),
            strictly_favorable: min_l1.map(|m| m > stencil.diameter() as i64).unwrap_or(true),
        }
    });

    let mut table = Table::new(
        &format!(
            "FIG4: misses, natural vs cache-fitting (n2={}, n3={}, cache {:?})",
            config.n2, config.n3, config.cache
        ),
        &["n1", "natural", "fitting", "ratio", "min_l1", "unfavorable"],
    );
    for r in &rows {
        table.add_row(vec![
            r.n1.to_string(),
            r.natural_misses.to_string(),
            r.fitting_misses.to_string(),
            format!("{:.2}", r.ratio),
            r.min_l1.map(|m| m.to_string()).unwrap_or_else(|| ">8".into()),
            if r.unfavorable { "YES".into() } else { "".into() },
        ]);
    }

    // Figure: the two miss curves.
    let mut plot = AsciiPlot::new("Figure 4: cache misses vs n1", 72, 18);
    plot.series("natural (compiler) order", rows.iter().map(|r| (r.n1 as f64, r.natural_misses as f64)).collect());
    plot.series("cache fitting", rows.iter().map(|r| (r.n1 as f64, r.fitting_misses as f64)).collect());
    println!("{}", plot.render());
    println!("{}", table.to_text());

    // Summary: the paper's headline "typical ratio 3.5".
    let favorable_ratios: Vec<f64> = rows.iter().filter(|r| r.strictly_favorable).map(|r| r.ratio).collect();
    let summary_stats = stats::Summary::of(&favorable_ratios);
    let mut summary = Table::new("FIG4 summary", &["metric", "value", "paper"]);
    summary.add_row(vec!["typical (median) natural/fitting ratio on favorable grids".into(), format!("{:.2}", summary_stats.p50), "≈3.5".into()]);
    summary.add_row(vec!["geomean ratio".into(), format!("{:.2}", stats::geomean(&favorable_ratios)), "—".into()]);
    let spike_n1: Vec<String> = rows.iter().filter(|r| r.unfavorable).map(|r| r.n1.to_string()).collect();
    summary.add_row(vec!["unfavorable n1 detected".into(), spike_n1.join(","), "45, 90 highlighted".into()]);
    let fit_worse = rows.iter().filter(|r| r.unfavorable && r.fitting_misses > r.natural_misses).count();
    summary.add_row(vec![
        "unfavorable grids where fitting > natural".into(),
        fit_worse.to_string(),
        "can happen (Fig 4 caption)".into(),
    ]);
    println!("{}", summary.to_text());

    save_csv(&table, "fig4");
    save_csv(&summary, "fig4_summary");
    vec![table, summary]
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Scaled-down sweep exercising the full driver path. n3 = 20 keeps
    /// enough z-depth for the fitting algorithm's pencils to amortize
    /// (the paper's n3 = 100; very thin grids make the pencil boundary
    /// dominate, which is expected behaviour, not a bug).
    fn tiny() -> Config {
        Config { n1_range: 44..47, n2: 91, n3: 20, cache: CacheParams::r10000() }
    }

    #[test]
    fn fig4_detects_n1_45_spike() {
        let tables = run(tiny());
        let t = &tables[0];
        assert_eq!(t.num_rows(), 3);
        // the n1=45 row must be flagged unfavorable with min_l1 = 2
        let row45 = &t.rows()[1];
        assert_eq!(row45[0], "45");
        assert_eq!(row45[4], "2");
        assert_eq!(row45[5], "YES");
        // neighbors not flagged
        assert_eq!(t.rows()[0][5], "");
        assert_eq!(t.rows()[2][5], "");
    }

    #[test]
    fn fig4_fitting_beats_natural_on_favorable() {
        let tables = run(tiny());
        let t = &tables[0];
        for row in t.rows() {
            // strictly favorable rows only (min_l1 > diameter or none ≤ 8)
            let strict = match row[4].as_str() {
                ">8" => true,
                v => v.parse::<i64>().unwrap() > 5,
            };
            if strict {
                let nat: u64 = row[1].parse().unwrap();
                let fit: u64 = row[2].parse().unwrap();
                assert!(fit < nat, "n1={} fitting {fit} !< natural {nat}", row[0]);
            }
        }
    }
}

#[cfg(test)]
mod debug_tests {
    use super::*;

    #[test]
    #[ignore]
    fn debug_fig4_breakdown() {
        use crate::engine;
        use crate::grid::MultiArrayLayout;
        use crate::cache::CacheSim;
        let cache = CacheParams::r10000();
        let stencil = Stencil::star13();
        for n1 in [44usize, 46, 52] {
            for n3 in [20usize, 100] {
                let grid = GridDesc::new(&[n1, 91, n3]);
                let lat = InterferenceLattice::new(grid.storage_dims(), cache.lattice_modulus());
                let nat = {
                    let order = crate::traversal::natural(&grid, 2);
                    let layout = MultiArrayLayout::paper_offsets(&grid, 1, 4096);
                    let mut sim = CacheSim::new(cache);
                    engine::simulate(&order, &layout, &stencil, &mut sim)
                };
                println!("n1={n1} n3={n3} natural: miss/pt={:.3} loads/pt={:.3}",
                    nat.total.misses() as f64 / nat.points as f64,
                    nat.u_loads as f64 / nat.points as f64);
                use crate::traversal::fitting::FittingOptions;
                let variants: Vec<(String, FittingOptions)> = (0..3).flat_map(|iv| {
                    vec![
                        (format!("iv={iv} w=1 serp"), FittingOptions{sweep_index:Some(iv), widths:vec![], serpentine:true}),
                    ]
                }).collect();
                for (name, opts) in &variants {
                    let order = crate::traversal::fitting::cache_fitting_opts(&grid, 2, &lat, opts);
                    let layout = MultiArrayLayout::paper_offsets(&grid, 1, 4096);
                    let mut sim = CacheSim::new(cache);
                    let rep = engine::simulate(&order, &layout, &stencil, &mut sim);
                    println!("  fit {name}: miss/pt={:.3} repl/pt={:.3} loads/pt={:.3}",
                        rep.total.misses() as f64 / rep.points as f64,
                        rep.total.replacement_misses as f64 / rep.points as f64,
                        rep.u_loads as f64 / rep.points as f64);
                }
                // tiled variants with z blocking
                for assoc in [1usize, 2] {
                    let (t1, t2) = crate::traversal::tiled::conflict_free_tile_assoc(grid.storage_dims(), 4096, 2, assoc);
                    for tz in [8usize, 16, 32, 1000] {
                        let tz_eff = tz.min(grid.dims()[2]);
                        let order = crate::traversal::blocked(&grid, 2, &[t1, t2, tz_eff]);
                        let layout = MultiArrayLayout::paper_offsets(&grid, 1, 4096);
                        let mut sim = CacheSim::new(cache);
                        let rep = engine::simulate(&order, &layout, &stencil, &mut sim);
                        println!("  tiled a={assoc} ({t1}x{t2}x{tz_eff}): miss/pt={:.3} repl/pt={:.3} loads/pt={:.3}",
                            rep.total.misses() as f64 / rep.points as f64,
                            rep.total.replacement_misses as f64 / rep.points as f64,
                            rep.u_loads as f64 / rep.points as f64);
                    }
                }
            }
        }
    }
}
