//! **BOUNDS** — tabulate the Eq 7 / Eq 12 sandwich.
//!
//! The paper proves `lower ≤ μ ≤ upper` but prints no table; we generate
//! one: for favorable grids across cache sizes, measure the cache-fitting
//! algorithm's actual u-loads in the simulator and place them between the
//! two bounds. Also reports the fundamental-parallelepiped volume
//! utilization (always exactly S — `det L = S` — versus the ≈ 0.8·S blocks
//! of the cache-miss-equation approach [4], the comparison the paper makes
//! at the end of §4).

use super::{measure, save_csv, OrderKind};
use crate::bounds::{lower_bound_loads, upper_bound_loads};
use crate::cache::CacheParams;
use crate::grid::GridDesc;
use crate::lattice::InterferenceLattice;
use crate::report::Table;
use crate::stencil::Stencil;

/// Favorable test grids per cache size (padded away from hyperbolae).
fn grids_for(quick: bool) -> Vec<Vec<usize>> {
    if quick {
        vec![vec![33, 29, 12], vec![41, 37, 12]]
    } else {
        vec![vec![33, 29, 40], vec![41, 37, 40], vec![67, 53, 40], vec![61, 47, 40]]
    }
}

pub fn run(quick: bool) -> Table {
    let stencil = Stencil::star(3, 1); // r = 1 keeps the c''_d constant modest
    let mut table = Table::new(
        "BOUNDS: Eq 7 ≤ measured u-loads (cache fitting) ≤ Eq 12, r=1 star",
        &["grid", "S", "lower (Eq7)", "measured", "upper (Eq12)", "meas/|G|", "ecc", "P volume util"],
    );
    for log_s in [10usize, 12, 14] {
        let s = 1usize << log_s;
        let cache = CacheParams::new(2, s / 8, 4);
        assert_eq!(cache.size_words(), s);
        for dims in grids_for(quick) {
            let grid = GridDesc::new(&dims);
            let lat = InterferenceLattice::new(grid.storage_dims(), s);
            if lat.is_unfavorable(stencil.diameter() as i64) {
                continue; // Eq 12 assumes a favorable lattice
            }
            let rep = measure(&grid, &stencil, cache, OrderKind::Auto, 1);
            let lb = lower_bound_loads(&grid, s);
            let ub = upper_bound_loads(&grid, s, stencil.radius() as u32, lat.eccentricity());
            // det L = S always: full cache utilization (vs ~0.8·S in [4]).
            let util = lat.determinant() as f64 / s as f64;
            table.add_row(vec![
                format!("{}x{}x{}", dims[0], dims[1], dims[2]),
                s.to_string(),
                format!("{lb:.0}"),
                rep.u_loads.to_string(),
                format!("{ub:.0}"),
                format!("{:.3}", rep.u_loads as f64 / grid.num_points() as f64),
                format!("{:.2}", lat.eccentricity()),
                format!("{util:.2}"),
            ]);
        }
    }
    println!("{}", table.to_text());
    save_csv(&table, "bounds");
    table
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sandwich_holds() {
        let t = run(true);
        assert!(t.num_rows() >= 4);
        for row in t.rows() {
            let lb: f64 = row[2].parse().unwrap();
            let measured: f64 = row[3].parse().unwrap();
            let ub: f64 = row[4].parse().unwrap();
            assert!(lb <= measured * 1.001, "row {row:?}");
            assert!(measured <= ub * 1.001, "row {row:?}");
        }
    }

    #[test]
    fn full_parallelepiped_utilization() {
        let t = run(true);
        for row in t.rows() {
            assert_eq!(row[7], "1.00", "det L must equal S: {row:?}");
        }
    }

    #[test]
    fn measured_loads_near_one_per_point() {
        // Cache fitting on favorable grids should be close to compulsory:
        // ~1 load per point, never >2.
        let t = run(true);
        for row in t.rows() {
            let per: f64 = row[5].parse().unwrap();
            assert!((0.9..2.0).contains(&per), "row {row:?}");
        }
    }
}
