//! **APPB** — Appendix B: favorable grids exist for every prime-power S.
//!
//! Runs the constructive proof for d = 2, 3 across cache sizes, reporting
//! the certificate (dims, shortest vector length, the achieved
//! `f = S/‖v‖^d`, eccentricity). Appendix B promises `f` bounded
//! independently of S — the table shows it staying flat across three
//! decades.

use super::save_csv;
use crate::bounds::favorable;
use crate::lattice::InterferenceLattice;
use crate::report::Table;

pub fn run() -> Table {
    let mut table = Table::new(
        "APPB: favorable-grid construction (shortest lattice vector ≥ (S/f)^{1/d})",
        &["d", "S", "dims (n_i)", "shortest ‖v‖", "(S/f)^{1/d} ref: S^{1/d}", "f", "eccentricity", "verified"],
    );
    for d in [2usize, 3] {
        for log_s in [8usize, 10, 12, 14, 16] {
            let s = 1usize << log_s;
            let fg = favorable::construct(d, s);
            let lat = InterferenceLattice::new(&fg.dims, s);
            let dims_str = fg.dims.iter().map(|n| n.to_string()).collect::<Vec<_>>().join("x");
            table.add_row(vec![
                d.to_string(),
                s.to_string(),
                dims_str,
                format!("{:.2}", fg.shortest_len),
                format!("{:.2}", (s as f64).powf(1.0 / d as f64)),
                format!("{:.1}", fg.f_quality),
                format!("{:.2}", lat.eccentricity()),
                if favorable::verify(&fg, s) { "YES".into() } else { "NO".into() },
            ]);
        }
    }
    println!("{}", table.to_text());
    save_csv(&table, "appb");
    table
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_constructions_verify() {
        let t = run();
        assert_eq!(t.num_rows(), 10);
        for row in t.rows() {
            assert_eq!(row[7], "YES", "row {row:?}");
        }
    }

    #[test]
    fn f_stays_bounded_across_s() {
        let t = run();
        for row in t.rows() {
            let f: f64 = row[5].parse().unwrap();
            assert!(f < 60.0, "f blew up: {row:?}");
        }
    }
}
