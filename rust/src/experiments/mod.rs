//! Experiment drivers: one per figure/table of the paper (see DESIGN.md §4
//! for the index). Each driver returns [`report::Table`]s (and prints an
//! ASCII rendition of the figure) and can write CSV snapshots under
//! `results/`.
//!
//! | id        | paper artifact | driver |
//! |-----------|----------------|--------|
//! | `fig4`    | Figure 4       | [`fig4::run`] |
//! | `fig5a`   | Figure 5A      | [`fig5::run_a`] |
//! | `fig5b`   | Figure 5B      | [`fig5::run_b`] |
//! | `fig5corr`| §6 correlation | [`fig5::run_corr`] |
//! | `sec3`    | §3 example     | [`sec3::run`] |
//! | `bounds`  | Eq 7/12 sandwich | [`bounds_table::run`] |
//! | `multirhs`| §5 Eq 13/14    | [`multirhs::run`] |
//! | `appb`    | Appendix B     | [`appb::run`] |
//! | `halo`    | PEM halo bound vs measured ghost traffic (not in the paper) | [`halo::run`] |
//! | `replay`  | serving-layer memo hit rates (not in the paper) | [`replay::run`] |

pub mod appb;
pub mod bounds_table;
pub mod fig4;
pub mod fig5;
pub mod halo;
pub mod multirhs;
pub mod replay;
pub mod sec3;

use crate::cache::{CacheParams, CacheSim, MachineModel};
use crate::engine::{self, MissReport};
use crate::grid::{GridDesc, MultiArrayLayout};
use crate::report::Table;
use crate::stencil::Stencil;
use crate::traversal;

/// Which traversal a measurement uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OrderKind {
    Natural,
    /// The faithful §4 pencil sweep (longest-vector default).
    CacheFitting,
    /// Auto-tuned fitting family (pencil sweeps + lattice tiles) — what the
    /// production planner and the FIG4 "cache fitting" line use.
    Auto,
    Blocked(usize),
    Strip(usize),
}

fn build_order(grid: &GridDesc, stencil: &Stencil, cache: &CacheParams, kind: OrderKind) -> crate::traversal::Order {
    let r = stencil.radius();
    match kind {
        OrderKind::Natural => traversal::natural(grid, r),
        OrderKind::CacheFitting => traversal::cache_fitting_for_cache(grid, r, cache),
        OrderKind::Auto => crate::tuner::auto_fitting_order(grid, stencil, cache).0,
        OrderKind::Blocked(t) => traversal::blocked(grid, r, &vec![t; grid.ndim()]),
        OrderKind::Strip(w) => traversal::strip(grid, r, w),
    }
}

/// Run one simulated measurement: build the order, stream the stencil's
/// address trace through a fresh cache, return the report. Uses the §5
/// offset layout (q at a half-tile cache offset), the layout every
/// comparison in the paper-reproduction suite shares.
pub fn measure(grid: &GridDesc, stencil: &Stencil, cache: CacheParams, kind: OrderKind, p: usize) -> MissReport {
    measure_with_offsets(grid, stencil, cache, kind, p)
}

/// Explicit-layout variant (contiguous baseline for the §5 comparison).
pub fn measure_contiguous(
    grid: &GridDesc,
    stencil: &Stencil,
    cache: CacheParams,
    kind: OrderKind,
    p: usize,
) -> MissReport {
    let order = build_order(grid, stencil, &cache, kind);
    let layout = MultiArrayLayout::contiguous(grid, p);
    let mut sim = CacheSim::new(cache);
    engine::simulate(&order, &layout, stencil, &mut sim)
}

/// §5 offset layout (`addr_i = addr_1 + m_i·S + s_i`, q at half-tile).
pub fn measure_with_offsets(
    grid: &GridDesc,
    stencil: &Stencil,
    cache: CacheParams,
    kind: OrderKind,
    p: usize,
) -> MissReport {
    let order = build_order(grid, stencil, &cache, kind);
    let layout = MultiArrayLayout::paper_offsets(grid, p, cache.size_words());
    let mut sim = CacheSim::new(cache);
    engine::simulate(&order, &layout, stencil, &mut sim)
}

/// [`measure`] against a full [`MachineModel`]: the same §5 offset layout
/// and traversal construction (both keyed to the L1 geometry, like the
/// paper's), but simulated through every level the machine exposes, so
/// the report's per-level profile carries L2/TLB counters. Single-level
/// machines reproduce [`measure`] exactly.
pub fn measure_machine(
    grid: &GridDesc,
    stencil: &Stencil,
    machine: &MachineModel,
    kind: OrderKind,
    p: usize,
) -> MissReport {
    let order = build_order(grid, stencil, &machine.l1, kind);
    let layout = MultiArrayLayout::paper_offsets(grid, p, machine.l1.size_words());
    engine::simulate_on_machine(&order, &layout, stencil, machine)
}

/// Save a table as CSV under `results/` (best effort — failures logged).
pub fn save_csv(table: &Table, name: &str) {
    let path = std::path::Path::new("results").join(format!("{name}.csv"));
    match crate::report::write_file(&path, &table.to_csv()) {
        Ok(()) => crate::log_info!("wrote {}", path.display()),
        Err(e) => crate::log_warn!("could not write {}: {e}", path.display()),
    }
}

/// Run an experiment by id. `quick` shrinks problem sizes for smoke runs.
pub fn run(id: &str, quick: bool) -> Result<Vec<Table>, String> {
    match id {
        "fig4" => Ok(fig4::run(fig4::Config::paper(quick))),
        "fig5a" => Ok(vec![fig5::run_a(fig5::Config::paper(quick)).table]),
        "fig5b" => Ok(vec![fig5::run_b(fig5::Config::paper(quick))]),
        "fig5corr" => Ok(fig5::run_corr(fig5::Config::paper(quick))),
        "sec3" => Ok(vec![sec3::run(quick)]),
        "bounds" => Ok(vec![bounds_table::run(quick)]),
        "multirhs" => Ok(vec![multirhs::run(quick)]),
        "appb" => Ok(vec![appb::run()]),
        "halo" => Ok(vec![halo::run(quick), halo::run_temporal(quick)]),
        // serving-layer replay (not a paper artifact, so not part of "all";
        // the `stencilcache replay` subcommand exposes the full knob set)
        "replay" => Ok(vec![replay::run(&replay::ReplayConfig::paper(quick)).table]),
        "all" => {
            let mut out = Vec::new();
            for id in ["fig4", "fig5a", "fig5b", "fig5corr", "sec3", "bounds", "multirhs", "appb", "halo"] {
                out.extend(run(id, quick)?);
            }
            Ok(out)
        }
        other => Err(format!(
            "unknown experiment {other:?}; available: fig4 fig5a fig5b fig5corr sec3 bounds multirhs appb halo replay all"
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_reports_points() {
        let g = GridDesc::new(&[12, 12, 12]);
        let s = Stencil::star(3, 1);
        let rep = measure(&g, &s, CacheParams::new(2, 32, 2), OrderKind::Natural, 1);
        assert_eq!(rep.points, 10 * 10 * 10);
    }

    #[test]
    fn unknown_experiment_is_error() {
        assert!(run("nope", true).is_err());
    }
}
