//! **FIG5** — reproduce Figure 5 of the paper.
//!
//! Plot A: for array sizes `40 ≤ n1, n2 < 100` (natural order forced, as
//! the paper does with a circular-shift subroutine), mark grids whose
//! measured cache misses exceed the smooth baseline by ≥ 15%. Plot B: mark
//! grids whose interference lattice has a vector with L1 norm < 8. The
//! paper's claims:
//!
//! - both maps are fitted well by the hyperbolae `n1·n2 = k·S/2`,
//!   k = 1..4 (unfavorable slices are multiples of half the cache);
//! - A and B coincide (short lattice vector ⇔ miss spike) — we quantify
//!   with the φ association coefficient.
//!
//! Substitution note (DESIGN.md): the paper thresholds "15% above the
//! *upper bound*"; our threshold is 15% above the **median per-point miss
//! rate** across the sweep — the same smooth floor, without depending on
//! the eccentricity term that itself diverges on unfavorable grids.
//!
//! **TLB column** (§6: the spikes correlate "for the TLB as well as the
//! L1 cache"): [`run_corr`] additionally sweeps the same grids through
//! the full `r10000-full` machine and associates *TLB-miss* spikes with
//! short vectors of the **page interference lattice** (modulus = the
//! TLB's 32768-word reach). Substitution note: our TLB model is the ideal
//! fully-associative LRU of the R10000 manual, so page-level conflict
//! structure is weaker than on the measured machine — the φ row reports
//! whatever the model shows rather than asserting the paper's qualitative
//! claim.

use super::{measure, measure_machine, save_csv, OrderKind};
use crate::cache::{CacheParams, Level, MachineModel};
use crate::grid::GridDesc;
use crate::lattice::InterferenceLattice;
use crate::report::Table;
use crate::stencil::Stencil;
use crate::util::stats;
use crate::util::threadpool::ThreadPool;

#[derive(Debug, Clone)]
pub struct Config {
    pub n_range: std::ops::Range<usize>,
    pub n3: usize,
    pub cache: CacheParams,
    /// Spike threshold relative to the median per-point rate.
    pub threshold: f64,
    /// L1 bar for plot B (paper: 8).
    pub short_bar: i64,
}

impl Config {
    pub fn paper(quick: bool) -> Config {
        Config {
            n_range: if quick { 40..70 } else { 40..100 },
            n3: if quick { 6 } else { 10 },
            cache: CacheParams::r10000(),
            threshold: 1.15,
            short_bar: 8,
        }
    }
}

/// Result of the Plot-A sweep.
pub struct PlotA {
    pub table: Table,
    /// (n1, n2, misses_per_point, spike?)
    pub cells: Vec<(usize, usize, f64, bool)>,
}

/// The (n1, n2) sweep grid of the configured range.
fn sweep_pairs(config: &Config) -> Vec<(usize, usize)> {
    let ns: Vec<usize> = config.n_range.clone().collect();
    ns.iter().flat_map(|&a| ns.iter().map(move |&b| (a, b))).collect()
}

/// Median of a rate column (the spike baseline).
fn median_rate(rates: &[f64]) -> f64 {
    let mut sorted = rates.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    stats::percentile_sorted(&sorted, 0.5)
}

/// The Plot-A presentation shared by [`run_a`] and [`run_corr`]:
/// threshold per-point rates against the sweep median, render the table +
/// ASCII map, save the CSV.
fn plot_a_from_rates(config: &Config, pairs: &[(usize, usize)], rates: &[f64]) -> PlotA {
    let median = median_rate(rates);
    let cells: Vec<(usize, usize, f64, bool)> = pairs
        .iter()
        .zip(rates)
        .map(|(&(n1, n2), &rate)| (n1, n2, rate, rate > config.threshold * median))
        .collect();

    let mut table = Table::new(
        &format!("FIG5A: miss spikes (natural order, n3={}, thr {:.0}% over median rate {:.3})", config.n3, (config.threshold - 1.0) * 100.0, median),
        &["n1", "n2", "misses_per_point", "spike"],
    );
    for &(n1, n2, rate, _spike) in cells.iter().filter(|c| c.3) {
        table.add_row(vec![n1.to_string(), n2.to_string(), format!("{rate:.3}"), "YES".into()]);
    }
    println!("{}", render_map("Figure 5A: miss spikes (■)", config, &cells.iter().map(|&(a, b, _, s)| (a, b, s)).collect::<Vec<_>>()));
    save_csv(&table, "fig5a");
    PlotA { table, cells }
}

/// Plot A: measured miss fluctuations under natural order.
pub fn run_a(config: Config) -> PlotA {
    let stencil = Stencil::star13();
    let pool = ThreadPool::with_default_parallelism();
    let pairs = sweep_pairs(&config);
    let rates: Vec<f64> = pool.scope_map(pairs.len(), |i| {
        let (n1, n2) = pairs[i];
        let grid = GridDesc::new(&[n1, n2, config.n3]);
        let rep = measure(&grid, &stencil, config.cache, OrderKind::Natural, 1);
        rep.misses_per_point()
    });
    plot_a_from_rates(&config, &pairs, &rates)
}

/// Plot B: lattices with short (< `short_bar` in L1) vectors — pure
/// number theory, no simulation.
pub fn run_b(config: Config) -> Table {
    let ns: Vec<usize> = config.n_range.clone().collect();
    let mut table = Table::new(
        &format!("FIG5B: interference lattices with L1-short (<{}) vectors; S = {}", config.short_bar, config.cache.lattice_modulus()),
        &["n1", "n2", "min_l1", "n1*n2 / (S/2)"],
    );
    let s_half = config.cache.lattice_modulus() as f64 / 2.0;
    let mut marks = Vec::new();
    for &n1 in &ns {
        for &n2 in &ns {
            let lat = InterferenceLattice::new(&[n1, n2, 50], config.cache.lattice_modulus());
            let short = lat.min_l1(config.short_bar - 1);
            marks.push((n1, n2, short.is_some()));
            if let Some(m) = short {
                table.add_row(vec![
                    n1.to_string(),
                    n2.to_string(),
                    m.to_string(),
                    format!("{:.3}", (n1 * n2) as f64 / s_half),
                ]);
            }
        }
    }
    println!("{}", render_map("Figure 5B: short lattice vectors (■)", &config, &marks));
    println!("{}", table.to_text());
    save_csv(&table, "fig5b");
    table
}

/// One sweep of the full machine over the Plot-A grids under natural
/// order: per-point (L1 misses, TLB misses) for each (n1, n2). The L1
/// column is bit-identical to [`run_a`]'s single-level sweep (the L1 of a
/// hierarchy sees exactly the single-level stream — pinned by
/// `hierarchy_l1_equals_standalone_cache_sim`), which is why [`run_corr`]
/// can feed both the miss-spike map and the TLB column from this one
/// simulation pass.
fn run_machine_rates(config: &Config, machine: &MachineModel) -> Vec<(f64, f64)> {
    let stencil = Stencil::star13();
    let pool = ThreadPool::with_default_parallelism();
    let pairs = sweep_pairs(config);
    pool.scope_map(pairs.len(), |i| {
        let (n1, n2) = pairs[i];
        let grid = GridDesc::new(&[n1, n2, config.n3]);
        let rep = measure_machine(&grid, &stencil, machine, OrderKind::Natural, 1);
        let tlb = rep.levels.get(Level::Tlb).map(|s| s.misses()).unwrap_or(0);
        let tlb_rate = if rep.points == 0 { 0.0 } else { tlb as f64 / rep.points as f64 };
        (rep.misses_per_point(), tlb_rate)
    })
}

/// The §6 correlation between Plot A and Plot B, plus the hyperbola fit
/// and the TLB spike-association row. One full-machine sweep feeds both
/// columns: its L1 rates are bit-identical to [`run_a`]'s (see
/// [`run_machine_rates`]), so the miss-spike map is not re-simulated.
pub fn run_corr(config: Config) -> Vec<Table> {
    let machine = MachineModel { l1: config.cache, ..MachineModel::r10000_full() };
    let page_modulus = machine.page_modulus().expect("r10000-full has a TLB");
    let pairs = sweep_pairs(&config);
    let machine_rates = run_machine_rates(&config, &machine);
    let l1_rates: Vec<f64> = machine_rates.iter().map(|r| r.0).collect();
    let a = plot_a_from_rates(&config, &pairs, &l1_rates);
    let ns: Vec<usize> = config.n_range.clone().collect();
    let mut both = 0usize;
    let mut only_a = 0usize;
    let mut only_b = 0usize;
    let mut neither = 0usize;
    let mut hyperbola_hits = 0usize;
    let mut spikes_on_hyperbola = 0usize;
    let s_half = config.cache.lattice_modulus() as f64 / 2.0;
    for &(n1, n2, _, spike) in &a.cells {
        let lat = InterferenceLattice::new(&[n1, n2, 50], config.cache.lattice_modulus());
        let short = lat.min_l1(config.short_bar - 1).is_some();
        match (spike, short) {
            (true, true) => both += 1,
            (true, false) => only_a += 1,
            (false, true) => only_b += 1,
            (false, false) => neither += 1,
        }
        // hyperbola proximity: n1 n2 within 1.5% of k·S/2
        let prod = (n1 * n2) as f64;
        let k = (prod / s_half).round();
        let near = k >= 1.0 && (prod - k * s_half).abs() / s_half <= 0.015;
        if near {
            hyperbola_hits += 1;
            if spike {
                spikes_on_hyperbola += 1;
            }
        }
    }
    let phi = stats::phi_coefficient(both, only_a, only_b, neither);

    // --- TLB column: the same sweep's TLB rates, associated with short
    // vectors of the page interference lattice ---
    let tlb_rates: Vec<f64> = machine_rates.iter().map(|r| r.1).collect();
    let tlb_median = median_rate(&tlb_rates);
    let (mut t_both, mut t_only_spike, mut t_only_short, mut t_neither) = (0usize, 0usize, 0usize, 0usize);
    for (&(n1, n2), &rate) in pairs.iter().zip(&tlb_rates) {
        let spike = rate > config.threshold * tlb_median && rate > 0.0;
        let short = InterferenceLattice::new(&[n1, n2, 50], page_modulus).min_l1(config.short_bar - 1).is_some();
        match (spike, short) {
            (true, true) => t_both += 1,
            (true, false) => t_only_spike += 1,
            (false, true) => t_only_short += 1,
            (false, false) => t_neither += 1,
        }
    }
    let phi_tlb = stats::phi_coefficient(t_both, t_only_spike, t_only_short, t_neither);

    let total = ns.len() * ns.len();
    let mut t = Table::new("FIG5 correlation: miss spikes vs short lattice vectors", &["metric", "value", "paper"]);
    t.add_row(vec!["grids".into(), total.to_string(), "3600".into()]);
    t.add_row(vec!["spike ∧ short-vector".into(), both.to_string(), "—".into()]);
    t.add_row(vec!["spike only".into(), only_a.to_string(), "—".into()]);
    t.add_row(vec!["short-vector only".into(), only_b.to_string(), "—".into()]);
    t.add_row(vec!["neither".into(), neither.to_string(), "—".into()]);
    t.add_row(vec!["phi association (L1)".into(), format!("{phi:.3}"), "\"good correlation\" (§6)".into()]);
    t.add_row(vec![
        "spike rate on n1·n2 ≈ k·S/2 hyperbolae".into(),
        format!("{spikes_on_hyperbola}/{hyperbola_hits}"),
        "plots fitted well by hyperbolae".into(),
    ]);
    t.add_row(vec![
        "tlb spike ∧ page short-vector".into(),
        format!("{t_both}/{}", t_both + t_only_spike + t_only_short + t_neither),
        "—".into(),
    ]);
    t.add_row(vec![
        "phi association (TLB)".into(),
        format!("{phi_tlb:.3}"),
        "spikes correlate \"for the TLB as well\" (§6)".into(),
    ]);
    println!("{}", t.to_text());
    save_csv(&t, "fig5corr");
    vec![a.table, t]
}

/// ASCII density map over (n1, n2).
fn render_map(title: &str, config: &Config, marks: &[(usize, usize, bool)]) -> String {
    let lo = config.n_range.start;
    let hi = config.n_range.end;
    let w = hi - lo;
    let mut canvas = vec![vec!['·'; w]; w];
    for &(n1, n2, m) in marks {
        if m {
            canvas[n2 - lo][n1 - lo] = '■';
        }
    }
    let mut out = format!("{title}  (x: n1 {lo}..{hi}, y: n2 {lo}..{hi})\n");
    for row in canvas.iter().rev() {
        out.push_str("  ");
        out.push_str(&row.iter().collect::<String>());
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Config {
        Config { n_range: 44..47, n3: 6, cache: CacheParams::r10000(), threshold: 1.15, short_bar: 8 }
    }

    #[test]
    fn fig5b_flags_45_91_family() {
        // 45·91 = 4095: within the tiny range we still see 45×45? 45·45 =
        // 2025 ≈ 2048·0.989 — just off the k=1 hyperbola; (1,0,1)-style
        // vectors need n1·n2 ≡ ±small (mod 4096). Check a wider-known cell:
        // run the driver and just assert structural integrity here.
        let t = run_b(tiny());
        for row in t.rows() {
            let m: i64 = row[2].parse().unwrap();
            assert!(m < 8);
        }
    }

    #[test]
    fn fig5a_runs_and_reports() {
        let a = run_a(tiny());
        assert_eq!(a.cells.len(), 9);
        assert!(a.cells.iter().all(|c| c.2 > 0.0));
    }

    #[test]
    fn corr_counts_partition_grid() {
        let tables = run_corr(tiny());
        let t = &tables[1];
        let total: usize = t.rows()[0][1].parse().unwrap();
        let parts: usize = (1..=4).map(|i| t.rows()[i][1].parse::<usize>().unwrap()).sum();
        assert_eq!(total, parts);
        assert_eq!(total, 9);
    }

    #[test]
    fn corr_emits_l1_and_tlb_association_rows() {
        let tables = run_corr(tiny());
        let t = &tables[1];
        let labels: Vec<&str> = t.rows().iter().map(|r| r[0].as_str()).collect();
        assert!(labels.contains(&"phi association (L1)"), "{labels:?}");
        assert!(labels.contains(&"phi association (TLB)"), "{labels:?}");
        // the TLB partition row covers the whole sweep
        let row = t.rows().iter().find(|r| r[0] == "tlb spike ∧ page short-vector").unwrap();
        let (num, den) = row[1].split_once('/').unwrap();
        let _: usize = num.parse().unwrap();
        assert_eq!(den.parse::<usize>().unwrap(), 9);
    }

    #[test]
    fn machine_rates_cover_sweep_and_match_single_level_l1() {
        let config = tiny();
        let machine = MachineModel { l1: config.cache, ..MachineModel::r10000_full() };
        let cells = run_machine_rates(&config, &machine);
        assert_eq!(cells.len(), 9);
        assert!(cells.iter().all(|c| c.0.is_finite() && c.1.is_finite() && c.1 >= 0.0));
        // the L1 column of the full-machine sweep is the single-level sweep
        let stencil = Stencil::star13();
        for (&(n1, n2), &(l1_rate, _)) in sweep_pairs(&config).iter().zip(&cells) {
            let grid = GridDesc::new(&[n1, n2, config.n3]);
            let rep = measure(&grid, &stencil, config.cache, OrderKind::Natural, 1);
            assert_eq!(rep.misses_per_point(), l1_rate, "{n1}x{n2}");
        }
    }
}
