//! **MULTIRHS** — §5: stencil computations with p right-hand-side arrays.
//!
//! Measures u-loads per point for p ∈ {1, 2, 4} under (a) natural order +
//! contiguous array placement and (b) cache fitting + the §5 offset
//! assignment (`addr_i = addr_1 + m_i·S + s_i`), against the Eq 13 lower
//! and Eq 14 upper bounds. The paper's claim: the offset assignment keeps
//! the tiles' cache images disjoint, so fitting stays near p·|G| loads
//! while the naive layout degrades with p (arrays whose spans are close to
//! multiples of S collide wholesale).

use super::{measure_contiguous, measure_with_offsets, save_csv, OrderKind};
use crate::bounds::{lower_bound_loads_multi, upper_bound_loads_multi};
use crate::cache::CacheParams;
use crate::grid::GridDesc;
use crate::lattice::InterferenceLattice;
use crate::report::Table;
use crate::stencil::Stencil;

pub fn run(quick: bool) -> Table {
    let cache = CacheParams::new(2, 128, 4); // S = 1024
    let s = cache.size_words();
    let dims: Vec<usize> = if quick { vec![33, 29, 12] } else { vec![33, 29, 40] };
    let grid = GridDesc::new(&dims);
    let stencil = Stencil::star(3, 1);
    let lat = InterferenceLattice::new(grid.storage_dims(), s);
    let g = grid.num_points() as f64;

    let mut table = Table::new(
        &format!("MULTIRHS: loads/point for p RHS arrays, grid {dims:?}, S={s}"),
        &["p", "Eq13 lb /pt", "natural+contig /pt", "fitting+offsets /pt", "Eq14 ub /pt", "fit within bounds"],
    );
    for p in [1usize, 2, 4] {
        let nat = measure_contiguous(&grid, &stencil, cache, OrderKind::Natural, p);
        let fit = measure_with_offsets(&grid, &stencil, cache, OrderKind::Auto, p);
        let lb = lower_bound_loads_multi(&grid, s, p) / g;
        let ub = upper_bound_loads_multi(&grid, s, stencil.radius() as u32, lat.eccentricity(), p) / g;
        let natpp = nat.u_loads as f64 / g;
        let fitpp = fit.u_loads as f64 / g;
        let ok = lb <= fitpp * 1.001 && fitpp <= ub * 1.001;
        table.add_row(vec![
            p.to_string(),
            format!("{lb:.3}"),
            format!("{natpp:.3}"),
            format!("{fitpp:.3}"),
            format!("{ub:.3}"),
            if ok { "YES".into() } else { "NO".into() },
        ]);
    }
    println!("{}", table.to_text());
    save_csv(&table, "multirhs");
    table
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fitting_within_bounds_for_all_p() {
        let t = run(true);
        assert_eq!(t.num_rows(), 3);
        for row in t.rows() {
            assert_eq!(row[5], "YES", "row {row:?}");
        }
    }

    #[test]
    fn loads_scale_roughly_with_p() {
        let t = run(true);
        let p1: f64 = t.rows()[0][3].parse().unwrap();
        let p4: f64 = t.rows()[2][3].parse().unwrap();
        // per-point loads grow ≥ p-proportionally (4×) but stay bounded.
        assert!(p4 > 3.5 * p1, "p4 {p4} vs p1 {p1}");
        assert!(p4 < 8.0 * p1, "p4 {p4} vs p1 {p1}");
    }
}
