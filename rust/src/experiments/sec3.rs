//! **SEC3EX** — the §3 example showing the lower bound's order is tight.
//!
//! 2-D grid with `n1 = k·S`, star stencil (r = 1), associativity `a`
//! exceeding the stencil diameter of 3. The strip order with width `S/a`
//! incurs exactly
//!
//! ```text
//! loads(u) = n1·n2·(1 − 2/n1 + 2a(1 − 2/n2)/S)
//! ```
//!
//! We run the strip traversal through the simulator and compare the
//! measured u-loads against the closed form and against Eq 7's lower bound
//! (measured ≥ bound, and within the same order).

use super::{save_csv, OrderKind};
use crate::bounds::{lower_bound_loads, sec3_example_loads};
use crate::cache::CacheParams;
use crate::grid::GridDesc;
use crate::report::Table;
use crate::stencil::Stencil;

/// Run with a small-S cache so the sweep is fast; `quick` shrinks n2.
pub fn run(quick: bool) -> Table {
    // a = 4 > diameter 3, as the example requires (a > 2r+1).
    let a = 4usize;
    let z = 64usize;
    let w = 1usize;
    let cache = CacheParams::new(a, z, w);
    let s = cache.size_words(); // 256
    let n2 = if quick { 64 } else { 200 };

    let mut table = Table::new(
        &format!("SEC3: strip order on n1 = k·S grids (S={s}, a={a}, star r=1)"),
        &["k", "n1", "n2", "measured u-loads", "closed form", "rel err", "Eq7 lower bound", "measured/|G|"],
    );
    for k in 1..=3usize {
        let n1 = k * s;
        let grid = GridDesc::new(&[n1, n2]);
        let stencil = Stencil::star(2, 1);
        let rep = super::measure(&grid, &stencil, cache, OrderKind::Strip(s / a), 1);
        let formula = sec3_example_loads(n1 as u64, n2 as u64, s as u64, a as u64, 1);
        let lb = lower_bound_loads(&grid, s);
        let rel = (rep.u_loads as f64 - formula).abs() / formula;
        table.add_row(vec![
            k.to_string(),
            n1.to_string(),
            n2.to_string(),
            rep.u_loads.to_string(),
            format!("{formula:.0}"),
            format!("{:.4}", rel),
            format!("{lb:.0}"),
            format!("{:.4}", rep.u_loads as f64 / grid.num_points() as f64),
        ]);
    }
    println!("{}", table.to_text());
    save_csv(&table, "sec3");
    table
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strip_order_matches_closed_form_within_few_percent() {
        let t = run(true);
        for row in t.rows() {
            let rel: f64 = row[5].parse().unwrap();
            assert!(rel < 0.05, "row {row:?}: rel err {rel}");
        }
    }

    #[test]
    fn measured_loads_at_least_lower_bound() {
        let t = run(true);
        for row in t.rows() {
            let measured: f64 = row[3].parse().unwrap();
            let lb: f64 = row[6].parse().unwrap();
            assert!(measured >= lb * 0.999, "row {row:?}");
        }
    }

    #[test]
    fn loads_per_point_near_one() {
        // The example is near-optimal: ~1.03 loads per grid point.
        let t = run(true);
        for row in t.rows() {
            let per: f64 = row[7].parse().unwrap();
            assert!(per < 1.1, "row {row:?}");
            assert!(per > 0.9, "row {row:?}");
        }
    }
}
