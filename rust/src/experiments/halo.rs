//! **HALO** — measured per-shard ghost traffic vs. the PEM bound.
//!
//! The shard/halo decomposition layer (DESIGN.md §2.9) bounds per-step
//! ghost loads by the parallel-external-memory surface term
//! `Σ_s (Π(ŵ_i + 2r) − Π ŵ_i)` with `ŵ_i = ⌈n_i/g_i⌉`. This driver runs
//! real block-decomposed solves over a ladder of shard grids and tabulates
//! the *measured* `HaloMsg` words per point next to that bound: the
//! measurement counts only in-grid ghost words (shards on the domain
//! boundary clip their halos), so it sits at or below the bound and
//! approaches it as shards move away from the boundary.

use super::save_csv;
use crate::report::Table;
use crate::shard::{self, ShardPlan, ShardStorage};
use crate::solver::NativeBackend;
use crate::stencil::Stencil;
use crate::util::threadpool::ThreadPool;
use std::sync::Arc;

/// Shard-grid ladder: 1 shard (no halo) up through 32 blocks.
fn shard_grids(quick: bool) -> Vec<Vec<usize>> {
    let mut grids = vec![vec![1, 1, 1], vec![2, 1, 1], vec![2, 2, 1], vec![2, 2, 2]];
    if !quick {
        grids.push(vec![4, 2, 2]);
        grids.push(vec![4, 4, 2]);
    }
    grids
}

pub fn run(quick: bool) -> Table {
    let n: usize = if quick { 24 } else { 48 };
    let dims = vec![n, n, n];
    let stencil = Stencil::star13();
    let steps = 2usize;
    let alpha = NativeBackend::stable_alpha(&stencil);
    let pool = ThreadPool::with_default_parallelism();
    let mut table = Table::new(
        &format!("HALO: measured ghost words/point vs PEM bound, {n}³ star13, {steps} steps"),
        // "redundant wpp" counts ghost points recomputed instead of
        // exchanged — identically zero at depth 1, where every ghost word
        // arrives over a HaloMsg (the column exists so the classic ladder
        // and the superstep ladder below read side by side).
        &["shard grid", "shards", "halo msgs/step", "measured wpp", "PEM bound wpp", "meas/bound", "redundant wpp"],
    );
    for g in shard_grids(quick) {
        let plan = Arc::new(ShardPlan::new(&dims, &g, stencil.radius()));
        let out = shard::solve_blocks(&plan, &stencil, alpha, steps, 0xBEEF, &ShardStorage::InMemory, &pool, None)
            .expect("in-memory block solve");
        let points = plan.num_points() as f64;
        let measured = out.halo_words_loaded as f64 / steps as f64 / points;
        let bound = plan.pem_halo_bound_per_point();
        let ratio = if bound > 0.0 { measured / bound } else { 0.0 };
        table.add_row(vec![
            format!("{}x{}x{}", g[0], g[1], g[2]),
            plan.num_shards().to_string(),
            (out.halo_exchanges / steps as u64).to_string(),
            format!("{measured:.4}"),
            format!("{bound:.4}"),
            format!("{ratio:.2}"),
            format!("{:.4}", out.halo_redundant_words as f64 / steps as f64 / points),
        ]);
    }
    println!("{}", table.to_text());
    save_csv(&table, "halo");
    table
}

/// Superstep-depth ladder (DESIGN.md §2.12): the same 2×2×2 decomposition
/// swept `k` steps per exchange round. Exchange rounds drop to `⌈steps/k⌉`
/// while ghost cells inside the deepened halo are recomputed redundantly —
/// the table shows both sides of that trade, plus the final norm, which is
/// identical down the ladder because the superstep path is bitwise equal
/// to `k` classic steps.
pub fn run_temporal(quick: bool) -> Table {
    let n: usize = if quick { 24 } else { 48 };
    let dims = vec![n, n, n];
    let stencil = Stencil::star13();
    let steps = 8usize;
    let alpha = NativeBackend::stable_alpha(&stencil);
    let pool = ThreadPool::with_default_parallelism();
    let g = vec![2usize, 2, 2];
    let mut table = Table::new(
        &format!("HALO-TEMPORAL: exchange rounds vs redundant recompute, {n}³ star13, grid 2x2x2, {steps} steps"),
        &["k", "rounds", "rounds/step", "exchanged wpp/step", "redundant wpp/step", "final ||u||"],
    );
    for k in [1usize, 2, 4, 8] {
        let plan = Arc::new(ShardPlan::with_depth(&dims, &g, stencil.radius(), k));
        let out = shard::solve_blocks(&plan, &stencil, alpha, steps, 0xBEEF, &ShardStorage::InMemory, &pool, None)
            .expect("in-memory superstep solve");
        let points = plan.num_points() as f64;
        let rounds = out.halo_words_loaded / plan.halo_words().max(1);
        table.add_row(vec![
            k.to_string(),
            rounds.to_string(),
            format!("{:.3}", rounds as f64 / steps as f64),
            format!("{:.4}", out.halo_words_loaded as f64 / steps as f64 / points),
            format!("{:.4}", out.halo_redundant_words as f64 / steps as f64 / points),
            format!("{:.6}", out.final_norm),
        ]);
    }
    println!("{}", table.to_text());
    save_csv(&table, "halo_temporal");
    table
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measured_never_exceeds_bound() {
        let t = run(true);
        assert!(t.num_rows() >= 4);
        for row in t.rows() {
            let measured: f64 = row[3].parse().unwrap();
            let bound: f64 = row[4].parse().unwrap();
            assert!(measured <= bound * 1.0001, "clipped halo must sit under the PEM bound: {row:?}");
        }
    }

    #[test]
    fn classic_ladder_recomputes_nothing() {
        let t = run(true);
        for row in t.rows() {
            assert_eq!(row[6], "0.0000", "depth-1 exchange must not recompute ghost cells: {row:?}");
        }
    }

    #[test]
    fn temporal_ladder_trades_rounds_for_recompute_at_fixed_answer() {
        let t = run_temporal(true);
        let rows = t.rows();
        assert_eq!(rows.len(), 4);
        // k = 1 is the classic path: one round per step, zero recompute.
        assert_eq!(rows[0][0], "1");
        assert_eq!(rows[0][2], "1.000");
        assert_eq!(rows[0][4], "0.0000");
        for w in rows.windows(2) {
            let (k0, k1): (usize, usize) = (w[0][0].parse().unwrap(), w[1][0].parse().unwrap());
            let (r0, r1): (u64, u64) = (w[0][1].parse().unwrap(), w[1][1].parse().unwrap());
            assert_eq!(r0 as usize, 8usize.div_ceil(k0), "rounds must be ceil(steps/k): {:?}", w[0]);
            assert_eq!(r1 as usize, 8usize.div_ceil(k1), "rounds must be ceil(steps/k): {:?}", w[1]);
            let (c0, c1): (f64, f64) = (w[0][4].parse().unwrap(), w[1][4].parse().unwrap());
            assert!(c1 > c0, "deeper halos must recompute more ghost cells: {c0} vs {c1}");
            // the answer itself does not move down the ladder
            assert_eq!(w[0][5], w[1][5], "superstep depth must not change the solution");
        }
    }

    #[test]
    fn single_shard_has_no_halo_and_traffic_grows_with_shards() {
        let t = run(true);
        let rows = t.rows();
        assert_eq!(rows[0][0], "1x1x1");
        assert_eq!(rows[0][3], "0.0000", "no ghost traffic without shard boundaries: {:?}", rows[0]);
        let first: f64 = rows[1][3].parse().unwrap();
        let last: f64 = rows.last().unwrap()[3].parse().unwrap();
        assert!(last > first, "more shard faces must move more ghost words: {first} vs {last}");
    }
}
