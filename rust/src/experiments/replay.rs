//! Workload replay: deterministic serving traces for the memo tier.
//!
//! The ROADMAP's target traffic is Zipf-skewed over a small set of hot
//! grid shapes, punctuated by one-off sweep scans (parameter studies
//! walking a line of shapes exactly once). This driver generates that
//! trace deterministically from [`crate::util::rng`], replays it through a
//! warm [`Service`], and reports per-phase memo hit rates and request
//! latencies — the serving-layer analog of the paper-figure drivers.
//!
//! Trace structure (all sizes from [`ReplayConfig`]):
//!
//! ```text
//! prefill (×3)  — warm every hot facet past the S3-FIFO promotion bar
//! hot/pre-scan  — Zipf(s) draws over the hot shapes, Plan/Analyze mixed
//! scan          — one-pass sweep of `scan` never-seen shapes (Analyze)
//! hot/post-scan — Zipf draws again: the hot set must still be resident
//! ```
//!
//! The replay is sequential (one request at a time) so latencies and hit
//! counts are exactly reproducible for a given seed.
//!
//! **Open-loop mode** ([`run_open_loop`]) is the tail-latency counterpart:
//! requests arrive on a deterministic Poisson (or bursty) schedule and are
//! dispatched onto a worker pool *regardless of whether earlier requests
//! finished* — the arrival clock never waits for the server, so queueing
//! delay shows up in the sojourn times instead of silently stretching the
//! trace (no coordinated omission). Arrivals past the admission cap are
//! shed at the door, exactly like the TCP front end does.

use crate::coordinator::{Admission, Coordinator, JobKind, PlannerConfig, Service, StencilRequest, StencilSpec};
use crate::report::Table;
use crate::util::rng::Rng;
use crate::util::stats::Summary;
use crate::util::threadpool::ThreadPool;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Configuration of a replay run.
#[derive(Debug, Clone)]
pub struct ReplayConfig {
    /// Total replayed requests (prefill not counted).
    pub requests: usize,
    /// Number of hot shapes.
    pub hot: usize,
    /// Number of one-off shapes in the mid-trace scan sweep.
    pub scan: usize,
    /// Zipf exponent over the hot shapes.
    pub zipf_s: f64,
    pub seed: u64,
    /// Memo-tier byte budget for the replayed service. The default is
    /// sized so the scan overflows it (exercising S3-FIFO eviction) while
    /// the hot set fits comfortably in the main queue.
    pub memo_bytes: usize,
}

impl ReplayConfig {
    /// The EXPERIMENTS.md configuration: ≥ 500 requests over 8 hot shapes
    /// with a 48-shape scan. `quick` shrinks the trace for smoke runs.
    pub fn paper(quick: bool) -> ReplayConfig {
        ReplayConfig {
            requests: if quick { 160 } else { 600 },
            hot: 8,
            scan: if quick { 16 } else { 48 },
            zipf_s: 1.1,
            seed: 0x5EED,
            memo_bytes: 32 * 1024,
        }
    }
}

/// The deterministic hot-shape list: distinct small 3-D grids with even
/// extents (disjoint by construction from [`scan_shapes`], which uses odd
/// extents). Unique for `n ≤ 343`.
pub fn hot_shapes(n: usize) -> Vec<Vec<usize>> {
    (0..n).map(|i| vec![12 + 2 * (i % 7), 14 + 2 * ((i / 7) % 7), 16 + 2 * ((i / 49) % 7)]).collect()
}

/// `n` one-off scan shapes starting at logical offset `offset` — odd
/// extents, so never colliding with [`hot_shapes`]. Unique for
/// `offset + n ≤ 729`.
pub fn scan_shapes(offset: usize, n: usize) -> Vec<Vec<usize>> {
    (offset..offset + n).map(|i| vec![11 + 2 * (i % 9), 13 + 2 * ((i / 9) % 9), 9 + 2 * ((i / 81) % 9)]).collect()
}

/// Discrete Zipf sampler over ranks `0..n` (weight `1/(k+1)^s`).
struct Zipf {
    cum: Vec<f64>,
}

impl Zipf {
    fn new(n: usize, s: f64) -> Zipf {
        assert!(n >= 1);
        let mut cum = Vec::with_capacity(n);
        let mut total = 0.0;
        for k in 0..n {
            total += 1.0 / ((k + 1) as f64).powf(s);
            cum.push(total);
        }
        Zipf { cum }
    }

    fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64() * self.cum.last().copied().unwrap();
        self.cum.iter().position(|&c| u < c).unwrap_or(self.cum.len() - 1)
    }
}

/// `n` Zipf-distributed requests over `shapes`, kinds alternating
/// Plan/Analyze by coin flip. Public so `bench_serving` replays the same
/// traffic shape the experiment does.
pub fn zipf_requests(shapes: &[Vec<usize>], zipf_s: f64, n: usize, rng: &mut Rng) -> Vec<StencilRequest> {
    let zipf = Zipf::new(shapes.len(), zipf_s);
    (0..n)
        .map(|_| {
            let dims = shapes[zipf.sample(rng)].clone();
            let kind = if rng.below(2) == 0 { JobKind::Plan } else { JobKind::Analyze };
            StencilRequest { dims, stencil: StencilSpec::Star13, rhs_arrays: 1, kind }
        })
        .collect()
}

fn scan_requests(shapes: &[Vec<usize>]) -> Vec<StencilRequest> {
    shapes
        .iter()
        .map(|dims| StencilRequest {
            dims: dims.clone(),
            stencil: StencilSpec::Star13,
            rhs_arrays: 1,
            kind: JobKind::Analyze,
        })
        .collect()
}

/// The three trace phases (pre-scan hot, scan, post-scan hot), generated
/// deterministically from the config.
pub fn generate_trace(cfg: &ReplayConfig) -> [Vec<StencilRequest>; 3] {
    let hot = hot_shapes(cfg.hot);
    let mut rng = Rng::new(cfg.seed);
    let scan_n = cfg.scan.min(cfg.requests / 2);
    let hot_total = cfg.requests - scan_n;
    let pre = hot_total / 2;
    [
        zipf_requests(&hot, cfg.zipf_s, pre, &mut rng),
        scan_requests(&scan_shapes(0, scan_n)),
        zipf_requests(&hot, cfg.zipf_s, hot_total - pre, &mut rng),
    ]
}

/// Per-phase replay measurements.
#[derive(Debug, Clone)]
pub struct Phase {
    pub name: &'static str,
    pub requests: u64,
    pub hits: u64,
    pub p50_us: f64,
    pub p90_us: f64,
}

impl Phase {
    pub fn hit_rate(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.hits as f64 / self.requests as f64
        }
    }
}

/// Outcome of a replay run.
#[derive(Debug, Clone)]
pub struct ReplayOutcome {
    pub table: Table,
    pub phases: Vec<Phase>,
    pub total_requests: u64,
    pub total_hits: u64,
    /// Memo misses on hot-shape requests *after* the scan — 0 iff the hot
    /// set survived the sweep (the scan-resistance claim).
    pub hot_misses_after_scan: u64,
    pub memo_evictions: u64,
    /// The serving coordinator's final metrics snapshot.
    pub metrics_json: String,
}

impl ReplayOutcome {
    pub fn hit_rate(&self) -> f64 {
        if self.total_requests == 0 {
            0.0
        } else {
            self.total_hits as f64 / self.total_requests as f64
        }
    }

    pub fn hot_set_retained(&self) -> bool {
        self.hot_misses_after_scan == 0
    }
}

/// Replay the configured trace through a fresh memoizing service and
/// measure per-phase hit rates and latencies.
pub fn run(cfg: &ReplayConfig) -> ReplayOutcome {
    let mut coord = Coordinator::analysis_only(PlannerConfig::default());
    coord.configure_memo(Some(cfg.memo_bytes));
    let svc = Service::over(coord);

    // Warm-up: three prefill passes leave every hot facet with frequency
    // ≥ 2, past the S3-FIFO promotion bar — so when the scan later forces
    // evictions, the hot entries are promoted into the main queue instead
    // of demoted to ghost history. (Pass 1 inserts, passes 2–3 hit.)
    let hot = hot_shapes(cfg.hot);
    for _ in 0..3 {
        svc.prefill(&hot, 1);
    }

    let trace = generate_trace(cfg);
    let metrics = svc.coordinator().metrics();
    let mut phases = Vec::new();
    for (name, reqs) in ["hot/pre-scan", "scan", "hot/post-scan"].into_iter().zip(trace.iter()) {
        let hits0 = metrics.sim_memo_hits.load(Ordering::Relaxed);
        let mut lat_us: Vec<f64> = Vec::with_capacity(reqs.len());
        for req in reqs {
            // sequential replay: deterministic hits and honest per-request
            // latency (no queueing delay folded in)
            let resp = svc.coordinator().submit(req).expect("replay requests are valid");
            lat_us.push(resp.wall_micros as f64);
        }
        let s = Summary::of(&lat_us);
        phases.push(Phase {
            name,
            requests: reqs.len() as u64,
            hits: metrics.sim_memo_hits.load(Ordering::Relaxed) - hits0,
            p50_us: s.p50,
            p90_us: s.p90,
        });
    }

    let title = format!(
        "workload replay: Zipf(s={}) over {} hot shapes + {}-shape scan, seed {:#x}",
        cfg.zipf_s, cfg.hot, phases[1].requests, cfg.seed
    );
    let mut table = Table::new(&title, &["phase", "requests", "memo hits", "hit rate", "p50 µs", "p90 µs"]);
    for p in &phases {
        table.add_row(vec![
            p.name.to_string(),
            p.requests.to_string(),
            p.hits.to_string(),
            format!("{:5.1}%", 100.0 * p.hit_rate()),
            format!("{:.0}", p.p50_us),
            format!("{:.0}", p.p90_us),
        ]);
    }
    let total_requests: u64 = phases.iter().map(|p| p.requests).sum();
    let total_hits: u64 = phases.iter().map(|p| p.hits).sum();
    table.add_row(vec![
        "total".to_string(),
        total_requests.to_string(),
        total_hits.to_string(),
        format!("{:5.1}%", if total_requests == 0 { 0.0 } else { 100.0 * total_hits as f64 / total_requests as f64 }),
        String::new(),
        String::new(),
    ]);

    let post = &phases[2];
    let hot_misses_after_scan = post.requests - post.hits;
    ReplayOutcome {
        table,
        total_requests,
        total_hits,
        hot_misses_after_scan,
        memo_evictions: metrics.memo_evictions.load(Ordering::Relaxed),
        metrics_json: svc.metrics_json(),
        phases,
    }
}

/// Arrival process for the open-loop replay.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Arrivals {
    /// Independent exponential gaps (memoryless, rate `rate_rps`).
    Poisson,
    /// `burst` back-to-back arrivals, then an exponential gap with mean
    /// `burst / rate_rps` — same average rate, much nastier tail.
    Bursty { burst: usize },
}

impl Arrivals {
    pub fn label(&self) -> String {
        match self {
            Arrivals::Poisson => "poisson".to_string(),
            Arrivals::Bursty { burst } => format!("bursty{burst}x"),
        }
    }
}

/// Configuration of an open-loop replay run.
#[derive(Debug, Clone)]
pub struct OpenLoopConfig {
    /// Arrivals to generate.
    pub requests: usize,
    /// Offered load in requests per second.
    pub rate_rps: f64,
    pub arrivals: Arrivals,
    /// Number of hot shapes (Zipf-drawn, like the closed-loop trace).
    pub hot: usize,
    pub zipf_s: f64,
    pub seed: u64,
    pub memo_bytes: usize,
    /// Admission cap: arrivals beyond this many in-flight requests are
    /// shed immediately (never queued).
    pub inflight_cap: usize,
    /// Dispatch workers draining admitted requests.
    pub workers: usize,
}

impl OpenLoopConfig {
    /// The EXPERIMENTS.md configuration: 2 krps over 8 hot shapes, cap 32.
    /// `quick` shrinks the trace for smoke runs.
    pub fn paper(quick: bool) -> OpenLoopConfig {
        OpenLoopConfig {
            requests: if quick { 160 } else { 480 },
            rate_rps: 2000.0,
            arrivals: Arrivals::Poisson,
            hot: 8,
            zipf_s: 1.1,
            seed: 0x0427,
            memo_bytes: 64 * 1024,
            inflight_cap: 32,
            workers: 4,
        }
    }
}

/// One exponential inter-arrival gap (seconds) with the given mean.
fn exp_gap(rng: &mut Rng, mean_s: f64) -> f64 {
    // 1 - u ∈ (0, 1]: ln never sees 0
    -(1.0 - rng.f64()).ln() * mean_s
}

/// The deterministic arrival schedule: microsecond offsets from the start
/// of the run, nondecreasing, mean rate `rate_rps` for either process.
pub fn arrival_offsets_us(cfg: &OpenLoopConfig) -> Vec<u64> {
    let mut rng = Rng::new(cfg.seed ^ 0xA221_7A15);
    let mut t = 0.0f64;
    let mut out = Vec::with_capacity(cfg.requests);
    match cfg.arrivals {
        Arrivals::Poisson => {
            for _ in 0..cfg.requests {
                t += exp_gap(&mut rng, 1.0 / cfg.rate_rps);
                out.push((t * 1e6) as u64);
            }
        }
        Arrivals::Bursty { burst } => {
            let burst = burst.max(1);
            while out.len() < cfg.requests {
                t += exp_gap(&mut rng, burst as f64 / cfg.rate_rps);
                for _ in 0..burst.min(cfg.requests - out.len()) {
                    out.push((t * 1e6) as u64);
                }
            }
        }
    }
    out
}

/// `sorted` must be ascending; returns the rank-`ceil(q·n)` element
/// (0 when empty) — same convention as `Histogram::quantile_us`.
fn percentile_sorted(sorted: &[u64], q: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let rank = ((q * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len());
    sorted[rank - 1]
}

/// Outcome of an open-loop replay run.
#[derive(Debug, Clone)]
pub struct OpenLoopOutcome {
    /// `poisson` / `bursty32x` — the arrival process label.
    pub label: String,
    pub offered_rps: f64,
    pub requests: u64,
    /// Requests that ran to a successful response.
    pub completed: u64,
    /// Requests shed at the admission door.
    pub shed: u64,
    /// Admitted requests that answered an error.
    pub errors: u64,
    /// `single_flight_collapsed` over the run (the trace starts cold, so
    /// the first burst on a hot shape collapses onto one computation).
    pub collapsed: u64,
    /// Sojourn percentiles, measured from the *scheduled* arrival time —
    /// dispatcher lag counts against the server (no coordinated omission).
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub p999_ms: f64,
    pub achieved_rps: f64,
    pub metrics_json: String,
}

impl OpenLoopOutcome {
    pub fn shed_rate(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.shed as f64 / self.requests as f64
        }
    }
}

/// Replay a deterministic open-loop arrival schedule against a fresh
/// memoizing service with bounded admission, and measure the sojourn tail.
///
/// The service starts **cold** on purpose: the opening burst of Zipf
/// rank-0 requests is the single-flight demonstration — N concurrent
/// misses on one key, one computation, `collapsed` > 0 in the outcome.
pub fn run_open_loop(cfg: &OpenLoopConfig) -> OpenLoopOutcome {
    let mut coord = Coordinator::analysis_only(PlannerConfig::default());
    coord.configure_memo(Some(cfg.memo_bytes));
    let svc = Arc::new(Service::over(coord));

    let hot = hot_shapes(cfg.hot);
    let mut rng = Rng::new(cfg.seed);
    let reqs = zipf_requests(&hot, cfg.zipf_s, cfg.requests, &mut rng);
    let offsets = arrival_offsets_us(cfg);

    let pool = ThreadPool::new(cfg.workers.max(1));
    let admission = Admission::new(cfg.inflight_cap);
    let sojourns: Arc<Mutex<Vec<u64>>> = Arc::new(Mutex::new(Vec::with_capacity(cfg.requests)));
    let errors = Arc::new(AtomicU64::new(0));
    let mut shed = 0u64;

    let t0 = Instant::now();
    for (req, &offset_us) in reqs.into_iter().zip(&offsets) {
        let target = Duration::from_micros(offset_us);
        let now = t0.elapsed();
        if target > now {
            std::thread::sleep(target - now);
        }
        // Shed at the door, not in a queue: open-loop arrivals never slow
        // down because the server is busy — the cap is the only backstop.
        let Some(permit) = Admission::try_acquire(&admission) else {
            shed += 1;
            continue;
        };
        let svc = Arc::clone(&svc);
        let sojourns = Arc::clone(&sojourns);
        let errors = Arc::clone(&errors);
        pool.submit(move || {
            let result = svc.coordinator().submit_caught(&req);
            if result.is_err() {
                errors.fetch_add(1, Ordering::Relaxed);
            }
            let sojourn_us = (t0.elapsed().as_micros() as u64).saturating_sub(offset_us);
            sojourns.lock().unwrap_or_else(std::sync::PoisonError::into_inner).push(sojourn_us);
            drop(permit);
        });
    }
    pool.wait_idle();
    let elapsed_s = t0.elapsed().as_secs_f64().max(1e-9);

    let mut lat: Vec<u64> = {
        let guard = sojourns.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        guard.clone()
    };
    lat.sort_unstable();
    let errors = errors.load(Ordering::Relaxed);
    let metrics = svc.coordinator().metrics();
    OpenLoopOutcome {
        label: cfg.arrivals.label(),
        offered_rps: cfg.rate_rps,
        requests: cfg.requests as u64,
        completed: lat.len() as u64 - errors,
        shed,
        errors,
        collapsed: metrics.single_flight_collapsed.load(Ordering::Relaxed),
        p50_ms: percentile_sorted(&lat, 0.50) as f64 / 1e3,
        p99_ms: percentile_sorted(&lat, 0.99) as f64 / 1e3,
        p999_ms: percentile_sorted(&lat, 0.999) as f64 / 1e3,
        achieved_rps: lat.len() as f64 / elapsed_s,
        metrics_json: svc.metrics_json(),
    }
}

/// Render open-loop outcomes side by side (the EXPERIMENTS.md table).
pub fn open_loop_table(outs: &[OpenLoopOutcome]) -> Table {
    let mut table = Table::new(
        "open-loop serving: deterministic arrivals vs sojourn tail (measured from scheduled arrival)",
        &["arrivals", "offered rps", "requests", "shed %", "p50 ms", "p99 ms", "p99.9 ms", "collapsed"],
    );
    for o in outs {
        table.add_row(vec![
            o.label.clone(),
            format!("{:.0}", o.offered_rps),
            o.requests.to_string(),
            format!("{:4.1}%", 100.0 * o.shed_rate()),
            format!("{:.2}", o.p50_ms),
            format!("{:.2}", o.p99_ms),
            format!("{:.2}", o.p999_ms),
            o.collapsed.to_string(),
        ]);
    }
    table
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_families_are_distinct_and_disjoint() {
        let hot = hot_shapes(40);
        let scan = scan_shapes(0, 80);
        let mut all: Vec<&Vec<usize>> = hot.iter().chain(scan.iter()).collect();
        let n = all.len();
        all.sort();
        all.dedup();
        assert_eq!(all.len(), n, "hot/scan shapes must be pairwise distinct");
    }

    #[test]
    fn zipf_prefers_low_ranks() {
        let z = Zipf::new(8, 1.1);
        let mut rng = Rng::new(9);
        let mut counts = [0usize; 8];
        for _ in 0..4000 {
            counts[z.sample(&mut rng)] += 1;
        }
        assert!(counts[0] > counts[3], "{counts:?}");
        assert!(counts[0] > counts[7] * 3, "{counts:?}");
        assert!(counts.iter().all(|&c| c > 0), "all ranks must appear: {counts:?}");
    }

    #[test]
    fn trace_is_deterministic_and_sized() {
        let cfg = ReplayConfig::paper(true);
        let a = generate_trace(&cfg);
        let b = generate_trace(&cfg);
        let total: usize = a.iter().map(|p| p.len()).sum();
        assert_eq!(total, cfg.requests);
        assert_eq!(a[1].len(), cfg.scan);
        for (x, y) in a.iter().flatten().zip(b.iter().flatten()) {
            assert_eq!(x.dims, y.dims);
            assert_eq!(format!("{:?}", x.kind), format!("{:?}", y.kind));
        }
    }

    #[test]
    fn quick_replay_hits_and_reports() {
        let out = run(&ReplayConfig::paper(true));
        assert_eq!(out.total_requests, 160);
        assert!(out.hit_rate() > 0.5, "hit rate {}", out.hit_rate());
        assert!(out.hot_set_retained());
        assert_eq!(out.table.num_rows(), 4);
        assert!(out.metrics_json.contains("sim_memo_hits"));
    }

    #[test]
    fn arrival_offsets_deterministic_and_rate_matched() {
        let cfg = OpenLoopConfig::paper(true);
        let a = arrival_offsets_us(&cfg);
        let b = arrival_offsets_us(&cfg);
        assert_eq!(a, b, "schedule must be a pure function of the config");
        assert_eq!(a.len(), cfg.requests);
        assert!(a.windows(2).all(|w| w[0] <= w[1]), "offsets nondecreasing");
        // mean gap ≈ 1/rate: the span of n arrivals concentrates around
        // n/rate (CV of the sum is 1/√n ≈ 8% here; 3σ bounds)
        let span_s = *a.last().unwrap() as f64 / 1e6;
        let expect = cfg.requests as f64 / cfg.rate_rps;
        assert!(span_s > expect * 0.7 && span_s < expect * 1.3, "span {span_s} vs {expect}");
    }

    #[test]
    fn bursty_arrivals_share_offsets_within_a_burst() {
        let cfg = OpenLoopConfig { arrivals: Arrivals::Bursty { burst: 8 }, ..OpenLoopConfig::paper(true) };
        let offs = arrival_offsets_us(&cfg);
        assert_eq!(offs.len(), cfg.requests);
        // every burst of 8 arrives at one instant (zero intra-burst gaps)
        for chunk in offs.chunks(8) {
            assert!(chunk.iter().all(|&t| t == chunk[0]), "{chunk:?}");
        }
        // distinct bursts are separated (exponential gaps are a.s. > 0)
        assert!(offs[0] < offs[8]);
    }

    #[test]
    fn percentile_sorted_pinned() {
        assert_eq!(percentile_sorted(&[], 0.5), 0);
        let xs: Vec<u64> = (1..=10).collect();
        assert_eq!(percentile_sorted(&xs, 0.50), 5);
        assert_eq!(percentile_sorted(&xs, 0.99), 10);
        assert_eq!(percentile_sorted(&xs, 0.999), 10);
        assert_eq!(percentile_sorted(&[7], 0.5), 7);
    }

    #[test]
    fn quick_open_loop_accounts_for_every_arrival() {
        // tiny, fast config: high rate + small cap forces real shedding
        let cfg = OpenLoopConfig {
            requests: 80,
            rate_rps: 20_000.0,
            inflight_cap: 4,
            workers: 2,
            ..OpenLoopConfig::paper(true)
        };
        let out = run_open_loop(&cfg);
        assert_eq!(out.completed + out.shed + out.errors, out.requests, "{out:?}");
        assert_eq!(out.errors, 0, "hot-shape requests are all valid");
        assert!(out.completed > 0);
        assert!(out.p50_ms <= out.p99_ms && out.p99_ms <= out.p999_ms);
        assert!(out.metrics_json.contains("latency_us"));
        let table = open_loop_table(&[out]);
        assert_eq!(table.num_rows(), 1);
    }
}
