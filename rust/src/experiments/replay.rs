//! Workload replay: deterministic serving traces for the memo tier.
//!
//! The ROADMAP's target traffic is Zipf-skewed over a small set of hot
//! grid shapes, punctuated by one-off sweep scans (parameter studies
//! walking a line of shapes exactly once). This driver generates that
//! trace deterministically from [`crate::util::rng`], replays it through a
//! warm [`Service`], and reports per-phase memo hit rates and request
//! latencies — the serving-layer analog of the paper-figure drivers.
//!
//! Trace structure (all sizes from [`ReplayConfig`]):
//!
//! ```text
//! prefill (×3)  — warm every hot facet past the S3-FIFO promotion bar
//! hot/pre-scan  — Zipf(s) draws over the hot shapes, Plan/Analyze mixed
//! scan          — one-pass sweep of `scan` never-seen shapes (Analyze)
//! hot/post-scan — Zipf draws again: the hot set must still be resident
//! ```
//!
//! The replay is sequential (one request at a time) so latencies and hit
//! counts are exactly reproducible for a given seed.

use crate::coordinator::{Coordinator, JobKind, PlannerConfig, Service, StencilRequest, StencilSpec};
use crate::report::Table;
use crate::util::rng::Rng;
use crate::util::stats::Summary;
use std::sync::atomic::Ordering;

/// Configuration of a replay run.
#[derive(Debug, Clone)]
pub struct ReplayConfig {
    /// Total replayed requests (prefill not counted).
    pub requests: usize,
    /// Number of hot shapes.
    pub hot: usize,
    /// Number of one-off shapes in the mid-trace scan sweep.
    pub scan: usize,
    /// Zipf exponent over the hot shapes.
    pub zipf_s: f64,
    pub seed: u64,
    /// Memo-tier byte budget for the replayed service. The default is
    /// sized so the scan overflows it (exercising S3-FIFO eviction) while
    /// the hot set fits comfortably in the main queue.
    pub memo_bytes: usize,
}

impl ReplayConfig {
    /// The EXPERIMENTS.md configuration: ≥ 500 requests over 8 hot shapes
    /// with a 48-shape scan. `quick` shrinks the trace for smoke runs.
    pub fn paper(quick: bool) -> ReplayConfig {
        ReplayConfig {
            requests: if quick { 160 } else { 600 },
            hot: 8,
            scan: if quick { 16 } else { 48 },
            zipf_s: 1.1,
            seed: 0x5EED,
            memo_bytes: 32 * 1024,
        }
    }
}

/// The deterministic hot-shape list: distinct small 3-D grids with even
/// extents (disjoint by construction from [`scan_shapes`], which uses odd
/// extents). Unique for `n ≤ 343`.
pub fn hot_shapes(n: usize) -> Vec<Vec<usize>> {
    (0..n).map(|i| vec![12 + 2 * (i % 7), 14 + 2 * ((i / 7) % 7), 16 + 2 * ((i / 49) % 7)]).collect()
}

/// `n` one-off scan shapes starting at logical offset `offset` — odd
/// extents, so never colliding with [`hot_shapes`]. Unique for
/// `offset + n ≤ 729`.
pub fn scan_shapes(offset: usize, n: usize) -> Vec<Vec<usize>> {
    (offset..offset + n).map(|i| vec![11 + 2 * (i % 9), 13 + 2 * ((i / 9) % 9), 9 + 2 * ((i / 81) % 9)]).collect()
}

/// Discrete Zipf sampler over ranks `0..n` (weight `1/(k+1)^s`).
struct Zipf {
    cum: Vec<f64>,
}

impl Zipf {
    fn new(n: usize, s: f64) -> Zipf {
        assert!(n >= 1);
        let mut cum = Vec::with_capacity(n);
        let mut total = 0.0;
        for k in 0..n {
            total += 1.0 / ((k + 1) as f64).powf(s);
            cum.push(total);
        }
        Zipf { cum }
    }

    fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64() * self.cum.last().copied().unwrap();
        self.cum.iter().position(|&c| u < c).unwrap_or(self.cum.len() - 1)
    }
}

/// `n` Zipf-distributed requests over `shapes`, kinds alternating
/// Plan/Analyze by coin flip. Public so `bench_serving` replays the same
/// traffic shape the experiment does.
pub fn zipf_requests(shapes: &[Vec<usize>], zipf_s: f64, n: usize, rng: &mut Rng) -> Vec<StencilRequest> {
    let zipf = Zipf::new(shapes.len(), zipf_s);
    (0..n)
        .map(|_| {
            let dims = shapes[zipf.sample(rng)].clone();
            let kind = if rng.below(2) == 0 { JobKind::Plan } else { JobKind::Analyze };
            StencilRequest { dims, stencil: StencilSpec::Star13, rhs_arrays: 1, kind }
        })
        .collect()
}

fn scan_requests(shapes: &[Vec<usize>]) -> Vec<StencilRequest> {
    shapes
        .iter()
        .map(|dims| StencilRequest {
            dims: dims.clone(),
            stencil: StencilSpec::Star13,
            rhs_arrays: 1,
            kind: JobKind::Analyze,
        })
        .collect()
}

/// The three trace phases (pre-scan hot, scan, post-scan hot), generated
/// deterministically from the config.
pub fn generate_trace(cfg: &ReplayConfig) -> [Vec<StencilRequest>; 3] {
    let hot = hot_shapes(cfg.hot);
    let mut rng = Rng::new(cfg.seed);
    let scan_n = cfg.scan.min(cfg.requests / 2);
    let hot_total = cfg.requests - scan_n;
    let pre = hot_total / 2;
    [
        zipf_requests(&hot, cfg.zipf_s, pre, &mut rng),
        scan_requests(&scan_shapes(0, scan_n)),
        zipf_requests(&hot, cfg.zipf_s, hot_total - pre, &mut rng),
    ]
}

/// Per-phase replay measurements.
#[derive(Debug, Clone)]
pub struct Phase {
    pub name: &'static str,
    pub requests: u64,
    pub hits: u64,
    pub p50_us: f64,
    pub p90_us: f64,
}

impl Phase {
    pub fn hit_rate(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.hits as f64 / self.requests as f64
        }
    }
}

/// Outcome of a replay run.
#[derive(Debug, Clone)]
pub struct ReplayOutcome {
    pub table: Table,
    pub phases: Vec<Phase>,
    pub total_requests: u64,
    pub total_hits: u64,
    /// Memo misses on hot-shape requests *after* the scan — 0 iff the hot
    /// set survived the sweep (the scan-resistance claim).
    pub hot_misses_after_scan: u64,
    pub memo_evictions: u64,
    /// The serving coordinator's final metrics snapshot.
    pub metrics_json: String,
}

impl ReplayOutcome {
    pub fn hit_rate(&self) -> f64 {
        if self.total_requests == 0 {
            0.0
        } else {
            self.total_hits as f64 / self.total_requests as f64
        }
    }

    pub fn hot_set_retained(&self) -> bool {
        self.hot_misses_after_scan == 0
    }
}

/// Replay the configured trace through a fresh memoizing service and
/// measure per-phase hit rates and latencies.
pub fn run(cfg: &ReplayConfig) -> ReplayOutcome {
    let mut coord = Coordinator::analysis_only(PlannerConfig::default());
    coord.configure_memo(Some(cfg.memo_bytes));
    let svc = Service::over(coord);

    // Warm-up: three prefill passes leave every hot facet with frequency
    // ≥ 2, past the S3-FIFO promotion bar — so when the scan later forces
    // evictions, the hot entries are promoted into the main queue instead
    // of demoted to ghost history. (Pass 1 inserts, passes 2–3 hit.)
    let hot = hot_shapes(cfg.hot);
    for _ in 0..3 {
        svc.prefill(&hot, 1);
    }

    let trace = generate_trace(cfg);
    let metrics = svc.coordinator().metrics();
    let mut phases = Vec::new();
    for (name, reqs) in ["hot/pre-scan", "scan", "hot/post-scan"].into_iter().zip(trace.iter()) {
        let hits0 = metrics.sim_memo_hits.load(Ordering::Relaxed);
        let mut lat_us: Vec<f64> = Vec::with_capacity(reqs.len());
        for req in reqs {
            // sequential replay: deterministic hits and honest per-request
            // latency (no queueing delay folded in)
            let resp = svc.coordinator().submit(req).expect("replay requests are valid");
            lat_us.push(resp.wall_micros as f64);
        }
        let s = Summary::of(&lat_us);
        phases.push(Phase {
            name,
            requests: reqs.len() as u64,
            hits: metrics.sim_memo_hits.load(Ordering::Relaxed) - hits0,
            p50_us: s.p50,
            p90_us: s.p90,
        });
    }

    let title = format!(
        "workload replay: Zipf(s={}) over {} hot shapes + {}-shape scan, seed {:#x}",
        cfg.zipf_s, cfg.hot, phases[1].requests, cfg.seed
    );
    let mut table = Table::new(&title, &["phase", "requests", "memo hits", "hit rate", "p50 µs", "p90 µs"]);
    for p in &phases {
        table.add_row(vec![
            p.name.to_string(),
            p.requests.to_string(),
            p.hits.to_string(),
            format!("{:5.1}%", 100.0 * p.hit_rate()),
            format!("{:.0}", p.p50_us),
            format!("{:.0}", p.p90_us),
        ]);
    }
    let total_requests: u64 = phases.iter().map(|p| p.requests).sum();
    let total_hits: u64 = phases.iter().map(|p| p.hits).sum();
    table.add_row(vec![
        "total".to_string(),
        total_requests.to_string(),
        total_hits.to_string(),
        format!("{:5.1}%", if total_requests == 0 { 0.0 } else { 100.0 * total_hits as f64 / total_requests as f64 }),
        String::new(),
        String::new(),
    ]);

    let post = &phases[2];
    let hot_misses_after_scan = post.requests - post.hits;
    ReplayOutcome {
        table,
        total_requests,
        total_hits,
        hot_misses_after_scan,
        memo_evictions: metrics.memo_evictions.load(Ordering::Relaxed),
        metrics_json: svc.metrics_json(),
        phases,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_families_are_distinct_and_disjoint() {
        let hot = hot_shapes(40);
        let scan = scan_shapes(0, 80);
        let mut all: Vec<&Vec<usize>> = hot.iter().chain(scan.iter()).collect();
        let n = all.len();
        all.sort();
        all.dedup();
        assert_eq!(all.len(), n, "hot/scan shapes must be pairwise distinct");
    }

    #[test]
    fn zipf_prefers_low_ranks() {
        let z = Zipf::new(8, 1.1);
        let mut rng = Rng::new(9);
        let mut counts = [0usize; 8];
        for _ in 0..4000 {
            counts[z.sample(&mut rng)] += 1;
        }
        assert!(counts[0] > counts[3], "{counts:?}");
        assert!(counts[0] > counts[7] * 3, "{counts:?}");
        assert!(counts.iter().all(|&c| c > 0), "all ranks must appear: {counts:?}");
    }

    #[test]
    fn trace_is_deterministic_and_sized() {
        let cfg = ReplayConfig::paper(true);
        let a = generate_trace(&cfg);
        let b = generate_trace(&cfg);
        let total: usize = a.iter().map(|p| p.len()).sum();
        assert_eq!(total, cfg.requests);
        assert_eq!(a[1].len(), cfg.scan);
        for (x, y) in a.iter().flatten().zip(b.iter().flatten()) {
            assert_eq!(x.dims, y.dims);
            assert_eq!(format!("{:?}", x.kind), format!("{:?}", y.kind));
        }
    }

    #[test]
    fn quick_replay_hits_and_reports() {
        let out = run(&ReplayConfig::paper(true));
        assert_eq!(out.total_requests, 160);
        assert!(out.hit_rate() > 0.5, "hit rate {}", out.hit_rate());
        assert!(out.hot_set_retained());
        assert_eq!(out.table.num_rows(), 4);
        assert!(out.metrics_json.contains("sim_memo_hits"));
    }
}
