//! Native numeric solve subsystem: the [`NumericBackend`] trait and its two
//! implementations.
//!
//! The coordinator's numeric jobs (`Execute`, `Solve`) used to be served
//! exclusively by AOT-compiled PJRT artifacts, which means they failed
//! cleanly — but failed — whenever the crate was built without the `pjrt`
//! feature or the artifact bundle was missing. This module closes that gap
//! with a pure-Rust backend that executes [`crate::engine::apply`] over the
//! planner-chosen streaming traversal:
//!
//! - **[`PjrtBackend`]** — the existing artifact path: one executor thread
//!   owns the XLA client (see [`crate::runtime::RuntimeService`]); numeric
//!   work is a channel round-trip per step.
//! - **[`NativeBackend`]** — double-buffered `u`/`q` f64 arrays over the
//!   (possibly padded) storage grid, the stencil applied by the engine
//!   along the planner's traversal, sharded across the worker pool over
//!   disjoint pencil ranges, with per-step residual/L2-norm reductions.
//!
//! The native path is what lets `Solve` run end-to-end in CI (no XLA), and
//! what `bench_numeric` uses to time real stencil FLOPs under each
//! traversal — the same experimental move as the paper's §6 R10000
//! measurements, but on today's hardware.
//!
//! ## Why sharded writes are safe
//!
//! Every [`crate::traversal::Traversal`] partitions its interior into
//! pencils, and `shard_ranges` splits `0..num_pencils()` into disjoint
//! ranges; each interior point belongs to exactly one pencil
//! (property-tested in `tests/streaming.rs`). A shard writes only
//! `q[offset(x)]` for points `x` of its own pencils and reads only `u`, so
//! concurrent shards never touch the same word of `q` — see
//! [`crate::engine::apply_sharded`] and DESIGN.md §5.

use crate::engine;
use crate::grid::GridDesc;
use crate::runtime::{HostTensor, RuntimeHandle};
use crate::stencil::Stencil;
use crate::traversal::{shard_ranges, TemporalTraversal, Traversal};
use crate::util::rng::Rng;
use crate::util::threadpool::ThreadPool;
use anyhow::{anyhow, Result};
use std::sync::Arc;
use std::time::Instant;

/// Per-step solver log entry (re-exported as `coordinator::SolveStep`).
#[derive(Debug, Clone, Copy)]
pub struct SolveStep {
    pub step: usize,
    /// ‖u‖₂ after the step's update.
    pub u_norm: f64,
    /// ‖Ku‖₂ before the update (the explicit-step residual).
    pub residual_norm: f64,
    pub micros: u64,
}

/// One numeric job, as the coordinator hands it to a backend. The PJRT
/// backend keys artifacts on `dims`; the native backend computes over
/// `grid`/`traversal`/`shards`.
pub struct NumericJob<'a> {
    /// Logical dims of the request (artifact shape key).
    pub dims: &'a [usize],
    /// Storage grid after planner padding.
    pub grid: &'a GridDesc,
    pub stencil: &'a Stencil,
    /// Planner-chosen streaming traversal over `grid`'s interior.
    pub traversal: &'a dyn Traversal,
    /// Pencil-shard fan-out for the numeric sweep (1 = sequential).
    pub shards: usize,
    /// Seed for the deterministic input field.
    pub seed: u64,
    /// Planner-chosen temporal traversal for multi-step Solve jobs: when
    /// set, the native backend advances `time_tile()` steps per pass over
    /// memory via [`engine::step_time_tiled`] (DESIGN.md §2.6) instead of
    /// the classic apply + axpy two-sweep loop. `None` — and Execute jobs
    /// always — use the classic path. The PJRT backend ignores it.
    pub temporal: Option<&'a TemporalTraversal>,
}

/// What a numeric backend returns.
#[derive(Debug)]
pub struct NumericOutcome {
    /// L2 norm of the result (`‖q‖` for execute, final `‖u‖` for solve).
    pub result_norm: f64,
    /// Per-step log (empty for execute).
    pub solve_log: Vec<SolveStep>,
    /// Total backend wall time in microseconds.
    pub micros: u64,
    /// Stencil applications performed (1 for execute, `steps` for solve).
    pub executions: u64,
    /// Ghost words carried across shard boundaries by typed `HaloMsg`s —
    /// nonzero only for block-decomposed solves (`crate::shard`), where it
    /// equals `rounds · ShardPlan::halo_words()` exactly, with
    /// `rounds = ⌈steps / depth⌉` superstep exchange rounds (depth 1 — the
    /// classic path — degenerates to `steps · halo_words()`).
    pub halo_words_loaded: u64,
    /// `HaloMsg` exchanges performed (block-decomposed solves only).
    pub halo_exchanges: u64,
    /// Ghost-zone points recomputed redundantly by deep-halo (k-step)
    /// supersteps — compute traded for exchange rounds, counted separately
    /// from `halo_words_loaded` so the measured-vs-PEM ladder stays honest.
    /// Zero for depth-1 solves and for the non-decomposed paths.
    pub halo_redundant_words: u64,
}

/// A numeric execution backend: applies the stencil once, or runs an
/// explicit damped-Jacobi iteration with per-step norm logging.
pub trait NumericBackend {
    /// Stable backend identifier ("pjrt" / "native") for metrics and logs.
    fn name(&self) -> &'static str;

    /// One stencil application `q = Ku` on the deterministic input field.
    fn execute(&self, job: &NumericJob<'_>) -> Result<NumericOutcome>;

    /// `steps` explicit steps `u ← u + α·Ku` with residual/L2 reductions.
    fn solve(&self, job: &NumericJob<'_>, steps: usize) -> Result<NumericOutcome>;
}

// ---------------------------------------------------------------------------
// Deterministic inputs
// ---------------------------------------------------------------------------

/// Deterministic pseudo-random input field for PJRT numeric jobs (f32, one
/// value per logical point): reproducible across runs so EXPERIMENTS.md
/// numbers are stable.
pub fn deterministic_input(dims: &[usize], seed: u64) -> HostTensor {
    let n: usize = dims.iter().product();
    let mut rng = Rng::new(seed);
    let data: Vec<f32> = (0..n).map(|_| (rng.f64() as f32) - 0.5).collect();
    HostTensor::new(dims.to_vec(), data).expect("consistent dims")
}

/// Deterministic f64 field over the K-interior of `grid` for stencil radius
/// `r`, zero elsewhere (Dirichlet boundary + padding words). Interior values
/// are drawn in natural order, so the field is identical no matter which
/// traversal or shard count later consumes it.
pub fn deterministic_field(grid: &GridDesc, r: usize, seed: u64) -> Vec<f64> {
    let mut u = vec![0.0f64; grid.storage_words() as usize];
    let mut rng = Rng::new(seed);
    crate::traversal::natural_stream(grid, r).stream(&mut |x| {
        u[grid.offset_of(x) as usize] = rng.f64() - 0.5;
    });
    u
}

// ---------------------------------------------------------------------------
// Sharded reductions
// ---------------------------------------------------------------------------

/// Below this buffer size the sharded reductions run sequentially: the
/// fan-out costs more than the loop.
const REDUCE_GRAIN_WORDS: usize = 1 << 16;

/// L2 norm of `v`, reduced over disjoint index ranges on the pool. The
/// chunk split is deterministic for a fixed `shards`, so results are
/// reproducible run-to-run (summation order only varies with `shards`).
pub fn l2_norm_sharded(v: &[f64], pool: &ThreadPool, shards: usize) -> f64 {
    if shards <= 1 || v.len() < REDUCE_GRAIN_WORDS {
        return v.iter().map(|x| x * x).sum::<f64>().sqrt();
    }
    let ranges = shard_ranges(v.len(), shards);
    let partials = pool.scope_map(ranges.len(), |i| ranges[i].clone().map(|j| v[j] * v[j]).sum::<f64>());
    partials.into_iter().sum::<f64>().sqrt()
}

/// Fused update + reductions: `u[i] += alpha·q[i]` over disjoint chunk
/// ranges on the pool; returns `(Σ u'², Σ q²)`. Partial sums are combined
/// in chunk order, so the result is deterministic for a fixed `shards`.
fn axpy_norms_sharded(u: &mut [f64], q: &[f64], alpha: f64, pool: &ThreadPool, shards: usize) -> (f64, f64) {
    let n = u.len().min(q.len());
    if shards <= 1 || n < REDUCE_GRAIN_WORDS {
        let (mut u2, mut r2) = (0.0, 0.0);
        for i in 0..n {
            u[i] += alpha * q[i];
            u2 += u[i] * u[i];
            r2 += q[i] * q[i];
        }
        return (u2, r2);
    }
    let ranges = shard_ranges(n, shards);
    // SAFETY rationale: chunk ranges are disjoint (shard_ranges partitions
    // 0..n), so each worker writes its own words of `u`; `q` is read-only.
    struct UPtr(*mut f64);
    unsafe impl Sync for UPtr {}
    let up = UPtr(u.as_mut_ptr());
    let up = &up;
    let partials = pool.scope_map(ranges.len(), |i| {
        let (mut u2, mut r2) = (0.0, 0.0);
        for j in ranges[i].clone() {
            unsafe {
                let p = up.0.add(j);
                let v = *p + alpha * q[j];
                *p = v;
                u2 += v * v;
            }
            r2 += q[j] * q[j];
        }
        (u2, r2)
    });
    partials.into_iter().fold((0.0, 0.0), |(a, b), (x, y)| (a + x, b + y))
}

// ---------------------------------------------------------------------------
// Native backend
// ---------------------------------------------------------------------------

/// Pure-Rust numeric backend: the engine's streaming `apply` over the
/// planner's traversal, sharded on the worker pool.
pub struct NativeBackend<'a> {
    pool: &'a ThreadPool,
    /// Kernel knobs (strict mode, software-prefetch distance) threaded
    /// into every engine sweep this backend runs. The default is the
    /// engine default: fast mode, no prefetch.
    kernel: engine::KernelCfg,
}

impl<'a> NativeBackend<'a> {
    pub fn new(pool: &'a ThreadPool) -> Self {
        NativeBackend { pool, kernel: engine::KernelCfg::default() }
    }

    /// Backend with explicit kernel knobs — how the coordinator threads
    /// the plan's `prefetch_distance` into the numeric sweeps.
    pub fn with_kernel(pool: &'a ThreadPool, kernel: engine::KernelCfg) -> Self {
        NativeBackend { pool, kernel }
    }

    /// Explicit-Euler step size for `stencil`: `α = 0.8/Σ|c_i|`.
    ///
    /// Stability story: for the star weights this crate builds, the
    /// per-axis Fourier symbol is nonpositive (r = 1: `2cosθ − 2 ≤ 0`;
    /// r = 2: `(8/3)cosθ − (1/6)cos2θ − 5/2 ≤ 0` — note Gershgorin alone
    /// does NOT show this for the mixed-sign r = 2 weights, whose disc
    /// reaches +1), so the operator's spectrum lies in `[−Σ|c_i|, 0]` and
    /// `I + αK` contracts every Dirichlet mode. For the 13-point star
    /// (`Σ|c_i| = 16`) α is exactly the 0.05 the PJRT artifacts bake in;
    /// the decay assertions in tests/CI pin this empirically. For stencils
    /// with `Σc_i ≠ 0` (e.g. averaging box stencils, spectrum reaching
    /// `+Σc_i`) *no* α makes the explicit step dissipative — `solve` still
    /// computes the iteration faithfully, but its norms may grow.
    pub fn stable_alpha(stencil: &Stencil) -> f64 {
        0.8 / stencil.coeffs().iter().map(|c| c.abs()).sum::<f64>()
    }

    /// Time-tiled solve body: supersteps of up to `time_tile()` timesteps,
    /// each one pass over main memory ([`engine::step_time_tiled`]), with
    /// the field double-buffered across supersteps (the clone is paid once
    /// and carries the Dirichlet boundary + padding words; every owned
    /// interior word is overwritten each superstep).
    ///
    /// The per-step log keeps one [`SolveStep`] per *timestep* — identical
    /// shape to the classic path — with the superstep's wall time split
    /// evenly across its steps (remainder on the first).
    fn solve_time_tiled(&self, job: &NumericJob<'_>, tt: &TemporalTraversal, steps: usize) -> Result<NumericOutcome> {
        let r = job.stencil.radius();
        let mut u = deterministic_field(job.grid, r, job.seed);
        let mut v = u.clone();
        let alpha = Self::stable_alpha(job.stencil);
        let k_max = tt.time_tile();
        let mut log = Vec::with_capacity(steps);
        let mut done = 0usize;
        while done < steps {
            let kk = (steps - done).min(k_max);
            let t0 = Instant::now();
            let norms = engine::step_time_tiled_cfg(
                tt,
                job.grid,
                job.stencil,
                &u,
                &mut v,
                alpha,
                kk,
                self.pool,
                job.shards,
                &self.kernel,
            );
            let total = t0.elapsed().as_micros() as u64;
            std::mem::swap(&mut u, &mut v);
            let (each, rem) = (total / kk as u64, total % kk as u64);
            for (s, (u2, r2)) in norms.into_iter().enumerate() {
                log.push(SolveStep {
                    step: done + s,
                    u_norm: u2.sqrt(),
                    residual_norm: r2.sqrt(),
                    micros: each + if s == 0 { rem } else { 0 },
                });
            }
            done += kk;
        }
        let result_norm = match log.last() {
            Some(s) => s.u_norm,
            None => l2_norm_sharded(&u, self.pool, job.shards),
        };
        let micros: u64 = log.iter().map(|s| s.micros).sum();
        Ok(NumericOutcome {
            result_norm,
            solve_log: log,
            micros,
            executions: steps as u64,
            halo_words_loaded: 0,
            halo_exchanges: 0,
            halo_redundant_words: 0,
        })
    }

    /// Block-decomposed solve over the shard/halo layer (DESIGN.md §2.9):
    /// the field lives as per-shard blocks ([`crate::shard::ShardedField`],
    /// in memory or out-of-core), ghost values cross shard boundaries only
    /// inside typed [`crate::shard::HaloMsg`]s, and the outcome carries the
    /// measured halo traffic. Runs on the request's *logical* dims — block
    /// layouts are per-shard, so planner padding (a storage-layout remedy
    /// for cache interference) does not apply. The step, the row kernel
    /// (`engine::kernel`, same `KernelCfg`), and α are the classic path's
    /// own, so the result field is bitwise identical to
    /// [`NumericBackend::solve`] on the same job.
    ///
    /// `time_tile` (k ≥ 1) sets the superstep depth: halos deepen to `k·r`
    /// and each exchange round advances up to k steps (DESIGN.md §2.12).
    /// k = 1 is the classic one-exchange-per-step solver, bit for bit.
    pub fn solve_decomposed(
        &self,
        job: &NumericJob<'_>,
        steps: usize,
        shard_grid: &[usize],
        storage: &crate::shard::ShardStorage,
        ram_budget_words: Option<u64>,
        time_tile: usize,
    ) -> Result<NumericOutcome> {
        let plan = Arc::new(crate::shard::ShardPlan::with_depth(
            job.dims,
            shard_grid,
            job.stencil.radius(),
            time_tile.max(1),
        ));
        let alpha = Self::stable_alpha(job.stencil);
        let out = crate::shard::solve_blocks_cfg(
            &plan,
            job.stencil,
            alpha,
            steps,
            job.seed,
            storage,
            self.pool,
            ram_budget_words,
            &self.kernel,
        )?;
        let log: Vec<SolveStep> = out
            .steps
            .iter()
            .enumerate()
            .map(|(i, sn)| SolveStep { step: i, u_norm: sn.u2.sqrt(), residual_norm: sn.r2.sqrt(), micros: sn.micros })
            .collect();
        let micros: u64 = log.iter().map(|s| s.micros).sum();
        Ok(NumericOutcome {
            result_norm: out.final_norm,
            solve_log: log,
            micros,
            executions: steps as u64,
            halo_words_loaded: out.halo_words_loaded,
            halo_exchanges: out.halo_exchanges,
            halo_redundant_words: out.halo_redundant_words,
        })
    }
}

impl NumericBackend for NativeBackend<'_> {
    fn name(&self) -> &'static str {
        "native"
    }

    fn execute(&self, job: &NumericJob<'_>) -> Result<NumericOutcome> {
        let r = job.stencil.radius();
        let u = deterministic_field(job.grid, r, job.seed);
        let mut q = vec![0.0f64; job.grid.storage_words() as usize];
        // time the sweep + reduction only, not input generation — the same
        // accounting the PJRT backend and NativeBackend::solve use.
        let t0 = Instant::now();
        engine::apply_sharded_cfg(
            job.traversal,
            job.grid,
            job.stencil,
            &u,
            &mut q,
            self.pool,
            job.shards,
            &self.kernel,
        );
        let norm = l2_norm_sharded(&q, self.pool, job.shards);
        Ok(NumericOutcome {
            result_norm: norm,
            solve_log: Vec::new(),
            micros: t0.elapsed().as_micros() as u64,
            executions: 1,
            halo_words_loaded: 0,
            halo_exchanges: 0,
            halo_redundant_words: 0,
        })
    }

    fn solve(&self, job: &NumericJob<'_>, steps: usize) -> Result<NumericOutcome> {
        if let Some(tt) = job.temporal {
            if steps > 0 {
                return self.solve_time_tiled(job, tt, steps);
            }
        }
        let r = job.stencil.radius();
        let mut u = deterministic_field(job.grid, r, job.seed);
        // q only ever holds Ku over the interior; boundary words stay zero,
        // so the axpy update leaves the Dirichlet boundary of u at zero.
        let mut q = vec![0.0f64; job.grid.storage_words() as usize];
        let alpha = Self::stable_alpha(job.stencil);
        let mut log = Vec::with_capacity(steps);
        for step in 0..steps {
            let t0 = Instant::now();
            engine::apply_sharded_cfg(
                job.traversal,
                job.grid,
                job.stencil,
                &u,
                &mut q,
                self.pool,
                job.shards,
                &self.kernel,
            );
            let (u2, r2) = axpy_norms_sharded(&mut u, &q, alpha, self.pool, job.shards);
            log.push(SolveStep {
                step,
                u_norm: u2.sqrt(),
                residual_norm: r2.sqrt(),
                micros: t0.elapsed().as_micros() as u64,
            });
        }
        let result_norm = match log.last() {
            Some(s) => s.u_norm,
            None => l2_norm_sharded(&u, self.pool, job.shards),
        };
        let micros: u64 = log.iter().map(|s| s.micros).sum();
        Ok(NumericOutcome {
            result_norm,
            solve_log: log,
            micros,
            executions: steps as u64,
            halo_words_loaded: 0,
            halo_exchanges: 0,
            halo_redundant_words: 0,
        })
    }
}

// ---------------------------------------------------------------------------
// PJRT backend
// ---------------------------------------------------------------------------

/// Artifact-execution backend over the runtime service's actor thread.
pub struct PjrtBackend {
    handle: Arc<RuntimeHandle>,
}

impl PjrtBackend {
    pub fn new(handle: Arc<RuntimeHandle>) -> PjrtBackend {
        PjrtBackend { handle }
    }

    fn artifact_for(&self, prefix: &str, dims: &[usize]) -> Result<String> {
        self.handle
            .manifest()
            .find_for_shape(prefix, dims)
            .map(|a| a.name.clone())
            .ok_or_else(|| {
                anyhow!(
                    "no {prefix} artifact for shape {dims:?}; available: {:?}. Add the shape to `make artifacts` (aot.py --shapes).",
                    self.handle.manifest().names()
                )
            })
    }
}

impl NumericBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn execute(&self, job: &NumericJob<'_>) -> Result<NumericOutcome> {
        let name = self.artifact_for("star13_", job.dims)?;
        let u = deterministic_input(job.dims, job.seed);
        let t0 = Instant::now();
        let out = self.handle.execute(&name, &[&u])?;
        Ok(NumericOutcome {
            result_norm: out[0].norm(),
            solve_log: Vec::new(),
            micros: t0.elapsed().as_micros() as u64,
            executions: 1,
            halo_words_loaded: 0,
            halo_exchanges: 0,
            halo_redundant_words: 0,
        })
    }

    fn solve(&self, job: &NumericJob<'_>, steps: usize) -> Result<NumericOutcome> {
        let name = self.artifact_for("step_norms_", job.dims)?;
        let mut u = deterministic_input(job.dims, job.seed);
        let mut log = Vec::with_capacity(steps);
        for step in 0..steps {
            let t0 = Instant::now();
            let mut out = self.handle.execute(&name, &[&u])?;
            let micros = t0.elapsed().as_micros() as u64;
            let norms = out.pop().ok_or_else(|| anyhow!("{name}: missing norms output"))?;
            u = out.pop().ok_or_else(|| anyhow!("{name}: missing state output"))?;
            log.push(SolveStep { step, u_norm: norms.data[0] as f64, residual_norm: norms.data[1] as f64, micros });
        }
        let micros: u64 = log.iter().map(|s| s.micros).sum();
        Ok(NumericOutcome {
            result_norm: u.norm(),
            solve_log: log,
            micros,
            executions: steps as u64,
            halo_words_loaded: 0,
            halo_exchanges: 0,
            halo_redundant_words: 0,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::traversal;

    fn job_parts(dims: &[usize], r: usize) -> (GridDesc, Stencil) {
        (GridDesc::new(dims), Stencil::star(dims.len(), r))
    }

    #[test]
    fn deterministic_field_zero_boundary() {
        let g = GridDesc::with_padding(&[8, 7], &[2, 0]);
        let u = deterministic_field(&g, 1, 3);
        assert_eq!(u.len(), g.storage_words() as usize);
        // boundary and padding words are zero; interior is non-trivial
        let mut interior_sum = 0.0;
        for x1 in 0..7i64 {
            for x0 in 0..8i64 {
                let v = u[g.offset_of(&[x0, x1]) as usize];
                let inside = (1..7).contains(&x0) && (1..6).contains(&x1);
                if inside {
                    interior_sum += v.abs();
                } else {
                    assert_eq!(v, 0.0, "boundary ({x0},{x1}) must be zero");
                }
            }
        }
        // padding column words (x0 = 8, 9 in storage) are untouched zeros
        assert!(interior_sum > 0.0);
        assert_eq!(deterministic_field(&g, 1, 3), u, "field must be reproducible");
    }

    #[test]
    fn stable_alpha_star13_matches_pjrt_artifacts() {
        let a = NativeBackend::stable_alpha(&Stencil::star13());
        assert!((a - 0.05).abs() < 1e-12, "alpha = {a}");
    }

    #[test]
    fn native_execute_norm_positive_and_deterministic() {
        let (g, s) = job_parts(&[12, 11, 10], 1);
        let t = traversal::natural_stream(&g, 1);
        let pool = ThreadPool::new(3);
        let backend = NativeBackend::new(&pool);
        let job = NumericJob {
            dims: &[12, 11, 10],
            grid: &g,
            stencil: &s,
            traversal: &t,
            shards: 3,
            seed: 7,
            temporal: None,
        };
        let a = backend.execute(&job).unwrap();
        let b = backend.execute(&job).unwrap();
        assert!(a.result_norm > 0.0);
        assert_eq!(a.result_norm, b.result_norm, "same job must give identical norms");
        assert_eq!(a.executions, 1);
        assert!(a.solve_log.is_empty());
    }

    #[test]
    fn native_solve_dissipates_energy() {
        let (g, s) = job_parts(&[14, 14, 14], 2);
        let t = traversal::natural_stream(&g, 2);
        let pool = ThreadPool::new(2);
        let backend = NativeBackend::new(&pool);
        let job = NumericJob {
            dims: &[14, 14, 14],
            grid: &g,
            stencil: &s,
            traversal: &t,
            shards: 2,
            seed: 0xBEEF,
            temporal: None,
        };
        let out = backend.solve(&job, 12).unwrap();
        assert_eq!(out.solve_log.len(), 12);
        assert_eq!(out.executions, 12);
        for w in out.solve_log.windows(2) {
            assert!(w[1].u_norm <= w[0].u_norm * 1.0001, "explicit heat step must not grow energy: {w:?}");
        }
        let (first, last) = (&out.solve_log[0], out.solve_log.last().unwrap());
        assert!(last.u_norm < first.u_norm, "{} !< {}", last.u_norm, first.u_norm);
        assert!(last.residual_norm.is_finite() && last.residual_norm > 0.0);
        assert_eq!(out.result_norm, last.u_norm);
    }

    #[test]
    fn native_solve_shard_invariant_within_tolerance() {
        // q is bitwise shard-invariant; only the norm reduction's summation
        // order varies with the shard count.
        let (g, s) = job_parts(&[40, 40, 40], 1);
        let t = traversal::natural_stream(&g, 1);
        let pool = ThreadPool::new(4);
        let backend = NativeBackend::new(&pool);
        let mk = |shards| NumericJob {
            dims: &[40, 40, 40],
            grid: &g,
            stencil: &s,
            traversal: &t,
            shards,
            seed: 5,
            temporal: None,
        };
        let a = backend.solve(&mk(1), 5).unwrap();
        let b = backend.solve(&mk(4), 5).unwrap();
        for (x, y) in a.solve_log.iter().zip(&b.solve_log) {
            assert!((x.u_norm - y.u_norm).abs() < 1e-9 * (1.0 + x.u_norm), "{} vs {}", x.u_norm, y.u_norm);
            assert!((x.residual_norm - y.residual_norm).abs() < 1e-9 * (1.0 + x.residual_norm));
        }
    }

    #[test]
    fn native_solve_zero_steps_returns_input_norm() {
        let (g, s) = job_parts(&[10, 10], 1);
        let t = traversal::natural_stream(&g, 1);
        let pool = ThreadPool::new(2);
        let backend = NativeBackend::new(&pool);
        let job = NumericJob {
            dims: &[10, 10],
            grid: &g,
            stencil: &s,
            traversal: &t,
            shards: 1,
            seed: 9,
            temporal: None,
        };
        let out = backend.solve(&job, 0).unwrap();
        assert!(out.solve_log.is_empty());
        let u = deterministic_field(&g, 1, 9);
        let expect = u.iter().map(|x| x * x).sum::<f64>().sqrt();
        assert_eq!(out.result_norm, expect);
    }

    #[test]
    fn native_execute_traversal_invariant() {
        // The result norm is independent of the traversal the sweep uses.
        let (g, s) = job_parts(&[16, 14, 12], 1);
        let pool = ThreadPool::new(2);
        let backend = NativeBackend::new(&pool);
        let nat = traversal::natural_stream(&g, 1);
        let blk = traversal::blocked_stream(&g, 1, &[4, 4, 4]);
        let jn = NumericJob {
            dims: &[16, 14, 12],
            grid: &g,
            stencil: &s,
            traversal: &nat,
            shards: 1,
            seed: 2,
            temporal: None,
        };
        let jb = NumericJob {
            dims: &[16, 14, 12],
            grid: &g,
            stencil: &s,
            traversal: &blk,
            shards: 1,
            seed: 2,
            temporal: None,
        };
        let a = backend.execute(&jn).unwrap();
        let b = backend.execute(&jb).unwrap();
        assert_eq!(a.result_norm, b.result_norm);
    }

    #[test]
    fn axpy_norms_matches_sequential() {
        let pool = ThreadPool::new(3);
        let n = REDUCE_GRAIN_WORDS + 123;
        let mut rng = Rng::new(4);
        let base: Vec<f64> = (0..n).map(|_| rng.f64() - 0.5).collect();
        let q: Vec<f64> = (0..n).map(|_| rng.f64() - 0.5).collect();
        let mut u_seq = base.clone();
        let (u2s, r2s) = axpy_norms_sharded(&mut u_seq, &q, 0.1, &pool, 1);
        let mut u_par = base.clone();
        let (u2p, r2p) = axpy_norms_sharded(&mut u_par, &q, 0.1, &pool, 5);
        assert_eq!(u_seq, u_par, "updated words must be identical");
        assert!((u2s - u2p).abs() < 1e-9 * (1.0 + u2s.abs()));
        assert!((r2s - r2p).abs() < 1e-9 * (1.0 + r2s.abs()));
        assert!((l2_norm_sharded(&u_par, &pool, 5) - u2s.sqrt()).abs() < 1e-9 * (1.0 + u2s.sqrt()));
    }

    #[test]
    fn temporal_solve_matches_classic_per_step_norms() {
        // star13 over 24³, 8 steps with k = 3 (so the last superstep is
        // partial): the field is bitwise equal to the classic path by
        // construction (see engine::step_time_tiled); the logged norms
        // differ only in summation order.
        let (g, s) = job_parts(&[24, 24, 24], 2);
        let t = traversal::natural_stream(&g, 2);
        let tt = traversal::temporal_stream(&g, 2, &[20, 6, 7], 3);
        let pool = ThreadPool::new(3);
        let backend = NativeBackend::new(&pool);
        let dims = [24usize, 24, 24];
        let classic = NumericJob {
            dims: &dims,
            grid: &g,
            stencil: &s,
            traversal: &t,
            shards: 1,
            seed: 11,
            temporal: None,
        };
        let tiled = NumericJob {
            dims: &dims,
            grid: &g,
            stencil: &s,
            traversal: &t,
            shards: 3,
            seed: 11,
            temporal: Some(&tt),
        };
        let a = backend.solve(&classic, 8).unwrap();
        let b = backend.solve(&tiled, 8).unwrap();
        assert_eq!(b.solve_log.len(), 8, "one SolveStep per timestep, superstep or not");
        assert_eq!(b.executions, 8);
        for (x, y) in a.solve_log.iter().zip(&b.solve_log) {
            assert_eq!(x.step, y.step);
            let du = (x.u_norm - y.u_norm).abs();
            assert!(du < 1e-9 * (1.0 + x.u_norm), "step {}: {} vs {}", x.step, x.u_norm, y.u_norm);
            assert!((x.residual_norm - y.residual_norm).abs() < 1e-9 * (1.0 + x.residual_norm));
        }
        assert!((a.result_norm - b.result_norm).abs() < 1e-9 * (1.0 + a.result_norm));
    }

    #[test]
    fn temporal_solve_zero_steps_returns_input_norm() {
        let (g, s) = job_parts(&[12, 12], 2);
        let tt = traversal::temporal_stream(&g, 2, &[8, 8], 2);
        let t = traversal::natural_stream(&g, 2);
        let pool = ThreadPool::new(2);
        let backend = NativeBackend::new(&pool);
        let job = NumericJob {
            dims: &[12, 12],
            grid: &g,
            stencil: &s,
            traversal: &t,
            shards: 1,
            seed: 3,
            temporal: Some(&tt),
        };
        let out = backend.solve(&job, 0).unwrap();
        assert!(out.solve_log.is_empty());
        let u = deterministic_field(&g, 2, 3);
        assert_eq!(out.result_norm, u.iter().map(|x| x * x).sum::<f64>().sqrt());
    }

    #[test]
    fn pjrt_backend_reports_missing_runtime_cleanly() {
        // Without artifacts RuntimeService::start fails before a backend can
        // even be constructed; this pins the error path used by the
        // coordinator's fallback decision.
        let err = crate::runtime::RuntimeService::start(Some(std::path::PathBuf::from("/nonexistent"))).err();
        assert!(err.is_some());
    }
}
