//! Tiny command-line argument parser (no clap offline).
//!
//! Supports the subset the `stencilcache` binary and the experiment drivers
//! need: `--flag`, `--key value`, `--key=value`, positional arguments, and
//! automatically generated usage text.

use std::collections::BTreeMap;

/// Parsed arguments: named options plus positionals, with typed accessors.
#[derive(Debug, Clone, Default)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
    known_flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    /// `flag_names` lists options that take no value; everything else of the
    /// form `--key v` consumes the following token as its value.
    pub fn parse<I: IntoIterator<Item = String>>(raw: I, flag_names: &[&str]) -> Result<Args, String> {
        let mut args = Args { known_flags: flag_names.iter().map(|s| s.to_string()).collect(), ..Default::default() };
        let mut it = raw.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if stripped.is_empty() {
                    // "--" terminates option parsing; remainder is positional.
                    args.positional.extend(it);
                    break;
                }
                if let Some((k, v)) = stripped.split_once('=') {
                    args.opts.insert(k.to_string(), v.to_string());
                } else if flag_names.contains(&stripped) {
                    args.flags.push(stripped.to_string());
                } else if let Some(v) = it.peek() {
                    if v.starts_with("--") {
                        return Err(format!("option --{stripped} expects a value"));
                    }
                    let v = it.next().unwrap();
                    args.opts.insert(stripped.to_string(), v);
                } else {
                    return Err(format!("option --{stripped} expects a value"));
                }
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    /// Parse the process's own argv (minus the binary name).
    pub fn from_env(flag_names: &[&str]) -> Result<Args, String> {
        Self::parse(std::env::args().skip(1), flag_names)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name) || self.opts.get(name).map(|v| v == "true").unwrap_or(false)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// Typed accessor with default; returns Err on malformed values rather
    /// than silently substituting the default.
    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name}: expected unsigned integer, got {v:?}")),
        }
    }

    pub fn get_i64(&self, name: &str, default: i64) -> Result<i64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name}: expected integer, got {v:?}")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name}: expected float, got {v:?}")),
        }
    }

    /// Enumerated option: the value (or `default` when absent) must be one
    /// of `allowed`; unknown values error listing the alternatives — used
    /// by `--machine=<preset>`.
    pub fn get_choice<'a>(&'a self, name: &str, allowed: &[&'a str], default: &'a str) -> Result<&'a str, String> {
        let v = self.get_or(name, default);
        match allowed.iter().find(|&&a| a == v) {
            Some(&a) => Ok(a),
            None => Err(format!("--{name}: unknown value {v:?}; expected one of {}", allowed.join(", "))),
        }
    }

    /// Parse a comma-separated dimension list such as "64,91,100".
    pub fn get_dims(&self, name: &str, default: &[usize]) -> Result<Vec<usize>, String> {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|p| p.trim().parse::<usize>().map_err(|_| format!("--{name}: bad dimension {p:?} in {v:?}")))
                .collect(),
        }
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// First positional argument (typically a subcommand).
    pub fn command(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }

    pub fn known_flags(&self) -> &[String] {
        &self.known_flags
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str], flags: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string()), flags).unwrap()
    }

    #[test]
    fn positional_and_options() {
        let a = parse(&["experiment", "fig4", "--n2", "91", "--verbose"], &["verbose"]);
        assert_eq!(a.command(), Some("experiment"));
        assert_eq!(a.positional(), &["experiment".to_string(), "fig4".to_string()]);
        assert_eq!(a.get("n2"), Some("91"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn equals_form() {
        let a = parse(&["--cache=2,512,4", "--seed=7"], &[]);
        assert_eq!(a.get("cache"), Some("2,512,4"));
        assert_eq!(a.get_usize("seed", 0).unwrap(), 7);
    }

    #[test]
    fn typed_accessors_and_defaults() {
        let a = parse(&["--x", "2.5"], &[]);
        assert_eq!(a.get_f64("x", 0.0).unwrap(), 2.5);
        assert_eq!(a.get_f64("y", 1.5).unwrap(), 1.5);
        assert_eq!(a.get_usize("n", 10).unwrap(), 10);
    }

    #[test]
    fn malformed_value_is_error() {
        let a = parse(&["--n", "abc"], &[]);
        assert!(a.get_usize("n", 0).is_err());
    }

    #[test]
    fn missing_value_is_error() {
        let r = Args::parse(["--key".to_string()].into_iter(), &[]);
        assert!(r.is_err());
        let r2 = Args::parse(["--key".to_string(), "--other".to_string(), "v".to_string()].into_iter(), &[]);
        assert!(r2.is_err());
    }

    #[test]
    fn dims_parsing() {
        let a = parse(&["--dims", "64,91,100"], &[]);
        assert_eq!(a.get_dims("dims", &[1]).unwrap(), vec![64, 91, 100]);
        assert_eq!(a.get_dims("other", &[2, 3]).unwrap(), vec![2, 3]);
        let bad = parse(&["--dims", "64,x"], &[]);
        assert!(bad.get_dims("dims", &[]).is_err());
    }

    #[test]
    fn choice_validates_against_list() {
        let a = parse(&["--machine", "r10000-full"], &[]);
        assert_eq!(a.get_choice("machine", &["r10000", "r10000-full"], "r10000").unwrap(), "r10000-full");
        // absent → default; invalid → error naming alternatives
        assert_eq!(a.get_choice("other", &["x", "y"], "y").unwrap(), "y");
        let bad = parse(&["--machine", "r9000"], &[]);
        let err = bad.get_choice("machine", &["r10000"], "r10000").unwrap_err();
        assert!(err.contains("r9000") && err.contains("r10000"));
    }

    #[test]
    fn double_dash_stops_parsing() {
        let a = parse(&["--a", "1", "--", "--not-an-opt"], &[]);
        assert_eq!(a.get("a"), Some("1"));
        assert_eq!(a.positional(), &["--not-an-opt".to_string()]);
    }
}
