//! Micro/meso benchmark harness (no criterion offline).
//!
//! Provides warmup, calibrated iteration counts, outlier-robust summary
//! statistics, and throughput reporting. All `rust/benches/*.rs` targets
//! (`harness = false`) are built on this.

use super::json::Json;
use super::stats::Summary;
use std::time::{Duration, Instant};

/// Configuration for a benchmark run.
#[derive(Debug, Clone)]
pub struct BenchConfig {
    /// Minimum wall-clock time to spend in warmup.
    pub warmup: Duration,
    /// Minimum wall-clock time to spend measuring.
    pub measure: Duration,
    /// Maximum number of samples collected (caps very fast functions).
    pub max_samples: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig { warmup: Duration::from_millis(200), measure: Duration::from_millis(800), max_samples: 200 }
    }
}

impl BenchConfig {
    /// Quick mode for CI/tests: minimal warmup and measurement.
    pub fn quick() -> Self {
        BenchConfig { warmup: Duration::from_millis(10), measure: Duration::from_millis(50), max_samples: 20 }
    }

    /// Honors the STENCILCACHE_BENCH_QUICK env var so `cargo bench` can be
    /// smoke-run quickly in constrained environments.
    pub fn from_env() -> Self {
        if std::env::var("STENCILCACHE_BENCH_QUICK").is_ok() {
            Self::quick()
        } else {
            Self::default()
        }
    }
}

/// Result of one benchmark: per-iteration timings plus optional items/iter
/// for throughput reporting.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub samples_ns: Vec<f64>,
    pub items_per_iter: Option<f64>,
}

impl BenchResult {
    pub fn summary(&self) -> Summary {
        Summary::of(&self.samples_ns)
    }

    /// Median time per iteration in nanoseconds.
    pub fn median_ns(&self) -> f64 {
        self.summary().p50
    }

    /// Items processed per second at the median timing.
    pub fn throughput(&self) -> Option<f64> {
        self.items_per_iter.map(|items| items * 1e9 / self.median_ns())
    }

    /// One-line human-readable report.
    pub fn report_line(&self) -> String {
        let s = self.summary();
        let mut line = format!(
            "{:<44} {:>12}/iter  (p50 {:>12}, p90 {:>12}, n={})",
            self.name,
            fmt_ns(s.mean),
            fmt_ns(s.p50),
            fmt_ns(s.p90),
            s.n
        );
        if let Some(tp) = self.throughput() {
            line.push_str(&format!("  {:>14}/s", fmt_count(tp)));
        }
        line
    }
}

/// Format nanoseconds with an adaptive unit.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Format a count/throughput with an adaptive SI suffix.
pub fn fmt_count(x: f64) -> String {
    if x >= 1e9 {
        format!("{:.2} G", x / 1e9)
    } else if x >= 1e6 {
        format!("{:.2} M", x / 1e6)
    } else if x >= 1e3 {
        format!("{:.2} k", x / 1e3)
    } else {
        format!("{x:.1} ")
    }
}

/// A benchmark group that runs closures and prints a report.
pub struct Bencher {
    config: BenchConfig,
    results: Vec<BenchResult>,
}

impl Bencher {
    pub fn new(config: BenchConfig) -> Bencher {
        Bencher { config, results: Vec::new() }
    }

    pub fn from_env() -> Bencher {
        Bencher::new(BenchConfig::from_env())
    }

    /// Benchmark `f`, which performs one logical iteration per call and
    /// returns a value that is passed to `std::hint::black_box`.
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> &BenchResult {
        self.bench_with_items(name, None, &mut f)
    }

    /// Like `bench` but records `items` processed per iteration so the
    /// report includes throughput (e.g. cache accesses/s, grid points/s).
    pub fn bench_items<T, F: FnMut() -> T>(&mut self, name: &str, items: f64, mut f: F) -> &BenchResult {
        self.bench_with_items(name, Some(items), &mut f)
    }

    fn bench_with_items<T>(&mut self, name: &str, items: Option<f64>, f: &mut dyn FnMut() -> T) -> &BenchResult {
        // Warmup until the clock budget is spent; also estimates iteration cost.
        let warm_start = Instant::now();
        let mut iters_done = 0u64;
        while warm_start.elapsed() < self.config.warmup || iters_done == 0 {
            std::hint::black_box(f());
            iters_done += 1;
            if iters_done > 1_000_000 {
                break;
            }
        }
        let est_ns = (warm_start.elapsed().as_nanos() as f64 / iters_done as f64).max(1.0);

        // Choose a batch size so each sample takes >= ~100µs, bounding timer noise.
        let batch = ((100_000.0 / est_ns).ceil() as u64).max(1);
        let mut samples = Vec::new();
        let measure_start = Instant::now();
        while measure_start.elapsed() < self.config.measure && samples.len() < self.config.max_samples {
            let t0 = Instant::now();
            for _ in 0..batch {
                std::hint::black_box(f());
            }
            samples.push(t0.elapsed().as_nanos() as f64 / batch as f64);
        }
        let result = BenchResult { name: name.to_string(), samples_ns: samples, items_per_iter: items };
        println!("{}", result.report_line());
        self.results.push(result);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Write all results as a JSON array (used to snapshot bench runs).
    pub fn to_json(&self) -> Json {
        let mut arr = Vec::new();
        for r in &self.results {
            let s = r.summary();
            let mut o = Json::obj();
            o.set("name", r.name.as_str())
                .set("mean_ns", s.mean)
                .set("p50_ns", s.p50)
                .set("p90_ns", s.p90)
                .set("n", s.n);
            if let Some(tp) = r.throughput() {
                o.set("throughput_per_s", tp);
            }
            arr.push(o);
        }
        Json::Arr(arr)
    }

    /// Build a snapshot array: wall-clock results, optionally tagged
    /// `"provisional": true` (regressions against provisional baselines are
    /// reported but do not fail the gate — use it when the committed baseline
    /// was captured on a different machine), followed by caller-provided
    /// metric entries such as deterministic traffic-model numbers.
    pub fn snapshot(&self, provisional: bool, extra: Vec<Json>) -> Json {
        let mut arr = match self.to_json() {
            Json::Arr(v) => v,
            _ => unreachable!("to_json always returns an array"),
        };
        if provisional {
            for e in &mut arr {
                e.set("provisional", true);
            }
        }
        arr.extend(extra);
        Json::Arr(arr)
    }
}

/// Snapshot output path requested via the `STENCILCACHE_BENCH_JSON` env var.
pub fn snapshot_path_from_env() -> Option<String> {
    std::env::var("STENCILCACHE_BENCH_JSON").ok().filter(|p| !p.is_empty())
}

/// Persist a snapshot pretty-printed with a trailing newline so committed
/// baselines (BENCH_*.json) diff cleanly between blessings.
pub fn write_snapshot(path: &str, snapshot: &Json) -> std::io::Result<()> {
    let mut text = snapshot.to_pretty();
    text.push('\n');
    std::fs::write(path, text)
}

/// Strip `"provisional": true` tags from every entry of a snapshot array.
/// Blessing a baseline records it as measured-on-this-machine, so later
/// regressions against it gate hard instead of report-only.
pub fn clear_provisional(snapshot: &Json) -> Json {
    match snapshot {
        Json::Arr(entries) => Json::Arr(
            entries
                .iter()
                .map(|e| match e {
                    Json::Obj(pairs) => Json::Obj(pairs.iter().filter(|(k, _)| k != "provisional").cloned().collect()),
                    other => other.clone(),
                })
                .collect(),
        ),
        other => other.clone(),
    }
}

/// Outcome of comparing a fresh bench snapshot against a committed baseline.
#[derive(Debug, Default)]
pub struct GateReport {
    /// Hard regressions: the perf gate should exit non-zero.
    pub failures: Vec<String>,
    /// Informational findings: provisional-baseline regressions, entries
    /// missing on one side, and similar report-only conditions.
    pub notes: Vec<String>,
}

impl GateReport {
    pub fn passed(&self) -> bool {
        self.failures.is_empty()
    }
}

fn metric(entry: &Json, key: &str) -> Option<f64> {
    entry.get(key).and_then(Json::as_f64)
}

fn entry_name(entry: &Json) -> Option<&str> {
    entry.get("name").and_then(Json::as_str)
}

/// Compare `current` against `baseline`, both JSON arrays of entries keyed by
/// `"name"`. Rules:
///
/// - `throughput_per_s` (wall-clock): regression when current drops below
///   baseline / `tolerance` (default CI tolerance is 2x, so only gross
///   slowdowns fail — micro-noise does not).
/// - `words_per_point` (deterministic traffic model): machine-independent, so
///   `tolerance` does not apply; any increase beyond 0.01% is a regression.
/// - Baseline entries tagged `"provisional": true` downgrade their
///   regressions to notes.
/// - Entries present on only one side produce notes, never failures, so
///   adding or renaming benches does not brick CI before re-blessing.
pub fn gate(baseline: &Json, current: &Json, tolerance: f64) -> GateReport {
    let mut rep = GateReport::default();
    let base = match baseline.as_arr() {
        Some(b) => b,
        None => {
            rep.failures.push("baseline snapshot is not a JSON array".to_string());
            return rep;
        }
    };
    let cur = match current.as_arr() {
        Some(c) => c,
        None => {
            rep.failures.push("current snapshot is not a JSON array".to_string());
            return rep;
        }
    };
    for b in base {
        let name = match entry_name(b) {
            Some(n) => n,
            None => continue,
        };
        let c = match cur.iter().find(|e| entry_name(e) == Some(name)) {
            Some(c) => c,
            None => {
                rep.notes.push(format!("{name}: in baseline but missing from current run"));
                continue;
            }
        };
        let provisional = matches!(b.get("provisional"), Some(Json::Bool(true)));
        let mut regressions = Vec::new();
        if let (Some(bt), Some(ct)) = (metric(b, "throughput_per_s"), metric(c, "throughput_per_s")) {
            if bt > 0.0 && ct < bt / tolerance {
                regressions.push(format!(
                    "{name}: throughput {ct:.3e}/s is below the {tolerance:.1}x floor of baseline {bt:.3e}/s"
                ));
            }
        }
        if let (Some(bw), Some(cw)) = (metric(b, "words_per_point"), metric(c, "words_per_point")) {
            if cw > bw * 1.0001 {
                regressions.push(format!("{name}: modelled words/point rose {bw:.4} -> {cw:.4}"));
            }
        }
        for msg in regressions {
            if provisional {
                rep.notes.push(format!("{msg} [provisional baseline: report-only]"));
            } else {
                rep.failures.push(msg);
            }
        }
    }
    for c in cur {
        if let Some(name) = entry_name(c) {
            if !base.iter().any(|b| entry_name(b) == Some(name)) {
                rep.notes.push(format!("{name}: new entry with no baseline (bless a refreshed snapshot to gate it)"));
            }
        }
    }
    rep
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick() -> Bencher {
        Bencher::new(BenchConfig { warmup: Duration::from_millis(1), measure: Duration::from_millis(5), max_samples: 10 })
    }

    #[test]
    fn bench_produces_samples() {
        let mut b = quick();
        let r = b.bench("noop-ish", || 1 + 1);
        assert!(!r.samples_ns.is_empty());
        assert!(r.median_ns() >= 0.0);
    }

    #[test]
    fn throughput_reported_when_items_given() {
        let mut b = quick();
        let r = b.bench_items("sum", 1000.0, || (0..1000u64).sum::<u64>());
        assert!(r.throughput().unwrap() > 0.0);
        assert!(r.report_line().contains("/s"));
    }

    #[test]
    fn json_snapshot_shape() {
        let mut b = quick();
        b.bench("x", || 0);
        let j = b.to_json().to_string();
        assert!(j.contains("\"name\":\"x\""));
        assert!(j.contains("mean_ns"));
    }

    fn entry(name: &str, throughput: Option<f64>, wpp: Option<f64>, provisional: bool) -> Json {
        let mut o = Json::obj();
        o.set("name", name);
        if let Some(tp) = throughput {
            o.set("throughput_per_s", tp);
        }
        if let Some(w) = wpp {
            o.set("words_per_point", w);
        }
        if provisional {
            o.set("provisional", true);
        }
        o
    }

    #[test]
    fn gate_passes_within_tolerance() {
        let base = Json::Arr(vec![entry("a", Some(100.0), None, false)]);
        let cur = Json::Arr(vec![entry("a", Some(60.0), None, false)]);
        let rep = gate(&base, &cur, 2.0);
        assert!(rep.passed(), "60/s vs 100/s baseline is within the 2x floor: {:?}", rep.failures);
        assert!(rep.notes.is_empty());
    }

    #[test]
    fn gate_fails_on_large_throughput_regression() {
        let base = Json::Arr(vec![entry("a", Some(100.0), None, false)]);
        let cur = Json::Arr(vec![entry("a", Some(40.0), None, false)]);
        let rep = gate(&base, &cur, 2.0);
        assert!(!rep.passed());
        assert!(rep.failures[0].contains("a: throughput"));
    }

    #[test]
    fn gate_provisional_baseline_is_report_only() {
        let base = Json::Arr(vec![entry("a", Some(100.0), None, true)]);
        let cur = Json::Arr(vec![entry("a", Some(10.0), None, false)]);
        let rep = gate(&base, &cur, 2.0);
        assert!(rep.passed());
        assert_eq!(rep.notes.len(), 1);
        assert!(rep.notes[0].contains("report-only"));
    }

    #[test]
    fn gate_hard_fails_on_traffic_model_increase() {
        let base = Json::Arr(vec![entry("model", None, Some(0.86), false)]);
        let worse = Json::Arr(vec![entry("model", None, Some(0.90), false)]);
        // The 2x wall-clock tolerance must NOT excuse a deterministic model regression.
        assert!(!gate(&base, &worse, 2.0).passed());
        let same = Json::Arr(vec![entry("model", None, Some(0.86), false)]);
        assert!(gate(&base, &same, 2.0).passed());
        let better = Json::Arr(vec![entry("model", None, Some(0.80), false)]);
        assert!(gate(&base, &better, 2.0).passed());
    }

    #[test]
    fn gate_missing_entries_are_notes_not_failures() {
        let base = Json::Arr(vec![entry("only_in_base", Some(1.0), None, false)]);
        let cur = Json::Arr(vec![entry("only_in_current", Some(1.0), None, false)]);
        let rep = gate(&base, &cur, 2.0);
        assert!(rep.passed());
        assert_eq!(rep.notes.len(), 2);
    }

    #[test]
    fn gate_rejects_non_array_snapshots() {
        let rep = gate(&Json::obj(), &Json::Arr(vec![]), 2.0);
        assert!(!rep.passed());
    }

    #[test]
    fn snapshot_marks_provisional_and_appends_extra() {
        let mut b = quick();
        b.bench_items("x", 10.0, || 0);
        let snap = b.snapshot(true, vec![entry("model", None, Some(5.0), false)]);
        let arr = snap.as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].get("provisional"), Some(&Json::Bool(true)));
        assert_eq!(arr[1].get("name").unwrap().as_str(), Some("model"));
    }

    #[test]
    fn clear_provisional_strips_tags_only() {
        let snap = Json::Arr(vec![
            entry("a", Some(10.0), None, true),
            entry("model", None, Some(0.5), false),
        ]);
        let blessed = clear_provisional(&snap);
        let arr = blessed.as_arr().unwrap();
        assert_eq!(arr[0].get("provisional"), None);
        assert_eq!(arr[0].get("throughput_per_s"), snap.as_arr().unwrap()[0].get("throughput_per_s"));
        assert_eq!(arr[1], snap.as_arr().unwrap()[1]);
        // a blessed baseline gates its own numbers hard
        let rep = gate(&blessed, &Json::Arr(vec![entry("a", Some(1.0), None, false)]), 2.0);
        assert!(!rep.passed());
    }

    #[test]
    fn snapshot_roundtrips_through_write_and_parse() {
        let mut b = quick();
        b.bench("y", || 0);
        let snap = b.snapshot(false, vec![]);
        let path = std::env::temp_dir().join(format!("stencilcache_bench_snap_{}.json", std::process::id()));
        let path = path.to_str().unwrap().to_string();
        write_snapshot(&path, &snap).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        assert!(text.ends_with('\n'));
        let parsed = super::super::json::parse(&text).unwrap();
        assert!(gate(&parsed, &snap, 2.0).passed());
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_ns(12.3), "12.3 ns");
        assert!(fmt_ns(12_345.0).contains("µs"));
        assert!(fmt_ns(12_345_678.0).contains("ms"));
        assert!(fmt_ns(2e9).contains(" s"));
        assert!(fmt_count(5e9).contains("G"));
        assert!(fmt_count(5e6).contains("M"));
        assert!(fmt_count(5e3).contains("k"));
    }
}
