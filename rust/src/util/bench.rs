//! Micro/meso benchmark harness (no criterion offline).
//!
//! Provides warmup, calibrated iteration counts, outlier-robust summary
//! statistics, and throughput reporting. All `rust/benches/*.rs` targets
//! (`harness = false`) are built on this.

use super::stats::Summary;
use std::time::{Duration, Instant};

/// Configuration for a benchmark run.
#[derive(Debug, Clone)]
pub struct BenchConfig {
    /// Minimum wall-clock time to spend in warmup.
    pub warmup: Duration,
    /// Minimum wall-clock time to spend measuring.
    pub measure: Duration,
    /// Maximum number of samples collected (caps very fast functions).
    pub max_samples: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig { warmup: Duration::from_millis(200), measure: Duration::from_millis(800), max_samples: 200 }
    }
}

impl BenchConfig {
    /// Quick mode for CI/tests: minimal warmup and measurement.
    pub fn quick() -> Self {
        BenchConfig { warmup: Duration::from_millis(10), measure: Duration::from_millis(50), max_samples: 20 }
    }

    /// Honors the STENCILCACHE_BENCH_QUICK env var so `cargo bench` can be
    /// smoke-run quickly in constrained environments.
    pub fn from_env() -> Self {
        if std::env::var("STENCILCACHE_BENCH_QUICK").is_ok() {
            Self::quick()
        } else {
            Self::default()
        }
    }
}

/// Result of one benchmark: per-iteration timings plus optional items/iter
/// for throughput reporting.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub samples_ns: Vec<f64>,
    pub items_per_iter: Option<f64>,
}

impl BenchResult {
    pub fn summary(&self) -> Summary {
        Summary::of(&self.samples_ns)
    }

    /// Median time per iteration in nanoseconds.
    pub fn median_ns(&self) -> f64 {
        self.summary().p50
    }

    /// Items processed per second at the median timing.
    pub fn throughput(&self) -> Option<f64> {
        self.items_per_iter.map(|items| items * 1e9 / self.median_ns())
    }

    /// One-line human-readable report.
    pub fn report_line(&self) -> String {
        let s = self.summary();
        let mut line = format!(
            "{:<44} {:>12}/iter  (p50 {:>12}, p90 {:>12}, n={})",
            self.name,
            fmt_ns(s.mean),
            fmt_ns(s.p50),
            fmt_ns(s.p90),
            s.n
        );
        if let Some(tp) = self.throughput() {
            line.push_str(&format!("  {:>14}/s", fmt_count(tp)));
        }
        line
    }
}

/// Format nanoseconds with an adaptive unit.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Format a count/throughput with an adaptive SI suffix.
pub fn fmt_count(x: f64) -> String {
    if x >= 1e9 {
        format!("{:.2} G", x / 1e9)
    } else if x >= 1e6 {
        format!("{:.2} M", x / 1e6)
    } else if x >= 1e3 {
        format!("{:.2} k", x / 1e3)
    } else {
        format!("{x:.1} ")
    }
}

/// A benchmark group that runs closures and prints a report.
pub struct Bencher {
    config: BenchConfig,
    results: Vec<BenchResult>,
}

impl Bencher {
    pub fn new(config: BenchConfig) -> Bencher {
        Bencher { config, results: Vec::new() }
    }

    pub fn from_env() -> Bencher {
        Bencher::new(BenchConfig::from_env())
    }

    /// Benchmark `f`, which performs one logical iteration per call and
    /// returns a value that is passed to `std::hint::black_box`.
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> &BenchResult {
        self.bench_with_items(name, None, &mut f)
    }

    /// Like `bench` but records `items` processed per iteration so the
    /// report includes throughput (e.g. cache accesses/s, grid points/s).
    pub fn bench_items<T, F: FnMut() -> T>(&mut self, name: &str, items: f64, mut f: F) -> &BenchResult {
        self.bench_with_items(name, Some(items), &mut f)
    }

    fn bench_with_items<T>(&mut self, name: &str, items: Option<f64>, f: &mut dyn FnMut() -> T) -> &BenchResult {
        // Warmup until the clock budget is spent; also estimates iteration cost.
        let warm_start = Instant::now();
        let mut iters_done = 0u64;
        while warm_start.elapsed() < self.config.warmup || iters_done == 0 {
            std::hint::black_box(f());
            iters_done += 1;
            if iters_done > 1_000_000 {
                break;
            }
        }
        let est_ns = (warm_start.elapsed().as_nanos() as f64 / iters_done as f64).max(1.0);

        // Choose a batch size so each sample takes >= ~100µs, bounding timer noise.
        let batch = ((100_000.0 / est_ns).ceil() as u64).max(1);
        let mut samples = Vec::new();
        let measure_start = Instant::now();
        while measure_start.elapsed() < self.config.measure && samples.len() < self.config.max_samples {
            let t0 = Instant::now();
            for _ in 0..batch {
                std::hint::black_box(f());
            }
            samples.push(t0.elapsed().as_nanos() as f64 / batch as f64);
        }
        let result = BenchResult { name: name.to_string(), samples_ns: samples, items_per_iter: items };
        println!("{}", result.report_line());
        self.results.push(result);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Write all results as a JSON array (used to snapshot bench runs).
    pub fn to_json(&self) -> super::json::Json {
        use super::json::Json;
        let mut arr = Vec::new();
        for r in &self.results {
            let s = r.summary();
            let mut o = Json::obj();
            o.set("name", r.name.as_str())
                .set("mean_ns", s.mean)
                .set("p50_ns", s.p50)
                .set("p90_ns", s.p90)
                .set("n", s.n);
            if let Some(tp) = r.throughput() {
                o.set("throughput_per_s", tp);
            }
            arr.push(o);
        }
        Json::Arr(arr)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick() -> Bencher {
        Bencher::new(BenchConfig { warmup: Duration::from_millis(1), measure: Duration::from_millis(5), max_samples: 10 })
    }

    #[test]
    fn bench_produces_samples() {
        let mut b = quick();
        let r = b.bench("noop-ish", || 1 + 1);
        assert!(!r.samples_ns.is_empty());
        assert!(r.median_ns() >= 0.0);
    }

    #[test]
    fn throughput_reported_when_items_given() {
        let mut b = quick();
        let r = b.bench_items("sum", 1000.0, || (0..1000u64).sum::<u64>());
        assert!(r.throughput().unwrap() > 0.0);
        assert!(r.report_line().contains("/s"));
    }

    #[test]
    fn json_snapshot_shape() {
        let mut b = quick();
        b.bench("x", || 0);
        let j = b.to_json().to_string();
        assert!(j.contains("\"name\":\"x\""));
        assert!(j.contains("mean_ns"));
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_ns(12.3), "12.3 ns");
        assert!(fmt_ns(12_345.0).contains("µs"));
        assert!(fmt_ns(12_345_678.0).contains("ms"));
        assert!(fmt_ns(2e9).contains(" s"));
        assert!(fmt_count(5e9).contains("G"));
        assert!(fmt_count(5e6).contains("M"));
        assert!(fmt_count(5e3).contains("k"));
    }
}
