//! Fixed-size worker thread pool (no tokio/rayon offline).
//!
//! Used by the coordinator's worker tier and by the experiment drivers to
//! parallelize independent grid simulations (FIG5A sweeps ~3600 grids).
//! Design: one shared MPMC queue guarded by a Mutex + Condvar; jobs are
//! boxed closures. `scope_map` provides the common "parallel map over an
//! index range" pattern with panic propagation.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<VecDeque<Job>>,
    cond: Condvar,
    shutdown: AtomicBool,
}

/// A fixed pool of worker threads executing boxed jobs FIFO.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn `n` workers (clamped to at least 1).
    pub fn new(n: usize) -> ThreadPool {
        let n = n.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            cond: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let workers = (0..n)
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("stencilcache-worker-{i}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("failed to spawn worker thread")
            })
            .collect();
        ThreadPool { shared, workers }
    }

    /// Pool sized to the machine (leaving one core for the coordinator).
    pub fn with_default_parallelism() -> ThreadPool {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        ThreadPool::new(n.saturating_sub(1).max(1))
    }

    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    /// Enqueue a job.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, job: F) {
        let mut q = self.shared.queue.lock().unwrap();
        q.push_back(Box::new(job));
        drop(q);
        self.shared.cond.notify_one();
    }

    /// Parallel map: apply `f` to every index in `0..n`, returning results in
    /// index order. A panic in any worker is captured with its original
    /// payload and re-raised (`resume_unwind`) on the *caller's* thread at
    /// the scope boundary — so a caller that wraps `scope_map` in
    /// `catch_unwind` (the coordinator's serving path does) observes the
    /// real panic instead of a synthetic one, and a poisoned request can be
    /// answered with an error while the process keeps serving.
    ///
    /// `f` must be `Sync` because all workers share one reference to it.
    pub fn scope_map<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send + 'static,
        F: Fn(usize) -> T + Sync,
    {
        if n == 0 {
            return Vec::new();
        }
        let results: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
        let next = AtomicUsize::new(0);
        let panicked = AtomicBool::new(false);
        let payload: Mutex<Option<Box<dyn std::any::Any + Send + 'static>>> = Mutex::new(None);
        // SAFETY-free approach: use std scoped threads are unavailable inside a
        // pool, so we run the work-stealing loop on the *caller* thread plus
        // the pool via raw pointers wrapped in an Arc'd closure would require
        // 'static. Instead we use std::thread::scope directly here: the pool's
        // value is its reusable workers for `submit`; scope_map gets its own
        // scoped threads sized to the pool. This keeps lifetimes safe without
        // unsafe code.
        let width = self.workers.len().min(n);
        std::thread::scope(|s| {
            for _ in 0..width {
                s.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n || panicked.load(Ordering::Relaxed) {
                        break;
                    }
                    let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(i)));
                    match out {
                        Ok(v) => *results[i].lock().unwrap() = Some(v),
                        Err(p) => {
                            // keep the first payload; later panics (other
                            // workers racing past the flag) are dropped
                            let mut slot = payload.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
                            slot.get_or_insert(p);
                            panicked.store(true, Ordering::Relaxed);
                            break;
                        }
                    }
                });
            }
        });
        if let Some(p) = payload.into_inner().unwrap_or_else(std::sync::PoisonError::into_inner) {
            std::panic::resume_unwind(p);
        }
        results
            .into_iter()
            .map(|m| m.into_inner().unwrap().expect("scope_map: missing result"))
            .collect()
    }

    /// Block until the queue is empty and all in-flight jobs finished.
    /// Implemented with a completion-counting barrier job per worker.
    pub fn wait_idle(&self) {
        let n = self.workers.len();
        let barrier = Arc::new(std::sync::Barrier::new(n + 1));
        for _ in 0..n {
            let b = Arc::clone(&barrier);
            self.submit(move || {
                b.wait();
            });
        }
        barrier.wait();
    }
}

fn worker_loop(shared: &Shared) {
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(job) = q.pop_front() {
                    break Some(job);
                }
                if shared.shutdown.load(Ordering::Acquire) {
                    break None;
                }
                q = shared.cond.wait(q).unwrap();
            }
        };
        match job {
            Some(job) => {
                // A panicking job must not kill the worker; the pool keeps
                // serving. catch_unwind keeps long experiment sweeps alive.
                let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
            }
            None => return,
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Release);
        self.shared.cond.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn submit_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn scope_map_orders_results() {
        let pool = ThreadPool::new(3);
        let out = pool.scope_map(50, |i| i * i);
        assert_eq!(out, (0..50).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn scope_map_empty() {
        let pool = ThreadPool::new(2);
        let out: Vec<usize> = pool.scope_map(0, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn scope_map_more_tasks_than_workers() {
        let pool = ThreadPool::new(1);
        let out = pool.scope_map(10, |i| i + 1);
        assert_eq!(out, (1..=10).collect::<Vec<_>>());
    }

    #[test]
    #[should_panic(expected = "boom")]
    fn scope_map_propagates_original_panic_payload() {
        let pool = ThreadPool::new(2);
        let _ = pool.scope_map(8, |i| {
            if i == 3 {
                panic!("boom");
            }
            i
        });
    }

    #[test]
    fn scope_map_panic_is_catchable_and_pool_survives() {
        // the serving path wraps scope_map items in catch_unwind; the
        // resumed payload must be the original one and the pool must keep
        // working afterwards
        let pool = ThreadPool::new(2);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.scope_map(4, |i| {
                if i == 1 {
                    panic!("poisoned request");
                }
                i
            })
        }));
        let payload = caught.expect_err("panic must propagate");
        let msg = payload.downcast_ref::<&str>().copied().unwrap_or("");
        assert_eq!(msg, "poisoned request");
        let out = pool.scope_map(6, |i| i * 2);
        assert_eq!(out, vec![0, 2, 4, 6, 8, 10]);
    }

    #[test]
    fn pool_survives_panicking_submitted_job() {
        let pool = ThreadPool::new(2);
        pool.submit(|| panic!("job panic"));
        let counter = Arc::new(AtomicU64::new(0));
        let c = Arc::clone(&counter);
        pool.submit(move || {
            c.fetch_add(1, Ordering::Relaxed);
        });
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(2);
        drop(pool); // must not hang
    }
}
