//! Fixed-size worker thread pool (no tokio/rayon offline).
//!
//! Used by the coordinator's worker tier and by the experiment drivers to
//! parallelize independent grid simulations (FIG5A sweeps ~3600 grids).
//! Design: one shared MPMC queue guarded by a Mutex + Condvar; jobs are
//! boxed closures. `scope_map` provides the common "parallel map over an
//! index range" pattern with panic propagation, and `scope_tasks` the
//! dependency-driven generalization: typed tasks that enqueue follow-on
//! tasks the moment their inputs land, with no wave barrier in between.
//!
//! NUMA-aware placement: a pool built with [`ThreadPool::new_pinned`]
//! pins worker `i` (including the scoped threads `scope_map`/`scope_tasks`
//! spawn) to core `i`, so a shard block that worker first-touches stays on
//! that worker's memory node across supersteps. Pinning is best-effort —
//! a raw `sched_setaffinity` syscall on Linux/x86_64, a no-op elsewhere.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, PoisonError};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<VecDeque<Job>>,
    cond: Condvar,
    shutdown: AtomicBool,
}

/// Best-effort: pin the calling thread to `core` (taken modulo 1024, the
/// mask capacity). Returns whether the kernel accepted the mask. Linux
/// x86_64 only — issued as a raw `sched_setaffinity(0, ..)` syscall so no
/// libc binding is needed; on other targets this is a no-op returning
/// false. Failure (e.g. a restricted container cpuset) is harmless: the
/// thread simply stays wherever the scheduler put it.
pub fn pin_current_thread(core: usize) -> bool {
    #[cfg(all(target_os = "linux", target_arch = "x86_64"))]
    {
        let mut mask = [0u64; 16]; // 1024-CPU affinity mask
        let core = core % (mask.len() * 64);
        mask[core / 64] = 1u64 << (core % 64);
        let ret: i64;
        // SAFETY: sched_setaffinity (nr 203) reads `rsi` bytes from the
        // pointer in `rdx`; the mask outlives the call and the size is
        // exact. The syscall clobbers rcx/r11 per the x86_64 ABI.
        unsafe {
            std::arch::asm!(
                "syscall",
                inlateout("rax") 203i64 => ret,
                in("rdi") 0usize,
                in("rsi") std::mem::size_of_val(&mask),
                in("rdx") mask.as_ptr(),
                lateout("rcx") _,
                lateout("r11") _,
                options(nostack, readonly)
            );
        }
        return ret == 0;
    }
    #[cfg(not(all(target_os = "linux", target_arch = "x86_64")))]
    {
        let _ = core;
        false
    }
}

/// A fixed pool of worker threads executing boxed jobs FIFO.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    pin: bool,
}

impl ThreadPool {
    /// Spawn `n` workers (clamped to at least 1).
    pub fn new(n: usize) -> ThreadPool {
        ThreadPool::build(n, false)
    }

    /// [`ThreadPool::new`] with NUMA-aware placement: worker `i` — and the
    /// `i`-th scoped thread of every `scope_map`/`scope_tasks` call — is
    /// pinned to core `i`. Combined with the shard fields' first-touch
    /// allocation (each block is allocated and written by the worker that
    /// computes it), a shard's data stays on its worker's memory node.
    pub fn new_pinned(n: usize) -> ThreadPool {
        ThreadPool::build(n, true)
    }

    fn build(n: usize, pin: bool) -> ThreadPool {
        let n = n.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            cond: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let workers = (0..n)
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("stencilcache-worker-{i}"))
                    .spawn(move || {
                        if pin {
                            pin_current_thread(i);
                        }
                        worker_loop(&shared)
                    })
                    .expect("failed to spawn worker thread")
            })
            .collect();
        ThreadPool { shared, workers, pin }
    }

    /// Pool sized to the machine (leaving one core for the coordinator).
    pub fn with_default_parallelism() -> ThreadPool {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        ThreadPool::new(n.saturating_sub(1).max(1))
    }

    /// Is this a NUMA-pinned pool (see [`ThreadPool::new_pinned`])?
    pub fn pinned(&self) -> bool {
        self.pin
    }

    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    /// Enqueue a job.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, job: F) {
        let mut q = self.shared.queue.lock().unwrap();
        q.push_back(Box::new(job));
        drop(q);
        self.shared.cond.notify_one();
    }

    /// Parallel map: apply `f` to every index in `0..n`, returning results in
    /// index order. A panic in any worker is captured with its original
    /// payload and re-raised (`resume_unwind`) on the *caller's* thread at
    /// the scope boundary — so a caller that wraps `scope_map` in
    /// `catch_unwind` (the coordinator's serving path does) observes the
    /// real panic instead of a synthetic one, and a poisoned request can be
    /// answered with an error while the process keeps serving.
    ///
    /// `f` must be `Sync` because all workers share one reference to it.
    pub fn scope_map<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send + 'static,
        F: Fn(usize) -> T + Sync,
    {
        if n == 0 {
            return Vec::new();
        }
        let results: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
        let next = AtomicUsize::new(0);
        let panicked = AtomicBool::new(false);
        let payload: Mutex<Option<Box<dyn std::any::Any + Send + 'static>>> = Mutex::new(None);
        // SAFETY-free approach: use std scoped threads are unavailable inside a
        // pool, so we run the work-stealing loop on the *caller* thread plus
        // the pool via raw pointers wrapped in an Arc'd closure would require
        // 'static. Instead we use std::thread::scope directly here: the pool's
        // value is its reusable workers for `submit`; scope_map gets its own
        // scoped threads sized to the pool. This keeps lifetimes safe without
        // unsafe code.
        let width = self.workers.len().min(n);
        std::thread::scope(|s| {
            for w in 0..width {
                let pin = self.pin;
                let (next, panicked, payload, results, f) = (&next, &panicked, &payload, &results, &f);
                s.spawn(move || {
                    if pin {
                        pin_current_thread(w);
                    }
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n || panicked.load(Ordering::Relaxed) {
                            break;
                        }
                        let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(i)));
                        match out {
                            Ok(v) => *results[i].lock().unwrap() = Some(v),
                            Err(p) => {
                                // keep the first payload; later panics (other
                                // workers racing past the flag) are dropped
                                let mut slot = payload.lock().unwrap_or_else(PoisonError::into_inner);
                                slot.get_or_insert(p);
                                panicked.store(true, Ordering::Relaxed);
                                break;
                            }
                        }
                    }
                });
            }
        });
        if let Some(p) = payload.into_inner().unwrap_or_else(std::sync::PoisonError::into_inner) {
            std::panic::resume_unwind(p);
        }
        results
            .into_iter()
            .map(|m| m.into_inner().unwrap().expect("scope_map: missing result"))
            .collect()
    }

    /// Dependency-driven scoped execution: seed a deque of typed tasks and
    /// let `worker` drain it, enqueueing follow-on tasks through the
    /// [`TaskSink`] the moment their dependencies resolve. Unlike
    /// `scope_map` there is **no wave barrier**: a task becomes runnable
    /// the instant something pushes it, regardless of what else is still
    /// in flight. Returns when every task (seeded or spawned) finished.
    ///
    /// Tasks are plain data (`T: Send`), not closures, so the scoped
    /// threads borrow caller state safely; `worker` is shared by all
    /// threads and must be `Sync`. A panic in any task aborts the drain
    /// and is re-raised on the caller's thread with its original payload,
    /// like `scope_map`.
    pub fn scope_tasks<T, F>(&self, seed: Vec<T>, worker: F)
    where
        T: Send,
        F: Fn(T, &TaskSink<T>) + Sync,
    {
        if seed.is_empty() {
            return;
        }
        let sink = TaskSink {
            queue: Mutex::new(VecDeque::from(seed)),
            cond: Condvar::new(),
            outstanding: AtomicUsize::new(0),
            abort: AtomicBool::new(false),
        };
        sink.outstanding
            .store(sink.queue.lock().unwrap_or_else(PoisonError::into_inner).len(), Ordering::SeqCst);
        let payload: Mutex<Option<Box<dyn std::any::Any + Send + 'static>>> = Mutex::new(None);
        let width = self.workers.len();
        std::thread::scope(|s| {
            for w in 0..width {
                let pin = self.pin;
                let (sink, payload, worker) = (&sink, &payload, &worker);
                s.spawn(move || {
                    if pin {
                        pin_current_thread(w);
                    }
                    loop {
                        let task = {
                            let mut q = sink.queue.lock().unwrap_or_else(PoisonError::into_inner);
                            loop {
                                if sink.abort.load(Ordering::Acquire) {
                                    return;
                                }
                                if let Some(t) = q.pop_front() {
                                    break t;
                                }
                                if sink.outstanding.load(Ordering::SeqCst) == 0 {
                                    return;
                                }
                                q = sink.cond.wait(q).unwrap_or_else(PoisonError::into_inner);
                            }
                        };
                        let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| worker(task, sink)));
                        // The termination predicates (abort, outstanding==0)
                        // are checked under the queue mutex before cond.wait,
                        // so every change to them must also happen while
                        // holding that mutex — otherwise the notify can land
                        // between a waiter's check and its wait (lost wakeup)
                        // and the scope never joins.
                        if let Err(p) = out {
                            let mut slot = payload.lock().unwrap_or_else(PoisonError::into_inner);
                            slot.get_or_insert(p);
                            let q = sink.queue.lock().unwrap_or_else(PoisonError::into_inner);
                            sink.abort.store(true, Ordering::Release);
                            drop(q);
                            sink.cond.notify_all();
                            return;
                        }
                        let q = sink.queue.lock().unwrap_or_else(PoisonError::into_inner);
                        let last = sink.outstanding.fetch_sub(1, Ordering::SeqCst) == 1;
                        drop(q);
                        if last {
                            // last task retired: wake idle workers to exit
                            sink.cond.notify_all();
                        }
                    }
                });
            }
        });
        if let Some(p) = payload.into_inner().unwrap_or_else(PoisonError::into_inner) {
            std::panic::resume_unwind(p);
        }
    }

    /// Block until the queue is empty and all in-flight jobs finished.
    /// Implemented with a completion-counting barrier job per worker.
    pub fn wait_idle(&self) {
        let n = self.workers.len();
        let barrier = Arc::new(std::sync::Barrier::new(n + 1));
        for _ in 0..n {
            let b = Arc::clone(&barrier);
            self.submit(move || {
                b.wait();
            });
        }
        barrier.wait();
    }
}

/// Shared state of one [`ThreadPool::scope_tasks`] drain: the deque of
/// pending tasks plus the outstanding count (queued + running). Handed to
/// every task so it can schedule successors the moment their inputs are
/// ready.
pub struct TaskSink<T> {
    queue: Mutex<VecDeque<T>>,
    cond: Condvar,
    outstanding: AtomicUsize,
    abort: AtomicBool,
}

impl<T> TaskSink<T> {
    /// Enqueue a follow-on task (runnable immediately).
    pub fn push(&self, task: T) {
        self.outstanding.fetch_add(1, Ordering::SeqCst);
        let mut q = self.queue.lock().unwrap_or_else(PoisonError::into_inner);
        q.push_back(task);
        drop(q);
        self.cond.notify_one();
    }
}

fn worker_loop(shared: &Shared) {
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(job) = q.pop_front() {
                    break Some(job);
                }
                if shared.shutdown.load(Ordering::Acquire) {
                    break None;
                }
                q = shared.cond.wait(q).unwrap();
            }
        };
        match job {
            Some(job) => {
                // A panicking job must not kill the worker; the pool keeps
                // serving. catch_unwind keeps long experiment sweeps alive.
                let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
            }
            None => return,
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        // shutdown is a termination predicate checked under the queue
        // mutex in worker_loop; store it while holding that mutex so the
        // notify cannot land between a worker's check and its wait (the
        // same lost-wakeup window scope_tasks guards against).
        let q = self.shared.queue.lock().unwrap_or_else(PoisonError::into_inner);
        self.shared.shutdown.store(true, Ordering::Release);
        drop(q);
        self.shared.cond.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn submit_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn scope_map_orders_results() {
        let pool = ThreadPool::new(3);
        let out = pool.scope_map(50, |i| i * i);
        assert_eq!(out, (0..50).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn scope_map_empty() {
        let pool = ThreadPool::new(2);
        let out: Vec<usize> = pool.scope_map(0, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn scope_map_more_tasks_than_workers() {
        let pool = ThreadPool::new(1);
        let out = pool.scope_map(10, |i| i + 1);
        assert_eq!(out, (1..=10).collect::<Vec<_>>());
    }

    #[test]
    #[should_panic(expected = "boom")]
    fn scope_map_propagates_original_panic_payload() {
        let pool = ThreadPool::new(2);
        let _ = pool.scope_map(8, |i| {
            if i == 3 {
                panic!("boom");
            }
            i
        });
    }

    #[test]
    fn scope_map_panic_is_catchable_and_pool_survives() {
        // the serving path wraps scope_map items in catch_unwind; the
        // resumed payload must be the original one and the pool must keep
        // working afterwards
        let pool = ThreadPool::new(2);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.scope_map(4, |i| {
                if i == 1 {
                    panic!("poisoned request");
                }
                i
            })
        }));
        let payload = caught.expect_err("panic must propagate");
        let msg = payload.downcast_ref::<&str>().copied().unwrap_or("");
        assert_eq!(msg, "poisoned request");
        let out = pool.scope_map(6, |i| i * 2);
        assert_eq!(out, vec![0, 2, 4, 6, 8, 10]);
    }

    #[test]
    fn pool_survives_panicking_submitted_job() {
        let pool = ThreadPool::new(2);
        pool.submit(|| panic!("job panic"));
        let counter = Arc::new(AtomicU64::new(0));
        let c = Arc::clone(&counter);
        pool.submit(move || {
            c.fetch_add(1, Ordering::Relaxed);
        });
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(2);
        drop(pool); // must not hang
    }

    #[test]
    fn scope_tasks_runs_chained_dependencies() {
        // a 100-deep dependency chain: each task enqueues its successor
        let pool = ThreadPool::new(4);
        let count = AtomicU64::new(0);
        pool.scope_tasks(vec![0u64], |t, sink| {
            count.fetch_add(1, Ordering::Relaxed);
            if t < 99 {
                sink.push(t + 1);
            }
        });
        assert_eq!(count.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn scope_tasks_fans_out_from_every_seed() {
        let pool = ThreadPool::new(3);
        let sum = AtomicU64::new(0);
        // 8 seeds, each spawning 4 children: 8 + 32 tasks total
        pool.scope_tasks((0..8u64).map(|i| (i, true)).collect(), |(i, parent), sink| {
            sum.fetch_add(i + 1, Ordering::Relaxed);
            if parent {
                for _ in 0..4 {
                    sink.push((i, false));
                }
            }
        });
        // parents contribute Σ(i+1) = 36, children 4 × 36
        assert_eq!(sum.load(Ordering::Relaxed), 36 * 5);
    }

    #[test]
    #[should_panic(expected = "graph boom")]
    fn scope_tasks_propagates_original_panic_payload() {
        let pool = ThreadPool::new(2);
        pool.scope_tasks(vec![0usize, 1, 2, 3], |t, _| {
            if t == 2 {
                panic!("graph boom");
            }
        });
    }

    #[test]
    fn scope_tasks_terminates_under_rapid_repeated_drains() {
        // Regression guard for the drain-end lost-wakeup race: the final
        // outstanding decrement must be serialized with the waiters'
        // predicate check (via the queue mutex), or an idle worker can
        // sleep through the last notify and the scope never joins. Tiny
        // tasks and many drains maximize contention on that edge — a
        // regression shows up as this test hanging.
        let pool = ThreadPool::new(4);
        for round in 0..300u64 {
            let count = AtomicU64::new(0);
            pool.scope_tasks((0..8u64).collect(), |t, sink| {
                count.fetch_add(1, Ordering::Relaxed);
                if t < 8 {
                    sink.push(t + 100);
                }
            });
            assert_eq!(count.load(Ordering::Relaxed), 16, "round {round}");
        }
    }

    #[test]
    fn scope_tasks_panicking_drains_always_unwind() {
        // The abort flag is a termination predicate too: storing it must
        // hold the queue mutex so every waiter observes it, and the
        // caller must get the payload back on every single drain.
        let pool = ThreadPool::new(4);
        for round in 0..100 {
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                pool.scope_tasks(vec![0usize; 8], |_, _| panic!("abort drain"));
            }));
            assert!(r.is_err(), "round {round} must re-raise the task panic");
        }
    }

    #[test]
    fn scope_tasks_empty_seed_is_a_noop() {
        let pool = ThreadPool::new(2);
        pool.scope_tasks(Vec::<usize>::new(), |_, _| panic!("must not run"));
    }

    #[test]
    fn pinned_pool_runs_everything_the_unpinned_one_does() {
        let pool = ThreadPool::new_pinned(2);
        assert!(pool.pinned());
        assert!(!ThreadPool::new(1).pinned());
        let out = pool.scope_map(8, |i| i * 3);
        assert_eq!(out, (0..8).map(|i| i * 3).collect::<Vec<_>>());
        let count = AtomicU64::new(0);
        pool.scope_tasks(vec![(); 5], |_, _| {
            count.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 5);
    }

    #[test]
    fn pin_current_thread_is_best_effort() {
        // on Linux/x86_64 pinning to core 0 should succeed; elsewhere the
        // helper is a no-op returning false — either way, no crash
        let _ = pin_current_thread(0);
        let _ = pin_current_thread(100_000); // wraps modulo mask capacity
    }
}
