//! Deterministic pseudo-random number generation.
//!
//! The build environment is offline (no `rand` crate), so we implement a
//! small, well-tested PRNG from scratch. We use `xoshiro256**`, which has
//! excellent statistical quality for simulation workloads and is trivially
//! seedable/reproducible — important because every experiment in
//! EXPERIMENTS.md must be re-runnable bit-for-bit.

/// xoshiro256** by Blackman & Vigna (public domain reference algorithm).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed. The state is expanded with
    /// SplitMix64 as recommended by the xoshiro authors, which guarantees a
    /// non-zero state for any seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next_sm = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next_sm(), next_sm(), next_sm(), next_sm()] }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform integer in `[0, n)`. Uses Lemire's multiply-shift rejection
    /// method to avoid modulo bias.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0) is meaningless");
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= lo.wrapping_neg() % n {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform integer in the inclusive range `[lo, hi]`.
    #[inline]
    pub fn range_inclusive(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        let span = (hi - lo) as u64 + 1;
        lo + self.below(span) as i64
    }

    /// Uniform usize in `[0, n)`.
    #[inline]
    pub fn below_usize(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform f64 in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in `[lo, hi)`.
    #[inline]
    pub fn f64_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard-normal variate (Box–Muller; we discard the second value for
    /// simplicity — callers in this codebase never need bulk normals).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below_usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element reference.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below_usize(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..32).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.below(10);
            assert!(x < 10);
            seen[x as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear in 1000 draws");
    }

    #[test]
    fn below_unbiased_rough() {
        // chi-square-ish sanity: counts within 20% of expectation.
        let mut r = Rng::new(123);
        let n = 7u64;
        let mut counts = vec![0usize; n as usize];
        let draws = 70_000;
        for _ in 0..draws {
            counts[r.below(n) as usize] += 1;
        }
        let expect = draws / n as usize;
        for &c in &counts {
            assert!((c as f64 - expect as f64).abs() < 0.2 * expect as f64);
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(5);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn range_inclusive_hits_endpoints() {
        let mut r = Rng::new(9);
        let (mut lo_seen, mut hi_seen) = (false, false);
        for _ in 0..1000 {
            let x = r.range_inclusive(-3, 3);
            assert!((-3..=3).contains(&x));
            lo_seen |= x == -3;
            hi_seen |= x == 3;
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn normal_mean_and_var_rough() {
        let mut r = Rng::new(13);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }
}
