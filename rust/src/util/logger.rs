//! Tiny leveled logger writing to stderr (no `log`/`env_logger` wiring
//! needed at this scale). Level is controlled by `STENCILCACHE_LOG`
//! (error|warn|info|debug|trace; default info).

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn parse(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }

    pub fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static MAX_LEVEL: AtomicU8 = AtomicU8::new(2); // Info
static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();

/// Initialize from the environment; idempotent.
pub fn init() {
    START.get_or_init(Instant::now);
    if let Ok(v) = std::env::var("STENCILCACHE_LOG") {
        if let Some(l) = Level::parse(&v) {
            set_level(l);
        }
    }
}

pub fn set_level(l: Level) {
    MAX_LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn level() -> Level {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

pub fn enabled(l: Level) -> bool {
    (l as u8) <= MAX_LEVEL.load(Ordering::Relaxed)
}

/// Log a preformatted message at `l`. Prefer the macros.
pub fn log(l: Level, target: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(l) {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed();
    eprintln!("[{:>9.3}s {} {}] {}", t.as_secs_f64(), l.tag(), target, msg);
}

#[macro_export]
macro_rules! log_error { ($($arg:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Error, module_path!(), format_args!($($arg)*)) } }
#[macro_export]
macro_rules! log_warn { ($($arg:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Warn, module_path!(), format_args!($($arg)*)) } }
#[macro_export]
macro_rules! log_info { ($($arg:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Info, module_path!(), format_args!($($arg)*)) } }
#[macro_export]
macro_rules! log_debug { ($($arg:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Debug, module_path!(), format_args!($($arg)*)) } }
#[macro_export]
macro_rules! log_trace { ($($arg:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Trace, module_path!(), format_args!($($arg)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_levels() {
        assert_eq!(Level::parse("info"), Some(Level::Info));
        assert_eq!(Level::parse("WARN"), Some(Level::Warn));
        assert_eq!(Level::parse("warning"), Some(Level::Warn));
        assert_eq!(Level::parse("bogus"), None);
    }

    #[test]
    fn level_ordering_controls_enabled() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Trace);
        assert!(enabled(Level::Trace));
        set_level(Level::Info); // restore default for other tests
    }

    #[test]
    fn log_does_not_panic() {
        init();
        log(Level::Info, "test", format_args!("hello {}", 42));
    }
}
