//! Minimal JSON emitter + parser (no serde offline). Only what the metrics
//! registry, experiment drivers, and the wire protocol need: objects,
//! arrays, strings, numbers, bools.
//! Output is deterministic (insertion-ordered objects) so experiment logs
//! diff cleanly between runs.
//!
//! The parser consumes **untrusted** bytes (the TCP front end feeds client
//! lines straight into it), so structural misuse and malformed input are
//! typed [`JsonError`]s — never panics — and [`parse`] enforces a byte-size
//! and nesting-depth limit so a hostile document cannot blow the stack.

use std::fmt::Write as _;

/// Default input cap for [`parse`] (8 MiB — far above any manifest or wire
/// line we produce; [`parse_with_limits`] overrides it).
pub const MAX_PARSE_BYTES: usize = 8 << 20;

/// Default nesting-depth cap for [`parse`]. Recursion depth is bounded by
/// this, so a `[[[[…` bomb errors out instead of overflowing the stack.
pub const MAX_PARSE_DEPTH: usize = 64;

/// Typed error for JSON parsing and structural misuse.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum JsonError {
    /// `try_set` on a non-object value.
    NotAnObject,
    /// Malformed input; `at` is the byte offset of the problem.
    Syntax { at: usize, msg: String },
    /// Nesting exceeded the parser's depth limit.
    TooDeep { limit: usize },
    /// Input exceeded the parser's byte-size limit.
    TooLarge { len: usize, limit: usize },
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, fm: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            JsonError::NotAnObject => write!(fm, "set on a non-object JSON value"),
            JsonError::Syntax { at, msg } => write!(fm, "{msg} at byte {at}"),
            JsonError::TooDeep { limit } => write!(fm, "nesting deeper than {limit} levels"),
            JsonError::TooLarge { len, limit } => write!(fm, "document of {len} bytes exceeds the {limit}-byte limit"),
        }
    }
}

impl std::error::Error for JsonError {}

/// A JSON value. Objects preserve insertion order.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Int(i64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

/// Compact serialization (`x.to_string()` comes from this impl).
impl std::fmt::Display for Json {
    fn fmt(&self, fm: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut out = String::new();
        self.write(&mut out);
        fm.write_str(&out)
    }
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Insert (or overwrite) a key in an object value.
    ///
    /// On a non-object the value is first reset to an empty object (the
    /// old scalar is discarded). Builder code always starts from
    /// [`Json::obj`], so that case is pure misuse recovery — the resident
    /// server must never panic over a structural mistake; use [`try_set`]
    /// to *detect* the misuse instead.
    ///
    /// [`try_set`]: Json::try_set
    pub fn set(&mut self, key: &str, val: impl Into<Json>) -> &mut Self {
        if !matches!(self, Json::Obj(_)) {
            *self = Json::obj();
        }
        self.try_set(key, val).expect("just coerced to an object")
    }

    /// Fallible [`set`](Json::set): `Err(JsonError::NotAnObject)` instead
    /// of coercing when `self` is not an object.
    pub fn try_set(&mut self, key: &str, val: impl Into<Json>) -> Result<&mut Self, JsonError> {
        if let Json::Obj(pairs) = self {
            if let Some(p) = pairs.iter_mut().find(|(k, _)| k == key) {
                p.1 = val.into();
            } else {
                pairs.push((key.to_string(), val.into()));
            }
        } else {
            return Err(JsonError::NotAnObject);
        }
        Ok(self)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Serialize with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Int(i) => {
                let _ = write!(out, "{i}");
            }
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        match self {
            Json::Arr(xs) if !xs.is_empty() => {
                out.push_str("[\n");
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    push_indent(out, indent + 1);
                    x.write_pretty(out, indent + 1);
                }
                out.push('\n');
                push_indent(out, indent);
                out.push(']');
            }
            Json::Obj(pairs) if !pairs.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    push_indent(out, indent + 1);
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 1);
                }
                out.push('\n');
                push_indent(out, indent);
                out.push('}');
            }
            other => other.write(out),
        }
    }
}

fn push_indent(out: &mut String, n: usize) {
    for _ in 0..n {
        out.push_str("  ");
    }
}

fn write_num(out: &mut String, x: f64) {
    if x.is_finite() {
        if x == x.trunc() && x.abs() < 1e15 {
            let _ = write!(out, "{}", x as i64);
            out.push_str(".0");
        } else {
            let _ = write!(out, "{x}");
        }
    } else {
        // JSON has no Inf/NaN; emit null like most tolerant encoders.
        out.push_str("null");
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document (manifest files, wire requests) under the default
/// [`MAX_PARSE_BYTES`] / [`MAX_PARSE_DEPTH`] limits. Supports the full JSON
/// grammar except exotic number forms; numbers parse as `Int` when
/// integral, else `Num`.
pub fn parse(s: &str) -> Result<Json, JsonError> {
    parse_with_limits(s, MAX_PARSE_BYTES, MAX_PARSE_DEPTH)
}

/// [`parse`] with explicit byte-size and nesting-depth limits (the server
/// passes its per-line byte cap).
pub fn parse_with_limits(s: &str, max_bytes: usize, max_depth: usize) -> Result<Json, JsonError> {
    if s.len() > max_bytes {
        return Err(JsonError::TooLarge { len: s.len(), limit: max_bytes });
    }
    let mut p = Parser { b: s.as_bytes(), i: 0, depth: 0, max_depth };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        return Err(p.err("trailing garbage"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
    depth: usize,
    max_depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: impl Into<String>) -> JsonError {
        JsonError::Syntax { at: self.i, msg: msg.into() }
    }

    /// Container entry: bounds the recursion (containers are the only
    /// recursive productions).
    fn descend(&mut self) -> Result<(), JsonError> {
        self.depth += 1;
        if self.depth > self.max_depth {
            return Err(JsonError::TooDeep { limit: self.max_depth });
        }
        Ok(())
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(self.err(format!("unexpected {:?}", other.map(|c| c as char)))),
        }
    }

    fn literal(&mut self, word: &str, val: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(val)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self.peek().map(|c| c.is_ascii_digit()).unwrap_or(false) {
            self.i += 1;
        }
        let mut is_float = false;
        if self.peek() == Some(b'.') {
            is_float = true;
            self.i += 1;
            while self.peek().map(|c| c.is_ascii_digit()).unwrap_or(false) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            is_float = true;
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while self.peek().map(|c| c.is_ascii_digit()).unwrap_or(false) {
                self.i += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        if is_float {
            txt.parse::<f64>().map(Json::Num).map_err(|e| self.err(e.to_string()))
        } else {
            txt.parse::<i64>().map(Json::Int).map_err(|e| self.err(e.to_string()))
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16).map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        other => return Err(self.err(format!("bad escape {other:?}"))),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let s = std::str::from_utf8(&self.b[self.i..]).map_err(|_| self.err("invalid utf-8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.descend()?;
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            self.depth -= 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    self.depth -= 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.descend()?;
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            self.depth -= 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            pairs.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    self.depth -= 1;
                    return Ok(Json::Obj(pairs));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }
}

impl Json {
    /// Typed accessors used by the manifest reader.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Int(i) => Some(*i),
            Json::Num(x) if *x == x.trunc() => Some(*x as i64),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            Json::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
}

impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<i64> for Json {
    fn from(i: i64) -> Json {
        Json::Int(i)
    }
}
impl From<usize> for Json {
    fn from(i: usize) -> Json {
        Json::Int(i as i64)
    }
}
impl From<u64> for Json {
    fn from(i: u64) -> Json {
        Json::Int(i as i64)
    }
}
impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Json {
        Json::Arr(xs.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(Json::Null.to_string(), "null");
        assert_eq!(Json::from(true).to_string(), "true");
        assert_eq!(Json::from(42i64).to_string(), "42");
        assert_eq!(Json::from(2.5).to_string(), "2.5");
        assert_eq!(Json::from(3.0).to_string(), "3.0");
        assert_eq!(Json::from("hi").to_string(), "\"hi\"");
    }

    #[test]
    fn escaping() {
        assert_eq!(Json::from("a\"b\\c\nd").to_string(), "\"a\\\"b\\\\c\\nd\"");
        assert_eq!(Json::from("\u{1}").to_string(), "\"\\u0001\"");
    }

    #[test]
    fn object_roundtrip_order() {
        let mut o = Json::obj();
        o.set("b", 1i64).set("a", 2i64).set("b", 3i64);
        assert_eq!(o.to_string(), "{\"b\":3,\"a\":2}");
        assert_eq!(o.get("a"), Some(&Json::Int(2)));
        assert_eq!(o.get("missing"), None);
    }

    #[test]
    fn arrays_and_nesting() {
        let mut o = Json::obj();
        o.set("xs", vec![1i64, 2, 3]);
        let mut inner = Json::obj();
        inner.set("k", "v");
        o.set("inner", inner);
        assert_eq!(o.to_string(), "{\"xs\":[1,2,3],\"inner\":{\"k\":\"v\"}}");
    }

    #[test]
    fn nonfinite_becomes_null() {
        assert_eq!(Json::from(f64::NAN).to_string(), "null");
        assert_eq!(Json::from(f64::INFINITY).to_string(), "null");
    }

    #[test]
    fn parse_roundtrip() {
        let src = "{\"a\":1,\"b\":[true,null,2.5],\"c\":{\"d\":\"x\\ny\"}}";
        let v = parse(src).unwrap();
        assert_eq!(v.to_string(), src);
        assert_eq!(v.get("a").unwrap().as_i64(), Some(1));
        assert_eq!(v.get("b").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_str(), Some("x\ny"));
    }

    #[test]
    fn parse_numbers() {
        assert_eq!(parse("42").unwrap().as_i64(), Some(42));
        assert_eq!(parse("-7").unwrap().as_i64(), Some(-7));
        assert_eq!(parse("2.5").unwrap().as_f64(), Some(2.5));
        assert_eq!(parse("1e3").unwrap().as_f64(), Some(1000.0));
        assert_eq!(parse("-1.5e-2").unwrap().as_f64(), Some(-0.015));
    }

    #[test]
    fn parse_whitespace_and_empty_containers() {
        let v = parse("  { \"a\" : [ ] , \"b\" : { } }  ").unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 0);
        assert!(matches!(v.get("b"), Some(Json::Obj(p)) if p.is_empty()));
    }

    #[test]
    fn parse_unicode_escape() {
        assert_eq!(parse("\"\\u0041\"").unwrap().as_str(), Some("A"));
    }

    #[test]
    fn parse_errors() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("\"open").is_err());
    }

    #[test]
    fn set_on_non_object_recovers_try_set_reports() {
        let mut v = Json::Int(7);
        assert_eq!(v.try_set("k", 1i64), Err(JsonError::NotAnObject));
        assert_eq!(v, Json::Int(7), "try_set must not mutate a non-object");
        // set() coerces instead of panicking: the server must survive it
        v.set("k", 1i64);
        assert_eq!(v.to_string(), "{\"k\":1}");
        let mut o = Json::obj();
        o.try_set("a", 1i64).unwrap().try_set("b", 2i64).unwrap();
        assert_eq!(o.to_string(), "{\"a\":1,\"b\":2}");
    }

    #[test]
    fn parse_depth_limit() {
        let ok = format!("{}{}", "[".repeat(MAX_PARSE_DEPTH), "]".repeat(MAX_PARSE_DEPTH));
        assert!(parse(&ok).is_ok(), "exactly the limit must parse");
        let deep = format!("{}{}", "[".repeat(MAX_PARSE_DEPTH + 1), "]".repeat(MAX_PARSE_DEPTH + 1));
        assert_eq!(parse(&deep), Err(JsonError::TooDeep { limit: MAX_PARSE_DEPTH }));
        // an unclosed bomb (the stack-blowing shape) errors the same way
        let bomb = "[".repeat(100_000);
        assert_eq!(parse(&bomb), Err(JsonError::TooDeep { limit: MAX_PARSE_DEPTH }));
        // mixed nesting counts both container kinds
        let mixed = format!("{}1{}", "{\"k\":[".repeat(40), "]}".repeat(40));
        assert_eq!(parse_with_limits(&mixed, MAX_PARSE_BYTES, 16), Err(JsonError::TooDeep { limit: 16 }));
    }

    #[test]
    fn parse_size_limit() {
        assert_eq!(
            parse_with_limits("[1,2,3]", 3, MAX_PARSE_DEPTH),
            Err(JsonError::TooLarge { len: 7, limit: 3 })
        );
        assert!(parse_with_limits("[1,2,3]", 7, MAX_PARSE_DEPTH).is_ok());
    }

    #[test]
    fn errors_carry_position_and_render() {
        let err = parse("{\"a\" 1}").unwrap_err();
        assert!(matches!(err, JsonError::Syntax { .. }));
        let shown = err.to_string();
        assert!(shown.contains("byte"), "Display includes the offset: {shown}");
    }

    #[test]
    fn pretty_has_newlines() {
        let mut o = Json::obj();
        o.set("a", 1i64);
        let p = o.to_pretty();
        assert!(p.contains('\n'));
        assert!(p.starts_with('{') && p.ends_with('}'));
    }
}
