//! Substrate utilities built from scratch for the offline environment
//! (no tokio / clap / criterion / proptest / rand / serde available):
//!
//! - [`rng`] — xoshiro256** PRNG (deterministic experiments)
//! - [`stats`] — summaries, percentiles, correlation
//! - [`json`] — minimal JSON emitter for metrics snapshots
//! - [`cli`] — argument parsing for the `stencilcache` binary
//! - [`threadpool`] — fixed worker pool + parallel map
//! - [`bench`] — warmup/calibrated benchmark harness
//! - [`proptest`] — property-based testing with shrinking
//! - [`logger`] — leveled stderr logger

pub mod bench;
pub mod cli;
pub mod json;
pub mod logger;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod threadpool;
