//! Minimal property-based testing harness (no proptest crate offline).
//!
//! `forall(seed, cases, gen, prop)` draws `cases` random inputs from `gen`
//! and checks `prop` on each; on failure it performs greedy shrinking via
//! the generator's `shrink` hook and reports the minimal counterexample.
//!
//! The generators used across the test-suite (grid dims, cache params,
//! stencil radii) live here so every module's property tests share them.

use super::rng::Rng;

/// A random-input generator with optional shrinking.
pub trait Gen {
    type Value: Clone + std::fmt::Debug;
    fn generate(&self, rng: &mut Rng) -> Self::Value;
    /// Candidate smaller versions of `v`, in decreasing preference order.
    fn shrink(&self, _v: &Self::Value) -> Vec<Self::Value> {
        Vec::new()
    }
}

/// Run a property over `cases` random inputs. Panics with the (shrunk)
/// counterexample on failure, mirroring proptest's behaviour.
pub fn forall<G: Gen>(seed: u64, cases: usize, gen: &G, prop: impl Fn(&G::Value) -> bool) {
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let v = gen.generate(&mut rng);
        if !prop(&v) {
            let minimal = shrink_loop(gen, v, &prop);
            panic!("property failed (case {case}/{cases}, seed {seed}); minimal counterexample: {minimal:?}");
        }
    }
}

fn shrink_loop<G: Gen>(gen: &G, mut v: G::Value, prop: &impl Fn(&G::Value) -> bool) -> G::Value {
    // Greedy descent: take the first failing shrink candidate, repeat.
    let mut budget = 1000;
    'outer: while budget > 0 {
        for cand in gen.shrink(&v) {
            budget -= 1;
            if !prop(&cand) {
                v = cand;
                continue 'outer;
            }
        }
        break;
    }
    v
}

// ---------------------------------------------------------------------------
// Shared generators
// ---------------------------------------------------------------------------

/// Uniform usize in [lo, hi], shrinking toward lo.
pub struct UsizeIn {
    pub lo: usize,
    pub hi: usize,
}

impl Gen for UsizeIn {
    type Value = usize;
    fn generate(&self, rng: &mut Rng) -> usize {
        self.lo + rng.below_usize(self.hi - self.lo + 1)
    }
    fn shrink(&self, v: &usize) -> Vec<usize> {
        let mut out = Vec::new();
        if *v > self.lo {
            out.push(self.lo);
            out.push(self.lo + (*v - self.lo) / 2);
            out.push(*v - 1);
        }
        out.dedup();
        out
    }
}

/// Grid dimensions: `d` dims each in [lo, hi], shrinking each dim toward lo.
pub struct DimsGen {
    pub d: usize,
    pub lo: usize,
    pub hi: usize,
}

impl Gen for DimsGen {
    type Value = Vec<usize>;
    fn generate(&self, rng: &mut Rng) -> Vec<usize> {
        (0..self.d).map(|_| self.lo + rng.below_usize(self.hi - self.lo + 1)).collect()
    }
    fn shrink(&self, v: &Vec<usize>) -> Vec<Vec<usize>> {
        let mut out = Vec::new();
        for i in 0..v.len() {
            if v[i] > self.lo {
                let mut smaller = v.clone();
                smaller[i] = self.lo + (v[i] - self.lo) / 2;
                out.push(smaller);
                let mut minus = v.clone();
                minus[i] -= 1;
                out.push(minus);
            }
        }
        out
    }
}

/// Pair of independent generators.
pub struct PairGen<A, B>(pub A, pub B);

impl<A: Gen, B: Gen> Gen for PairGen<A, B> {
    type Value = (A::Value, B::Value);
    fn generate(&self, rng: &mut Rng) -> Self::Value {
        (self.0.generate(rng), self.1.generate(rng))
    }
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out: Vec<Self::Value> =
            self.0.shrink(&v.0).into_iter().map(|a| (a, v.1.clone())).collect();
        out.extend(self.1.shrink(&v.1).into_iter().map(|b| (v.0.clone(), b)));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall(1, 200, &UsizeIn { lo: 1, hi: 100 }, |&x| x >= 1 && x <= 100);
    }

    #[test]
    fn failing_property_shrinks_to_minimum() {
        // Property "x < 10" fails for x >= 10; minimal counterexample is 10.
        let result = std::panic::catch_unwind(|| {
            forall(2, 500, &UsizeIn { lo: 0, hi: 1000 }, |&x| x < 10);
        });
        let err = result.unwrap_err();
        let msg = err.downcast_ref::<String>().expect("panic message");
        assert!(msg.contains("counterexample: 10"), "got: {msg}");
    }

    #[test]
    fn dims_gen_in_bounds() {
        let g = DimsGen { d: 3, lo: 4, hi: 16 };
        let mut rng = Rng::new(3);
        for _ in 0..100 {
            let dims = g.generate(&mut rng);
            assert_eq!(dims.len(), 3);
            assert!(dims.iter().all(|&n| (4..=16).contains(&n)));
        }
    }

    #[test]
    fn pair_gen_shrinks_both_sides() {
        let g = PairGen(UsizeIn { lo: 0, hi: 10 }, UsizeIn { lo: 0, hi: 10 });
        let shrinks = g.shrink(&(5, 5));
        assert!(shrinks.iter().any(|&(a, b)| a < 5 && b == 5));
        assert!(shrinks.iter().any(|&(a, b)| a == 5 && b < 5));
    }
}
