//! Small statistics helpers used by the benchmark harness, the metrics
//! registry, and the experiment drivers.

/// Summary statistics over a sample of f64 observations.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub stddev: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
}

impl Summary {
    /// Compute a summary; `xs` may be in any order. Returns a zeroed summary
    /// for an empty sample.
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary { n: 0, mean: 0.0, stddev: 0.0, min: 0.0, max: 0.0, p50: 0.0, p90: 0.0, p99: 0.0 };
        }
        let mut sorted: Vec<f64> = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).expect("NaN in sample"));
        let n = sorted.len();
        let mean = sorted.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        Summary {
            n,
            mean,
            stddev: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile_sorted(&sorted, 0.50),
            p90: percentile_sorted(&sorted, 0.90),
            p99: percentile_sorted(&sorted, 0.99),
        }
    }
}

/// Linear-interpolated percentile of an already-sorted sample, `q` in [0,1].
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Pearson correlation coefficient of two equal-length samples.
/// Used by the FIG5 experiment to quantify the paper's §6 claim that miss
/// spikes correlate with short interference-lattice vectors.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    if xs.is_empty() {
        return 0.0;
    }
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut cov = 0.0;
    let mut vx = 0.0;
    let mut vy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        cov += (x - mx) * (y - my);
        vx += (x - mx) * (x - mx);
        vy += (y - my) * (y - my);
    }
    if vx == 0.0 || vy == 0.0 {
        return 0.0;
    }
    cov / (vx.sqrt() * vy.sqrt())
}

/// 2x2 contingency-table association (phi coefficient). Used to correlate
/// binary classifications: "grid has a miss spike" vs "lattice has a short
/// vector" (Figure 5A vs 5B).
pub fn phi_coefficient(both: usize, only_a: usize, only_b: usize, neither: usize) -> f64 {
    let (a, b, c, d) = (both as f64, only_a as f64, only_b as f64, neither as f64);
    let denom = ((a + b) * (c + d) * (a + c) * (b + d)).sqrt();
    if denom == 0.0 {
        return 0.0;
    }
    (a * d - b * c) / denom
}

/// Geometric mean (used for speedup aggregation across grids).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let s: f64 = xs.iter().map(|x| x.max(f64::MIN_POSITIVE).ln()).sum();
    (s / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
        assert!((s.stddev - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn summary_empty_and_singleton() {
        assert_eq!(Summary::of(&[]).n, 0);
        let s = Summary::of(&[7.5]);
        assert_eq!(s.n, 1);
        assert_eq!(s.mean, 7.5);
        assert_eq!(s.p99, 7.5);
        assert_eq!(s.stddev, 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let sorted = [0.0, 10.0];
        assert!((percentile_sorted(&sorted, 0.5) - 5.0).abs() < 1e-12);
        assert_eq!(percentile_sorted(&sorted, 0.0), 0.0);
        assert_eq!(percentile_sorted(&sorted, 1.0), 10.0);
    }

    #[test]
    fn pearson_perfect_and_anti() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
        let zs = [8.0, 6.0, 4.0, 2.0];
        assert!((pearson(&xs, &zs) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_constant_is_zero() {
        assert_eq!(pearson(&[1.0, 1.0, 1.0], &[1.0, 2.0, 3.0]), 0.0);
    }

    #[test]
    fn phi_perfect_association() {
        // spikes iff short vector: only both/neither populated.
        assert!((phi_coefficient(10, 0, 0, 30) - 1.0).abs() < 1e-12);
        // perfect anti-association
        assert!((phi_coefficient(0, 10, 30, 0) + 1.0).abs() < 1e-12);
        // independence-ish
        let phi = phi_coefficient(5, 5, 5, 5);
        assert!(phi.abs() < 1e-12);
    }

    #[test]
    fn geomean_basic() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[3.0, 3.0, 3.0]) - 3.0).abs() < 1e-12);
    }
}
