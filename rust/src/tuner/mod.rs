//! Traversal auto-tuner.
//!
//! The paper gives a family of lattice-guided traversals (§4 pencil sweep;
//! the §3/§4-remark axis-swept tiles); which one wins on a concrete grid
//! depends on the lattice geometry in ways the closed-form bounds are too
//! loose to rank (the Eq 12 constant `c''_d = r(2r+1)^d·2d·2^{d(d−1)/4}`
//! is ~4·10³ for the 13-point star). The tuner does what a production
//! system does: run each candidate on a cheap **calibration slice** of the
//! grid (the paper itself notes the third dimension is irrelevant to the
//! interference phenomenon — the lattice only involves n_1…n_{d−1}) and
//! pick the argmin before committing to the full sweep.

use crate::cache::{CacheParams, CacheSim, MachineModel};
use crate::engine;
use crate::grid::{GridDesc, MultiArrayLayout};
use crate::stencil::Stencil;
use crate::traversal::{self, FittingOptions, Order, Traversal};

/// A candidate traversal family member.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Candidate {
    /// §4 pencil sweep with options.
    Pencil { sweep_index: Option<usize> },
    /// Axis-swept lattice tile (3-D only) with occupancy budget and z block.
    TiledZ { assoc: usize, tz: usize },
    /// Lexicographic baseline.
    Natural,
}

impl Candidate {
    pub fn name(&self) -> String {
        match self {
            Candidate::Pencil { sweep_index: None } => "pencil".into(),
            Candidate::Pencil { sweep_index: Some(i) } => format!("pencil(iv={i})"),
            Candidate::TiledZ { assoc, tz } => format!("tiled(a={assoc},tz={tz})"),
            Candidate::Natural => "natural".into(),
        }
    }

    /// §4 fitting options for a Pencil candidate.
    fn pencil_opts(sweep_index: Option<usize>) -> FittingOptions {
        FittingOptions { sweep_index, ..FittingOptions::default() }
    }

    /// Tile geometry for a TiledZ candidate — single source of truth shared
    /// by the materialized and streaming builders, so calibration (build)
    /// and production (build_stream) can never disagree on the tile.
    fn tiled_z_tile(grid: &GridDesc, r: usize, cache: &CacheParams, assoc: usize, tz: usize) -> Vec<usize> {
        let (t1, t2) = traversal::tiled::conflict_free_tile_assoc(grid.storage_dims(), cache.lattice_modulus(), r, assoc);
        let tz_eff = tz.min(grid.dims()[grid.ndim() - 1]).max(1);
        vec![t1, t2, tz_eff]
    }

    /// Materialize the order for `grid`.
    pub fn build(&self, grid: &GridDesc, r: usize, cache: &CacheParams) -> Order {
        match self {
            Candidate::Pencil { sweep_index } => {
                let lat = crate::lattice::InterferenceLattice::new(grid.storage_dims(), cache.lattice_modulus());
                traversal::fitting::cache_fitting_opts(grid, r, &lat, &Self::pencil_opts(*sweep_index))
            }
            Candidate::TiledZ { assoc, tz } => {
                traversal::blocked(grid, r, &Self::tiled_z_tile(grid, r, cache, *assoc, *tz))
            }
            Candidate::Natural => traversal::natural(grid, r),
        }
    }

    /// Build the candidate as a **streaming** traversal for `grid` — the
    /// production path: nothing proportional to the grid is materialized.
    pub fn build_stream(&self, grid: &GridDesc, r: usize, cache: &CacheParams) -> Box<dyn Traversal> {
        match self {
            Candidate::Pencil { sweep_index } => {
                let lat = crate::lattice::InterferenceLattice::new(grid.storage_dims(), cache.lattice_modulus());
                Box::new(traversal::fitting::cache_fitting_stream_opts(grid, r, &lat, &Self::pencil_opts(*sweep_index)))
            }
            Candidate::TiledZ { assoc, tz } => {
                Box::new(traversal::blocked_stream(grid, r, &Self::tiled_z_tile(grid, r, cache, *assoc, *tz)))
            }
            Candidate::Natural => Box::new(traversal::natural_stream(grid, r)),
        }
    }
}

/// The fitting-family candidate set (what the paper's "cache fitting
/// algorithm" line uses in FIG4 — natural excluded on purpose so the
/// unfavorable-grid pathology stays visible, as in the paper's figure).
pub fn fitting_candidates(d: usize) -> Vec<Candidate> {
    let mut c = vec![Candidate::Pencil { sweep_index: None }];
    for iv in 0..d {
        c.push(Candidate::Pencil { sweep_index: Some(iv) });
    }
    if d == 3 {
        c.push(Candidate::TiledZ { assoc: 1, tz: 16 });
        c.push(Candidate::TiledZ { assoc: 2, tz: 16 });
        c.push(Candidate::TiledZ { assoc: 2, tz: 32 });
    }
    c
}

/// What the tuner minimizes on the calibration slice.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TuneMetric {
    /// Simulated L1 cache misses — deterministic, machine-independent;
    /// what the paper's analysis predicts (default).
    SimulatedMisses,
    /// Wall-clock of a real numeric `engine::apply` sweep — what a serving
    /// system actually pays. Noisy, so each candidate is timed best-of-3;
    /// use when calibrating the native numeric backend on live hardware.
    WallClock,
    /// Estimated stall cycles over the machine's **full** memory model
    /// (L1 + L2 + TLB where present, weighted by the machine's latency
    /// model) — deterministic like `SimulatedMisses`, but it can rank
    /// candidates differently when TLB or L2 traffic dominates. Machines
    /// with a nonzero `Latency::prefetch` term are priced with the
    /// kernel's software prefetch hiding cold-miss memory trips
    /// (`LoadProfile::stall_cycles_prefetched`), keeping the estimate
    /// correlated with the vectorized wall clock. On a single-level
    /// no-prefetch machine it is `misses × mem_latency`, so it agrees
    /// with `SimulatedMisses` exactly.
    StallCycles,
}

/// Outcome of tuning: the winning candidate and its calibration score
/// (misses, nanoseconds and/or stall cycles, depending on the metric).
#[derive(Debug)]
pub struct Tuned {
    pub candidate: Candidate,
    /// Simulated misses on the calibration slice (0 unless
    /// `SimulatedMisses`).
    pub calib_misses: u64,
    /// Best-of-3 apply wall time on the slice (0 unless `WallClock`).
    pub calib_nanos: u64,
    /// Estimated stall cycles on the slice (0 unless `StallCycles`).
    pub calib_stall: u64,
}

/// The z-thinned calibration grid for `grid` (last dim clamped to
/// `calib_z`, padding preserved).
fn calibration_grid(grid: &GridDesc, stencil: &Stencil, calib_z: usize) -> GridDesc {
    let d = grid.ndim();
    let mut calib_dims = grid.dims().to_vec();
    if d >= 2 {
        calib_dims[d - 1] = calib_dims[d - 1].min(calib_z.max(2 * stencil.radius() + 2));
    }
    let pad: Vec<usize> = grid.storage_dims().iter().zip(grid.dims()).map(|(&s, &l)| s - l).collect();
    GridDesc::with_padding(&calib_dims, &pad)
}

/// Pick the best candidate for (grid, stencil, cache) by simulating each
/// on a z-thinned calibration grid (last dim clamped to `calib_z`).
pub fn tune(grid: &GridDesc, stencil: &Stencil, cache: &CacheParams, candidates: &[Candidate], calib_z: usize) -> Tuned {
    tune_with_metric(grid, stencil, &MachineModel::l1_only(*cache), candidates, calib_z, TuneMetric::SimulatedMisses)
}

/// [`tune`] with an explicit machine and calibration metric: simulated L1
/// misses (the paper's model), measured wall-clock of the numeric sweep
/// (what the native backend cares about on real hardware), or estimated
/// stall cycles over the machine's full memory hierarchy.
pub fn tune_with_metric(
    grid: &GridDesc,
    stencil: &Stencil,
    machine: &MachineModel,
    candidates: &[Candidate],
    calib_z: usize,
    metric: TuneMetric,
) -> Tuned {
    assert!(!candidates.is_empty());
    let cache = &machine.l1;
    let calib = calibration_grid(grid, stencil, calib_z);
    let r = stencil.radius();
    let mut best: Option<Tuned> = None;
    let win = |cand: &Candidate, misses: u64, nanos: u64, stall: u64| Tuned {
        candidate: cand.clone(),
        calib_misses: misses,
        calib_nanos: nanos,
        calib_stall: stall,
    };
    match metric {
        TuneMetric::SimulatedMisses => {
            let layout = MultiArrayLayout::paper_offsets(&calib, 1, cache.size_words());
            for cand in candidates {
                let order = cand.build(&calib, r, cache);
                let mut sim = CacheSim::new(*cache);
                let rep = engine::simulate(&order, &layout, stencil, &mut sim);
                let misses = rep.total.misses();
                if best.as_ref().map(|b| misses < b.calib_misses).unwrap_or(true) {
                    best = Some(win(cand, misses, 0, 0));
                }
            }
        }
        TuneMetric::WallClock => {
            let words = calib.storage_words() as usize;
            let u = crate::solver::deterministic_field(&calib, r, 0xCA11B);
            let mut q = vec![0.0f64; words];
            for cand in candidates {
                let t = cand.build_stream(&calib, r, cache);
                let mut best_ns = u64::MAX;
                // best-of-3: the first run also warms u/q into the caches
                for _ in 0..3 {
                    let t0 = std::time::Instant::now();
                    engine::apply(t.as_ref(), &calib, stencil, &u, &mut q);
                    best_ns = best_ns.min(t0.elapsed().as_nanos() as u64);
                }
                if best.as_ref().map(|b| best_ns < b.calib_nanos).unwrap_or(true) {
                    best = Some(win(cand, 0, best_ns, 0));
                }
            }
        }
        TuneMetric::StallCycles => {
            let layout = MultiArrayLayout::paper_offsets(&calib, 1, cache.size_words());
            for cand in candidates {
                let order = cand.build(&calib, r, cache);
                let rep = engine::simulate_on_machine(&order, &layout, stencil, machine);
                // price candidates the way the native kernel will run
                // them: with the machine's planner-chosen software
                // prefetch hiding cold-miss memory trips (a no-op on
                // machines whose latency model has no prefetch term)
                let stall = rep.levels.stall_cycles_prefetched(machine.latency, machine.prefetch_distance());
                if best.as_ref().map(|b| stall < b.calib_stall).unwrap_or(true) {
                    best = Some(win(cand, 0, 0, stall));
                }
            }
        }
    }
    best.unwrap()
}

/// One-call convenience: tune over the fitting family and build the
/// winning order for the full grid (materialized — kept for the experiment
/// drivers, which replay one small order many times).
pub fn auto_fitting_order(grid: &GridDesc, stencil: &Stencil, cache: &CacheParams) -> (Order, Candidate) {
    let tuned = tune(grid, stencil, cache, &fitting_candidates(grid.ndim()), 16);
    let order = tuned.candidate.build(grid, stencil.radius(), cache);
    (order, tuned.candidate)
}

/// Streaming twin of [`auto_fitting_order`]: tune on the cheap calibration
/// slice (materialized — the slice is z-thinned by construction), then
/// build the winner as a lazy [`Traversal`] over the *full* grid. This is
/// what the coordinator's Analyze path uses: the full-grid visit sequence
/// is never materialized.
pub fn auto_fitting_traversal(grid: &GridDesc, stencil: &Stencil, cache: &CacheParams) -> (Box<dyn Traversal>, Candidate) {
    let tuned = tune(grid, stencil, cache, &fitting_candidates(grid.ndim()), 16);
    let t = tuned.candidate.build_stream(grid, stencil.radius(), cache);
    (t, tuned.candidate)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tuner_picks_a_candidate() {
        let grid = GridDesc::new(&[44, 91, 30]);
        let stencil = Stencil::star13();
        let cache = CacheParams::r10000();
        let tuned = tune(&grid, &stencil, &cache, &fitting_candidates(3), 16);
        assert!(tuned.calib_misses > 0);
        assert_eq!(tuned.calib_nanos, 0);
    }

    #[test]
    fn wallclock_metric_times_real_sweeps() {
        let grid = GridDesc::new(&[40, 36, 30]);
        let stencil = Stencil::star(3, 1);
        let cache = CacheParams::new(2, 64, 2);
        let cands = fitting_candidates(3);
        let tuned = tune_with_metric(&grid, &stencil, &MachineModel::l1_only(cache), &cands, 16, TuneMetric::WallClock);
        assert!(tuned.calib_nanos > 0, "wall-clock calibration must measure something");
        assert_eq!(tuned.calib_misses, 0);
        assert!(cands.contains(&tuned.candidate));
    }

    #[test]
    fn stall_metric_on_single_level_machine_agrees_with_misses() {
        // Single level: stall = misses × mem latency, so the argmin must
        // coincide with the SimulatedMisses pick and the scores must be
        // proportional.
        let grid = GridDesc::new(&[44, 91, 30]);
        let stencil = Stencil::star13();
        let machine = MachineModel::r10000();
        let cands = fitting_candidates(3);
        let by_misses = tune(&grid, &stencil, &machine.l1, &cands, 16);
        let by_stall = tune_with_metric(&grid, &stencil, &machine, &cands, 16, TuneMetric::StallCycles);
        assert_eq!(by_misses.candidate, by_stall.candidate);
        assert_eq!(by_stall.calib_stall, by_misses.calib_misses * machine.latency.mem);
    }

    #[test]
    fn stall_metric_runs_on_full_hierarchy() {
        let grid = GridDesc::new(&[40, 36, 30]);
        let stencil = Stencil::star(3, 1);
        let machine = MachineModel::r10000_full();
        let cands = fitting_candidates(3);
        let tuned = tune_with_metric(&grid, &stencil, &machine, &cands, 16, TuneMetric::StallCycles);
        assert!(tuned.calib_stall > 0);
        assert_eq!(tuned.calib_misses, 0);
        assert!(cands.contains(&tuned.candidate));
    }

    #[test]
    fn auto_order_is_permutation_of_natural() {
        let grid = GridDesc::new(&[30, 28, 20]);
        let stencil = Stencil::star(3, 1);
        let cache = CacheParams::new(2, 64, 2);
        let (order, _) = auto_fitting_order(&grid, &stencil, &cache);
        assert_eq!(
            order.canonical_set(),
            traversal::natural(&grid, 1).canonical_set()
        );
    }

    #[test]
    fn auto_beats_natural_on_favorable_fig4_grid() {
        let grid = GridDesc::new(&[44, 91, 40]);
        let stencil = Stencil::star13();
        let cache = CacheParams::r10000();
        let layout = MultiArrayLayout::paper_offsets(&grid, 1, cache.size_words());
        let run = |order: &Order| {
            let mut sim = CacheSim::new(cache);
            engine::simulate(order, &layout, &stencil, &mut sim).total.misses()
        };
        let nat = run(&traversal::natural(&grid, 2));
        let (auto, cand) = auto_fitting_order(&grid, &stencil, &cache);
        let fit = run(&auto);
        assert!(
            (fit as f64) < 0.45 * nat as f64,
            "auto ({}) {fit} vs natural {nat}",
            cand.name()
        );
    }

    #[test]
    fn stream_candidate_matches_materialized() {
        let grid = GridDesc::new(&[30, 28, 20]);
        let cache = CacheParams::new(2, 64, 2);
        for cand in fitting_candidates(3) {
            let mat = cand.build(&grid, 1, &cache);
            let streamed = traversal::materialize(cand.build_stream(&grid, 1, &cache).as_ref());
            assert_eq!(
                streamed.canonical_set(),
                mat.canonical_set(),
                "candidate {}",
                cand.name()
            );
        }
    }

    #[test]
    fn auto_traversal_agrees_with_auto_order() {
        let grid = GridDesc::new(&[30, 28, 20]);
        let stencil = Stencil::star(3, 1);
        let cache = CacheParams::new(2, 64, 2);
        let (order, cand_o) = auto_fitting_order(&grid, &stencil, &cache);
        let (stream, cand_s) = auto_fitting_traversal(&grid, &stencil, &cache);
        assert_eq!(cand_o, cand_s);
        assert_eq!(stream.num_points(), order.len() as u64);
        assert_eq!(
            traversal::materialize(stream.as_ref()).canonical_set(),
            order.canonical_set()
        );
    }

    #[test]
    fn tuner_respects_2d_grids() {
        let grid = GridDesc::new(&[60, 32]);
        let stencil = Stencil::star(2, 1);
        let cache = CacheParams::new(1, 64, 1);
        let cands = fitting_candidates(2);
        assert!(cands.iter().all(|c| !matches!(c, Candidate::TiledZ { .. })));
        let tuned = tune(&grid, &stencil, &cache, &cands, 16);
        let _ = tuned.candidate.build(&grid, 1, &cache);
    }

    #[test]
    fn candidate_names_stable() {
        assert_eq!(Candidate::Natural.name(), "natural");
        assert_eq!(Candidate::TiledZ { assoc: 2, tz: 16 }.name(), "tiled(a=2,tz=16)");
        assert_eq!(Candidate::Pencil { sweep_index: Some(1) }.name(), "pencil(iv=1)");
    }
}
