//! The memoization tier of the serving layer: an **S3-FIFO** cache over
//! canonical request keys.
//!
//! Everything the analysis pipeline produces — lattice reduction, §6
//! short-vector verdicts, padding advice, Eq 7/12 bounds, and the cache
//! simulation itself — is a pure function of
//! `(dims, stencil, rhs arrays, machine, planner knobs)` (sharded
//! analyses additionally of the worker-pool size: the coordinator admits
//! a report only when it was computed at the quiet-coordinator shard
//! count, so a hit always serves what a quiet recompute would produce).
//! Real serving
//! traffic is Zipf-skewed over a small set of hot grid shapes punctuated
//! by one-off sweep scans, so the coordinator memoizes [`Plan`]s and
//! analysis [`MissReport`]s behind an S3-FIFO admission/eviction policy
//! (Yang et al., *FIFO queues are all you need for cache eviction*):
//!
//! - a **small** probationary FIFO (~10% of the budget, clamped to ≥ 1 so
//!   tiny capacities still admit — the reference design's `capacity / 10`
//!   rounds to 0 below 10) absorbs one-hit-wonder scan traffic;
//! - a **main** FIFO (the rest of the budget) holds objects that proved
//!   reuse while probationary; eviction is lazy-promotion (freq > 0 →
//!   decrement and reinsert);
//! - a **ghost** FIFO of recently demoted *keys* (no values) readmits
//!   comeback shapes straight into main.
//!
//! Unlike the related-repo reference (`/root/related/djc__s3-fifo`), which
//! scans its `VecDeque`s linearly on every `get`, this implementation
//! keeps a `HashMap` index beside the queues: lookups are O(1) and the
//! queues hold only keys. Capacity is **weight-budgeted**: the coordinator
//! charges approximate entry bytes, unit tests charge 1 per entry to get
//! entry-count semantics.

use super::planner::{Plan, PlannerConfig, TraversalChoice};
use super::{StencilRequest, StencilSpec};
use crate::engine::MissReport;
use std::collections::{HashMap, VecDeque};
use std::hash::Hash;
use std::sync::Arc;

/// Access-frequency saturation (2 bits in the original design).
pub const MAX_FREQ: u8 = 3;

/// Default byte budget for a coordinator's memo tier.
pub const DEFAULT_MEMO_BYTES: usize = 1 << 20;

// ---------------------------------------------------------------------------
// Canonical request keys
// ---------------------------------------------------------------------------

/// Which memoized artifact a key addresses.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Facet {
    /// The planner output alone (`JobKind::Plan`, and the plan lookup that
    /// Execute/Solve reuse before running numerics).
    Plan,
    /// A full analysis under the given traversal. `JobKind::Analyze` is
    /// canonicalized to `Analysis(plan.traversal)`, so an explicit
    /// `AnalyzeWith` that names the planner's own choice shares the entry.
    Analysis(TraversalChoice),
}

/// Canonical cache identity of a request against one planner
/// configuration.
///
/// Canonicalization rules (see DESIGN.md §2.8):
/// 1. `StencilSpec::Star13` ≡ `StencilSpec::Star { r: 2 }` (they build the
///    identical 3-D stencil);
/// 2. `JobKind::Analyze` ≡ `JobKind::AnalyzeWith(plan.traversal)`;
/// 3. Execute/Solve share the `Facet::Plan` entry — numerics always run.
///
/// The machine model and planner knobs are part of the key, so one shared
/// cache can never serve a plan computed for a different machine.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct RequestKey {
    pub dims: Vec<usize>,
    pub stencil: StencilSpec,
    pub rhs_arrays: usize,
    pub machine: crate::cache::MachineModel,
    pub max_pad: usize,
    pub auto_pad: bool,
    /// Block-shard override — part of the identity because it changes the
    /// plan's `shard_grid` and therefore the decomposed solve's traffic.
    pub shard_grid: Option<Vec<usize>>,
    /// RAM budget — part of the identity because it flips `out_of_core`
    /// and refines the shard grid.
    pub ram_budget_words: Option<u64>,
    pub facet: Facet,
}

impl RequestKey {
    fn canonical_stencil(spec: &StencilSpec) -> StencilSpec {
        match spec {
            // Star13 *is* star(3, 2); the two specs build bit-identical
            // stencils, so they must share cache entries.
            StencilSpec::Star13 => StencilSpec::Star { r: 2 },
            s => s.clone(),
        }
    }

    fn new(config: &PlannerConfig, req: &StencilRequest, facet: Facet) -> RequestKey {
        RequestKey {
            dims: req.dims.clone(),
            stencil: RequestKey::canonical_stencil(&req.stencil),
            rhs_arrays: req.rhs_arrays,
            machine: config.machine.clone(),
            max_pad: config.max_pad,
            auto_pad: config.auto_pad,
            shard_grid: config.shard_grid.clone(),
            ram_budget_words: config.ram_budget_words,
            facet,
        }
    }

    /// Key for the plan artifact of `req`.
    pub fn plan_facet(config: &PlannerConfig, req: &StencilRequest) -> RequestKey {
        RequestKey::new(config, req, Facet::Plan)
    }

    /// Key for an analysis under the *resolved* traversal choice.
    pub fn analysis_facet(config: &PlannerConfig, req: &StencilRequest, choice: TraversalChoice) -> RequestKey {
        RequestKey::new(config, req, Facet::Analysis(choice))
    }

    /// Approximate heap + inline bytes of this key (budget charging).
    pub fn approx_bytes(&self) -> usize {
        std::mem::size_of::<RequestKey>()
            + (self.dims.len() + self.shard_grid.as_ref().map_or(0, |g| g.len())) * std::mem::size_of::<usize>()
    }
}

/// A memoized artifact. Plans are `Arc`-shared: a cache hit clones the
/// `Arc`, never the `Plan`.
#[derive(Debug, Clone)]
pub enum CachedValue {
    Plan(Arc<Plan>),
    Analysis { plan: Arc<Plan>, report: MissReport },
}

impl CachedValue {
    pub fn plan(&self) -> &Arc<Plan> {
        match self {
            CachedValue::Plan(p) => p,
            CachedValue::Analysis { plan, .. } => plan,
        }
    }

    /// Approximate bytes held alive by this value (the shared `Plan` is
    /// charged once per entry — an overestimate that keeps the budget
    /// conservative).
    pub fn approx_bytes(&self) -> usize {
        let p = self.plan();
        let plan_bytes = std::mem::size_of::<Plan>()
            + (p.dims.len() + p.storage_dims.len() + p.pad.len() + p.shard_grid.len())
                * std::mem::size_of::<usize>();
        match self {
            CachedValue::Plan(_) => plan_bytes,
            CachedValue::Analysis { .. } => plan_bytes + std::mem::size_of::<MissReport>(),
        }
    }
}

/// Budget charge for one memo entry (key + value).
pub fn entry_bytes(key: &RequestKey, value: &CachedValue) -> usize {
    key.approx_bytes() + value.approx_bytes()
}

// ---------------------------------------------------------------------------
// The generic S3-FIFO structure
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Queue {
    Small,
    Main,
}

#[derive(Debug)]
struct Entry<V> {
    value: V,
    weight: usize,
    freq: u8,
    queue: Queue,
}

/// Cumulative per-queue counters of an [`S3Fifo`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MemoCounters {
    /// Hits served while the entry was probationary (small queue).
    pub small_hits: u64,
    /// Hits served from the main queue.
    pub main_hits: u64,
    pub misses: u64,
    /// New entries admitted (overwrites of a resident key not included).
    pub insertions: u64,
    /// Entries evicted from the small queue (demoted to ghost history).
    pub small_evictions: u64,
    /// Entries evicted from the main queue (dropped entirely).
    pub main_evictions: u64,
    /// Insertions whose key was found in the ghost history and therefore
    /// admitted straight into the main queue.
    pub ghost_readmits: u64,
}

impl MemoCounters {
    pub fn hits(&self) -> u64 {
        self.small_hits + self.main_hits
    }

    pub fn evictions(&self) -> u64 {
        self.small_evictions + self.main_evictions
    }

    pub fn lookups(&self) -> u64 {
        self.hits() + self.misses
    }

    pub fn hit_rate(&self) -> f64 {
        if self.lookups() == 0 {
            0.0
        } else {
            self.hits() as f64 / self.lookups() as f64
        }
    }
}

/// Point-in-time usage snapshot of an [`S3Fifo`] (for `metrics_json`).
#[derive(Debug, Clone, Copy)]
pub struct MemoSnapshot {
    /// Resident entries (small + main).
    pub entries: usize,
    /// Resident weight (bytes under the coordinator's charging).
    pub weight: usize,
    pub capacity: usize,
    pub ghost_keys: usize,
    pub counters: MemoCounters,
}

/// A weight-budgeted S3-FIFO cache with an O(1) `HashMap` index.
///
/// `capacity` and per-entry weights share one unit: the coordinator passes
/// bytes, tests pass 1 per entry for entry-count semantics. The small
/// (probationary) queue targets 10% of the budget, **clamped to ≥ 1** so
/// capacities below 10 still admit through it.
#[derive(Debug)]
pub struct S3Fifo<K, V> {
    capacity: usize,
    small_budget: usize,
    entries: HashMap<K, Entry<V>>,
    small: VecDeque<K>,
    main: VecDeque<K>,
    /// Ghost history: FIFO of demoted keys + membership index. Deque
    /// removal is lazy — readmitted keys leave a stale deque slot — so
    /// every slot carries the generation of its demotion and trimming
    /// only honors a slot whose generation matches the index entry (a
    /// stale slot can never expire a key's *later* re-demotion).
    ghost: VecDeque<(K, u64)>,
    ghost_index: HashMap<K, u64>,
    ghost_gen: u64,
    weight: usize,
    small_weight: usize,
    counters: MemoCounters,
}

impl<K: Hash + Eq + Clone, V> S3Fifo<K, V> {
    /// Create a cache with the given weight budget (≥ 1 enforced).
    pub fn with_capacity(capacity: usize) -> S3Fifo<K, V> {
        let capacity = capacity.max(1);
        S3Fifo {
            capacity,
            small_budget: (capacity / 10).max(1),
            entries: HashMap::new(),
            small: VecDeque::new(),
            main: VecDeque::new(),
            ghost: VecDeque::new(),
            ghost_index: HashMap::new(),
            ghost_gen: 0,
            weight: 0,
            small_weight: 0,
            counters: MemoCounters::default(),
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Probationary-queue share of the budget (≥ 1 by construction).
    pub fn small_budget(&self) -> usize {
        self.small_budget
    }

    /// Resident entries.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Resident weight (same unit as the capacity).
    pub fn weight(&self) -> usize {
        self.weight
    }

    pub fn contains(&self, key: &K) -> bool {
        self.entries.contains_key(key)
    }

    pub fn counters(&self) -> MemoCounters {
        self.counters
    }

    pub fn snapshot(&self) -> MemoSnapshot {
        MemoSnapshot {
            entries: self.entries.len(),
            weight: self.weight,
            capacity: self.capacity,
            ghost_keys: self.ghost_index.len(),
            counters: self.counters,
        }
    }

    /// Look up `key`, bumping its frequency (saturating at [`MAX_FREQ`])
    /// and the per-queue hit counters.
    pub fn get(&mut self, key: &K) -> Option<&V> {
        match self.entries.get_mut(key) {
            Some(e) => {
                e.freq = e.freq.saturating_add(1).min(MAX_FREQ);
                match e.queue {
                    Queue::Small => self.counters.small_hits += 1,
                    Queue::Main => self.counters.main_hits += 1,
                }
                Some(&e.value)
            }
            None => {
                self.counters.misses += 1;
                None
            }
        }
    }

    /// Insert (or overwrite) `key` with the given budget weight, evicting
    /// until the budget fits. Returns the number of resident entries fully
    /// evicted by this call. Entries heavier than the whole budget are
    /// refused (admitting one would flush the entire cache for an object
    /// that cannot stay).
    pub fn insert(&mut self, key: K, value: V, weight: usize) -> u64 {
        let weight = weight.max(1);
        if weight > self.capacity {
            return 0;
        }
        if let Some(e) = self.entries.get_mut(&key) {
            // Overwrite in place (e.g. two workers raced on a cold key):
            // queue position and frequency survive, the budget adjusts.
            self.weight = self.weight - e.weight + weight;
            if e.queue == Queue::Small {
                self.small_weight = self.small_weight - e.weight + weight;
            }
            e.weight = weight;
            e.value = value;
            return self.evict_to_fit();
        }
        self.counters.insertions += 1;
        let queue = if self.ghost_index.remove(&key).is_some() {
            // The key proved reuse before being demoted: readmit straight
            // into main (its stale ghost-deque slot is skipped on trim).
            self.counters.ghost_readmits += 1;
            Queue::Main
        } else {
            Queue::Small
        };
        match queue {
            Queue::Small => {
                self.small.push_back(key.clone());
                self.small_weight += weight;
            }
            Queue::Main => self.main.push_back(key.clone()),
        }
        self.entries.insert(key, Entry { value, weight, freq: 0, queue });
        self.weight += weight;
        self.evict_to_fit()
    }

    fn evict_to_fit(&mut self) -> u64 {
        let mut evicted = 0;
        while self.weight > self.capacity && !self.entries.is_empty() {
            if self.small_weight > self.small_budget || self.main.is_empty() {
                evicted += self.evict_small();
            } else {
                evicted += self.evict_main();
            }
        }
        // every eviction path runs through here (fresh inserts *and*
        // overwrites), so the ghost bound holds after any mutation
        self.trim_ghost();
        evicted
    }

    /// Pop the oldest probationary entry: promote it to main if it was hit
    /// while probationary, demote its key to the ghost history otherwise.
    /// Returns 1 iff an entry left the cache.
    fn evict_small(&mut self) -> u64 {
        let Some(key) = self.small.pop_front() else { return 0 };
        let e = self.entries.get_mut(&key).expect("small-queue key must be resident");
        self.small_weight -= e.weight;
        if e.freq > 1 {
            e.queue = Queue::Main;
            self.main.push_back(key);
            0
        } else {
            let w = e.weight;
            self.entries.remove(&key);
            self.weight -= w;
            self.counters.small_evictions += 1;
            self.ghost_gen += 1;
            self.ghost_index.insert(key.clone(), self.ghost_gen);
            self.ghost.push_back((key, self.ghost_gen));
            1
        }
    }

    /// Pop the oldest main entry: lazy promotion reinserts it with
    /// decremented frequency; a zero-frequency entry is dropped for good
    /// (main evictees do not enter the ghost history).
    fn evict_main(&mut self) -> u64 {
        let Some(key) = self.main.pop_front() else { return 0 };
        let e = self.entries.get_mut(&key).expect("main-queue key must be resident");
        if e.freq > 0 {
            e.freq -= 1;
            self.main.push_back(key);
            0
        } else {
            let w = e.weight;
            self.entries.remove(&key);
            self.weight -= w;
            self.counters.main_evictions += 1;
            1
        }
    }

    /// Bound the ghost history to roughly the resident entry count (≥ 8 so
    /// tiny caches keep a useful comeback window). The deque is hard-capped
    /// at twice that, so stale (readmitted) slots cannot accumulate under
    /// demote/readmit-heavy traffic.
    fn trim_ghost(&mut self) {
        let cap = self.entries.len().max(8);
        while self.ghost.len() > 2 * cap && self.pop_ghost_slot() {}
        while self.ghost_index.len() > cap && self.pop_ghost_slot() {}
    }

    /// Pop one ghost-deque slot, removing its index entry only when the
    /// generations match (a stale slot left by a readmission is simply
    /// discarded). Returns false once the deque is empty.
    fn pop_ghost_slot(&mut self) -> bool {
        match self.ghost.pop_front() {
            Some((k, gen)) => {
                if self.ghost_index.get(&k) == Some(&gen) {
                    self.ghost_index.remove(&k);
                }
                true
            }
            None => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn unit_cache(capacity: usize) -> S3Fifo<u64, u64> {
        S3Fifo::with_capacity(capacity)
    }

    /// Insert with weight 1 → the capacity behaves as an entry count.
    fn put(c: &mut S3Fifo<u64, u64>, k: u64) -> u64 {
        c.insert(k, k * 10, 1)
    }

    #[test]
    fn get_and_insert_roundtrip() {
        let mut c = unit_cache(8);
        assert_eq!(c.get(&1), None);
        put(&mut c, 1);
        assert_eq!(c.get(&1), Some(&10));
        assert_eq!(c.counters().misses, 1);
        assert_eq!(c.counters().small_hits, 1);
        assert_eq!(c.len(), 1);
        assert_eq!(c.weight(), 1);
    }

    #[test]
    fn small_budget_clamped_for_tiny_capacities() {
        // The reference design sizes small as capacity/10, which rounds to
        // 0 for capacities < 10 and makes the probationary queue useless.
        for cap in [1usize, 2, 9] {
            let c: S3Fifo<u64, u64> = S3Fifo::with_capacity(cap);
            assert_eq!(c.small_budget(), 1, "capacity {cap}");
        }
        assert_eq!(unit_cache(100).small_budget(), 10);
    }

    #[test]
    fn capacity_one_still_serves() {
        let mut c = unit_cache(1);
        put(&mut c, 1);
        assert_eq!(c.get(&1), Some(&10));
        let evicted = put(&mut c, 2);
        assert_eq!(evicted, 1, "capacity 1: admitting 2 must evict 1");
        assert_eq!(c.len(), 1);
        assert!(c.contains(&2));
        assert_eq!(c.get(&2), Some(&20));
    }

    #[test]
    fn capacity_two_keeps_latest_pair_bounded() {
        let mut c = unit_cache(2);
        for k in 0..20 {
            put(&mut c, k);
            assert!(c.len() <= 2, "k={k}: len {}", c.len());
            assert!(c.weight() <= 2);
        }
        assert!(c.counters().evictions() >= 18);
    }

    #[test]
    fn capacity_nine_never_overflows_and_hits_hot_key() {
        let mut c = unit_cache(9);
        for k in 0..50 {
            put(&mut c, k % 12);
            let _ = c.get(&0); // keep key 0 hot
            assert!(c.weight() <= 9, "k={k}");
        }
        assert!(c.contains(&0), "hot key must survive a working set of 12 > 9");
    }

    #[test]
    fn ghost_readmits_go_straight_to_main() {
        let mut c = unit_cache(4);
        // fill + overflow: 0 is the oldest probationary entry with no hits
        for k in 0..5 {
            put(&mut c, k);
        }
        assert!(!c.contains(&0), "0 must be demoted to ghost");
        let demotions = c.counters().small_evictions;
        assert!(demotions >= 1);
        // comeback: 0 readmits into main
        put(&mut c, 0);
        assert_eq!(c.counters().ghost_readmits, 1);
        assert!(c.contains(&0));
        // a scan of fresh keys flows through small; the readmitted 0 stays
        for k in 100..120 {
            put(&mut c, k);
        }
        assert!(c.contains(&0), "main-resident comeback key must survive the scan");
    }

    #[test]
    fn one_pass_scan_does_not_evict_hot_main_entries() {
        let mut c = unit_cache(20);
        // warm 4 hot keys well past the promotion bar
        for k in 0..4 {
            put(&mut c, k);
        }
        for _ in 0..3 {
            for k in 0..4 {
                let _ = c.get(&k);
            }
        }
        // one-pass scan of 100 cold keys
        for k in 1000..1100 {
            put(&mut c, k);
        }
        for k in 0..4u64 {
            assert!(c.contains(&k), "hot key {k} evicted by the scan");
        }
        assert!(c.counters().evictions() > 0, "the scan must have overflowed the budget");
    }

    #[test]
    fn stale_ghost_slot_does_not_expire_a_re_demotion() {
        // Lifecycle that leaves a stale ghost-deque slot for key 0 aliasing
        // a later, live re-demotion: demote → readmit (stale slot) →
        // evict from main → demote again. Trimming must discard the stale
        // slot instead of erasing the fresh membership.
        let mut c = unit_cache(4);
        for k in 0..5 {
            put(&mut c, k); // 0 demoted to ghost
        }
        put(&mut c, 0); // readmits to main, leaving its deque slot stale
        assert_eq!(c.counters().ghost_readmits, 1);
        // readmit 1..=4 into main too; with small empty, admitting 5 must
        // evict main's oldest zero-frequency entry — key 0 — outright
        for k in [1u64, 2, 3, 4, 5] {
            put(&mut c, k);
        }
        assert!(!c.contains(&0), "0 should fall out of main (freq 0)");
        assert_eq!(c.counters().main_evictions, 1);
        // demote 0 a second time: a *fresh* ghost membership
        put(&mut c, 0);
        put(&mut c, 6);
        assert!(!c.contains(&0));
        // push the ghost index past its cap so trimming walks the deque —
        // the stale slot for 0 sits at the very front
        for k in 100..107 {
            put(&mut c, k);
        }
        let readmits = c.counters().ghost_readmits;
        put(&mut c, 0);
        assert_eq!(c.counters().ghost_readmits, readmits + 1, "stale slot expired the fresh re-demotion of 0");
    }

    #[test]
    fn ghost_history_stays_bounded_under_readmit_churn() {
        let mut c = unit_cache(4);
        for round in 0..100u64 {
            for k in 0..6 {
                put(&mut c, k + (round % 2) * 3); // overlapping working sets
            }
            let s = c.snapshot();
            assert!(s.ghost_keys <= s.entries.max(8), "round {round}: ghost {0} entries {1}", s.ghost_keys, s.entries);
        }
        assert!(c.counters().ghost_readmits > 0);
    }

    #[test]
    fn byte_weights_bound_total_weight() {
        let mut c: S3Fifo<u64, Vec<u8>> = S3Fifo::with_capacity(1000);
        for k in 0..30 {
            c.insert(k, vec![0u8; 64], 64);
            assert!(c.weight() <= 1000);
        }
        assert!(c.len() <= 1000 / 64);
        // an entry heavier than the whole budget is refused
        let evicted = c.insert(999, vec![0u8; 4096], 4096);
        assert_eq!(evicted, 0);
        assert!(!c.contains(&999));
    }

    #[test]
    fn overwrite_adjusts_weight_without_reinsertion() {
        let mut c: S3Fifo<u64, u64> = S3Fifo::with_capacity(10);
        c.insert(1, 10, 2);
        c.insert(1, 11, 5);
        assert_eq!(c.len(), 1);
        assert_eq!(c.weight(), 5);
        assert_eq!(c.counters().insertions, 1, "overwrite is not a new admission");
        assert_eq!(c.get(&1), Some(&11));
    }

    #[test]
    fn counters_account_every_lookup() {
        let mut c = unit_cache(4);
        for k in 0..3 {
            put(&mut c, k);
        }
        for _ in 0..5 {
            let _ = c.get(&1);
        }
        let _ = c.get(&99);
        let snap = c.counters();
        assert_eq!(snap.hits(), 5);
        assert_eq!(snap.misses, 1);
        assert_eq!(snap.lookups(), 6);
        assert!((snap.hit_rate() - 5.0 / 6.0).abs() < 1e-12);
    }

    #[test]
    fn eviction_loop_terminates_when_everything_is_hot() {
        // every resident entry has saturated freq: eviction must still
        // make progress (lazy promotion decrements, then drops)
        let mut c = unit_cache(3);
        for k in 0..3 {
            put(&mut c, k);
            for _ in 0..4 {
                let _ = c.get(&k);
            }
        }
        for k in 10..30 {
            put(&mut c, k);
            assert!(c.weight() <= 3);
        }
    }

    #[test]
    fn snapshot_reflects_state() {
        let mut c = unit_cache(6);
        for k in 0..9 {
            put(&mut c, k);
        }
        let s = c.snapshot();
        assert_eq!(s.entries, c.len());
        assert_eq!(s.weight, c.weight());
        assert_eq!(s.capacity, 6);
        assert_eq!(s.counters, c.counters());
        assert!(s.ghost_keys >= 1);
    }
}
