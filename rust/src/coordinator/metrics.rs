//! Coordinator metrics registry: lock-free counters, log-bucketed latency
//! histograms, and JSON snapshots.

use crate::util::json::Json;
use std::sync::atomic::{AtomicU64, Ordering};

/// Request kinds with a latency histogram, in [`Metrics::latency`] index
/// order (`Analyze` and `AnalyzeWith` share the "analyze" histogram).
pub const LATENCY_KINDS: [&str; 4] = ["plan", "analyze", "execute", "solve"];

/// Lock-free log-bucketed latency histogram (microsecond samples).
///
/// Bucket 0 holds exactly 0 µs; bucket `b ≥ 1` holds `[2^(b-1), 2^b)` µs,
/// with the last bucket open-ended — 31 doubling buckets span sub-µs hits
/// to ~half an hour. Quantiles return the inclusive upper edge of the
/// bucket containing the requested rank, so the estimate is exact to
/// within one doubling and never *under*-reports a tail.
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; Histogram::BUCKETS],
}

impl Histogram {
    pub const BUCKETS: usize = 32;

    pub fn new() -> Histogram {
        Histogram { buckets: std::array::from_fn(|_| AtomicU64::new(0)) }
    }

    /// Bucket index for a sample: the bit width of `value_us`.
    fn bucket_index(value_us: u64) -> usize {
        ((64 - value_us.leading_zeros()) as usize).min(Histogram::BUCKETS - 1)
    }

    /// Inclusive upper edge of bucket `b` (`u64::MAX` for the open last
    /// bucket).
    pub fn bucket_upper(b: usize) -> u64 {
        if b == 0 {
            0
        } else if b >= Histogram::BUCKETS - 1 {
            u64::MAX
        } else {
            (1u64 << b) - 1
        }
    }

    pub fn record(&self, value_us: u64) {
        self.buckets[Histogram::bucket_index(value_us)].fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum()
    }

    /// Quantile estimate for `q` in (0, 1]: upper edge of the bucket
    /// holding rank `ceil(q·n)` (0 when empty).
    pub fn quantile_us(&self, q: f64) -> u64 {
        let counts: Vec<u64> = self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0;
        }
        let rank = ((q * total as f64).ceil() as u64).clamp(1, total);
        let mut cum = 0u64;
        for (b, c) in counts.iter().enumerate() {
            cum += c;
            if cum >= rank {
                return Histogram::bucket_upper(b);
            }
        }
        Histogram::bucket_upper(Histogram::BUCKETS - 1)
    }

    /// `{count, p50_us, p99_us, p999_us}` snapshot.
    pub fn snapshot(&self) -> Json {
        let mut o = Json::obj();
        o.set("count", self.count())
            .set("p50_us", self.quantile_us(0.50))
            .set("p99_us", self.quantile_us(0.99))
            .set("p999_us", self.quantile_us(0.999));
        o
    }
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

/// Counters exported by the coordinator. All updates are relaxed atomics —
/// metrics never synchronize program logic.
#[derive(Debug, Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub planned: AtomicU64,
    pub analyzed: AtomicU64,
    pub executed: AtomicU64,
    pub failed: AtomicU64,
    pub points_processed: AtomicU64,
    pub sim_accesses: AtomicU64,
    pub sim_misses: AtomicU64,
    /// L2 misses simulated by hierarchical analyses (0 on single-level
    /// machines).
    pub sim_l2_misses: AtomicU64,
    /// TLB misses (page walks) simulated by hierarchical analyses.
    pub sim_tlb_misses: AtomicU64,
    /// Additive stall-cycle estimate accumulated over analyses (the
    /// machine's latency model applied to each job's per-level profile).
    pub sim_stall_cycles: AtomicU64,
    /// Requests whose primary artifact (plan for Plan/Execute/Solve,
    /// analysis report for Analyze/AnalyzeWith) was served from the memo
    /// tier without recomputation.
    pub sim_memo_hits: AtomicU64,
    /// Requests whose primary artifact had to be computed (and was then
    /// admitted to the memo tier). Zero-sum with `sim_memo_hits` over all
    /// successful requests on a memoizing coordinator.
    pub sim_memo_misses: AtomicU64,
    /// Entries the memo tier evicted to stay inside its byte budget.
    pub memo_evictions: AtomicU64,
    /// Analyze jobs that fanned out across pencil shards.
    pub sharded_analyses: AtomicU64,
    /// Total pencil shards executed on the worker pool.
    pub shards_executed: AtomicU64,
    pub pjrt_executions: AtomicU64,
    pub pjrt_micros: AtomicU64,
    /// Stencil applications served by the native numeric backend.
    pub native_executions: AtomicU64,
    pub native_micros: AtomicU64,
    /// Ghost words carried across shard boundaries by `HaloMsg`s in
    /// block-decomposed solves (the measured PEM halo traffic).
    pub halo_words_loaded: AtomicU64,
    /// `HaloMsg` exchanges performed by block-decomposed solves.
    pub halo_exchanges: AtomicU64,
    /// Ghost-zone points recomputed redundantly by deep-halo supersteps
    /// (decomposed solves with `shard_time_tile > 1`) — counted apart from
    /// `halo_words_loaded` so the exchanged-vs-recomputed trade stays
    /// visible and the PEM ladder stays honest.
    pub halo_redundant_words: AtomicU64,
    /// Requests that joined an in-flight computation for the same
    /// canonical key instead of recomputing (single-flight collapsing).
    pub single_flight_collapsed: AtomicU64,
    /// TCP connections accepted by the serving front end.
    pub server_connections: AtomicU64,
    /// Requests decoded off the wire (including ones later shed).
    pub server_requests: AtomicU64,
    /// Wire requests shed by admission control (`overloaded` responses).
    pub server_shed: AtomicU64,
    /// Wire requests rejected as malformed (`bad_request` responses).
    pub server_bad_requests: AtomicU64,
    /// Per-kind service-time histograms (µs), indexed as [`LATENCY_KINDS`].
    pub latency: [Histogram; LATENCY_KINDS.len()],
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn bump(counter: &AtomicU64, by: u64) {
        counter.fetch_add(by, Ordering::Relaxed);
    }

    /// Record one service-time sample for the kind at `kind_idx` (an index
    /// into [`LATENCY_KINDS`]; out-of-range samples are dropped).
    pub fn record_latency(&self, kind_idx: usize, micros: u64) {
        if let Some(h) = self.latency.get(kind_idx) {
            h.record(micros);
        }
    }

    /// Point-in-time snapshot as JSON (insertion-ordered, stable for diffs).
    pub fn snapshot(&self) -> Json {
        let mut o = Json::obj();
        o.set("requests", self.requests.load(Ordering::Relaxed))
            .set("planned", self.planned.load(Ordering::Relaxed))
            .set("analyzed", self.analyzed.load(Ordering::Relaxed))
            .set("executed", self.executed.load(Ordering::Relaxed))
            .set("failed", self.failed.load(Ordering::Relaxed))
            .set("points_processed", self.points_processed.load(Ordering::Relaxed))
            .set("sim_accesses", self.sim_accesses.load(Ordering::Relaxed))
            .set("sim_misses", self.sim_misses.load(Ordering::Relaxed))
            .set("sim_l2_misses", self.sim_l2_misses.load(Ordering::Relaxed))
            .set("sim_tlb_misses", self.sim_tlb_misses.load(Ordering::Relaxed))
            .set("sim_stall_cycles", self.sim_stall_cycles.load(Ordering::Relaxed))
            .set("sim_memo_hits", self.sim_memo_hits.load(Ordering::Relaxed))
            .set("sim_memo_misses", self.sim_memo_misses.load(Ordering::Relaxed))
            .set("memo_evictions", self.memo_evictions.load(Ordering::Relaxed))
            .set("sharded_analyses", self.sharded_analyses.load(Ordering::Relaxed))
            .set("shards_executed", self.shards_executed.load(Ordering::Relaxed))
            .set("pjrt_executions", self.pjrt_executions.load(Ordering::Relaxed))
            .set("pjrt_micros", self.pjrt_micros.load(Ordering::Relaxed))
            .set("native_executions", self.native_executions.load(Ordering::Relaxed))
            .set("native_micros", self.native_micros.load(Ordering::Relaxed))
            .set("halo_words_loaded", self.halo_words_loaded.load(Ordering::Relaxed))
            .set("halo_exchanges", self.halo_exchanges.load(Ordering::Relaxed))
            .set("halo_redundant_words", self.halo_redundant_words.load(Ordering::Relaxed))
            .set("single_flight_collapsed", self.single_flight_collapsed.load(Ordering::Relaxed))
            .set("server_connections", self.server_connections.load(Ordering::Relaxed))
            .set("server_requests", self.server_requests.load(Ordering::Relaxed))
            .set("server_shed", self.server_shed.load(Ordering::Relaxed))
            .set("server_bad_requests", self.server_bad_requests.load(Ordering::Relaxed));
        let mut lat = Json::obj();
        for (i, name) in LATENCY_KINDS.iter().enumerate() {
            lat.set(name, self.latency[i].snapshot());
        }
        o.set("latency_us", lat);
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        Metrics::bump(&m.requests, 3);
        Metrics::bump(&m.requests, 2);
        assert_eq!(m.requests.load(Ordering::Relaxed), 5);
    }

    #[test]
    fn snapshot_shape() {
        let m = Metrics::new();
        Metrics::bump(&m.executed, 1);
        let s = m.snapshot().to_string();
        assert!(s.contains("\"executed\":1"));
        assert!(s.contains("\"requests\":0"));
        assert!(s.contains("\"sim_memo_hits\":0"));
        assert!(s.contains("\"sim_memo_misses\":0"));
        assert!(s.contains("\"memo_evictions\":0"));
        assert!(s.contains("\"halo_redundant_words\":0"));
    }

    #[test]
    fn histogram_bucket_boundaries_pinned() {
        // bucket 0 ⇔ 0 µs; bucket b ⇔ [2^(b-1), 2^b)
        assert_eq!(Histogram::bucket_index(0), 0);
        assert_eq!(Histogram::bucket_index(1), 1);
        assert_eq!(Histogram::bucket_index(2), 2);
        assert_eq!(Histogram::bucket_index(3), 2);
        assert_eq!(Histogram::bucket_index(4), 3);
        assert_eq!(Histogram::bucket_index(1023), 10);
        assert_eq!(Histogram::bucket_index(1024), 11);
        assert_eq!(Histogram::bucket_index(u64::MAX), Histogram::BUCKETS - 1);
        assert_eq!(Histogram::bucket_upper(0), 0);
        assert_eq!(Histogram::bucket_upper(1), 1);
        assert_eq!(Histogram::bucket_upper(3), 7);
        assert_eq!(Histogram::bucket_upper(Histogram::BUCKETS - 1), u64::MAX);
    }

    #[test]
    fn histogram_quantiles_pinned() {
        let h = Histogram::new();
        assert_eq!(h.quantile_us(0.5), 0, "empty histogram reports 0");
        for v in 1..=8u64 {
            h.record(v);
        }
        // buckets: b1{1}=1, b2{2,3}=2, b3{4..7}=3, b4{8}=1; n=8
        assert_eq!(h.count(), 8);
        // p50 rank 4 lands in b3 → upper edge 7; p99 rank 8 in b4 → 15
        assert_eq!(h.quantile_us(0.50), 7);
        assert_eq!(h.quantile_us(0.99), 15);
        assert_eq!(h.quantile_us(0.999), 15);
        // a single sample answers every quantile with its own bucket edge
        let one = Histogram::new();
        one.record(0);
        assert_eq!(one.quantile_us(0.999), 0);
        let s = h.snapshot().to_string();
        assert!(s.contains("\"count\":8"));
        assert!(s.contains("\"p50_us\":7"));
    }

    #[test]
    fn latency_kinds_flow_into_snapshot() {
        let m = Metrics::new();
        m.record_latency(0, 3); // plan
        m.record_latency(1, 900); // analyze
        m.record_latency(99, 1); // out of range: dropped, no panic
        let s = m.snapshot().to_string();
        assert!(s.contains("\"latency_us\""));
        assert!(s.contains("\"plan\":{\"count\":1"));
        assert!(s.contains("\"analyze\":{\"count\":1"));
        assert!(s.contains("\"execute\":{\"count\":0"));
        assert_eq!(m.latency[1].quantile_us(0.5), 1023);
    }

    #[test]
    fn concurrent_updates() {
        let m = std::sync::Arc::new(Metrics::new());
        let mut handles = Vec::new();
        for _ in 0..8 {
            let m = m.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    Metrics::bump(&m.sim_accesses, 1);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.sim_accesses.load(Ordering::Relaxed), 8000);
    }
}
