//! Coordinator metrics registry: lock-free counters + JSON snapshots.

use crate::util::json::Json;
use std::sync::atomic::{AtomicU64, Ordering};

/// Counters exported by the coordinator. All updates are relaxed atomics —
/// metrics never synchronize program logic.
#[derive(Debug, Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub planned: AtomicU64,
    pub analyzed: AtomicU64,
    pub executed: AtomicU64,
    pub failed: AtomicU64,
    pub points_processed: AtomicU64,
    pub sim_accesses: AtomicU64,
    pub sim_misses: AtomicU64,
    /// L2 misses simulated by hierarchical analyses (0 on single-level
    /// machines).
    pub sim_l2_misses: AtomicU64,
    /// TLB misses (page walks) simulated by hierarchical analyses.
    pub sim_tlb_misses: AtomicU64,
    /// Additive stall-cycle estimate accumulated over analyses (the
    /// machine's latency model applied to each job's per-level profile).
    pub sim_stall_cycles: AtomicU64,
    /// Requests whose primary artifact (plan for Plan/Execute/Solve,
    /// analysis report for Analyze/AnalyzeWith) was served from the memo
    /// tier without recomputation.
    pub sim_memo_hits: AtomicU64,
    /// Requests whose primary artifact had to be computed (and was then
    /// admitted to the memo tier). Zero-sum with `sim_memo_hits` over all
    /// successful requests on a memoizing coordinator.
    pub sim_memo_misses: AtomicU64,
    /// Entries the memo tier evicted to stay inside its byte budget.
    pub memo_evictions: AtomicU64,
    /// Analyze jobs that fanned out across pencil shards.
    pub sharded_analyses: AtomicU64,
    /// Total pencil shards executed on the worker pool.
    pub shards_executed: AtomicU64,
    pub pjrt_executions: AtomicU64,
    pub pjrt_micros: AtomicU64,
    /// Stencil applications served by the native numeric backend.
    pub native_executions: AtomicU64,
    pub native_micros: AtomicU64,
    /// Ghost words carried across shard boundaries by `HaloMsg`s in
    /// block-decomposed solves (the measured PEM halo traffic).
    pub halo_words_loaded: AtomicU64,
    /// `HaloMsg` exchanges performed by block-decomposed solves.
    pub halo_exchanges: AtomicU64,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn bump(counter: &AtomicU64, by: u64) {
        counter.fetch_add(by, Ordering::Relaxed);
    }

    /// Point-in-time snapshot as JSON (insertion-ordered, stable for diffs).
    pub fn snapshot(&self) -> Json {
        let mut o = Json::obj();
        o.set("requests", self.requests.load(Ordering::Relaxed))
            .set("planned", self.planned.load(Ordering::Relaxed))
            .set("analyzed", self.analyzed.load(Ordering::Relaxed))
            .set("executed", self.executed.load(Ordering::Relaxed))
            .set("failed", self.failed.load(Ordering::Relaxed))
            .set("points_processed", self.points_processed.load(Ordering::Relaxed))
            .set("sim_accesses", self.sim_accesses.load(Ordering::Relaxed))
            .set("sim_misses", self.sim_misses.load(Ordering::Relaxed))
            .set("sim_l2_misses", self.sim_l2_misses.load(Ordering::Relaxed))
            .set("sim_tlb_misses", self.sim_tlb_misses.load(Ordering::Relaxed))
            .set("sim_stall_cycles", self.sim_stall_cycles.load(Ordering::Relaxed))
            .set("sim_memo_hits", self.sim_memo_hits.load(Ordering::Relaxed))
            .set("sim_memo_misses", self.sim_memo_misses.load(Ordering::Relaxed))
            .set("memo_evictions", self.memo_evictions.load(Ordering::Relaxed))
            .set("sharded_analyses", self.sharded_analyses.load(Ordering::Relaxed))
            .set("shards_executed", self.shards_executed.load(Ordering::Relaxed))
            .set("pjrt_executions", self.pjrt_executions.load(Ordering::Relaxed))
            .set("pjrt_micros", self.pjrt_micros.load(Ordering::Relaxed))
            .set("native_executions", self.native_executions.load(Ordering::Relaxed))
            .set("native_micros", self.native_micros.load(Ordering::Relaxed))
            .set("halo_words_loaded", self.halo_words_loaded.load(Ordering::Relaxed))
            .set("halo_exchanges", self.halo_exchanges.load(Ordering::Relaxed));
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        Metrics::bump(&m.requests, 3);
        Metrics::bump(&m.requests, 2);
        assert_eq!(m.requests.load(Ordering::Relaxed), 5);
    }

    #[test]
    fn snapshot_shape() {
        let m = Metrics::new();
        Metrics::bump(&m.executed, 1);
        let s = m.snapshot().to_string();
        assert!(s.contains("\"executed\":1"));
        assert!(s.contains("\"requests\":0"));
        assert!(s.contains("\"sim_memo_hits\":0"));
        assert!(s.contains("\"sim_memo_misses\":0"));
        assert!(s.contains("\"memo_evictions\":0"));
    }

    #[test]
    fn concurrent_updates() {
        let m = std::sync::Arc::new(Metrics::new());
        let mut handles = Vec::new();
        for _ in 0..8 {
            let m = m.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    Metrics::bump(&m.sim_accesses, 1);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.sim_accesses.load(Ordering::Relaxed), 8000);
    }
}
