//! The planner: turn a stencil job into an execution plan.
//!
//! This is where the paper's results become *policy*:
//!
//! 1. build the interference lattice(s) of the requested layout — the
//!    cache-line lattice always, and the **page interference lattice**
//!    when the machine has a TLB (a grid can be TLB-unfavorable while
//!    L1-favorable, and vice versa);
//! 2. if the grid is unfavorable (§6 short-vector criterion, on either
//!    lattice), consult the padding advisor and re-plan on the padded
//!    layout — the advisor resolves every lattice the machine exposes;
//! 3. choose the traversal: cache-fitting (§4) by default, natural when
//!    the whole working set already fits the cache (no replacement misses
//!    possible — fitting buys nothing and costs order-generation time);
//! 4. attach the Eq 7 / Eq 12 bound predictions so callers can check the
//!    measured loads landed inside the sandwich.

use crate::bounds::{lower_bound_loads_multi, upper_bound_loads_multi};
use crate::cache::MachineModel;
use crate::grid::GridDesc;
use crate::lattice::InterferenceLattice;
use crate::padding::{self, PaddingAdvice};
use crate::stencil::Stencil;
use crate::traversal::{self, Traversal};

/// Traversal policy chosen by the planner.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TraversalChoice {
    /// Lexicographic sweep — optimal when the working set fits the cache.
    Natural,
    /// The paper's §4 pencil sweep.
    CacheFitting,
}

/// A complete plan for one stencil job.
#[derive(Debug, Clone)]
pub struct Plan {
    /// Logical dims of the request.
    pub dims: Vec<usize>,
    /// Storage layout after (possible) padding.
    pub storage_dims: Vec<usize>,
    pub pad: Vec<usize>,
    pub traversal: TraversalChoice,
    /// Recommended pencil-shard count for Analyze workers: 1 below
    /// [`SHARD_GRAIN_POINTS`] (sequential, exact), growing with interior
    /// volume so big jobs fan out across the pool. The coordinator clamps
    /// this to its worker count.
    pub shards: usize,
    /// §6 verdict on the *unpadded* layout (cache-line lattice).
    pub was_unfavorable: bool,
    /// §6 verdict on the *unpadded* layout's page interference lattice —
    /// `None` when the machine has no TLB.
    pub was_tlb_unfavorable: Option<bool>,
    /// Shortest lattice vector (L1, searched to the stencil diameter) of
    /// the final layout.
    pub min_l1: Option<i64>,
    /// Shortest page-lattice vector of the final layout (`None` when the
    /// machine has no TLB or no vector within the searched horizon).
    pub page_min_l1: Option<i64>,
    /// Eccentricity of the final layout's reduced basis.
    pub eccentricity: f64,
    /// Eq 7 prediction (loads for the whole job).
    pub lower_bound: f64,
    /// Eq 12 prediction.
    pub upper_bound: f64,
    /// Timesteps per tile visit for multi-step Solve jobs (temporal
    /// blocking, DESIGN.md §2.6): `1` when a halo-deep tile cannot fit the
    /// machine's scratch budget — then the solve falls back to the fused
    /// single-step pass, which has no redundancy.
    pub time_tile: usize,
    /// Owned tile extents backing `time_tile` (empty when `time_tile == 1`:
    /// the fused pass needs no fixed tile shape and the coordinator picks
    /// shard-parallel tiles instead).
    pub time_tile_dims: Vec<usize>,
    /// Block-shard grid for the decomposed solve path (DESIGN.md §2.9,
    /// `crate::shard`): the config override when given, else chosen by the
    /// PEM surface/volume criterion targeting the pencil fan-out, and
    /// refined further when `out_of_core` so every shard's working set
    /// fits the RAM budget.
    pub shard_grid: Vec<usize>,
    /// The solve's ping-pong field pair exceeds the configured RAM budget:
    /// the coordinator must stream shard blocks from disk tiles instead of
    /// holding both fields resident.
    pub out_of_core: bool,
    /// Superstep depth `k` for the *decomposed* solve path (DESIGN.md
    /// §2.12): halos deepen to `k·r` and shards exchange once per `k`
    /// steps. The config override when given, else chosen jointly with the
    /// shard grid by [`choose_shard_time_tile`]; `1` (classic
    /// one-exchange-per-step) whenever the deep sweep slab overflows the
    /// deepest cache or the redundant ghost recompute outweighs the saved
    /// exchange traffic.
    pub shard_time_tile: usize,
    /// Software-prefetch distance (words ahead) the native row kernel
    /// should run with: the config override when given, else
    /// `MachineModel::prefetch_distance()` (0 on machines whose latency
    /// model has no prefetch term, e.g. the paper's R10000).
    pub prefetch_distance: usize,
}

/// Planner configuration.
#[derive(Debug, Clone)]
pub struct PlannerConfig {
    /// The machine to plan for: L1 geometry (lattice/bounds) plus optional
    /// L2/TLB levels the analysis pipeline simulates and the padding
    /// advisor must also satisfy.
    pub machine: MachineModel,
    /// Maximum per-dimension pad the advisor may spend.
    pub max_pad: usize,
    /// Allow the planner to pad unfavorable grids.
    pub auto_pad: bool,
    /// Explicit block-shard grid for decomposed solves (one entry per
    /// dimension); `None` lets the planner choose by the PEM criterion.
    /// Setting this forces native Solve through the decomposed path even
    /// in memory — the way to exercise the halo exchange deliberately.
    pub shard_grid: Option<Vec<usize>>,
    /// RAM budget in words for solve fields. When the ping-pong field pair
    /// exceeds it the solve runs out-of-core (disk tiles, bounded
    /// concurrency). `None` = unbounded, fully in memory.
    pub ram_budget_words: Option<u64>,
    /// Override for the kernel's software-prefetch distance in words
    /// (CLI `--prefetch-distance`); `None` lets the machine model choose.
    pub prefetch_distance: Option<usize>,
    /// Superstep depth override for decomposed solves (CLI `--time-tile`):
    /// `Some(k)` forces `k`-deep halos verbatim (clamped to ≥ 1); `None`
    /// lets [`choose_shard_time_tile`] pick from the machine model.
    pub time_tile: Option<usize>,
    /// Pin shard workers to cores (CLI `--numa`): the coordinator builds
    /// its pool with `ThreadPool::new_pinned`, so first-touch allocation
    /// places each shard's blocks on its worker's NUMA node and the
    /// worker stays there for every superstep.
    pub numa: bool,
}

impl Default for PlannerConfig {
    fn default() -> Self {
        PlannerConfig {
            machine: MachineModel::r10000(),
            max_pad: 8,
            auto_pad: true,
            shard_grid: None,
            ram_budget_words: None,
            prefetch_distance: None,
            time_tile: None,
            numa: false,
        }
    }
}

/// Interior points per Analyze shard: below this, sharding buys nothing
/// (order generation and thread fan-out dominate) and the coordinator runs
/// the exact sequential simulation instead.
pub const SHARD_GRAIN_POINTS: u64 = 1 << 21;

/// Hard cap on recommended shards (the coordinator further clamps to its
/// worker count).
pub const MAX_SHARDS: usize = 64;

/// Deepest time tile the planner will consider. Past this the halo
/// redundancy (`2kr` extra layers per axis) erodes the traffic win faster
/// than the amortization grows it.
pub const MAX_TIME_TILE: usize = 8;

/// Modelled main-memory traffic of one *classic* solve step, in words per
/// interior point: the apply sweep reads `u` and writes `q` (2 words), the
/// axpy/norm sweep reads both and rewrites `u` (3 words).
pub const CLASSIC_SOLVE_TRAFFIC_WPP: f64 = 5.0;

/// Choose the time-tile depth `k` and owned tile extents for a multi-step
/// solve over `grid`, from the machine's cache capacities (the §6 criterion
/// extended in time; DESIGN.md §2.6).
///
/// A depth-`k` tile needs a scratch box of `tile + 2kr` per axis to be
/// cache-resident — two of them (ping-pong), so the budget is half the
/// effective capacity (L2 when the machine has one, else L1). Dim 0 is
/// never cut (lines stay contiguous); outer dims get uniform box extents
/// `⌊rem^(1/left)⌋`, each either uncut (when the full extent fits) or cut
/// with the owned part at least as large as the halo (`target ≥ 2·2kr`) so
/// redundant halo work cannot exceed useful work. The deepest feasible
/// `k ≤ MAX_TIME_TILE` wins; `(1, [])` means temporal blocking does not
/// pay and the solver should use the fused single-step pass.
pub fn choose_time_tile(machine: &MachineModel, grid: &GridDesc, r: usize) -> (usize, Vec<usize>) {
    let dims = grid.dims();
    let d = dims.len();
    if d < 2 || r == 0 {
        return (1, Vec::new());
    }
    let e: Vec<usize> = dims.iter().map(|&n| n.saturating_sub(2 * r)).collect();
    if e.iter().any(|&x| x == 0) {
        return (1, Vec::new());
    }
    // deepest *cache* level only — a TLB-but-no-L2 machine must size by
    // its L1, not its page reach (see MachineModel::scratch_words)
    let budget = machine.scratch_words() / 2; // two ping-pong scratch buffers
    for k in (2..=MAX_TIME_TILE).rev() {
        let halo = 2 * k * r;
        let box0 = dims[0].min(e[0] + halo);
        if box0 == 0 || budget < box0 {
            continue;
        }
        let mut rem = budget / box0;
        let mut tiles = vec![e[0]];
        let mut left = d - 1;
        let mut ok = true;
        for i in 1..d {
            if rem == 0 {
                ok = false;
                break;
            }
            let target = iroot(rem, left);
            let full = dims[i].min(e[i] + halo);
            if target >= full {
                tiles.push(e[i]);
                rem /= full;
            } else if target >= 2 * halo {
                tiles.push(target - halo);
                rem /= target;
            } else {
                ok = false;
                break;
            }
            left -= 1;
        }
        if ok {
            return (k, tiles);
        }
    }
    (1, Vec::new())
}

/// Largest `t` with `tⁿ ≤ x` (exact integer root; the float seed is only a
/// starting guess).
fn iroot(x: usize, n: usize) -> usize {
    if n <= 1 {
        return x;
    }
    let fits = |t: usize| (t as u128).pow(n as u32) <= x as u128;
    let mut t = (x as f64).powf(1.0 / n as f64).floor() as usize;
    while fits(t + 1) {
        t += 1;
    }
    while t > 0 && !fits(t) {
        t -= 1;
    }
    t
}

/// Modelled main-memory traffic of one *time-tiled* solve step, in words
/// per interior point per timestep — the deterministic counterpart of
/// [`CLASSIC_SOLVE_TRAFFIC_WPP`], and the metric the committed
/// `BENCH_NUMERIC.json` snapshot gates on (machine-independent, so CI can
/// enforce it exactly).
///
/// Per tile and superstep the words crossing main memory are: the halo-deep
/// box read once (step 1 reads `u_in` directly), the owned words written
/// once into `u_out`, and — for `k > 1` — the box's Dirichlet shell seeded
/// into both scratch buffers. Everything else lives in cache-resident
/// scratch. Summed over tiles, divided by `k` timesteps of interior points.
pub fn temporal_solve_traffic_wpp(grid: &GridDesc, r: usize, k: usize, tile: &[usize]) -> f64 {
    let dims = grid.dims();
    let d = dims.len();
    assert_eq!(tile.len(), d);
    assert!(k >= 1);
    let lo: Vec<i64> = vec![r as i64; d];
    let hi: Vec<i64> = dims.iter().map(|&n| n as i64 - r as i64).collect();
    let interior: f64 = (0..d).map(|i| (hi[i] - lo[i]).max(0) as f64).product();
    if interior == 0.0 {
        return 0.0;
    }
    let tiles_along: Vec<usize> = (0..d).map(|i| ((hi[i] - lo[i]) as usize).div_ceil(tile[i])).collect();
    let h = (k * r) as i64;
    let mut traffic = 0.0;
    for t in 0..tiles_along.iter().product::<usize>() {
        let mut idx = t;
        let (mut box_w, mut owned_w, mut inner_w) = (1.0, 1.0, 1.0);
        for i in 0..d {
            let ti = (idx % tiles_along[i]) as i64;
            idx /= tiles_along[i];
            let o_lo = lo[i] + ti * tile[i] as i64;
            let o_hi = (o_lo + tile[i] as i64).min(hi[i]);
            let b_lo = (o_lo - h).max(0);
            let b_hi = (o_hi + h).min(dims[i] as i64);
            owned_w *= (o_hi - o_lo) as f64;
            box_w *= (b_hi - b_lo) as f64;
            inner_w *= (b_hi.min(hi[i]) - b_lo.max(lo[i])).max(0) as f64;
        }
        traffic += box_w + owned_w;
        if k > 1 {
            traffic += 2.0 * (box_w - inner_w); // Dirichlet shell, seeded into both scratch buffers
        }
    }
    traffic / (interior * k as f64)
}

/// Choose the superstep depth `k` for a block-decomposed solve over
/// `dims` split as `shard_grid` (DESIGN.md §2.12) — the shard-layer twin
/// of [`choose_time_tile`], deciding how many steps one halo exchange
/// should feed.
///
/// Two tests, both against the machine model:
///
/// 1. **Cache residency** (the §6 criterion in time): a shard's `k`-step
///    sweep ping-pongs over its `k·r`-deep halo box, and the sweep is
///    only memory-free if its working slab — `diameter` planes of the
///    box, i.e. `(2r+1) · Π(box dims except the last)` — stays resident
///    in the deepest cache. Two such slabs (ping + pong) share
///    [`MachineModel::scratch_words`], so each gets half.
/// 2. **Cost**: a depth-`k` superstep moves `|halo box| + |owned|` words
///    through memory once, pulls `halo_words(k)` ghost words at the
///    cross-node [`crate::cache::Latency::remote`] price, and burns
///    `redundant_points(k)` ghost-point recomputes; a classic step pays
///    the full `|halo box| + |owned|` memory sweep *every* step plus a
///    `halo_words(1)` remote exchange. `k` wins only while
///    `cost(k)/k < cost(1)` — so k degrades to 1 exactly when the
///    redundant halo compute (plus the deeper exchange) exceeds the
///    sweeps it saves.
///
/// Returns the deepest winning `k ≤ MAX_TIME_TILE`; 1 means exchange
/// every step. Single-shard plans always get 1 (no exchange to amortize,
/// and a deep sweep would only add ghost recompute).
pub fn choose_shard_time_tile(machine: &MachineModel, dims: &[usize], shard_grid: &[usize], r: usize) -> usize {
    use crate::shard::{box_words, ShardPlan};
    if r == 0 || dims.is_empty() || dims.iter().any(|&n| n <= 2 * r) {
        return 1;
    }
    let base = ShardPlan::new(dims, shard_grid, r);
    if base.num_shards() <= 1 {
        return 1;
    }
    let lat = machine.latency;
    let budget = (machine.scratch_words() / 2) as u64; // ping-pong slab pair
    let diam = (2 * r + 1) as u64;
    // one fused multiply-add per stencil tap per recomputed ghost point
    let point_cycles = 2 * (2 * dims.len() as u64 * r as u64 + 1);
    let sweep_words = |p: &ShardPlan| -> u64 {
        (0..p.num_shards()).map(|s| box_words(&p.halo_box(s)) + box_words(&p.owned_box(s))).sum()
    };
    let classic = sweep_words(&base) * lat.mem + base.halo_words() * lat.remote;
    for k in (2..=MAX_TIME_TILE).rev() {
        let deep = ShardPlan::with_depth(dims, shard_grid, r, k);
        let resident = (0..deep.num_shards()).all(|s| {
            let b = deep.halo_box(s);
            let lead: u64 = b[..b.len() - 1].iter().map(|rg| (rg.end - rg.start).max(0) as u64).product();
            diam * lead <= budget
        });
        if !resident {
            continue;
        }
        let per_super =
            sweep_words(&deep) * lat.mem + deep.halo_words() * lat.remote + deep.redundant_points(k) * point_cycles;
        if per_super < classic * k as u64 {
            return k;
        }
    }
    1
}

/// Build the streaming traversal for `choice` over the (padded) grid — the
/// single construction point shared by the coordinator's Analyze path and
/// the native numeric sweep, so analysis and computation always walk the
/// grid in the same order the plan promised.
pub fn build_traversal(
    config: &PlannerConfig,
    grid: &GridDesc,
    stencil: &Stencil,
    choice: TraversalChoice,
) -> Box<dyn Traversal> {
    match choice {
        TraversalChoice::Natural => Box::new(traversal::natural_stream(grid, stencil.radius())),
        // the planner's fitting path is the auto-tuned family
        TraversalChoice::CacheFitting => crate::tuner::auto_fitting_traversal(grid, stencil, &config.machine.l1).0,
    }
}

/// Produce a plan for evaluating `stencil` with `p` RHS arrays over `dims`.
pub fn plan(config: &PlannerConfig, dims: &[usize], stencil: &Stencil, p: usize) -> Plan {
    let cache = &config.machine.l1;
    let grid = GridDesc::new(dims);
    let was_unfavorable = padding::is_unfavorable(&grid, stencil, cache);
    // §6 verdict at page granularity: a short vector in the page
    // interference lattice means one stencil application contends for the
    // TLB's reach — unfavorable for translation no matter the traversal.
    let was_tlb_unfavorable = config.machine.page_modulus().map(|m| padding::is_unfavorable_mod(&grid, stencil, m));

    let needs_pad = was_unfavorable || was_tlb_unfavorable == Some(true);
    let (pad, storage_dims) = if needs_pad && config.auto_pad {
        let advice: PaddingAdvice = padding::advise_machine(&grid, stencil, &config.machine, config.max_pad);
        (advice.pad, advice.storage_dims)
    } else {
        (vec![0; dims.len()], dims.to_vec())
    };
    let padded = GridDesc::with_padding(dims, &pad);
    let lattice = InterferenceLattice::new(padded.storage_dims(), cache.lattice_modulus());
    let min_l1 = lattice.min_l1(stencil.diameter() as i64);
    let eccentricity = lattice.eccentricity();
    let page_min_l1 = match config.machine.page_modulus() {
        Some(m) => InterferenceLattice::new(padded.storage_dims(), m).min_l1(stencil.diameter() as i64),
        None => None,
    };

    // Natural order is optimal when a full working slab (the K-extension of
    // one scanning face of the natural sweep: (2r+1) planes of the leading
    // dims product) fits in cache — then there are no replacement misses to
    // save. For d-dim grids the natural working set is diameter × (product
    // of all dims except the last).
    let slab: u64 = padded.storage_dims()[..dims.len() - 1].iter().map(|&n| n as u64).product::<u64>()
        * stencil.diameter() as u64
        * p as u64;
    let traversal = if dims.len() == 1 || slab <= cache.size_words() as u64 {
        TraversalChoice::Natural
    } else {
        TraversalChoice::CacheFitting
    };

    let (lower_bound, upper_bound) = if dims.len() >= 2 {
        (
            lower_bound_loads_multi(&padded, cache.size_words(), p),
            upper_bound_loads_multi(&padded, cache.size_words(), stencil.radius() as u32, eccentricity, p),
        )
    } else {
        let g = padded.num_points() as f64 * p as f64;
        (g, g) // 1-D: single sweep, every word loaded once
    };

    let interior = padded.interior_points(stencil.radius());
    let shards = (interior.div_ceil(SHARD_GRAIN_POINTS) as usize).clamp(1, MAX_SHARDS);
    let (time_tile, time_tile_dims) = choose_time_tile(&config.machine, &padded, stencil.radius());

    // Block decomposition (DESIGN.md §2.9): the solve's ping-pong field
    // pair must fit the RAM budget or the blocks stream from disk. The
    // grid itself comes from the PEM surface/volume criterion (longest
    // axis halves first), targeting the same fan-out as the pencil
    // shards; the budget then refines it until one shard's halo-extended
    // working set fits.
    let out_of_core = config.ram_budget_words.is_some_and(|b| 2 * grid.num_points() > b);
    let mut shard_grid = match &config.shard_grid {
        Some(g) => {
            assert_eq!(g.len(), dims.len(), "shard grid arity mismatch: {g:?} for dims {dims:?}");
            g.clone()
        }
        None => crate::shard::choose_shard_grid(dims, stencil.radius(), shards),
    };
    if out_of_core {
        shard_grid =
            crate::shard::refine_grid_for_budget(dims, stencil.radius(), shard_grid, config.ram_budget_words.unwrap());
    }

    // Superstep depth for the decomposed path: the override verbatim, else
    // model-chosen jointly with the grid above — then walked back down if
    // the deep plan's ping-pong working set would blow the RAM budget the
    // out-of-core concurrency divides by.
    let mut shard_time_tile = match config.time_tile {
        // A deep superstep needs a nonempty interior (every dim ≥ 2r+1);
        // below that the solve would run classic per-step sweeps while
        // still carrying k·r-deep halo boxes — all cost, no amortization.
        // choose_shard_time_tile already refuses such grids; the explicit
        // override must not sneak past the same guard.
        Some(k) if dims.iter().all(|&n| n > 2 * stencil.radius()) => k.max(1),
        Some(_) => 1,
        None => choose_shard_time_tile(&config.machine, dims, &shard_grid, stencil.radius()),
    };
    if config.time_tile.is_none() {
        if let Some(b) = config.ram_budget_words {
            while shard_time_tile > 1
                && crate::shard::ShardPlan::with_depth(dims, &shard_grid, stencil.radius(), shard_time_tile)
                    .peak_working_words()
                    > b
            {
                shard_time_tile -= 1;
            }
        }
    }

    Plan {
        dims: dims.to_vec(),
        storage_dims,
        pad,
        traversal,
        shards,
        was_unfavorable,
        was_tlb_unfavorable,
        min_l1,
        page_min_l1,
        eccentricity,
        lower_bound,
        upper_bound,
        time_tile,
        time_tile_dims,
        shard_grid,
        out_of_core,
        shard_time_tile,
        prefetch_distance: config.prefetch_distance.unwrap_or_else(|| config.machine.prefetch_distance()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> PlannerConfig {
        PlannerConfig::default()
    }

    #[test]
    fn favorable_large_grid_uses_fitting_without_padding() {
        let p = plan(&cfg(), &[67, 89, 100], &Stencil::star13(), 1);
        assert!(!p.was_unfavorable);
        assert_eq!(p.pad, vec![0, 0, 0]);
        assert_eq!(p.traversal, TraversalChoice::CacheFitting);
        assert!(p.lower_bound < p.upper_bound);
    }

    #[test]
    fn unfavorable_grid_gets_padded() {
        let p = plan(&cfg(), &[45, 91, 100], &Stencil::star13(), 1);
        assert!(p.was_unfavorable);
        assert!(p.pad.iter().any(|&x| x > 0), "{p:?}");
        // final layout clears the bar
        assert!(p.min_l1.is_none() || p.min_l1.unwrap() >= 5);
    }

    #[test]
    fn auto_pad_can_be_disabled() {
        let mut c = cfg();
        c.auto_pad = false;
        let p = plan(&c, &[45, 91, 100], &Stencil::star13(), 1);
        assert!(p.was_unfavorable);
        assert_eq!(p.pad, vec![0, 0, 0]);
        assert_eq!(p.storage_dims, vec![45, 91, 100]);
    }

    #[test]
    fn small_grid_prefers_natural() {
        // 16×16×16: one slab = 16·16·5 = 1280 words < 4096 ⇒ natural.
        let p = plan(&cfg(), &[16, 16, 16], &Stencil::star13(), 1);
        assert_eq!(p.traversal, TraversalChoice::Natural);
    }

    #[test]
    fn multi_rhs_shrinks_natural_window() {
        // Same 16³ grid with p = 4: slab 4× bigger ⇒ fitting.
        let p = plan(&cfg(), &[16, 16, 16], &Stencil::star13(), 4);
        assert_eq!(p.traversal, TraversalChoice::CacheFitting);
    }

    #[test]
    fn one_dimensional_grid() {
        let p = plan(&cfg(), &[1000], &Stencil::star(1, 1), 1);
        assert_eq!(p.traversal, TraversalChoice::Natural);
        assert_eq!(p.lower_bound, p.upper_bound);
    }

    #[test]
    fn bounds_scale_with_volume() {
        let small = plan(&cfg(), &[32, 32, 32], &Stencil::star13(), 1);
        let big = plan(&cfg(), &[64, 64, 64], &Stencil::star13(), 1);
        assert!(big.lower_bound > 7.0 * small.lower_bound);
    }

    #[test]
    fn build_traversal_covers_the_interior_for_both_choices() {
        let config = cfg();
        let stencil = Stencil::star13();
        let grid = GridDesc::new(&[24, 22, 20]);
        for choice in [TraversalChoice::Natural, TraversalChoice::CacheFitting] {
            let t = build_traversal(&config, &grid, &stencil, choice);
            assert_eq!(t.num_points(), grid.interior_points(2), "{choice:?}");
            assert_eq!(t.ndim(), 3);
        }
    }

    #[test]
    fn single_level_plans_carry_no_tlb_verdict() {
        let p = plan(&cfg(), &[45, 91, 100], &Stencil::star13(), 1);
        assert_eq!(p.was_tlb_unfavorable, None);
        assert_eq!(p.page_min_l1, None);
    }

    #[test]
    fn hierarchical_machine_adds_page_lattice_verdict() {
        let mut c = cfg();
        c.machine = MachineModel::r10000_full();
        // L1-unfavorable 45×91 (4095 ≡ −1 mod 4096) is page-favorable on
        // the 32768-word TLB span — the two verdicts are independent.
        c.auto_pad = false;
        let p = plan(&c, &[45, 91, 100], &Stencil::star13(), 1);
        assert!(p.was_unfavorable);
        assert_eq!(p.was_tlb_unfavorable, Some(false));
        // single-level planning on the same dims is unchanged by the
        // machine's extra levels (L1 lattice, bounds, traversal policy)
        let q = plan(&PlannerConfig { auto_pad: false, ..cfg() }, &[45, 91, 100], &Stencil::star13(), 1);
        assert_eq!(p.pad, q.pad);
        assert_eq!(p.traversal, q.traversal);
        assert_eq!(p.lower_bound, q.lower_bound);
        assert_eq!(p.upper_bound, q.upper_bound);
    }

    #[test]
    fn tlb_only_unfavorability_triggers_padding() {
        use crate::cache::{CacheParams, Latency, TlbParams};
        // Machine from the padding test: L1 modulus 4096, TLB span 18432
        // (not a multiple of 4096). 95×97 is L1-favorable but
        // page-unfavorable ((2,0,2) hits the span); the planner must
        // still pad it.
        let machine = MachineModel {
            name: "r10000+tlb36",
            l1: CacheParams::r10000(),
            l2: None,
            tlb: Some(TlbParams { entries: 36, page_words: 512 }),
            latency: Latency::r10000(),
        };
        let c = PlannerConfig { machine, ..cfg() };
        let p = plan(&c, &[95, 97, 40], &Stencil::star13(), 1);
        assert!(!p.was_unfavorable);
        assert_eq!(p.was_tlb_unfavorable, Some(true));
        assert!(p.pad.iter().any(|&x| x > 0), "{p:?}");
        assert!(p.page_min_l1.is_none() || p.page_min_l1.unwrap() >= 5, "{p:?}");
    }

    #[test]
    fn time_tile_degrades_to_one_when_cache_cannot_hold_a_halo_deep_tile() {
        // L1-only machine: 4096 words, budget 2048. Even k = 2 needs a cut
        // outer dim of 2·(2·2·2) = 16 box words against a target of at most
        // ⌊√(2048/box0)⌋ — infeasible at every size below.
        for dims in [vec![128usize, 128, 128], vec![32, 32, 32], vec![20, 20, 20]] {
            let p = plan(&cfg(), &dims, &Stencil::star13(), 1);
            assert_eq!(p.time_tile, 1, "{dims:?}");
            assert!(p.time_tile_dims.is_empty(), "{dims:?}");
        }
        // ... and trivially for 1-D / empty-interior grids on any machine.
        let full = PlannerConfig { machine: MachineModel::r10000_full(), ..cfg() };
        assert_eq!(plan(&full, &[4096], &Stencil::star(1, 1), 1).time_tile, 1);
        assert_eq!(choose_time_tile(&MachineModel::r10000_full(), &GridDesc::new(&[4, 4]), 2), (1, Vec::new()));
    }

    #[test]
    fn tlb_reach_is_not_tile_scratch() {
        use crate::cache::{CacheParams, Latency, TlbParams};
        // A TLB-but-no-L2 machine: huge translation reach (64Ki pages ≈
        // 32M words) over a tiny 512-word L1. Sizing the tile by the
        // deepest *level* would pick the page reach and happily fit a
        // deep tile that thrashes the only real cache; the deepest-cache
        // fallback must skip TLB levels and degrade to k = 1.
        let machine = MachineModel {
            name: "tiny-l1+huge-tlb",
            l1: CacheParams::new(2, 32, 8), // 512 words
            l2: None,
            tlb: Some(TlbParams { entries: 65536, page_words: 512 }),
            latency: Latency::r10000(),
        };
        assert_eq!(machine.scratch_words(), 512);
        assert!(machine.page_modulus().unwrap() > machine.scratch_words());
        let g = GridDesc::new(&[64, 64, 64]);
        assert_eq!(choose_time_tile(&machine, &g, 2), (1, Vec::new()));
        let p = plan(&PlannerConfig { machine, ..cfg() }, &[64, 64, 64], &Stencil::star13(), 1);
        assert_eq!(p.time_tile, 1);
        assert!(p.time_tile_dims.is_empty());
    }

    #[test]
    fn shard_grid_defaults_to_single_block_and_follows_overrides() {
        // small grid, no budget: one block, fully in memory
        let p = plan(&cfg(), &[32, 32, 32], &Stencil::star13(), 1);
        assert_eq!(p.shard_grid, vec![1, 1, 1]);
        assert!(!p.out_of_core);
        // explicit override is taken verbatim
        let c = PlannerConfig { shard_grid: Some(vec![2, 1, 2]), ..cfg() };
        let p = plan(&c, &[32, 32, 32], &Stencil::star13(), 1);
        assert_eq!(p.shard_grid, vec![2, 1, 2]);
        assert!(!p.out_of_core);
    }

    #[test]
    fn ram_budget_flips_out_of_core_and_refines_the_grid() {
        // 128³ fields are 2·2M words; a 1M-word budget forces out-of-core
        // and the refinement must cut until one shard's working set
        // (2·|halo box|) fits the budget.
        let c = PlannerConfig { ram_budget_words: Some(1 << 20), ..cfg() };
        let p = plan(&c, &[128, 128, 128], &Stencil::star13(), 1);
        assert!(p.out_of_core);
        let sp = crate::shard::ShardPlan::new(&[128, 128, 128], &p.shard_grid, 2);
        assert!(sp.peak_working_words() <= 1 << 20, "{:?}", p.shard_grid);
        assert!(sp.num_shards() > 1);
        // a budget the ping-pong pair fits under stays in memory
        let c = PlannerConfig { ram_budget_words: Some(1 << 23), ..cfg() };
        assert!(!plan(&c, &[128, 128, 128], &Stencil::star13(), 1).out_of_core);
    }

    #[test]
    fn time_tile_engages_when_l2_holds_the_tile() {
        let c = PlannerConfig { machine: MachineModel::r10000_full(), ..cfg() };
        let p = plan(&c, &[128, 128, 128], &Stencil::star13(), 1);
        assert_eq!((p.time_tile, p.time_tile_dims.as_slice()), (5, &[124, 25, 25][..]));
        let q = plan(&c, &[256, 256, 256], &Stencil::star13(), 1);
        assert_eq!((q.time_tile, q.time_tile_dims.as_slice()), (4, &[252, 16, 16][..]));
        // small grids go maximally deep (whole grid fits: tiles uncut)
        let s = plan(&c, &[32, 32, 32], &Stencil::star13(), 1);
        assert_eq!((s.time_tile, s.time_tile_dims.as_slice()), (8, &[28, 28, 28][..]));
        // the chosen box really fits the scratch budget
        for pl in [&p, &q, &s] {
            let halo = 2 * pl.time_tile * 2;
            let boxw: usize = pl.dims.iter().zip(&pl.time_tile_dims).map(|(&n, &t)| n.min(t + halo)).product();
            assert!(boxw <= 512 * 1024 / 2, "box {boxw} exceeds the ping-pong budget");
        }
    }

    #[test]
    fn temporal_traffic_model_beats_classic() {
        let g = GridDesc::new(&[128, 128, 128]);
        // fused single-step pass: ~2 words/point (read everything once,
        // write the interior once) — already well under classic's 5.
        let fused = temporal_solve_traffic_wpp(&g, 2, 1, &[124, 124, 124]);
        assert!(fused > 1.9 && fused < 2.3, "fused wpp = {fused}");
        // deep tile: the box redundancy is amortized over k steps
        let deep = temporal_solve_traffic_wpp(&g, 2, 5, &[124, 25, 25]);
        assert!(deep < fused, "deep wpp = {deep} ≥ fused {fused}");
        assert!(deep < CLASSIC_SOLVE_TRAFFIC_WPP / 3.0, "deep wpp = {deep}");
    }

    #[test]
    fn shard_time_tile_degrades_to_one_when_deep_slab_overflows_the_cache() {
        // L1-only r10000: 4096 words, slab budget 2048. A 128³/2×2×2 deep
        // halo box leads with 66·66+ planes — diameter·lead ≈ 22K words —
        // so no k ≥ 2 is cache-resident and the chooser must fall back to
        // exchange-every-step.
        let m = MachineModel::r10000();
        assert_eq!(choose_shard_time_tile(&m, &[128, 128, 128], &[2, 2, 2], 2), 1);
        let c = PlannerConfig { shard_grid: Some(vec![2, 2, 2]), ..cfg() };
        let p = plan(&c, &[128, 128, 128], &Stencil::star13(), 1);
        assert_eq!(p.shard_time_tile, 1);
        // single-shard plans never deepen: there is no exchange to amortize
        let full = MachineModel::r10000_full();
        assert_eq!(choose_shard_time_tile(&full, &[32, 32, 32], &[1, 1, 1], 2), 1);
    }

    #[test]
    fn time_tile_override_degrades_to_one_without_a_full_interior() {
        // Any dim ≤ 2r means the superstep path cannot run; the explicit
        // --time-tile override must clamp to 1 like the model path does,
        // so tiny grids never carry k·r-deep halos through the classic
        // per-step loop.
        let c = PlannerConfig { shard_grid: Some(vec![2, 1]), time_tile: Some(4), ..cfg() };
        let p = plan(&c, &[4, 16], &Stencil::star(2, 2), 1);
        assert_eq!(p.shard_time_tile, 1);
        // a grid that clears 2r+1 on every dim keeps the override verbatim
        let p = plan(&c, &[16, 16], &Stencil::star(2, 2), 1);
        assert_eq!(p.shard_time_tile, 4);
    }

    #[test]
    fn shard_time_tile_engages_when_the_deep_slab_is_cache_resident() {
        // r10000-full: 512K-word L2. The same 128³/2×2×2 deep slab
        // (5·80·80 ≈ 32K words) fits with room to spare, and the modelled
        // superstep cost beats k classic sweeps — the chooser goes deep.
        let full = MachineModel::r10000_full();
        let k = choose_shard_time_tile(&full, &[128, 128, 128], &[2, 2, 2], 2);
        assert!(k >= 4, "k = {k}");
        let c = PlannerConfig {
            machine: MachineModel::r10000_full(),
            shard_grid: Some(vec![2, 2, 2]),
            ..cfg()
        };
        assert_eq!(plan(&c, &[128, 128, 128], &Stencil::star13(), 1).shard_time_tile, k);
        // an explicit override is taken verbatim, clamped to ≥ 1
        let c = PlannerConfig { time_tile: Some(3), ..c };
        assert_eq!(plan(&c, &[128, 128, 128], &Stencil::star13(), 1).shard_time_tile, 3);
        let c = PlannerConfig { time_tile: Some(0), ..c };
        assert_eq!(plan(&c, &[128, 128, 128], &Stencil::star13(), 1).shard_time_tile, 1);
    }

    #[test]
    fn shard_recommendation_scales_with_interior() {
        // small grids stay sequential (exact simulation)
        let small = plan(&cfg(), &[32, 32, 32], &Stencil::star13(), 1);
        assert_eq!(small.shards, 1);
        // just past the grain: 2 shards (div_ceil, not floor) — a ~170³
        // interior is ~2.3 grains
        let mid = plan(&cfg(), &[174, 174, 174], &Stencil::star13(), 1);
        assert!(mid.shards >= 2, "shards = {}", mid.shards);
        // a 512³ analyze fans out: interior ≈ 1.3·10⁸ points
        let big = plan(&cfg(), &[512, 512, 512], &Stencil::star13(), 1);
        assert!(big.shards > 8, "shards = {}", big.shards);
        assert!(big.shards <= MAX_SHARDS);
    }
}
