//! The planner: turn a stencil job into an execution plan.
//!
//! This is where the paper's results become *policy*:
//!
//! 1. build the interference lattice(s) of the requested layout — the
//!    cache-line lattice always, and the **page interference lattice**
//!    when the machine has a TLB (a grid can be TLB-unfavorable while
//!    L1-favorable, and vice versa);
//! 2. if the grid is unfavorable (§6 short-vector criterion, on either
//!    lattice), consult the padding advisor and re-plan on the padded
//!    layout — the advisor resolves every lattice the machine exposes;
//! 3. choose the traversal: cache-fitting (§4) by default, natural when
//!    the whole working set already fits the cache (no replacement misses
//!    possible — fitting buys nothing and costs order-generation time);
//! 4. attach the Eq 7 / Eq 12 bound predictions so callers can check the
//!    measured loads landed inside the sandwich.

use crate::bounds::{lower_bound_loads_multi, upper_bound_loads_multi};
use crate::cache::MachineModel;
use crate::grid::GridDesc;
use crate::lattice::InterferenceLattice;
use crate::padding::{self, PaddingAdvice};
use crate::stencil::Stencil;
use crate::traversal::{self, Traversal};

/// Traversal policy chosen by the planner.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TraversalChoice {
    /// Lexicographic sweep — optimal when the working set fits the cache.
    Natural,
    /// The paper's §4 pencil sweep.
    CacheFitting,
}

/// A complete plan for one stencil job.
#[derive(Debug, Clone)]
pub struct Plan {
    /// Logical dims of the request.
    pub dims: Vec<usize>,
    /// Storage layout after (possible) padding.
    pub storage_dims: Vec<usize>,
    pub pad: Vec<usize>,
    pub traversal: TraversalChoice,
    /// Recommended pencil-shard count for Analyze workers: 1 below
    /// [`SHARD_GRAIN_POINTS`] (sequential, exact), growing with interior
    /// volume so big jobs fan out across the pool. The coordinator clamps
    /// this to its worker count.
    pub shards: usize,
    /// §6 verdict on the *unpadded* layout (cache-line lattice).
    pub was_unfavorable: bool,
    /// §6 verdict on the *unpadded* layout's page interference lattice —
    /// `None` when the machine has no TLB.
    pub was_tlb_unfavorable: Option<bool>,
    /// Shortest lattice vector (L1, searched to the stencil diameter) of
    /// the final layout.
    pub min_l1: Option<i64>,
    /// Shortest page-lattice vector of the final layout (`None` when the
    /// machine has no TLB or no vector within the searched horizon).
    pub page_min_l1: Option<i64>,
    /// Eccentricity of the final layout's reduced basis.
    pub eccentricity: f64,
    /// Eq 7 prediction (loads for the whole job).
    pub lower_bound: f64,
    /// Eq 12 prediction.
    pub upper_bound: f64,
}

/// Planner configuration.
#[derive(Debug, Clone)]
pub struct PlannerConfig {
    /// The machine to plan for: L1 geometry (lattice/bounds) plus optional
    /// L2/TLB levels the analysis pipeline simulates and the padding
    /// advisor must also satisfy.
    pub machine: MachineModel,
    /// Maximum per-dimension pad the advisor may spend.
    pub max_pad: usize,
    /// Allow the planner to pad unfavorable grids.
    pub auto_pad: bool,
}

impl Default for PlannerConfig {
    fn default() -> Self {
        PlannerConfig { machine: MachineModel::r10000(), max_pad: 8, auto_pad: true }
    }
}

/// Interior points per Analyze shard: below this, sharding buys nothing
/// (order generation and thread fan-out dominate) and the coordinator runs
/// the exact sequential simulation instead.
pub const SHARD_GRAIN_POINTS: u64 = 1 << 21;

/// Hard cap on recommended shards (the coordinator further clamps to its
/// worker count).
pub const MAX_SHARDS: usize = 64;

/// Build the streaming traversal for `choice` over the (padded) grid — the
/// single construction point shared by the coordinator's Analyze path and
/// the native numeric sweep, so analysis and computation always walk the
/// grid in the same order the plan promised.
pub fn build_traversal(
    config: &PlannerConfig,
    grid: &GridDesc,
    stencil: &Stencil,
    choice: TraversalChoice,
) -> Box<dyn Traversal> {
    match choice {
        TraversalChoice::Natural => Box::new(traversal::natural_stream(grid, stencil.radius())),
        // the planner's fitting path is the auto-tuned family
        TraversalChoice::CacheFitting => crate::tuner::auto_fitting_traversal(grid, stencil, &config.machine.l1).0,
    }
}

/// Produce a plan for evaluating `stencil` with `p` RHS arrays over `dims`.
pub fn plan(config: &PlannerConfig, dims: &[usize], stencil: &Stencil, p: usize) -> Plan {
    let cache = &config.machine.l1;
    let grid = GridDesc::new(dims);
    let was_unfavorable = padding::is_unfavorable(&grid, stencil, cache);
    // §6 verdict at page granularity: a short vector in the page
    // interference lattice means one stencil application contends for the
    // TLB's reach — unfavorable for translation no matter the traversal.
    let was_tlb_unfavorable = config.machine.page_modulus().map(|m| padding::is_unfavorable_mod(&grid, stencil, m));

    let needs_pad = was_unfavorable || was_tlb_unfavorable == Some(true);
    let (pad, storage_dims) = if needs_pad && config.auto_pad {
        let advice: PaddingAdvice = padding::advise_machine(&grid, stencil, &config.machine, config.max_pad);
        (advice.pad, advice.storage_dims)
    } else {
        (vec![0; dims.len()], dims.to_vec())
    };
    let padded = GridDesc::with_padding(dims, &pad);
    let lattice = InterferenceLattice::new(padded.storage_dims(), cache.lattice_modulus());
    let min_l1 = lattice.min_l1(stencil.diameter() as i64);
    let eccentricity = lattice.eccentricity();
    let page_min_l1 = match config.machine.page_modulus() {
        Some(m) => InterferenceLattice::new(padded.storage_dims(), m).min_l1(stencil.diameter() as i64),
        None => None,
    };

    // Natural order is optimal when a full working slab (the K-extension of
    // one scanning face of the natural sweep: (2r+1) planes of the leading
    // dims product) fits in cache — then there are no replacement misses to
    // save. For d-dim grids the natural working set is diameter × (product
    // of all dims except the last).
    let slab: u64 = padded.storage_dims()[..dims.len() - 1].iter().map(|&n| n as u64).product::<u64>()
        * stencil.diameter() as u64
        * p as u64;
    let traversal = if dims.len() == 1 || slab <= cache.size_words() as u64 {
        TraversalChoice::Natural
    } else {
        TraversalChoice::CacheFitting
    };

    let (lower_bound, upper_bound) = if dims.len() >= 2 {
        (
            lower_bound_loads_multi(&padded, cache.size_words(), p),
            upper_bound_loads_multi(&padded, cache.size_words(), stencil.radius() as u32, eccentricity, p),
        )
    } else {
        let g = padded.num_points() as f64 * p as f64;
        (g, g) // 1-D: single sweep, every word loaded once
    };

    let interior = padded.interior_points(stencil.radius());
    let shards = (interior.div_ceil(SHARD_GRAIN_POINTS) as usize).clamp(1, MAX_SHARDS);

    Plan {
        dims: dims.to_vec(),
        storage_dims,
        pad,
        traversal,
        shards,
        was_unfavorable,
        was_tlb_unfavorable,
        min_l1,
        page_min_l1,
        eccentricity,
        lower_bound,
        upper_bound,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> PlannerConfig {
        PlannerConfig::default()
    }

    #[test]
    fn favorable_large_grid_uses_fitting_without_padding() {
        let p = plan(&cfg(), &[67, 89, 100], &Stencil::star13(), 1);
        assert!(!p.was_unfavorable);
        assert_eq!(p.pad, vec![0, 0, 0]);
        assert_eq!(p.traversal, TraversalChoice::CacheFitting);
        assert!(p.lower_bound < p.upper_bound);
    }

    #[test]
    fn unfavorable_grid_gets_padded() {
        let p = plan(&cfg(), &[45, 91, 100], &Stencil::star13(), 1);
        assert!(p.was_unfavorable);
        assert!(p.pad.iter().any(|&x| x > 0), "{p:?}");
        // final layout clears the bar
        assert!(p.min_l1.is_none() || p.min_l1.unwrap() >= 5);
    }

    #[test]
    fn auto_pad_can_be_disabled() {
        let mut c = cfg();
        c.auto_pad = false;
        let p = plan(&c, &[45, 91, 100], &Stencil::star13(), 1);
        assert!(p.was_unfavorable);
        assert_eq!(p.pad, vec![0, 0, 0]);
        assert_eq!(p.storage_dims, vec![45, 91, 100]);
    }

    #[test]
    fn small_grid_prefers_natural() {
        // 16×16×16: one slab = 16·16·5 = 1280 words < 4096 ⇒ natural.
        let p = plan(&cfg(), &[16, 16, 16], &Stencil::star13(), 1);
        assert_eq!(p.traversal, TraversalChoice::Natural);
    }

    #[test]
    fn multi_rhs_shrinks_natural_window() {
        // Same 16³ grid with p = 4: slab 4× bigger ⇒ fitting.
        let p = plan(&cfg(), &[16, 16, 16], &Stencil::star13(), 4);
        assert_eq!(p.traversal, TraversalChoice::CacheFitting);
    }

    #[test]
    fn one_dimensional_grid() {
        let p = plan(&cfg(), &[1000], &Stencil::star(1, 1), 1);
        assert_eq!(p.traversal, TraversalChoice::Natural);
        assert_eq!(p.lower_bound, p.upper_bound);
    }

    #[test]
    fn bounds_scale_with_volume() {
        let small = plan(&cfg(), &[32, 32, 32], &Stencil::star13(), 1);
        let big = plan(&cfg(), &[64, 64, 64], &Stencil::star13(), 1);
        assert!(big.lower_bound > 7.0 * small.lower_bound);
    }

    #[test]
    fn build_traversal_covers_the_interior_for_both_choices() {
        let config = cfg();
        let stencil = Stencil::star13();
        let grid = GridDesc::new(&[24, 22, 20]);
        for choice in [TraversalChoice::Natural, TraversalChoice::CacheFitting] {
            let t = build_traversal(&config, &grid, &stencil, choice);
            assert_eq!(t.num_points(), grid.interior_points(2), "{choice:?}");
            assert_eq!(t.ndim(), 3);
        }
    }

    #[test]
    fn single_level_plans_carry_no_tlb_verdict() {
        let p = plan(&cfg(), &[45, 91, 100], &Stencil::star13(), 1);
        assert_eq!(p.was_tlb_unfavorable, None);
        assert_eq!(p.page_min_l1, None);
    }

    #[test]
    fn hierarchical_machine_adds_page_lattice_verdict() {
        let mut c = cfg();
        c.machine = MachineModel::r10000_full();
        // L1-unfavorable 45×91 (4095 ≡ −1 mod 4096) is page-favorable on
        // the 32768-word TLB span — the two verdicts are independent.
        c.auto_pad = false;
        let p = plan(&c, &[45, 91, 100], &Stencil::star13(), 1);
        assert!(p.was_unfavorable);
        assert_eq!(p.was_tlb_unfavorable, Some(false));
        // single-level planning on the same dims is unchanged by the
        // machine's extra levels (L1 lattice, bounds, traversal policy)
        let q = plan(&PlannerConfig { auto_pad: false, ..cfg() }, &[45, 91, 100], &Stencil::star13(), 1);
        assert_eq!(p.pad, q.pad);
        assert_eq!(p.traversal, q.traversal);
        assert_eq!(p.lower_bound, q.lower_bound);
        assert_eq!(p.upper_bound, q.upper_bound);
    }

    #[test]
    fn tlb_only_unfavorability_triggers_padding() {
        use crate::cache::{CacheParams, Latency, TlbParams};
        // Machine from the padding test: L1 modulus 4096, TLB span 18432
        // (not a multiple of 4096). 95×97 is L1-favorable but
        // page-unfavorable ((2,0,2) hits the span); the planner must
        // still pad it.
        let machine = MachineModel {
            name: "r10000+tlb36",
            l1: CacheParams::r10000(),
            l2: None,
            tlb: Some(TlbParams { entries: 36, page_words: 512 }),
            latency: Latency::r10000(),
        };
        let c = PlannerConfig { machine, max_pad: 8, auto_pad: true };
        let p = plan(&c, &[95, 97, 40], &Stencil::star13(), 1);
        assert!(!p.was_unfavorable);
        assert_eq!(p.was_tlb_unfavorable, Some(true));
        assert!(p.pad.iter().any(|&x| x > 0), "{p:?}");
        assert!(p.page_min_l1.is_none() || p.page_min_l1.unwrap() >= 5, "{p:?}");
    }

    #[test]
    fn shard_recommendation_scales_with_interior() {
        // small grids stay sequential (exact simulation)
        let small = plan(&cfg(), &[32, 32, 32], &Stencil::star13(), 1);
        assert_eq!(small.shards, 1);
        // just past the grain: 2 shards (div_ceil, not floor) — a ~170³
        // interior is ~2.3 grains
        let mid = plan(&cfg(), &[174, 174, 174], &Stencil::star13(), 1);
        assert!(mid.shards >= 2, "shards = {}", mid.shards);
        // a 512³ analyze fans out: interior ≈ 1.3·10⁸ points
        let big = plan(&cfg(), &[512, 512, 512], &Stencil::star13(), 1);
        assert!(big.shards > 8, "shards = {}", big.shards);
        assert!(big.shards <= MAX_SHARDS);
    }
}
