//! The long-lived serving front end.
//!
//! A [`Service`] owns the [`Coordinator`] — and through it the memo tier
//! and the worker thread pool — and turns the one-shot
//! `serve(&[StencilRequest])` batch call into a resident server:
//!
//! - [`Service::submit`] enqueues a request and returns a [`Ticket`]
//!   immediately (nothing runs yet);
//! - [`Service::drain`] flushes the queue through the coordinator's
//!   batched, pooled `serve` path and returns `(Ticket, response)` pairs
//!   in submission order;
//! - [`Service::serve`] is the synchronous batch path for callers that
//!   already hold a whole workload;
//! - [`Service::prefill`] warms the memo tier from a shape list before
//!   traffic arrives (plan + default-analysis facets per shape).
//!
//! The memo tier makes the long-lived shape pay off: across `drain` calls
//! the hot shapes of a Zipf-skewed workload stay resident, so repeat
//! requests cost an index lookup instead of a lattice reduction + cache
//! simulation (see `experiments::replay` for the measured effect).

use super::{Coordinator, JobKind, MemoSnapshot, PlannerConfig, StencilRequest, StencilResponse, StencilSpec};
use anyhow::Result;
use std::sync::{Mutex, MutexGuard, PoisonError};

/// Handle to a queued request; [`Service::drain`] tags each response with
/// the ticket of the submission that produced it.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Ticket(pub u64);

#[derive(Default)]
struct Queued {
    next: u64,
    reqs: Vec<(Ticket, StencilRequest)>,
}

/// A resident stencil-serving service: coordinator + memo tier + worker
/// pool behind a submit/drain queue.
pub struct Service {
    coord: Coordinator,
    queue: Mutex<Queued>,
}

impl Service {
    /// Analysis-only service with a memoizing coordinator (the common
    /// configuration; attach a runtime by building the coordinator
    /// yourself and using [`Service::over`]).
    pub fn new(config: PlannerConfig) -> Service {
        Service::over(Coordinator::analysis_only(config))
    }

    /// Wrap an existing coordinator (e.g. one with a PJRT runtime or a
    /// custom memo budget).
    pub fn over(coord: Coordinator) -> Service {
        Service { coord, queue: Mutex::new(Queued::default()) }
    }

    pub fn coordinator(&self) -> &Coordinator {
        &self.coord
    }

    /// Queue lock with poison recovery: a caller panicking mid-`submit`
    /// (e.g. fault injection unwinding through a server thread) must not
    /// brick the resident queue — worst case is one lost enqueue attempt,
    /// never a corrupt queue (the push is the last statement under the
    /// lock).
    fn lock_queue(&self) -> MutexGuard<'_, Queued> {
        self.queue.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Mutable access (memo reconfiguration between traffic waves).
    pub fn coordinator_mut(&mut self) -> &mut Coordinator {
        &mut self.coord
    }

    /// Enqueue a request for the next [`Service::drain`].
    pub fn submit(&self, req: StencilRequest) -> Ticket {
        let mut q = self.lock_queue();
        let t = Ticket(q.next);
        q.next += 1;
        q.reqs.push((t, req));
        t
    }

    /// Requests currently queued (not yet drained).
    pub fn pending(&self) -> usize {
        self.lock_queue().reqs.len()
    }

    /// Run every queued request through the coordinator's batched serve
    /// path; responses come back tagged with their tickets, in submission
    /// order. Requests submitted concurrently with a drain land in the
    /// next one.
    pub fn drain(&self) -> Vec<(Ticket, Result<StencilResponse>)> {
        let batch = {
            let mut q = self.lock_queue();
            std::mem::take(&mut q.reqs)
        };
        if batch.is_empty() {
            return Vec::new();
        }
        let (tickets, reqs): (Vec<Ticket>, Vec<StencilRequest>) = batch.into_iter().unzip();
        let resps = self.coord.serve(&reqs);
        tickets.into_iter().zip(resps).collect()
    }

    /// Synchronous batch path (delegates to [`Coordinator::serve`]).
    pub fn serve(&self, reqs: &[StencilRequest]) -> Vec<Result<StencilResponse>> {
        self.coord.serve(reqs)
    }

    /// Warm the memo tier: for every shape, compute (or re-touch) the plan
    /// facet and the default-analysis facet. 3-D shapes warm the paper's
    /// star13, other ranks a radius-1 star — matching what
    /// `StencilRequest::analyze` would ask for. Returns the number of
    /// successfully warmed requests; failures (e.g. zero dims) are skipped
    /// — warm-up is best effort.
    pub fn prefill(&self, shapes: &[Vec<usize>], rhs_arrays: usize) -> usize {
        let mut reqs = Vec::with_capacity(shapes.len() * 2);
        for dims in shapes {
            let stencil = if dims.len() == 3 { StencilSpec::Star13 } else { StencilSpec::Star { r: 1 } };
            for kind in [JobKind::Plan, JobKind::Analyze] {
                reqs.push(StencilRequest { dims: dims.clone(), stencil: stencil.clone(), rhs_arrays, kind });
            }
        }
        self.coord.serve(&reqs).iter().filter(|r| r.is_ok()).count()
    }

    /// Memo-tier usage (`None` when the coordinator's memo is disabled).
    pub fn memo_snapshot(&self) -> Option<MemoSnapshot> {
        self.coord.memo_snapshot()
    }

    /// Metrics snapshot of the underlying coordinator.
    pub fn metrics_json(&self) -> String {
        self.coord.metrics_json()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::Ordering;

    fn svc() -> Service {
        Service::new(PlannerConfig::default())
    }

    fn analyze(n: usize) -> StencilRequest {
        StencilRequest::analyze(&[n, n, n])
    }

    #[test]
    fn submit_then_drain_answers_in_ticket_order() {
        let s = svc();
        let t0 = s.submit(analyze(16));
        let t1 = s.submit(analyze(20));
        let t2 = s.submit(analyze(16));
        assert_eq!(s.pending(), 3);
        let out = s.drain();
        assert_eq!(s.pending(), 0);
        assert_eq!(out.len(), 3);
        assert_eq!(out[0].0, t0);
        assert_eq!(out[1].0, t1);
        assert_eq!(out[2].0, t2);
        for ((_, resp), n) in out.iter().zip([16usize, 20, 16]) {
            assert_eq!(resp.as_ref().unwrap().plan.dims, vec![n, n, n]);
        }
    }

    #[test]
    fn drain_on_empty_queue_is_empty() {
        let s = svc();
        assert!(s.drain().is_empty());
    }

    #[test]
    fn tickets_stay_unique_across_drains() {
        let s = svc();
        let a = s.submit(analyze(12));
        let _ = s.drain();
        let b = s.submit(analyze(12));
        assert_ne!(a, b);
        let out = s.drain();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].0, b);
    }

    #[test]
    fn prefill_warms_the_memo() {
        let s = svc();
        let shapes = vec![vec![16, 16, 16], vec![20, 20, 20]];
        assert_eq!(s.prefill(&shapes, 1), 4);
        let misses_after_prefill = s.coordinator().metrics().sim_memo_misses.load(Ordering::Relaxed);
        // traffic on the prefetched shapes is pure hits
        for dims in &shapes {
            let _ = s.coordinator().submit(&StencilRequest::analyze(dims)).unwrap();
        }
        let m = s.coordinator().metrics();
        assert_eq!(m.sim_memo_misses.load(Ordering::Relaxed), misses_after_prefill);
        assert!(m.sim_memo_hits.load(Ordering::Relaxed) >= 2);
    }

    #[test]
    fn second_drain_of_same_workload_is_memoized() {
        let s = svc();
        for _ in 0..2 {
            for n in [14usize, 18, 14] {
                s.submit(analyze(n));
            }
            let out = s.drain();
            assert!(out.iter().all(|(_, r)| r.is_ok()));
        }
        let m = s.coordinator().metrics();
        // 2 unique shapes analyzed once each (the duplicate inside wave 1
        // may race its twin, so allow 2..=3), wave 2 entirely from cache
        let analyzed = m.analyzed.load(Ordering::Relaxed);
        assert!((2..=3).contains(&analyzed), "analyzed {analyzed}");
        assert!(m.sim_memo_hits.load(Ordering::Relaxed) >= 3);
    }

    #[test]
    fn service_metrics_passthrough() {
        let s = svc();
        s.submit(analyze(12));
        let _ = s.drain();
        assert!(s.metrics_json().contains("sim_memo_misses"));
        assert!(s.memo_snapshot().unwrap().entries >= 2);
    }
}
