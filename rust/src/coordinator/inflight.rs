//! Request-coalescing primitives for the serving layer.
//!
//! [`SingleFlight`] collapses concurrent misses on one canonical key into a
//! single computation: the first caller becomes the *leader* and computes,
//! every later caller blocks on the flight and receives a clone of the
//! leader's value (for the coordinator that clone is an `Arc` bump, never a
//! recomputed plan or report). A leader that unwinds without publishing
//! wakes its waiters with [`Flight::Retry`] instead of hanging them.
//!
//! [`Admission`] is the bounded-inflight admission controller: at most
//! `cap` permits are out at any instant, and an acquire past the cap is
//! *shed* (counted and refused) rather than queued — an overloaded server
//! answers `Overloaded` immediately instead of building an unbounded
//! backlog.

use std::collections::HashMap;
use std::hash::Hash;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};

/// Lock with poison recovery: a panic in some other holder must not brick
/// this long-lived structure (the protected state is always internally
/// consistent — every critical section here is a handful of non-panicking
/// map/scalar operations).
fn recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

enum SlotState<V> {
    /// The leader is still computing.
    Pending,
    /// The leader published a value; waiters clone it.
    Done(V),
    /// The leader unwound without publishing; waiters must retry.
    Abandoned,
}

struct Slot<V> {
    state: Mutex<SlotState<V>>,
    cond: Condvar,
}

/// One in-flight computation per key; see the module docs.
pub struct SingleFlight<K, V> {
    slots: Mutex<HashMap<K, Arc<Slot<V>>>>,
}

/// Outcome of [`SingleFlight::join`].
pub enum Flight<'a, K: Eq + Hash + Clone, V: Clone> {
    /// This caller owns the computation: compute, then call
    /// [`Leader::complete`]. Dropping the token without completing wakes
    /// every waiter with `Retry`.
    Leader(Leader<'a, K, V>),
    /// Another caller computed the value while we waited.
    Shared(V),
    /// The leader abandoned the flight (panicked mid-compute); re-probe
    /// any cache and join again.
    Retry,
}

/// The leader's obligation token for one flight.
pub struct Leader<'a, K: Eq + Hash + Clone, V: Clone> {
    owner: &'a SingleFlight<K, V>,
    key: K,
    slot: Arc<Slot<V>>,
    completed: bool,
}

impl<K: Eq + Hash + Clone, V: Clone> SingleFlight<K, V> {
    pub fn new() -> SingleFlight<K, V> {
        SingleFlight { slots: Mutex::new(HashMap::new()) }
    }

    /// Join the flight for `key`. The first caller becomes the leader;
    /// everyone else blocks until the leader completes or abandons.
    pub fn join(&self, key: &K) -> Flight<'_, K, V> {
        let slot = {
            let mut slots = recover(&self.slots);
            match slots.get(key) {
                Some(s) => Arc::clone(s),
                None => {
                    let s = Arc::new(Slot { state: Mutex::new(SlotState::Pending), cond: Condvar::new() });
                    slots.insert(key.clone(), Arc::clone(&s));
                    return Flight::Leader(Leader { owner: self, key: key.clone(), slot: s, completed: false });
                }
            }
        };
        let mut st = recover(&slot.state);
        loop {
            match &*st {
                SlotState::Pending => st = slot.cond.wait(st).unwrap_or_else(PoisonError::into_inner),
                SlotState::Done(v) => return Flight::Shared(v.clone()),
                SlotState::Abandoned => return Flight::Retry,
            }
        }
    }

    /// Keys with a leader computing right now.
    pub fn in_flight(&self) -> usize {
        recover(&self.slots).len()
    }

    fn finish(&self, key: &K, slot: &Arc<Slot<V>>, outcome: SlotState<V>) {
        {
            let mut slots = recover(&self.slots);
            // Remove only the exact slot this leader owns: after an
            // abandoned flight a retrying caller may already have installed
            // a fresh one under the same key.
            if slots.get(key).is_some_and(|s| Arc::ptr_eq(s, slot)) {
                slots.remove(key);
            }
        }
        *recover(&slot.state) = outcome;
        slot.cond.notify_all();
    }
}

impl<K: Eq + Hash + Clone, V: Clone> Default for SingleFlight<K, V> {
    fn default() -> SingleFlight<K, V> {
        SingleFlight::new()
    }
}

impl<K: Eq + Hash + Clone, V: Clone> Leader<'_, K, V> {
    /// Publish the computed value to every waiter and retire the flight.
    pub fn complete(mut self, value: V) {
        self.completed = true;
        self.owner.finish(&self.key, &self.slot, SlotState::Done(value));
    }
}

impl<K: Eq + Hash + Clone, V: Clone> Drop for Leader<'_, K, V> {
    fn drop(&mut self) {
        if !self.completed {
            // Unwound without a value: wake the waiters so each can retry
            // instead of blocking forever on a dead leader.
            self.owner.finish(&self.key, &self.slot, SlotState::Abandoned);
        }
    }
}

/// Bounded-inflight admission controller; see the module docs.
#[derive(Debug)]
pub struct Admission {
    cap: usize,
    inflight: AtomicUsize,
    admitted: AtomicU64,
    shed: AtomicU64,
}

/// RAII admission slot: dropping it releases the slot (on completion *or*
/// unwind — a panicking request must not leak capacity).
#[derive(Debug)]
pub struct Permit {
    adm: Arc<Admission>,
}

impl Admission {
    pub fn new(cap: usize) -> Arc<Admission> {
        Arc::new(Admission {
            cap: cap.max(1),
            inflight: AtomicUsize::new(0),
            admitted: AtomicU64::new(0),
            shed: AtomicU64::new(0),
        })
    }

    /// Try to admit one request: `Some(permit)` below the cap, `None`
    /// (shed, counted) at the cap. Never blocks.
    pub fn try_acquire(this: &Arc<Admission>) -> Option<Permit> {
        let mut cur = this.inflight.load(Ordering::Relaxed);
        loop {
            if cur >= this.cap {
                this.shed.fetch_add(1, Ordering::Relaxed);
                return None;
            }
            match this.inflight.compare_exchange_weak(cur, cur + 1, Ordering::AcqRel, Ordering::Relaxed) {
                Ok(_) => {
                    this.admitted.fetch_add(1, Ordering::Relaxed);
                    return Some(Permit { adm: Arc::clone(this) });
                }
                Err(now) => cur = now,
            }
        }
    }

    pub fn cap(&self) -> usize {
        self.cap
    }

    pub fn inflight(&self) -> usize {
        self.inflight.load(Ordering::Relaxed)
    }

    pub fn admitted_total(&self) -> u64 {
        self.admitted.load(Ordering::Relaxed)
    }

    pub fn shed_total(&self) -> u64 {
        self.shed.load(Ordering::Relaxed)
    }
}

impl Drop for Permit {
    fn drop(&mut self) {
        self.adm.inflight.fetch_sub(1, Ordering::AcqRel);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;
    use std::sync::Barrier;

    #[test]
    fn single_flight_collapses_concurrent_joins() {
        let sf: SingleFlight<u64, u64> = SingleFlight::new();
        let computed = AtomicU64::new(0);
        let k = 8;
        let barrier = Barrier::new(k);
        let vals: Vec<u64> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..k)
                .map(|_| {
                    let (sf, computed, barrier) = (&sf, &computed, &barrier);
                    s.spawn(move || {
                        barrier.wait();
                        loop {
                            match sf.join(&7) {
                                Flight::Leader(token) => {
                                    computed.fetch_add(1, Ordering::Relaxed);
                                    token.complete(42);
                                    break 42;
                                }
                                Flight::Shared(v) => break v,
                                Flight::Retry => continue,
                            }
                        }
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert!(vals.iter().all(|&v| v == 42));
        // Every thread got the value; at least one collapse is guaranteed
        // only when joins overlap, but the compute count never exceeds the
        // thread count and a leader exists per retry round.
        assert!(computed.load(Ordering::Relaxed) >= 1);
        assert_eq!(sf.in_flight(), 0);
    }

    #[test]
    fn followers_observe_leader_value_not_their_own() {
        let sf: SingleFlight<&'static str, u64> = SingleFlight::new();
        let Flight::Leader(token) = sf.join(&"k") else { panic!("first join must lead") };
        let follower = std::thread::scope(|s| {
            let sf = &sf;
            let h = s.spawn(move || match sf.join(&"k") {
                Flight::Shared(v) => v,
                _ => panic!("second concurrent join must follow"),
            });
            // Publish only once the follower holds the flight: joining
            // clones the slot Arc (map + leader + follower = 3), after
            // which the follower can only observe Done(99).
            while Arc::strong_count(&token.slot) < 3 {
                std::thread::yield_now();
            }
            token.complete(99);
            h.join().unwrap()
        });
        assert_eq!(follower, 99);
    }

    #[test]
    fn abandoned_leader_wakes_waiters_with_retry() {
        let sf = Arc::new(SingleFlight::<u64, u64>::new());
        let Flight::Leader(token) = sf.join(&1) else { panic!("first join must lead") };
        let sf2 = Arc::clone(&sf);
        let waiter = std::thread::spawn(move || {
            loop {
                match sf2.join(&1) {
                    Flight::Leader(t) => {
                        // after the abandon, the retrying waiter leads
                        t.complete(5);
                        break 5u64;
                    }
                    Flight::Shared(v) => break v,
                    Flight::Retry => continue,
                }
            }
        });
        // simulate a panicking leader: drop without complete()
        drop(token);
        assert_eq!(waiter.join().unwrap(), 5);
        assert_eq!(sf.in_flight(), 0);
    }

    #[test]
    fn admission_sheds_at_cap_and_recovers() {
        let adm = Admission::new(2);
        let p1 = Admission::try_acquire(&adm).expect("slot 1");
        let p2 = Admission::try_acquire(&adm).expect("slot 2");
        assert!(Admission::try_acquire(&adm).is_none(), "cap reached must shed");
        assert_eq!(adm.shed_total(), 1);
        assert_eq!(adm.inflight(), 2);
        drop(p1);
        let p3 = Admission::try_acquire(&adm).expect("slot freed by drop");
        drop(p2);
        drop(p3);
        assert_eq!(adm.inflight(), 0);
        assert_eq!(adm.admitted_total(), 3);
    }

    #[test]
    fn admission_never_exceeds_cap_under_contention() {
        let adm = Admission::new(4);
        let peak = Arc::new(AtomicU64::new(0));
        std::thread::scope(|s| {
            for _ in 0..16 {
                let (adm, peak) = (Arc::clone(&adm), Arc::clone(&peak));
                s.spawn(move || {
                    for _ in 0..200 {
                        if let Some(p) = Admission::try_acquire(&adm) {
                            let now = adm.inflight() as u64;
                            peak.fetch_max(now, Ordering::Relaxed);
                            drop(p);
                        }
                    }
                });
            }
        });
        assert!(peak.load(Ordering::Relaxed) <= 4, "inflight exceeded the cap");
        assert_eq!(adm.inflight(), 0);
    }
}
