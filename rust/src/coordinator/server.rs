//! JSON-over-TCP serving front end.
//!
//! Line-delimited request/response over plain `TcpStream`s — no HTTP, no
//! serde, no async runtime; connections and dispatch run on the existing
//! [`ThreadPool`] machinery. One request per line, one response line per
//! request; responses for pipelined requests on a connection may arrive
//! out of order (match on `id`).
//!
//! Request object (`\n`-terminated, ≤ `max_line_bytes`):
//!
//! ```text
//! {"id": 7,                       // echoed verbatim; any JSON value
//!  "kind": "plan" | "analyze" | "analyze_with" | "execute" | "solve"
//!        | "metrics" | "chaos_panic" | "shutdown",
//!  "dims": [64, 64, 64],          // per-dim extents, 1..=4096, ≤ 6 dims
//!  "stencil": "star13" | {"star": 2},   // optional; default star13 for
//!                                       // 3-D dims, {"star":1} otherwise
//!  "rhs": 1,                      // optional RHS-array count, 1..=64
//!  "steps": 5,                    // solve only, 1..=10000
//!  "traversal": "natural" | "fitting"}  // analyze_with only
//! ```
//!
//! Success: `{"id":…, "ok":true, "wall_us":…, "plan":{…}, …}` with
//! `misses_per_point`/`points` for analyses, `result_norm`/`steps` for
//! numeric jobs. Failure: `{"id":…, "ok":false, "error":"bad_request" |
//! "overloaded" | "internal", "message":…}`.
//!
//! Three serving-layer properties hold by construction:
//!
//! - **single-flight**: concurrent misses on one canonical key compute
//!   once (the coordinator's flight tier; watch `single_flight_collapsed`
//!   in a `metrics` response);
//! - **admission control**: at most `max_inflight` stencil jobs run at
//!   once; excess requests get an immediate typed `overloaded` response
//!   instead of queueing (`metrics`/`shutdown` bypass admission — control
//!   traffic must work *especially* under overload);
//! - **panic containment**: a request that panics (or sends malformed
//!   JSON) receives an error response while the server keeps serving
//!   (`Coordinator::submit_caught` + the poison-recovering locks).

use super::inflight::{Admission, Permit};
use super::{JobKind, Service, StencilRequest, StencilResponse, StencilSpec, TraversalChoice};
use crate::util::json::{self, Json};
use crate::util::threadpool::ThreadPool;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex, PoisonError};
use std::thread::JoinHandle;
use std::time::Instant;

/// Input caps for wire requests — generous for every real workload, tight
/// enough that a hostile client cannot request months of compute.
pub const MAX_WIRE_DIMS: usize = 6;
pub const MAX_WIRE_EXTENT: usize = 4096;
pub const MAX_WIRE_RADIUS: usize = 8;
pub const MAX_WIRE_STEPS: usize = 10_000;
/// Depth cap for wire JSON (requests are flat; 16 is plenty).
pub const MAX_WIRE_DEPTH: usize = 16;

/// Server tuning knobs.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Bind address; port 0 picks an ephemeral port (read it back from
    /// [`Server::addr`]).
    pub addr: String,
    /// Admission cap: stencil jobs admitted concurrently; the excess is
    /// shed with a typed `overloaded` response.
    pub max_inflight: usize,
    /// Dispatch workers turning decoded requests into responses (the
    /// coordinator's own pool fans each job out further).
    pub workers: usize,
    /// Per-line byte cap; a longer request line answers `bad_request` and
    /// closes the connection (mid-line resync is impossible).
    pub max_line_bytes: usize,
}

impl Default for ServerConfig {
    fn default() -> ServerConfig {
        let workers = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).clamp(2, 16);
        ServerConfig { addr: "127.0.0.1:0".into(), max_inflight: 64, workers, max_line_bytes: 64 * 1024 }
    }
}

/// A running JSON-over-TCP front end over an [`Arc<Service>`].
///
/// Dropping the server shuts it down: stops accepting, closes live
/// connections, joins every thread.
pub struct Server {
    svc: Arc<Service>,
    admission: Arc<Admission>,
    stop: Arc<AtomicBool>,
    addr: SocketAddr,
    accept: Option<JoinHandle<()>>,
    conns: Arc<Mutex<Vec<TcpStream>>>,
    conn_threads: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

fn lock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

impl Server {
    /// Bind and start serving. Returns once the listener is live.
    pub fn start(svc: Arc<Service>, config: ServerConfig) -> std::io::Result<Server> {
        let listener = TcpListener::bind(config.addr.as_str())?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let admission = Admission::new(config.max_inflight);
        let pool = Arc::new(ThreadPool::new(config.workers));
        let conns: Arc<Mutex<Vec<TcpStream>>> = Arc::new(Mutex::new(Vec::new()));
        let conn_threads: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
        let accept = {
            let svc = Arc::clone(&svc);
            let stop = Arc::clone(&stop);
            let admission = Arc::clone(&admission);
            let conns = Arc::clone(&conns);
            let conn_threads = Arc::clone(&conn_threads);
            let max_line = config.max_line_bytes.max(64);
            std::thread::Builder::new().name("stencilcache-accept".into()).spawn(move || {
                for incoming in listener.incoming() {
                    if stop.load(Ordering::Acquire) {
                        break;
                    }
                    let stream = match incoming {
                        Ok(s) => s,
                        Err(_) => continue,
                    };
                    if let Ok(clone) = stream.try_clone() {
                        lock(&conns).push(clone);
                    }
                    let svc = Arc::clone(&svc);
                    let stop = Arc::clone(&stop);
                    let admission = Arc::clone(&admission);
                    let pool = Arc::clone(&pool);
                    let handle = std::thread::Builder::new()
                        .name("stencilcache-conn".into())
                        .spawn(move || handle_conn(stream, svc, admission, pool, stop, addr, max_line));
                    if let Ok(h) = handle {
                        lock(&conn_threads).push(h);
                    }
                }
            })?
        };
        Ok(Server { svc, admission, stop, addr, accept: Some(accept), conns, conn_threads })
    }

    /// The bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn service(&self) -> &Arc<Service> {
        &self.svc
    }

    pub fn admission(&self) -> &Arc<Admission> {
        &self.admission
    }

    /// Block until the server is asked to stop (a wire `shutdown` request
    /// or [`Server::shutdown`] from another thread).
    pub fn wait(&mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }

    /// Stop accepting, close live connections, join every thread.
    /// Idempotent; also runs on drop.
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Release);
        // unblock the accept loop with a throwaway connection
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        for s in lock(&self.conns).drain(..) {
            let _ = s.shutdown(Shutdown::Both);
        }
        let handles: Vec<JoinHandle<()>> = lock(&self.conn_threads).drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

enum LineError {
    TooLong,
    Io,
}

/// `read_until('\n')` with a hard byte cap (a `Take` bounds each call, so
/// a client streaming an endless line cannot grow the buffer unboundedly).
fn read_line_bounded<R: BufRead>(r: &mut R, buf: &mut Vec<u8>, max: usize) -> Result<usize, LineError> {
    let mut limited = r.by_ref().take(max as u64 + 1);
    match limited.read_until(b'\n', buf) {
        Ok(n) => {
            if n > max && buf.last() != Some(&b'\n') {
                Err(LineError::TooLong)
            } else {
                Ok(n)
            }
        }
        Err(_) => Err(LineError::Io),
    }
}

fn handle_conn(
    stream: TcpStream,
    svc: Arc<Service>,
    admission: Arc<Admission>,
    pool: Arc<ThreadPool>,
    stop: Arc<AtomicBool>,
    server_addr: SocketAddr,
    max_line: usize,
) {
    super::Metrics::bump(&svc.coordinator().metrics().server_connections, 1);
    let write_half = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    // One writer thread per connection serializes response lines: dispatch
    // jobs finish out of order on the pool, and interleaved partial writes
    // would corrupt the stream.
    let (tx, rx) = mpsc::channel::<String>();
    let writer = std::thread::Builder::new().name("stencilcache-conn-writer".into()).spawn(move || {
        let mut out = write_half;
        for line in rx {
            let ok = out
                .write_all(line.as_bytes())
                .and_then(|_| out.write_all(b"\n"))
                .and_then(|_| out.flush())
                .is_ok();
            if !ok {
                break;
            }
        }
    });
    let Ok(writer) = writer else { return };
    let mut reader = BufReader::new(stream);
    let mut buf: Vec<u8> = Vec::new();
    loop {
        if stop.load(Ordering::Acquire) {
            break;
        }
        buf.clear();
        match read_line_bounded(&mut reader, &mut buf, max_line) {
            Ok(0) => break, // EOF
            Ok(_) => {}
            Err(LineError::TooLong) => {
                let msg = format!("request line exceeds {max_line} bytes");
                let _ = tx.send(error_response(Json::Null, "bad_request", &msg).to_string());
                break;
            }
            Err(LineError::Io) => break,
        }
        let Ok(text) = std::str::from_utf8(&buf) else {
            super::Metrics::bump(&svc.coordinator().metrics().server_bad_requests, 1);
            let _ = tx.send(error_response(Json::Null, "bad_request", "request line is not UTF-8").to_string());
            continue;
        };
        let text = text.trim();
        if text.is_empty() {
            continue;
        }
        handle_line(text, &svc, &admission, &pool, &stop, server_addr, &tx);
    }
    drop(tx);
    let _ = writer.join();
}

fn handle_line(
    text: &str,
    svc: &Arc<Service>,
    admission: &Arc<Admission>,
    pool: &ThreadPool,
    stop: &Arc<AtomicBool>,
    server_addr: SocketAddr,
    tx: &mpsc::Sender<String>,
) {
    let metrics = svc.coordinator().metrics();
    let parsed = match json::parse_with_limits(text, text.len(), MAX_WIRE_DEPTH) {
        Ok(v) => v,
        Err(e) => {
            super::Metrics::bump(&metrics.server_bad_requests, 1);
            let _ = tx.send(error_response(Json::Null, "bad_request", &format!("malformed JSON: {e}")).to_string());
            return;
        }
    };
    let id = parsed.get("id").cloned().unwrap_or(Json::Null);
    super::Metrics::bump(&metrics.server_requests, 1);
    let Some(kind) = parsed.get("kind").and_then(Json::as_str) else {
        super::Metrics::bump(&metrics.server_bad_requests, 1);
        let _ = tx.send(error_response(id, "bad_request", "missing \"kind\"").to_string());
        return;
    };
    match kind {
        "metrics" => {
            let mut o = Json::obj();
            o.set("id", id).set("ok", true).set("metrics", svc.coordinator().metrics_json_value());
            let _ = tx.send(o.to_string());
        }
        "shutdown" => {
            let mut o = Json::obj();
            o.set("id", id).set("ok", true).set("stopping", true);
            let _ = tx.send(o.to_string());
            stop.store(true, Ordering::Release);
            // unblock the accept loop; the owner's shutdown()/drop joins
            let _ = TcpStream::connect(server_addr);
        }
        _ => {
            let req = match decode_request(kind, &parsed) {
                Ok(r) => r,
                Err(msg) => {
                    super::Metrics::bump(&metrics.server_bad_requests, 1);
                    let _ = tx.send(error_response(id, "bad_request", &msg).to_string());
                    return;
                }
            };
            let Some(permit) = Admission::try_acquire(admission) else {
                super::Metrics::bump(&metrics.server_shed, 1);
                let msg = format!("inflight cap {} reached; retry later", admission.cap());
                let _ = tx.send(error_response(id, "overloaded", &msg).to_string());
                return;
            };
            let svc = Arc::clone(svc);
            let tx = tx.clone();
            let t0 = Instant::now();
            pool.submit(move || {
                let permit: Permit = permit; // move the slot into the job
                let result = svc.coordinator().submit_caught(&req);
                let line = response_line(id, result, t0.elapsed().as_micros() as u64);
                drop(permit);
                let _ = tx.send(line.to_string());
            });
        }
    }
}

/// Decode a wire object into a [`StencilRequest`], enforcing the input
/// caps. Errors are client-facing `bad_request` messages.
fn decode_request(kind: &str, v: &Json) -> Result<StencilRequest, String> {
    let job = match kind {
        "plan" => JobKind::Plan,
        "analyze" => JobKind::Analyze,
        "analyze_with" => match v.get("traversal").and_then(Json::as_str) {
            Some("natural") => JobKind::AnalyzeWith(TraversalChoice::Natural),
            Some("fitting") | Some("cache_fitting") => JobKind::AnalyzeWith(TraversalChoice::CacheFitting),
            other => {
                return Err(format!("analyze_with needs \"traversal\": \"natural\" or \"fitting\" (got {other:?})"))
            }
        },
        "execute" => JobKind::Execute,
        "solve" => {
            let steps = v.get("steps").and_then(Json::as_i64).unwrap_or(0);
            if steps < 1 || steps as usize > MAX_WIRE_STEPS {
                return Err(format!("solve needs \"steps\" in 1..={MAX_WIRE_STEPS}"));
            }
            JobKind::Solve { steps: steps as usize }
        }
        "chaos_panic" => JobKind::ChaosPanic,
        other => {
            return Err(format!(
                "unknown kind {other:?} (expected plan|analyze|analyze_with|execute|solve|metrics|shutdown)"
            ))
        }
    };
    let dims: Vec<usize> = match v.get("dims").and_then(Json::as_arr) {
        Some(xs) => {
            if xs.is_empty() || xs.len() > MAX_WIRE_DIMS {
                return Err(format!("\"dims\" needs 1..={MAX_WIRE_DIMS} entries"));
            }
            let mut out = Vec::with_capacity(xs.len());
            for x in xs {
                match x.as_i64() {
                    Some(d) if d >= 1 && (d as usize) <= MAX_WIRE_EXTENT => out.push(d as usize),
                    _ => return Err(format!("\"dims\" entries must be integers in 1..={MAX_WIRE_EXTENT}")),
                }
            }
            out
        }
        // fault injection never reaches the validators, so dims are moot
        None if matches!(job, JobKind::ChaosPanic) => vec![4, 4, 4],
        None => return Err("missing \"dims\" array".into()),
    };
    let stencil = match v.get("stencil") {
        None => {
            if dims.len() == 3 {
                StencilSpec::Star13
            } else {
                StencilSpec::Star { r: 1 }
            }
        }
        Some(Json::Str(s)) if s == "star13" => StencilSpec::Star13,
        Some(obj) => match obj.get("star").and_then(Json::as_i64) {
            Some(r) if r >= 1 && (r as usize) <= MAX_WIRE_RADIUS => StencilSpec::Star { r: r as usize },
            _ => {
                return Err(format!("\"stencil\" must be \"star13\" or {{\"star\": r}} with r in 1..={MAX_WIRE_RADIUS}"))
            }
        },
    };
    let rhs = match v.get("rhs") {
        None => 1,
        Some(x) => match x.as_i64() {
            Some(r) if (1..=64).contains(&r) => r as usize,
            _ => return Err("\"rhs\" must be an integer in 1..=64".into()),
        },
    };
    Ok(StencilRequest { dims, stencil, rhs_arrays: rhs, kind: job })
}

fn error_response(id: Json, class: &str, message: &str) -> Json {
    let mut o = Json::obj();
    o.set("id", id).set("ok", false).set("error", class).set("message", message);
    o
}

/// Encode a coordinator outcome as one response line. Panics surface as
/// `internal`, validator rejections as `bad_request`.
fn response_line(id: Json, result: anyhow::Result<StencilResponse>, wall_us: u64) -> Json {
    match result {
        Ok(resp) => {
            let mut o = Json::obj();
            o.set("id", id).set("ok", true).set("wall_us", wall_us);
            let mut plan = Json::obj();
            plan.set("dims", resp.plan.dims.clone())
                .set("pad", resp.plan.pad.clone())
                .set("traversal", format!("{:?}", resp.plan.traversal))
                .set("shards", resp.plan.shards)
                .set("time_tile", resp.plan.time_tile)
                .set("unfavorable", resp.plan.was_unfavorable);
            o.set("plan", plan);
            if let Some(m) = &resp.miss_report {
                o.set("points", m.points).set("misses_per_point", m.misses_per_point());
            }
            if let Some(n) = resp.result_norm {
                o.set("result_norm", n);
            }
            if let Some(last) = resp.solve_log.last() {
                o.set("steps", resp.solve_log.len()).set("final_residual", last.residual_norm);
            }
            o
        }
        Err(e) => {
            let msg = e.to_string();
            let class = if msg.contains("panicked") { "internal" } else { "bad_request" };
            error_response(id, class, &msg)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::PlannerConfig;
    use std::time::Duration;

    struct Client {
        stream: TcpStream,
        reader: BufReader<TcpStream>,
    }

    impl Client {
        fn connect(addr: SocketAddr) -> Client {
            let stream = TcpStream::connect(addr).expect("connect");
            stream.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
            let reader = BufReader::new(stream.try_clone().expect("clone"));
            Client { stream, reader }
        }

        fn send(&mut self, line: &str) {
            self.stream.write_all(line.as_bytes()).unwrap();
            self.stream.write_all(b"\n").unwrap();
            self.stream.flush().unwrap();
        }

        fn recv(&mut self) -> Json {
            let mut line = String::new();
            let n = self.reader.read_line(&mut line).expect("response before timeout");
            assert!(n > 0, "server closed the connection unexpectedly");
            json::parse(line.trim()).expect("response is valid JSON")
        }
    }

    fn start_server(max_inflight: usize) -> Server {
        let svc = Arc::new(Service::new(PlannerConfig::default()));
        let cfg = ServerConfig { max_inflight, workers: 4, ..ServerConfig::default() };
        Server::start(svc, cfg).expect("server start")
    }

    fn is_ok(v: &Json) -> bool {
        v.get("ok") == Some(&Json::Bool(true))
    }

    fn error_class(v: &Json) -> &str {
        v.get("error").and_then(Json::as_str).unwrap_or("")
    }

    #[test]
    fn round_trip_plan_and_analyze() {
        let mut server = start_server(16);
        let mut c = Client::connect(server.addr());
        c.send("{\"id\":1,\"kind\":\"plan\",\"dims\":[24,24,24]}");
        let r = c.recv();
        assert!(is_ok(&r), "{r}");
        assert_eq!(r.get("id").unwrap().as_i64(), Some(1));
        assert!(r.get("plan").unwrap().get("dims").is_some());
        c.send("{\"id\":2,\"kind\":\"analyze\",\"dims\":[20,20,20]}");
        let r = c.recv();
        assert!(is_ok(&r), "{r}");
        assert!(r.get("misses_per_point").unwrap().as_f64().unwrap() > 0.0);
        server.shutdown();
    }

    #[test]
    fn malformed_and_invalid_requests_answer_errors_and_server_survives() {
        let mut server = start_server(16);
        let mut c = Client::connect(server.addr());
        // malformed JSON
        c.send("{\"id\":1,\"kind\":\"analyze\",\"dims\":[16,16");
        let r = c.recv();
        assert!(!is_ok(&r));
        assert_eq!(error_class(&r), "bad_request");
        // structurally valid, semantically invalid (star13 is 3-D)
        c.send("{\"id\":2,\"kind\":\"analyze\",\"dims\":[16,16],\"stencil\":\"star13\"}");
        let r = c.recv();
        assert!(!is_ok(&r));
        assert_eq!(error_class(&r), "bad_request");
        // a panicking request answers internal...
        c.send("{\"id\":3,\"kind\":\"chaos_panic\"}");
        let r = c.recv();
        assert!(!is_ok(&r));
        assert_eq!(error_class(&r), "internal");
        // ...and the same connection keeps working afterwards
        c.send("{\"id\":4,\"kind\":\"plan\",\"dims\":[16,16,16]}");
        let r = c.recv();
        assert!(is_ok(&r), "{r}");
        server.shutdown();
    }

    #[test]
    fn oversized_line_is_rejected() {
        let svc = Arc::new(Service::new(PlannerConfig::default()));
        let cfg = ServerConfig { max_line_bytes: 256, workers: 2, ..ServerConfig::default() };
        let mut server = Server::start(svc, cfg).expect("server start");
        let mut c = Client::connect(server.addr());
        let huge = format!("{{\"id\":1,\"kind\":\"plan\",\"pad\":\"{}\"}}", "x".repeat(512));
        c.send(&huge);
        let r = c.recv();
        assert!(!is_ok(&r));
        assert_eq!(error_class(&r), "bad_request");
        server.shutdown();
    }

    #[test]
    fn metrics_request_reports_latency_histograms() {
        let mut server = start_server(16);
        let mut c = Client::connect(server.addr());
        c.send("{\"id\":1,\"kind\":\"analyze\",\"dims\":[16,16,16]}");
        assert!(is_ok(&c.recv()));
        c.send("{\"id\":2,\"kind\":\"metrics\"}");
        let r = c.recv();
        assert!(is_ok(&r), "{r}");
        let m = r.get("metrics").expect("metrics body");
        assert!(m.get("server_requests").unwrap().as_i64().unwrap() >= 2);
        let lat = m.get("latency_us").expect("latency histograms");
        assert_eq!(lat.get("analyze").unwrap().get("count").unwrap().as_i64(), Some(1));
        assert!(lat.get("analyze").unwrap().get("p999_us").is_some());
        server.shutdown();
    }

    #[test]
    fn wire_shutdown_stops_the_accept_loop() {
        let mut server = start_server(4);
        let mut c = Client::connect(server.addr());
        c.send("{\"id\":1,\"kind\":\"shutdown\"}");
        let r = c.recv();
        assert!(is_ok(&r), "{r}");
        // wait() returning (instead of hanging the test) IS the assertion:
        // the wire request stopped the accept loop
        server.wait();
        server.shutdown();
    }
}
