//! L3 coordinator: the serving layer that turns stencil jobs into plans,
//! simulations, and numeric executions.
//!
//! Pipeline per request:
//!
//! ```text
//! StencilRequest ─▶ Planner (lattice analysis, padding, traversal choice,
//!                   shard recommendation, bound predictions)
//!                ─▶ Batcher (group by shape/kind, heaviest batch first)
//!                ─▶ Workers (thread pool):
//!                     Analyze  → streaming traversal → engine::simulate,
//!                                fanned out over pencil shards when the
//!                                interior is large (simulate_sharded)
//!                     Execute  → NumericBackend (PJRT artifact when one is
//!                                available, native engine sweep otherwise)
//!                     Solve    → repeated step + residual/L2 reductions on
//!                                the selected backend
//! ```
//!
//! Python never appears here: numeric work runs from the AOT artifacts in
//! `artifacts/` via the PJRT CPU client **or** — when the `pjrt` feature is
//! off or the shape has no artifact — on the pure-Rust
//! [`crate::solver::NativeBackend`], which applies the stencil over the
//! planner-chosen traversal, sharded across the worker pool. Analysis work
//! runs on the cache simulator. All paths are pure rust at request time.
//!
//! Since the serving-layer refactor the coordinator is **memoizing**: the
//! plan and the analysis report are pure functions of the request key, so
//! they are cached in an [`S3Fifo`] tier and `Plan` /
//! `Analyze` / `AnalyzeWith` responses whose canonical [`RequestKey`]
//! matches a cached entry are served without recomputation
//! (`Execute`/`Solve` reuse the cached *plan* but always run numerics).
//! [`Service`] wraps a coordinator into the long-lived serving front end
//! (`submit`/`serve`/`drain` + `prefill` warm-up).

mod batcher;
mod inflight;
mod memo;
mod metrics;
mod planner;
mod server;
mod service;

pub use batcher::{group_by_shape, schedule, Batch, BatchKey};
pub use inflight::{Admission, Flight, Leader, Permit, SingleFlight};
pub use memo::{entry_bytes, CachedValue, Facet, MemoCounters, MemoSnapshot, RequestKey, S3Fifo, DEFAULT_MEMO_BYTES};
pub use metrics::{Histogram, Metrics, LATENCY_KINDS};
pub use server::{Server, ServerConfig};
pub use planner::{
    build_traversal, choose_shard_time_tile, choose_time_tile, plan, temporal_solve_traffic_wpp, Plan, PlannerConfig,
    TraversalChoice, CLASSIC_SOLVE_TRAFFIC_WPP, MAX_SHARDS, MAX_TIME_TILE, SHARD_GRAIN_POINTS,
};
pub use service::{Service, Ticket};

pub use crate::solver::{deterministic_input, SolveStep};

use crate::cache::Level;
use crate::engine::{self, MissReport};
use crate::grid::{GridDesc, MultiArrayLayout};
use crate::runtime::RuntimeHandle;
use crate::solver::{NativeBackend, NumericBackend, NumericJob, PjrtBackend};
use crate::stencil::Stencil;
use crate::traversal::{self, Traversal};
use crate::util::json::Json;
use crate::util::threadpool::ThreadPool;
use anyhow::{bail, Result};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};
use std::time::Instant;

/// Stencil shape specification in requests.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum StencilSpec {
    /// Star of radius r in the dims' dimensionality.
    Star { r: usize },
    /// The paper's 13-point star (3-D, r = 2).
    Star13,
}

impl StencilSpec {
    pub fn build(&self, ndim: usize) -> Stencil {
        match self {
            StencilSpec::Star { r } => Stencil::star(ndim, *r),
            StencilSpec::Star13 => {
                assert_eq!(ndim, 3, "star13 is 3-D");
                Stencil::star13()
            }
        }
    }
}

/// What the caller wants done.
#[derive(Debug, Clone)]
pub enum JobKind {
    /// Plan only (lattice analysis + bounds).
    Plan,
    /// Simulate cache behaviour under the planned traversal.
    Analyze,
    /// Simulate under an explicitly requested traversal (baseline runs).
    AnalyzeWith(TraversalChoice),
    /// One stencil application (PJRT artifact when available, native
    /// engine sweep otherwise).
    Execute,
    /// `steps` heat/Jacobi iterations with per-step norms, on the same
    /// backend selection as `Execute`.
    Solve { steps: usize },
    /// Fault injection: panics inside `dispatch`, exercising the serving
    /// layer's panic containment (`submit_caught`, scope_map propagation,
    /// poison recovery). Exposed on the wire as `"chaos_panic"` for the
    /// smoke harness; never useful to a real client.
    #[doc(hidden)]
    ChaosPanic,
}

/// A stencil job.
#[derive(Debug, Clone)]
pub struct StencilRequest {
    pub dims: Vec<usize>,
    pub stencil: StencilSpec,
    /// Number of RHS arrays (§5); 1 for the classic q = Ku.
    pub rhs_arrays: usize,
    pub kind: JobKind,
}

impl StencilRequest {
    pub fn analyze(dims: &[usize]) -> StencilRequest {
        StencilRequest { dims: dims.to_vec(), stencil: StencilSpec::Star13, rhs_arrays: 1, kind: JobKind::Analyze }
    }

    fn batch_key(&self, config: &PlannerConfig) -> BatchKey {
        let kind = match self.kind {
            JobKind::Plan => "plan",
            JobKind::Analyze => "analyze",
            JobKind::AnalyzeWith(TraversalChoice::Natural) => "analyze-nat",
            JobKind::AnalyzeWith(TraversalChoice::CacheFitting) => "analyze-fit",
            JobKind::Execute => "execute",
            JobKind::Solve { .. } => "solve",
            JobKind::ChaosPanic => "chaos",
        };
        BatchKey { kind, dims: self.dims.clone(), stencil: self.stencil.clone(), machine: config.machine.clone() }
    }
}

/// The coordinator's answer.
///
/// The plan is `Arc`-shared with the memo tier (and with every other
/// response for the same request key): a cache hit costs one refcount
/// bump, not a `Plan` clone.
#[derive(Debug)]
pub struct StencilResponse {
    pub plan: Arc<Plan>,
    pub miss_report: Option<MissReport>,
    /// Final tensor norm for numeric jobs.
    pub result_norm: Option<f64>,
    pub solve_log: Vec<SolveStep>,
    pub wall_micros: u64,
}

/// Decrement-on-drop guard for the coordinator's in-flight fan-out count:
/// a panicking shard worker unwinds through the job, and a leaked count
/// would permanently shrink every later job's budget on this long-lived
/// coordinator.
struct ActiveGuard<'a>(&'a AtomicUsize);

impl Drop for ActiveGuard<'_> {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

/// The coordinator.
pub struct Coordinator {
    config: PlannerConfig,
    runtime: Option<Arc<RuntimeHandle>>,
    pool: ThreadPool,
    metrics: Arc<Metrics>,
    /// Memoization tier (S3-FIFO over canonical request keys), on by
    /// default with [`DEFAULT_MEMO_BYTES`]; `None` disables memoization
    /// entirely (cold baselines, benches). The mutex is held only for the
    /// O(1) index operation — a hit copies an `Arc<Plan>` pointer plus a
    /// small inline `Copy` report, never a `Plan`.
    memo: Option<Mutex<S3Fifo<RequestKey, CachedValue>>>,
    /// Single-flight tier over the memo: N concurrent misses on one
    /// canonical plan key compute once; the waiters share the leader's
    /// `Arc<Plan>` (see `plan_for`).
    plan_flights: SingleFlight<RequestKey, Arc<Plan>>,
    /// Same collapsing for analysis reports (plan + `Copy` report).
    analysis_flights: SingleFlight<RequestKey, CachedValue>,
    /// Fan-out jobs (analyses + native numeric sweeps) currently executing —
    /// divides the shard budget so that concurrent jobs inside `serve`
    /// share the machine instead of each fanning out to the full worker
    /// count (nested fan-out would run O(workers²) threads).
    active_fanout: AtomicUsize,
}

impl Coordinator {
    fn new_inner(config: PlannerConfig, runtime: Option<Arc<RuntimeHandle>>) -> Coordinator {
        // NUMA mode pins worker i to core i, so first-touch allocation
        // keeps each shard's blocks on the node of the worker that
        // computes them (the pinning also covers scoped fan-out threads).
        let pool = if config.numa {
            let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
            ThreadPool::new_pinned(n.saturating_sub(1).max(1))
        } else {
            ThreadPool::with_default_parallelism()
        };
        Coordinator {
            config,
            runtime,
            pool,
            metrics: Arc::new(Metrics::new()),
            memo: Some(Mutex::new(S3Fifo::with_capacity(DEFAULT_MEMO_BYTES))),
            plan_flights: SingleFlight::new(),
            analysis_flights: SingleFlight::new(),
            active_fanout: AtomicUsize::new(0),
        }
    }

    /// Standalone coordinator (no PJRT runtime attached): plans and
    /// simulations run as always, and Execute/Solve requests are served by
    /// the native numeric backend.
    pub fn analysis_only(config: PlannerConfig) -> Coordinator {
        Coordinator::new_inner(config, None)
    }

    /// Full coordinator with the PJRT runtime service attached; numeric
    /// requests whose shape has no artifact still fall back to the native
    /// backend.
    pub fn with_runtime(config: PlannerConfig, runtime: Arc<RuntimeHandle>) -> Coordinator {
        Coordinator::new_inner(config, Some(runtime))
    }

    /// Replace the memo tier: `Some(bytes)` installs a fresh S3-FIFO with
    /// that byte budget, `None` disables memoization. Existing cached
    /// entries are dropped either way.
    pub fn configure_memo(&mut self, capacity_bytes: Option<usize>) {
        self.memo = capacity_bytes.map(|b| Mutex::new(S3Fifo::with_capacity(b)));
    }

    /// Lock the memo index with poison recovery. A request that panics
    /// while holding this lock (caught at the serving boundary by
    /// `submit_caught`) poisons the mutex; `unwrap()` here would then brick
    /// every later request on the resident server. Recovering is always
    /// sound for the S3-FIFO: each critical section is a short sequence of
    /// index operations whose worst interrupted outcome is a stale or
    /// missing *cache* entry — recomputed on the next miss, never wrong.
    fn lock_memo(m: &Mutex<S3Fifo<RequestKey, CachedValue>>) -> MutexGuard<'_, S3Fifo<RequestKey, CachedValue>> {
        m.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Usage + counters of the memo tier (`None` when disabled).
    pub fn memo_snapshot(&self) -> Option<MemoSnapshot> {
        self.memo.as_ref().map(|m| Coordinator::lock_memo(m).snapshot())
    }

    fn memo_get(&self, key: &RequestKey) -> Option<CachedValue> {
        self.memo.as_ref().and_then(|m| Coordinator::lock_memo(m).get(key).cloned())
    }

    fn memo_put(&self, key: RequestKey, value: CachedValue) {
        if let Some(m) = &self.memo {
            let weight = entry_bytes(&key, &value);
            let evicted = Coordinator::lock_memo(m).insert(key, value, weight);
            if evicted > 0 {
                Metrics::bump(&self.metrics.memo_evictions, evicted);
            }
        }
    }

    /// Build the response for a memoized analysis entry, if resident.
    fn analysis_from_memo(&self, key: &RequestKey) -> Option<StencilResponse> {
        match self.memo_get(key) {
            Some(CachedValue::Analysis { plan, report }) => Some(StencilResponse {
                plan,
                miss_report: Some(report),
                result_norm: None,
                solve_log: Vec::new(),
                wall_micros: 0,
            }),
            _ => None,
        }
    }

    /// Record the request-level memo outcome for the *primary* artifact of
    /// a request (no-op on a non-memoizing coordinator).
    fn note_memo(&self, hit: bool) {
        if self.memo.is_none() {
            return;
        }
        if hit {
            Metrics::bump(&self.metrics.sim_memo_hits, 1);
        } else {
            Metrics::bump(&self.metrics.sim_memo_misses, 1);
        }
    }

    /// Resolve the plan for `req` through the memo tier. Returns the
    /// `Arc`-shared plan and whether it was a cache hit; on a miss the
    /// freshly computed plan is admitted under its canonical key.
    ///
    /// Concurrent misses on the same key are **single-flighted**: the first
    /// caller plans, everyone else blocks on the flight and shares the
    /// leader's `Arc<Plan>` (counted in `single_flight_collapsed`). This
    /// closes the duplicated-work window the memo tier alone leaves open —
    /// a burst of N identical cold requests used to run N lattice
    /// reductions.
    fn plan_for(&self, req: &StencilRequest, stencil: &Stencil) -> (Arc<Plan>, bool) {
        let key = RequestKey::plan_facet(&self.config, req);
        if let Some(CachedValue::Plan(p)) = self.memo_get(&key) {
            return (p, true);
        }
        loop {
            match self.plan_flights.join(&key) {
                Flight::Leader(token) => {
                    // Re-probe under leadership: the previous leader may
                    // have published between our miss and our join.
                    if let Some(CachedValue::Plan(p)) = self.memo_get(&key) {
                        token.complete(p.clone());
                        return (p, true);
                    }
                    let plan = Arc::new(plan(&self.config, &req.dims, stencil, req.rhs_arrays));
                    Metrics::bump(&self.metrics.planned, 1);
                    self.memo_put(key.clone(), CachedValue::Plan(plan.clone()));
                    token.complete(plan.clone());
                    return (plan, false);
                }
                Flight::Shared(p) => {
                    Metrics::bump(&self.metrics.single_flight_collapsed, 1);
                    return (p, false);
                }
                Flight::Retry => {
                    // leader panicked mid-plan; probe the memo and lead the
                    // next flight ourselves if it is still missing
                    if let Some(CachedValue::Plan(p)) = self.memo_get(&key) {
                        return (p, true);
                    }
                }
            }
        }
    }

    /// Register an in-flight fan-out job; returns the drop guard and this
    /// job's worker-share budget (≥ 1).
    fn enter_fanout(&self) -> (ActiveGuard<'_>, usize) {
        let active = self.active_fanout.fetch_add(1, Ordering::SeqCst) + 1;
        let budget = (self.pool.workers() / active).max(1);
        (ActiveGuard(&self.active_fanout), budget)
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    pub fn config(&self) -> &PlannerConfig {
        &self.config
    }

    /// Histogram index for a request kind (see [`LATENCY_KINDS`]);
    /// `None` for kinds without a latency series (fault injection).
    fn latency_index(kind: &JobKind) -> Option<usize> {
        match kind {
            JobKind::Plan => Some(0),
            JobKind::Analyze | JobKind::AnalyzeWith(_) => Some(1),
            JobKind::Execute => Some(2),
            JobKind::Solve { .. } => Some(3),
            JobKind::ChaosPanic => None,
        }
    }

    /// Handle one request synchronously.
    pub fn submit(&self, req: &StencilRequest) -> Result<StencilResponse> {
        Metrics::bump(&self.metrics.requests, 1);
        let t0 = Instant::now();
        let result = self.dispatch(req);
        let micros = t0.elapsed().as_micros() as u64;
        // errors are recorded too: a failing tail is still a tail
        if let Some(idx) = Coordinator::latency_index(&req.kind) {
            self.metrics.record_latency(idx, micros);
        }
        if result.is_err() {
            Metrics::bump(&self.metrics.failed, 1);
        }
        result.map(|mut r| {
            r.wall_micros = micros;
            r
        })
    }

    /// [`submit`](Coordinator::submit) with panic containment: a request
    /// that panics anywhere in dispatch (a worker bug, fault injection)
    /// unwinds to this boundary and becomes a per-request `Err` instead of
    /// aborting the process. This is the entry point every resident
    /// serving path (TCP front end, `serve` waves, open-loop replay) uses —
    /// one poisoned request must never take down the server.
    pub fn submit_caught(&self, req: &StencilRequest) -> Result<StencilResponse> {
        match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| self.submit(req))) {
            Ok(result) => result,
            Err(payload) => {
                Metrics::bump(&self.metrics.failed, 1);
                bail!("request panicked: {}", panic_message(payload.as_ref()))
            }
        }
    }

    /// Handle a slice of requests: batch by shape, run batches across the
    /// worker pool, return responses in submission order.
    pub fn serve(&self, reqs: &[StencilRequest]) -> Vec<Result<StencilResponse>> {
        let keys: Vec<BatchKey> = reqs.iter().map(|r| r.batch_key(&self.config)).collect();
        let batches = group_by_shape(&keys);
        // flatten batches into a worklist of request indices, batch-major
        // and heaviest-batch-first (see batcher::schedule): same-shape
        // requests run adjacently (cache-hot executables/orders) and the
        // pool's tail stays short on mixed workloads.
        let ordered = schedule(&batches);
        let outcomes = self.pool.scope_map(ordered.len(), |slot| {
            let idx = ordered[slot];
            // submit_caught: one panicking request in a wave answers as an
            // Err in its slot; its siblings still complete
            (idx, self.submit_caught(&reqs[idx]))
        });
        let mut slots: Vec<Option<Result<StencilResponse>>> = (0..reqs.len()).map(|_| None).collect();
        for (idx, resp) in outcomes {
            slots[idx] = Some(resp);
        }
        slots.into_iter().map(|s| s.expect("every request answered")).collect()
    }

    fn dispatch(&self, req: &StencilRequest) -> Result<StencilResponse> {
        // Fault injection first: the panic must exercise the *containment*
        // path (submit_caught / scope_map propagation), not the validators.
        if matches!(req.kind, JobKind::ChaosPanic) {
            panic!("chaos_panic: injected worker fault");
        }
        if req.dims.is_empty() || req.dims.iter().any(|&d| d == 0) {
            bail!("invalid dims {:?}", req.dims);
        }
        if req.rhs_arrays == 0 {
            bail!("rhs_arrays must be >= 1");
        }
        // `StencilSpec::build` asserts this; on the long-lived serving path
        // a malformed request must be a per-request Err, not a panic that
        // poisons the whole serve/drain wave.
        if req.stencil == StencilSpec::Star13 && req.dims.len() != 3 {
            bail!("star13 stencil requires 3-D dims, got {:?}", req.dims);
        }
        let stencil = req.stencil.build(req.dims.len());
        // An explicit traversal request needs no plan to form its analysis
        // key, so a resident entry skips the planner entirely (the plan
        // facet may have been evicted independently of the analysis). The
        // cold path below re-probes the same key once more — a duplicate
        // structural miss in the S3-FIFO counters, never in the
        // request-level sim_memo_* metrics.
        if let JobKind::AnalyzeWith(choice) = &req.kind {
            let key = RequestKey::analysis_facet(&self.config, req, *choice);
            if let Some(resp) = self.analysis_from_memo(&key) {
                self.note_memo(true);
                return Ok(resp);
            }
        }
        // The plan is always resolved through the memo tier: Plan requests
        // serve it directly, analyses embed it, numeric jobs reuse it for
        // traversal/shard choices (but always run the numerics).
        let (plan, plan_hit) = self.plan_for(req, &stencil);

        match &req.kind {
            JobKind::Plan => {
                self.note_memo(plan_hit);
                Ok(StencilResponse {
                    plan,
                    miss_report: None,
                    result_norm: None,
                    solve_log: Vec::new(),
                    wall_micros: 0,
                })
            }
            JobKind::Analyze => self.run_analysis(req, &stencil, plan, None),
            JobKind::AnalyzeWith(choice) => self.run_analysis(req, &stencil, plan, Some(*choice)),
            JobKind::Execute => {
                self.note_memo(plan_hit);
                self.run_numeric(req, &stencil, plan, None)
            }
            JobKind::Solve { steps } => {
                self.note_memo(plan_hit);
                self.run_numeric(req, &stencil, plan, Some(*steps))
            }
            JobKind::ChaosPanic => unreachable!("handled at dispatch entry"),
        }
    }

    fn run_analysis(
        &self,
        req: &StencilRequest,
        stencil: &Stencil,
        plan: Arc<Plan>,
        force: Option<TraversalChoice>,
    ) -> Result<StencilResponse> {
        let choice = force.unwrap_or(plan.traversal);
        // Canonical analysis key: `Analyze` ≡ `AnalyzeWith(plan.traversal)`,
        // so the default analysis and an explicit request for the
        // planner's own choice share one cache entry.
        let key = RequestKey::analysis_facet(&self.config, req, choice);
        if let Some(resp) = self.analysis_from_memo(&key) {
            self.note_memo(true);
            return Ok(resp);
        }
        self.note_memo(false);
        // Single-flight over the analysis key: concurrent identical misses
        // elect one leader to simulate; everyone else blocks on the flight
        // and shares the leader's value (`Arc<Plan>` bump + `Copy` report).
        let value = loop {
            match self.analysis_flights.join(&key) {
                Flight::Leader(token) => {
                    // re-probe under leadership: a previous leader may have
                    // published between our miss and our join
                    if let Some(v @ CachedValue::Analysis { .. }) = self.memo_get(&key) {
                        token.complete(v.clone());
                        break v;
                    }
                    let (report, admit) = self.compute_analysis(req, stencil, &plan, choice);
                    let v = CachedValue::Analysis { plan: plan.clone(), report };
                    if admit {
                        self.memo_put(key.clone(), v.clone());
                    }
                    token.complete(v.clone());
                    break v;
                }
                Flight::Shared(v) => {
                    Metrics::bump(&self.metrics.single_flight_collapsed, 1);
                    break v;
                }
                Flight::Retry => {
                    // the leader panicked mid-simulation; take over unless
                    // some other waiter already published
                    if let Some(v @ CachedValue::Analysis { .. }) = self.memo_get(&key) {
                        break v;
                    }
                }
            }
        };
        let CachedValue::Analysis { plan, report } = value else {
            unreachable!("analysis flights carry analysis values")
        };
        Ok(StencilResponse {
            plan,
            miss_report: Some(report),
            result_norm: None,
            solve_log: Vec::new(),
            wall_micros: 0,
        })
    }

    /// The actual cache simulation behind `run_analysis` (leader side of
    /// the flight). Returns the merged report and whether it may be
    /// admitted to the memo.
    fn compute_analysis(
        &self,
        req: &StencilRequest,
        stencil: &Stencil,
        plan: &Arc<Plan>,
        choice: TraversalChoice,
    ) -> (MissReport, bool) {
        let grid = GridDesc::with_padding(&plan.dims, &plan.pad);
        // The hot path is a lazy stream: nothing proportional to the grid
        // is materialized, so Analyze scales to 512³+ grids whose packed
        // visit sequence would not fit in memory.
        let order = planner::build_traversal(&self.config, &grid, stencil, choice);
        let layout = MultiArrayLayout::paper_offsets(&grid, req.rhs_arrays, self.config.machine.l1.size_words());
        // Fan big jobs out across pencil shards. The budget is the
        // planner's recommendation clamped to this job's *share* of the
        // worker pool: `scope_map` spawns fresh scoped threads per call, so
        // N concurrent analyses each sharding to the full pool would run
        // O(workers²) simulator threads. Dividing by the number of
        // in-flight fan-out jobs keeps total fan-out ≈ the worker count;
        // small jobs (or saturated pools) run the exact sequential sim.
        let (_guard, budget) = self.enter_fanout();
        let shards = plan.shards.min(budget);
        // Shard-boundary cold misses make a merged sharded report a
        // function of the *effective* shard count, which concurrent
        // fan-out load can clamp below the plan's recommendation. The memo
        // must serve what a quiet recompute would produce, so reports are
        // admitted only when computed at the quiet-coordinator count.
        let quiet_shards = plan.shards.min(self.pool.workers());
        let machine = &self.config.machine;
        let report = if shards > 1 && order.num_pencils() > 1 {
            let ran = traversal::shard_ranges(order.num_pencils(), shards).len() as u64;
            Metrics::bump(&self.metrics.sharded_analyses, 1);
            Metrics::bump(&self.metrics.shards_executed, ran);
            engine::simulate_sharded(order.as_ref(), &layout, stencil, machine, &self.pool, shards)
        } else {
            engine::simulate_on_machine(order.as_ref(), &layout, stencil, machine)
        };
        Metrics::bump(&self.metrics.analyzed, 1);
        Metrics::bump(&self.metrics.points_processed, report.points);
        Metrics::bump(&self.metrics.sim_accesses, report.total.accesses);
        Metrics::bump(&self.metrics.sim_misses, report.total.misses());
        if let Some(l2) = report.levels.get(Level::L2) {
            Metrics::bump(&self.metrics.sim_l2_misses, l2.misses());
        }
        if let Some(tlb) = report.levels.get(Level::Tlb) {
            Metrics::bump(&self.metrics.sim_tlb_misses, tlb.misses());
        }
        Metrics::bump(&self.metrics.sim_stall_cycles, report.levels.stall_cycles(machine.latency));
        (report, shards == quiet_shards)
    }

    /// Serve a numeric job (`Execute` when `steps` is None, `Solve`
    /// otherwise) on the best available backend: the PJRT artifact path
    /// when a runtime is attached *and* the shape has a matching artifact,
    /// the native engine sweep otherwise. The native sweep reuses the
    /// plan's traversal choice and shard recommendation, so the numeric
    /// path walks the grid exactly as the analysis path predicted.
    ///
    /// Determinism note: the result field is bitwise shard-invariant, but
    /// norm reductions sum in chunk order, so their last bits depend on the
    /// *effective* shard count — which `enter_fanout` may clamp below the
    /// plan's recommendation while other fan-out jobs are in flight.
    /// Sequential submissions are exactly reproducible; record
    /// EXPERIMENTS.md numbers from a quiet coordinator.
    fn run_numeric(
        &self,
        req: &StencilRequest,
        stencil: &Stencil,
        plan: Arc<Plan>,
        steps: Option<usize>,
    ) -> Result<StencilResponse> {
        // Block-decomposed native path (DESIGN.md §2.9): an explicit
        // shard-grid override or an out-of-core verdict routes Solve
        // through the shard/halo layer — per-shard blocks (disk tiles when
        // out-of-core), typed HaloMsg exchange, measured halo traffic in
        // the metrics. Execute jobs and default in-memory solves keep the
        // temporal fast path below; PJRT cannot honor a RAM budget or a
        // shard grid, so the explicit request wins over artifacts.
        if let Some(n) = steps {
            if self.config.shard_grid.is_some() || plan.out_of_core {
                return self.run_decomposed_solve(req, stencil, plan, n);
            }
        }
        let grid = GridDesc::with_padding(&plan.dims, &plan.pad);
        let seed: u64 = if steps.is_some() { 0xBEEF } else { 0xC0FFEE };
        let prefix = if steps.is_some() { "step_norms_" } else { "star13_" };
        // The AOT artifacts compute the 13-point star specifically, so the
        // PJRT path is eligible only for Star13 requests whose shape has an
        // artifact; every other stencil runs natively (the engine handles
        // arbitrary stencils).
        let pjrt = self.runtime.as_ref().filter(|_| req.stencil == StencilSpec::Star13).cloned();
        let pjrt = pjrt.filter(|rt| rt.manifest().find_for_shape(prefix, &req.dims).is_some());

        let (order, shards, _guard) = if pjrt.is_some() {
            // the artifact encodes its own loop nest; a cheap placeholder
            // traversal satisfies the job shape
            (Box::new(traversal::natural_stream(&grid, stencil.radius())) as Box<dyn Traversal>, 1, None)
        } else {
            let order = planner::build_traversal(&self.config, &grid, stencil, plan.traversal);
            // native sweeps fan out like analyses: share the pool
            let (guard, budget) = self.enter_fanout();
            (order, plan.shards.min(budget), Some(guard))
        };
        let backend: Box<dyn NumericBackend + '_> = match pjrt {
            Some(rt) => Box::new(PjrtBackend::new(rt)),
            // native sweeps run the row kernel with the plan's prefetch
            // distance (0 on machines whose latency model has no prefetch
            // term — then the kernel issues no prefetch at all)
            None => Box::new(NativeBackend::with_kernel(
                &self.pool,
                engine::KernelCfg { strict: false, prefetch: plan.prefetch_distance },
            )),
        };
        // Temporal traversal for native Solve jobs (DESIGN.md §2.6): tile
        // depth and shape from the plan. With k = 1 the *fused* single-pass
        // update still replaces the classic apply + axpy two-sweep loop
        // (no q traffic, one sweep), tiled along the last dim so shards
        // keep their parallelism; Execute and PJRT jobs stay classic.
        let temporal = if steps.is_some() && pjrt.is_none() && grid.ndim() <= traversal::MAX_STREAM_DIMS {
            let r = stencil.radius();
            let tile = if plan.time_tile > 1 {
                plan.time_tile_dims.clone()
            } else {
                let mut t: Vec<usize> = grid.dims().iter().map(|&n| n.saturating_sub(2 * r).max(1)).collect();
                let last = t.len() - 1;
                t[last] = t[last].div_ceil(shards.max(1));
                t
            };
            Some(traversal::temporal_stream(&grid, r, &tile, plan.time_tile))
        } else {
            None
        };
        let job = NumericJob {
            dims: &req.dims,
            grid: &grid,
            stencil,
            traversal: order.as_ref(),
            shards,
            seed,
            temporal: temporal.as_ref(),
        };
        let out = match steps {
            Some(n) => backend.solve(&job, n)?,
            None => backend.execute(&job)?,
        };
        if backend.name() == "pjrt" {
            Metrics::bump(&self.metrics.pjrt_executions, out.executions);
            Metrics::bump(&self.metrics.pjrt_micros, out.micros);
        } else {
            Metrics::bump(&self.metrics.native_executions, out.executions);
            Metrics::bump(&self.metrics.native_micros, out.micros);
        }
        Metrics::bump(&self.metrics.executed, 1);
        // PJRT artifacts compute every grid point (zero-halo everywhere);
        // the native sweep computes the K-interior — count what actually
        // ran, matching run_analysis's interior-points semantics.
        let points_per_exec = if backend.name() == "pjrt" { grid.num_points() } else { order.num_points() };
        Metrics::bump(&self.metrics.points_processed, points_per_exec * out.executions);
        Ok(StencilResponse {
            plan,
            miss_report: None,
            result_norm: Some(out.result_norm),
            solve_log: out.solve_log,
            wall_micros: 0,
        })
    }

    /// Solve via the block-decomposed shard/halo layer (DESIGN.md §2.9):
    /// the plan's shard grid cuts the *logical* grid into axis-aligned
    /// blocks that communicate only through typed `HaloMsg`s; out-of-core
    /// plans stream the blocks through disk tiles under the configured RAM
    /// budget. Results are bitwise-identical to the classic native Solve
    /// for star stencils — each interior row runs the same
    /// `engine::kernel::update_row` (same `KernelCfg`) over the same
    /// operand values, and only the norm reductions re-associate.
    fn run_decomposed_solve(
        &self,
        req: &StencilRequest,
        stencil: &Stencil,
        plan: Arc<Plan>,
        steps: usize,
    ) -> Result<StencilResponse> {
        // Padding is a cache-interference remedy for monolithic sweeps;
        // per-shard blocks are fresh, small allocations, so the decomposed
        // path always runs on the unpadded dims.
        let grid = GridDesc::new(&req.dims);
        let order = traversal::natural_stream(&grid, stencil.radius());
        let (_guard, _budget) = self.enter_fanout();
        let storage = if plan.out_of_core {
            crate::shard::ShardStorage::temp()
        } else {
            crate::shard::ShardStorage::InMemory
        };
        let backend = NativeBackend::with_kernel(
            &self.pool,
            engine::KernelCfg { strict: false, prefetch: plan.prefetch_distance },
        );
        let job = NumericJob {
            dims: &req.dims,
            grid: &grid,
            stencil,
            traversal: &order,
            shards: plan.shard_grid.iter().product(),
            seed: 0xBEEF,
            temporal: None,
        };
        let out = backend.solve_decomposed(
            &job,
            steps,
            &plan.shard_grid,
            &storage,
            self.config.ram_budget_words,
            plan.shard_time_tile,
        )?;
        Metrics::bump(&self.metrics.native_executions, out.executions);
        Metrics::bump(&self.metrics.native_micros, out.micros);
        Metrics::bump(&self.metrics.halo_words_loaded, out.halo_words_loaded);
        Metrics::bump(&self.metrics.halo_exchanges, out.halo_exchanges);
        Metrics::bump(&self.metrics.halo_redundant_words, out.halo_redundant_words);
        Metrics::bump(&self.metrics.executed, 1);
        Metrics::bump(&self.metrics.points_processed, order.num_points() * out.executions);
        Ok(StencilResponse {
            plan,
            miss_report: None,
            result_norm: Some(out.result_norm),
            solve_log: out.solve_log,
            wall_micros: 0,
        })
    }

    /// Snapshot the metrics as JSON text (memo-tier usage included when
    /// memoization is enabled).
    pub fn metrics_json(&self) -> String {
        self.metrics_json_value().to_pretty()
    }

    /// [`metrics_json`](Coordinator::metrics_json) as a structured value —
    /// the wire front end embeds it in `metrics` responses.
    pub fn metrics_json_value(&self) -> Json {
        let mut j = self.metrics.snapshot();
        j.set("pool_workers", self.pool.workers());
        if let Some(s) = self.memo_snapshot() {
            j.set("memo_entries", s.entries as u64)
                .set("memo_bytes", s.weight as u64)
                .set("memo_capacity_bytes", s.capacity as u64)
                .set("memo_ghost_keys", s.ghost_keys as u64)
                .set("memo_small_hits", s.counters.small_hits)
                .set("memo_main_hits", s.counters.main_hits)
                .set("memo_ghost_readmits", s.counters.ghost_readmits);
        }
        if let Some(rt) = &self.runtime {
            j.set("cached_executables", rt.cached_executables());
            j.set("platform", rt.platform());
        }
        j
    }
}

/// Best-effort text of a panic payload (`&str` and `String` panics cover
/// everything this codebase raises).
fn panic_message(payload: &(dyn std::any::Any + Send)) -> &str {
    if let Some(s) = payload.downcast_ref::<&str>() {
        s
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s
    } else {
        "non-string panic payload"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::Ordering;

    fn coord() -> Coordinator {
        Coordinator::analysis_only(PlannerConfig::default())
    }

    #[test]
    fn plan_job_returns_plan_only() {
        let c = coord();
        let req = StencilRequest {
            dims: vec![45, 91, 100],
            stencil: StencilSpec::Star13,
            rhs_arrays: 1,
            kind: JobKind::Plan,
        };
        let resp = c.submit(&req).unwrap();
        assert!(resp.plan.was_unfavorable);
        assert!(resp.miss_report.is_none());
        assert_eq!(c.metrics.planned.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn analyze_small_grid() {
        let c = coord();
        let req = StencilRequest::analyze(&[20, 20, 20]);
        let resp = c.submit(&req).unwrap();
        let rep = resp.miss_report.unwrap();
        assert_eq!(rep.points, 16 * 16 * 16);
        assert!(rep.total.misses() > 0);
    }

    #[test]
    fn forced_traversals_differ_on_conflicting_grid() {
        // Grid engineered to conflict: storage rows collide every 4 columns
        // (n1·n2 = 2048·… use a small cache to keep runtime down).
        let config = PlannerConfig {
            machine: crate::cache::MachineModel::l1_only(crate::cache::CacheParams::new(1, 64, 1)),
            max_pad: 0,
            auto_pad: false,
            ..PlannerConfig::default()
        };
        let c = Coordinator::analysis_only(config);
        let mk = |kind| StencilRequest {
            dims: vec![60, 32],
            stencil: StencilSpec::Star { r: 1 },
            rhs_arrays: 1,
            kind,
        };
        let nat = c.submit(&mk(JobKind::AnalyzeWith(TraversalChoice::Natural))).unwrap();
        let fit = c.submit(&mk(JobKind::AnalyzeWith(TraversalChoice::CacheFitting))).unwrap();
        let (nm, fm) = (
            nat.miss_report.unwrap().total.replacement_misses,
            fit.miss_report.unwrap().total.replacement_misses,
        );
        assert!(fm < nm, "fitting {fm} !< natural {nm}");
    }

    #[test]
    fn analyze_on_full_machine_reports_per_level_loads() {
        use crate::cache::{Level, MachineModel};
        let config = PlannerConfig { machine: MachineModel::r10000_full(), ..PlannerConfig::default() };
        let c = Coordinator::analysis_only(config);
        let resp = c.submit(&StencilRequest::analyze(&[20, 20, 20])).unwrap();
        let rep = resp.miss_report.unwrap();
        assert_eq!(rep.levels.levels().len(), 3);
        let l1 = rep.levels.get(Level::L1).unwrap();
        let l2 = rep.levels.get(Level::L2).unwrap();
        let tlb = rep.levels.get(Level::Tlb).unwrap();
        assert_eq!(l1, rep.total);
        assert_eq!(l2.accesses, l1.misses());
        assert_eq!(tlb.accesses, l1.accesses);
        // the L1-level numbers are bit-identical to a single-level run
        let single = coord().submit(&StencilRequest::analyze(&[20, 20, 20])).unwrap();
        assert_eq!(single.miss_report.unwrap().total, rep.total);
        // per-level metrics flow
        assert!(c.metrics.sim_stall_cycles.load(Ordering::Relaxed) > 0);
        assert!(c.metrics.sim_tlb_misses.load(Ordering::Relaxed) > 0);
        let j = c.metrics_json();
        assert!(j.contains("sim_tlb_misses"));
    }

    #[test]
    fn invalid_requests_rejected() {
        let c = coord();
        let bad_dims = StencilRequest { dims: vec![], stencil: StencilSpec::Star { r: 1 }, rhs_arrays: 1, kind: JobKind::Plan };
        assert!(c.submit(&bad_dims).is_err());
        let zero_dim = StencilRequest { dims: vec![0, 4], stencil: StencilSpec::Star { r: 1 }, rhs_arrays: 1, kind: JobKind::Plan };
        assert!(c.submit(&zero_dim).is_err());
        let no_rhs = StencilRequest { dims: vec![8, 8], stencil: StencilSpec::Star { r: 1 }, rhs_arrays: 0, kind: JobKind::Plan };
        assert!(c.submit(&no_rhs).is_err());
        // star13 off its 3-D home must be a per-request error, not a panic
        // that would poison a whole serve wave on the long-lived service
        let star13_2d =
            StencilRequest { dims: vec![16, 16], stencil: StencilSpec::Star13, rhs_arrays: 1, kind: JobKind::Plan };
        assert!(c.submit(&star13_2d).is_err());
        assert_eq!(c.metrics.failed.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn execute_without_runtime_falls_back_to_native() {
        let c = coord();
        let req = StencilRequest {
            dims: vec![16, 16, 16],
            stencil: StencilSpec::Star13,
            rhs_arrays: 1,
            kind: JobKind::Execute,
        };
        let resp = c.submit(&req).expect("native execute");
        assert!(resp.result_norm.unwrap() > 0.0);
        assert!(resp.solve_log.is_empty());
        assert_eq!(c.metrics.native_executions.load(Ordering::Relaxed), 1);
        assert_eq!(c.metrics.pjrt_executions.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn solve_without_runtime_runs_natively_and_dissipates() {
        let c = coord();
        let req = StencilRequest {
            dims: vec![20, 20, 20],
            stencil: StencilSpec::Star13,
            rhs_arrays: 1,
            kind: JobKind::Solve { steps: 6 },
        };
        let resp = c.submit(&req).expect("native solve");
        assert_eq!(resp.solve_log.len(), 6);
        for w in resp.solve_log.windows(2) {
            assert!(w[1].u_norm <= w[0].u_norm * 1.0001, "{w:?}");
        }
        assert_eq!(resp.result_norm.unwrap(), resp.solve_log.last().unwrap().u_norm);
        assert_eq!(c.metrics.native_executions.load(Ordering::Relaxed), 6);
        assert_eq!(c.metrics.executed.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn decomposed_solve_matches_default_solve_and_counts_halo() {
        let mk = |kind| StencilRequest {
            dims: vec![20, 18, 16],
            stencil: StencilSpec::Star { r: 2 },
            rhs_arrays: 1,
            kind,
        };
        let base = coord().submit(&mk(JobKind::Solve { steps: 4 })).unwrap();
        // time_tile pinned to 1: this test pins the *classic*
        // exchange-every-step accounting (the superstep path has its own
        // rounds-based test below)
        let config =
            PlannerConfig { shard_grid: Some(vec![2, 1, 2]), time_tile: Some(1), ..PlannerConfig::default() };
        let c = Coordinator::analysis_only(config);
        let dec = c.submit(&mk(JobKind::Solve { steps: 4 })).unwrap();
        assert_eq!(dec.plan.shard_grid, vec![2, 1, 2]);
        assert_eq!(dec.plan.shard_time_tile, 1);
        assert_eq!(dec.solve_log.len(), 4);
        // same field, re-associated norm reductions
        for (a, b) in base.solve_log.iter().zip(&dec.solve_log) {
            assert!((a.u_norm - b.u_norm).abs() < 1e-9 * (1.0 + a.u_norm), "{} vs {}", a.u_norm, b.u_norm);
            assert!((a.residual_norm - b.residual_norm).abs() < 1e-9 * (1.0 + a.residual_norm));
        }
        // measured halo traffic is exact: steps × the plan's ghost words
        let sp = crate::shard::ShardPlan::new(&[20, 18, 16], &[2, 1, 2], 2);
        assert_eq!(c.metrics.halo_words_loaded.load(Ordering::Relaxed), 4 * sp.halo_words());
        assert!(c.metrics.halo_exchanges.load(Ordering::Relaxed) > 0);
        assert_eq!(c.metrics.native_executions.load(Ordering::Relaxed), 4);
        // classic depth: nothing is recomputed redundantly
        assert_eq!(c.metrics.halo_redundant_words.load(Ordering::Relaxed), 0);
        let j = c.metrics_json();
        assert!(j.contains("halo_words_loaded"));
        assert!(j.contains("halo_exchanges"));
    }

    #[test]
    fn decomposed_temporal_solve_matches_and_amortizes_exchange_rounds() {
        let mk = |kind| StencilRequest {
            dims: vec![20, 18, 16],
            stencil: StencilSpec::Star { r: 2 },
            rhs_arrays: 1,
            kind,
        };
        let base = coord().submit(&mk(JobKind::Solve { steps: 5 })).unwrap();
        let config =
            PlannerConfig { shard_grid: Some(vec![2, 1, 2]), time_tile: Some(2), ..PlannerConfig::default() };
        let c = Coordinator::analysis_only(config);
        let deep = c.submit(&mk(JobKind::Solve { steps: 5 })).unwrap();
        assert_eq!(deep.plan.shard_time_tile, 2);
        assert_eq!(deep.solve_log.len(), 5);
        // same field as the monolithic solve, re-associated reductions
        for (a, b) in base.solve_log.iter().zip(&deep.solve_log) {
            assert!((a.u_norm - b.u_norm).abs() < 1e-9 * (1.0 + a.u_norm), "{} vs {}", a.u_norm, b.u_norm);
            assert!((a.residual_norm - b.residual_norm).abs() < 1e-9 * (1.0 + a.residual_norm));
        }
        // 5 steps at k = 2 → ⌈5/2⌉ = 3 exchange rounds of the deep halo,
        // and the ghost rind recompute shows up in its own counter
        let sp = crate::shard::ShardPlan::with_depth(&[20, 18, 16], &[2, 1, 2], 2, 2);
        assert_eq!(c.metrics.halo_words_loaded.load(Ordering::Relaxed), 3 * sp.halo_words());
        assert!(c.metrics.halo_redundant_words.load(Ordering::Relaxed) > 0);
        assert!(c.metrics_json().contains("halo_redundant_words"));
    }

    #[test]
    fn ram_budget_routes_solve_out_of_core() {
        let req = StencilRequest {
            dims: vec![16, 16, 16],
            stencil: StencilSpec::Star { r: 1 },
            rhs_arrays: 1,
            kind: JobKind::Solve { steps: 3 },
        };
        // 2 × 16³ = 8192 working words > 6000 ⇒ the planner flips the job
        // out-of-core and refines the shard grid under the budget.
        let config = PlannerConfig { ram_budget_words: Some(6_000), ..PlannerConfig::default() };
        let c = Coordinator::analysis_only(config);
        let ooc = c.submit(&req).unwrap();
        assert!(ooc.plan.out_of_core);
        assert!(ooc.plan.shard_grid.iter().product::<usize>() > 1);
        let base = coord().submit(&req).unwrap();
        for (a, b) in base.solve_log.iter().zip(&ooc.solve_log) {
            assert!((a.u_norm - b.u_norm).abs() < 1e-9 * (1.0 + a.u_norm), "{} vs {}", a.u_norm, b.u_norm);
            assert!((a.residual_norm - b.residual_norm).abs() < 1e-9 * (1.0 + a.residual_norm));
        }
        assert!(c.metrics.halo_words_loaded.load(Ordering::Relaxed) > 0);
    }

    #[test]
    fn native_solve_deterministic_across_submissions() {
        let c = coord();
        let mk = || StencilRequest {
            dims: vec![18, 16, 14],
            stencil: StencilSpec::Star { r: 1 },
            rhs_arrays: 1,
            kind: JobKind::Solve { steps: 4 },
        };
        let a = c.submit(&mk()).unwrap();
        let b = c.submit(&mk()).unwrap();
        for (x, y) in a.solve_log.iter().zip(&b.solve_log) {
            assert_eq!(x.u_norm, y.u_norm);
            assert_eq!(x.residual_norm, y.residual_norm);
        }
    }

    #[test]
    fn serve_preserves_order_and_batches() {
        let c = coord();
        let reqs: Vec<StencilRequest> = [16usize, 20, 16, 24, 20, 16]
            .iter()
            .map(|&n| StencilRequest::analyze(&[n, n, n]))
            .collect();
        let resps = c.serve(&reqs);
        assert_eq!(resps.len(), 6);
        for (req, resp) in reqs.iter().zip(&resps) {
            let resp = resp.as_ref().unwrap();
            assert_eq!(resp.plan.dims, req.dims);
        }
        assert_eq!(c.metrics.requests.load(Ordering::Relaxed), 6);
    }

    #[test]
    fn metrics_json_renders() {
        let c = coord();
        let _ = c.submit(&StencilRequest::analyze(&[12, 12, 12]));
        let j = c.metrics_json();
        assert!(j.contains("sim_accesses"));
        assert!(j.contains("sharded_analyses"));
        assert!(j.contains("pool_workers"));
        // memo tier counters are part of the snapshot
        assert!(j.contains("sim_memo_hits"));
        assert!(j.contains("sim_memo_misses"));
        assert!(j.contains("memo_evictions"));
        assert!(j.contains("memo_entries"));
    }

    #[test]
    fn repeated_analyze_served_from_memo() {
        let c = coord();
        let req = StencilRequest::analyze(&[20, 20, 20]);
        let cold = c.submit(&req).unwrap();
        let accesses_after_cold = c.metrics.sim_accesses.load(Ordering::Relaxed);
        let warm = c.submit(&req).unwrap();
        // second submission recomputed nothing...
        assert_eq!(c.metrics.analyzed.load(Ordering::Relaxed), 1);
        assert_eq!(c.metrics.sim_accesses.load(Ordering::Relaxed), accesses_after_cold);
        assert_eq!(c.metrics.sim_memo_hits.load(Ordering::Relaxed), 1);
        assert_eq!(c.metrics.sim_memo_misses.load(Ordering::Relaxed), 1);
        // ...and the served report is the cold one, bit for bit
        let (a, b) = (cold.miss_report.unwrap(), warm.miss_report.unwrap());
        assert_eq!(a.points, b.points);
        assert_eq!(a.total, b.total);
        assert_eq!((a.u_loads, a.u_misses), (b.u_loads, b.u_misses));
        assert_eq!(a.levels, b.levels);
        // the plan is Arc-shared between the cached entry and the response
        assert!(Arc::ptr_eq(&cold.plan, &warm.plan));
    }

    #[test]
    fn solve_reuses_cached_plan_but_reruns_numerics() {
        let c = coord();
        let mk = || StencilRequest {
            dims: vec![16, 16, 16],
            stencil: StencilSpec::Star13,
            rhs_arrays: 1,
            kind: JobKind::Solve { steps: 3 },
        };
        let a = c.submit(&mk()).unwrap();
        let b = c.submit(&mk()).unwrap();
        // one plan computation, two full numeric runs
        assert_eq!(c.metrics.planned.load(Ordering::Relaxed), 1);
        assert_eq!(c.metrics.sim_memo_hits.load(Ordering::Relaxed), 1);
        assert_eq!(c.metrics.native_executions.load(Ordering::Relaxed), 6);
        assert_eq!(a.result_norm.unwrap(), b.result_norm.unwrap());
    }

    #[test]
    fn analyze_canonicalizes_to_planner_choice() {
        let c = coord();
        let dims = vec![20, 20, 20];
        let plan_resp = c
            .submit(&StencilRequest { dims: dims.clone(), stencil: StencilSpec::Star13, rhs_arrays: 1, kind: JobKind::Plan })
            .unwrap();
        // default Analyze, then an explicit request for the planner's own
        // choice: one computation, one hit
        let _ = c.submit(&StencilRequest::analyze(&dims)).unwrap();
        let _ = c
            .submit(&StencilRequest {
                dims: dims.clone(),
                stencil: StencilSpec::Star13,
                rhs_arrays: 1,
                kind: JobKind::AnalyzeWith(plan_resp.plan.traversal),
            })
            .unwrap();
        assert_eq!(c.metrics.analyzed.load(Ordering::Relaxed), 1);
        // star13 ≡ star(r = 2): same canonical key, so this hits too
        let star2 = StencilRequest { dims, stencil: StencilSpec::Star { r: 2 }, rhs_arrays: 1, kind: JobKind::Analyze };
        let _ = c.submit(&star2).unwrap();
        assert_eq!(c.metrics.analyzed.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn forced_off_planner_traversal_is_a_distinct_entry() {
        let c = coord();
        let dims = vec![20, 20, 20]; // planner picks Natural here
        let _ = c.submit(&StencilRequest::analyze(&dims)).unwrap();
        let _ = c
            .submit(&StencilRequest {
                dims,
                stencil: StencilSpec::Star13,
                rhs_arrays: 1,
                kind: JobKind::AnalyzeWith(TraversalChoice::CacheFitting),
            })
            .unwrap();
        assert_eq!(c.metrics.analyzed.load(Ordering::Relaxed), 2, "different traversal ⇒ different analysis");
    }

    #[test]
    fn memo_can_be_disabled() {
        let mut c = coord();
        c.configure_memo(None);
        let req = StencilRequest::analyze(&[16, 16, 16]);
        let _ = c.submit(&req).unwrap();
        let _ = c.submit(&req).unwrap();
        assert_eq!(c.metrics.analyzed.load(Ordering::Relaxed), 2);
        assert_eq!(c.metrics.sim_memo_hits.load(Ordering::Relaxed), 0);
        assert_eq!(c.metrics.sim_memo_misses.load(Ordering::Relaxed), 0);
        assert!(c.memo_snapshot().is_none());
        assert!(!c.metrics_json().contains("memo_entries"));
    }

    #[test]
    fn different_rhs_counts_do_not_share_entries() {
        let c = coord();
        let mk = |rhs| StencilRequest {
            dims: vec![16, 16, 16],
            stencil: StencilSpec::Star13,
            rhs_arrays: rhs,
            kind: JobKind::Plan,
        };
        let one = c.submit(&mk(1)).unwrap();
        let four = c.submit(&mk(4)).unwrap();
        assert_eq!(c.metrics.planned.load(Ordering::Relaxed), 2);
        // p = 4 shrinks the natural-order window (see planner tests)
        assert_ne!(one.plan.traversal, four.plan.traversal);
    }

    #[test]
    fn small_analyses_stay_sequential_and_exact() {
        // below the shard grain the coordinator must run the exact
        // sequential simulation (shard counters untouched)
        let c = coord();
        let resp = c.submit(&StencilRequest::analyze(&[20, 20, 20])).unwrap();
        assert_eq!(resp.plan.shards, 1);
        assert!(resp.miss_report.is_some());
        assert_eq!(c.metrics.sharded_analyses.load(Ordering::Relaxed), 0);
        assert_eq!(c.metrics.shards_executed.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn chaos_panic_is_contained_and_service_continues() {
        let c = coord();
        let req = StencilRequest::analyze(&[16, 16, 16]);
        let _ = c.submit(&req).unwrap();
        let chaos = StencilRequest {
            dims: vec![4, 4, 4],
            stencil: StencilSpec::Star { r: 1 },
            rhs_arrays: 1,
            kind: JobKind::ChaosPanic,
        };
        let err = c.submit_caught(&chaos).expect_err("chaos must fail");
        assert!(err.to_string().contains("panicked"), "{err}");
        assert!(err.to_string().contains("chaos_panic"), "{err}");
        // regression: the coordinator keeps serving — memo hits still flow
        let again = c.submit(&req).unwrap();
        assert!(again.miss_report.is_some());
        assert!(c.metrics.sim_memo_hits.load(Ordering::Relaxed) >= 1);
        assert!(c.metrics.failed.load(Ordering::Relaxed) >= 1);
    }

    #[test]
    fn serve_wave_survives_one_panicking_request() {
        let c = coord();
        let chaos = StencilRequest {
            dims: vec![4, 4, 4],
            stencil: StencilSpec::Star { r: 1 },
            rhs_arrays: 1,
            kind: JobKind::ChaosPanic,
        };
        let reqs = vec![StencilRequest::analyze(&[16, 16, 16]), chaos, StencilRequest::analyze(&[20, 20, 20])];
        let out = c.serve(&reqs);
        assert_eq!(out.len(), 3);
        assert!(out[0].is_ok(), "healthy request before the panic must succeed");
        assert!(out[1].is_err(), "the poisoned request answers as an Err");
        assert!(out[2].is_ok(), "healthy request after the panic must succeed");
        // and the same coordinator serves the next wave too
        let next = c.serve(&[StencilRequest::analyze(&[16, 16, 16])]);
        assert!(next[0].is_ok());
    }

    #[test]
    fn memo_survives_a_poisoned_lock() {
        let c = coord();
        let req = StencilRequest::analyze(&[16, 16, 16]);
        let _ = c.submit(&req).unwrap();
        // poison the memo mutex the way a mid-request panic would
        let m = c.memo.as_ref().unwrap();
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _guard = m.lock().unwrap();
            panic!("poison the memo lock");
        }));
        assert!(m.lock().is_err(), "the mutex is genuinely poisoned");
        // regression: lock recovery keeps the memo (and the service) alive
        let warm = c.submit(&req).unwrap();
        assert!(warm.miss_report.is_some());
        assert!(c.metrics.sim_memo_hits.load(Ordering::Relaxed) >= 2);
        assert!(c.memo_snapshot().is_some());
    }

    #[test]
    fn latency_histograms_record_per_kind() {
        let c = coord();
        let _ = c.submit(&StencilRequest::analyze(&[12, 12, 12])).unwrap();
        let _ = c.submit(&StencilRequest {
            dims: vec![12, 12, 12],
            stencil: StencilSpec::Star13,
            rhs_arrays: 1,
            kind: JobKind::Plan,
        });
        assert_eq!(c.metrics.latency[0].count(), 1, "plan series");
        assert_eq!(c.metrics.latency[1].count(), 1, "analyze series");
        assert_eq!(c.metrics.latency[2].count(), 0, "execute untouched");
        let j = c.metrics_json();
        assert!(j.contains("latency_us"));
        assert!(j.contains("p999_us"));
        assert!(j.contains("single_flight_collapsed"));
    }

    #[test]
    fn deterministic_input_is_deterministic() {
        let a = deterministic_input(&[4, 4, 4], 1);
        let b = deterministic_input(&[4, 4, 4], 1);
        assert_eq!(a, b);
        let c2 = deterministic_input(&[4, 4, 4], 2);
        assert_ne!(a, c2);
    }
}
