//! Shape-keyed batching.
//!
//! Numeric jobs are served by AOT-compiled executables keyed on the input
//! shape; grouping same-shape requests amortizes executable lookup and
//! keeps the PJRT compile cache hot, and analysis jobs that share
//! (dims, stencil, cache) can share one traversal order — generating the
//! cache-fitting order is O(N log N) and dominates small analyses.

use super::StencilSpec;
use crate::cache::MachineModel;
use std::collections::HashMap;

/// A batch: the shared shape key plus the indices of the member requests
/// (into the original submission order).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Batch {
    pub key: BatchKey,
    pub members: Vec<usize>,
}

/// Requests batch together iff kind, dims, stencil, **and** the machine
/// they are analyzed against all agree — the sharing contract stated
/// above: analysis jobs may share a traversal only when
/// `(dims, stencil, cache)` agree, and numeric jobs may share an
/// executable only for the same stencil shape. (An earlier version keyed
/// on `(kind, dims)` alone, wrongly batching star13 with star(r=1)
/// requests on the same grid.)
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct BatchKey {
    pub kind: &'static str,
    pub dims: Vec<usize>,
    pub stencil: StencilSpec,
    pub machine: MachineModel,
}

/// Group request indices by key, preserving first-seen batch order and
/// submission order within each batch (fairness: no request starves).
pub fn group_by_shape(keys: &[BatchKey]) -> Vec<Batch> {
    let mut index: HashMap<&BatchKey, usize> = HashMap::new();
    let mut batches: Vec<Batch> = Vec::new();
    for (i, k) in keys.iter().enumerate() {
        match index.get(k) {
            Some(&b) => batches[b].members.push(i),
            None => {
                index.insert(k, batches.len());
                batches.push(Batch { key: k.clone(), members: vec![i] });
            }
        }
    }
    batches
}

/// Flatten batches into a worklist of request indices for the worker pool.
///
/// Batch-major so same-shape requests run adjacently (cache-hot
/// executables and shared traversal geometry), with batches ordered by
/// descending estimated weight — longest-processing-time-first keeps the
/// pool's tail short when one giant shape batch dominates a mixed
/// workload. Weight = member count × grid volume. Within a batch,
/// submission order is preserved; response slots are re-mapped by the
/// caller, so this ordering never changes observable results.
pub fn schedule(batches: &[Batch]) -> Vec<usize> {
    let mut order: Vec<&Batch> = batches.iter().collect();
    order.sort_by_key(|b| {
        let volume: u64 = b.key.dims.iter().map(|&d| d as u64).product();
        std::cmp::Reverse(volume.saturating_mul(b.members.len() as u64))
    });
    order.iter().flat_map(|b| b.members.iter().copied()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(kind: &'static str, dims: &[usize]) -> BatchKey {
        key_with(kind, dims, StencilSpec::Star13, MachineModel::r10000())
    }

    fn key_with(kind: &'static str, dims: &[usize], stencil: StencilSpec, machine: MachineModel) -> BatchKey {
        BatchKey { kind, dims: dims.to_vec(), stencil, machine }
    }

    #[test]
    fn groups_same_shape() {
        let keys = vec![
            key("exec", &[16, 16, 16]),
            key("exec", &[32, 32, 32]),
            key("exec", &[16, 16, 16]),
            key("analyze", &[16, 16, 16]),
            key("exec", &[16, 16, 16]),
        ];
        let batches = group_by_shape(&keys);
        assert_eq!(batches.len(), 3);
        assert_eq!(batches[0].members, vec![0, 2, 4]);
        assert_eq!(batches[1].members, vec![1]);
        assert_eq!(batches[2].members, vec![3]);
    }

    #[test]
    fn empty_input() {
        assert!(group_by_shape(&[]).is_empty());
    }

    #[test]
    fn schedule_is_a_permutation_heaviest_first() {
        let keys = vec![
            key("analyze", &[8, 8, 8]),
            key("analyze", &[64, 64, 64]),
            key("analyze", &[8, 8, 8]),
            key("analyze", &[64, 64, 64]),
            key("analyze", &[8, 8, 8]),
        ];
        let batches = group_by_shape(&keys);
        let order = schedule(&batches);
        // permutation of all indices
        let mut sorted = order.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![0, 1, 2, 3, 4]);
        // the 64³ batch (heavier despite fewer members) runs first
        assert_eq!(&order[..2], &[1, 3]);
        // submission order preserved within each batch
        assert_eq!(&order[2..], &[0, 2, 4]);
    }

    #[test]
    fn different_stencils_on_same_dims_do_not_batch() {
        // Regression: the key used to be (kind, dims) only, so a star13
        // analysis and a star(r=1) analysis on the same grid would share a
        // batch (and, per the sharing contract, a traversal) despite
        // walking different interiors.
        let m = MachineModel::r10000;
        let keys = vec![
            key_with("analyze", &[32, 32, 32], StencilSpec::Star13, m()),
            key_with("analyze", &[32, 32, 32], StencilSpec::Star { r: 1 }, m()),
            key_with("analyze", &[32, 32, 32], StencilSpec::Star13, m()),
        ];
        let batches = group_by_shape(&keys);
        assert_eq!(batches.len(), 2);
        assert_eq!(batches[0].members, vec![0, 2]);
        assert_eq!(batches[1].members, vec![1]);
    }

    #[test]
    fn different_machines_on_same_shape_do_not_batch() {
        let keys = vec![
            key_with("analyze", &[24, 24, 24], StencilSpec::Star13, MachineModel::r10000()),
            key_with("analyze", &[24, 24, 24], StencilSpec::Star13, MachineModel::r10000_full()),
        ];
        assert_eq!(group_by_shape(&keys).len(), 2);
    }

    #[test]
    fn all_members_covered_exactly_once() {
        let keys: Vec<BatchKey> =
            (0..50).map(|i| key(if i % 2 == 0 { "a" } else { "b" }, &[i % 5, 8, 8])).collect();
        let batches = group_by_shape(&keys);
        let mut seen = vec![false; keys.len()];
        for b in &batches {
            for &m in &b.members {
                assert!(!seen[m], "request {m} in two batches");
                seen[m] = true;
                assert_eq!(keys[m], b.key);
            }
        }
        assert!(seen.iter().all(|&s| s));
    }
}
