//! Memory layout for multiple arrays over the same grid.
//!
//! §5 of the paper computes `q` from `p` right-hand-side arrays and chooses
//! the arrays' base addresses so that the cache images of their assigned
//! parallelepiped tiles do not overlap:
//!
//! ```text
//! addr_i = addr_1 + m_i·S + s_i,   m_1 = s_1 = 0,
//! m_i = m_{i-1} + ⌈(|V| − s_i + s_{i-1}) / S⌉
//! ```
//!
//! where `s_i` is the in-cache offset of tile `P_i` relative to `P_1`. The
//! effect: array `i`'s copy of tile `P_j` lands at cache offset
//! `s_j − s_i (mod S)` — each array owns its own slice of the cache. A
//! naive layout (arrays contiguous) is provided as the baseline.

use super::GridDesc;

/// Base addresses for `p` same-shape arrays plus the output array `q`.
#[derive(Debug, Clone)]
pub struct MultiArrayLayout {
    grid: GridDesc,
    /// Base word address of each RHS array u_1 … u_p.
    bases: Vec<u64>,
    /// Base of the output array q.
    q_base: u64,
    /// Total words spanned by the layout.
    total_words: u64,
}

impl MultiArrayLayout {
    /// Naive contiguous layout: arrays packed back to back (what a Fortran
    /// COMMON block or consecutive ALLOCATEs would give you).
    pub fn contiguous(grid: &GridDesc, p: usize) -> MultiArrayLayout {
        assert!(p >= 1);
        let span = grid.storage_words();
        let bases: Vec<u64> = (0..p as u64).map(|i| i * span).collect();
        let q_base = p as u64 * span;
        MultiArrayLayout { grid: grid.clone(), bases, q_base, total_words: (p as u64 + 1) * span }
    }

    /// §5 offset assignment: array `i` shifted so that its tile `P_i` has
    /// cache offset `s_i` — tiles partition the fundamental parallelepiped,
    /// `s_i = i·⌈S/p⌉` words along the sweep direction. `cache_words` is S.
    pub fn paper_offsets(grid: &GridDesc, p: usize, cache_words: usize) -> MultiArrayLayout {
        assert!(p >= 1);
        let s = cache_words as u64;
        let v = grid.storage_words();
        let tile = s / p as u64; // ⌈S/p⌉ rounding irrelevant for offsets here
        let mut bases = vec![0u64];
        let mut m_prev = 0u64;
        let mut s_prev = 0u64;
        for i in 1..p as u64 {
            let s_i = i * tile;
            // m_i = m_{i-1} + ceil((V - s_i + s_{i-1})/S)
            let need = v + s_prev - s_i.min(v + s_prev); // V - s_i + s_{i-1}, clamped ≥ 0
            let m_i = m_prev + need.div_ceil(s);
            bases.push(m_i * s + s_i);
            m_prev = m_i;
            s_prev = s_i;
        }
        // q goes after the last array, at a *half-tile* cache offset: the
        // RHS arrays occupy tile offsets {i·S/p}; shifting q by S/(2p) puts
        // its write stream in the middle of a tile, maximizing its distance
        // from every RHS array's active window (q is write-only traffic —
        // §5 considers only the p inputs, but the output has to land
        // somewhere and colliding it with u_1 doubles u_1's replacements).
        let last_end = bases[p - 1] + v;
        let q_base = last_end.div_ceil(s) * s + tile / 2;
        MultiArrayLayout { grid: grid.clone(), bases, q_base, total_words: q_base + v }
    }

    pub fn grid(&self) -> &GridDesc {
        &self.grid
    }

    pub fn num_arrays(&self) -> usize {
        self.bases.len()
    }

    /// Base address of RHS array `i` (0-based).
    pub fn base(&self, i: usize) -> u64 {
        self.bases[i]
    }

    pub fn q_base(&self) -> u64 {
        self.q_base
    }

    pub fn total_words(&self) -> u64 {
        self.total_words
    }

    /// Word address of point `x` in RHS array `i`.
    #[inline]
    pub fn addr(&self, i: usize, x: &[i64]) -> u64 {
        self.bases[i] + self.grid.offset_of(x)
    }

    /// Word address of point `x` in the output array.
    #[inline]
    pub fn q_addr(&self, x: &[i64]) -> u64 {
        self.q_base + self.grid.offset_of(x)
    }

    /// Cache offset (mod S) of array `i`'s origin — used by tests to verify
    /// the §5 non-overlap property.
    pub fn cache_offset(&self, i: usize, cache_words: usize) -> u64 {
        self.bases[i] % cache_words as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn contiguous_layout_packs() {
        let g = GridDesc::new(&[10, 10]);
        let l = MultiArrayLayout::contiguous(&g, 3);
        assert_eq!(l.base(0), 0);
        assert_eq!(l.base(1), 100);
        assert_eq!(l.base(2), 200);
        assert_eq!(l.q_base(), 300);
        assert_eq!(l.total_words(), 400);
        assert_eq!(l.addr(1, &[5, 0]), 105);
        assert_eq!(l.q_addr(&[0, 1]), 310);
    }

    #[test]
    fn paper_offsets_distinct_cache_slices() {
        let g = GridDesc::new(&[40, 40]); // V = 1600
        let s = 1024;
        let p = 4;
        let l = MultiArrayLayout::paper_offsets(&g, p, s);
        // Each array's origin must land at its tile offset i·(S/p) mod S.
        for i in 0..p {
            assert_eq!(l.cache_offset(i, s), (i * (s / p)) as u64, "array {i}");
        }
        // Bases strictly increasing and non-overlapping in memory.
        for i in 1..p {
            assert!(l.base(i) >= l.base(i - 1) + g.storage_words(), "arrays {i} overlaps");
        }
        assert!(l.q_base() >= l.base(p - 1) + g.storage_words());
        // q sits at a half-tile cache offset, away from every RHS tile.
        assert_eq!(l.q_base() % s as u64, (s / p / 2) as u64);
    }

    #[test]
    fn paper_offsets_single_array_is_trivial() {
        let g = GridDesc::new(&[8, 8]);
        let l = MultiArrayLayout::paper_offsets(&g, 1, 64);
        assert_eq!(l.base(0), 0);
        assert_eq!(l.num_arrays(), 1);
    }

    #[test]
    fn addresses_respect_grid_strides() {
        let g = GridDesc::with_padding(&[5, 5], &[3, 0]);
        let l = MultiArrayLayout::contiguous(&g, 1);
        assert_eq!(l.addr(0, &[0, 1]), 8); // padded stride
    }
}
