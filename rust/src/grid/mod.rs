//! Structured discretization grids and their memory layout.
//!
//! The paper's arrays are Fortran arrays: column-major storage, the first
//! index varying fastest. Address arithmetic is what the interference
//! lattice and the cache simulator consume, so this module is the single
//! source of truth for linearization:
//!
//! ```text
//! addr(x) = base + x_1 + n_1·x_2 + n_1 n_2·x_3 + …       (words)
//! ```
//!
//! A [`GridDesc`] may carry padding: the *storage* dims exceed the
//! *logical* dims — exactly the transformation §6 of the paper prescribes
//! to escape unfavorable sizes. [`MultiArrayLayout`] implements §5's offset
//! assignment for p right-hand-side arrays.

mod layout;

pub use layout::MultiArrayLayout;

/// A d-dimensional structured grid with logical dims and storage padding.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct GridDesc {
    /// Logical (computational) extents n_1 … n_d.
    dims: Vec<usize>,
    /// Storage extents (≥ dims); the interference lattice is built on these.
    storage: Vec<usize>,
    /// Column-major strides over the *storage* extents.
    strides: Vec<u64>,
}

impl GridDesc {
    /// Unpadded grid.
    pub fn new(dims: &[usize]) -> GridDesc {
        Self::with_padding(dims, &vec![0; dims.len()])
    }

    /// Grid with per-dimension padding: storage_i = dims_i + pad_i.
    pub fn with_padding(dims: &[usize], pad: &[usize]) -> GridDesc {
        assert!(!dims.is_empty(), "zero-dimensional grid");
        assert_eq!(dims.len(), pad.len());
        assert!(dims.iter().all(|&n| n >= 1), "dims must be positive: {dims:?}");
        let storage: Vec<usize> = dims.iter().zip(pad).map(|(&n, &p)| n + p).collect();
        let mut strides = vec![1u64; dims.len()];
        for i in 1..dims.len() {
            strides[i] = strides[i - 1]
                .checked_mul(storage[i - 1] as u64)
                .expect("grid too large: stride overflow");
        }
        GridDesc { dims: dims.to_vec(), storage, strides }
    }

    pub fn ndim(&self) -> usize {
        self.dims.len()
    }

    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    pub fn storage_dims(&self) -> &[usize] {
        &self.storage
    }

    pub fn strides(&self) -> &[u64] {
        &self.strides
    }

    /// Number of logical grid points |G|.
    pub fn num_points(&self) -> u64 {
        self.dims.iter().map(|&n| n as u64).product()
    }

    /// Number of storage words per array defined on this grid.
    pub fn storage_words(&self) -> u64 {
        self.storage.iter().map(|&n| n as u64).product()
    }

    /// Linear word offset of logical point `x` (no base).
    #[inline]
    pub fn offset_of(&self, x: &[i64]) -> u64 {
        debug_assert_eq!(x.len(), self.dims.len());
        let mut off = 0i64;
        for (&xi, &s) in x.iter().zip(&self.strides) {
            off += xi * s as i64;
        }
        debug_assert!(off >= 0);
        off as u64
    }

    /// Signed linear offset of a stencil displacement vector.
    #[inline]
    pub fn delta_of(&self, k: &[i64]) -> i64 {
        k.iter().zip(&self.strides).map(|(&ki, &s)| ki * s as i64).sum()
    }

    /// Is `x` a logical grid point?
    pub fn contains(&self, x: &[i64]) -> bool {
        x.len() == self.dims.len() && x.iter().zip(&self.dims).all(|(&xi, &n)| xi >= 0 && (xi as usize) < n)
    }

    /// The K-interior for a stencil of radius `r`: points where every
    /// stencil neighbor stays inside the grid. (Paper: R, the K-interior of
    /// G; D = G \ R is the boundary.) Returns per-dim [lo, hi) ranges, or
    /// None if the grid is too small to have an interior.
    pub fn interior(&self, r: usize) -> Option<Vec<std::ops::Range<i64>>> {
        let mut out = Vec::with_capacity(self.dims.len());
        for &n in &self.dims {
            if n < 2 * r + 1 {
                return None;
            }
            out.push(r as i64..(n - r) as i64);
        }
        Some(out)
    }

    /// |R| — number of interior points for radius `r`.
    pub fn interior_points(&self, r: usize) -> u64 {
        match self.interior(r) {
            None => 0,
            Some(ranges) => ranges.iter().map(|rg| (rg.end - rg.start) as u64).product(),
        }
    }

    /// |D| = |G| − |R|, the boundary point count.
    pub fn boundary_points(&self, r: usize) -> u64 {
        self.num_points() - self.interior_points(r)
    }

    /// Smallest logical extent (the `l` in the paper's lower bound Eq 7).
    pub fn min_dim(&self) -> usize {
        *self.dims.iter().min().unwrap()
    }

    /// Iterate all logical points in natural (column-major) order, calling
    /// `f` with the coordinate vector. For hot paths use the traversal
    /// module instead; this is the simple generic walker.
    pub fn for_each_point(&self, mut f: impl FnMut(&[i64])) {
        let d = self.dims.len();
        let mut x = vec![0i64; d];
        loop {
            f(&x);
            // odometer increment, dim 0 fastest
            let mut i = 0;
            loop {
                x[i] += 1;
                if (x[i] as usize) < self.dims[i] {
                    break;
                }
                x[i] = 0;
                i += 1;
                if i == d {
                    return;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strides_column_major() {
        let g = GridDesc::new(&[91, 100, 64]);
        assert_eq!(g.strides(), &[1, 91, 9100]);
        assert_eq!(g.offset_of(&[1, 0, 0]), 1);
        assert_eq!(g.offset_of(&[0, 1, 0]), 91);
        assert_eq!(g.offset_of(&[0, 0, 1]), 9100);
        assert_eq!(g.offset_of(&[2, 3, 4]), 2 + 3 * 91 + 4 * 9100);
    }

    #[test]
    fn padding_changes_strides_not_logical_dims() {
        let g = GridDesc::with_padding(&[45, 91, 100], &[3, 0, 0]);
        assert_eq!(g.dims(), &[45, 91, 100]);
        assert_eq!(g.storage_dims(), &[48, 91, 100]);
        assert_eq!(g.strides(), &[1, 48, 48 * 91]);
        assert_eq!(g.num_points(), 45 * 91 * 100);
        assert_eq!(g.storage_words(), 48 * 91 * 100);
    }

    #[test]
    fn delta_of_signed() {
        let g = GridDesc::new(&[10, 10]);
        assert_eq!(g.delta_of(&[-1, 0]), -1);
        assert_eq!(g.delta_of(&[0, -2]), -20);
        assert_eq!(g.delta_of(&[1, 1]), 11);
    }

    #[test]
    fn interior_counts() {
        let g = GridDesc::new(&[10, 10, 10]);
        let r = g.interior(1).unwrap();
        assert_eq!(r, vec![1..9, 1..9, 1..9]);
        assert_eq!(g.interior_points(1), 8 * 8 * 8);
        assert_eq!(g.boundary_points(1), 1000 - 512);
        // radius too large
        assert!(GridDesc::new(&[4, 4]).interior(2).is_none());
        assert_eq!(GridDesc::new(&[4, 4]).interior_points(2), 0);
    }

    #[test]
    fn contains_checks_bounds() {
        let g = GridDesc::new(&[5, 5]);
        assert!(g.contains(&[0, 0]));
        assert!(g.contains(&[4, 4]));
        assert!(!g.contains(&[5, 0]));
        assert!(!g.contains(&[-1, 0]));
    }

    #[test]
    fn for_each_point_visits_all_once_in_order() {
        let g = GridDesc::new(&[3, 2]);
        let mut seen = Vec::new();
        g.for_each_point(|x| seen.push((x[0], x[1])));
        assert_eq!(seen, vec![(0, 0), (1, 0), (2, 0), (0, 1), (1, 1), (2, 1)]);
    }

    #[test]
    fn min_dim() {
        assert_eq!(GridDesc::new(&[40, 91, 100]).min_dim(), 40);
    }
}
