//! Stencil operators `q = Ku`.
//!
//! A stencil is a finite set of displacement vectors `k_1 … k_s` (the
//! *stencil vectors*, paper §3): `q(x) = f(u(x+k_1), …, u(x+k_s))`. We
//! carry a coefficient per vector so the numeric path computes the common
//! linear case `q(x) = Σ c_i·u(x+k_i)` (difference operators).
//!
//! Constructors cover the paper's shapes:
//! - [`Stencil::star`] — the star of radius r: `{0, ±k·e_i | 1 ≤ k ≤ r}`;
//!   `star(3, 2)` is the paper's **13-point second-order star** used in all
//!   measurements;
//! - [`Stencil::box_stencil`] — the full cube `{|x_i|∞ ≤ r}`;
//! - [`Stencil::from_offsets`] — arbitrary.

use crate::lattice::IntVec;

/// A stencil operator: displacement vectors with coefficients.
#[derive(Debug, Clone, PartialEq)]
pub struct Stencil {
    ndim: usize,
    offsets: Vec<IntVec>,
    coeffs: Vec<f64>,
}

impl Stencil {
    /// Arbitrary stencil from (offset, coefficient) pairs.
    pub fn from_offsets(ndim: usize, pairs: Vec<(IntVec, f64)>) -> Stencil {
        assert!(!pairs.is_empty(), "empty stencil");
        for (o, _) in &pairs {
            assert_eq!(o.len(), ndim, "offset arity mismatch");
        }
        // Reject duplicate offsets — a redundant stencil breaks the §2
        // load/miss inequality assumptions.
        let mut seen = std::collections::HashSet::new();
        for (o, _) in &pairs {
            assert!(seen.insert(o.clone()), "duplicate stencil offset {o:?}");
        }
        let (offsets, coeffs) = pairs.into_iter().unzip();
        Stencil { ndim, offsets, coeffs }
    }

    /// Star stencil of radius `r` in `d` dimensions: center plus up to `r`
    /// steps along each axis; `1 + 2rd` points. Coefficients are those of
    /// the standard 2r-order accurate Laplacian-like operator normalized to
    /// sum 0 with center weight −2rd/h² style; for cache analysis only the
    /// *shape* matters, but the numeric path uses these weights.
    pub fn star(d: usize, r: usize) -> Stencil {
        assert!(d >= 1 && r >= 1);
        let mut pairs: Vec<(IntVec, f64)> = Vec::with_capacity(1 + 2 * r * d);
        // Second-order-style weights: center −2d·Σw_k, axis ±k weight w_k.
        // For r=1: classical 7-point (d=3). For r=2: the 13-point star with
        // fourth-order weights (−1/12, 4/3) per axis.
        let axis_w: Vec<f64> = match r {
            1 => vec![1.0],
            2 => vec![4.0 / 3.0, -1.0 / 12.0],
            _ => (1..=r).map(|k| 1.0 / k as f64).collect(), // generic decay
        };
        let center_w = -2.0 * d as f64 * axis_w.iter().sum::<f64>();
        pairs.push((vec![0; d], center_w));
        for i in 0..d {
            for k in 1..=r as i64 {
                for sign in [1i64, -1] {
                    let mut o = vec![0i64; d];
                    o[i] = sign * k;
                    pairs.push((o, axis_w[(k - 1) as usize]));
                }
            }
        }
        Stencil::from_offsets(d, pairs)
    }

    /// The paper's measurement stencil: 13-point second-order star in 3-D.
    pub fn star13() -> Stencil {
        Stencil::star(3, 2)
    }

    /// Full box stencil `{‖x‖∞ ≤ r}` with uniform averaging weights
    /// (coefficients sum to 1, unlike the difference-operator stars).
    pub fn box_stencil(d: usize, r: usize) -> Stencil {
        // d = 0 would underflow the odometer below; r = 0 is legal (the
        // identity stencil) and useful in tests.
        assert!(d >= 1, "box stencil needs at least one dimension");
        let side = 2 * r + 1;
        let count = side.pow(d as u32);
        let w = 1.0 / count as f64;
        let mut pairs = Vec::with_capacity(count);
        let mut o = vec![-(r as i64); d];
        loop {
            pairs.push((o.clone(), w));
            let mut i = 0;
            loop {
                o[i] += 1;
                if o[i] <= r as i64 {
                    break;
                }
                o[i] = -(r as i64);
                i += 1;
                if i == d {
                    return Stencil::from_offsets(d, pairs);
                }
            }
        }
    }

    pub fn ndim(&self) -> usize {
        self.ndim
    }

    /// |K| — number of stencil points.
    pub fn size(&self) -> usize {
        self.offsets.len()
    }

    pub fn offsets(&self) -> &[IntVec] {
        &self.offsets
    }

    pub fn coeffs(&self) -> &[f64] {
        &self.coeffs
    }

    /// Radius r: max L∞ norm over stencil vectors (paper §3 "locality").
    pub fn radius(&self) -> usize {
        self.offsets.iter().map(|o| o.iter().map(|&x| x.unsigned_abs()).max().unwrap_or(0)).max().unwrap_or(0) as usize
    }

    /// Diameter `2r + 1` (the quantity compared against lattice vector
    /// lengths in the unfavorable-grid criterion).
    pub fn diameter(&self) -> usize {
        2 * self.radius() + 1
    }

    /// Does this stencil contain the unit star `{0, ±e_i}`? The paper's
    /// lower bound (§3) applies to any stencil containing the star.
    pub fn contains_star(&self) -> bool {
        let d = self.ndim;
        let mut need: Vec<IntVec> = vec![vec![0; d]];
        for i in 0..d {
            for sign in [1i64, -1] {
                let mut o = vec![0i64; d];
                o[i] = sign;
                need.push(o);
            }
        }
        need.iter().all(|n| self.offsets.contains(n))
    }

    /// Signed projections of the stencil vectors onto direction `v`
    /// (paper §4: h_1 … h_s, used to size pencils; returns (h−, h+)).
    pub fn projection_extent(&self, v: &[i64]) -> (f64, f64) {
        let vnorm2: f64 = v.iter().map(|&x| (x * x) as f64).sum();
        assert!(vnorm2 > 0.0);
        let mut h_min = f64::INFINITY;
        let mut h_max = f64::NEG_INFINITY;
        for o in &self.offsets {
            let dot: f64 = o.iter().zip(v).map(|(&a, &b)| (a * b) as f64).sum();
            let h = dot / vnorm2.sqrt();
            h_min = h_min.min(h);
            h_max = h_max.max(h);
        }
        (h_min, h_max)
    }

    /// Apply the linear stencil at one point given a flat `u` buffer and the
    /// precomputed linear deltas (from `GridDesc::delta_of`).
    #[inline]
    pub fn apply_at(&self, u: &[f64], base: usize, deltas: &[i64]) -> f64 {
        debug_assert_eq!(deltas.len(), self.coeffs.len());
        let mut acc = 0.0;
        for (&c, &dlt) in self.coeffs.iter().zip(deltas) {
            acc += c * u[(base as i64 + dlt) as usize];
        }
        acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn star13_shape() {
        let s = Stencil::star13();
        assert_eq!(s.size(), 13);
        assert_eq!(s.radius(), 2);
        assert_eq!(s.diameter(), 5);
        assert!(s.contains_star());
        // coefficients sum to zero (difference operator annihilates constants)
        let sum: f64 = s.coeffs().iter().sum();
        assert!(sum.abs() < 1e-12, "sum = {sum}");
    }

    #[test]
    fn star_r1_is_2d_plus_1_points() {
        for d in 1..=4 {
            let s = Stencil::star(d, 1);
            assert_eq!(s.size(), 2 * d + 1);
            assert!(s.contains_star());
            assert_eq!(s.diameter(), 3);
        }
    }

    #[test]
    fn star_offset_counts_d1_to_d4() {
        // |K| = 1 + 2rd for every (d, r), including the generic r ≥ 3
        // weight path; construction also exercises duplicate-offset
        // rejection (from_offsets panics on repeats).
        for d in 1..=4usize {
            for r in 1..=3usize {
                let s = Stencil::star(d, r);
                assert_eq!(s.size(), 1 + 2 * r * d, "star({d},{r})");
                assert_eq!(s.radius(), r);
                assert_eq!(s.ndim(), d);
            }
        }
    }

    #[test]
    fn star_coefficients_sum_to_zero_d1_to_d4() {
        // A difference operator must annihilate constants for every
        // dimensionality and radius (the numeric backend's solve relies on
        // this: constant modes carry no residual).
        for d in 1..=4usize {
            for r in 1..=3usize {
                let sum: f64 = Stencil::star(d, r).coeffs().iter().sum();
                assert!(sum.abs() < 1e-12, "star({d},{r}): Σc = {sum}");
            }
        }
    }

    #[test]
    fn box_stencil_d1_to_d4() {
        for d in 1..=4usize {
            let s = Stencil::box_stencil(d, 1);
            assert_eq!(s.size(), 3usize.pow(d as u32), "box({d},1)");
            assert_eq!(s.radius(), 1);
            // averaging weights sum to one
            let sum: f64 = s.coeffs().iter().sum();
            assert!((sum - 1.0).abs() < 1e-12, "box({d},1): Σc = {sum}");
        }
        // r = 0 is the identity stencil
        let id = Stencil::box_stencil(2, 0);
        assert_eq!(id.size(), 1);
        assert_eq!(id.radius(), 0);
        assert_eq!(id.diameter(), 1);
    }

    #[test]
    #[should_panic(expected = "at least one dimension")]
    fn box_stencil_rejects_zero_dims() {
        let _ = Stencil::box_stencil(0, 1);
    }

    #[test]
    fn star_offsets_unique_d1_to_d4() {
        for d in 1..=4usize {
            let s = Stencil::star(d, 2);
            let mut seen = std::collections::HashSet::new();
            for o in s.offsets() {
                assert!(seen.insert(o.clone()), "duplicate offset {o:?} in star({d},2)");
            }
        }
    }

    #[test]
    fn box_stencil_counts() {
        assert_eq!(Stencil::box_stencil(2, 1).size(), 9);
        assert_eq!(Stencil::box_stencil(3, 1).size(), 27);
        assert_eq!(Stencil::box_stencil(3, 2).size(), 125);
        assert!(Stencil::box_stencil(3, 1).contains_star());
    }

    #[test]
    fn radius_of_asymmetric_stencil() {
        let s = Stencil::from_offsets(2, vec![(vec![0, 0], 1.0), (vec![3, 0], 0.5), (vec![0, -1], 0.5)]);
        assert_eq!(s.radius(), 3);
        assert!(!s.contains_star());
    }

    #[test]
    #[should_panic(expected = "duplicate stencil offset")]
    fn duplicate_offsets_rejected() {
        let _ = Stencil::from_offsets(1, vec![(vec![1], 1.0), (vec![1], 2.0)]);
    }

    #[test]
    fn projection_extent_star13_axis() {
        let s = Stencil::star13();
        let (lo, hi) = s.projection_extent(&[1, 0, 0]);
        assert_eq!((lo, hi), (-2.0, 2.0));
        let (lo_d, hi_d) = s.projection_extent(&[1, 1, 0]);
        // max projection: offset (2,0,0)·(1,1,0)/√2 = √2
        assert!((hi_d - 2.0 / 2f64.sqrt()).abs() < 1e-12);
        assert!((lo_d + 2.0 / 2f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn apply_at_linear_combination() {
        // 1-D second difference on a quadratic: u(x) = x², u'' = 2.
        let s = Stencil::star(1, 1); // weights: center −2, ±1 → discrete u''
        let u: Vec<f64> = (0..10).map(|x| (x * x) as f64).collect();
        let deltas = [0i64, 1, -1];
        // order of offsets: center, +1, -1 — match deltas accordingly.
        let offs = s.offsets();
        assert_eq!(offs[0], vec![0]);
        let q = s.apply_at(&u, 5, &deltas);
        assert!((q - 2.0).abs() < 1e-12, "q = {q}");
    }

    #[test]
    fn star13_fourth_order_on_quartic() {
        // The r=2 star weights (−1/12, 4/3) reproduce u'' exactly for cubics.
        let s = Stencil::star(1, 2);
        let u: Vec<f64> = (0..20).map(|x| (x as f64).powi(3)).collect();
        let g = crate::grid::GridDesc::new(&[20]);
        let deltas: Vec<i64> = s.offsets().iter().map(|o| g.delta_of(o)).collect();
        let x = 10.0f64;
        let q = s.apply_at(&u, 10, &deltas);
        assert!((q - 6.0 * x).abs() < 1e-9, "q = {q}, want {}", 6.0 * x);
    }
}
