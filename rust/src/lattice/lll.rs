//! Lenstra–Lenstra–Lovász basis reduction.
//!
//! §4 of the paper requires a *reduced* basis: one with
//! `Π‖b_i‖ ≤ c_d · det L` (Eq 10). LLL delivers this with
//! `c_d = 2^{d(d-1)/4}` in polynomial time (the paper cites
//! Schrijver Ch. 6.2 for exactly this algorithm). The reduced basis powers
//! the cache-fitting traversal (fundamental parallelepiped with good
//! surface-to-volume ratio, Eq 11) and the eccentricity bound.
//!
//! Implementation: classical LLL with floating-point Gram–Schmidt. Our
//! lattices are tiny (d ≤ 6) with entries ≤ S ≈ 2^22, far inside f64's
//! exact range, so fp-LLL is robust here; a final exactness check verifies
//! the size-reduction and Lovász conditions with integer arithmetic where
//! possible.

use super::vec::{gram_schmidt, norm2_sq, sub_scaled, IntVec};

/// The Lovász condition parameter; 0.75 is the classical choice.
pub const DELTA: f64 = 0.75;

/// Reduce `basis` in place with LLL (δ = 0.75). Returns the number of swap
/// steps performed (diagnostic; bounded polynomially).
pub fn lll_reduce(basis: &mut [IntVec]) -> usize {
    let n = basis.len();
    if n <= 1 {
        return 0;
    }
    let mut swaps = 0;
    let (mut gso, mut mu) = gram_schmidt(basis);
    let mut norms: Vec<f64> = gso.iter().map(|v| v.iter().map(|x| x * x).sum()).collect();

    let mut k = 1;
    let mut guard = 0usize;
    while k < n {
        guard += 1;
        assert!(guard < 100_000, "LLL failed to terminate (numerical trouble)");
        // Size-reduce b_k against b_{k-1} ... b_0.
        for j in (0..k).rev() {
            let q = mu[k][j].round();
            if q != 0.0 {
                let (bj, bk) = split_two(basis, j, k);
                sub_scaled(bk, bj, q as i64);
                // update mu row k
                for l in 0..=j {
                    let delta = if l == j { q } else { q * mu[j][l] };
                    mu[k][l] -= delta;
                }
            }
        }
        // Lovász condition.
        if norms[k] >= (DELTA - mu[k][k - 1] * mu[k][k - 1]) * norms[k - 1] {
            k += 1;
        } else {
            basis.swap(k - 1, k);
            swaps += 1;
            // Recompute GSO from scratch — cheap at our dimensions and
            // sidesteps the delicate incremental update formulas.
            let (g, m) = gram_schmidt(basis);
            gso = g;
            mu = m;
            norms = gso.iter().map(|v| v.iter().map(|x| x * x).sum()).collect();
            k = k.max(2) - 1;
        }
    }
    swaps
}

/// Get mutable references to two distinct rows.
fn split_two<'a>(basis: &'a mut [IntVec], j: usize, k: usize) -> (&'a IntVec, &'a mut IntVec) {
    assert!(j < k);
    let (lo, hi) = basis.split_at_mut(k);
    (&lo[j], &mut hi[0])
}

/// Check Eq 10: `Π‖b_i‖ ≤ 2^{d(d-1)/4} · |det L|` — the defining property of
/// a reduced basis that every downstream bound relies on.
pub fn satisfies_reduced_bound(basis: &[IntVec], det_abs: f64) -> bool {
    let d = basis.len();
    let prod: f64 = basis.iter().map(|b| (norm2_sq(b) as f64).sqrt()).product();
    let c_d = 2f64.powf(d as f64 * (d as f64 - 1.0) / 4.0);
    prod <= c_d * det_abs * (1.0 + 1e-9)
}

/// Eccentricity `e = max ‖b_i‖ / min ‖b_i‖` of a basis (paper §4: ratio of
/// the longest basis vector to the shortest — the constant multiplying the
/// upper bound Eq 12).
pub fn eccentricity(basis: &[IntVec]) -> f64 {
    let norms: Vec<f64> = basis.iter().map(|b| (norm2_sq(b) as f64).sqrt()).collect();
    let max = norms.iter().cloned().fold(0.0f64, f64::max);
    let min = norms.iter().cloned().fold(f64::INFINITY, f64::min);
    if min == 0.0 {
        f64::INFINITY
    } else {
        max / min
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lattice::vec::{det, norm2};

    #[test]
    fn reduces_skewed_2d_basis() {
        // Classic example: [[1, 1], [1, 2]] ~ already nice; try a skewed one.
        let mut b = vec![vec![201, 37], vec![1648, 297]];
        let d0 = det(&b).unsigned_abs();
        lll_reduce(&mut b);
        assert_eq!(det(&b).unsigned_abs(), d0, "determinant must be preserved");
        assert!(satisfies_reduced_bound(&b, d0 as f64));
        // LLL guarantee: ‖b_0‖ ≤ 2^{(d-1)/4} · det^{1/d} ≈ 42.5 here.
        assert!(norm2(&b[0]) < 43.0, "b0 = {:?}", b[0]);
    }

    #[test]
    fn preserves_lattice_membership() {
        // The reduced basis must generate the same lattice: check both ways
        // via determinant (equal up to sign) + integrality of change of basis.
        let orig = vec![vec![4096, 0, 0], vec![-91, 1, 0], vec![-9100, 0, 1]];
        let mut red = orig.clone();
        lll_reduce(&mut red);
        assert_eq!(det(&red).abs(), det(&orig).abs());
        // Every reduced vector must satisfy the congruence defining the
        // original lattice: i1 + 91*i2 + 9100*i3... wait — orig basis encodes
        // i1 + n1 i2 + n1 n2 i3 ≡ 0 (mod S) with n1=91, n1n2=9100, S=4096.
        for v in &red {
            let val = v[0] as i128 + 91 * v[1] as i128 + 9100 * v[2] as i128;
            assert_eq!(val.rem_euclid(4096), 0, "reduced vector {v:?} left the lattice");
        }
    }

    #[test]
    fn identity_basis_untouched() {
        let mut b = vec![vec![1, 0], vec![0, 1]];
        let swaps = lll_reduce(&mut b);
        assert_eq!(swaps, 0);
        assert_eq!(b, vec![vec![1, 0], vec![0, 1]]);
    }

    #[test]
    fn single_vector_basis() {
        let mut b = vec![vec![5, 3]];
        assert_eq!(lll_reduce(&mut b), 0);
        assert_eq!(b, vec![vec![5, 3]]);
    }

    #[test]
    fn eccentricity_of_square_is_one() {
        assert_eq!(eccentricity(&[vec![2, 0], vec![0, 2]]), 1.0);
        let e = eccentricity(&[vec![1, 0], vec![0, 10]]);
        assert!((e - 10.0).abs() < 1e-12);
    }

    #[test]
    fn reduced_bound_flags_bad_basis() {
        // Extremely skewed basis of Z^2: product of norms >> det.
        let bad = vec![vec![1, 0], vec![1000, 1]];
        assert!(!satisfies_reduced_bound(&bad, 1.0));
        let mut good = bad.clone();
        lll_reduce(&mut good);
        assert!(satisfies_reduced_bound(&good, 1.0));
    }

    #[test]
    fn random_3d_lattices_reduced() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(99);
        for _ in 0..25 {
            let s = 1 << (8 + rng.below(6)); // 256..8192
            let n1 = 16 + rng.below(200) as i64;
            let n2 = 16 + rng.below(200) as i64;
            let mut b = vec![vec![s, 0, 0], vec![-n1, 1, 0], vec![-n1 * n2, 0, 1]];
            let d0 = det(&b).unsigned_abs();
            lll_reduce(&mut b);
            assert_eq!(det(&b).unsigned_abs(), d0);
            assert!(satisfies_reduced_bound(&b, d0 as f64), "s={s} n1={n1} n2={n2} b={b:?}");
        }
    }
}
