//! Shortest-vector computations on the interference lattice.
//!
//! Two related queries drive the paper's analysis:
//!
//! 1. the (Euclidean) shortest nonzero vector — used by Appendix B's
//!    favorable-grid criterion `‖v‖ ≥ (S/f)^{1/d}` and by the eccentricity
//!    argument after Eq 12;
//! 2. the **L1**-shortest vector — Figure 5B classifies a grid as
//!    *unfavorable* when the lattice contains a vector of L1 norm < 8
//!    (more precisely: shorter than the stencil diameter / associativity).
//!
//! After LLL reduction the shortest vector has bounded coefficients w.r.t.
//! the reduced basis, so a small Fincke–Pohst-style enumeration is exact.

use super::vec::{gram_schmidt, is_zero, norm1, norm2_sq, IntVec};

/// Exact shortest nonzero lattice vector (Euclidean norm), given an
/// LLL-reduced basis. Enumerates coefficient vectors with a Gram–Schmidt
/// pruning bound seeded by `‖b_0‖`.
pub fn shortest_vector(reduced: &[IntVec]) -> IntVec {
    let n = reduced.len();
    assert!(n > 0);
    let (gso, mu) = gram_schmidt(reduced);
    let gso_norms: Vec<f64> = gso.iter().map(|v| v.iter().map(|x| x * x).sum()).collect();
    let mut best = reduced[0].clone();
    let mut best_sq = norm2_sq(&best) as f64;

    // Depth-first enumeration over coefficients x_{n-1} ... x_0 with the
    // classical bound sum_{i>=k} (x_i + Σ mu_ji x_j)^2 * ||b*_i||^2 <= best.
    let mut coeff = vec![0i64; n];
    enumerate(reduced, &mu, &gso_norms, &mut coeff, n, 0.0, &mut best, &mut best_sq, &mut vec![0.0; n]);
    best
}

#[allow(clippy::too_many_arguments)]
fn enumerate(
    basis: &[IntVec],
    mu: &[Vec<f64>],
    gso_norms: &[f64],
    coeff: &mut Vec<i64>,
    level: usize, // processing index level-1; level==0 → full assignment
    partial: f64, // accumulated squared norm from levels >= level
    best: &mut IntVec,
    best_sq: &mut f64,
    centers: &mut Vec<f64>,
) {
    if level == 0 {
        if coeff.iter().all(|&c| c == 0) {
            return;
        }
        // materialize the vector and use its exact integer norm.
        let d = basis[0].len();
        let mut v = vec![0i64; d];
        for (c, b) in coeff.iter().zip(basis) {
            for i in 0..d {
                v[i] += c * b[i];
            }
        }
        let sq = norm2_sq(&v) as f64;
        if sq > 0.0 && sq < *best_sq {
            *best_sq = sq;
            *best = v;
        }
        return;
    }
    let k = level - 1;
    // center of the interval for x_k given choices above.
    let mut center = 0.0;
    for j in level..coeff.len() {
        center -= coeff[j] as f64 * mu[j][k];
    }
    centers[k] = center;
    if gso_norms[k] <= 0.0 {
        return;
    }
    let radius = ((*best_sq - partial) / gso_norms[k]).max(0.0).sqrt();
    let lo = (center - radius - 1e-9).ceil() as i64;
    let hi = (center + radius + 1e-9).floor() as i64;
    // Visit nearest-first for better pruning.
    let mut candidates: Vec<i64> = (lo..=hi).collect();
    candidates.sort_by(|a, b| {
        let da = (*a as f64 - center).abs();
        let db = (*b as f64 - center).abs();
        da.partial_cmp(&db).unwrap()
    });
    for x in candidates {
        let dist = x as f64 - center;
        let add = dist * dist * gso_norms[k];
        if partial + add >= *best_sq + 1e-9 {
            continue;
        }
        coeff[k] = x;
        enumerate(basis, mu, gso_norms, coeff, k, partial + add, best, best_sq, centers);
        coeff[k] = 0;
    }
}

/// All nonzero lattice vectors with L1 norm ≤ `max_l1`, found by direct
/// congruence enumeration of the ball — exact and independent of any basis.
///
/// `dims` are the grid dimensions n_1..n_d and `modulus` is S: membership is
/// `i_1 + n_1 i_2 + n_1 n_2 i_3 + ... ≡ 0 (mod S)` (Eq 8 of the paper).
pub fn short_vectors_by_congruence(dims: &[usize], modulus: usize, max_l1: i64) -> Vec<IntVec> {
    let d = dims.len();
    assert!(d >= 1);
    let mut strides = vec![1i64; d];
    for i in 1..d {
        strides[i] = strides[i - 1] * dims[i - 1] as i64;
    }
    let s = modulus as i64;
    let mut out = Vec::new();
    let mut v = vec![0i64; d];
    // Walk the L1 ball; for the first coordinate solve the congruence
    // directly instead of scanning: i1 ≡ -(Σ_{k≥2} strides_k i_k) (mod S).
    walk_tail(&mut v, 1, max_l1, &strides, s, d, &mut out);
    out
}

fn walk_tail(v: &mut Vec<i64>, idx: usize, budget: i64, strides: &[i64], s: i64, d: usize, out: &mut Vec<IntVec>) {
    if idx == d {
        // choose i1 with |i1| <= budget and i1 ≡ r (mod S)
        let tail: i64 = (1..d).map(|k| strides[k].wrapping_mul(v[k])).sum();
        let r = (-tail).rem_euclid(s);
        // candidates: r - kS within [-budget, budget]
        let mut i1 = r;
        while i1 > budget {
            i1 -= s;
        }
        while i1 >= -budget {
            v[0] = i1;
            if !is_zero(v) {
                out.push(v.clone());
            }
            i1 -= s;
        }
        v[0] = 0;
        return;
    }
    for x in -budget..=budget {
        v[idx] = x;
        walk_tail(v, idx + 1, budget - x.abs(), strides, s, d, out);
    }
    v[idx] = 0;
}

/// The minimum L1 norm over nonzero lattice vectors, searched up to
/// `max_l1`; `None` if no vector that short exists.
pub fn min_l1_norm(dims: &[usize], modulus: usize, max_l1: i64) -> Option<i64> {
    short_vectors_by_congruence(dims, modulus, max_l1).iter().map(|v| norm1(v)).min()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lattice::lll::lll_reduce;
    use crate::lattice::vec::norm2;

    #[test]
    fn shortest_in_z2() {
        let b = vec![vec![1, 0], vec![0, 1]];
        let v = shortest_vector(&b);
        assert_eq!(norm2_sq(&v), 1);
    }

    #[test]
    fn shortest_known_2d() {
        // Lattice {(x,y) : x + 4y ≡ 0 mod 16}: contains (4,3)? 4+12=16 ✓
        // norm²=25; (0,4): 16≡0 ✓ norm²=16; (-4,1): -4+4=0 ✓ norm²=17;
        // (4,-1): 4-4=0 ✓ norm²=17; (0,4) norm 4; shortest should be (0,±4).
        let mut b = vec![vec![16, 0], vec![-4, 1]];
        lll_reduce(&mut b);
        let v = shortest_vector(&b);
        assert_eq!(norm2_sq(&v), 16, "got {v:?}");
    }

    #[test]
    fn shortest_matches_congruence_enumeration_3d() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(4242);
        for _ in 0..20 {
            let s = 1usize << (6 + rng.below(6)); // 64..2048
            let dims = vec![8 + rng.below_usize(120), 8 + rng.below_usize(120), 50];
            let n1 = dims[0] as i64;
            let m3 = n1 * dims[1] as i64;
            let mut basis = vec![vec![s as i64, 0, 0], vec![-n1, 1, 0], vec![-m3, 0, 1]];
            lll_reduce(&mut basis);
            let sv = shortest_vector(&basis);
            let l2 = norm2(&sv);
            // brute-force check via congruence enumeration within L1 ball of
            // radius ceil(l2 * sqrt(3)) — contains all vectors with L2 ≤ l2.
            let ball = (l2 * 3f64.sqrt()).ceil() as i64 + 1;
            let all = short_vectors_by_congruence(&dims, s, ball);
            let brute_min = all.iter().map(|v| norm2_sq(v)).min().unwrap();
            assert_eq!(norm2_sq(&sv), brute_min, "dims={dims:?} S={s} sv={sv:?}");
        }
    }

    #[test]
    fn congruence_vectors_satisfy_eq8() {
        let dims = [45usize, 91, 100];
        let s = 4096usize;
        let vs = short_vectors_by_congruence(&dims, s, 8);
        assert!(!vs.is_empty());
        for v in &vs {
            let val = v[0] as i128 + 45 * v[1] as i128 + 45 * 91 * v[2] as i128;
            assert_eq!(val.rem_euclid(4096), 0, "{v:?}");
            assert!(norm1(v) <= 8);
        }
    }

    #[test]
    fn paper_fig4_spikes_n1_45_and_90() {
        // Paper: n1=45 (n2=91) yields shortest vector (1,0,1); n1=90 yields
        // (2,0,1). Verify both are lattice members and are the L1-minima.
        let s = 4096usize;
        // n1=45: 1 + 45*91*1 = 4096 ≡ 0 ✓
        let m = min_l1_norm(&[45, 91, 100], s, 8).expect("short vector expected");
        assert_eq!(m, 2);
        let vs = short_vectors_by_congruence(&[45, 91, 100], s, 2);
        assert!(vs.iter().any(|v| (v[0] == 1 && v[1] == 0 && v[2] == 1) || (v[0] == -1 && v[1] == 0 && v[2] == -1)), "{vs:?}");
        // n1=90: 2 + 90*91 = 8192 ≡ 0 mod 4096 ✓
        let m90 = min_l1_norm(&[90, 91, 100], s, 8).expect("short vector expected");
        assert_eq!(m90, 3);
        let vs90 = short_vectors_by_congruence(&[90, 91, 100], s, 3);
        assert!(vs90.iter().any(|v| (v[0] == 2 && v[1] == 0 && v[2] == 1) || (v[0] == -2 && v[1] == 0 && v[2] == -1)), "{vs90:?}");
    }

    #[test]
    fn favorable_grid_has_no_short_vector() {
        // A deliberately padded dimension pair should clear the L1<8 bar.
        // 67*89 = 5963; 5963 mod 4096 = 1867 — far from 0 and 2048.
        assert_eq!(min_l1_norm(&[67, 89, 100], 4096, 4), None);
    }

    #[test]
    fn min_l1_respects_bound_parameter() {
        // With a generous bound there is always *some* vector (e.g. (S,0,0)).
        let m = min_l1_norm(&[67, 89, 100], 64, 64);
        assert!(m.is_some());
        assert!(m.unwrap() <= 64);
    }
}
