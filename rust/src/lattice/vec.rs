//! Integer vector and small dense matrix helpers for lattice computations.
//! Dimensions are tiny (d ≤ 6), so everything is plain `Vec<i64>` / `Vec<f64>`
//! with no SIMD heroics — the lattice math runs once per grid, not per point.

/// Integer vector in Z^d.
pub type IntVec = Vec<i64>;

/// Dot product.
pub fn dot(a: &[i64], b: &[i64]) -> i128 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(&x, &y)| x as i128 * y as i128).sum()
}

/// Squared Euclidean norm.
pub fn norm2_sq(a: &[i64]) -> i128 {
    dot(a, a)
}

/// Euclidean norm as f64.
pub fn norm2(a: &[i64]) -> f64 {
    (norm2_sq(a) as f64).sqrt()
}

/// L1 (taxicab) norm — the norm Figure 5B uses for "short" vectors.
pub fn norm1(a: &[i64]) -> i64 {
    a.iter().map(|&x| x.abs()).sum()
}

/// L∞ norm.
pub fn norm_inf(a: &[i64]) -> i64 {
    a.iter().map(|&x| x.abs()).max().unwrap_or(0)
}

/// a - k*b in place.
pub fn sub_scaled(a: &mut [i64], b: &[i64], k: i64) {
    for (x, &y) in a.iter_mut().zip(b) {
        *x -= k * y;
    }
}

/// Is this the zero vector?
pub fn is_zero(a: &[i64]) -> bool {
    a.iter().all(|&x| x == 0)
}

/// f64 Gram–Schmidt orthogonalization of an integer basis.
/// Returns (`gso`, `mu`) where `gso[i]` is b*_i and `mu[i][j]` (j<i) are the
/// projection coefficients; exactly the quantities LLL needs.
pub fn gram_schmidt(basis: &[IntVec]) -> (Vec<Vec<f64>>, Vec<Vec<f64>>) {
    let n = basis.len();
    let d = if n > 0 { basis[0].len() } else { 0 };
    let mut gso: Vec<Vec<f64>> = Vec::with_capacity(n);
    let mut mu = vec![vec![0.0; n]; n];
    for i in 0..n {
        let mut v: Vec<f64> = basis[i].iter().map(|&x| x as f64).collect();
        for j in 0..i {
            let denom: f64 = gso[j].iter().map(|x| x * x).sum();
            let num: f64 = basis[i].iter().zip(&gso[j]).map(|(&x, y)| x as f64 * y).sum();
            let m = if denom > 0.0 { num / denom } else { 0.0 };
            mu[i][j] = m;
            for k in 0..d {
                v[k] -= m * gso[j][k];
            }
        }
        gso.push(v);
    }
    (gso, mu)
}

/// Determinant of a square integer matrix (rows = vectors), via fraction-free
/// Bareiss elimination — exact for the sizes we use.
pub fn det(rows: &[IntVec]) -> i128 {
    let n = rows.len();
    assert!(rows.iter().all(|r| r.len() == n), "det requires a square matrix");
    let mut m: Vec<Vec<i128>> = rows.iter().map(|r| r.iter().map(|&x| x as i128).collect()).collect();
    let mut sign = 1i128;
    let mut prev = 1i128;
    for k in 0..n {
        if m[k][k] == 0 {
            // pivot search
            let Some(p) = (k + 1..n).find(|&i| m[i][k] != 0) else {
                return 0;
            };
            m.swap(k, p);
            sign = -sign;
        }
        for i in k + 1..n {
            for j in k + 1..n {
                m[i][j] = (m[i][j] * m[k][k] - m[i][k] * m[k][j]) / prev;
            }
            m[i][k] = 0;
        }
        prev = m[k][k];
    }
    sign * m[n - 1][n - 1]
}

/// Solve the real linear system `B^T y = x` for y, i.e. express point `x` in
/// the (row-vector) basis `B`: x = Σ y_i · B_i. Gaussian elimination with
/// partial pivoting; `B` must be non-singular.
pub fn solve_in_basis(basis: &[IntVec], x: &[f64]) -> Vec<f64> {
    let n = basis.len();
    debug_assert_eq!(x.len(), n);
    // Build column matrix A with A[:, i] = basis[i] (so A y = x).
    let mut a = vec![vec![0.0f64; n + 1]; n];
    for (i, b) in basis.iter().enumerate() {
        for r in 0..n {
            a[r][i] = b[r] as f64;
        }
    }
    for r in 0..n {
        a[r][n] = x[r];
    }
    for col in 0..n {
        // pivot
        let piv = (col..n).max_by(|&i, &j| a[i][col].abs().partial_cmp(&a[j][col].abs()).unwrap()).unwrap();
        a.swap(col, piv);
        assert!(a[col][col].abs() > 1e-12, "singular basis");
        for r in 0..n {
            if r != col {
                let f = a[r][col] / a[col][col];
                for c in col..=n {
                    a[r][c] -= f * a[col][c];
                }
            }
        }
    }
    (0..n).map(|i| a[i][n] / a[i][i]).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn norms() {
        let v = vec![3, -4, 0];
        assert_eq!(norm2_sq(&v), 25);
        assert_eq!(norm2(&v), 5.0);
        assert_eq!(norm1(&v), 7);
        assert_eq!(norm_inf(&v), 4);
        assert!(is_zero(&[0, 0]));
        assert!(!is_zero(&v));
    }

    #[test]
    fn dot_large_values_no_overflow() {
        let a = vec![i64::MAX / 4, i64::MAX / 4];
        assert!(dot(&a, &a) > 0);
    }

    #[test]
    fn gram_schmidt_orthogonal() {
        let basis = vec![vec![3, 1], vec![2, 2]];
        let (gso, mu) = gram_schmidt(&basis);
        let d: f64 = gso[0].iter().zip(&gso[1]).map(|(a, b)| a * b).sum();
        assert!(d.abs() < 1e-9, "GSO vectors not orthogonal: {d}");
        assert!(mu[1][0] > 0.0);
    }

    #[test]
    fn det_identity_and_swap() {
        assert_eq!(det(&[vec![1, 0], vec![0, 1]]), 1);
        assert_eq!(det(&[vec![0, 1], vec![1, 0]]), -1);
        assert_eq!(det(&[vec![2, 0, 0], vec![0, 3, 0], vec![0, 0, 4]]), 24);
        assert_eq!(det(&[vec![1, 2], vec![2, 4]]), 0);
    }

    #[test]
    fn det_interference_basis_is_s() {
        // Eq 9 basis has determinant S.
        let s = 4096i64;
        let basis = vec![vec![s, 0, 0], vec![-91, 1, 0], vec![-91 * 100, 0, 1]];
        assert_eq!(det(&basis), s as i128);
    }

    #[test]
    fn solve_in_basis_roundtrip() {
        let basis = vec![vec![2, 1], vec![1, 3]];
        // x = 1*b0 + 2*b1 = (4, 7)
        let y = solve_in_basis(&basis, &[4.0, 7.0]);
        assert!((y[0] - 1.0).abs() < 1e-9);
        assert!((y[1] - 2.0).abs() < 1e-9);
    }

    #[test]
    #[should_panic(expected = "singular")]
    fn solve_singular_panics() {
        let basis = vec![vec![1, 2], vec![2, 4]];
        solve_in_basis(&basis, &[1.0, 1.0]);
    }
}
