//! The **interference lattice** of a grid (paper §4).
//!
//! For an array of dimensions `n_1 × … × n_d` stored column-major
//! (Fortran order, as in the paper) and a cache of size `S` words, the
//! interference lattice `L` is the set of index-space vectors
//! `(i_1, …, i_d)` with
//!
//! ```text
//! i_1 + n_1·i_2 + n_1 n_2·i_3 + … + n_1⋯n_{d-1}·i_d ≡ 0  (mod S)     (Eq 8)
//! ```
//!
//! — i.e. pairs of grid points mapping to the same cache location. `L` has
//! the explicit basis (Eq 9) `v_1 = S·e_1`, `v_i = −m_i·e_1 + e_i`, with
//! `m_i = Π_{j<i} n_j`, hence `det L = S`. Points of `L` are exactly where
//! self-interference strikes; a **reduced** basis of `L` gives the
//! fundamental parallelepiped that the cache-fitting traversal sweeps.

mod lll;
mod shortest;
pub mod vec;

pub use lll::{eccentricity, lll_reduce, satisfies_reduced_bound, DELTA};
pub use shortest::{min_l1_norm, short_vectors_by_congruence, shortest_vector};
pub use vec::IntVec;

use vec::{det, norm1, norm2, solve_in_basis};

/// The interference lattice of a grid w.r.t. a cache of `modulus` words,
/// carrying both the canonical (Eq 9) and the LLL-reduced basis.
#[derive(Debug, Clone)]
pub struct InterferenceLattice {
    dims: Vec<usize>,
    modulus: usize,
    /// m_i = Π_{j<i} n_j (m_1 = 1): the linearization strides.
    strides: Vec<i64>,
    /// Canonical basis per Eq 9.
    canonical: Vec<IntVec>,
    /// LLL-reduced basis.
    reduced: Vec<IntVec>,
}

impl InterferenceLattice {
    /// Build the lattice for `dims` and cache size `modulus` (= S in words).
    pub fn new(dims: &[usize], modulus: usize) -> InterferenceLattice {
        let d = dims.len();
        assert!(d >= 1, "need at least one dimension");
        assert!(modulus >= 2, "cache size must be >= 2 words");
        assert!(dims.iter().all(|&n| n >= 1), "dimensions must be positive");
        let mut strides = vec![1i64; d];
        for i in 1..d {
            strides[i] = strides[i - 1]
                .checked_mul(dims[i - 1] as i64)
                .expect("grid too large: linearization stride overflows i64");
        }
        let mut canonical: Vec<IntVec> = Vec::with_capacity(d);
        let mut v1 = vec![0i64; d];
        v1[0] = modulus as i64;
        canonical.push(v1);
        for i in 1..d {
            let mut v = vec![0i64; d];
            v[0] = -strides[i];
            v[i] = 1;
            canonical.push(v);
        }
        let mut reduced = canonical.clone();
        lll_reduce(&mut reduced);
        InterferenceLattice { dims: dims.to_vec(), modulus, strides, canonical, reduced }
    }

    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    pub fn modulus(&self) -> usize {
        self.modulus
    }

    /// Linearization strides m_1=1, m_2=n_1, m_3=n_1 n_2, …
    pub fn strides(&self) -> &[i64] {
        &self.strides
    }

    /// The Eq 9 basis.
    pub fn canonical_basis(&self) -> &[IntVec] {
        &self.canonical
    }

    /// The LLL-reduced basis (Eq 10 holds with c_d = 2^{d(d−1)/4}).
    pub fn reduced_basis(&self) -> &[IntVec] {
        &self.reduced
    }

    /// |det L| — always equals S (paper §4).
    pub fn determinant(&self) -> u128 {
        det(&self.reduced).unsigned_abs()
    }

    /// Membership test via Eq 8.
    pub fn contains(&self, v: &[i64]) -> bool {
        assert_eq!(v.len(), self.dims.len());
        let sum: i128 = v.iter().zip(&self.strides).map(|(&x, &m)| x as i128 * m as i128).sum();
        sum.rem_euclid(self.modulus as i128) == 0
    }

    /// Exact Euclidean-shortest nonzero vector.
    pub fn shortest(&self) -> IntVec {
        shortest_vector(&self.reduced)
    }

    /// Euclidean length of the shortest nonzero vector.
    pub fn shortest_len(&self) -> f64 {
        norm2(&self.shortest())
    }

    /// Minimum L1 norm among nonzero vectors, searched up to `max_l1`.
    pub fn min_l1(&self, max_l1: i64) -> Option<i64> {
        min_l1_norm(&self.dims, self.modulus, max_l1)
    }

    /// Eccentricity of the reduced basis (paper §4; multiplies Eq 12).
    pub fn eccentricity(&self) -> f64 {
        eccentricity(&self.reduced)
    }

    /// The paper's §6 **unfavorable** criterion: "when the shortest vector
    /// of the interference lattice is shorter than the diameter of the
    /// operator, the number of cache misses sharply increases". (The §4
    /// *upper-bound validity* condition is the weaker diameter/associativity;
    /// empirically — Figure 4's n1 = 90 spike on the 2-way R10000 — the
    /// diameter itself is the right classification bar, and Figure 5B uses
    /// an even larger horizon of 8.)
    pub fn is_unfavorable(&self, stencil_diameter: i64) -> bool {
        let bar = stencil_diameter;
        self.min_l1(bar).map(|m| m < bar).unwrap_or(false)
    }

    /// Coordinates of grid point `x` (real-valued) in the reduced basis:
    /// returns y with x = Σ y_i b_i. Used by the cache-fitting traversal to
    /// assign points to pencils.
    pub fn coords_in_reduced(&self, x: &[f64]) -> Vec<f64> {
        solve_in_basis(&self.reduced, x)
    }

    /// Sort key for choosing the sweep vector `v` in the cache-fitting
    /// algorithm: index (into the reduced basis) of the longest vector, as
    /// §5 prescribes ("the longest edge vector is selected for subdivision";
    /// sweeping along the longest edge gives the thinnest pencils ⇒ most
    /// face area parallel to the sweep, fewest boundary replacements).
    pub fn longest_basis_index(&self) -> usize {
        (0..self.reduced.len())
            .max_by(|&i, &j| {
                norm2(&self.reduced[i]).partial_cmp(&norm2(&self.reduced[j])).unwrap()
            })
            .unwrap()
    }

    /// Surface-to-volume ratio bound of the reduced fundamental
    /// parallelepiped (Eq 11): `|∂P| / det L ≤ 2 Σ_j Π_{i≠j} ‖b_i‖ / det L`.
    pub fn surface_to_volume(&self) -> f64 {
        let norms: Vec<f64> = self.reduced.iter().map(|b| norm2(b)).collect();
        let prod: f64 = norms.iter().product();
        let surface: f64 = 2.0 * norms.iter().map(|&n| prod / n).sum::<f64>();
        surface / self.determinant() as f64
    }

    /// All lattice vectors within L1 radius `r`.
    pub fn vectors_within_l1(&self, r: i64) -> Vec<IntVec> {
        short_vectors_by_congruence(&self.dims, self.modulus, r)
    }

    /// Convenience: L1 norm of v.
    pub fn l1(v: &[i64]) -> i64 {
        norm1(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn canonical_basis_matches_eq9() {
        let l = InterferenceLattice::new(&[91, 100, 64], 4096);
        assert_eq!(l.canonical_basis()[0], vec![4096, 0, 0]);
        assert_eq!(l.canonical_basis()[1], vec![-91, 1, 0]);
        assert_eq!(l.canonical_basis()[2], vec![-9100, 0, 1]);
        assert_eq!(l.strides(), &[1, 91, 9100]);
    }

    #[test]
    fn determinant_is_s() {
        for &s in &[64usize, 1024, 4096] {
            let l = InterferenceLattice::new(&[45, 91, 100], s);
            assert_eq!(l.determinant(), s as u128);
        }
    }

    #[test]
    fn reduced_basis_members_of_lattice() {
        let l = InterferenceLattice::new(&[45, 91, 100], 4096);
        for v in l.reduced_basis() {
            assert!(l.contains(v), "{v:?} not in lattice");
        }
    }

    #[test]
    fn unfavorable_grid_detection_matches_paper() {
        // Paper Fig 4: n1 = 45 and 90 are the spikes with n2 = 91.
        let cache = crate::cache::CacheParams::r10000();
        let diam = 5; // 13-pt star has radius 2 ⇒ diameter 5
        let l45 = InterferenceLattice::new(&[45, 91, 100], cache.lattice_modulus());
        // shortest vector (1,0,1) has L1 2 < 5 ⇒ unfavorable.
        assert!(l45.is_unfavorable(diam));
        // n1 = 90: shortest vector (2,0,1), L1 3 < 5 ⇒ unfavorable.
        let l90 = InterferenceLattice::new(&[90, 91, 100], cache.lattice_modulus());
        assert!(l90.is_unfavorable(diam));
        let l67 = InterferenceLattice::new(&[67, 89, 100], cache.lattice_modulus());
        assert!(!l67.is_unfavorable(diam));
    }

    #[test]
    fn shortest_vector_is_member_and_minimal_l1_consistency() {
        let l = InterferenceLattice::new(&[45, 91, 100], 4096);
        let sv = l.shortest();
        assert!(l.contains(&sv));
        assert!((l.shortest_len() - (2f64).sqrt()).abs() < 1e-9, "expected (1,0,1): {sv:?}");
    }

    #[test]
    fn coords_in_reduced_roundtrip() {
        let l = InterferenceLattice::new(&[40, 50, 60], 1024);
        let b = l.reduced_basis();
        // x = 2*b0 - 1*b1 + 3*b2
        let d = 3;
        let mut x = vec![0.0f64; d];
        for i in 0..d {
            x[i] = 2.0 * b[0][i] as f64 - b[1][i] as f64 + 3.0 * b[2][i] as f64;
        }
        let y = l.coords_in_reduced(&x);
        assert!((y[0] - 2.0).abs() < 1e-8);
        assert!((y[1] + 1.0).abs() < 1e-8);
        assert!((y[2] - 3.0).abs() < 1e-8);
    }

    #[test]
    fn surface_to_volume_obeys_eq11() {
        // Eq 11: |∂P|/det ≤ e·c'_d·S^{-1/d} with c'_d = 2d·c_d,
        // c_d = 2^{d(d-1)/4}.
        for dims in [[40usize, 91, 100], [64, 64, 64], [45, 91, 100]] {
            let s = 4096usize;
            let l = InterferenceLattice::new(&dims, s);
            let d = 3.0;
            let c_d = 2f64.powf(d * (d - 1.0) / 4.0);
            let bound = l.eccentricity() * 2.0 * d * c_d * (s as f64).powf(-1.0 / d);
            assert!(
                l.surface_to_volume() <= bound + 1e-9,
                "eq11 violated for {dims:?}: {} > {}",
                l.surface_to_volume(),
                bound
            );
        }
    }

    #[test]
    fn one_dimensional_lattice() {
        let l = InterferenceLattice::new(&[100], 64);
        assert_eq!(l.canonical_basis(), &[vec![64]]);
        assert!(l.contains(&[128]));
        assert!(!l.contains(&[96]));
        assert_eq!(l.shortest(), vec![64]);
    }

    #[test]
    fn property_shortest_is_shortest_among_sampled_members() {
        use crate::util::proptest::{forall, DimsGen};
        forall(7, 30, &DimsGen { d: 3, lo: 20, hi: 120 }, |dims| {
            let l = InterferenceLattice::new(dims, 1024);
            let sv_len_sq = vec::norm2_sq(&l.shortest());
            // every random small combination of basis vectors must be >= sv
            let mut rng = crate::util::rng::Rng::new(dims.iter().sum::<usize>() as u64);
            for _ in 0..50 {
                let c: Vec<i64> = (0..3).map(|_| rng.range_inclusive(-4, 4)).collect();
                let b = l.reduced_basis();
                let mut v = vec![0i64; 3];
                for i in 0..3 {
                    for k in 0..3 {
                        v[k] += c[i] * b[i][k];
                    }
                }
                if !vec::is_zero(&v) && vec::norm2_sq(&v) < sv_len_sq {
                    return false;
                }
            }
            true
        });
    }
}
