//! Reporting: aligned text tables, markdown tables, CSV, and ASCII line
//! plots. The experiment drivers print the same rows/series the paper's
//! figures show; EXPERIMENTS.md embeds this output.

mod plot;
mod table;

pub use plot::AsciiPlot;
pub use table::Table;

use crate::cache::{Latency, LoadProfile};

/// Render a per-level [`LoadProfile`] as a table: one row per memory
/// level with the §2 counters, per-point rates, and that level's share of
/// the stall estimate — what `stencilcache analyze --machine=<preset>`
/// prints for hierarchical machines.
pub fn load_profile_table(title: &str, profile: &LoadProfile, points: u64, latency: Latency) -> Table {
    let mut t = Table::new(title, &["level", "accesses", "misses", "misses/pt", "cold", "replacement", "stall-cycles"]);
    let pts = points.max(1) as f64;
    for lv in profile.levels() {
        // isolate this level's stall contribution by zeroing the others
        let solo = {
            let mut p = LoadProfile::default();
            for other in profile.levels() {
                p.push(other.level, if other.level == lv.level { other.stats } else { Default::default() });
            }
            p.stall_cycles(latency)
        };
        t.add_row(vec![
            lv.level.name().into(),
            lv.stats.accesses.to_string(),
            lv.stats.misses().to_string(),
            format!("{:.4}", lv.stats.misses() as f64 / pts),
            lv.stats.cold_misses.to_string(),
            lv.stats.replacement_misses.to_string(),
            solo.to_string(),
        ]);
    }
    t
}

/// Write string content to a file, creating parent directories.
pub fn write_file(path: &std::path::Path, content: &str) -> std::io::Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, content)
}

#[cfg(test)]
mod profile_tests {
    use super::*;
    use crate::cache::{CacheStats, Level};

    #[test]
    fn per_level_rows_and_stall_shares_sum() {
        let mut p = LoadProfile::default();
        let mk = |cold: u64, repl: u64| CacheStats {
            accesses: 100,
            hits: 100 - cold - repl,
            cold_misses: cold,
            replacement_misses: repl,
            ..CacheStats::default()
        };
        p.push(Level::L1, mk(10, 5));
        p.push(Level::L2, mk(3, 1));
        p.push(Level::Tlb, mk(2, 0));
        let lat = Latency { l2: 10, mem: 100, tlb: 50, prefetch: 0, remote: 300 };
        let t = load_profile_table("profile", &p, 50, lat);
        assert_eq!(t.num_rows(), 3);
        assert_eq!(t.rows()[0][0], "L1");
        let share_sum: u64 = t.rows().iter().map(|r| r[6].parse::<u64>().unwrap()).sum();
        assert_eq!(share_sum, p.stall_cycles(lat));
    }
}
