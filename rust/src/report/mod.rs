//! Reporting: aligned text tables, markdown tables, CSV, and ASCII line
//! plots. The experiment drivers print the same rows/series the paper's
//! figures show; EXPERIMENTS.md embeds this output.

mod plot;
mod table;

pub use plot::AsciiPlot;
pub use table::Table;

/// Write string content to a file, creating parent directories.
pub fn write_file(path: &std::path::Path, content: &str) -> std::io::Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, content)
}
