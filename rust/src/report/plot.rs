//! ASCII line/scatter plots for terminal output of the paper's figures.
//! Multiple named series share one canvas; values are auto-scaled.

/// An ASCII plot canvas. X values are the series index positions mapped to
/// columns; each series gets a distinct glyph.
pub struct AsciiPlot {
    title: String,
    width: usize,
    height: usize,
    series: Vec<(String, char, Vec<(f64, f64)>)>,
}

const GLYPHS: &[char] = &['*', '+', 'o', 'x', '#', '@'];

impl AsciiPlot {
    pub fn new(title: &str, width: usize, height: usize) -> AsciiPlot {
        AsciiPlot { title: title.to_string(), width: width.max(16), height: height.max(4), series: Vec::new() }
    }

    /// Add a named series of (x, y) points.
    pub fn series(&mut self, name: &str, points: Vec<(f64, f64)>) -> &mut Self {
        let glyph = GLYPHS[self.series.len() % GLYPHS.len()];
        self.series.push((name.to_string(), glyph, points));
        self
    }

    /// Render to a string. Empty plots render a placeholder.
    pub fn render(&self) -> String {
        let all: Vec<(f64, f64)> =
            self.series.iter().flat_map(|(_, _, pts)| pts.iter().copied()).filter(|(x, y)| x.is_finite() && y.is_finite()).collect();
        if all.is_empty() {
            return format!("{}\n  (no data)\n", self.title);
        }
        let (mut xmin, mut xmax) = (f64::INFINITY, f64::NEG_INFINITY);
        let (mut ymin, mut ymax) = (f64::INFINITY, f64::NEG_INFINITY);
        for &(x, y) in &all {
            xmin = xmin.min(x);
            xmax = xmax.max(x);
            ymin = ymin.min(y);
            ymax = ymax.max(y);
        }
        if (xmax - xmin).abs() < f64::EPSILON {
            xmax = xmin + 1.0;
        }
        if (ymax - ymin).abs() < f64::EPSILON {
            ymax = ymin + 1.0;
        }
        let mut canvas = vec![vec![' '; self.width]; self.height];
        for (_, glyph, pts) in &self.series {
            for &(x, y) in pts {
                if !x.is_finite() || !y.is_finite() {
                    continue;
                }
                let col = ((x - xmin) / (xmax - xmin) * (self.width - 1) as f64).round() as usize;
                let row = ((y - ymin) / (ymax - ymin) * (self.height - 1) as f64).round() as usize;
                let r = self.height - 1 - row.min(self.height - 1);
                canvas[r][col.min(self.width - 1)] = *glyph;
            }
        }
        let mut out = String::new();
        out.push_str(&format!("{}\n", self.title));
        let legend: Vec<String> = self.series.iter().map(|(n, g, _)| format!("{g} {n}")).collect();
        out.push_str(&format!("  [{}]\n", legend.join("   ")));
        for (i, row) in canvas.iter().enumerate() {
            let label = if i == 0 {
                format!("{ymax:>10.3}")
            } else if i == self.height - 1 {
                format!("{ymin:>10.3}")
            } else {
                " ".repeat(10)
            };
            out.push_str(&format!("{label} |{}\n", row.iter().collect::<String>()));
        }
        out.push_str(&format!("{:>10} +{}\n", "", "-".repeat(self.width)));
        out.push_str(&format!("{:>12}{:<width$}{:>8}\n", format!("{xmin:.1}"), "", format!("{xmax:.1}"), width = self.width.saturating_sub(8)));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_plot() {
        let p = AsciiPlot::new("empty", 40, 10);
        assert!(p.render().contains("(no data)"));
    }

    #[test]
    fn renders_points_in_canvas() {
        let mut p = AsciiPlot::new("line", 40, 10);
        p.series("up", (0..10).map(|i| (i as f64, i as f64)).collect());
        let r = p.render();
        assert!(r.contains('*'));
        assert!(r.contains("up"));
        // y axis labels present
        assert!(r.contains("9.000"));
        assert!(r.contains("0.000"));
    }

    #[test]
    fn two_series_get_distinct_glyphs() {
        let mut p = AsciiPlot::new("two", 30, 8);
        p.series("a", vec![(0.0, 0.0), (1.0, 1.0)]);
        p.series("b", vec![(0.0, 1.0), (1.0, 0.0)]);
        let r = p.render();
        assert!(r.contains('*') && r.contains('+'));
    }

    #[test]
    fn constant_series_no_division_by_zero() {
        let mut p = AsciiPlot::new("flat", 30, 6);
        p.series("c", vec![(0.0, 5.0), (1.0, 5.0), (2.0, 5.0)]);
        let r = p.render();
        assert!(r.contains('*'));
    }

    #[test]
    fn nonfinite_points_skipped() {
        let mut p = AsciiPlot::new("nan", 30, 6);
        p.series("s", vec![(0.0, f64::NAN), (1.0, 2.0)]);
        let r = p.render();
        assert!(r.contains('*'));
    }
}
