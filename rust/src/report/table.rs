//! Column-aligned text / markdown / CSV tables.

/// A simple table builder: set headers, push rows of strings, render.
#[derive(Debug, Clone, Default)]
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table { title: title.to_string(), headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn title(&self) -> &str {
        &self.title
    }

    pub fn add_row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity must match headers");
        self.rows.push(cells);
    }

    /// Convenience: row from Display items.
    pub fn row(&mut self, cells: &[&dyn std::fmt::Display]) {
        self.add_row(cells.iter().map(|c| c.to_string()).collect());
    }

    pub fn num_rows(&self) -> usize {
        self.rows.len()
    }

    pub fn rows(&self) -> &[Vec<String>] {
        &self.rows
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        w
    }

    /// Render as an aligned plain-text table.
    pub fn to_text(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>width$}", c, width = w[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&"-".repeat(w.iter().sum::<usize>() + 2 * (w.len().saturating_sub(1))));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    /// Render as a GitHub-flavored markdown table (for EXPERIMENTS.md).
    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("**{}**\n\n", self.title));
        }
        out.push_str(&format!("| {} |\n", self.headers.join(" | ")));
        out.push_str(&format!("|{}\n", "---|".repeat(self.headers.len())));
        for row in &self.rows {
            out.push_str(&format!("| {} |\n", row.join(" | ")));
        }
        out
    }

    /// Render as CSV (RFC-4180-ish; quotes cells containing commas/quotes).
    pub fn to_csv(&self) -> String {
        let esc = |c: &str| -> String {
            if c.contains(',') || c.contains('"') || c.contains('\n') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.to_string()
            }
        };
        let mut out = String::new();
        out.push_str(&self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Table {
        let mut t = Table::new("demo", &["n1", "misses", "ratio"]);
        t.add_row(vec!["40".into(), "123456".into(), "3.50".into()]);
        t.add_row(vec!["41".into(), "99".into(), "3.41".into()]);
        t
    }

    #[test]
    fn text_alignment() {
        let txt = sample().to_text();
        assert!(txt.contains("== demo =="));
        let lines: Vec<&str> = txt.lines().collect();
        // header + separator + 2 rows + title
        assert_eq!(lines.len(), 5);
        // right-aligned: "99" should be padded to the width of "misses"/123456
        assert!(lines[3].contains("    99") || lines[4].contains("    99"));
    }

    #[test]
    fn markdown_shape() {
        let md = sample().to_markdown();
        assert!(md.contains("| n1 | misses | ratio |"));
        assert!(md.contains("|---|---|---|"));
        assert!(md.contains("| 40 | 123456 | 3.50 |"));
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new("", &["a", "b"]);
        t.add_row(vec!["x,y".into(), "q\"t".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"q\"\"t\""));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_mismatch_panics() {
        let mut t = Table::new("", &["a", "b"]);
        t.add_row(vec!["only-one".into()]);
    }
}
