//! `stencilcache` CLI — the L3 leader entrypoint.
//!
//! Subcommands:
//!
//! ```text
//! stencilcache analyze --dims 45,91,100 [--machine r10000|r10000-full|modern]
//!                      [--cache 2,512,4] [--rhs 1]
//!     lattice analysis (cache-line + page lattices) + padding advice +
//!     simulated misses per traversal; hierarchical machines additionally
//!     report per-level loads and a stall-cycle estimate
//! stencilcache experiment <fig4|fig5a|fig5b|fig5corr|sec3|bounds|multirhs|appb|all> [--quick]
//!     regenerate a paper figure/table
//! stencilcache solve --n 64 --steps 100 [--shard-grid 2,2,2] [--ram-budget-mb 256]
//!                    [--prefetch-distance W] [--time-tile K] [--numa]
//!     run the heat solver (PJRT when artifacts exist, native otherwise).
//!     --shard-grid forces the block decomposition (DESIGN.md §2.9);
//!     --ram-budget-mb caps resident field memory — solves whose working
//!     set exceeds it run out-of-core over disk tiles.
//!     --prefetch-distance overrides how many words ahead the native row
//!     kernel software-prefetches (0 disables; default: the machine
//!     model's choice, see DESIGN.md §2.11).
//!     --time-tile forces the sharded superstep depth k (halos deepen to
//!     k·r and shards exchange once per k steps; default: the planner
//!     chooses k from the machine model, see DESIGN.md §2.12).
//!     --numa pins shard workers to cores so first-touch keeps each
//!     shard's pages on its worker's node.
//! stencilcache serve-demo [--requests 64]
//!     demo of the serving layer (submit/drain) over a mixed workload
//! stencilcache serve [--port 7077] [--cap 64] [--workers N]
//!     run the JSON-over-TCP front end (newline-delimited requests, see
//!     README "Network serving"). --cap bounds in-flight requests; excess
//!     arrivals answer a typed "overloaded" response. Stops cleanly on a
//!     {"kind":"shutdown"} request.
//! stencilcache serve-smoke
//!     end-to-end smoke of the TCP front end against itself: malformed
//!     lines, an injected worker panic, a duplicate-key burst (asserts
//!     single-flight collapse), and an overload burst against a cap-1
//!     server (asserts shed + recovery). Exits non-zero on any failure.
//! stencilcache replay [--requests 600] [--hot 8] [--scan 48] [--zipf 1.1]
//!                     [--seed N] [--memo-bytes 32768] [--quick]
//!     replay a deterministic Zipf+scan trace through the memoizing
//!     service; prints per-phase memo hit rates and latencies. Exits
//!     non-zero if the memo tier never hits (CI smoke gate).
//! stencilcache replay --open-loop [--rate 2000] [--burst 32] [--cap 32]
//!                     [--requests 480] [--workers 4] [--quick]
//!     open-loop arrivals (Poisson, or bursty with --burst > 1) against a
//!     bounded-admission service: sojourn tail measured from the scheduled
//!     arrival times, shed rate, and single-flight collapse count.
//! stencilcache bench-gate --baseline BENCH_NUMERIC.json --current fresh.json [--tolerance 2.0]
//!     compare a fresh bench snapshot against a committed baseline; exits
//!     non-zero on a throughput regression beyond the tolerance factor or
//!     any increase in a modelled words/point metric. Baseline entries
//!     tagged "provisional" are report-only.
//! stencilcache bench-gate --bless --baseline BENCH_NUMERIC.json [--current fresh.json]
//!     re-bless the committed baseline: copy the fresh snapshot (--current,
//!     or the STENCILCACHE_BENCH_JSON path) over it with "provisional"
//!     tags cleared, so future regressions gate hard.
//! stencilcache info
//!     artifact + platform report
//! ```

use stencilcache::cache::{CacheParams, MachineModel};
use stencilcache::coordinator::{Coordinator, JobKind, PlannerConfig, Service, StencilRequest, StencilSpec, TraversalChoice};
use stencilcache::report;
use stencilcache::runtime::RuntimeService;
use stencilcache::util::cli::Args;
use stencilcache::util::logger;

fn main() {
    logger::init();
    let args = match Args::from_env(&["quick", "verbose", "no-auto-pad", "bless", "open-loop", "numa"]) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    if args.flag("verbose") {
        logger::set_level(logger::Level::Debug);
    }
    let code = match args.command() {
        Some("analyze") => cmd_analyze(&args),
        Some("experiment") => cmd_experiment(&args),
        Some("solve") => cmd_solve(&args),
        Some("serve-demo") => cmd_serve_demo(&args),
        Some("serve") => cmd_serve(&args),
        Some("serve-smoke") => cmd_serve_smoke(),
        Some("replay") => cmd_replay(&args),
        Some("bench-gate") => cmd_bench_gate(&args),
        Some("info") => cmd_info(),
        _ => {
            eprintln!(
                "usage: stencilcache <analyze|experiment|solve|serve-demo|serve|serve-smoke|replay|bench-gate|info> [options]"
            );
            eprintln!("       see rust/src/main.rs docs for options");
            2
        }
    };
    std::process::exit(code);
}

fn parse_cache(args: &Args) -> Result<CacheParams, String> {
    let spec = args.get_dims("cache", &[2, 512, 4])?;
    if spec.len() != 3 {
        return Err("--cache expects a,z,w".into());
    }
    Ok(CacheParams::new(spec[0], spec[1], spec[2]))
}

/// Resolve `--machine <preset>` / `--cache a,z,w` into a machine
/// descriptor: a named preset when `--machine` is given (validated against
/// [`MachineModel::preset_names`]), a single-level machine around
/// `--cache` otherwise.
fn parse_machine(args: &Args) -> Result<MachineModel, String> {
    if args.get("machine").is_some() {
        if args.get("cache").is_some() {
            return Err("--machine and --cache are mutually exclusive (a preset fixes the L1 geometry)".into());
        }
        let name = args.get_choice("machine", MachineModel::preset_names(), "r10000")?;
        Ok(MachineModel::preset(name).expect("validated preset"))
    } else {
        Ok(MachineModel::l1_only(parse_cache(args)?))
    }
}

fn cmd_analyze(args: &Args) -> i32 {
    let run = || -> Result<(), String> {
        let dims = args.get_dims("dims", &[45, 91, 100])?;
        let machine = parse_machine(args)?;
        let rhs = args.get_usize("rhs", 1)?;
        let config = PlannerConfig {
            machine: machine.clone(),
            max_pad: args.get_usize("max-pad", 8)?,
            auto_pad: !args.flag("no-auto-pad"),
            ..PlannerConfig::default()
        };
        let coord = Coordinator::analysis_only(config);
        let stencil = if dims.len() == 3 { StencilSpec::Star13 } else { StencilSpec::Star { r: 1 } };

        println!("== plan ({}) ==", machine.name);
        let plan_resp = coord
            .submit(&StencilRequest { dims: dims.clone(), stencil: stencil.clone(), rhs_arrays: rhs, kind: JobKind::Plan })
            .map_err(|e| e.to_string())?;
        println!("{:#?}", plan_resp.plan);

        for (label, kind) in [
            ("natural", JobKind::AnalyzeWith(TraversalChoice::Natural)),
            ("cache-fitting", JobKind::AnalyzeWith(TraversalChoice::CacheFitting)),
        ] {
            let resp = coord
                .submit(&StencilRequest { dims: dims.clone(), stencil: stencil.clone(), rhs_arrays: rhs, kind })
                .map_err(|e| e.to_string())?;
            let rep = resp.miss_report.unwrap();
            println!(
                "{label:>14}: misses {} ({:.3}/pt), u-loads {} ({:.3}/pt)  [{} µs]",
                rep.total.misses(),
                rep.misses_per_point(),
                rep.u_loads,
                rep.u_loads_per_point(),
                resp.wall_micros
            );
            if machine.is_hierarchical() {
                let t = report::load_profile_table(
                    &format!("per-level loads ({label})"),
                    &rep.levels,
                    rep.points,
                    machine.latency,
                );
                println!("{}", t.to_text());
                let stall = rep.levels.stall_cycles(machine.latency);
                println!(
                    "{label:>14}: stall estimate ≈ {stall} cycles ({:.2}/pt)\n",
                    stall as f64 / rep.points.max(1) as f64
                );
            }
        }
        println!("\n== metrics ==\n{}", coord.metrics_json());
        Ok(())
    };
    match run() {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("analyze: {e}");
            1
        }
    }
}

fn cmd_experiment(args: &Args) -> i32 {
    let id = args.positional().get(1).map(|s| s.as_str()).unwrap_or("all");
    match stencilcache::experiments::run(id, args.flag("quick")) {
        Ok(tables) => {
            println!("\n(experiment {id} complete; {} table(s) printed, CSVs under results/)", tables.len());
            0
        }
        Err(e) => {
            eprintln!("experiment: {e}");
            1
        }
    }
}

fn cmd_solve(args: &Args) -> i32 {
    let run = || -> Result<(), String> {
        let n = args.get_usize("n", 64)?;
        let steps = args.get_usize("steps", 100)?;
        let shard_grid = match args.get("shard-grid") {
            Some(_) => Some(args.get_dims("shard-grid", &[])?),
            None => None,
        };
        let ram_budget_mb = args.get_usize("ram-budget-mb", 0)?;
        // --ram-budget-mb caps the *field* working set in f64 words; the
        // planner flips the solve out-of-core when 2·N³ words exceed it.
        let ram_budget_words = (ram_budget_mb > 0).then(|| ram_budget_mb as u64 * (1 << 20) / 8);
        // --prefetch-distance overrides the machine model's choice of how
        // many words ahead the row kernel prefetches (0 disables).
        let prefetch_distance = match args.get("prefetch-distance") {
            Some(_) => Some(args.get_usize("prefetch-distance", 0)?),
            None => None,
        };
        // --time-tile forces the sharded superstep depth; without it the
        // planner picks k from the machine model (DESIGN.md §2.12).
        let time_tile = match args.get("time-tile") {
            Some(_) => Some(args.get_usize("time-tile", 1)?.max(1)),
            None => None,
        };
        let numa = args.flag("numa");
        let mk_config = || PlannerConfig {
            shard_grid: shard_grid.clone(),
            ram_budget_words,
            prefetch_distance,
            time_tile,
            numa,
            ..PlannerConfig::default()
        };
        // PJRT when artifacts are available, the native backend otherwise;
        // surface the startup error so broken artifact setups stay visible.
        let svc = match RuntimeService::start(None) {
            Ok(s) => Some(s),
            Err(e) => {
                println!("(PJRT runtime unavailable: {e} — solving on the native numeric backend)");
                None
            }
        };
        let coord = match &svc {
            Some(s) => Coordinator::with_runtime(mk_config(), s.handle()),
            None => Coordinator::analysis_only(mk_config()),
        };
        let resp = coord
            .submit(&StencilRequest {
                dims: vec![n, n, n],
                stencil: StencilSpec::Star13,
                rhs_arrays: 1,
                kind: JobKind::Solve { steps },
            })
            .map_err(|e| e.to_string())?;
        // mirrors the coordinator's routing: the decomposed path engages
        // only on an explicit shard grid or an out-of-core verdict
        if shard_grid.is_some() || resp.plan.out_of_core {
            println!(
                "(block-decomposed solve: shard grid {:?}, time tile k={}{})",
                resp.plan.shard_grid,
                resp.plan.shard_time_tile,
                if resp.plan.out_of_core { ", out-of-core disk tiles" } else { "" }
            );
        }
        println!("step   ||u||        ||Ku||       µs");
        for s in resp.solve_log.iter().step_by((steps / 20).max(1)) {
            println!("{:>4}  {:>11.5}  {:>11.5}  {:>7}", s.step, s.u_norm, s.residual_norm, s.micros);
        }
        let total_us: u64 = resp.solve_log.iter().map(|s| s.micros).sum::<u64>().max(1);
        let pts = (n * n * n) as f64 * steps as f64;
        println!(
            "\nsolved {n}³ × {steps} steps in {:.2} ms  ({:.1} Mpoint/s end-to-end)",
            total_us as f64 / 1e3,
            pts / total_us as f64
        );
        println!("\n{}", coord.metrics_json());
        Ok(())
    };
    match run() {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("solve: {e}");
            1
        }
    }
}

fn cmd_serve_demo(args: &Args) -> i32 {
    let run = || -> Result<(), String> {
        let n_req = args.get_usize("requests", 24)?;
        let rt = RuntimeService::start(None).ok();
        let coord = match &rt {
            Some(s) => Coordinator::with_runtime(PlannerConfig::default(), s.handle()),
            None => {
                println!("(no artifacts — serving analysis-only workload)");
                Coordinator::analysis_only(PlannerConfig::default())
            }
        };
        let service = Service::over(coord);
        // mixed workload: plans, analyses, executes over a few shapes,
        // queued through the long-lived service and drained as one wave
        let mut rng = stencilcache::util::rng::Rng::new(1);
        for i in 0..n_req {
            let dims = *rng.choose(&[[24usize, 24, 24], [16, 16, 16], [45, 91, 20], [32, 32, 32]]);
            let kind = match i % 3 {
                0 => JobKind::Plan,
                1 => JobKind::Analyze,
                _ if rt.is_some() && dims[0] == dims[1] && dims[1] == dims[2] && [16usize, 32].contains(&dims[0]) => JobKind::Execute,
                _ => JobKind::Analyze,
            };
            service.submit(StencilRequest { dims: dims.to_vec(), stencil: StencilSpec::Star13, rhs_arrays: 1, kind });
        }
        let t0 = std::time::Instant::now();
        let resps = service.drain();
        let wall = t0.elapsed();
        let ok = resps.iter().filter(|(_, r)| r.is_ok()).count();
        println!("served {ok}/{} requests in {:.1} ms", resps.len(), wall.as_secs_f64() * 1e3);
        println!("{}", service.metrics_json());
        Ok(())
    };
    match run() {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("serve-demo: {e}");
            1
        }
    }
}

fn cmd_serve(args: &Args) -> i32 {
    use stencilcache::coordinator::{Server, ServerConfig};
    let run = || -> Result<(), String> {
        let dflt = ServerConfig::default();
        let port = args.get_usize("port", 7077)?;
        let cfg = ServerConfig {
            addr: format!("127.0.0.1:{port}"),
            max_inflight: args.get_usize("cap", dflt.max_inflight)?.max(1),
            workers: args.get_usize("workers", dflt.workers)?.max(1),
            max_line_bytes: dflt.max_line_bytes,
        };
        let svc = std::sync::Arc::new(Service::new(PlannerConfig::default()));
        let mut server = Server::start(svc, cfg).map_err(|e| e.to_string())?;
        println!(
            "stencilcache serving on {} — newline-delimited JSON, kind = plan|analyze|analyze_with|execute|solve|metrics|shutdown",
            server.addr()
        );
        server.wait(); // returns when a wire shutdown (or signal) stops the accept loop
        server.shutdown();
        println!("server stopped");
        Ok(())
    };
    match run() {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("serve: {e}");
            1
        }
    }
}

/// Minimal line-protocol client for the smoke harness.
struct SmokeClient {
    stream: std::net::TcpStream,
    reader: std::io::BufReader<std::net::TcpStream>,
}

impl SmokeClient {
    fn connect(addr: std::net::SocketAddr) -> Result<SmokeClient, String> {
        let stream = std::net::TcpStream::connect(addr).map_err(|e| format!("connect {addr}: {e}"))?;
        stream
            .set_read_timeout(Some(std::time::Duration::from_secs(120)))
            .map_err(|e| e.to_string())?;
        let reader = std::io::BufReader::new(stream.try_clone().map_err(|e| e.to_string())?);
        Ok(SmokeClient { stream, reader })
    }

    fn send(&mut self, line: &str) -> Result<(), String> {
        use std::io::Write;
        self.stream
            .write_all(line.as_bytes())
            .and_then(|_| self.stream.write_all(b"\n"))
            .and_then(|_| self.stream.flush())
            .map_err(|e| format!("send: {e}"))
    }

    fn recv(&mut self) -> Result<stencilcache::util::json::Json, String> {
        use std::io::BufRead;
        let mut line = String::new();
        let n = self.reader.read_line(&mut line).map_err(|e| format!("recv: {e}"))?;
        if n == 0 {
            return Err("recv: server closed the connection".into());
        }
        stencilcache::util::json::parse(line.trim()).map_err(|e| format!("recv: bad response JSON: {e}"))
    }
}

fn cmd_serve_smoke() -> i32 {
    use stencilcache::coordinator::{Server, ServerConfig};
    use stencilcache::util::json::Json;
    let is_ok = |v: &Json| v.get("ok") == Some(&Json::Bool(true));
    let error_class = |v: &Json| v.get("error").and_then(Json::as_str).unwrap_or("").to_string();
    let run = || -> Result<(), String> {
        // --- server 1: error containment + single-flight ---
        let svc = std::sync::Arc::new(Service::new(PlannerConfig::default()));
        let cfg = ServerConfig { max_inflight: 16, workers: 4, ..ServerConfig::default() };
        let mut server = Server::start(svc, cfg).map_err(|e| e.to_string())?;
        let mut c = SmokeClient::connect(server.addr())?;

        // malformed JSON answers bad_request, connection stays up
        c.send("{\"id\":1,\"kind\":\"analyze\",\"dims\":[16,16")?;
        let r = c.recv()?;
        if is_ok(&r) || error_class(&r) != "bad_request" {
            return Err(format!("malformed line: expected bad_request, got {r}"));
        }
        // semantically invalid request (star13 is 3-D)
        c.send("{\"id\":2,\"kind\":\"analyze\",\"dims\":[16,16],\"stencil\":\"star13\"}")?;
        let r = c.recv()?;
        if is_ok(&r) || error_class(&r) != "bad_request" {
            return Err(format!("invalid request: expected bad_request, got {r}"));
        }
        // injected worker panic answers internal; the server keeps serving
        c.send("{\"id\":3,\"kind\":\"chaos_panic\"}")?;
        let r = c.recv()?;
        if is_ok(&r) || error_class(&r) != "internal" {
            return Err(format!("chaos_panic: expected internal, got {r}"));
        }
        c.send("{\"id\":4,\"kind\":\"plan\",\"dims\":[16,16,16]}")?;
        let r = c.recv()?;
        if !is_ok(&r) {
            return Err(format!("post-panic plan: expected ok, got {r}"));
        }
        println!("serve-smoke: malformed / invalid / panicking requests contained; server still serving");

        // duplicate-key burst: 8 pipelined identical cold analyses must
        // collapse onto one computation. Timing-dependent (a very fast
        // leader can finish before the rest arrive), so retry on fresh
        // keys until the collapse counter moves.
        let mut collapsed = 0i64;
        for attempt in 0..10usize {
            let n = 40 + 2 * attempt;
            for i in 0..8 {
                c.send(&format!("{{\"id\":{},\"kind\":\"analyze\",\"dims\":[{n},{n},{n}]}}", 100 + i))?;
            }
            for _ in 0..8 {
                let r = c.recv()?;
                if !is_ok(&r) {
                    return Err(format!("duplicate-key burst: unexpected failure {r}"));
                }
            }
            c.send("{\"id\":999,\"kind\":\"metrics\"}")?;
            let m = c.recv()?;
            collapsed = m
                .get("metrics")
                .and_then(|j| j.get("single_flight_collapsed"))
                .and_then(Json::as_i64)
                .unwrap_or(0);
            if collapsed > 0 {
                break;
            }
        }
        if collapsed == 0 {
            return Err("single_flight_collapsed stayed 0 across 10 duplicate-key bursts".into());
        }
        println!("serve-smoke: duplicate-key burst collapsed {collapsed} request(s) onto in-flight computations");

        // clean wire shutdown
        c.send("{\"id\":5,\"kind\":\"shutdown\"}")?;
        let r = c.recv()?;
        if !is_ok(&r) {
            return Err(format!("shutdown: expected ok, got {r}"));
        }
        server.wait();
        server.shutdown();
        println!("serve-smoke: wire shutdown joined cleanly");

        // --- server 2: admission control (cap 1) ---
        let svc2 = std::sync::Arc::new(Service::new(PlannerConfig::default()));
        let cfg2 = ServerConfig { max_inflight: 1, workers: 4, ..ServerConfig::default() };
        let mut server2 = Server::start(svc2, cfg2).map_err(|e| e.to_string())?;
        let mut c2 = SmokeClient::connect(server2.addr())?;
        for i in 0..8 {
            c2.send(&format!("{{\"id\":{i},\"kind\":\"analyze\",\"dims\":[64,64,64]}}"))?;
        }
        let (mut ok, mut overloaded) = (0u32, 0u32);
        for _ in 0..8 {
            let r = c2.recv()?;
            if is_ok(&r) {
                ok += 1;
            } else if error_class(&r) == "overloaded" {
                overloaded += 1;
            } else {
                return Err(format!("overload burst: unexpected response {r}"));
            }
        }
        if ok == 0 || overloaded == 0 {
            return Err(format!("overload burst: ok {ok}, overloaded {overloaded} — expected both nonzero"));
        }
        // the cap-1 server recovers once the burst drains
        c2.send("{\"id\":9,\"kind\":\"plan\",\"dims\":[16,16,16]}")?;
        let r = c2.recv()?;
        if !is_ok(&r) {
            return Err(format!("post-overload plan: expected ok, got {r}"));
        }
        server2.shutdown();
        println!("serve-smoke: cap-1 server shed {overloaded}/8 and recovered");
        println!("serve-smoke: PASS");
        Ok(())
    };
    match run() {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("serve-smoke: FAIL: {e}");
            1
        }
    }
}

fn cmd_replay(args: &Args) -> i32 {
    use stencilcache::experiments::replay::{self, ReplayConfig};
    if args.flag("open-loop") {
        return cmd_replay_open_loop(args);
    }
    let run = || -> Result<(), String> {
        let mut cfg = ReplayConfig::paper(args.flag("quick"));
        cfg.requests = args.get_usize("requests", cfg.requests)?.max(1);
        cfg.hot = args.get_usize("hot", cfg.hot)?.max(1);
        cfg.scan = args.get_usize("scan", cfg.scan)?;
        cfg.zipf_s = args.get_f64("zipf", cfg.zipf_s)?;
        cfg.seed = args.get_usize("seed", cfg.seed as usize)? as u64;
        cfg.memo_bytes = args.get_usize("memo-bytes", cfg.memo_bytes)?;
        let out = replay::run(&cfg);
        println!("{}", out.table.to_text());
        println!(
            "overall memo hit rate: {:.1}% ({}/{} requests); hot set retained across scan: {}; evictions: {}",
            100.0 * out.hit_rate(),
            out.total_hits,
            out.total_requests,
            if out.hot_set_retained() { "yes" } else { "NO" },
            out.memo_evictions,
        );
        println!("\n== metrics ==\n{}", out.metrics_json);
        if out.total_hits == 0 {
            return Err("memo hit rate was zero — the memoization tier is not engaging".into());
        }
        Ok(())
    };
    match run() {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("replay: {e}");
            1
        }
    }
}

fn cmd_replay_open_loop(args: &Args) -> i32 {
    use stencilcache::experiments::replay::{open_loop_table, run_open_loop, Arrivals, OpenLoopConfig};
    let run = || -> Result<(), String> {
        let mut cfg = OpenLoopConfig::paper(args.flag("quick"));
        cfg.requests = args.get_usize("requests", cfg.requests)?.max(1);
        cfg.hot = args.get_usize("hot", cfg.hot)?.max(1);
        cfg.zipf_s = args.get_f64("zipf", cfg.zipf_s)?;
        cfg.seed = args.get_usize("seed", cfg.seed as usize)? as u64;
        cfg.memo_bytes = args.get_usize("memo-bytes", cfg.memo_bytes)?;
        cfg.rate_rps = args.get_f64("rate", cfg.rate_rps)?;
        cfg.inflight_cap = args.get_usize("cap", cfg.inflight_cap)?.max(1);
        cfg.workers = args.get_usize("workers", cfg.workers)?.max(1);
        let burst = args.get_usize("burst", 1)?;
        if burst > 1 {
            cfg.arrivals = Arrivals::Bursty { burst };
        }
        if cfg.rate_rps <= 0.0 {
            return Err("--rate must be positive".into());
        }
        let out = run_open_loop(&cfg);
        println!("{}", open_loop_table(std::slice::from_ref(&out)).to_text());
        println!(
            "completed {} / shed {} / errors {} of {} arrivals; achieved {:.0} rps; single-flight collapsed {}",
            out.completed, out.shed, out.errors, out.requests, out.achieved_rps, out.collapsed
        );
        println!("\n== metrics ==\n{}", out.metrics_json);
        if out.completed == 0 {
            return Err("no request completed — the serving path is not draining".into());
        }
        Ok(())
    };
    match run() {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("replay --open-loop: {e}");
            1
        }
    }
}

fn cmd_bench_gate(args: &Args) -> i32 {
    use stencilcache::util::{bench, json};
    let run = || -> Result<bool, String> {
        let baseline = args.get("baseline").ok_or("bench-gate requires --baseline <committed BENCH_*.json>")?;
        let load = |path: &str| -> Result<json::Json, String> {
            let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
            json::parse(&text).map_err(|e| format!("{path}: {e}"))
        };
        if args.flag("bless") {
            let current = match args.get("current") {
                Some(c) => c.to_string(),
                None => bench::snapshot_path_from_env().ok_or(
                    "bench-gate --bless needs a fresh snapshot: pass --current or set STENCILCACHE_BENCH_JSON",
                )?,
            };
            let snap = bench::clear_provisional(&load(&current)?);
            bench::write_snapshot(baseline, &snap).map_err(|e| format!("{baseline}: {e}"))?;
            println!("bench-gate: blessed {current} over {baseline} (provisional tags cleared)");
            return Ok(true);
        }
        let current = args.get("current").ok_or("bench-gate requires --current <fresh snapshot>")?;
        let tolerance = args.get_f64("tolerance", 2.0)?;
        if tolerance < 1.0 {
            return Err("--tolerance must be >= 1.0 (it is a slowdown factor)".into());
        }
        let rep = bench::gate(&load(baseline)?, &load(current)?, tolerance);
        for note in &rep.notes {
            println!("note: {note}");
        }
        for failure in &rep.failures {
            eprintln!("REGRESSION: {failure}");
        }
        println!(
            "bench-gate: {} failure(s), {} note(s) at tolerance {tolerance}x ({current} vs {baseline})",
            rep.failures.len(),
            rep.notes.len()
        );
        Ok(rep.passed())
    };
    match run() {
        Ok(true) => 0,
        Ok(false) => 1,
        Err(e) => {
            eprintln!("bench-gate: {e}");
            2
        }
    }
}

fn cmd_info() -> i32 {
    println!("stencilcache {}", stencilcache::version());
    match RuntimeService::start(None) {
        Ok(svc) => {
            let h = svc.handle();
            println!("platform: {}", h.platform());
            println!("artifacts:");
            for a in h.manifest().artifacts() {
                println!("  {:<24} {:?} outputs={} — {}", a.name, a.input_shape, a.n_outputs, a.description);
            }
            0
        }
        Err(e) => {
            println!("runtime unavailable: {e}");
            println!("(run `make artifacts` to build the AOT bundle)");
            1
        }
    }
}
