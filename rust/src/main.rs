//! `stencilcache` CLI — the L3 leader entrypoint.
//!
//! Subcommands:
//!
//! ```text
//! stencilcache analyze --dims 45,91,100 [--machine r10000|r10000-full|modern]
//!                      [--cache 2,512,4] [--rhs 1]
//!     lattice analysis (cache-line + page lattices) + padding advice +
//!     simulated misses per traversal; hierarchical machines additionally
//!     report per-level loads and a stall-cycle estimate
//! stencilcache experiment <fig4|fig5a|fig5b|fig5corr|sec3|bounds|multirhs|appb|all> [--quick]
//!     regenerate a paper figure/table
//! stencilcache solve --n 64 --steps 100 [--shard-grid 2,2,2] [--ram-budget-mb 256]
//!     run the heat solver (PJRT when artifacts exist, native otherwise).
//!     --shard-grid forces the block decomposition (DESIGN.md §2.9);
//!     --ram-budget-mb caps resident field memory — solves whose working
//!     set exceeds it run out-of-core over disk tiles.
//! stencilcache serve-demo [--requests 64]
//!     demo of the serving layer (submit/drain) over a mixed workload
//! stencilcache replay [--requests 600] [--hot 8] [--scan 48] [--zipf 1.1]
//!                     [--seed N] [--memo-bytes 32768] [--quick]
//!     replay a deterministic Zipf+scan trace through the memoizing
//!     service; prints per-phase memo hit rates and latencies. Exits
//!     non-zero if the memo tier never hits (CI smoke gate).
//! stencilcache bench-gate --baseline BENCH_NUMERIC.json --current fresh.json [--tolerance 2.0]
//!     compare a fresh bench snapshot against a committed baseline; exits
//!     non-zero on a throughput regression beyond the tolerance factor or
//!     any increase in a modelled words/point metric. Baseline entries
//!     tagged "provisional" are report-only.
//! stencilcache bench-gate --bless --baseline BENCH_NUMERIC.json [--current fresh.json]
//!     re-bless the committed baseline: copy the fresh snapshot (--current,
//!     or the STENCILCACHE_BENCH_JSON path) over it with "provisional"
//!     tags cleared, so future regressions gate hard.
//! stencilcache info
//!     artifact + platform report
//! ```

use stencilcache::cache::{CacheParams, MachineModel};
use stencilcache::coordinator::{Coordinator, JobKind, PlannerConfig, Service, StencilRequest, StencilSpec, TraversalChoice};
use stencilcache::report;
use stencilcache::runtime::RuntimeService;
use stencilcache::util::cli::Args;
use stencilcache::util::logger;

fn main() {
    logger::init();
    let args = match Args::from_env(&["quick", "verbose", "no-auto-pad", "bless"]) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    if args.flag("verbose") {
        logger::set_level(logger::Level::Debug);
    }
    let code = match args.command() {
        Some("analyze") => cmd_analyze(&args),
        Some("experiment") => cmd_experiment(&args),
        Some("solve") => cmd_solve(&args),
        Some("serve-demo") => cmd_serve_demo(&args),
        Some("replay") => cmd_replay(&args),
        Some("bench-gate") => cmd_bench_gate(&args),
        Some("info") => cmd_info(),
        _ => {
            eprintln!("usage: stencilcache <analyze|experiment|solve|serve-demo|replay|bench-gate|info> [options]");
            eprintln!("       see rust/src/main.rs docs for options");
            2
        }
    };
    std::process::exit(code);
}

fn parse_cache(args: &Args) -> Result<CacheParams, String> {
    let spec = args.get_dims("cache", &[2, 512, 4])?;
    if spec.len() != 3 {
        return Err("--cache expects a,z,w".into());
    }
    Ok(CacheParams::new(spec[0], spec[1], spec[2]))
}

/// Resolve `--machine <preset>` / `--cache a,z,w` into a machine
/// descriptor: a named preset when `--machine` is given (validated against
/// [`MachineModel::preset_names`]), a single-level machine around
/// `--cache` otherwise.
fn parse_machine(args: &Args) -> Result<MachineModel, String> {
    if args.get("machine").is_some() {
        if args.get("cache").is_some() {
            return Err("--machine and --cache are mutually exclusive (a preset fixes the L1 geometry)".into());
        }
        let name = args.get_choice("machine", MachineModel::preset_names(), "r10000")?;
        Ok(MachineModel::preset(name).expect("validated preset"))
    } else {
        Ok(MachineModel::l1_only(parse_cache(args)?))
    }
}

fn cmd_analyze(args: &Args) -> i32 {
    let run = || -> Result<(), String> {
        let dims = args.get_dims("dims", &[45, 91, 100])?;
        let machine = parse_machine(args)?;
        let rhs = args.get_usize("rhs", 1)?;
        let config = PlannerConfig {
            machine: machine.clone(),
            max_pad: args.get_usize("max-pad", 8)?,
            auto_pad: !args.flag("no-auto-pad"),
            ..PlannerConfig::default()
        };
        let coord = Coordinator::analysis_only(config);
        let stencil = if dims.len() == 3 { StencilSpec::Star13 } else { StencilSpec::Star { r: 1 } };

        println!("== plan ({}) ==", machine.name);
        let plan_resp = coord
            .submit(&StencilRequest { dims: dims.clone(), stencil: stencil.clone(), rhs_arrays: rhs, kind: JobKind::Plan })
            .map_err(|e| e.to_string())?;
        println!("{:#?}", plan_resp.plan);

        for (label, kind) in [
            ("natural", JobKind::AnalyzeWith(TraversalChoice::Natural)),
            ("cache-fitting", JobKind::AnalyzeWith(TraversalChoice::CacheFitting)),
        ] {
            let resp = coord
                .submit(&StencilRequest { dims: dims.clone(), stencil: stencil.clone(), rhs_arrays: rhs, kind })
                .map_err(|e| e.to_string())?;
            let rep = resp.miss_report.unwrap();
            println!(
                "{label:>14}: misses {} ({:.3}/pt), u-loads {} ({:.3}/pt)  [{} µs]",
                rep.total.misses(),
                rep.misses_per_point(),
                rep.u_loads,
                rep.u_loads_per_point(),
                resp.wall_micros
            );
            if machine.is_hierarchical() {
                let t = report::load_profile_table(
                    &format!("per-level loads ({label})"),
                    &rep.levels,
                    rep.points,
                    machine.latency,
                );
                println!("{}", t.to_text());
                let stall = rep.levels.stall_cycles(machine.latency);
                println!(
                    "{label:>14}: stall estimate ≈ {stall} cycles ({:.2}/pt)\n",
                    stall as f64 / rep.points.max(1) as f64
                );
            }
        }
        println!("\n== metrics ==\n{}", coord.metrics_json());
        Ok(())
    };
    match run() {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("analyze: {e}");
            1
        }
    }
}

fn cmd_experiment(args: &Args) -> i32 {
    let id = args.positional().get(1).map(|s| s.as_str()).unwrap_or("all");
    match stencilcache::experiments::run(id, args.flag("quick")) {
        Ok(tables) => {
            println!("\n(experiment {id} complete; {} table(s) printed, CSVs under results/)", tables.len());
            0
        }
        Err(e) => {
            eprintln!("experiment: {e}");
            1
        }
    }
}

fn cmd_solve(args: &Args) -> i32 {
    let run = || -> Result<(), String> {
        let n = args.get_usize("n", 64)?;
        let steps = args.get_usize("steps", 100)?;
        let shard_grid = match args.get("shard-grid") {
            Some(_) => Some(args.get_dims("shard-grid", &[])?),
            None => None,
        };
        let ram_budget_mb = args.get_usize("ram-budget-mb", 0)?;
        // --ram-budget-mb caps the *field* working set in f64 words; the
        // planner flips the solve out-of-core when 2·N³ words exceed it.
        let ram_budget_words = (ram_budget_mb > 0).then(|| ram_budget_mb as u64 * (1 << 20) / 8);
        let mk_config = || PlannerConfig { shard_grid: shard_grid.clone(), ram_budget_words, ..PlannerConfig::default() };
        // PJRT when artifacts are available, the native backend otherwise;
        // surface the startup error so broken artifact setups stay visible.
        let svc = match RuntimeService::start(None) {
            Ok(s) => Some(s),
            Err(e) => {
                println!("(PJRT runtime unavailable: {e} — solving on the native numeric backend)");
                None
            }
        };
        let coord = match &svc {
            Some(s) => Coordinator::with_runtime(mk_config(), s.handle()),
            None => Coordinator::analysis_only(mk_config()),
        };
        let resp = coord
            .submit(&StencilRequest {
                dims: vec![n, n, n],
                stencil: StencilSpec::Star13,
                rhs_arrays: 1,
                kind: JobKind::Solve { steps },
            })
            .map_err(|e| e.to_string())?;
        // mirrors the coordinator's routing: the decomposed path engages
        // only on an explicit shard grid or an out-of-core verdict
        if shard_grid.is_some() || resp.plan.out_of_core {
            println!(
                "(block-decomposed solve: shard grid {:?}{})",
                resp.plan.shard_grid,
                if resp.plan.out_of_core { ", out-of-core disk tiles" } else { "" }
            );
        }
        println!("step   ||u||        ||Ku||       µs");
        for s in resp.solve_log.iter().step_by((steps / 20).max(1)) {
            println!("{:>4}  {:>11.5}  {:>11.5}  {:>7}", s.step, s.u_norm, s.residual_norm, s.micros);
        }
        let total_us: u64 = resp.solve_log.iter().map(|s| s.micros).sum::<u64>().max(1);
        let pts = (n * n * n) as f64 * steps as f64;
        println!(
            "\nsolved {n}³ × {steps} steps in {:.2} ms  ({:.1} Mpoint/s end-to-end)",
            total_us as f64 / 1e3,
            pts / total_us as f64
        );
        println!("\n{}", coord.metrics_json());
        Ok(())
    };
    match run() {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("solve: {e}");
            1
        }
    }
}

fn cmd_serve_demo(args: &Args) -> i32 {
    let run = || -> Result<(), String> {
        let n_req = args.get_usize("requests", 24)?;
        let rt = RuntimeService::start(None).ok();
        let coord = match &rt {
            Some(s) => Coordinator::with_runtime(PlannerConfig::default(), s.handle()),
            None => {
                println!("(no artifacts — serving analysis-only workload)");
                Coordinator::analysis_only(PlannerConfig::default())
            }
        };
        let service = Service::over(coord);
        // mixed workload: plans, analyses, executes over a few shapes,
        // queued through the long-lived service and drained as one wave
        let mut rng = stencilcache::util::rng::Rng::new(1);
        for i in 0..n_req {
            let dims = *rng.choose(&[[24usize, 24, 24], [16, 16, 16], [45, 91, 20], [32, 32, 32]]);
            let kind = match i % 3 {
                0 => JobKind::Plan,
                1 => JobKind::Analyze,
                _ if rt.is_some() && dims[0] == dims[1] && dims[1] == dims[2] && [16usize, 32].contains(&dims[0]) => JobKind::Execute,
                _ => JobKind::Analyze,
            };
            service.submit(StencilRequest { dims: dims.to_vec(), stencil: StencilSpec::Star13, rhs_arrays: 1, kind });
        }
        let t0 = std::time::Instant::now();
        let resps = service.drain();
        let wall = t0.elapsed();
        let ok = resps.iter().filter(|(_, r)| r.is_ok()).count();
        println!("served {ok}/{} requests in {:.1} ms", resps.len(), wall.as_secs_f64() * 1e3);
        println!("{}", service.metrics_json());
        Ok(())
    };
    match run() {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("serve-demo: {e}");
            1
        }
    }
}

fn cmd_replay(args: &Args) -> i32 {
    use stencilcache::experiments::replay::{self, ReplayConfig};
    let run = || -> Result<(), String> {
        let mut cfg = ReplayConfig::paper(args.flag("quick"));
        cfg.requests = args.get_usize("requests", cfg.requests)?.max(1);
        cfg.hot = args.get_usize("hot", cfg.hot)?.max(1);
        cfg.scan = args.get_usize("scan", cfg.scan)?;
        cfg.zipf_s = args.get_f64("zipf", cfg.zipf_s)?;
        cfg.seed = args.get_usize("seed", cfg.seed as usize)? as u64;
        cfg.memo_bytes = args.get_usize("memo-bytes", cfg.memo_bytes)?;
        let out = replay::run(&cfg);
        println!("{}", out.table.to_text());
        println!(
            "overall memo hit rate: {:.1}% ({}/{} requests); hot set retained across scan: {}; evictions: {}",
            100.0 * out.hit_rate(),
            out.total_hits,
            out.total_requests,
            if out.hot_set_retained() { "yes" } else { "NO" },
            out.memo_evictions,
        );
        println!("\n== metrics ==\n{}", out.metrics_json);
        if out.total_hits == 0 {
            return Err("memo hit rate was zero — the memoization tier is not engaging".into());
        }
        Ok(())
    };
    match run() {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("replay: {e}");
            1
        }
    }
}

fn cmd_bench_gate(args: &Args) -> i32 {
    use stencilcache::util::{bench, json};
    let run = || -> Result<bool, String> {
        let baseline = args.get("baseline").ok_or("bench-gate requires --baseline <committed BENCH_*.json>")?;
        let load = |path: &str| -> Result<json::Json, String> {
            let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
            json::parse(&text).map_err(|e| format!("{path}: {e}"))
        };
        if args.flag("bless") {
            let current = match args.get("current") {
                Some(c) => c.to_string(),
                None => bench::snapshot_path_from_env().ok_or(
                    "bench-gate --bless needs a fresh snapshot: pass --current or set STENCILCACHE_BENCH_JSON",
                )?,
            };
            let snap = bench::clear_provisional(&load(&current)?);
            bench::write_snapshot(baseline, &snap).map_err(|e| format!("{baseline}: {e}"))?;
            println!("bench-gate: blessed {current} over {baseline} (provisional tags cleared)");
            return Ok(true);
        }
        let current = args.get("current").ok_or("bench-gate requires --current <fresh snapshot>")?;
        let tolerance = args.get_f64("tolerance", 2.0)?;
        if tolerance < 1.0 {
            return Err("--tolerance must be >= 1.0 (it is a slowdown factor)".into());
        }
        let rep = bench::gate(&load(baseline)?, &load(current)?, tolerance);
        for note in &rep.notes {
            println!("note: {note}");
        }
        for failure in &rep.failures {
            eprintln!("REGRESSION: {failure}");
        }
        println!(
            "bench-gate: {} failure(s), {} note(s) at tolerance {tolerance}x ({current} vs {baseline})",
            rep.failures.len(),
            rep.notes.len()
        );
        Ok(rep.passed())
    };
    match run() {
        Ok(true) => 0,
        Ok(false) => 1,
        Err(e) => {
            eprintln!("bench-gate: {e}");
            2
        }
    }
}

fn cmd_info() -> i32 {
    println!("stencilcache {}", stencilcache::version());
    match RuntimeService::start(None) {
        Ok(svc) => {
            let h = svc.handle();
            println!("platform: {}", h.platform());
            println!("artifacts:");
            for a in h.manifest().artifacts() {
                println!("  {:<24} {:?} outputs={} — {}", a.name, a.input_shape, a.n_outputs, a.description);
            }
            0
        }
        Err(e) => {
            println!("runtime unavailable: {e}");
            println!("(run `make artifacts` to build the AOT bundle)");
            1
        }
    }
}
