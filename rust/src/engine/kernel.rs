//! The one vectorized inner kernel behind every native sweep.
//!
//! Before this module, each consumer of the engine carried its own copy of
//! the innermost loop: `apply_pencils` folded point-by-point, `tile_line`
//! fused the axpy and norms by hand, and `shard::step_shard` had a third
//! transliteration. They were kept bitwise-locked only by discipline. Now
//! there is exactly one definition of "fold the stencil over a row":
//!
//! - [`fold_point`] — the scalar reference. One point, coefficients in
//!   declaration order, `acc += c * u[base + delta]`. This is the bitwise
//!   ground truth every other path is measured against.
//! - [`fold_row`] / [`update_row`] — the row kernels. A *row* is a maximal
//!   dim-0-contiguous run of interior points (dim-0 stride is 1 by layout,
//!   so the `n` outputs are adjacent words). Rows come from
//!   [`Traversal::stream_rows`](crate::traversal::Traversal::stream_rows).
//! - [`sum_sq`] — the shared reduction used by field norms.
//!
//! ## Why lanes-across-points is bitwise-safe
//!
//! The portable path processes four *consecutive points* per step and
//! iterates coefficients sequentially, exactly like the scalar fold:
//!
//! ```text
//! for (c, delta) in stencil:          # same outer loop as fold_point
//!     for lane in 0..4:               # acc[l] += c * u[idx + delta + l]
//! ```
//!
//! Each lane therefore performs the *same IEEE-754 operations in the same
//! order* as [`fold_point`] would for that point — no horizontal add, no
//! reassociation — so the portable kernel is **bitwise identical** to the
//! scalar reference (property-tested in `tests/kernel.rs`). The array-of-4
//! body is written so the autovectorizer cannot miss it even without the
//! `simd` feature.
//!
//! ## The `simd` feature and the reassociation tolerance
//!
//! With `--features simd` on x86_64, rows dispatch at runtime (AVX2+FMA
//! detection) to an explicit `std::arch` path using `_mm256_fmadd_pd`.
//! FMA skips the intermediate rounding of `c * u + acc`, so results differ
//! from the scalar reference by accumulated rounding only: the documented
//! tolerance is **≤ 1e-12 relative** for the stencils and fields this repo
//! sweeps (|coeffs| ≤ 13, well-scaled operands). Setting
//! [`KernelCfg::strict`] forces the portable path back to bitwise.
//!
//! Within a build, all four consumers share whichever path is active, and
//! the FMA path keeps a point's value independent of its position in a row
//! (the remainder tail uses `f64::mul_add`, the scalar spelling of the
//! same fused operation) — so sequential/sharded/temporal/out-of-core
//! sweeps remain *mutually* bitwise identical even in fast mode. Norm
//! accumulations ([`update_row`]'s `u2`/`r2`) are always extracted
//! lane-by-lane in increasing-j scalar order for the same reason.
//!
//! ## Software prefetch
//!
//! [`KernelCfg::prefetch`] is a distance in *words*: each 4-point chunk
//! issues one `_mm_prefetch(T0)` for the operand line `prefetch` words
//! ahead of the chunk base, hiding the memory latency of streaming rows
//! behind the fold arithmetic (see `cache::Latency::prefetch` for the
//! model side). The planner picks the distance from the `MachineModel`
//! (`MachineModel::prefetch_distance`); 0 disables. Prefetch is a hint —
//! it never changes results — and compiles out entirely without the
//! `simd` feature.

/// Number of points a vector chunk covers. Fixed at 4 (one AVX2 `__m256d`);
/// the portable path uses the same width so chunk boundaries — and thus
/// remainder handling — are identical across paths.
pub const LANES: usize = 4;

/// Kernel execution knobs, chosen by the planner and threaded through
/// every native consumer (`NativeBackend`, the temporal tiler, the
/// shard/halo block solver).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KernelCfg {
    /// Force the portable lane-per-point path, which is bitwise identical
    /// to the scalar [`fold_point`] reference. With the `simd` feature off
    /// this is the only path, so every build without `simd` is strict by
    /// construction.
    pub strict: bool,
    /// Software-prefetch distance in words ahead of the current chunk
    /// (0 = no prefetch). Planner-chosen via
    /// `MachineModel::prefetch_distance`; only takes effect on x86_64
    /// builds with the `simd` feature.
    pub prefetch: usize,
}

impl Default for KernelCfg {
    fn default() -> KernelCfg {
        KernelCfg { strict: false, prefetch: 0 }
    }
}

impl KernelCfg {
    /// Bitwise mode: portable path regardless of build features.
    pub fn strict() -> KernelCfg {
        KernelCfg { strict: true, prefetch: 0 }
    }

    /// True when this config resolves to the explicit AVX2+FMA path on
    /// the running machine (always false without the `simd` feature).
    pub fn uses_fma(&self) -> bool {
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        {
            if !self.strict
                && std::arch::is_x86_feature_detected!("avx2")
                && std::arch::is_x86_feature_detected!("fma")
            {
                return true;
            }
        }
        false
    }
}

/// Fold the stencil at one point: `Σ coeffs[i] * u[base + deltas[i]]`,
/// accumulated in declaration order. This is the scalar **bitwise
/// reference** for every vector path (it is the pre-kernel
/// `engine::fold_point`, unchanged).
#[inline(always)]
pub(crate) fn fold_point(coeffs: &[f64], deltas: &[i64], u: &[f64], base: i64) -> f64 {
    let mut acc = 0.0;
    for (&c, &dl) in coeffs.iter().zip(deltas) {
        acc += c * u[(base + dl) as usize];
    }
    acc
}

/// Compute `out[j] = (K u)[base + j]` for a dim-0-contiguous row of
/// `out.len()` points. Portable path is bitwise identical to calling
/// [`fold_point`] per point; the `simd` fast path matches to ≤ 1e-12
/// relative (see module docs).
pub fn fold_row(coeffs: &[f64], deltas: &[i64], u: &[f64], base: i64, out: &mut [f64], cfg: &KernelCfg) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if cfg.uses_fma() {
        // SAFETY: AVX2+FMA presence was just verified at runtime.
        unsafe { fma::fold_row(coeffs, deltas, u, base, out, cfg.prefetch) };
        return;
    }
    fold_row_portable(coeffs, deltas, u, base, out, cfg.prefetch);
}

/// Fused single-step update over one dim-0-contiguous row of `n` points:
/// for every `j in 0..n` write `out[j] = src[sbase + j] + alpha * q_j`
/// (where `q_j` is the stencil fold at `sbase + j`), and accumulate
/// `acc.0 += v²`, `acc.1 += q²` **only** over `j in lo..hi` — the
/// sub-range of the row that lands in the caller's owned output region
/// (temporal tiles fold a halo-deep super-box but count norms only for
/// owned points).
///
/// Norms accumulate into the caller's *running* sums in strictly
/// increasing-`j` scalar order (lanes extracted after each chunk), so on
/// the portable path the add sequence — and therefore the result — is
/// bitwise identical to the scalar loop this replaces; on the FMA path
/// it stays *mutually* identical across sequential/sharded/temporal/
/// out-of-core consumers. `tests/shard.rs` pins the block-decomposed
/// solve's norms exactly against a flat scalar reference through this
/// property.
///
/// # Safety
/// `out` must be valid for `n` consecutive `f64` writes and must not
/// alias `src`. `lo <= hi <= n`, and every fold stays inside `src`
/// (callers pass interior rows).
#[allow(clippy::too_many_arguments)]
pub unsafe fn update_row(
    coeffs: &[f64],
    deltas: &[i64],
    src: &[f64],
    sbase: i64,
    alpha: f64,
    n: usize,
    lo: usize,
    hi: usize,
    out: *mut f64,
    acc: &mut (f64, f64),
    cfg: &KernelCfg,
) {
    debug_assert!(lo <= hi && hi <= n);
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if cfg.uses_fma() {
        // SAFETY: AVX2+FMA presence was just verified at runtime; caller
        // upholds the pointer contract.
        return fma::update_row(coeffs, deltas, src, sbase, alpha, n, lo, hi, out, acc, cfg.prefetch);
    }
    let (u2, r2) = (&mut acc.0, &mut acc.1);
    seg_portable(coeffs, deltas, src, sbase, alpha, 0, lo, out, None, cfg.prefetch);
    seg_portable(coeffs, deltas, src, sbase, alpha, lo, hi, out, Some((u2, r2)), cfg.prefetch);
    seg_portable(coeffs, deltas, src, sbase, alpha, hi, n, out, None, cfg.prefetch);
}

/// Σ v² over a slice — the one shared vector reduction for field norms
/// (`shard::field::ShardedField::norm_sq` and friends). Four independent
/// accumulators (reassociated relative to a left-to-right scalar sum, as
/// any vector reduction must be); remainder elements join the combined
/// sum through the same final accumulator. Callers that need
/// bitwise-stable norms against the scalar path (solve residuals) use
/// [`update_row`]'s j-ordered accumulation instead.
pub fn sum_sq(v: &[f64]) -> f64 {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if std::arch::is_x86_feature_detected!("avx2") && std::arch::is_x86_feature_detected!("fma") {
        // SAFETY: AVX2+FMA presence was just verified at runtime.
        return unsafe { fma::sum_sq(v) };
    }
    sum_sq_portable(v)
}

// ---------------------------------------------------------------------
// portable path (the bitwise one; also the autovectorizer target)
// ---------------------------------------------------------------------

/// Fold LANES consecutive points starting at linear index `idx`.
/// Lane `l` performs exactly the operations of `fold_point(.., idx + l)`
/// in the same order, so the result is bitwise identical per lane.
#[inline(always)]
fn fold4_portable(coeffs: &[f64], deltas: &[i64], u: &[f64], idx: usize) -> [f64; LANES] {
    let mut acc = [0.0f64; LANES];
    for (&c, &dl) in coeffs.iter().zip(deltas) {
        let s = (idx as i64 + dl) as usize;
        let w = &u[s..s + LANES];
        for (a, &wv) in acc.iter_mut().zip(w) {
            *a += c * wv;
        }
    }
    acc
}

#[inline(always)]
fn fold_row_portable(coeffs: &[f64], deltas: &[i64], u: &[f64], base: i64, out: &mut [f64], dist: usize) {
    let n = out.len();
    let mut j = 0;
    while j + LANES <= n {
        let idx = (base + j as i64) as usize;
        prefetch_ahead(u, idx, dist);
        out[j..j + LANES].copy_from_slice(&fold4_portable(coeffs, deltas, u, idx));
        j += LANES;
    }
    while j < n {
        out[j] = fold_point(coeffs, deltas, u, base + j as i64);
        j += 1;
    }
}

/// One segment of a fused row update: fold+axpy+write `j0..j1`, with
/// optional (u2, r2) accumulation in increasing-j order.
#[inline(always)]
unsafe fn seg_portable(
    coeffs: &[f64],
    deltas: &[i64],
    src: &[f64],
    sbase: i64,
    alpha: f64,
    j0: usize,
    j1: usize,
    out: *mut f64,
    mut norms: Option<(&mut f64, &mut f64)>,
    dist: usize,
) {
    let mut j = j0;
    while j + LANES <= j1 {
        let idx = (sbase + j as i64) as usize;
        prefetch_ahead(src, idx, dist);
        let q = fold4_portable(coeffs, deltas, src, idx);
        let w = &src[idx..idx + LANES];
        let mut v = [0.0f64; LANES];
        for l in 0..LANES {
            v[l] = w[l] + alpha * q[l];
            out.add(j + l).write(v[l]);
        }
        if let Some((u2, r2)) = norms.as_mut() {
            // lane extraction in increasing-j order keeps the norm sums
            // bitwise equal to the scalar loop
            for l in 0..LANES {
                **u2 += v[l] * v[l];
                **r2 += q[l] * q[l];
            }
        }
        j += LANES;
    }
    while j < j1 {
        let q = fold_point(coeffs, deltas, src, sbase + j as i64);
        let v = src[(sbase + j as i64) as usize] + alpha * q;
        out.add(j).write(v);
        if let Some((u2, r2)) = norms.as_mut() {
            **u2 += v * v;
            **r2 += q * q;
        }
        j += 1;
    }
}

#[inline(always)]
fn sum_sq_portable(v: &[f64]) -> f64 {
    let mut acc = [0.0f64; LANES];
    let mut chunks = v.chunks_exact(LANES);
    for c in chunks.by_ref() {
        for (a, &x) in acc.iter_mut().zip(c) {
            *a += x * x;
        }
    }
    let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]);
    for &x in chunks.remainder() {
        s += x * x;
    }
    s
}

/// Issue a T0 prefetch for the operand `dist` words ahead of `idx`
/// (clamped into the slice so the pointer arithmetic stays in-bounds; the
/// instruction itself cannot fault). Compiles to nothing without the
/// `simd` feature or off x86_64.
#[inline(always)]
fn prefetch_ahead(u: &[f64], idx: usize, dist: usize) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if dist > 0 {
        use std::arch::x86_64::{_mm_prefetch, _MM_HINT_T0};
        let p = (idx + dist).min(u.len() - 1);
        // SAFETY: p < u.len(), so the pointer is inside the allocation.
        unsafe { _mm_prefetch::<_MM_HINT_T0>(u.as_ptr().add(p) as *const i8) };
    }
    #[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
    {
        let _ = (u, idx, dist);
    }
}

// ---------------------------------------------------------------------
// explicit AVX2 + FMA path (behind the `simd` feature, runtime-detected)
// ---------------------------------------------------------------------

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod fma {
    use super::{prefetch_ahead, LANES};
    use std::arch::x86_64::*;

    /// Scalar fold with fused multiply-add — the tail companion of
    /// [`fold4`]. `mul_add` is the same correctly-rounded operation as
    /// `vfmadd`, so a point's value does not depend on whether it fell in
    /// a vector chunk or the remainder (position-independence is what
    /// keeps decomposed-vs-classic fields bitwise equal under `simd`).
    #[inline(always)]
    fn fold_point_fma(coeffs: &[f64], deltas: &[i64], u: &[f64], base: i64) -> f64 {
        let mut acc = 0.0f64;
        for (&c, &dl) in coeffs.iter().zip(deltas) {
            acc = c.mul_add(u[(base + dl) as usize], acc);
        }
        acc
    }

    /// Fold LANES consecutive points with one fmadd per coefficient.
    ///
    /// # Safety
    /// Caller verified AVX2+FMA; `idx + delta .. + LANES` stays in `u`.
    #[inline]
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn fold4(coeffs: &[f64], deltas: &[i64], u: &[f64], idx: usize) -> __m256d {
        let mut acc = _mm256_setzero_pd();
        for (&c, &dl) in coeffs.iter().zip(deltas) {
            let w = _mm256_loadu_pd(u.as_ptr().add((idx as i64 + dl) as usize));
            acc = _mm256_fmadd_pd(_mm256_set1_pd(c), w, acc);
        }
        acc
    }

    /// # Safety
    /// Caller verified AVX2+FMA at runtime.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn fold_row(coeffs: &[f64], deltas: &[i64], u: &[f64], base: i64, out: &mut [f64], dist: usize) {
        let n = out.len();
        let mut j = 0;
        while j + LANES <= n {
            let idx = (base + j as i64) as usize;
            prefetch_ahead(u, idx, dist);
            _mm256_storeu_pd(out.as_mut_ptr().add(j), fold4(coeffs, deltas, u, idx));
            j += LANES;
        }
        while j < n {
            out[j] = fold_point_fma(coeffs, deltas, u, base + j as i64);
            j += 1;
        }
    }

    /// # Safety
    /// Caller verified AVX2+FMA and upholds [`super::update_row`]'s
    /// pointer contract.
    #[target_feature(enable = "avx2", enable = "fma")]
    #[allow(clippy::too_many_arguments)]
    pub unsafe fn update_row(
        coeffs: &[f64],
        deltas: &[i64],
        src: &[f64],
        sbase: i64,
        alpha: f64,
        n: usize,
        lo: usize,
        hi: usize,
        out: *mut f64,
        acc: &mut (f64, f64),
        dist: usize,
    ) {
        let (u2, r2) = (&mut acc.0, &mut acc.1);
        seg(coeffs, deltas, src, sbase, alpha, 0, lo, out, None, dist);
        seg(coeffs, deltas, src, sbase, alpha, lo, hi, out, Some((u2, r2)), dist);
        seg(coeffs, deltas, src, sbase, alpha, hi, n, out, None, dist);
    }

    #[inline]
    #[target_feature(enable = "avx2", enable = "fma")]
    #[allow(clippy::too_many_arguments)]
    unsafe fn seg(
        coeffs: &[f64],
        deltas: &[i64],
        src: &[f64],
        sbase: i64,
        alpha: f64,
        j0: usize,
        j1: usize,
        out: *mut f64,
        mut norms: Option<(&mut f64, &mut f64)>,
        dist: usize,
    ) {
        let va = _mm256_set1_pd(alpha);
        let mut j = j0;
        while j + LANES <= j1 {
            let idx = (sbase + j as i64) as usize;
            prefetch_ahead(src, idx, dist);
            let q = fold4(coeffs, deltas, src, idx);
            let w = _mm256_loadu_pd(src.as_ptr().add(idx));
            let v = _mm256_fmadd_pd(va, q, w);
            _mm256_storeu_pd(out.add(j), v);
            if let Some((u2, r2)) = norms.as_mut() {
                let mut vl = [0.0f64; LANES];
                let mut ql = [0.0f64; LANES];
                _mm256_storeu_pd(vl.as_mut_ptr(), v);
                _mm256_storeu_pd(ql.as_mut_ptr(), q);
                // increasing-j scalar extraction: keeps norms identical
                // across sequential/sharded/temporal/out-of-core paths
                for l in 0..LANES {
                    **u2 += vl[l] * vl[l];
                    **r2 += ql[l] * ql[l];
                }
            }
            j += LANES;
        }
        while j < j1 {
            let q = fold_point_fma(coeffs, deltas, src, sbase + j as i64);
            let v = alpha.mul_add(q, src[(sbase + j as i64) as usize]);
            out.add(j).write(v);
            if let Some((u2, r2)) = norms.as_mut() {
                **u2 += v * v;
                **r2 += q * q;
            }
            j += 1;
        }
    }

    /// # Safety
    /// Caller verified AVX2+FMA at runtime.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn sum_sq(v: &[f64]) -> f64 {
        let mut acc = _mm256_setzero_pd();
        let mut chunks = v.chunks_exact(LANES);
        for c in chunks.by_ref() {
            let x = _mm256_loadu_pd(c.as_ptr());
            acc = _mm256_fmadd_pd(x, x, acc);
        }
        let mut lanes = [0.0f64; LANES];
        _mm256_storeu_pd(lanes.as_mut_ptr(), acc);
        let mut s = (lanes[0] + lanes[1]) + (lanes[2] + lanes[3]);
        for &x in chunks.remainder() {
            s = x.mul_add(x, s);
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// star-5-like 1-D operand layout: deltas within ±2 of the base.
    fn fixture(n: usize) -> (Vec<f64>, Vec<f64>, Vec<i64>) {
        let u: Vec<f64> = (0..n).map(|i| ((i * 37 + 11) % 101) as f64 * 0.125 - 6.0).collect();
        let coeffs = vec![-4.25, 1.0, 1.5, 0.5, 0.75];
        let deltas = vec![0, -1, 1, -2, 2];
        (u, coeffs, deltas)
    }

    #[test]
    fn portable_fold_row_is_bitwise_equal_to_fold_point() {
        let (u, coeffs, deltas) = fixture(64);
        let cfg = KernelCfg::strict();
        // every base alignment and every remainder length 0..8
        for base in 2..10i64 {
            for n in 0..=9usize {
                let mut out = vec![0.0; n];
                fold_row(&coeffs, &deltas, &u, base, &mut out, &cfg);
                for (j, &q) in out.iter().enumerate() {
                    let want = fold_point(&coeffs, &deltas, &u, base + j as i64);
                    assert_eq!(q.to_bits(), want.to_bits(), "base={base} n={n} j={j}");
                }
            }
        }
    }

    #[test]
    fn update_row_matches_scalar_fused_loop_bitwise_on_portable_path() {
        let (u, coeffs, deltas) = fixture(64);
        let cfg = KernelCfg { strict: true, prefetch: 8 };
        let alpha = 0.037;
        let (n, sbase) = (23usize, 3i64);
        // seed the accumulators nonzero: update_row must *continue* the
        // caller's running sums (shard sweeps depend on this), not reset
        let mut acc = (0.25, 0.5);
        let (mut wu2, mut wr2) = (0.25, 0.5);
        for (lo, hi) in [(0usize, 23usize), (2, 21), (5, 5), (0, 0), (7, 23)] {
            let mut out = vec![0.0; n];
            unsafe { update_row(&coeffs, &deltas, &u, sbase, alpha, n, lo, hi, out.as_mut_ptr(), &mut acc, &cfg) };
            // scalar reference, exactly the pre-kernel tile_line shape
            let mut want = vec![0.0; n];
            for (j, w) in want.iter_mut().enumerate() {
                let q = fold_point(&coeffs, &deltas, &u, sbase + j as i64);
                let v = u[(sbase + j as i64) as usize] + alpha * q;
                *w = v;
                if (lo..hi).contains(&j) {
                    wu2 += v * v;
                    wr2 += q * q;
                }
            }
            for j in 0..n {
                assert_eq!(out[j].to_bits(), want[j].to_bits(), "lo={lo} hi={hi} j={j}");
            }
            assert_eq!(acc.0.to_bits(), wu2.to_bits(), "u2 lo={lo} hi={hi}");
            assert_eq!(acc.1.to_bits(), wr2.to_bits(), "r2 lo={lo} hi={hi}");
        }
    }

    #[test]
    fn default_mode_matches_strict_within_reassociation_tolerance() {
        // On non-simd builds default == strict (bitwise); under `simd`
        // the FMA path must stay within the documented 1e-12.
        let (u, coeffs, deltas) = fixture(80);
        let fast = KernelCfg::default();
        let mut out_fast = vec![0.0; 31];
        let mut out_ref = vec![0.0; 31];
        fold_row(&coeffs, &deltas, &u, 4, &mut out_fast, &fast);
        fold_row(&coeffs, &deltas, &u, 4, &mut out_ref, &KernelCfg::strict());
        for (a, b) in out_fast.iter().zip(&out_ref) {
            let tol = 1e-12 * b.abs().max(1.0);
            assert!((a - b).abs() <= tol, "{a} vs {b}");
        }
    }

    #[test]
    fn sum_sq_matches_scalar_sum_within_tolerance() {
        let (u, _, _) = fixture(1003);
        for n in [0usize, 1, 3, 4, 5, 8, 17, 1003] {
            let s = sum_sq(&u[..n]);
            let want: f64 = u[..n].iter().map(|v| v * v).sum();
            let tol = 1e-12 * want.abs().max(1.0);
            assert!((s - want).abs() <= tol, "n={n}: {s} vs {want}");
        }
    }

    #[test]
    fn prefetch_distance_never_changes_results() {
        let (u, coeffs, deltas) = fixture(64);
        let mut base_out = vec![0.0; 40];
        fold_row(&coeffs, &deltas, &u, 8, &mut base_out, &KernelCfg::default());
        for dist in [1usize, 7, 64, 100_000] {
            let cfg = KernelCfg { strict: false, prefetch: dist };
            let mut out = vec![0.0; 40];
            fold_row(&coeffs, &deltas, &u, 8, &mut out, &cfg);
            for (a, b) in out.iter().zip(&base_out) {
                assert_eq!(a.to_bits(), b.to_bits(), "dist={dist}");
            }
        }
    }
}
