//! The stencil execution engine: walks a [`Traversal`] stream and either
//! feeds the induced address stream to a memory-model simulator (**analysis
//! mode** — any [`MemoryModel`], from the paper's single [`CacheSim`] to a
//! full L1/L2/TLB [`crate::cache::Hierarchy`]) or computes the stencil
//! numerically (**numeric mode**), or both.
//!
//! The engine is the moral equivalent of the measured Fortran loop nests in
//! the paper's §6: per interior point it issues `|K|` reads of `u` (one per
//! stencil vector, in stencil order) followed by one write of `q`, exactly
//! like the compiled `q(i1,j,k) = c0*u(i1,j,k) + …` statement.
//!
//! All entry points consume the traversal as a *stream*: nothing
//! proportional to the grid is materialized, so analysis scales to grids
//! (512³+) whose visit sequence would not fit in memory. A materialized
//! [`crate::traversal::Order`] still works everywhere — it is itself a
//! (single-pencil) `Traversal`. [`simulate_sharded`] splits the stream's
//! pencils into disjoint ranges and fans them out across a worker pool.
//!
//! Numeric-mode inner loops all live in [`kernel`]: one vectorized row
//! fold (portable 4-lane, optional AVX2/FMA behind the `simd` feature,
//! planner-chosen software prefetch) shared by the sequential, sharded,
//! time-tiled and block-decomposed paths, with the scalar
//! `kernel::fold_point` kept as the bitwise reference.

pub mod kernel;

use crate::cache::{CacheSim, CacheStats, LoadProfile, MachineModel, MemoryModel};
use crate::grid::{GridDesc, MultiArrayLayout};
use crate::stencil::Stencil;
use crate::traversal::{shard_ranges, TemporalTraversal, Traversal, MAX_STREAM_DIMS};
use crate::util::threadpool::ThreadPool;
pub use kernel::KernelCfg;
pub(crate) use kernel::fold_point;
use std::ops::Range;

/// Result of an analysis-mode run.
///
/// `total`, `u_loads` and `u_misses` are **L1-level** quantities (the
/// paper's §2 counters) regardless of the memory model, so single-level
/// numbers are identical whether simulated on a bare [`CacheSim`] or as
/// the first level of a hierarchy; `levels` carries the per-level profile
/// (one row for a single-level model, L1/L2/TLB rows for a hierarchy).
#[derive(Debug, Clone, Copy)]
pub struct MissReport {
    /// Interior points visited.
    pub points: u64,
    /// Combined L1 counters over the whole address stream (u reads + q
    /// writes).
    pub total: CacheStats,
    /// Counters attributable to reads of the RHS array(s) only — the
    /// quantity the paper's bounds constrain (loads of `u`).
    pub u_loads: u64,
    pub u_misses: u64,
    /// Per-level counters over the whole address stream.
    pub levels: LoadProfile,
}

impl MissReport {
    /// Misses per interior point (the y-axis of Figure 4).
    pub fn misses_per_point(&self) -> f64 {
        if self.points == 0 {
            0.0
        } else {
            self.total.misses() as f64 / self.points as f64
        }
    }

    /// Loads of u per interior point — comparable against Eq 7 / Eq 12
    /// (which are stated per grid point).
    pub fn u_loads_per_point(&self) -> f64 {
        if self.points == 0 {
            0.0
        } else {
            self.u_loads as f64 / self.points as f64
        }
    }

    /// Merge shard reports by summing every counter, level-wise for the
    /// per-level profile (the shard union's exact totals, given each shard
    /// ran on its own memory model).
    pub fn merged(reports: &[MissReport]) -> MissReport {
        let mut out = MissReport {
            points: 0,
            total: CacheStats::default(),
            u_loads: 0,
            u_misses: 0,
            levels: LoadProfile::default(),
        };
        for r in reports {
            out.points += r.points;
            out.u_loads += r.u_loads;
            out.u_misses += r.u_misses;
            out.total.accumulate(&r.total);
            out.levels.merge(&r.levels);
        }
        out
    }
}

/// Simulate the memory behaviour of evaluating `stencil` over the full
/// `traversal` stream, with `u` at `layout.base(i)` for each RHS array and
/// `q` at `layout.q_base()`. Every RHS array is read at every stencil point
/// (the §5 multi-array model); `p = layout.num_arrays()`. Generic over the
/// memory model: a bare [`CacheSim`] reproduces the paper's single-level
/// numbers exactly; a [`crate::cache::Hierarchy`] additionally fills the
/// report's per-level profile.
pub fn simulate<T: Traversal + ?Sized, M: MemoryModel + ?Sized>(
    traversal: &T,
    layout: &MultiArrayLayout,
    stencil: &Stencil,
    sim: &mut M,
) -> MissReport {
    simulate_pencils(traversal, 0..traversal.num_pencils(), layout, stencil, sim)
}

/// [`simulate`] restricted to a pencil range of the traversal — the shard
/// body of [`simulate_sharded`], also usable directly for incremental
/// analyses: every counter in the returned report (including `total` and
/// `levels`) covers only *this call's* accesses, so reports from
/// successive ranges over one shared memory model sum cleanly via
/// [`MissReport::merged`].
pub fn simulate_pencils<T: Traversal + ?Sized, M: MemoryModel + ?Sized>(
    traversal: &T,
    pencils: Range<usize>,
    layout: &MultiArrayLayout,
    stencil: &Stencil,
    sim: &mut M,
) -> MissReport {
    let grid = layout.grid().clone();
    let d = grid.ndim();
    assert_eq!(stencil.ndim(), d);
    assert_eq!(traversal.ndim(), d);
    let deltas: Vec<i64> = stencil.offsets().iter().map(|o| grid.delta_of(o)).collect();
    let p = layout.num_arrays();
    let bases: Vec<i64> = (0..p).map(|i| layout.base(i) as i64).collect();
    let q_base = layout.q_base() as i64;

    let entry_stats = sim.l1_stats();
    let entry_profile = sim.profile();
    let mut u_loads = 0u64;
    let mut u_misses = 0u64;
    let mut points = 0u64;

    traversal.stream_pencils(pencils, &mut |x| {
        let off = grid.offset_of(x) as i64;
        let pre = sim.l1_stats();
        for &b in &bases {
            let base = b + off;
            for &dl in &deltas {
                sim.access((base + dl) as u64);
            }
        }
        let post = sim.l1_stats();
        u_loads += post.loads() - pre.loads();
        u_misses += post.misses() - pre.misses();
        // write q(x): one access (write-allocate).
        sim.access((q_base + off) as u64);
        points += 1;
    });
    MissReport {
        points,
        total: CacheStats::delta(sim.l1_stats(), entry_stats),
        u_loads,
        u_misses,
        levels: LoadProfile::delta(&sim.profile(), &entry_profile),
    }
}

/// [`simulate`] against a [`MachineModel`]: builds the machine's memory
/// model and dispatches once on its shape, so the per-access loop is
/// monomorphized for both the single-level and the hierarchical case (no
/// per-access virtual calls). The shared sequential entry point for the
/// coordinator, the experiment drivers and the tuner's stall metric.
pub fn simulate_on_machine<T: Traversal + ?Sized>(
    traversal: &T,
    layout: &MultiArrayLayout,
    stencil: &Stencil,
    machine: &MachineModel,
) -> MissReport {
    if machine.is_hierarchical() {
        let mut hier = machine.build_hierarchy();
        simulate(traversal, layout, stencil, &mut hier)
    } else {
        let mut sim = CacheSim::new(machine.l1);
        simulate(traversal, layout, stencil, &mut sim)
    }
}

/// Sharded analysis: partition the traversal's pencils into at most
/// `shards` disjoint ranges and simulate each on its own fresh [`CacheSim`]
/// across the worker pool, summing the per-shard counters.
///
/// Pencil ranges are independent by construction (each pencil's working set
/// is cache-resident on its own; replacement traffic crosses only pencil
/// *walls*, §4), so per-shard caches change only the boundary terms: each
/// shard re-fetches its leading halo cold instead of warm. Totals are
/// therefore a slight **overcount** of the sequential run's misses —
/// conservative for bound checking — while scaling Analyze wall time with
/// cores. With one shard (or one pencil) this degrades to the exact
/// sequential [`simulate`].
pub fn simulate_sharded<T: Traversal + ?Sized>(
    traversal: &T,
    layout: &MultiArrayLayout,
    stencil: &Stencil,
    machine: &MachineModel,
    pool: &ThreadPool,
    shards: usize,
) -> MissReport {
    // Branch once on the machine shape so each shard's access loop is
    // monomorphized (no per-access virtual dispatch on the hot path).
    if machine.is_hierarchical() {
        simulate_sharded_with(traversal, layout, stencil, || machine.build_hierarchy(), pool, shards)
    } else {
        simulate_sharded_with(traversal, layout, stencil, || CacheSim::new(machine.l1), pool, shards)
    }
}

/// The sharding engine behind [`simulate_sharded`], parameterized by a
/// per-shard memory-model builder.
fn simulate_sharded_with<T, M, F>(
    traversal: &T,
    layout: &MultiArrayLayout,
    stencil: &Stencil,
    build: F,
    pool: &ThreadPool,
    shards: usize,
) -> MissReport
where
    T: Traversal + ?Sized,
    M: MemoryModel,
    F: Fn() -> M + Sync,
{
    let ranges = shard_ranges(traversal.num_pencils(), shards);
    if ranges.len() <= 1 {
        let mut sim = build();
        return simulate(traversal, layout, stencil, &mut sim);
    }
    let reports = pool.scope_map(ranges.len(), |i| {
        let mut sim = build();
        simulate_pencils(traversal, ranges[i].clone(), layout, stencil, &mut sim)
    });
    MissReport::merged(&reports)
}

/// Numeric mode: compute `q(x) = Σ c_i·u(x + k_i)` over the traversal, for
/// a single RHS array. Buffers are sized by `grid.storage_words()`. The
/// stream is consumed allocation-free, row-at-a-time through the one
/// vectorized [`kernel`] (default [`KernelCfg`]: fast mode, no prefetch —
/// bitwise identical to the scalar reference on builds without `simd`).
pub fn apply<T: Traversal + ?Sized>(traversal: &T, grid: &GridDesc, stencil: &Stencil, u: &[f64], q: &mut [f64]) {
    apply_cfg(traversal, grid, stencil, u, q, &KernelCfg::default())
}

/// [`apply`] with explicit kernel knobs (strict mode, prefetch distance).
pub fn apply_cfg<T: Traversal + ?Sized>(
    traversal: &T,
    grid: &GridDesc,
    stencil: &Stencil,
    u: &[f64],
    q: &mut [f64],
    cfg: &KernelCfg,
) {
    apply_pencils_cfg(traversal, 0..traversal.num_pencils(), grid, stencil, u, q, cfg)
}

/// Buffer/arity validation shared by the numeric entry points.
fn check_numeric_args<T: Traversal + ?Sized>(traversal: &T, grid: &GridDesc, stencil: &Stencil, u: &[f64], q: &[f64]) {
    let d = grid.ndim();
    assert_eq!(stencil.ndim(), d);
    assert_eq!(traversal.ndim(), d);
    assert!(u.len() as u64 >= grid.storage_words(), "u buffer too small");
    assert!(q.len() as u64 >= grid.storage_words(), "q buffer too small");
}

/// The pre-kernel per-point sweep: streams *points* (not rows) and folds
/// each through the scalar [`kernel::fold_point`] reference. Kept as the
/// bitwise ground truth for the kernel property tests and as the scalar
/// baseline row in `bench_numeric` — production callers use [`apply`],
/// which routes rows through the vector kernel.
pub fn apply_reference<T: Traversal + ?Sized>(
    traversal: &T,
    grid: &GridDesc,
    stencil: &Stencil,
    u: &[f64],
    q: &mut [f64],
) {
    check_numeric_args(traversal, grid, stencil, u, q);
    let deltas: Vec<i64> = stencil.offsets().iter().map(|o| grid.delta_of(o)).collect();
    let coeffs = stencil.coeffs();
    traversal.stream_pencils(0..traversal.num_pencils(), &mut |x| {
        let base = grid.offset_of(x) as i64;
        q[base as usize] = fold_point(coeffs, &deltas, u, base);
    });
}

/// [`apply`] restricted to a pencil range of the traversal — the shard body
/// of [`apply_sharded`]. Writes only the `q` words of points in `pencils`;
/// every other word of `q` is left untouched.
pub fn apply_pencils<T: Traversal + ?Sized>(
    traversal: &T,
    pencils: Range<usize>,
    grid: &GridDesc,
    stencil: &Stencil,
    u: &[f64],
    q: &mut [f64],
) {
    apply_pencils_cfg(traversal, pencils, grid, stencil, u, q, &KernelCfg::default())
}

/// [`apply_pencils`] with explicit kernel knobs. The traversal is consumed
/// as **rows** ([`Traversal::stream_rows`]): each maximal dim-0-contiguous
/// run is folded by one [`kernel::fold_row`] call, which is where the
/// 4-lane vectorization and software prefetch live. Traversals without
/// row structure degrade to 1-long rows — same results, scalar speed.
pub fn apply_pencils_cfg<T: Traversal + ?Sized>(
    traversal: &T,
    pencils: Range<usize>,
    grid: &GridDesc,
    stencil: &Stencil,
    u: &[f64],
    q: &mut [f64],
    cfg: &KernelCfg,
) {
    check_numeric_args(traversal, grid, stencil, u, q);
    let deltas: Vec<i64> = stencil.offsets().iter().map(|o| grid.delta_of(o)).collect();
    let coeffs = stencil.coeffs();
    traversal.stream_rows(pencils, &mut |x, n| {
        let base = grid.offset_of(x) as i64;
        let b = base as usize;
        kernel::fold_row(coeffs, &deltas, u, base, &mut q[b..b + n], cfg);
    });
}

/// Sharded numeric apply: partition the traversal's pencils into at most
/// `shards` disjoint ranges and run the stencil sweep concurrently on the
/// worker pool.
///
/// **Write-disjointness.** Pencil ranges partition the interior point set
/// (no dupes, no gaps — property-tested in `tests/streaming.rs`), each
/// shard writes only `q[offset(x)]` for its own points `x`, and `u` is
/// read-only, so no two workers ever touch the same word. Per-point
/// arithmetic is identical to the sequential [`apply`] (same kernel, same
/// coefficient order, and `q` depends only on `u`), so the result field is
/// **bitwise** equal to the sequential sweep for any traversal and shard
/// count.
pub fn apply_sharded<T: Traversal + ?Sized>(
    traversal: &T,
    grid: &GridDesc,
    stencil: &Stencil,
    u: &[f64],
    q: &mut [f64],
    pool: &ThreadPool,
    shards: usize,
) {
    apply_sharded_cfg(traversal, grid, stencil, u, q, pool, shards, &KernelCfg::default())
}

/// [`apply_sharded`] with explicit kernel knobs.
#[allow(clippy::too_many_arguments)]
pub fn apply_sharded_cfg<T: Traversal + ?Sized>(
    traversal: &T,
    grid: &GridDesc,
    stencil: &Stencil,
    u: &[f64],
    q: &mut [f64],
    pool: &ThreadPool,
    shards: usize,
    cfg: &KernelCfg,
) {
    let ranges = shard_ranges(traversal.num_pencils(), shards);
    if ranges.len() <= 1 {
        return apply_cfg(traversal, grid, stencil, u, q, cfg);
    }
    check_numeric_args(traversal, grid, stencil, u, q);
    let deltas: Vec<i64> = stencil.offsets().iter().map(|o| grid.delta_of(o)).collect();
    let coeffs = stencil.coeffs();
    // Raw-pointer sink so workers never hold overlapping `&mut` slices;
    // SAFETY: the disjointness argument above — each word of q is written
    // by at most one worker, and u/q are distinct buffers.
    struct QPtr(*mut f64);
    unsafe impl Sync for QPtr {}
    let qp = QPtr(q.as_mut_ptr());
    let qp = &qp;
    pool.scope_map(ranges.len(), |i| {
        traversal.stream_rows(ranges[i].clone(), &mut |x, n| {
            let base = grid.offset_of(x) as i64;
            // SAFETY: rows of disjoint pencil ranges are disjoint, so this
            // worker is the only one touching q[base..base+n].
            let out = unsafe { std::slice::from_raw_parts_mut(qp.0.add(base as usize), n) };
            kernel::fold_row(coeffs, &deltas, u, base, out, cfg);
        });
    });
}

// ---------------------------------------------------------------------------
// Temporal blocking (time-tiled solve step)
// ---------------------------------------------------------------------------

/// Advance the whole field `k` timesteps of the damped explicit iteration
/// `u ← u + α·Ku` in one pass over main memory: for each owned tile of
/// `tt`, step a halo-deep box `k` times in ping-pong scratch buffers
/// (overlapped temporal blocking — the `j`-th step's valid region shrinks
/// by `r` per side, so tiles are fully independent and the existing
/// disjoint-pencil sharding applies unchanged), then write the tile's owned
/// words of timestep `k` straight into `u_out`.
///
/// `u_out` must enter holding the field's **boundary words** (callers
/// double-buffer: clone the initial field once, then swap after every
/// superstep) — the Dirichlet update never touches them. With `k = 1` the
/// scratch degenerates away entirely and this is the *fused* single-pass
/// update (no `q` array, no second axpy pass — and no halo redundancy).
///
/// Returns `k` pairs `(Σ u'², Σ (Ku)²)`. Every per-term product is the
/// identical value the classic `apply` + axpy path computes (same
/// [`fold_point`] coefficient order onto the same operand values, same
/// `u + α·acc` update expression — so the resulting **field is bitwise
/// equal** to `k` sequential single steps, by induction over steps).
/// Boundary words contribute zero to both sums on the classic path, so
/// only the norms' **summation order** differs (tile-major here,
/// chunk-major there) — the documented fp tolerance; see DESIGN.md §2.6.
///
/// ## Why concurrent tiles are safe
///
/// Within one superstep every worker reads only `u_in` (shared) plus its
/// own scratch, and writes only the owned words of its tiles in `u_out`;
/// owned tiles partition the K-interior (property-tested in
/// `traversal::temporal`), so no word is ever written by two workers.
#[allow(clippy::too_many_arguments)]
pub fn step_time_tiled(
    tt: &TemporalTraversal,
    grid: &GridDesc,
    stencil: &Stencil,
    u_in: &[f64],
    u_out: &mut [f64],
    alpha: f64,
    k: usize,
    pool: &ThreadPool,
    shards: usize,
) -> Vec<(f64, f64)> {
    step_time_tiled_cfg(tt, grid, stencil, u_in, u_out, alpha, k, pool, shards, &KernelCfg::default())
}

/// [`step_time_tiled`] with explicit kernel knobs — every tile line runs
/// through the same [`kernel::update_row`] as the classic and sharded
/// paths, so the modes stay locked together.
#[allow(clippy::too_many_arguments)]
pub fn step_time_tiled_cfg(
    tt: &TemporalTraversal,
    grid: &GridDesc,
    stencil: &Stencil,
    u_in: &[f64],
    u_out: &mut [f64],
    alpha: f64,
    k: usize,
    pool: &ThreadPool,
    shards: usize,
    cfg: &KernelCfg,
) -> Vec<(f64, f64)> {
    check_numeric_args(tt, grid, stencil, u_in, u_out);
    assert!(k >= 1 && k <= tt.time_tile(), "k = {k} outside 1..={}", tt.time_tile());
    assert_eq!(tt.radius(), stencil.radius(), "traversal halo must match the stencil radius");
    let ranges = shard_ranges(tt.num_pencils(), shards);
    if ranges.is_empty() {
        return vec![(0.0, 0.0); k];
    }
    let gdeltas: Vec<i64> = stencil.offsets().iter().map(|o| grid.delta_of(o)).collect();
    let ctx = TileCtx { tt, grid, stencil, coeffs: stencil.coeffs(), gdeltas: &gdeltas, alpha, k, cfg };
    // Raw-pointer sink, same pattern as `apply_sharded`; SAFETY: the
    // disjointness argument above — each owned word of u_out is written by
    // exactly one worker, and u_in/u_out are distinct buffers.
    struct OutPtr(*mut f64);
    unsafe impl Sync for OutPtr {}
    let op = OutPtr(u_out.as_mut_ptr());
    let op = &op;
    let worker = |i: usize| {
        let mut acc = vec![(0.0f64, 0.0f64); k];
        let (mut a, mut b) = (Vec::new(), Vec::new());
        for t in ranges[i].clone() {
            advance_tile(&ctx, t, u_in, op.0, &mut a, &mut b, &mut acc);
        }
        acc
    };
    let partials = if ranges.len() == 1 { vec![worker(0)] } else { pool.scope_map(ranges.len(), worker) };
    let mut out = vec![(0.0, 0.0); k];
    for p in partials {
        for (o, v) in out.iter_mut().zip(p) {
            o.0 += v.0;
            o.1 += v.1;
        }
    }
    out
}

/// Immutable per-sweep context shared by every tile of one time-tiled step.
struct TileCtx<'a> {
    tt: &'a TemporalTraversal,
    grid: &'a GridDesc,
    stencil: &'a Stencil,
    coeffs: &'a [f64],
    /// Stencil deltas in the global storage layout (step-1 reads).
    gdeltas: &'a [i64],
    alpha: f64,
    k: usize,
    cfg: &'a KernelCfg,
}

/// Advance one owned tile `k` steps: seed the scratch boundary shell, run
/// the shrinking-valid-region ping-pong, write owned step-`k` words to
/// `out`, and accumulate per-step norms into `acc`.
///
/// Validity induction (the halo math): step `s` computes the region
/// `V_s = clamp(T ± (k−s)·r, interior)`. Its reads lie in `V_s ± r`, and
/// every such point is either in `V_{s−1}` (written by the previous step)
/// or a *boundary* word of the box — which the Dirichlet update holds
/// constant, so the seeded time-0 copy is correct at every step. Step 1
/// reads `u_in` directly (no box copy); step `k` has `V_k = T` exactly.
fn advance_tile(
    ctx: &TileCtx<'_>,
    t: usize,
    u_in: &[f64],
    out: *mut f64,
    a: &mut Vec<f64>,
    b: &mut Vec<f64>,
    acc: &mut [(f64, f64)],
) {
    let d = ctx.grid.ndim();
    let dims = ctx.grid.dims();
    let gs = ctx.grid.strides();
    let interior = ctx.tt.interior();
    let (k, r) = (ctx.k, ctx.tt.radius() as i64);
    let tr = ctx.tt.tile_ranges(t);
    let h = k as i64 * r;
    // halo-deep box around the owned tile, clipped to the full grid
    let mut blo = [0i64; MAX_STREAM_DIMS];
    let mut be = [0i64; MAX_STREAM_DIMS];
    let mut ls = [0i64; MAX_STREAM_DIMS];
    let mut vol = 1i64;
    for i in 0..d {
        blo[i] = (tr[i].start - h).max(0);
        let bhi = (tr[i].end + h).min(dims[i] as i64);
        be[i] = bhi - blo[i];
        ls[i] = vol;
        vol *= be[i];
    }
    let ldeltas: Vec<i64> = if k > 1 {
        if a.len() < vol as usize {
            a.resize(vol as usize, 0.0);
        }
        if b.len() < vol as usize {
            b.resize(vol as usize, 0.0);
        }
        seed_boundary_shell(ctx, &blo[..d], &be[..d], &ls[..d], u_in, a, b);
        ctx.stencil.offsets().iter().map(|o| o.iter().zip(&ls[..d]).map(|(&c, &st)| c * st).sum()).collect()
    } else {
        Vec::new()
    };
    for s in 1..=k {
        let g2 = (k - s) as i64 * r;
        let mut vlo = [0i64; MAX_STREAM_DIMS];
        let mut vhi = [0i64; MAX_STREAM_DIMS];
        for i in 0..d {
            vlo[i] = (tr[i].start - g2).max(interior[i].start);
            vhi[i] = (tr[i].end + g2).min(interior[i].end);
        }
        let (first, last, odd) = (s == 1, s == k, s % 2 == 1);
        // ping-pong parity: odd steps write b, even steps write a; reads
        // come from the opposite buffer (step 1 reads u_in directly, step
        // k writes u_out directly).
        let dst: *mut f64 = if last { out } else if odd { b.as_mut_ptr() } else { a.as_mut_ptr() };
        let src: &[f64] = if first { u_in } else if odd { &a[..] } else { &b[..] };
        let deltas: &[i64] = if first { ctx.gdeltas } else { &ldeltas };
        let n0 = (vhi[0] - vlo[0]) as usize;
        // the owned dim-0 segment of each line (T ⊆ V_s in every dim)
        let (o_lo, o_hi) = ((tr[0].start - vlo[0]) as usize, (tr[0].end - vlo[0]) as usize);
        let mut xo = [0i64; MAX_STREAM_DIMS];
        xo[1..d].copy_from_slice(&vlo[1..d]);
        'lines: loop {
            let mut in_t = true;
            let mut gb = vlo[0] * gs[0] as i64;
            let mut lb = vlo[0] - blo[0];
            for i in 1..d {
                in_t &= tr[i].start <= xo[i] && xo[i] < tr[i].end;
                gb += xo[i] * gs[i] as i64;
                lb += (xo[i] - blo[i]) * ls[i];
            }
            let sbase = if first { gb } else { lb };
            let obase = if last { gb } else { lb };
            let (olo, ohi) = if in_t { (o_lo, o_hi) } else { (n0, n0) };
            // One dim-0 line of the step through the shared vector kernel:
            // n0 updated values written through `line_out`, norms
            // accumulated over the owned sub-segment [olo, ohi) only, in
            // increasing-j order (per-term bitwise identical to the
            // classic axpy-norm terms).
            // SAFETY: dst is either u_out (disjoint owned writes across
            // tiles) or this worker's scratch sized to the box; obase..+n0
            // lies inside it because V_s ⊆ box (local) / storage (global),
            // and src reads stay inside the box/storage for the same
            // reason.
            unsafe {
                let line_out = dst.add(obase as usize);
                // per-line local partials, folded into the step slot
                // afterwards — the exact grouping of the pre-kernel
                // `tile_line`, so temporal norms are unchanged bit-for-bit
                // on the portable path
                let mut part = (0.0, 0.0);
                kernel::update_row(
                    ctx.coeffs,
                    deltas,
                    src,
                    sbase,
                    ctx.alpha,
                    n0,
                    olo,
                    ohi,
                    line_out,
                    &mut part,
                    ctx.cfg,
                );
                acc[s - 1].0 += part.0;
                acc[s - 1].1 += part.1;
            }
            let mut i = 1;
            loop {
                if i >= d {
                    break 'lines;
                }
                xo[i] += 1;
                if xo[i] < vhi[i] {
                    continue 'lines;
                }
                xo[i] = vlo[i];
                i += 1;
            }
        }
    }
}

/// Copy the box words *outside* the K-interior (the Dirichlet shell) from
/// `u_in` into both scratch buffers: those words are read by steps ≥ 2 but
/// never written, and they are constant in time, so the time-0 copy is
/// correct forever. Interior scratch words need no seeding — the validity
/// induction shows every interior read of step `s ≥ 2` was written by step
/// `s − 1`.
fn seed_boundary_shell(
    ctx: &TileCtx<'_>,
    blo: &[i64],
    be: &[i64],
    ls: &[i64],
    u_in: &[f64],
    a: &mut [f64],
    b: &mut [f64],
) {
    let d = blo.len();
    let gs = ctx.grid.strides();
    let interior = ctx.tt.interior();
    let n0 = be[0] as usize;
    let cap_l = (interior[0].start - blo[0]).clamp(0, be[0]) as usize;
    let cap_r = (interior[0].end - blo[0]).clamp(0, be[0]) as usize;
    let mut xo = [0i64; MAX_STREAM_DIMS];
    for i in 1..d {
        xo[i] = blo[i];
    }
    loop {
        let mut outer_boundary = false;
        let mut gb = blo[0] * gs[0] as i64;
        let mut lb = 0i64;
        for i in 1..d {
            outer_boundary |= xo[i] < interior[i].start || xo[i] >= interior[i].end;
            gb += xo[i] * gs[i] as i64;
            lb += (xo[i] - blo[i]) * ls[i];
        }
        let (gb, lb) = (gb as usize, lb as usize);
        if outer_boundary {
            a[lb..lb + n0].copy_from_slice(&u_in[gb..gb + n0]);
            b[lb..lb + n0].copy_from_slice(&u_in[gb..gb + n0]);
        } else {
            a[lb..lb + cap_l].copy_from_slice(&u_in[gb..gb + cap_l]);
            b[lb..lb + cap_l].copy_from_slice(&u_in[gb..gb + cap_l]);
            a[lb + cap_r..lb + n0].copy_from_slice(&u_in[gb + cap_r..gb + n0]);
            b[lb + cap_r..lb + n0].copy_from_slice(&u_in[gb + cap_r..gb + n0]);
        }
        let mut i = 1;
        loop {
            if i >= d {
                return;
            }
            xo[i] += 1;
            if xo[i] < blo[i] + be[i] {
                break;
            }
            xo[i] = blo[i];
            i += 1;
        }
    }
}

/// Combined mode used by tests: numeric result plus miss report in one
/// sweep (numbers must be identical to running the two modes separately).
pub fn apply_and_simulate<T: Traversal + ?Sized, M: MemoryModel + ?Sized>(
    traversal: &T,
    layout: &MultiArrayLayout,
    stencil: &Stencil,
    u: &[f64],
    q: &mut [f64],
    sim: &mut M,
) -> MissReport {
    let report = simulate(traversal, layout, stencil, sim);
    apply(traversal, layout.grid(), stencil, u, q);
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::CacheParams;
    use crate::traversal::{cache_fitting_for_cache, natural, natural_stream};

    fn setup(dims: &[usize]) -> (GridDesc, Stencil, MultiArrayLayout) {
        let g = GridDesc::new(dims);
        let s = Stencil::star(dims.len(), 1);
        let l = MultiArrayLayout::contiguous(&g, 1);
        (g, s, l)
    }

    #[test]
    fn simulate_counts_expected_accesses() {
        let (g, s, l) = setup(&[6, 6]);
        let order = natural(&g, 1);
        let mut sim = CacheSim::new(CacheParams::new(2, 8, 2));
        let rep = simulate(&order, &l, &s, &mut sim);
        let pts = g.interior_points(1);
        assert_eq!(rep.points, pts);
        // |K| u-reads + 1 q-write per point
        assert_eq!(rep.total.accesses, pts * (s.size() as u64 + 1));
    }

    #[test]
    fn streaming_equals_materialized_simulation() {
        // The same traversal, streamed vs materialized, must produce the
        // identical report — the stream is the same visit sequence.
        let (g, s, l) = setup(&[10, 9]);
        let mut sim_m = CacheSim::new(CacheParams::new(2, 16, 2));
        let rep_m = simulate(&natural(&g, 1), &l, &s, &mut sim_m);
        let mut sim_s = CacheSim::new(CacheParams::new(2, 16, 2));
        let rep_s = simulate(&natural_stream(&g, 1), &l, &s, &mut sim_s);
        assert_eq!(rep_m.points, rep_s.points);
        assert_eq!(rep_m.total, rep_s.total);
        assert_eq!(rep_m.u_loads, rep_s.u_loads);
        assert_eq!(rep_m.u_misses, rep_s.u_misses);
    }

    #[test]
    fn sharded_simulation_visits_every_point_once() {
        let (g, s, l) = setup(&[12, 11]);
        let cache = CacheParams::new(2, 16, 2);
        let t = natural_stream(&g, 1);
        let pool = ThreadPool::new(3);
        let rep = simulate_sharded(&t, &l, &s, &MachineModel::l1_only(cache), &pool, 4);
        let pts = g.interior_points(1);
        assert_eq!(rep.points, pts);
        assert_eq!(rep.total.accesses, pts * (s.size() as u64 + 1));
        // per-shard cold boundaries can only add misses vs the warm
        // sequential run, never remove loads below the per-point compulsory
        let mut sim = CacheSim::new(cache);
        let seq = simulate(&t, &l, &s, &mut sim);
        assert!(rep.total.misses() >= seq.total.misses());
        assert_eq!(rep.total.accesses, seq.total.accesses);
    }

    #[test]
    fn sharded_with_one_shard_is_exact() {
        let (g, s, l) = setup(&[9, 8]);
        let cache = CacheParams::new(2, 16, 2);
        let t = natural_stream(&g, 1);
        let pool = ThreadPool::new(2);
        let sharded = simulate_sharded(&t, &l, &s, &MachineModel::l1_only(cache), &pool, 1);
        let mut sim = CacheSim::new(cache);
        let seq = simulate(&t, &l, &s, &mut sim);
        assert_eq!(sharded.total, seq.total);
        assert_eq!(sharded.points, seq.points);
    }

    #[test]
    fn u_loads_lower_bounded_by_distinct_points() {
        // Every distinct u word read is at least one cold load: for a star
        // stencil over the full interior, the K-extension is touched.
        let (g, s, l) = setup(&[8, 8]);
        let order = natural(&g, 1);
        let mut sim = CacheSim::new(CacheParams::new(2, 16, 2));
        let rep = simulate(&order, &l, &s, &mut sim);
        // K-extension of the interior of an 8×8 grid with r=1 star: the
        // interior 6×6 plus one-deep faces = 36 + 4*6 = 60 points.
        assert!(rep.u_loads >= 60, "u_loads = {}", rep.u_loads);
    }

    #[test]
    fn apply_matches_direct_computation() {
        let (g, s, _) = setup(&[7, 5]);
        let words = g.storage_words() as usize;
        let mut rng = crate::util::rng::Rng::new(8);
        let u: Vec<f64> = (0..words).map(|_| rng.f64()).collect();
        let mut q1 = vec![0.0; words];
        let mut q2 = vec![0.0; words];
        apply(&natural(&g, 1), &g, &s, &u, &mut q1);
        // direct nested-loop reference
        for j in 1..4i64 {
            for i in 1..6i64 {
                let mut acc = 0.0;
                for (o, &c) in s.offsets().iter().zip(s.coeffs()) {
                    let idx = g.offset_of(&[i + o[0], j + o[1]]) as usize;
                    acc += c * u[idx];
                }
                q2[g.offset_of(&[i, j]) as usize] = acc;
            }
        }
        assert_eq!(q1, q2);
    }

    #[test]
    fn row_kernel_apply_matches_pointwise_reference() {
        // The row-at-a-time kernel path (natural/strip/blocked overrides
        // of stream_rows plus the 1-long-row fallback) must reproduce the
        // per-point scalar reference sweep — bitwise on the portable
        // path, ≤1e-12 relative when the `simd` FMA path is active.
        let (g, s, _) = setup(&[13, 11, 9]);
        let words = g.storage_words() as usize;
        let mut rng = crate::util::rng::Rng::new(17);
        let u: Vec<f64> = (0..words).map(|_| rng.f64()).collect();
        let cache = CacheParams::new(1, 16, 2);
        let traversals: Vec<Box<dyn Traversal>> = vec![
            Box::new(natural_stream(&g, 1)),
            Box::new(crate::traversal::strip_stream(&g, 1, 3)),
            Box::new(crate::traversal::blocked_stream(&g, 1, &[4, 3, 5])),
            Box::new(crate::traversal::cache_fitting_stream_for_cache(&g, 1, &cache)),
        ];
        let strict = KernelCfg { strict: true, prefetch: 16 };
        for t in &traversals {
            let mut q_ref = vec![0.0; words];
            apply_reference(t.as_ref(), &g, &s, &u, &mut q_ref);
            let mut q_strict = vec![0.0; words];
            apply_cfg(t.as_ref(), &g, &s, &u, &mut q_strict, &strict);
            assert_eq!(q_ref, q_strict, "strict mode must be bitwise");
            let mut q_fast = vec![0.0; words];
            apply(t.as_ref(), &g, &s, &u, &mut q_fast);
            for (a, b) in q_fast.iter().zip(&q_ref) {
                assert!((a - b).abs() <= 1e-12 * b.abs().max(1.0), "{a} vs {b}");
            }
        }
    }

    #[test]
    fn apply_streams_without_order() {
        // numeric mode over a lazy traversal gives the same field as over
        // the materialized order.
        let (g, s, _) = setup(&[9, 7]);
        let words = g.storage_words() as usize;
        let mut rng = crate::util::rng::Rng::new(11);
        let u: Vec<f64> = (0..words).map(|_| rng.f64()).collect();
        let mut q_mat = vec![0.0; words];
        let mut q_str = vec![0.0; words];
        apply(&natural(&g, 1), &g, &s, &u, &mut q_mat);
        apply(&natural_stream(&g, 1), &g, &s, &u, &mut q_str);
        assert_eq!(q_mat, q_str);
    }

    #[test]
    fn apply_result_independent_of_order() {
        // The stencil is explicit (reads u, writes q): any visit order gives
        // identical results. This is the safety property that lets the
        // coordinator swap traversals freely.
        let (g, s, _) = setup(&[10, 9]);
        let words = g.storage_words() as usize;
        let mut rng = crate::util::rng::Rng::new(9);
        let u: Vec<f64> = (0..words).map(|_| rng.f64()).collect();
        let mut q_nat = vec![0.0; words];
        let mut q_fit = vec![0.0; words];
        let cache = CacheParams::new(1, 16, 2);
        apply(&natural(&g, 1), &g, &s, &u, &mut q_nat);
        apply(&cache_fitting_for_cache(&g, 1, &cache), &g, &s, &u, &mut q_fit);
        assert_eq!(q_nat, q_fit);
    }

    #[test]
    fn multi_rhs_reads_all_arrays() {
        let g = GridDesc::new(&[6, 6]);
        let s = Stencil::star(2, 1);
        let l = MultiArrayLayout::contiguous(&g, 3);
        let order = natural(&g, 1);
        let mut sim = CacheSim::new(CacheParams::new(2, 64, 2));
        let rep = simulate(&order, &l, &s, &mut sim);
        let pts = g.interior_points(1);
        assert_eq!(rep.total.accesses, pts * (3 * s.size() as u64 + 1));
    }

    #[test]
    fn report_rates() {
        let (g, s, l) = setup(&[6, 6]);
        let order = natural(&g, 1);
        let mut sim = CacheSim::new(CacheParams::new(2, 8, 2));
        let rep = simulate(&order, &l, &s, &mut sim);
        assert!(rep.misses_per_point() > 0.0);
        assert!(rep.u_loads_per_point() >= 1.0); // ≥ 1 load per point (Eq 7 prefactor)
    }

    #[test]
    fn incremental_ranges_over_shared_sim_sum_cleanly() {
        // simulate_pencils on successive ranges of one warm CacheSim must
        // return per-call deltas whose merge equals the one-shot run.
        let (g, s, l) = setup(&[10, 9]);
        let t = natural_stream(&g, 1);
        let np = t.num_pencils();
        let mut sim = CacheSim::new(CacheParams::new(2, 16, 2));
        let r1 = simulate_pencils(&t, 0..np / 2, &l, &s, &mut sim);
        let r2 = simulate_pencils(&t, np / 2..np, &l, &s, &mut sim);
        let merged = MissReport::merged(&[r1, r2]);
        let mut sim2 = CacheSim::new(CacheParams::new(2, 16, 2));
        let whole = simulate(&t, &l, &s, &mut sim2);
        assert_eq!(merged.points, whole.points);
        assert_eq!(merged.total, whole.total);
        assert_eq!(merged.u_loads, whole.u_loads);
        assert_eq!(merged.u_misses, whole.u_misses);
    }

    #[test]
    fn apply_pencils_ranges_partition_the_field() {
        // Applying over split pencil ranges must produce the same q as one
        // full sweep: each range writes exactly its own points.
        let (g, s, _) = setup(&[11, 9]);
        let words = g.storage_words() as usize;
        let mut rng = crate::util::rng::Rng::new(21);
        let u: Vec<f64> = (0..words).map(|_| rng.f64()).collect();
        let t = natural_stream(&g, 1);
        let np = t.num_pencils();
        let mut q_whole = vec![0.0; words];
        apply(&t, &g, &s, &u, &mut q_whole);
        let mut q_split = vec![0.0; words];
        apply_pencils(&t, 0..np / 3, &g, &s, &u, &mut q_split);
        apply_pencils(&t, np / 3..np, &g, &s, &u, &mut q_split);
        assert_eq!(q_whole, q_split);
    }

    #[test]
    fn apply_sharded_bitwise_equals_sequential() {
        let (g, s, _) = setup(&[18, 16, 14]);
        let words = g.storage_words() as usize;
        let mut rng = crate::util::rng::Rng::new(13);
        let u: Vec<f64> = (0..words).map(|_| rng.f64()).collect();
        let pool = ThreadPool::new(3);
        let mut q_seq = vec![0.0; words];
        let t = natural_stream(&g, 1);
        apply(&t, &g, &s, &u, &mut q_seq);
        for shards in [1usize, 2, 5, 64] {
            let mut q_par = vec![0.0; words];
            apply_sharded(&t, &g, &s, &u, &mut q_par, &pool, shards);
            assert_eq!(q_seq, q_par, "shards={shards}");
        }
        // the streaming fitting traversal shards over lattice pencils —
        // same field bit-for-bit
        let cache = CacheParams::new(1, 16, 2);
        let fit = crate::traversal::cache_fitting_stream_for_cache(&g, 1, &cache);
        let mut q_fit = vec![0.0; words];
        apply_sharded(&fit, &g, &s, &u, &mut q_fit, &pool, 4);
        assert_eq!(q_seq, q_fit);
    }

    #[test]
    fn merged_conserves_hit_miss_access_identity() {
        // For any sharded run: hits + misses == accesses must hold for the
        // merged report exactly as for the sequential one, and accesses and
        // points must agree between the two (only the hit/miss split may
        // shift at shard boundaries).
        let (g, s, l) = setup(&[14, 13, 12]);
        let cache = CacheParams::new(2, 32, 2);
        let pool = ThreadPool::new(3);
        for t in [natural_stream(&g, 1)] {
            let mut sim = CacheSim::new(cache);
            let seq = simulate(&t, &l, &s, &mut sim);
            let shd = simulate_sharded(&t, &l, &s, &MachineModel::l1_only(cache), &pool, 5);
            for rep in [&seq, &shd] {
                assert_eq!(rep.total.hits + rep.total.misses(), rep.total.accesses);
                assert!(rep.u_misses <= rep.u_loads + rep.total.misses());
            }
            assert_eq!(seq.points, shd.points);
            assert_eq!(seq.total.accesses, shd.total.accesses);
        }
    }

    #[test]
    fn incremental_ranges_sum_cleanly_for_strip_and_blocked() {
        // stats_delta correctness across warm-cache range splits must hold
        // for every pencil geometry, not just dim-0 lines.
        let g = GridDesc::new(&[12, 10, 9]);
        let s = Stencil::star(3, 1);
        let l = MultiArrayLayout::contiguous(&g, 1);
        let cache = CacheParams::new(2, 16, 2);
        let traversals: Vec<Box<dyn Traversal>> = vec![
            Box::new(crate::traversal::strip_stream(&g, 1, 3)),
            Box::new(crate::traversal::blocked_stream(&g, 1, &[4, 3, 5])),
        ];
        for t in &traversals {
            let np = t.num_pencils();
            let mut sim = CacheSim::new(cache);
            let r1 = simulate_pencils(t.as_ref(), 0..np / 3, &l, &s, &mut sim);
            let r2 = simulate_pencils(t.as_ref(), np / 3..2 * np / 3, &l, &s, &mut sim);
            let r3 = simulate_pencils(t.as_ref(), 2 * np / 3..np, &l, &s, &mut sim);
            let merged = MissReport::merged(&[r1, r2, r3]);
            let mut sim2 = CacheSim::new(cache);
            let whole = simulate(t.as_ref(), &l, &s, &mut sim2);
            assert_eq!(merged.points, whole.points);
            assert_eq!(merged.total, whole.total);
            assert_eq!(merged.u_loads, whole.u_loads);
            assert_eq!(merged.u_misses, whole.u_misses);
        }
    }

    #[test]
    fn merged_report_sums_counters() {
        let stats = CacheStats { accesses: 10, hits: 4, cold_misses: 6, ..CacheStats::default() };
        let a = MissReport { points: 3, total: stats, u_loads: 5, u_misses: 2, levels: LoadProfile::single(stats) };
        let m = MissReport::merged(&[a, a]);
        assert_eq!(m.points, 6);
        assert_eq!(m.total.accesses, 20);
        assert_eq!(m.total.misses(), 12);
        assert_eq!(m.u_loads, 10);
        assert_eq!(m.levels.get(crate::cache::Level::L1).unwrap(), m.total);
    }

    /// A tiny hierarchical machine small enough that every level sees
    /// replacement traffic on test-sized grids.
    fn tiny_machine() -> MachineModel {
        MachineModel {
            name: "tiny-full",
            l1: CacheParams::new(1, 8, 2),
            l2: Some(CacheParams::new(2, 16, 2)),
            tlb: Some(crate::cache::TlbParams { entries: 4, page_words: 16 }),
            latency: crate::cache::Latency::r10000(),
        }
    }

    #[test]
    fn hierarchy_report_l1_matches_single_level_run() {
        // The single-level §2 numbers must be bit-identical whether the
        // stream runs on a bare CacheSim or as the L1 of a hierarchy.
        let (g, s, l) = setup(&[10, 9]);
        let machine = tiny_machine();
        let t = natural_stream(&g, 1);
        let mut solo = CacheSim::new(machine.l1);
        let single = simulate(&t, &l, &s, &mut solo);
        let mut hier = machine.build_hierarchy();
        let multi = simulate(&t, &l, &s, &mut hier);
        assert_eq!(single.total, multi.total);
        assert_eq!(single.u_loads, multi.u_loads);
        assert_eq!(single.u_misses, multi.u_misses);
        assert_eq!(multi.levels.levels().len(), 3);
        assert_eq!(multi.levels.get(crate::cache::Level::L1).unwrap(), single.total);
    }

    #[test]
    fn apply_and_simulate_accepts_any_memory_model() {
        let (g, s, l) = setup(&[8, 7]);
        let words = g.storage_words() as usize;
        let mut rng = crate::util::rng::Rng::new(5);
        let u: Vec<f64> = (0..words).map(|_| rng.f64()).collect();
        let t = natural_stream(&g, 1);
        let mut q1 = vec![0.0; words];
        let mut hier = tiny_machine().build_hierarchy();
        let rep = apply_and_simulate(&t, &l, &s, &u, &mut q1, &mut hier);
        assert_eq!(rep.levels.levels().len(), 3);
        let mut q2 = vec![0.0; words];
        apply(&t, &g, &s, &u, &mut q2);
        assert_eq!(q1, q2);
    }

    #[test]
    fn sharded_hierarchy_merges_per_level_stats_consistently() {
        // The acceptance property: simulate_sharded with a Hierarchy must
        // merge per-level stats consistently with the sequential run — one
        // shard is exactly sequential (levels included); many shards keep
        // per-level accesses conserved where sharding cannot change them
        // (L1 and TLB see every word access) and only add boundary misses.
        use crate::cache::Level;
        let (g, s, l) = setup(&[14, 13]);
        let machine = tiny_machine();
        let t = natural_stream(&g, 1);
        let pool = ThreadPool::new(3);
        let mut hier = machine.build_hierarchy();
        let seq = simulate(&t, &l, &s, &mut hier);

        let one = simulate_sharded(&t, &l, &s, &machine, &pool, 1);
        assert_eq!(one.total, seq.total);
        assert_eq!(one.levels, seq.levels);

        for shards in [2usize, 5] {
            let shd = simulate_sharded(&t, &l, &s, &machine, &pool, shards);
            assert_eq!(shd.points, seq.points);
            let (sl, ql) = (seq.levels, shd.levels);
            for level in [Level::L1, Level::Tlb] {
                assert_eq!(ql.get(level).unwrap().accesses, sl.get(level).unwrap().accesses, "{shards} shards");
            }
            for lv in ql.levels() {
                assert_eq!(lv.stats.hits + lv.stats.misses(), lv.stats.accesses, "{:?}", lv.level);
            }
            // per-shard cold boundaries only add misses at every level
            // relative to the warm sequential run
            for level in [Level::L1, Level::Tlb] {
                assert!(ql.get(level).unwrap().misses() >= sl.get(level).unwrap().misses(), "{shards} shards");
            }
            // L2 sees exactly the L1 misses
            assert_eq!(ql.get(Level::L2).unwrap().accesses, ql.get(Level::L1).unwrap().misses());
        }
    }

    #[test]
    fn incremental_hierarchy_ranges_sum_cleanly() {
        // LoadProfile::delta correctness: successive ranges over one warm
        // hierarchy must merge (levels included) to the one-shot run.
        let (g, s, l) = setup(&[11, 10]);
        let machine = tiny_machine();
        let t = natural_stream(&g, 1);
        let np = t.num_pencils();
        let mut hier = machine.build_hierarchy();
        let r1 = simulate_pencils(&t, 0..np / 2, &l, &s, &mut hier);
        let r2 = simulate_pencils(&t, np / 2..np, &l, &s, &mut hier);
        let merged = MissReport::merged(&[r1, r2]);
        let mut hier2 = machine.build_hierarchy();
        let whole = simulate(&t, &l, &s, &mut hier2);
        assert_eq!(merged.total, whole.total);
        assert_eq!(merged.levels, whole.levels);
        assert_eq!(merged.u_loads, whole.u_loads);
    }
}
