//! The stencil execution engine: walks a traversal [`Order`] and either
//! feeds the induced address stream to a cache simulator (**analysis
//! mode**) or computes the stencil numerically (**numeric mode**), or both.
//!
//! The engine is the moral equivalent of the measured Fortran loop nests in
//! the paper's §6: per interior point it issues `|K|` reads of `u` (one per
//! stencil vector, in stencil order) followed by one write of `q`, exactly
//! like the compiled `q(i1,j,k) = c0*u(i1,j,k) + …` statement.

use crate::cache::{CacheSim, CacheStats};
use crate::grid::{GridDesc, MultiArrayLayout};
use crate::stencil::Stencil;
use crate::traversal::Order;

/// Result of an analysis-mode run.
#[derive(Debug, Clone, Copy)]
pub struct MissReport {
    /// Interior points visited.
    pub points: u64,
    /// Combined counters over the whole address stream (u reads + q writes).
    pub total: CacheStats,
    /// Counters attributable to reads of the RHS array(s) only — the
    /// quantity the paper's bounds constrain (loads of `u`).
    pub u_loads: u64,
    pub u_misses: u64,
}

impl MissReport {
    /// Misses per interior point (the y-axis of Figure 4).
    pub fn misses_per_point(&self) -> f64 {
        if self.points == 0 {
            0.0
        } else {
            self.total.misses() as f64 / self.points as f64
        }
    }

    /// Loads of u per interior point — comparable against Eq 7 / Eq 12
    /// (which are stated per grid point).
    pub fn u_loads_per_point(&self) -> f64 {
        if self.points == 0 {
            0.0
        } else {
            self.u_loads as f64 / self.points as f64
        }
    }
}

/// Simulate the cache behaviour of evaluating `stencil` over `order`,
/// with `u` at `layout.base(i)` for each RHS array and `q` at
/// `layout.q_base()`. Every RHS array is read at every stencil point
/// (the §5 multi-array model); `p = layout.num_arrays()`.
pub fn simulate(
    order: &Order,
    layout: &MultiArrayLayout,
    stencil: &Stencil,
    sim: &mut CacheSim,
) -> MissReport {
    let grid = layout.grid().clone();
    let d = grid.ndim();
    assert_eq!(stencil.ndim(), d);
    let deltas: Vec<i64> = stencil.offsets().iter().map(|o| grid.delta_of(o)).collect();
    let p = layout.num_arrays();
    let bases: Vec<i64> = (0..p).map(|i| layout.base(i) as i64).collect();
    let q_base = layout.q_base() as i64;

    let mut u_loads = 0u64;
    let mut u_misses = 0u64;

    let mut x = vec![0i64; d];
    for &packed in order.packed() {
        Order::unpack(packed, &mut x);
        let off = grid.offset_of(&x) as i64;
        let pre = sim.stats();
        for &b in &bases {
            let base = b + off;
            for &dl in &deltas {
                sim.access((base + dl) as u64);
            }
        }
        let post = sim.stats();
        u_loads += post.loads() - pre.loads();
        u_misses += post.misses() - pre.misses();
        // write q(x): one access (write-allocate).
        sim.access((q_base + off) as u64);
    }
    MissReport { points: order.len() as u64, total: sim.stats(), u_loads, u_misses }
}

/// Numeric mode: compute `q(x) = Σ c_i·u(x + k_i)` over the order, for a
/// single RHS array. Buffers are sized by `grid.storage_words()`.
pub fn apply(order: &Order, grid: &GridDesc, stencil: &Stencil, u: &[f64], q: &mut [f64]) {
    let d = grid.ndim();
    assert_eq!(stencil.ndim(), d);
    assert!(u.len() as u64 >= grid.storage_words(), "u buffer too small");
    assert!(q.len() as u64 >= grid.storage_words(), "q buffer too small");
    let deltas: Vec<i64> = stencil.offsets().iter().map(|o| grid.delta_of(o)).collect();
    let coeffs = stencil.coeffs();
    let mut x = vec![0i64; d];
    for &packed in order.packed() {
        Order::unpack(packed, &mut x);
        let base = grid.offset_of(&x) as i64;
        let mut acc = 0.0;
        for (&c, &dl) in coeffs.iter().zip(&deltas) {
            acc += c * u[(base + dl) as usize];
        }
        q[base as usize] = acc;
    }
}

/// Combined mode used by tests: numeric result plus miss report in one
/// sweep (numbers must be identical to running the two modes separately).
pub fn apply_and_simulate(
    order: &Order,
    layout: &MultiArrayLayout,
    stencil: &Stencil,
    u: &[f64],
    q: &mut [f64],
    sim: &mut CacheSim,
) -> MissReport {
    let report = simulate(order, layout, stencil, sim);
    apply(order, layout.grid(), stencil, u, q);
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::CacheParams;
    use crate::traversal::{cache_fitting_for_cache, natural};

    fn setup(dims: &[usize]) -> (GridDesc, Stencil, MultiArrayLayout) {
        let g = GridDesc::new(dims);
        let s = Stencil::star(dims.len(), 1);
        let l = MultiArrayLayout::contiguous(&g, 1);
        (g, s, l)
    }

    #[test]
    fn simulate_counts_expected_accesses() {
        let (g, s, l) = setup(&[6, 6]);
        let order = natural(&g, 1);
        let mut sim = CacheSim::new(CacheParams::new(2, 8, 2));
        let rep = simulate(&order, &l, &s, &mut sim);
        let pts = g.interior_points(1);
        assert_eq!(rep.points, pts);
        // |K| u-reads + 1 q-write per point
        assert_eq!(rep.total.accesses, pts * (s.size() as u64 + 1));
    }

    #[test]
    fn u_loads_lower_bounded_by_distinct_points() {
        // Every distinct u word read is at least one cold load: for a star
        // stencil over the full interior, the K-extension is touched.
        let (g, s, l) = setup(&[8, 8]);
        let order = natural(&g, 1);
        let mut sim = CacheSim::new(CacheParams::new(2, 16, 2));
        let rep = simulate(&order, &l, &s, &mut sim);
        // K-extension of the interior of an 8×8 grid with r=1 star: the
        // interior 6×6 plus one-deep faces = 36 + 4*6 = 60 points.
        assert!(rep.u_loads >= 60, "u_loads = {}", rep.u_loads);
    }

    #[test]
    fn apply_matches_direct_computation() {
        let (g, s, _) = setup(&[7, 5]);
        let words = g.storage_words() as usize;
        let mut rng = crate::util::rng::Rng::new(8);
        let u: Vec<f64> = (0..words).map(|_| rng.f64()).collect();
        let mut q1 = vec![0.0; words];
        let mut q2 = vec![0.0; words];
        apply(&natural(&g, 1), &g, &s, &u, &mut q1);
        // direct nested-loop reference
        for j in 1..4i64 {
            for i in 1..6i64 {
                let mut acc = 0.0;
                for (o, &c) in s.offsets().iter().zip(s.coeffs()) {
                    let idx = g.offset_of(&[i + o[0], j + o[1]]) as usize;
                    acc += c * u[idx];
                }
                q2[g.offset_of(&[i, j]) as usize] = acc;
            }
        }
        assert_eq!(q1, q2);
    }

    #[test]
    fn apply_result_independent_of_order() {
        // The stencil is explicit (reads u, writes q): any visit order gives
        // identical results. This is the safety property that lets the
        // coordinator swap traversals freely.
        let (g, s, _) = setup(&[10, 9]);
        let words = g.storage_words() as usize;
        let mut rng = crate::util::rng::Rng::new(9);
        let u: Vec<f64> = (0..words).map(|_| rng.f64()).collect();
        let mut q_nat = vec![0.0; words];
        let mut q_fit = vec![0.0; words];
        let cache = CacheParams::new(1, 16, 2);
        apply(&natural(&g, 1), &g, &s, &u, &mut q_nat);
        apply(&cache_fitting_for_cache(&g, 1, &cache), &g, &s, &u, &mut q_fit);
        assert_eq!(q_nat, q_fit);
    }

    #[test]
    fn multi_rhs_reads_all_arrays() {
        let g = GridDesc::new(&[6, 6]);
        let s = Stencil::star(2, 1);
        let l = MultiArrayLayout::contiguous(&g, 3);
        let order = natural(&g, 1);
        let mut sim = CacheSim::new(CacheParams::new(2, 64, 2));
        let rep = simulate(&order, &l, &s, &mut sim);
        let pts = g.interior_points(1);
        assert_eq!(rep.total.accesses, pts * (3 * s.size() as u64 + 1));
    }

    #[test]
    fn report_rates() {
        let (g, s, l) = setup(&[6, 6]);
        let order = natural(&g, 1);
        let mut sim = CacheSim::new(CacheParams::new(2, 8, 2));
        let rep = simulate(&order, &l, &s, &mut sim);
        assert!(rep.misses_per_point() > 0.0);
        assert!(rep.u_loads_per_point() >= 1.0); // ≥ 1 load per point (Eq 7 prefactor)
    }
}
