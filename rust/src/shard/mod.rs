//! Shard/halo decomposition layer (DESIGN.md §2.9).
//!
//! The paper's §4–§6 traffic bounds are surface-to-volume arguments per
//! cache level. Hupp & Jacob's *parallel external memory* (PEM) model
//! (PAPERS.md) applies the same argument one level further out: when a
//! grid is decomposed into axis-aligned shards, the words a shard must
//! load beyond its owned box are exactly its ghost (halo) surface, and
//! per exchange they are bounded by `Π(ŵ_i + 2r) − Π ŵ_i` where `ŵ_i` is
//! the largest owned extent along axis `i` and `r` the stencil radius.
//! This module makes that decomposition first-class instead of the
//! implicit pencil-range split the coordinator used to bury in
//! `engine::apply_sharded`:
//!
//! - [`ShardPlan`] — grid → shard-box geometry: per-axis cuts, owned
//!   boxes, halo-extended boxes of width `r`, owner lookup, and the
//!   measured-vs-bound halo accounting ([`ShardPlan::halo_words`] vs
//!   [`ShardPlan::pem_halo_bound`]);
//! - [`HaloMsg`] ([`msg`]) — the typed exchange buffer; ghost values move
//!   between shards **only** inside these messages, so a network
//!   transport is a drop-in replacement for the in-process exchange;
//! - [`ShardedField`] ([`field`]) — per-shard block storage with an
//!   in-memory backend (per-shard allocation, NUMA-friendly: each block
//!   is touched only by its worker) and an **out-of-core** backend (one
//!   disk tile per shard, streamed under a configurable RAM budget), plus
//!   the block-decomposed solve driver [`field::solve_blocks`] whose
//!   result field is bitwise identical to the unsharded path (every
//!   interior row runs through `engine::kernel::update_row`, the ONE
//!   shared row kernel).
//!
//! The measured halo is exact, not modelled: because owned boxes
//! partition the grid, every ghost cell of a shard has exactly one owner,
//! so the words carried by [`HaloMsg`]s equal the geometric
//! `Σ_s (|halo_box(s)| − |owned_box(s)|)` — an invariant the property
//! tests pin. Clipping at the physical boundary only shrinks halo boxes,
//! so measured ≤ PEM bound always holds.

pub mod field;
pub mod msg;

pub use field::{
    solve_blocks, solve_blocks_cfg, solve_blocks_with_field, solve_blocks_with_field_cfg, BlockSolveOutcome,
    ShardStorage, ShardedField, StepNorms,
};
pub use msg::HaloMsg;

use crate::traversal::shard_ranges;
use std::ops::Range;

/// Ceiling on the total block-shard count the planner's budget refinement
/// will reach for — a backstop against degenerate grids, far above any
/// sensible decomposition (cf. `MAX_SHARDS` for the pencil fan-out).
pub const MAX_BLOCK_SHARDS: usize = 512;

/// Number of points in an axis-aligned box.
pub fn box_words(b: &[Range<i64>]) -> u64 {
    b.iter().map(|rg| (rg.end - rg.start).max(0) as u64).product()
}

/// Column-major (dim-0-fastest) strides over a box's extents.
pub(crate) fn box_strides(b: &[Range<i64>]) -> Vec<u64> {
    let mut s = vec![1u64; b.len()];
    for i in 1..b.len() {
        s[i] = s[i - 1] * (b[i - 1].end - b[i - 1].start).max(0) as u64;
    }
    s
}

/// Visit the rows of a box: runs along dim 0, higher dims advancing
/// dim-1-fastest. Calls `f(row_start_coords, row_len)` per row. The halo
/// pack/unpack paths and the out-of-core tile IO all iterate through this
/// one helper, so payload order is column-major everywhere by
/// construction.
pub(crate) fn for_each_row(region: &[Range<i64>], mut f: impl FnMut(&[i64], usize)) {
    let d = region.len();
    if region.iter().any(|rg| rg.end <= rg.start) {
        return;
    }
    let row_len = (region[0].end - region[0].start) as usize;
    let mut x: Vec<i64> = region.iter().map(|rg| rg.start).collect();
    loop {
        f(&x, row_len);
        let mut i = 1;
        loop {
            if i == d {
                return;
            }
            x[i] += 1;
            if x[i] < region[i].end {
                break;
            }
            x[i] = region[i].start;
            i += 1;
        }
    }
}

/// The two-level decomposition of a logical grid into axis-aligned shards
/// with ghost regions of width `r` (the stencil radius).
///
/// Owned boxes partition `[0, n_i)` per axis via the same near-equal
/// contiguous split as `traversal::shard_ranges`, so every grid point —
/// boundary included — has exactly one owner. The halo-extended box of a
/// shard is its owned box grown by `r` per side, clipped to the grid.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardPlan {
    dims: Vec<usize>,
    grid: Vec<usize>,
    /// Per axis: ascending cut coordinates, `grid[i] + 1` entries from 0
    /// to `dims[i]`; axis-shard `k` owns `cuts[i][k]..cuts[i][k+1]`.
    cuts: Vec<Vec<i64>>,
    r: usize,
    /// Temporal halo depth: halo boxes extend `depth · r` per side so one
    /// exchange feeds a `depth`-step sweep. Classic single-step exchange
    /// is `depth == 1`.
    depth: usize,
}

impl ShardPlan {
    /// Decompose `dims` into `shard_grid[i]` slabs per axis with ghost
    /// width `r`. Axis counts are clamped to `1..=dims[i]`.
    pub fn new(dims: &[usize], shard_grid: &[usize], r: usize) -> ShardPlan {
        ShardPlan::with_depth(dims, shard_grid, r, 1)
    }

    /// [`ShardPlan::new`] with a temporal halo depth: ghost regions are
    /// `depth · r` wide, sized for `depth` stencil applications between
    /// exchanges. `depth` is clamped to ≥ 1.
    pub fn with_depth(dims: &[usize], shard_grid: &[usize], r: usize, depth: usize) -> ShardPlan {
        assert!(!dims.is_empty(), "zero-dimensional shard plan");
        assert_eq!(dims.len(), shard_grid.len(), "shard grid arity mismatch");
        assert!(dims.iter().all(|&n| n >= 1), "dims must be positive: {dims:?}");
        let mut grid = Vec::with_capacity(dims.len());
        let mut cuts = Vec::with_capacity(dims.len());
        for (&n, &g) in dims.iter().zip(shard_grid) {
            let ranges = shard_ranges(n, g.max(1));
            grid.push(ranges.len());
            let mut c: Vec<i64> = ranges.iter().map(|rg| rg.start as i64).collect();
            c.push(n as i64);
            cuts.push(c);
        }
        ShardPlan { dims: dims.to_vec(), grid, cuts, r, depth: depth.max(1) }
    }

    pub fn ndim(&self) -> usize {
        self.dims.len()
    }

    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    /// Shards per axis.
    pub fn shard_grid(&self) -> &[usize] {
        &self.grid
    }

    /// Ghost width (stencil radius).
    pub fn radius(&self) -> usize {
        self.r
    }

    /// Temporal halo depth (steps one exchange feeds); 1 = classic.
    pub fn depth(&self) -> usize {
        self.depth
    }

    /// Ascending cut coordinates along `axis`: `shard_grid()[axis] + 1`
    /// entries from 0 to `dims[axis]`; axis-shard `k` owns
    /// `cuts[k]..cuts[k + 1]`.
    pub fn axis_cuts(&self, axis: usize) -> &[i64] {
        &self.cuts[axis]
    }

    pub fn num_shards(&self) -> usize {
        self.grid.iter().product()
    }

    /// Logical grid points |G|.
    pub fn num_points(&self) -> u64 {
        self.dims.iter().map(|&n| n as u64).product()
    }

    /// Per-axis shard coordinates of shard `s` (dim-0 fastest, matching
    /// the temporal tile odometer).
    pub fn shard_coords(&self, s: usize) -> Vec<usize> {
        debug_assert!(s < self.num_shards());
        let mut c = vec![0usize; self.grid.len()];
        let mut k = s;
        for i in 0..self.grid.len() {
            c[i] = k % self.grid[i];
            k /= self.grid[i];
        }
        c
    }

    /// Inverse of [`ShardPlan::shard_coords`].
    pub fn shard_index(&self, coords: &[usize]) -> usize {
        debug_assert_eq!(coords.len(), self.grid.len());
        let mut s = 0usize;
        let mut stride = 1usize;
        for i in 0..self.grid.len() {
            debug_assert!(coords[i] < self.grid[i]);
            s += coords[i] * stride;
            stride *= self.grid[i];
        }
        s
    }

    /// The box of points shard `s` owns (a partition cell of the grid).
    pub fn owned_box(&self, s: usize) -> Vec<Range<i64>> {
        let c = self.shard_coords(s);
        c.iter().zip(&self.cuts).map(|(&k, cut)| cut[k]..cut[k + 1]).collect()
    }

    /// The owned box grown by `depth · r` per side, clipped to the grid —
    /// the region shard `s` must hold to apply `depth` stencil sweeps at
    /// every owned interior point without a fresh exchange.
    pub fn halo_box(&self, s: usize) -> Vec<Range<i64>> {
        self.grown_box(s, (self.depth * self.r) as i64)
    }

    /// The owned box grown by `g` per side, clipped to the grid.
    fn grown_box(&self, s: usize, g: i64) -> Vec<Range<i64>> {
        self.owned_box(s)
            .iter()
            .zip(&self.dims)
            .map(|(rg, &n)| (rg.start - g).max(0)..(rg.end + g).min(n as i64))
            .collect()
    }

    /// The box sweep-step `s` (1-based) of a `kk`-step superstep writes
    /// for shard `shard`: the owned box grown by `(kk − s) · r`, clipped.
    /// Step `kk` writes exactly the owned box; step 1 writes the widest
    /// rind, one diameter inside the `kk·r`-deep halo box.
    pub fn sweep_box(&self, shard: usize, kk: usize, s: usize) -> Vec<Range<i64>> {
        debug_assert!(s >= 1 && s <= kk && kk <= self.depth);
        self.grown_box(shard, ((kk - s) * self.r) as i64)
    }

    /// Which shard owns logical point `x`.
    pub fn owner_of(&self, x: &[i64]) -> usize {
        debug_assert_eq!(x.len(), self.dims.len());
        let mut coords = vec![0usize; self.dims.len()];
        for i in 0..self.dims.len() {
            debug_assert!(x[i] >= 0 && (x[i] as usize) < self.dims[i]);
            // cuts are ascending; the owner is the last cut ≤ x_i.
            coords[i] = self.cuts[i].partition_point(|&c| c <= x[i]) - 1;
        }
        self.shard_index(&coords)
    }

    /// The halo sources of shard `dst`: every other shard whose owned box
    /// intersects `dst`'s halo-extended box, with the intersection region
    /// (global coordinates). Deterministic order: source shards ascend in
    /// the dim-0-fastest odometer. Because owned boxes partition the grid,
    /// the returned regions tile `halo_box(dst) \ owned_box(dst)` exactly.
    pub fn sources_for(&self, dst: usize) -> Vec<(usize, Vec<Range<i64>>)> {
        let d = self.dims.len();
        let ext = self.halo_box(dst);
        // per-axis range of axis-shard indices overlapping the halo box
        let mut lo = vec![0usize; d];
        let mut hi = vec![0usize; d];
        for i in 0..d {
            lo[i] = self.cuts[i].partition_point(|&c| c <= ext[i].start) - 1;
            hi[i] = self.cuts[i].partition_point(|&c| c <= ext[i].end - 1) - 1;
        }
        let mut out = Vec::new();
        let mut c = lo.clone();
        loop {
            let s = self.shard_index(&c);
            if s != dst {
                let owned = self.owned_box(s);
                let region: Vec<Range<i64>> = ext
                    .iter()
                    .zip(&owned)
                    .map(|(e, o)| e.start.max(o.start)..e.end.min(o.end))
                    .collect();
                if box_words(&region) > 0 {
                    out.push((s, region));
                }
            }
            let mut i = 0;
            loop {
                if i == d {
                    out.sort_by_key(|(s, _)| *s);
                    return out;
                }
                c[i] += 1;
                if c[i] <= hi[i] {
                    break;
                }
                c[i] = lo[i];
                i += 1;
            }
        }
    }

    /// Ghost words one full exchange loads, summed over shards — the
    /// *measured* per-step halo traffic (exact: clipped extended boxes
    /// minus owned boxes).
    pub fn halo_words(&self) -> u64 {
        (0..self.num_shards()).map(|s| box_words(&self.halo_box(s)) - box_words(&self.owned_box(s))).sum()
    }

    /// The PEM surface-to-volume bound on one exchange:
    /// `shards · (Π(ŵ_i + 2·depth·r) − Π ŵ_i)` with `ŵ_i = ⌈n_i / g_i⌉`
    /// the largest owned extent per axis. Boundary clipping only shrinks
    /// halo boxes and the surface term is monotone in the extents, so
    /// [`ShardPlan::halo_words`] ≤ this bound always.
    pub fn pem_halo_bound(&self) -> u64 {
        let grown: u64 = self
            .dims
            .iter()
            .zip(&self.grid)
            .map(|(&n, &g)| (n.div_ceil(g) + 2 * self.depth * self.r) as u64)
            .product();
        let owned: u64 = self.dims.iter().zip(&self.grid).map(|(&n, &g)| n.div_ceil(g) as u64).product();
        self.num_shards() as u64 * (grown - owned)
    }

    /// Stencil-interior points a `kk`-step superstep computes *beyond*
    /// what `kk` classic single steps would — the redundant ghost-zone
    /// recompute that deep halos trade against exchange rounds:
    /// `Σ_shards Σ_{s=1..kk} (|sweep_box(s) ∩ I| − |owned ∩ I|)` with `I`
    /// the global stencil interior `[r, n_i − r)`.
    pub fn redundant_points(&self, kk: usize) -> u64 {
        let r = self.r as i64;
        let interior: Vec<Range<i64>> = self.dims.iter().map(|&n| r..(n as i64 - r)).collect();
        let clip = |b: &[Range<i64>]| -> u64 {
            box_words(
                &b.iter()
                    .zip(&interior)
                    .map(|(x, i)| x.start.max(i.start)..x.end.min(i.end))
                    .collect::<Vec<_>>(),
            )
        };
        let mut extra = 0u64;
        for shard in 0..self.num_shards() {
            let owned_i = clip(&self.owned_box(shard));
            for s in 1..=kk.min(self.depth) {
                extra += clip(&self.sweep_box(shard, kk.min(self.depth), s)) - owned_i;
            }
        }
        extra
    }

    /// Measured halo words per grid point per exchange — the
    /// EXPERIMENTS.md / bench-gate words-per-point figure.
    pub fn halo_words_per_point(&self) -> f64 {
        self.halo_words() as f64 / self.num_points() as f64
    }

    /// Bound counterpart of [`ShardPlan::halo_words_per_point`].
    pub fn pem_halo_bound_per_point(&self) -> f64 {
        self.pem_halo_bound() as f64 / self.num_points() as f64
    }

    /// Peak resident words one shard's step needs: the halo-extended
    /// read buffer, the owned write block, and the transient [`HaloMsg`]
    /// payloads (which sum to halo-box minus owned words) — `2·|ext|` per
    /// concurrently processed shard. A deep plan (`depth > 1`) ping-pongs
    /// two halo-box buffers *and* extracts the owned block at the end, so
    /// its peak is `2·|ext| + |owned|`. The out-of-core driver divides
    /// the RAM budget by this to pick its concurrency.
    pub fn peak_working_words(&self) -> u64 {
        (0..self.num_shards())
            .map(|s| {
                let ext = 2 * box_words(&self.halo_box(s));
                if self.depth > 1 {
                    ext + box_words(&self.owned_box(s))
                } else {
                    ext
                }
            })
            .max()
            .unwrap_or(0)
    }
}

/// Choose a shard grid for `dims` by the PEM surface/volume criterion:
/// repeatedly halve the axis with the largest local slab extent (halo
/// surface shrinks fastest where the slab is longest) until `target`
/// shards are reached, never cutting a slab below the stencil diameter
/// `2r + 1` (a thinner slab would load more ghost words than it owns).
/// Ties prefer the highest axis, keeping dim-0 runs long — contiguous
/// rows for the streaming traversal and the disk tiles.
pub fn choose_shard_grid(dims: &[usize], r: usize, target: usize) -> Vec<usize> {
    let d = dims.len();
    let mut grid = vec![1usize; d];
    let min_extent = 2 * r + 1;
    let mut shards = 1usize;
    while shards < target {
        let mut best: Option<usize> = None;
        for i in 0..d {
            if dims[i] / (grid[i] * 2) < min_extent {
                continue;
            }
            let ext = dims[i] / grid[i];
            let better = match best {
                None => true,
                Some(b) => {
                    let bext = dims[b] / grid[b];
                    ext > bext || (ext == bext && i > b)
                }
            };
            if better {
                best = Some(i);
            }
        }
        match best {
            Some(i) => {
                grid[i] *= 2;
                shards *= 2;
            }
            None => break,
        }
    }
    grid
}

/// Grow `grid` until every shard's working set fits `budget_words`
/// ([`ShardPlan::peak_working_words`]), splitting by the same
/// longest-axis criterion as [`choose_shard_grid`]. Stops at
/// [`MAX_BLOCK_SHARDS`] or when no axis can be cut without dropping below
/// the stencil diameter; the solve driver reports the budget violation if
/// refinement ran out of axes.
pub fn refine_grid_for_budget(dims: &[usize], r: usize, mut grid: Vec<usize>, budget_words: u64) -> Vec<usize> {
    let min_extent = 2 * r + 1;
    loop {
        let plan = ShardPlan::new(dims, &grid, r);
        if plan.peak_working_words() <= budget_words || plan.num_shards() >= MAX_BLOCK_SHARDS {
            return grid;
        }
        let mut best: Option<usize> = None;
        for i in 0..dims.len() {
            if dims[i] / (grid[i] * 2) < min_extent {
                continue;
            }
            let ext = dims[i] / grid[i];
            let better = match best {
                None => true,
                Some(b) => {
                    let bext = dims[b] / grid[b];
                    ext > bext || (ext == bext && i > b)
                }
            };
            if better {
                best = Some(i);
            }
        }
        match best {
            Some(i) => grid[i] *= 2,
            None => return grid,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cuts_partition_every_axis() {
        let p = ShardPlan::new(&[10, 7, 5], &[3, 2, 1], 1);
        assert_eq!(p.num_shards(), 6);
        for (i, &n) in p.dims().iter().enumerate() {
            assert_eq!(p.cuts[i][0], 0);
            assert_eq!(*p.cuts[i].last().unwrap(), n as i64);
            for w in p.cuts[i].windows(2) {
                assert!(w[0] < w[1], "axis {i}: empty or inverted cell {w:?}");
            }
        }
    }

    #[test]
    fn owned_boxes_partition_the_grid() {
        let p = ShardPlan::new(&[9, 8], &[2, 3], 2);
        let mut owned_total = 0u64;
        for s in 0..p.num_shards() {
            owned_total += box_words(&p.owned_box(s));
        }
        assert_eq!(owned_total, p.num_points());
        // every point's owner contains it
        for x0 in 0..9i64 {
            for x1 in 0..8i64 {
                let s = p.owner_of(&[x0, x1]);
                let b = p.owned_box(s);
                assert!(b[0].contains(&x0) && b[1].contains(&x1), "({x0},{x1}) not in owner's box {b:?}");
            }
        }
    }

    #[test]
    fn shard_index_roundtrip() {
        let p = ShardPlan::new(&[16, 16, 16], &[2, 3, 2], 1);
        for s in 0..p.num_shards() {
            assert_eq!(p.shard_index(&p.shard_coords(s)), s);
        }
    }

    #[test]
    fn halo_box_is_owned_grown_by_radius_clipped() {
        for r in [1usize, 2, 4] {
            let p = ShardPlan::new(&[32, 32], &[2, 2], r);
            for s in 0..p.num_shards() {
                let o = p.owned_box(s);
                let h = p.halo_box(s);
                for i in 0..2 {
                    assert_eq!(h[i].start, (o[i].start - r as i64).max(0));
                    assert_eq!(h[i].end, (o[i].end + r as i64).min(32));
                }
            }
        }
    }

    #[test]
    fn sources_tile_the_halo_exactly() {
        let p = ShardPlan::new(&[12, 10, 8], &[2, 2, 2], 2);
        for dst in 0..p.num_shards() {
            let srcs = p.sources_for(dst);
            let words: u64 = srcs.iter().map(|(_, rg)| box_words(rg)).sum();
            assert_eq!(words, box_words(&p.halo_box(dst)) - box_words(&p.owned_box(dst)));
            // regions are pairwise disjoint (owners partition the grid)
            for (a, (sa, ra)) in srcs.iter().enumerate() {
                assert_ne!(*sa, dst);
                for (sb, rb) in srcs.iter().skip(a + 1) {
                    assert_ne!(sa, sb);
                    let overlap: u64 = ra
                        .iter()
                        .zip(rb)
                        .map(|(x, y)| (x.end.min(y.end) - x.start.max(y.start)).max(0) as u64)
                        .product();
                    assert_eq!(overlap, 0, "regions of src {sa} and {sb} overlap");
                }
            }
        }
    }

    #[test]
    fn thin_slabs_pull_ghosts_from_non_neighbors() {
        // slab width 1 < r = 2: the halo of a middle shard spans two
        // shards per side, so sources_for must reach past adjacency.
        let p = ShardPlan::new(&[8], &[8], 2);
        let srcs = p.sources_for(4);
        let ids: Vec<usize> = srcs.iter().map(|(s, _)| *s).collect();
        assert_eq!(ids, vec![2, 3, 5, 6]);
    }

    #[test]
    fn measured_halo_never_exceeds_pem_bound() {
        for (dims, grid, r) in [
            (vec![64usize, 64, 64], vec![2usize, 2, 2], 2usize),
            (vec![45, 91, 100], vec![1, 2, 4], 1),
            (vec![17, 9], vec![4, 3], 2),
            (vec![33], vec![5], 4),
        ] {
            let p = ShardPlan::new(&dims, &grid, r);
            assert!(
                p.halo_words() <= p.pem_halo_bound(),
                "{dims:?}/{grid:?}/r{r}: measured {} > bound {}",
                p.halo_words(),
                p.pem_halo_bound()
            );
        }
    }

    #[test]
    fn single_shard_has_no_halo() {
        let p = ShardPlan::new(&[20, 20, 20], &[1, 1, 1], 2);
        assert_eq!(p.halo_words(), 0);
        assert_eq!(p.pem_halo_bound(), 0);
        assert!(p.sources_for(0).is_empty());
    }

    #[test]
    fn interior_2x2x2_halo_matches_closed_form() {
        // 128³ split 2×2×2 at r = 2: every shard is a corner — two clipped
        // sides per axis — so each extended box is 66³ over a 64³ owned box.
        let p = ShardPlan::new(&[128, 128, 128], &[2, 2, 2], 2);
        assert_eq!(p.halo_words(), 8 * (66u64.pow(3) - 64u64.pow(3)));
        assert_eq!(p.pem_halo_bound(), 8 * (68u64.pow(3) - 64u64.pow(3)));
    }

    #[test]
    fn deep_plan_grows_halo_by_depth_times_radius() {
        let shallow = ShardPlan::new(&[32, 32], &[2, 2], 2);
        let deep = ShardPlan::with_depth(&[32, 32], &[2, 2], 2, 3);
        assert_eq!(shallow.depth(), 1);
        assert_eq!(deep.depth(), 3);
        for s in 0..deep.num_shards() {
            let o = deep.owned_box(s);
            let h = deep.halo_box(s);
            for i in 0..2 {
                assert_eq!(h[i].start, (o[i].start - 6).max(0));
                assert_eq!(h[i].end, (o[i].end + 6).min(32));
            }
            // sweep boxes shrink by r per step down to the owned box
            assert_eq!(deep.sweep_box(s, 3, 3), o);
            let s1 = deep.sweep_box(s, 3, 1);
            for i in 0..2 {
                assert_eq!(s1[i].start, (o[i].start - 4).max(0));
                assert_eq!(s1[i].end, (o[i].end + 4).min(32));
            }
        }
        // depth scales the PEM surface term too
        assert!(deep.pem_halo_bound() > shallow.pem_halo_bound());
        assert_eq!(deep.pem_halo_bound(), 4 * ((16 + 12) * (16 + 12) - 16 * 16));
        // a 1-step superstep recomputes nothing
        assert_eq!(deep.redundant_points(1), 0);
        assert!(deep.redundant_points(3) > deep.redundant_points(2));
    }

    #[test]
    fn choose_grid_splits_longest_axis_first() {
        let g = choose_shard_grid(&[256, 64, 64], 2, 4);
        assert_eq!(g, vec![4, 1, 1]);
        let g = choose_shard_grid(&[128, 128, 128], 2, 8);
        assert_eq!(g, vec![2, 2, 2]);
        // ties prefer the highest axis (long dim-0 rows survive)
        let g = choose_shard_grid(&[64, 64, 64], 2, 2);
        assert_eq!(g, vec![1, 1, 2]);
    }

    #[test]
    fn choose_grid_respects_stencil_diameter_floor() {
        // 12 points at r = 2: diameter 5, so one halving (extent 6) is
        // legal but a second (extent 3) is not.
        let g = choose_shard_grid(&[12], 2, 64);
        assert_eq!(g, vec![2]);
        // nothing splittable at all
        let g = choose_shard_grid(&[6, 6], 2, 8);
        assert_eq!(g, vec![1, 1]);
    }

    #[test]
    fn refine_grid_reaches_the_budget() {
        let dims = vec![128usize, 128, 128];
        let base = choose_shard_grid(&dims, 2, 1);
        assert_eq!(base, vec![1, 1, 1]);
        // budget of two 68³ boxes forces roughly 2×2×2 blocks
        let refined = refine_grid_for_budget(&dims, 2, base, 2 * 68 * 68 * 68);
        let p = ShardPlan::new(&dims, &refined, 2);
        assert!(p.peak_working_words() <= 2 * 68 * 68 * 68, "{refined:?}");
        assert!(p.num_shards() >= 8);
    }
}
