//! Typed halo exchange messages.
//!
//! A [`HaloMsg`] is the **only** channel through which ghost values cross
//! a shard boundary: the solve driver builds one message per
//! (source, destination) pair from [`super::ShardPlan::sources_for`],
//! fills its payload by reading the source's *old* block, and unpacks it
//! into the destination's halo-extended compute buffer. Keeping the
//! exchange typed — a global-coordinate region plus a column-major
//! payload — is what makes a network transport a drop-in later: serialize
//! the struct, nothing else changes.

use std::ops::Range;

/// One ghost-region transfer from shard `src` to shard `dst`.
#[derive(Debug, Clone, PartialEq)]
pub struct HaloMsg {
    /// Owning shard the ghost values are read from.
    pub src: usize,
    /// Shard whose halo-extended buffer receives them.
    pub dst: usize,
    /// Global-coordinate box of the transferred region
    /// (`halo_box(dst) ∩ owned_box(src)`).
    pub region: Vec<Range<i64>>,
    /// The region's values in column-major (dim-0-fastest) order;
    /// `data.len() == words()`.
    pub data: Vec<f64>,
}

impl HaloMsg {
    /// Number of ghost words this message carries.
    pub fn words(&self) -> u64 {
        super::box_words(&self.region)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn words_is_region_volume() {
        let m = HaloMsg { src: 0, dst: 1, region: vec![0..3, 2..4], data: vec![0.0; 6] };
        assert_eq!(m.words(), 6);
        assert_eq!(m.data.len() as u64, m.words());
    }
}
